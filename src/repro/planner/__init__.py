"""Sparse einsum planner: cost-model-driven contraction paths with plan
caching and kernel dispatch (DESIGN.md §5).

Layering::

    ir.py        einsum IR — parse + classify into contraction families
    cost.py      paper §5.3 flop/memory formulas per candidate path
    plan.py      path enumeration, ranking, plan cache, autotuning
    tuner.py     measured kernel-tile autotuning + on-disk plan cache
    dispatch.py  lowering onto repro.sparse.ops / repro.kernels

``repro.core.api.einsum`` and ``api.TTTP`` are thin shims over
:func:`planned_einsum`; the completion solvers opt in through the
``path=`` overrides of :func:`planned_mttkrp` / :func:`planned_tttp`.
"""
from __future__ import annotations

import functools
from typing import List, Optional, Sequence, Tuple

import jax

from repro.core.distributed import AxisCtx, LOCAL
from repro.core.sparse_tensor import SparseTensor
from repro.planner import config as _pconfig
from repro.planner.config import (DEFAULT_CONFIG, PlannerConfig,
                                  default_config, set_default_config)
from repro.planner.cost import PathCost, candidate_paths, estimate, rank_paths
from repro.planner.dispatch import execute
from repro.planner.ir import ContractionIR, DistInfo, build_ir
from repro.planner.plan import (Plan, clear_plan_cache, plan_cache_size,
                                plan_contraction)
from repro.planner.tuner import ensure_tuned

__all__ = [
    "ContractionIR", "DistInfo", "PathCost", "Plan", "PlannerConfig",
    "DEFAULT_CONFIG", "default_config", "set_default_config",
    "build_ir", "candidate_paths", "estimate", "rank_paths",
    "plan_contraction", "clear_plan_cache", "plan_cache_size",
    "execute", "ensure_tuned", "planned_einsum", "planned_mttkrp",
    "planned_tttp", "planned_cg_matvec", "planned_reduce",
    "mttkrp_fn", "tttp_fn",
]

# mode letters for synthesized expressions; 'z' is reserved for the kept
# rank, 'y' for the contracted rank of the Gram-matvec family
_MODE_LETTERS = "abcdefghij"
_RANK_LETTER = "z"
_RANK2_LETTER = "y"


def mttkrp_fn(path: Optional[str] = None):
    """The solvers' opt-in seam: ``None`` returns the direct kernel
    (``sparse.ops.mttkrp``, no planning overhead); a path string returns a
    drop-in pinned to that planner path. Same ``(st, factors, mode)``
    signature either way."""
    if path is None:
        from repro.sparse import ops as sops
        return sops.mttkrp
    return functools.partial(planned_mttkrp, path=path)


def tttp_fn(path: Optional[str] = None):
    """As :func:`mttkrp_fn` for TTTP: ``None`` → ``kernels.ops.tttp``,
    a path string → planner dispatch pinned to it."""
    if path is None:
        from repro.kernels import ops as kops
        return kops.tttp
    return functools.partial(planned_tttp, path=path)


def planned_einsum(expr: str, *operands, path: Optional[str] = None,
                   plan: Optional[Plan] = None, autotune: bool = False,
                   ctx: AxisCtx = LOCAL, rowsharded: bool = False,
                   config: Optional[PlannerConfig] = None):
    """Einsum through the planner; ``path=`` forces a candidate, ``plan=``
    bypasses planning entirely (the caller owns signature compatibility),
    ``ctx=`` names the mesh axes the call runs under (collectives applied
    inside dispatch, communication terms in the ranking — DESIGN.md §9)."""
    if plan is None:
        if not any(isinstance(op, SparseTensor) for op in operands):
            # pure-dense: nothing to plan — delegate untouched, preserving
            # jnp.einsum's acceptance of lists/scalars
            import jax.numpy as jnp
            return jnp.einsum(expr, *operands)
        plan = plan_contraction(expr, operands, path=path, autotune=autotune,
                                ctx=ctx, rowsharded=rowsharded, config=config)
    return plan.execute(operands)


def _synth_expr(ndim: int, factor_modes: Sequence[int], out: str) -> str:
    s_term = _MODE_LETTERS[:ndim]
    terms = [s_term] + [s_term[d] + _RANK_LETTER for d in factor_modes]
    return ",".join(terms) + "->" + out


def planned_mttkrp(st: SparseTensor, factors: Sequence[Optional[jax.Array]],
                   mode: int, path: Optional[str] = None,
                   autotune: bool = False, ctx: AxisCtx = LOCAL,
                   rowsharded: bool = False, h_slices: int = 1,
                   config: Optional[PlannerConfig] = None) -> jax.Array:
    """Classic MTTKRP onto ``mode`` via the planner (drop-in for
    ``repro.sparse.ops.mttkrp``). ``factors[mode]`` is ignored/None.
    ``rowsharded`` declares factor rows sharded over ``ctx``'s data axes
    (dispatches the gather/reduce-scatter path, H-sliced by ``h_slices``)."""
    present = [d for d in range(st.ndim) if d != mode and factors[d] is not None]
    out = _MODE_LETTERS[mode] + _RANK_LETTER
    expr = _synth_expr(st.ndim, present, out)
    ops = (st, *[factors[d] for d in present])
    if h_slices != 1:
        config = (config or _pconfig.default_config()).with_h_slices(h_slices)
    return planned_einsum(expr, *ops, path=path, autotune=autotune,
                          ctx=ctx, rowsharded=rowsharded, config=config)


def planned_reduce(st: SparseTensor, keep_modes: Tuple[int, ...],
                   path: Optional[str] = None,
                   ctx: AxisCtx = LOCAL) -> jax.Array:
    """Sparse mode-subset reduction via the planner (drop-in for
    ``SparseTensor.reduce_mode`` with psum(data) under ``ctx``)."""
    s_term = _MODE_LETTERS[:st.ndim]
    expr = s_term + "->" + "".join(s_term[d] for d in keep_modes)
    return planned_einsum(expr, st, path=path, ctx=ctx)


def planned_cg_matvec(weights: SparseTensor,
                      factors: Sequence[jax.Array], mode: int,
                      x: jax.Array, path: Optional[str] = None,
                      autotune: bool = False, ctx: AxisCtx = LOCAL,
                      config: Optional[PlannerConfig] = None) -> jax.Array:
    """Weighted Gram matvec (paper §2.2 + eq. 3) via the planner:

        y[i, r] = Σ_{n: i_mode(n)=i} ω_n (Π_{d≠mode} A_d[i_d, r]) ·
                  Σ_s x[i, s] Π_{d≠mode} A_d[i_d, s]

    ``weights.values`` holds ω_n (the Ω indicator for plain ALS, the loss
    curvature ℓ''(t_n, m_n) for the generalized Gauss-Newton solver).
    Candidate paths: ``fused`` (the single-pass ``kernels.ops
    .cg_matvec_bucketed``), ``tttp_mttkrp`` (eq.-3 composition), ``sliced``
    (H-sliced both halves), ``dense``. Regularization/damping is NOT
    included — callers add ``lam * x`` themselves."""
    nd = weights.ndim
    others = [d for d in range(nd) if d != mode]
    if any(factors[d] is None for d in others):
        raise ValueError("the Gram matvec needs a factor on every "
                         "non-target mode")
    s_term = _MODE_LETTERS[:nd]
    terms = ([s_term]
             + [s_term[d] + _RANK_LETTER for d in others]
             + [s_term[mode] + _RANK2_LETTER]
             + [s_term[d] + _RANK2_LETTER for d in others])
    expr = ",".join(terms) + "->" + s_term[mode] + _RANK_LETTER
    ops = (weights, *[factors[d] for d in others], x,
           *[factors[d] for d in others])
    return planned_einsum(expr, *ops, path=path, autotune=autotune,
                          ctx=ctx, config=config)


def planned_tttp(st: SparseTensor, factors: Sequence[Optional[jax.Array]],
                 path: Optional[str] = None, autotune: bool = False,
                 ctx: AxisCtx = LOCAL, rowsharded: bool = False,
                 h_slices: int = 1,
                 config: Optional[PlannerConfig] = None) -> SparseTensor:
    """TTTP via the planner (drop-in for ``repro.core.tttp.tttp``): accepts
    None entries and vector factors, per the paper's Listing 3 surface."""
    fs: List[Optional[jax.Array]] = [
        None if f is None else (f[:, None] if f.ndim == 1 else f)
        for f in factors]
    present = [d for d in range(st.ndim) if fs[d] is not None]
    if not present:
        raise ValueError("TTTP requires at least one factor")
    s_term = _MODE_LETTERS[:st.ndim]
    expr = _synth_expr(st.ndim, present, s_term)
    ops = (st, *[fs[d] for d in present])
    if h_slices != 1:
        config = (config or _pconfig.default_config()).with_h_slices(h_slices)
    return planned_einsum(expr, *ops, path=path, autotune=autotune,
                          ctx=ctx, rowsharded=rowsharded, config=config)
