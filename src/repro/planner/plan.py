"""Plan construction + caching + optional one-shot autotuning.

A :class:`Plan` binds a classified :class:`~repro.planner.ir.ContractionIR`
to a chosen execution path with the full cost ranking attached. Plans are
cached on the *static signature* of the call (DESIGN.md §5.3, §9):

    (normalized expr, per-operand (kind, shape, cap, nnz, dtype),
     override, AxisCtx, rowsharded, PlannerConfig)

so planning happens once per (expression, operand layout, distribution) —
identical calls return the *identical* Plan object, and the key never
touches array data, making ``plan_contraction`` safe to call at jax trace
time (including inside ``shard_map``, where the ctx's axis sizes resolve
statically).

``autotune=True`` upgrades a plan by timing every candidate path once on the
provided operands (skipped under tracing, where no concrete data exists) and
pinning the measured winner; the timings are stored on the plan.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence, Tuple

import jax

from repro.core.distributed import AxisCtx, LOCAL
from repro.planner import cost as pcost
from repro.planner import dispatch as pdispatch
from repro.planner import ir as pir
from repro.planner.config import (DEFAULT_CONFIG, PlannerConfig,
                                  default_config)


@dataclasses.dataclass(frozen=True)
class Plan:
    """An executable contraction plan (immutable; shared via the cache)."""
    ir: pir.ContractionIR
    path: str
    ranking: Tuple[pcost.PathCost, ...]   # all candidates, cheapest first
    autotuned: bool = False
    timings: Optional[Tuple[Tuple[str, float], ...]] = None  # (path, seconds)
    ctx: AxisCtx = LOCAL                  # mesh axes dispatch psums over
    config: PlannerConfig = DEFAULT_CONFIG

    @property
    def candidates(self) -> Tuple[str, ...]:
        return tuple(c.path for c in self.ranking)

    def cost(self, path: Optional[str] = None) -> pcost.PathCost:
        path = path or self.path
        for c in self.ranking:
            if c.path == path:
                return c
        raise KeyError(path)

    def execute(self, operands: Sequence):
        return pdispatch.execute(self.ir, self.path, operands,
                                 ctx=self.ctx, config=self.config)


def _signature(expr: str, operands: Sequence, path: Optional[str],
               ctx: AxisCtx, dist: Optional[pir.DistInfo],
               config: PlannerConfig) -> Tuple:
    sig = []
    for op in operands:
        if hasattr(op, "cap") and hasattr(op, "indices"):  # SparseTensor
            sig.append(("sparse", tuple(op.shape), op.cap, op.nnz,
                        str(op.values.dtype), op.dense_dim,
                        getattr(op, "nnz_rows", None)))
        else:
            # plans are value-independent, so a degenerate signature for
            # non-array operands (lists/scalars) is harmless
            sig.append(("dense", tuple(getattr(op, "shape", ())),
                        str(getattr(op, "dtype", type(op).__name__))))
    return (pir.normalize(expr), tuple(sig), path, ctx, dist, config)


_CACHE: Dict[Tuple, Plan] = {}

# candidates whose estimated memory traffic exceeds this (in words) are not
# timed during autotuning — ~1 GiB of f32, far above any sane transient
AUTOTUNE_MEM_BUDGET_WORDS = 2 ** 28


def clear_plan_cache() -> None:
    _CACHE.clear()


def plan_cache_size() -> int:
    return len(_CACHE)


def _any_tracer(operands: Sequence) -> bool:
    for op in operands:
        arrays = ((op.indices, op.values) if isinstance(op, pir.SparseTensor)
                  else (op,))
        if any(isinstance(a, jax.core.Tracer) for a in arrays):
            return True
    return False


def _time_path(ir: pir.ContractionIR, path: str, operands: Sequence,
               ctx: AxisCtx, config: PlannerConfig, iters: int = 3) -> float:
    from repro.planner import tuner  # deferred: tuner pulls in kernels.ops

    def run():
        return pdispatch.execute(ir, path, operands, ctx=ctx, config=config)
    return tuner.fenced_time(run, iters=iters,
                             span_name=f"planner/autotune/{path}",
                             kind=str(ir.kind), expr=ir.expr)


def _dist_info(ctx: AxisCtx, rowsharded: bool) -> Optional[pir.DistInfo]:
    """Static distribution signature of a ctx (axis sizes resolve at trace
    time inside shard_map; LOCAL ⇒ None)."""
    data = ctx.data_size()
    model = ctx.model_size()
    if data == 1 and model == 1 and not rowsharded:
        return None
    return pir.DistInfo(data, model, rowsharded)


def plan_contraction(expr: str, operands: Sequence,
                     path: Optional[str] = None,
                     autotune: bool = False,
                     ctx: AxisCtx = LOCAL,
                     rowsharded: bool = False,
                     config: Optional[PlannerConfig] = None,
                     validate: bool = False,
                     validate_spmd: bool = False) -> Plan:
    """Plan (or fetch the cached plan for) one einsum call.

    ``path`` forces a specific candidate (validated against the IR);
    ``autotune`` measures all candidates once and pins the winner;
    ``ctx`` names the mesh axes the call runs under — the cost model adds
    the communication terms its axis sizes imply and dispatch applies the
    matching collectives; ``rowsharded`` declares the dense factors'
    ROWS sharded over the data axes (paper Fig. 2).

    ``validate=True`` certifies, abstractly (``jax.eval_shape``, no kernel
    runs), that every candidate path of this call produces identical output
    avals *before* the plan may enter the cache — the §5.3 all-paths-agree
    contract, enforced at the exact point a violation would otherwise be
    memoized (DESIGN.md §12.2). Raises
    :class:`repro.analysis.contracts.PlanContractError` on disagreement;
    cache hits are already-certified and skip the check.

    ``validate_spmd=True`` additionally certifies the *collective schedule*
    of every candidate path (DESIGN.md §15.1): the sharding-propagation
    interpreter replays each path over operand avals under this ctx's mesh
    axes and raises :class:`repro.analysis.spmd.sharding.SpmdContractError`
    on a partial-sum escape, redundant/wrong-axis psum, or a gather into a
    sharded dimension. Aval-only, so it composes with tracing; a LOCAL ctx
    has no mesh axes and passes trivially.
    """
    ctx = ctx if ctx is not None else LOCAL
    config = config if config is not None else default_config()
    # resolve the axis SIZES into the key, not just the ctx's axis names —
    # two shard_map regions sharing names on different-size meshes must not
    # alias to one plan (the ranking and candidate legality depend on sizes)
    dist = _dist_info(ctx, rowsharded)
    key = _signature(expr, operands, path, ctx, dist, config)
    cached = _CACHE.get(key)
    if cached is not None and (path is not None or cached.autotuned
                               or not autotune):
        return cached

    ir = pir.build_ir(expr, operands, dist=dist)
    ranking = pcost.rank_paths(ir)
    candidates = tuple(c.path for c in ranking)
    if validate and not _any_tracer(operands):
        # deferred import: analysis depends on the planner, never the reverse
        from repro.analysis.contracts import certify_candidates
        certify_candidates(ir, candidates, operands, ctx, config)
    if validate_spmd:
        # aval-only (works on tracers too): certify the collective schedule
        from repro.analysis.spmd.sharding import certify_plan
        certify_plan(ir, candidates, operands, ctx, config)
    if path is not None:
        # a forced path makes autotuning moot — the plan is final
        if path not in candidates:
            raise ValueError(f"path {path!r} not legal for {expr!r}; "
                             f"candidates: {candidates}")
        plan = Plan(ir, path, ranking, ctx=ctx, config=config)
    elif autotune and not _any_tracer(operands):
        # only time candidates whose estimated footprint is sane — the dense
        # and KR-first fallbacks explode at low density and would OOM here
        feasible = [c.path for c in ranking
                    if c.mem <= AUTOTUNE_MEM_BUDGET_WORDS]
        if not feasible:
            feasible = [ranking[0].path]
        timings = tuple((p, _time_path(ir, p, operands, ctx, config))
                        for p in feasible)
        winner = min(timings, key=lambda t: t[1])[0]
        plan = Plan(ir, winner, ranking, autotuned=True, timings=timings,
                    ctx=ctx, config=config)
    else:
        plan = Plan(ir, ranking[0].path, ranking, ctx=ctx, config=config)
    _CACHE[key] = plan
    return plan
