"""Cost model for candidate contraction paths (paper §5.3, DESIGN.md §5.2).

Each candidate path gets a :class:`PathCost` with separate flop and
memory-traffic estimates (in fused multiply-adds and words moved), combined
into a time proxy with machine-balance constants. Only the *ratios* between
paths matter for ranking; the constants encode a ~10 flops/word balance point
typical of both TPU VPU and modern CPUs.

Formulas (m = nnz, R = rank, N = sparse order, I_d = mode sizes):

* all-at-once MTTKRP / TTTP: Θ(mR·#factors) flops, Θ(mR) transient traffic —
  no intermediate *tensor* is ever formed (paper Fig. 5b "all-at-once");
* pairwise T-first: an extra hypersparse TTM — Θ(mR) flops plus a lexicographic
  sort of the m keys (Θ(m log m) traffic per key column) and a materialized
  Θ(mR) sparse intermediate (paper Fig. 5b "contract with T first");
* pairwise KR-first: the Khatri-Rao product is dense — Θ(K·R) flops *and*
  memory with K = Π_{d≠mode} I_d, which explodes at low density
  (paper §5.3's conclusion: only viable for relatively dense tensors);
* dense fallback: densify and ``jnp.einsum`` — Θ(Π I_d · R).
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Tuple

from repro.planner import ir as pir

# Machine-balance constants (per second): ranking only depends on the ratio.
# These are the UNCALIBRATED defaults — the planner's autotuner
# (``repro.planner.tuner``) fits the live rates below against fenced kernel
# measurements (§5.3 calibration), so untuned shapes rank on measured
# machine balance rather than the TPU-napkin defaults.
FLOP_RATE = 1.0e11   # fused multiply-adds / s
MEM_RATE = 1.0e10    # words / s
COMM_RATE = 1.0e9    # words / s over mesh links (≈10× slower than HBM)
# words of traffic per element per sort-key column (multi-pass stable argsort)
SORT_WORDS_PER_KEY = 8.0

_DEFAULT_RATES = {"flop": FLOP_RATE, "mem": MEM_RATE, "comm": COMM_RATE}
_RATES = dict(_DEFAULT_RATES)


def rates() -> dict:
    """The live machine-balance rates (a copy)."""
    return dict(_RATES)


def set_rates(flop: float = None, mem: float = None,
              comm: float = None) -> None:
    """Install calibrated rates; None leaves a rate unchanged. Rates must be
    positive — the time proxy divides by them."""
    for key, val in (("flop", flop), ("mem", mem), ("comm", comm)):
        if val is not None:
            if not val > 0:
                raise ValueError(f"{key} rate must be positive, got {val}")
            _RATES[key] = float(val)


def reset_rates() -> None:
    _RATES.update(_DEFAULT_RATES)


def calibrate(samples) -> dict:
    """Fit the flop/mem rates to measured (flops, mem, seconds) samples.

    Least-squares on seconds ≈ flops/flop_rate + mem/mem_rate (the §5.3
    roofline proxy with both terms exposed): solves for the inverse rates
    with a positivity clamp. With fewer than two samples — or when the fit
    degenerates (collinear samples can drive an inverse rate ≤ 0) — falls
    back to scaling both default rates by the median measured/predicted
    ratio, which preserves the default flop:mem balance while matching the
    observed magnitude. Returns the installed rates."""
    samples = [(float(f), float(w), float(s)) for f, w, s in samples
               if s > 0 and (f > 0 or w > 0)]
    if not samples:
        return rates()
    inv = None
    if len(samples) >= 2:
        import numpy as np
        a = np.array([[f, w] for f, w, _ in samples])
        t = np.array([s for _, _, s in samples])
        sol, *_ = np.linalg.lstsq(a, t, rcond=None)
        if sol[0] > 0 and sol[1] > 0:
            inv = sol
    if inv is not None:
        set_rates(flop=1.0 / inv[0], mem=1.0 / inv[1])
    else:
        ratios = sorted(
            s / (f / _DEFAULT_RATES["flop"] + w / _DEFAULT_RATES["mem"])
            for f, w, s in samples)
        scale = ratios[len(ratios) // 2]
        set_rates(flop=_DEFAULT_RATES["flop"] / scale,
                  mem=_DEFAULT_RATES["mem"] / scale)
    return rates()

# Preference order used only to break exact score ties deterministically.
_TIE_ORDER = ("all_at_once", "fused", "tttp_mttkrp", "segment", "dense_output",
              "bucketed", "rowsharded", "sliced", "t_first", "hypersparse",
              "pairwise", "kr_first", "dense")


@dataclasses.dataclass(frozen=True)
class PathCost:
    path: str
    flops: float
    mem: float          # words of memory traffic (input + transient + output)
    comm: float = 0.0   # words moved over mesh links (psum / gather / scatter)
    note: str = ""

    @property
    def seconds(self) -> float:
        """Roofline-style time proxy: compute + traffic + communication
        (not overlapped). Reads the LIVE rates, so tuner calibration
        re-ranks candidate paths process-wide."""
        return (self.flops / _RATES["flop"] + self.mem / _RATES["mem"]
                + self.comm / _RATES["comm"])


def _sort_traffic(m: int, key_cols: int) -> float:
    return m * max(math.log2(max(m, 2)), 1.0) * SORT_WORDS_PER_KEY * key_cols


def _dense_size(ir: pir.ContractionIR) -> float:
    return float(math.prod(ir.sparse.shape))


def _factor_words(ir: pir.ContractionIR) -> float:
    shape = ir.sparse.shape
    r = ir.rank_size
    return float(sum(shape[d] * r for d in ir.factor_modes))


def candidate_paths(ir: pir.ContractionIR) -> List[str]:
    """Legal execution paths for this IR, unranked. Distribution-aware:
    row-sharded factors admit only the gather/scatter schedule, and a
    sharded model axis (column-sliced R) rules out the paths that cannot
    insert the inter-half psum(model) (DESIGN.md §9)."""
    dist = ir.dist or pir.LOCAL_DIST
    if dist.rowsharded:
        if ir.kind == pir.TTTP or (ir.kind == pir.MTTKRP
                                   and pir.is_classic_mttkrp(ir)):
            return ["rowsharded"]
        raise NotImplementedError(
            f"row-sharded factors support TTTP and classic MTTKRP only, "
            f"not {ir.kind!r} ({ir.expr!r})")
    if ir.kind == pir.DENSE:
        return ["dense"]
    if ir.kind == pir.REDUCE:
        return ["segment", "dense"]
    if ir.kind == pir.TTTP:
        return ["all_at_once", "sliced", "pairwise", "dense"]
    if ir.kind == pir.TTM:
        return ["dense_output", "hypersparse", "dense"]
    if ir.kind == pir.MTTKRP:
        if pir.is_classic_mttkrp(ir):
            return ["all_at_once", "bucketed", "t_first", "kr_first", "dense"]
        return ["all_at_once", "dense"]
    if ir.kind == pir.CG_MATVEC:
        if dist.model_size > 1:
            # the contracted rank is column-sharded: the TTTP half must be
            # psum(model)'d before the MTTKRP half — single-pass fusion and
            # the densified fallback cannot express the intermediate psum
            return ["tttp_mttkrp", "sliced"]
        return ["tttp_mttkrp", "fused", "sliced", "dense"]
    raise ValueError(f"unknown IR kind {ir.kind!r}")


def estimate(ir: pir.ContractionIR, path: str) -> PathCost:
    """Flop/traffic/communication estimate for one (IR, path) pair.

    Flop and memory terms use the IR's (per-shard) operand sizes; the
    communication term adds the collective volumes the distribution
    signature implies (paper §4's per-kernel communication analysis), so
    distributed variants rank against local ones on the same scale."""
    cost = _base_estimate(ir, path)
    comm = _comm_words(ir, path)
    return dataclasses.replace(cost, comm=comm) if comm else cost


def _psum_words(volume: float, axis_size: int) -> float:
    """Ring all-reduce of ``volume`` words: ≈2V per device for size > 1."""
    return 2.0 * volume if axis_size > 1 else 0.0


def _comm_words(ir: pir.ContractionIR, path: str) -> float:
    """Collective volume (words per device) for this (IR, path) under the
    IR's distribution signature (DESIGN.md §9)."""
    dist = ir.dist or pir.LOCAL_DIST
    if ir.kind == pir.DENSE or dist.is_local:
        return 0.0
    shape = ir.sparse.shape
    m = float(ir.nnz)
    r = float(ir.rank_size)
    if path == "rowsharded":
        # all-gather each non-target factor's column slices (every device
        # receives the full rows once per sweep over H slices) ...
        gathered = sum(shape[d] * r for d in ir.factor_modes)
        if ir.kind == pir.MTTKRP:
            # ... plus the reduce-scatter of output rows to their owners
            gathered += float(shape[ir.keep_modes[0]]) * r
        return float(gathered)
    if ir.kind == pir.REDUCE:
        out = float(math.prod(shape[d] for d in ir.keep_modes) or 1)
        return _psum_words(out, dist.data_size)
    if ir.kind == pir.TTTP:
        # local partial inner products over the column slice, psum(model)
        return _psum_words(m, dist.model_size)
    if ir.kind == pir.TTM:
        others = float(math.prod(shape[d] for d in range(len(shape))
                                 if d != ir.contract_mode))
        return _psum_words(others * r, dist.data_size)
    if ir.kind == pir.MTTKRP:
        out = float(math.prod(shape[d] for d in ir.keep_modes) or 1) * r
        return _psum_words(out, dist.data_size)
    if ir.kind == pir.CG_MATVEC:
        out = float(shape[ir.keep_modes[0]]) * r
        return (_psum_words(m, dist.model_size)
                + _psum_words(out, dist.data_size))
    return 0.0


def _base_estimate(ir: pir.ContractionIR, path: str) -> PathCost:
    if ir.kind == pir.DENSE:
        # jnp.einsum handles its own path; charge the naive product size.
        size = math.prod(s for _, s in ir.sizes)
        return PathCost("dense", float(size), float(size))

    m = float(ir.nnz)
    n = len(ir.sparse.shape)
    shape = ir.sparse.shape
    coo_words = m * (n + 1)          # indices + values

    if ir.kind == pir.REDUCE:
        # hypersparse output bound: rows actually touched (streamed
        # nnz_rows hints and the Θ(m) cap), not the full extent product
        out_words = float(ir.out_cells(ir.keep_modes))
        if path == "segment":
            return PathCost(path, m, coo_words + out_words)
        if path == "dense":
            d = _dense_size(ir)
            return PathCost(path, d, d + coo_words + out_words,
                            note="densify + jnp.einsum")

    r = float(ir.rank_size)
    nf = len(ir.factor_modes)

    if ir.kind == pir.TTTP:
        base_in = coo_words + _factor_words(ir)
        if path == "rowsharded":
            # per-slice all-gathered factor columns, discarded after use;
            # gather volume is charged as communication, not memory
            return PathCost(path, m * r * (nf + 1), base_in + m,
                            note="row-sharded per-slice gather (Fig. 2)")
        if path == "all_at_once":
            # the Pallas kernel streams R tiles and XLA fuses the jnp
            # gather-product-reduce chain: no (m, R) intermediate lands
            return PathCost(path, m * r * (nf + 1), base_in + m,
                            note="fused gather-product-reduce (Pallas/XLA)")
        if path == "sliced":
            # bounds the transient at mR/H but re-reads the COO indices
            # once per slice
            h = _sliced_h(int(r))
            return PathCost(path, m * r * (nf + 1),
                            base_in + (h - 1) * coo_words + m * r / h,
                            note=f"H={h} column slices")
        if path == "pairwise":
            # one materialized (m, R) intermediate per factor contraction
            return PathCost(path, m * r * (nf + 1), base_in + m * r * nf,
                            note="paper Fig. 6 baseline")
        if path == "dense":
            d = _dense_size(ir)
            return PathCost(path, d * r, d + base_in + m)

    if ir.kind == pir.TTM:
        others = float(math.prod(shape[d] for d in range(n)
                                 if d != ir.contract_mode))
        base_in = coo_words + shape[ir.contract_mode] * r
        if path == "dense_output":
            return PathCost(path, m * r, base_in + others * r,
                            note="scatter-add into dense output")
        if path == "hypersparse":
            # sort + segment-sum into ≤ m compressed keys, then densified for
            # the einsum (dense-output) contract; Θ(m) storage until then
            return PathCost(path, m * r,
                            base_in + _sort_traffic(int(m), n - 1) +
                            m * r + others * r,
                            note="compressed-key output, then densified")
        if path == "dense":
            d = _dense_size(ir)
            return PathCost(path, d * r, d + base_in + others * r)

    if ir.kind == pir.MTTKRP:
        out_words = float(ir.out_cells(ir.keep_modes)) * r
        base_in = coo_words + _factor_words(ir)
        if path == "all_at_once":
            return PathCost(path, m * r * nf, base_in + m * r + out_words,
                            note="gather-product-segment-sum")
        if path == "bucketed":
            # Consumes the ingest-time cached RowBlockBuckets view attached
            # to the SparseTensor (values re-gathered per call through the
            # cached pattern), so no per-call bucketize is charged; under
            # tracing without a cached pattern dispatch falls back to
            # all_at_once, which this formula then matches.
            return PathCost(path, m * r * nf, base_in + m * r + out_words,
                            note="ingest-cached buckets + one-hot matmul")
        if path == "rowsharded":
            return PathCost(path, m * r * nf, base_in + m * r + out_words,
                            note="row-sharded gather + psum-scatter (Fig. 2)")
        if path == "t_first":
            mode = ir.keep_modes[0]
            last = [d for d in range(n) if d != mode][-1]
            flops = m * r + m * r * max(nf - 1, 1)
            mem = (base_in + _sort_traffic(int(m), n - 1) + m * r + out_words)
            return PathCost(path, flops, mem,
                            note=f"hypersparse TTM over mode {last} first")
        if path == "kr_first":
            mode = ir.keep_modes[0]
            k = float(math.prod(shape[d] for d in range(n) if d != mode))
            flops = k * r * max(nf - 1, 1) + m * r
            return PathCost(path, flops, base_in + k * r + out_words,
                            note="dense Khatri-Rao intermediate, Θ(K·R) memory")
        if path == "dense":
            d = _dense_size(ir)
            return PathCost(path, d * r, d + base_in + out_words)

    if ir.kind == pir.CG_MATVEC:
        # nf = non-target factors per half; the contracted-rank half also
        # reads x (counted in _factor_words via factor_modes)
        nf = n - 1
        out_words = float(ir.out_cells(ir.keep_modes)) * r
        base_in = coo_words + _factor_words(ir)
        if path == "tttp_mttkrp":
            # TTTP then MTTKRP: the Khatri-Rao rows are gathered twice, and
            # a Θ(m) z intermediate lands between the halves
            return PathCost(path, m * r * (2 * nf + 1),
                            base_in + 2 * m + out_words,
                            note="TTTP + MTTKRP composition (eq. 3)")
        if path == "fused":
            # one pass per nonzero, KR gather shared across both halves,
            # over the ingest-time cached buckets (no per-call bucketize;
            # without a cached pattern dispatch falls back to tttp_mttkrp)
            return PathCost(path, m * r * (nf + 2), base_in + out_words,
                            note="fused single-pass kernel, cached buckets")
        if path == "sliced":
            h = _sliced_h(int(r))
            return PathCost(path, m * r * (2 * nf + 1),
                            base_in + (h - 1) * coo_words + m * r / h +
                            2 * m + out_words,
                            note=f"H={h} column slices on both halves")
        if path == "dense":
            d = _dense_size(ir)
            return PathCost(path, 2 * d * r, d + base_in + out_words)

    raise ValueError(f"no cost formula for kind={ir.kind!r} path={path!r}")


def _sliced_h(r: int) -> int:
    """Static H for the sliced TTTP schedule: largest of {4, 2, 1} dividing R."""
    for h in (4, 2):
        if r % h == 0:
            return h
    return 1


def rank_paths(ir: pir.ContractionIR) -> Tuple[PathCost, ...]:
    """All candidates, cheapest-first (deterministic tie-break)."""
    costs = [estimate(ir, p) for p in candidate_paths(ir)]
    return tuple(sorted(costs, key=lambda c: (c.seconds,
                                              _TIE_ORDER.index(c.path))))
