"""Planner tunables recorded in plan cache keys.

A :class:`PlannerConfig` carries the static knobs that change what a plan
*executes* (not what it computes): the CCSR bucket granularity of the
bucketed/fused kernels and the H-slicing factor of the row-sharded
distributed paths. Configs are frozen/hashable and participate in the plan
cache key, so two calls that differ only in bucket granularity get distinct
plans (and distinct ingest-time bucket views).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class PlannerConfig:
    """Static execution knobs for planner dispatch.

    ``block_rows``  — rows per CCSR bucket consumed by the bucketed MTTKRP
                      and fused CG-matvec kernels (one-hot matmul height);
    ``h_slices``    — column-slice count for the row-sharded distributed
                      paths (paper Fig. 2 per-slice gather schedule).
    """
    block_rows: int = 8
    h_slices: int = 1

    def with_h_slices(self, h: int) -> "PlannerConfig":
        return self if h == self.h_slices else \
            dataclasses.replace(self, h_slices=h)


DEFAULT_CONFIG = PlannerConfig()

# process-wide default, resolved at call time (not import time) so drivers
# can retune it — e.g. ``launch/complete.py --block-rows`` — and ingest
# (data.pipeline) and dispatch agree on the bucket granularity
_DEFAULT = DEFAULT_CONFIG


def default_config() -> PlannerConfig:
    return _DEFAULT


def set_default_config(cfg: PlannerConfig) -> None:
    global _DEFAULT
    _DEFAULT = cfg
