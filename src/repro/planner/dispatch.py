"""Plan dispatcher — lowers a chosen (IR, path) onto the kernel library,
applying the collectives the plan's AxisCtx implies (one execution layer
from IR to mesh, DESIGN.md §9).

Each contraction family maps onto ``repro.sparse.ops`` / ``repro.kernels``
(which internally select the Pallas kernels when their block-size
preconditions hold, jnp fallbacks otherwise):

* REDUCE  → linearized multi-mode segment-sum (arbitrary kept-mode subsets),
  psum(data) on the dense output;
* TTTP    → ``kernels.ops.tttp`` (Pallas/ref), pairwise or H-sliced variants;
  under a model axis (column-sliced R) the local partial values are
  psum(model)'d;
* TTM     → dense-output scatter-add or hypersparse compressed-key kernel,
  psum(data) on the dense output;
* MTTKRP  → all-at-once gather–product–segment-sum, CCSR-bucketed kernel,
  pairwise T-first / KR-first, or the generalized multi-output-mode form;
  psum(data) on the (rows, R_local) output;
* CG_MATVEC → the eq.-3 weighted Gram matvec: the TTTP half is psum(model)'d
  before the MTTKRP half, the output psum(data)'d;
* rowsharded → factor ROWS sharded over the data axes (paper Fig. 2):
  per-slice all-gather + local compute (+ reduce-scatter for MTTKRP),
  dispatched onto ``repro.core.distributed``'s collective kernels.

Every path of a given IR computes the same einsum, so forcing paths is a
numerical no-op (tested in ``tests/test_planner.py``). All jnp paths are
jit-safe; the ``bucketed``/``fused`` paths consume the ingest-time cached
``RowBlockBuckets`` view on the SparseTensor (``SparseTensor.row_buckets``)
— values are re-gathered through the cached pattern per call — and fall
back to ``all_at_once``/``tttp_mttkrp`` when no pattern is available under
tracing.
"""
from __future__ import annotations

import math
import time
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core import tttp as core_tttp
from repro.core.distributed import AxisCtx, LOCAL
from repro.core.sparse_tensor import SparseTensor
from repro.core.utils import linearize
from repro.kernels import ops as kops
from repro.planner import ir as pir
from repro.planner.config import PlannerConfig, default_config
from repro.planner.cost import _sliced_h
from repro.sparse import ops as sops


def _split_operands(ir: pir.ContractionIR, operands: Sequence):
    st = operands[ir.sparse_pos]
    dense_ops = [operands[i] for i in ir.dense_positions]
    return st, dense_ops


def _factors_by_mode(ir: pir.ContractionIR,
                     dense_ops: Sequence[jax.Array]) -> List[Optional[jax.Array]]:
    """Length-N factor list with None at uncovered modes."""
    factors: List[Optional[jax.Array]] = [None] * len(ir.sparse.shape)
    for mode, f in zip(ir.factor_modes, dense_ops):
        factors[mode] = f
    return factors


def _reorder(res: jax.Array, canon: str, out: str) -> jax.Array:
    """Transpose a result with axis order ``canon`` into axis order ``out``."""
    if canon == out:
        return res
    return jnp.transpose(res, tuple(canon.index(c) for c in out))


def _densified_einsum(ir: pir.ContractionIR, st: SparseTensor,
                      dense_ops: Sequence) -> jax.Array:
    """Dense fallback preserving the original operand order (the sparse
    operand need not be first). ``optimize="greedy"``: jnp.einsum's default
    exhaustive path search is exponential in operand count and hangs at
    trace time on order-5 CG matvecs (11 operands); greedy is near-optimal
    for these factor-matrix chains and linear-time."""
    args: List = [None] * len(ir.operands)
    args[ir.sparse_pos] = st.todense()
    for pos, op in zip(ir.dense_positions, dense_ops):
        args[pos] = op
    return jnp.einsum(ir.expr, *args, optimize="greedy")


# ---------------------------------------------------------------------------
# per-kind executors
# ---------------------------------------------------------------------------

def _exec_reduce(ir: pir.ContractionIR, st: SparseTensor, path: str,
                 ctx: AxisCtx):
    if path == "dense" and st.dense_dim is None:
        return ctx.psum_data(_densified_einsum(ir, st, ()))
    # trailing-dense values ride along unreduced (reduce_mode semantics);
    # the densify fallback cannot express them, so it also lands here
    if not ir.keep_modes:
        return ctx.psum_data(st.sum())
    kept_shape = tuple(st.shape[d] for d in ir.keep_modes)
    k = int(math.prod(kept_shape))
    lin = linearize(st.indices[:, list(ir.keep_modes)], kept_shape)
    out = jax.ops.segment_sum(st.masked_values(), lin, num_segments=k)
    return ctx.psum_data(out.reshape(kept_shape + out.shape[1:]))


def _exec_tttp(ir: pir.ContractionIR, st: SparseTensor, dense_ops, path: str,
               ctx: AxisCtx, config: PlannerConfig):
    factors = _factors_by_mode(ir, dense_ops)
    if path == "rowsharded":
        from repro.core.distributed import multilinear_rowsharded
        acc = multilinear_rowsharded(st, factors, ctx,
                                     h_slices=config.h_slices)
        return st.with_values(st.values * acc)
    if path == "all_at_once":
        res = kops.tttp(st, factors)
    elif path == "sliced":
        res = core_tttp.tttp_sliced(st, factors, _sliced_h(ir.rank_size))
    elif path == "pairwise":
        res = core_tttp.tttp_pairwise(st, factors)
    elif path == "dense":
        # Form the dense multilinear model over the covered modes only and
        # sample it per entry. (Gathering from a densified *result* would
        # double-count duplicate COO coordinates.)
        s_term = ir.sparse_term
        covered = sorted(ir.factor_modes)
        model_out = "".join(s_term[d] for d in covered)
        terms = [ir.operands[i].term for i in ir.dense_positions]
        model = jnp.einsum(",".join(terms) + "->" + model_out, *dense_ops)
        vals = st.values * model[tuple(st.indices[:, d] for d in covered)]
        res = st.with_values(vals)
    else:
        raise ValueError(f"unknown TTTP path {path!r}")
    if ctx.model is not None:
        # values are linear in the per-column partial inner products, so
        # the psum over column slices applies directly to them
        res = res.with_values(ctx.psum_model(res.values))
    return res


def _exec_ttm(ir: pir.ContractionIR, st: SparseTensor, dense_ops, path: str,
              ctx: AxisCtx):
    (w,) = dense_ops
    mode = ir.contract_mode
    s_term = ir.sparse_term
    canon = "".join(c for c in s_term if s_term.index(c) != mode) + ir.rank_index
    if path == "dense_output":
        res = sops.ttm_dense_output(st, w, mode)
    elif path == "hypersparse":
        res = sops.ttm_hypersparse(st, w, mode).todense()
    elif path == "dense":
        return ctx.psum_data(_densified_einsum(ir, st, dense_ops))
    else:
        raise ValueError(f"unknown TTM path {path!r}")
    return ctx.psum_data(_reorder(res, canon, ir.out))


def _mttkrp_general(ir: pir.ContractionIR, st: SparseTensor,
                    factors: Sequence[Optional[jax.Array]]) -> jax.Array:
    """All-at-once partial MTTKRP with any kept-mode subset: gather factor
    rows, multiply, segment-sum over the linearized kept key."""
    prod = st.masked_values()[:, None]
    for d, f in enumerate(factors):
        if f is not None:
            prod = prod * f[st.indices[:, d]]
    kept_shape = tuple(st.shape[d] for d in ir.keep_modes)
    k = int(math.prod(kept_shape)) if kept_shape else 1
    lin = linearize(st.indices[:, list(ir.keep_modes)], kept_shape)
    res = jax.ops.segment_sum(prod, lin, num_segments=k)
    return res.reshape(kept_shape + (res.shape[-1],))


def _exec_mttkrp(ir: pir.ContractionIR, st: SparseTensor, dense_ops,
                 path: str, ctx: AxisCtx, config: PlannerConfig):
    if path == "dense":
        return ctx.psum_data(_densified_einsum(ir, st, dense_ops))
    factors = _factors_by_mode(ir, dense_ops)
    out_sparse = ir.out.replace(ir.rank_index, "")
    canon = out_sparse + ir.rank_index           # kept modes in out order, r last
    if not pir.is_classic_mttkrp(ir):
        if path != "all_at_once":
            raise ValueError(f"path {path!r} requires the classic MTTKRP "
                             f"shape (one kept mode, all others contracted)")
        return ctx.psum_data(
            _reorder(_mttkrp_general(ir, st, factors), canon, ir.out))
    mode = ir.keep_modes[0]
    if path == "rowsharded":
        from repro.core.distributed import _mttkrp_rowsharded_impl
        # the reduce-scatter inside already sums over the data axes
        res = _mttkrp_rowsharded_impl(st, factors, mode, ctx,
                                      h_slices=config.h_slices)
        return _reorder(res, canon, ir.out)
    if path == "bucketed":
        buckets = st.row_buckets(mode, config.block_rows)
        if buckets is not None:
            res = kops.mttkrp_bucketed(buckets, factors,
                                       num_rows=st.shape[mode])
        else:                                    # tracing, no cached pattern
            res = sops.mttkrp(st, factors, mode)
    elif path == "all_at_once":
        res = sops.mttkrp(st, factors, mode)
    elif path == "t_first":
        res = sops.mttkrp_pairwise_t_first(st, factors, mode)
    elif path == "kr_first":
        res = sops.mttkrp_pairwise_kr_first(st, factors, mode)
    else:
        raise ValueError(f"unknown MTTKRP path {path!r}")
    return ctx.psum_data(_reorder(res, canon, ir.out))


def _cg_factor_groups(ir: pir.ContractionIR, dense_ops: Sequence):
    """Split the CG_MATVEC dense operands into the kept-rank (MTTKRP half)
    and contracted-rank (TTTP half) factor lists, indexed by sparse mode."""
    nd = len(ir.sparse.shape)
    s_term = ir.sparse_term
    r_fac: List[Optional[jax.Array]] = [None] * nd
    s_fac: List[Optional[jax.Array]] = [None] * nd
    for pos, op in zip(ir.dense_positions, dense_ops):
        t = ir.operands[pos].term
        d = s_term.index(t[0])
        if t[1] == ir.rank_index:
            r_fac[d] = op
        else:
            s_fac[d] = op
    return r_fac, s_fac


def _exec_cg_matvec(ir: pir.ContractionIR, st: SparseTensor, dense_ops,
                    path: str, ctx: AxisCtx, config: PlannerConfig):
    """Weighted Gram matvec (paper eq. 3): values of ``st`` are the
    curvature weights ω_n; ``s_fac[mode]`` is the CG direction x. Under a
    model axis the TTTP half's partial is psum(model)'d before the MTTKRP
    half; the output is psum(data)'d."""
    if path == "dense":
        return ctx.psum_data(_densified_einsum(ir, st, dense_ops))
    mode = ir.keep_modes[0]
    r_fac, s_fac = _cg_factor_groups(ir, dense_ops)
    x = s_fac[mode]
    canon = ir.sparse_term[mode] + ir.rank_index
    # the fused kernel computes the Khatri-Rao gather ONCE and reuses it for
    # both halves — only valid when both halves share the same factor
    # objects (always true via planned_cg_matvec); without an ingest-time
    # cached bucket pattern (tracing), fall back to the composition
    shared = all(s_fac[d] is r_fac[d] for d in range(len(r_fac)) if d != mode)
    if path == "fused" and shared:
        buckets = st.row_buckets(mode, config.block_rows)
        if buckets is not None:
            res = kops.cg_matvec_bucketed(buckets, r_fac, x,
                                          num_rows=st.shape[mode])
            return ctx.psum_data(_reorder(res, canon, ir.out))
    if path in ("fused", "tttp_mttkrp"):
        partial = ctx.psum_model(core_tttp.multilinear_values(st, s_fac))
        z = st.with_values(st.values * partial)
        return ctx.psum_data(_reorder(sops.mttkrp(z, r_fac, mode), canon,
                                      ir.out))
    if path == "sliced":
        r2 = ir.size_of(ir.rank2_index)
        h2 = _sliced_h(r2)
        rs2 = r2 // h2
        acc = jnp.zeros((st.cap,), st.values.dtype)
        for h in range(h2):
            sl = [None if f is None else f[:, h * rs2:(h + 1) * rs2]
                  for f in s_fac]
            acc = acc + core_tttp.multilinear_values(st, sl)
        z = st.with_values(st.values * ctx.psum_model(acc))
        r1 = ir.rank_size
        h1 = _sliced_h(r1)
        rs1 = r1 // h1
        cols = [sops.mttkrp(
            z, [None if f is None else f[:, h * rs1:(h + 1) * rs1]
                for f in r_fac], mode) for h in range(h1)]
        res = jnp.concatenate(cols, axis=1) if h1 > 1 else cols[0]
        return ctx.psum_data(_reorder(res, canon, ir.out))
    raise ValueError(f"unknown CG_MATVEC path {path!r}")


def execute(ir: pir.ContractionIR, path: str, operands: Sequence,
            ctx: Optional[AxisCtx] = None,
            config: Optional[PlannerConfig] = None):
    """Run the contraction along ``path``. Operand list must match the IR;
    ``ctx`` supplies the mesh axes whose collectives dispatch applies (None
    or LOCAL ⇒ single-device semantics).

    With tracing enabled (``repro.obs``), each EAGER execution records a
    span plus a predicted-vs-measured plan entry: the §5.3 cost-model
    flop/traffic/comm prediction for this (IR, path) next to the fenced
    wall time — the persistent accounting that validates the cost model
    (DESIGN.md §11). Traced executions (inside jit) skip all of it."""
    from repro import obs
    if not (obs.enabled() and obs.trace_clean()):
        return _execute(ir, path, operands, ctx, config)
    kind = str(ir.kind)
    with obs.span(f"planner/{kind}/{path}", expr=ir.expr, nnz=ir.nnz,
                  rank=ir.rank_size) as sp:
        t0 = time.perf_counter()
        out = sp.fence(_execute(ir, path, operands, ctx, config))
        seconds = time.perf_counter() - t0
    from repro.planner import cost as pcost
    c = pcost.estimate(ir, path)
    obs.get_registry().record_plan(
        f"{ir.expr}|{path}|m{ir.nnz}|r{ir.rank_size}",
        kind, path, ir.expr,
        {"flops": c.flops, "mem": c.mem, "comm": c.comm,
         "seconds": c.seconds}, seconds)
    return out


def _execute(ir: pir.ContractionIR, path: str, operands: Sequence,
             ctx: Optional[AxisCtx], config: Optional[PlannerConfig]):
    ctx = ctx if ctx is not None else LOCAL
    config = config if config is not None else default_config()
    if ir.kind == pir.DENSE:
        return jnp.einsum(ir.expr, *operands)
    st, dense_ops = _split_operands(ir, operands)
    if ir.kind == pir.REDUCE:
        return _exec_reduce(ir, st, path, ctx)
    if ir.kind == pir.TTTP:
        return _exec_tttp(ir, st, dense_ops, path, ctx, config)
    if ir.kind == pir.TTM:
        return _exec_ttm(ir, st, dense_ops, path, ctx)
    if ir.kind == pir.MTTKRP:
        return _exec_mttkrp(ir, st, dense_ops, path, ctx, config)
    if ir.kind == pir.CG_MATVEC:
        return _exec_cg_matvec(ir, st, dense_ops, path, ctx, config)
    raise ValueError(f"unknown IR kind {ir.kind!r}")
