"""Measured kernel-tile autotuning with a persistent on-disk plan cache
(DESIGN.md §13).

The planner's one-shot ``autotune=True`` times candidate *paths*; this
module tunes the *kernel tiles* underneath them: for each kernel family it
sweeps a small lattice of :class:`~repro.kernels.tile.KernelTile`
candidates, times each with fenced ``obs.span`` measurements (so the
timings land in the same registry as planner dispatch spans and surface in
PERF.md), records every candidate into the predicted-vs-measured
``PlanRecord`` table, installs the winner into the process-wide tile table
(``repro.kernels.tile.set_tile``), and calibrates the §5.3 cost-model rate
constants (``repro.planner.cost.set_rates``) from the same measurements.

Winners persist to an on-disk JSON plan cache keyed by

    (device kind, tile-lattice version, family, plan signature)

so a second run of the same workload performs ZERO timings: the cache entry
re-installs the tile and the stored calibration rates. Any key component
changing — a different accelerator, a new lattice version after the
candidate set evolves, a different tensor signature — misses by
construction and re-measures. The cache path comes from the
``REPRO_PLAN_CACHE`` env var or the ``--plan-cache`` flag of
``launch/complete.py`` / ``launch/experiment.py``.

Caveat (also in DESIGN.md §13): jit'd callers bake the resolved tile in at
trace time, so tune at startup BEFORE compiling sweeps — retuning later
affects only future traces.
"""
from __future__ import annotations

import json
import os
import time
from typing import Dict, List, Optional, Sequence, Tuple

import jax

from repro import obs
from repro.kernels.tile import (FAMILIES, KernelTile, current_tile,
                                set_tile)
from repro.planner import cost as pcost

# Bump when the candidate set below changes shape: stale cached winners from
# an older lattice must re-measure, not silently win against new candidates.
LATTICE_VERSION = 1

# Per-family candidate tiles. The DEFAULT tile is always first, so the
# measured winner is never slower than the default configuration (the
# BENCH_kernels.json acceptance bound). Small on purpose: interpret-mode CI
# times every candidate.
LATTICES: Dict[str, Tuple[KernelTile, ...]] = {
    "tttp": (
        KernelTile(),
        KernelTile(block_m=512),
        KernelTile(block_m=256, block_r=64, buckets_per_step=2),
        KernelTile(block_m=2048, block_r=64),
    ),
    "mttkrp": (
        KernelTile(),
        KernelTile(block_m=256, buckets_per_step=2),
        KernelTile(block_m=512, block_r=64),
        KernelTile(schedule="segmented"),
        KernelTile(block_m=256, block_r=64, buckets_per_step=4),
    ),
    "cg_matvec": (
        KernelTile(),
        KernelTile(block_m=256, buckets_per_step=2),
        KernelTile(schedule="segmented"),
        KernelTile(block_m=512, buckets_per_step=4),
    ),
}

# the planner path each family's tuned kernel realizes (PlanRecord rows)
_FAMILY_PATH = {"tttp": "all_at_once", "mttkrp": "bucketed",
                "cg_matvec": "fused"}

_MODE_LETTERS = "abcdefghij"


def fenced_time(fn, iters: int = 3, span_name: str = "tuner/measure",
                **attrs) -> float:
    """Best-of-``iters`` fenced wall time of ``fn()`` after one warmup call
    (compile). Every timed run executes inside an ``obs.span`` whose fence
    blocks on the result, so measurements share the registry (and PERF.md)
    with planner dispatch spans."""
    jax.block_until_ready(fn())              # warmup / compile
    best = float("inf")
    for _ in range(iters):
        with obs.span(span_name, **attrs) as sp:
            t0 = time.perf_counter()
            sp.fence(fn())
            best = min(best, time.perf_counter() - t0)
    return best


def _family_ir(family: str, st, factors):
    """The ContractionIR whose §5.3 estimate prices this family's tuned
    kernel (mode-0 form — the shape every solver sweep hits first)."""
    from repro.planner import ir as pir
    s = _MODE_LETTERS[:st.ndim]
    if family == "tttp":
        expr = ",".join([s] + [s[d] + "z" for d in range(st.ndim)]) + "->" + s
        operands = (st, *factors)
    elif family == "mttkrp":
        expr = (",".join([s] + [s[d] + "z" for d in range(1, st.ndim)])
                + "->" + s[0] + "z")
        operands = (st, *factors[1:])
    elif family == "cg_matvec":
        others = range(1, st.ndim)
        expr = (",".join([s] + [s[d] + "z" for d in others] + [s[0] + "y"]
                         + [s[d] + "y" for d in others])
                + "->" + s[0] + "z")
        operands = (st, *factors[1:], factors[0], *factors[1:])
    else:
        raise KeyError(f"unknown kernel family {family!r}")
    return pir.build_ir(expr, operands)


def _family_runner(family: str, tile: KernelTile, st, omega, factors, x):
    """An argless callable running this family's Pallas kernel under
    ``tile`` — the thing the tuner times."""
    from repro.kernels import ops as kops
    if family == "tttp":
        return lambda: kops.tttp_values(st, factors, use_pallas=True,
                                        tile=tile)
    fs = [None] + list(factors[1:])
    if family == "mttkrp":
        buckets = st.row_buckets(0, tile.block_rows)
        return lambda: kops.mttkrp_bucketed(buckets, fs,
                                            num_rows=st.shape[0],
                                            use_pallas=True, tile=tile)
    if family == "cg_matvec":
        buckets = omega.row_buckets(0, tile.block_rows)
        return lambda: kops.cg_matvec_bucketed(buckets, fs, x,
                                               num_rows=st.shape[0],
                                               use_pallas=True, tile=tile)
    raise KeyError(f"unknown kernel family {family!r}")


def tune_family(family: str, st, factors, omega=None, x=None,
                lattice: Optional[Sequence[KernelTile]] = None,
                iters: int = 3) -> Dict:
    """Time every lattice candidate for one family, install the winner, and
    return ``{"tile", "seconds", "timings", "predicted"}``. Each timed
    candidate bumps the ``tuner/measurements`` counter and lands a
    PlanRecord row keyed ``autotune/<family>|<path>|tile:<short>``."""
    lattice = tuple(lattice if lattice is not None else LATTICES[family])
    # static VMEM certification (DESIGN.md §15.3): a candidate the footprint
    # model rejects is never timed — pruning happens BEFORE the sweep, and
    # the prune count rides the summary line and the tuner counters
    from repro.kernels import vmem as kvmem
    src = omega if (family == "cg_matvec" and omega is not None) else st
    kept, pruned = kvmem.prune_lattice(
        family, lattice,
        lambda t: kvmem.workload_geometry(family, src, factors, t, x=x))
    if pruned:
        obs.counter_add("tuner/vmem_pruned", len(pruned))
        if not kept:
            detail = "\n".join(e.format() for _, e in pruned)
            raise ValueError(
                f"every {family!r} lattice candidate exceeds the VMEM "
                f"budget ({kvmem.vmem_budget_bytes()} B) — raise "
                f"REPRO_VMEM_MB or add smaller tiles:\n{detail}")
    lattice = tuple(kept)
    ir = _family_ir(family, st, factors)
    path = _FAMILY_PATH[family]
    cost = pcost.estimate(ir, path)
    predicted = {"flops": cost.flops, "mem": cost.mem, "comm": cost.comm,
                 "seconds": cost.seconds}
    timings: List[Tuple[KernelTile, float]] = []
    for tile in lattice:
        run = _family_runner(family, tile, st, omega, factors, x)
        seconds = fenced_time(
            run, iters=iters, span_name=f"tuner/{family}",
            tile=tile.short(), nnz=ir.nnz, rank=ir.rank_size)
        obs.counter_add("tuner/measurements")
        obs.get_registry().record_plan(
            f"autotune/{family}|{path}|tile:{tile.short()}",
            str(ir.kind), path, ir.expr, predicted, seconds)
        timings.append((tile, seconds))
    winner, best = min(timings, key=lambda t: t[1])
    set_tile(family, winner)
    return {"tile": winner, "seconds": best,
            "timings": [(t.short(), s) for t, s in timings],
            "vmem_pruned": [(t.short(), e.total) for t, e in pruned],
            "predicted": predicted}


# ---------------------------------------------------------------------------
# persistent on-disk plan cache
# ---------------------------------------------------------------------------

def device_kind() -> str:
    return jax.devices()[0].device_kind


def plan_signature(st, factors) -> str:
    """Static signature of the tuned workload: tile winners transfer across
    runs of the same (shape, nnz, rank, dtype) tensor only."""
    r = next(int(f.shape[1]) for f in factors if f is not None)
    return (f"shape={'x'.join(str(s) for s in st.shape)}|nnz={st.nnz}"
            f"|cap={st.cap}|r={r}|dt={st.values.dtype}")


def cache_key(family: str, st, factors,
              lattice_version: Optional[int] = None) -> str:
    from repro.kernels.vmem import vmem_budget_bytes
    v = LATTICE_VERSION if lattice_version is None else lattice_version
    # the VMEM budget is part of key validity: a winner tuned under one
    # budget may be a pruned (unrunnable) candidate under a smaller one
    return (f"{device_kind()}|v{v}|{family}|{plan_signature(st, factors)}"
            f"|vmem={vmem_budget_bytes()}")


class PlanCacheFile:
    """The on-disk winner store: a flat JSON object of full cache keys →
    ``{tile, seconds, timings}`` plus the calibrated rates. Unknown or
    stale keys (different device kind / lattice version / signature) simply
    never match — invalidation by key construction, no file-level state."""

    def __init__(self, path: Optional[str]):
        self.path = path
        self.entries: Dict[str, Dict] = {}
        self.rates: Optional[Dict[str, float]] = None
        if path and os.path.exists(path):
            try:
                with open(path) as f:
                    data = json.load(f)
                self.entries = dict(data.get("entries", {}))
                self.rates = data.get("rates")
            except (OSError, ValueError):
                self.entries = {}
                self.rates = None

    def get(self, key: str) -> Optional[KernelTile]:
        entry = self.entries.get(key)
        if entry is None:
            return None
        try:
            return KernelTile.from_json(entry["tile"])
        except (KeyError, TypeError, ValueError):
            return None

    def put(self, key: str, result: Dict) -> None:
        self.entries[key] = {"tile": result["tile"].to_json(),
                             "seconds": result["seconds"],
                             "timings": result["timings"]}

    def save(self) -> None:
        if not self.path:
            return
        parent = os.path.dirname(os.path.abspath(self.path))
        os.makedirs(parent, exist_ok=True)
        with open(self.path, "w") as f:
            json.dump({"lattice_version": LATTICE_VERSION,
                       "entries": self.entries, "rates": self.rates},
                      f, indent=2, sort_keys=True)


def ensure_tuned(st, factors, omega=None, x=None,
                 families: Optional[Sequence[str]] = None,
                 cache_path: Optional[str] = None,
                 calibrate: bool = True, iters: int = 3) -> Dict:
    """Tune (or cache-restore) the kernel tiles for ``families`` and return
    a summary ``{"hits", "measured", "winners", "cache_path"}``.

    Per family: a cache hit installs the stored tile with zero timings
    (counter ``tuner/cache_hits``); a miss sweeps the lattice, installs the
    winner and stores it. ``cache_path`` defaults to ``REPRO_PLAN_CACHE``;
    None/empty disables persistence (always measures). Fresh measurements
    calibrate the cost-model rates and persist them; a fully-cached run
    re-installs the stored rates instead. The cg_matvec family needs
    ``omega`` (the Ω-indicator tensor) and is skipped without it; ``x``
    defaults to the mode-0 factor (same shape as the CG direction)."""
    cache_path = (cache_path if cache_path is not None
                  else os.environ.get("REPRO_PLAN_CACHE") or None)
    if families is None:
        families = [f for f in FAMILIES
                    if f != "cg_matvec" or omega is not None]
    if x is None:
        x = factors[0]
    cache = PlanCacheFile(cache_path)
    summary: Dict = {"hits": 0, "measured": 0, "vmem_pruned": 0,
                     "winners": {}, "cache_path": cache_path}
    samples = []
    fresh = False
    for family in families:
        key = cache_key(family, st, factors)
        tile = cache.get(key)
        if tile is not None:
            set_tile(family, tile)
            obs.counter_add("tuner/cache_hits")
            summary["hits"] += 1
            summary["winners"][family] = tile.short()
            continue
        result = tune_family(family, st, factors, omega=omega, x=x,
                             iters=iters)
        cache.put(key, result)
        fresh = True
        summary["measured"] += len(result["timings"])
        summary["vmem_pruned"] += len(result["vmem_pruned"])
        summary["winners"][family] = result["tile"].short()
        p = result["predicted"]
        samples.append((p["flops"], p["mem"], result["seconds"]))
    if calibrate:
        if samples:
            cache.rates = pcost.calibrate(samples)
            obs.counter_add("tuner/calibrations")
        elif cache.rates:
            # fully cached: restore the rates the original measurements fit
            pcost.set_rates(**{k: cache.rates.get(k) for k in
                               ("flop", "mem", "comm")})
    if fresh and cache_path:
        cache.save()
    summary["rates"] = pcost.rates()
    return summary


def tiles_summary() -> Dict[str, str]:
    return {f: current_tile(f).short() for f in FAMILIES}
