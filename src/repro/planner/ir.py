"""Einsum IR — typed contraction nodes over mixed sparse/dense operands.

Parses an einsum expression plus the concrete operand list into a
:class:`ContractionIR`, classifying it into one of the contraction families
the paper's kernels cover (DESIGN.md §5.1):

* ``DENSE``  — no sparse operand; delegated to ``jnp.einsum`` untouched;
* ``REDUCE`` — one sparse operand, output indices an arbitrary ordered subset
  of the sparse term (``"ijkl->li"``, ``"ijk->"``);
* ``TTTP``   — output equals the sparse term: the sampled multilinear form
  ``t_n · Σ_r Π_d A_d[i_d, r]`` (SDDMM is the order-2 case);
* ``TTM``    — one dense matrix contracting one sparse mode, dense output
  (``"ijk,kr->ijr"``, any output order, any tensor order);
* ``MTTKRP`` — ≥2 rank-sharing factor matrices contracting a subset of the
  sparse modes; covers the classic single-output-mode MTTKRP and the partial
  / multi-output-mode generalization (``"ijkl,kr,lr->ijr"``);
* ``CG_MATVEC`` — the implicit-CG weighted Gram matvec (paper §2.2 + eq. 3):
  TWO rank indices, one contracted (the TTTP half) and one kept (the MTTKRP
  half), with factors covering every mode on the contracted-rank side and
  every non-output mode on the kept-rank side
  (``"ijk,jr,kr,iy,jy,ky->ir"``). This is the one multi-stage composition
  the planner fuses: the kernel-level single-pass path reuses the Khatri-Rao
  gather across both halves.

The IR is built from *static* metadata only (terms, shapes, capacities, nnz
hints, dtypes) so construction is safe at jax trace time.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence, Tuple

from repro.core.sparse_tensor import SparseTensor

DENSE = "dense"
REDUCE = "reduce"
TTTP = "tttp"
TTM = "ttm"
MTTKRP = "mttkrp"
CG_MATVEC = "cg_matvec"

KINDS = (DENSE, REDUCE, TTTP, TTM, MTTKRP, CG_MATVEC)


@dataclasses.dataclass(frozen=True)
class DistInfo:
    """Static distribution signature of a contraction call (DESIGN.md §9).

    Built from the :class:`~repro.core.distributed.AxisCtx` the caller runs
    under (sizes resolved at trace time inside ``shard_map``):

    * ``data_size``  — product of the data-axis sizes: nonzeros sharded,
      factor rows replicated; outputs on factor rows need a psum(data);
    * ``model_size`` — model-axis size: factor COLUMNS sharded (the paper's
      H-slicing of R as a mesh axis); inner products over R need a
      psum(model);
    * ``rowsharded`` — factor ROWS sharded over the data axes instead
      (the paper's Fig. 2 memory-scalable distribution): contractions must
      all-gather column slices and reduce-scatter row outputs.

    Operand shapes in the IR are the *local* (per-shard) shapes — flop and
    memory terms are per-device automatically; ``DistInfo`` is what the
    communication terms of the cost model key off.
    """
    data_size: int = 1
    model_size: int = 1
    rowsharded: bool = False

    @property
    def is_local(self) -> bool:
        return (self.data_size == 1 and self.model_size == 1
                and not self.rowsharded)


LOCAL_DIST = DistInfo()


@dataclasses.dataclass(frozen=True)
class OperandInfo:
    """Static description of one einsum operand."""
    term: str                  # its index string
    is_sparse: bool
    shape: Tuple[int, ...]
    cap: Optional[int]         # padded capacity (sparse only)
    nnz: Optional[int]         # static nonzero hint (sparse only; ≤ cap)
    dtype: str
    dense_dim: Optional[int] = None  # trailing dense axis size (sparse only)
    # per-mode nonzero-row-count hint from streamed ingest metadata
    # (data.streaming.IngestStats → SparseTensor.nnz_rows): lets the cost
    # model bound segment/bucket output traffic hypersparsely
    nnz_rows: Optional[Tuple[int, ...]] = None


@dataclasses.dataclass(frozen=True)
class ContractionIR:
    """A classified contraction. ``sizes`` maps index letters to extents."""
    expr: str
    kind: str
    operands: Tuple[OperandInfo, ...]
    out: str
    sizes: Tuple[Tuple[str, int], ...]
    sparse_pos: Optional[int] = None
    # sparse-pattern metadata (unused fields left at defaults):
    keep_modes: Tuple[int, ...] = ()        # REDUCE/MTTKRP: kept sparse modes,
                                            #   ordered as they appear in out
    rank_index: Optional[str] = None        # TTTP/TTM/MTTKRP rank letter
                                            #   (CG_MATVEC: the KEPT rank)
    factor_modes: Tuple[int, ...] = ()      # sparse mode matched by each
                                            #   dense factor, in operand order
    contract_mode: Optional[int] = None     # TTM: the contracted sparse mode
    rank2_index: Optional[str] = None       # CG_MATVEC: the contracted rank
                                            #   letter (the TTTP half)
    dist: Optional[DistInfo] = None         # distribution signature (None =
                                            #   local single-device run)

    # -- helpers -----------------------------------------------------------
    def size_of(self, idx: str) -> int:
        return dict(self.sizes)[idx]

    @property
    def sparse(self) -> Optional[OperandInfo]:
        return None if self.sparse_pos is None else self.operands[self.sparse_pos]

    @property
    def sparse_term(self) -> str:
        return self.operands[self.sparse_pos].term

    @property
    def nnz(self) -> int:
        """Best static nonzero estimate: the nnz hint, else the capacity.
        Clamped to the capacity — SparseTensor carries the GLOBAL nnz hint
        through sharding, but inside shard_map the operand's cap is the
        per-shard bound, and cost terms here are per-device."""
        sp = self.sparse
        return sp.cap if sp.nnz is None else min(sp.nnz, sp.cap)

    @property
    def rank_size(self) -> int:
        return 1 if self.rank_index is None else self.size_of(self.rank_index)

    def out_cells(self, modes: Tuple[int, ...]) -> int:
        """Hypersparse bound on the kept-mode output cells actually carrying
        data: the full extent product, tightened by the per-mode
        nonzero-row hints (streamed ingest metadata) and by nnz (each
        nonzero lands in exactly one output cell). Dense extents are the
        fallback when no hint is attached."""
        sp = self.sparse
        cells = 1
        for d in modes:
            e = sp.shape[d]
            if sp.nnz_rows is not None:
                e = min(e, sp.nnz_rows[d])
            cells *= e
        return max(1, min(cells, self.nnz) if modes else 1)

    @property
    def dense_positions(self) -> Tuple[int, ...]:
        return tuple(i for i, op in enumerate(self.operands)
                     if not op.is_sparse)


def _operand_info(term: str, op) -> OperandInfo:
    if isinstance(op, SparseTensor):
        nnz_rows = (None if op.nnz_rows is None
                    else tuple(int(r) for r in op.nnz_rows))
        return OperandInfo(term, True, tuple(op.shape), op.cap, op.nnz,
                           str(op.values.dtype), op.dense_dim,
                           nnz_rows=nnz_rows)
    return OperandInfo(term, False, tuple(op.shape), None, None,
                       str(op.dtype))


def normalize(expr: str) -> str:
    return expr.replace(" ", "")


def build_ir(expr: str, operands: Sequence,
             dist: Optional[DistInfo] = None) -> ContractionIR:
    """Parse + classify. Raises ``ValueError`` on malformed expressions and
    ``NotImplementedError`` on patterns outside the supported families.

    ``dist`` attaches the static distribution signature; with
    ``dist.rowsharded`` the dense factors carry *local* row counts
    (rows sharded over the data axes), so their mode extent is validated
    against ``local_rows * data_size``."""
    ir = _build_ir(expr, operands, dist)
    return ir if dist is None else dataclasses.replace(ir, dist=dist)


def _build_ir(expr: str, operands: Sequence,
              dist: Optional[DistInfo]) -> ContractionIR:
    expr = normalize(expr)
    if "->" not in expr:
        raise ValueError(f"einsum expression must be explicit (have '->'): {expr!r}")
    lhs, out = expr.split("->")
    terms = lhs.split(",")
    if len(terms) != len(operands):
        raise ValueError(f"{expr!r}: {len(terms)} terms but "
                         f"{len(operands)} operands")
    infos = tuple(_operand_info(t, op) for t, op in zip(terms, operands))

    rowsharded = dist is not None and dist.rowsharded
    sizes: Dict[str, int] = {}
    for info in infos:
        if len(info.term) != len(info.shape):
            raise ValueError(f"term {info.term!r} has {len(info.term)} indices "
                             f"but operand has shape {info.shape}")
        if len(set(info.term)) != len(info.term):
            raise NotImplementedError(
                f"repeated index within a term is unsupported: {info.term!r}")
        shape = info.shape
        if rowsharded and not info.is_sparse and len(info.term) == 2:
            # factor rows are sharded over the data axes: the logical mode
            # extent is local_rows * data_size (sparse indices stay global)
            shape = (shape[0] * dist.data_size, shape[1])
        for c, s in zip(info.term, shape):
            if sizes.setdefault(c, int(s)) != int(s):
                raise ValueError(f"index {c!r} has conflicting sizes "
                                 f"{sizes[c]} and {s} in {expr!r}")
    for c in out:
        if c not in sizes:
            raise ValueError(f"output index {c!r} not in any input term")
    if len(set(out)) != len(out):
        raise NotImplementedError(f"repeated output index unsupported: {out!r}")
    size_items = tuple(sorted(sizes.items()))

    sparse_positions = [i for i, info in enumerate(infos) if info.is_sparse]
    if not sparse_positions:
        return ContractionIR(expr, DENSE, infos, out, size_items)
    if len(sparse_positions) > 1:
        raise NotImplementedError(
            "contractions with multiple sparse operands are not supported "
            "yet (the planner handles a single sparse operand)")
    spos = sparse_positions[0]
    s_term = infos[spos].term
    dense_infos = [(i, info) for i, info in enumerate(infos) if i != spos]

    if infos[spos].dense_dim is not None and dense_infos:
        raise NotImplementedError(
            "a SparseTensor with a trailing dense axis is only supported in "
            "reductions (the trailing axis rides along unreduced)")

    # ---- single sparse operand, no dense: mode-subset reduction ----------
    if not dense_infos:
        if not set(out) <= set(s_term):
            raise ValueError(f"output {out!r} not a subset of {s_term!r}")
        keep = tuple(s_term.index(c) for c in out)
        return ContractionIR(expr, REDUCE, infos, out, size_items,
                             sparse_pos=spos, keep_modes=keep)

    # ---- factor-matrix families: every dense term is (mode, rank) --------
    new_idx = {c for _, info in dense_infos for c in info.term
               if c not in s_term}
    if len(new_idx) == 2:
        return _classify_cg_matvec(expr, infos, out, size_items, spos,
                                   s_term, dense_infos, new_idx)
    if len(new_idx) != 1:
        raise NotImplementedError(
            f"expected exactly one rank index shared by the dense factors "
            f"(or two for the Gram-matvec family), "
            f"got {sorted(new_idx)} in {expr!r}")
    (r_idx,) = new_idx
    factor_modes = []
    for _, info in dense_infos:
        t = info.term
        if len(t) != 2 or t[1] != r_idx or t[0] not in s_term:
            raise NotImplementedError(
                f"dense operand term {t!r} is not a ({{sparse mode}}, "
                f"{r_idx!r}) factor matrix in {expr!r}")
        factor_modes.append(s_term.index(t[0]))
    if len(set(factor_modes)) != len(factor_modes):
        raise NotImplementedError(
            f"two factors contract the same sparse mode in {expr!r}")
    factor_modes = tuple(factor_modes)

    # TTTP / SDDMM: output pattern equals the sparse pattern
    if out == s_term:
        return ContractionIR(expr, TTTP, infos, out, size_items,
                             sparse_pos=spos, rank_index=r_idx,
                             factor_modes=factor_modes)

    # TTM / MTTKRP: rank index appears in the output, contracted sparse
    # modes are exactly the factor-covered ones
    if r_idx not in out:
        raise NotImplementedError(
            f"rank index {r_idx!r} neither reduced as TTTP nor kept in the "
            f"output in {expr!r}")
    out_sparse = out.replace(r_idx, "")
    if not set(out_sparse) <= set(s_term):
        raise ValueError(f"output indices {out_sparse!r} not all in sparse "
                         f"term {s_term!r}")
    contracted = set(s_term) - set(out_sparse)
    covered = {s_term[m] for m in factor_modes}
    if covered != contracted:
        raise NotImplementedError(
            f"factors cover modes {sorted(covered)} but the contracted "
            f"sparse modes are {sorted(contracted)} in {expr!r}")
    keep = tuple(s_term.index(c) for c in out_sparse)
    if len(dense_infos) == 1:
        return ContractionIR(expr, TTM, infos, out, size_items,
                             sparse_pos=spos, keep_modes=keep,
                             rank_index=r_idx, factor_modes=factor_modes,
                             contract_mode=factor_modes[0])
    return ContractionIR(expr, MTTKRP, infos, out, size_items,
                         sparse_pos=spos, keep_modes=keep,
                         rank_index=r_idx, factor_modes=factor_modes)


def _classify_cg_matvec(expr, infos, out, size_items, spos, s_term,
                        dense_infos, new_idx) -> ContractionIR:
    """Classify the two-rank-index weighted Gram matvec (paper eq. 3):

        y[i, r] = Σ_n ω_n · (Π_{d≠mode} A_d[i_d, r]) · Σ_s x[i_mode, s] ·
                  Π_{d≠mode} A_d[i_d, s]

    i.e. one rank index (``rank2_index``) fully contracted over factors
    covering EVERY sparse mode (the TTTP half, with the target-mode factor
    playing x), and one rank index kept in the output over factors covering
    every non-target mode (the MTTKRP half)."""
    kept = [c for c in new_idx if c in out]
    if len(kept) != 1:
        raise NotImplementedError(
            f"two rank indices require exactly one kept in the output "
            f"(the Gram-matvec family), got {sorted(kept)} kept in {expr!r}")
    r_idx = kept[0]
    (s_idx,) = new_idx - {r_idx}
    out_modes = [c for c in out if c != r_idx]
    if len(out_modes) != 1 or out_modes[0] not in s_term:
        raise NotImplementedError(
            f"Gram-matvec output must be one sparse mode plus the kept rank, "
            f"got {out!r} in {expr!r}")
    keep = s_term.index(out_modes[0])
    factor_modes, r_modes, s_modes = [], [], []
    for _, info in dense_infos:
        t = info.term
        if len(t) != 2 or t[1] not in (r_idx, s_idx) or t[0] not in s_term:
            raise NotImplementedError(
                f"dense operand term {t!r} is not a ({{sparse mode}}, rank) "
                f"factor matrix in {expr!r}")
        m = s_term.index(t[0])
        factor_modes.append(m)
        (r_modes if t[1] == r_idx else s_modes).append(m)
    nd = len(s_term)
    if (sorted(r_modes) != [d for d in range(nd) if d != keep]
            or sorted(s_modes) != list(range(nd))):
        raise NotImplementedError(
            f"Gram matvec needs kept-rank factors on every non-output mode "
            f"and contracted-rank factors on every mode; got kept-rank modes "
            f"{sorted(r_modes)}, contracted-rank modes {sorted(s_modes)} "
            f"in {expr!r}")
    return ContractionIR(expr, CG_MATVEC, infos, out, size_items,
                         sparse_pos=spos, keep_modes=(keep,),
                         rank_index=r_idx, factor_modes=tuple(factor_modes),
                         rank2_index=s_idx)


def is_classic_mttkrp(ir: ContractionIR) -> bool:
    """True for the paper's MTTKRP: one kept mode, factors on all others —
    the only shape the pairwise and bucketed kernels implement."""
    return (ir.kind == MTTKRP and len(ir.keep_modes) == 1 and
            len(ir.factor_modes) == len(ir.sparse.shape) - 1)
