"""Per-user fold-in: damped one-row ALS against frozen factors.

A cold request arrives with a short history — observed entries over the
*other* modes — and needs a factor row NOW, without touching the trained
model. The row solves the same regularized normal equations one ALS mode
update solves (paper §2.2), restricted to one row:

    (G_u + λI) x_u = b_u,   b_u = MTTKRP(history, frozen factors)
    G_u x = MTTKRP(TTTP(Ω_u, [.., x, ..]), frozen factors)   (eq. 3)

so fold-in is a *reuse* of the training machinery, not new math: all B
requests in a batch are packed as the B "rows" of one SparseTensor whose
``mode`` extent is the batch slot, and ``als.gram_matvec`` +
``als.batched_cg`` solve all of them in lockstep — exactly one batched
one-row ALS update. ``matvec_path`` routes the Gram matvec through the
planner's CG_MATVEC family (``"tttp_mttkrp"``, ``"dense"``, …) instead of
the direct kernel composition.
"""
from __future__ import annotations

import functools
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.completion import als
from repro.core.sparse_tensor import SparseTensor
from repro.core.utils import round_up
from repro.sparse import ops as sops

History = Tuple[np.ndarray, np.ndarray]   # (other-mode indices, values)


def pack_histories(histories: Sequence[History], shape: Sequence[int],
                   mode: int, cap: Optional[int] = None,
                   pad_multiple: int = 8) -> SparseTensor:
    """Pack per-user histories into ONE SparseTensor whose ``mode`` extent
    is the batch slot.

    Each history is ``(other_idx, values)`` with ``other_idx`` of shape
    (n_u, ndim-1) indexing the non-``mode`` modes in ascending mode order.
    Entry capacity pads to ``cap`` (or the next ``pad_multiple``) so the
    engine can bucket compilations."""
    ndim = len(shape)
    others = [d for d in range(ndim) if d != mode]
    idx_rows: List[np.ndarray] = []
    val_rows: List[np.ndarray] = []
    for slot, (other_idx, values) in enumerate(histories):
        values = np.asarray(values, np.float32).reshape(-1)
        other_idx = np.asarray(other_idx, np.int32).reshape(
            values.shape[0], ndim - 1)
        idx = np.zeros((values.shape[0], ndim), np.int32)
        idx[:, others] = other_idx
        idx[:, mode] = slot
        idx_rows.append(idx)
        val_rows.append(values)
    indices = np.concatenate(idx_rows, axis=0)
    values = np.concatenate(val_rows, axis=0)
    for d in others:
        lo, hi = indices[:, d].min(initial=0), indices[:, d].max(initial=0)
        if lo < 0 or hi >= shape[d]:
            raise ValueError(f"history index out of range on mode {d}: "
                             f"[{lo}, {hi}] vs extent {shape[d]}")
    st_shape = tuple(len(histories) if d == mode else int(shape[d])
                     for d in range(ndim))
    return SparseTensor.from_coo(indices, values, st_shape, cap=cap,
                                 pad_multiple=pad_multiple)


def fold_in(st_hist: SparseTensor, factors: Sequence[jax.Array], mode: int,
            lam: float = 1e-2, cg_tol: float = 1e-6,
            cg_iters: Optional[int] = None,
            matvec_path: Optional[str] = None,
            weights: Optional[jax.Array] = None,
            x0: Optional[jax.Array] = None):
    """Solve the batched one-row damped ALS systems; returns ``(rows
    (B, R), cg_iters_run)``.

    ``st_hist`` is a :func:`pack_histories` tensor (``shape[mode]`` = B).
    ``weights`` supplies per-entry ω_n (implicit-feedback/confidence
    weighting, or a loss curvature); default is the plain Ω indicator.
    CG on an R×R SPD system terminates in R iterations *in exact
    arithmetic only* — in float32 with a fitted (ill-scaled) Gram it does
    not, so the default budget is max(4R, 32) with the ``cg_tol``
    relative-residual stop doing the real work (converged rows freeze, so
    the extra headroom costs little). The result matches a fresh explicit
    one-row ALS solve to ~1e-5 at serving ranks."""
    fs = list(factors)
    others = [d for d in range(st_hist.ndim) if d != mode]
    if any(fs[d] is None for d in others):
        raise ValueError("fold-in needs a frozen factor on every other mode")
    r = int(fs[others[0]].shape[1])
    batch = int(st_hist.shape[mode])
    cg_iters = max(4 * r, 32) if cg_iters is None else cg_iters

    b_factors = [None if d == mode else fs[d] for d in range(st_hist.ndim)]
    b = sops.mttkrp(st_hist, b_factors, mode)               # (B, R)
    omega = st_hist.with_values(
        jnp.ones((st_hist.cap,), b.dtype) if weights is None else weights)
    mv = functools.partial(als.gram_matvec, omega, fs, mode, lam=lam,
                           matvec_path=matvec_path)
    if x0 is None:
        x0 = jnp.zeros((batch, r), b.dtype)
    rows, iters = als.batched_cg(mv, b, x0, tol=cg_tol, max_iters=cg_iters)
    return rows, iters


def fold_in_single(factors: Sequence[jax.Array], mode: int,
                   other_idx, values, shape: Sequence[int],
                   **kw) -> jax.Array:
    """One user's fold-in row (R,): convenience wrapper over the batched
    path with B = 1."""
    st = pack_histories([(other_idx, values)], shape, mode,
                        cap=round_up(max(len(np.asarray(values)), 1), 8))
    rows, _ = fold_in(st, factors, mode, **kw)
    return rows[0]
