"""ServeEngine: the batched query front-end over a frozen ServingModel.

Requests arrive as host arrays of arbitrary size; the engine pads each
batch up to a power-of-two bucket (bounding jit recompiles to
O(log max_batch) per endpoint) and dispatches jit-compiled kernels:

* ``score``   — entry scoring via the gather→Hadamard→rank-sum chain, or
                via a forced planner TTTP path (``score_path=``) so the
                parity of serving vs training dispatch is testable;
* ``top_k``   — query-vector build + blocked streaming top-k
                (``serve.topk``), retrieval over any mode;
* ``fold_in`` — batched one-row ALS on the eq.-3 Gram matvec
                (``serve.foldin``), capacity padded to buckets.

Every endpoint is wrapped in an ``obs.span`` (fenced — the span covers
the device work, not just dispatch) and feeds per-endpoint counters, so
an enabled trace shows the serving latency breakdown next to the planner
and kernel spans it triggers.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core.sparse_tensor import SparseTensor
from repro.serve import foldin as _foldin
from repro.serve import topk as _topk
from repro.serve.model import ServingModel, apply_link, multilinear_scores


def percentiles(samples_s: Sequence[float]) -> Dict[str, float]:
    """Load-generator summary of per-call wall times (seconds in,
    microseconds out): p50/p95/p99/mean/max over the sample set."""
    if not samples_s:
        return {}
    xs = np.sort(np.asarray(samples_s, np.float64)) * 1e6
    pick = lambda q: float(xs[min(len(xs) - 1, int(q * len(xs)))])
    return {"p50_us": pick(0.50), "p95_us": pick(0.95),
            "p99_us": pick(0.99), "mean_us": float(xs.mean()),
            "max_us": float(xs.max()), "calls": len(xs)}


def _bucket(n: int, lo: int, hi: int) -> int:
    b = lo
    while b < min(n, hi):
        b *= 2
    return b


class ServeEngine:
    """Stateless-per-request serving over one frozen :class:`ServingModel`.

    ``score_path`` forces the scoring contraction through a planner TTTP
    candidate (``all_at_once``/``sliced``/``pairwise``/``dense``) instead
    of the direct gather chain; ``foldin_matvec_path`` routes fold-in's
    Gram matvec through the CG_MATVEC family the same way."""

    def __init__(self, model: ServingModel, max_batch: int = 4096,
                 min_batch: int = 64, topk_block: int = 4096,
                 score_path: Optional[str] = None,
                 foldin_lam: float = 1e-2,
                 foldin_matvec_path: Optional[str] = None):
        # the engine gathers factor rows by GLOBAL index on every score and
        # scans full factors for top-k: a device-sharded factor would
        # resolve those indices against its local shard and return garbage.
        # Refuse construction instead (ROADMAP: sharded-factor serving).
        for d, f in enumerate(model.factors):
            sh = getattr(f, "sharding", None)
            if sh is not None and not getattr(sh, "is_fully_replicated",
                                              True):
                raise ValueError(
                    f"ServeEngine requires fully replicated factors, but "
                    f"factor {d} is sharded ({sh}); all-gather the factors "
                    f"onto every device (or serve from a host copy) before "
                    f"constructing the engine")
        self.model = model
        self.max_batch = int(max_batch)
        self.min_batch = int(min_batch)
        self.topk_block = int(topk_block)
        self.score_path = score_path
        self.foldin_lam = float(foldin_lam)
        self.foldin_matvec_path = foldin_matvec_path
        self._score_jit = jax.jit(self._score_impl,
                                  static_argnames=("link",))
        self._topk_jit = jax.jit(self._topk_impl,
                                 static_argnames=("target_mode", "k"))
        self._foldin_jit = jax.jit(self._foldin_impl,
                                   static_argnames=("mode",))

    # -- jitted kernels (factors passed as args: one trace per bucket) -----
    def _score_impl(self, factors, idx, link: str):
        if self.score_path is None:
            m = multilinear_scores(factors, idx)
        else:
            from repro.planner import planned_tttp
            ones = jnp.ones((idx.shape[0],), factors[0].dtype)
            st = SparseTensor(idx, ones, jnp.ones_like(ones, bool),
                              self.model.shape)
            m = planned_tttp(st, list(factors), path=self.score_path).values
        return apply_link(m, link)

    def _topk_impl(self, factors, fixed, target_mode: int, k: int):
        q = _topk.query_rows(factors, fixed)
        return _topk.topk_over_mode(factors[target_mode], q, k,
                                    block_rows=self.topk_block,
                                    link=self.model.link)

    def _foldin_impl(self, st_hist, factors, mode: int):
        rows, iters = _foldin.fold_in(
            st_hist, list(factors), mode, lam=self.foldin_lam,
            matvec_path=self.foldin_matvec_path)
        return rows, iters

    # -- endpoints ----------------------------------------------------------
    def score(self, indices, link: Optional[bool] = True) -> np.ndarray:
        """(n,) predictions for (n, ndim) entry indices. ``link=False``
        returns raw model-space values."""
        idx = np.asarray(indices, np.int32)
        if idx.ndim != 2 or idx.shape[1] != self.model.ndim:
            raise ValueError(f"score expects (n, {self.model.ndim}) "
                             f"indices, got {idx.shape}")
        n = idx.shape[0]
        lk = self.model.link if link else "identity"
        fs = tuple(self.model.factors)
        out = np.empty((n,), np.dtype(fs[0].dtype))
        with obs.span("serve/score", n=n, link=lk,
                      path=self.score_path or "gather") as sp:
            for lo in range(0, n, self.max_batch):
                chunk = idx[lo:lo + self.max_batch]
                b = _bucket(chunk.shape[0], self.min_batch, self.max_batch)
                pad = np.zeros((b, idx.shape[1]), np.int32)
                pad[:chunk.shape[0]] = chunk
                vals = sp.fence(self._score_jit(fs, jnp.asarray(pad), lk))
                out[lo:lo + chunk.shape[0]] = \
                    np.asarray(vals)[:chunk.shape[0]]
            obs.counter_add("serve/queries", n)
        return out

    def top_k(self, fixed: Mapping[int, np.ndarray], target_mode: int,
              k: int) -> Tuple[np.ndarray, np.ndarray]:
        """Per-query top-k over ``target_mode``: ``fixed`` maps each other
        mode to (B,) indices or (B, R) rows; returns (scores, indices),
        each (B, k), scores descending."""
        if target_mode in fixed:
            raise ValueError(f"target mode {target_mode} cannot be fixed")
        fx = {int(d): jnp.asarray(v) for d, v in fixed.items()}
        sizes = {int(v.shape[0]) for v in fx.values()}
        if len(sizes) != 1:
            raise ValueError(f"fixed modes disagree on batch: {sizes}")
        b = sizes.pop()
        fs = tuple(self.model.factors)
        with obs.span("serve/top_k", b=b, k=k,
                      target_mode=target_mode) as sp:
            vals, idx = sp.fence(self._topk_jit(fs, fx, target_mode,
                                                int(k)))
            obs.counter_add("serve/topk_queries", b)
        return np.asarray(vals), np.asarray(idx)

    def fold_in(self, histories: Sequence[_foldin.History],
                mode: int) -> np.ndarray:
        """(B, R) fresh factor rows for B cold users' histories over the
        other modes (see ``serve.foldin``)."""
        total = sum(len(np.asarray(v).reshape(-1)) for _, v in histories)
        cap = _bucket(max(total, 1), self.min_batch, 1 << 30)
        st = _foldin.pack_histories(histories, self.model.shape, mode,
                                    cap=cap)
        # drop the exact-nnz static hint: it varies per request batch and
        # would force a retrace per distinct history size
        st = dataclasses.replace(st, nnz=None)
        fs = tuple(self.model.factors)
        with obs.span("serve/fold_in", b=len(histories), nnz=total,
                      cap=cap, mode=mode) as sp:
            rows, _ = sp.fence(self._foldin_jit(st, fs, mode))
            obs.counter_add("serve/foldin_users", len(histories))
        return np.asarray(rows)
