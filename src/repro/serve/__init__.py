"""Real-time recommendation serving on frozen factors (DESIGN.md §14).

The training side of the repo fits CP factor matrices at Netflix scale;
this package *uses* them: restore a frozen-factor checkpoint and answer

* batched entry scoring — predict (i, j, k) via the multilinear CP model
  (``link="log"`` evaluates in rate space, matching the ``*_log`` losses);
* per-user fold-in for cold requests — one damped one-row ALS solve
  against the frozen factors, i.e. batched CG on the paper's eq.-3
  weighted Gram matvec (``als.gram_matvec`` / the CG_MATVEC planner
  family), no retraining;
* top-k item retrieval — blocked matmul over the item factor with a
  streaming top-k merge, never materializing the full score row.

Layering::

    model.py    ServingModel — frozen factors + link, checkpoint/npz load
    foldin.py   history packing + batched one-row ALS fold-in
    topk.py     query vectors + blocked streaming top-k
    engine.py   ServeEngine — jit'd batched endpoints, obs.span'd
"""
from repro.serve.engine import ServeEngine, percentiles
from repro.serve.foldin import fold_in, fold_in_single, pack_histories
from repro.serve.model import ServingModel, apply_link, load_factors
from repro.serve.topk import query_rows, topk_over_mode

__all__ = [
    "ServeEngine", "ServingModel", "apply_link", "fold_in",
    "fold_in_single", "load_factors", "pack_histories", "percentiles",
    "query_rows", "topk_over_mode",
]
