"""Top-k item retrieval: blocked matmul + streaming top-k merge.

For a CP model, the scores of every item j for a query (user i at context
k, say) factor through a single R-vector:

    s_j = Σ_r U[i,r] W[k,r] V[j,r] = V @ q,   q = U[i] ⊙ W[k]

so retrieval is one matvec against the item factor. At millions of items
the full (B, J) score matrix is never materialized: the item factor is
processed in row blocks, each block's (B, block) scores are merged into a
running (B, k) top-k via ``lax.top_k`` on the concatenation — the
``TopKTensor``/``topkx`` streaming idiom, VMEM/cache-resident at
Θ(B·(k + block)) regardless of J. Links that are monotone (both supported
links are) commute with top-k, so the merge runs in model space and the
link is applied once to the k winners.
"""
from __future__ import annotations

from typing import Mapping, Sequence, Union

import jax
import jax.numpy as jnp

from repro.core.utils import pad_axis, round_up
from repro.serve.model import apply_link


def query_rows(factors: Sequence[jax.Array],
               fixed: Mapping[int, Union[jax.Array, "jnp.ndarray"]]):
    """(B, R) query vectors: Hadamard product over the fixed modes.

    ``fixed`` maps mode → either (B,) int indices into that mode's frozen
    factor or explicit (B, R) rows (e.g. fresh fold-in output that is not
    part of any factor)."""
    if not fixed:
        raise ValueError("query_rows needs at least one fixed mode")
    q = None
    for d in sorted(fixed):
        v = jnp.asarray(fixed[d])
        rows = v if v.ndim == 2 else factors[d][v]
        q = rows if q is None else q * rows
    return q


def topk_over_mode(item_factor: jax.Array, queries: jax.Array, k: int,
                   block_rows: int = 4096, link: str = "identity"):
    """Streaming blocked top-k: ``(scores (B, k), indices (B, k))``,
    scores descending per row, with ``link`` applied to the winners.

    ``item_factor`` is the (J, R) frozen factor of the retrieved mode;
    ``queries`` the (B, R) query vectors. jit-safe: the block loop is a
    ``lax.fori_loop`` over static block count, padding rows masked to
    -inf so they can never win."""
    j, r = int(item_factor.shape[0]), int(item_factor.shape[1])
    k = min(int(k), j)
    block = min(int(block_rows), round_up(j, 8))
    jp = round_up(j, block)
    vp = pad_axis(item_factor, jp, axis=0)
    b = queries.shape[0]
    neg = jnp.array(jnp.finfo(queries.dtype).min, queries.dtype)

    def body(i, carry):
        vals, idx = carry
        blk = jax.lax.dynamic_slice(vp, (i * block, 0), (block, r))
        s = queries @ blk.T                              # (B, block)
        gidx = i * block + jnp.arange(block, dtype=jnp.int32)
        s = jnp.where(gidx[None, :] < j, s, neg)
        cat_v = jnp.concatenate([vals, s], axis=1)
        cat_i = jnp.concatenate(
            [idx, jnp.broadcast_to(gidx[None, :], (b, block))], axis=1)
        vals, sel = jax.lax.top_k(cat_v, k)
        return vals, jnp.take_along_axis(cat_i, sel, axis=1)

    init = (jnp.full((b, k), neg, queries.dtype),
            jnp.zeros((b, k), jnp.int32))
    vals, idx = jax.lax.fori_loop(0, jp // block, body, init)
    return apply_link(vals, link), idx
