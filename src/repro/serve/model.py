"""ServingModel: frozen CP factors + link, restored from a checkpoint.

The serving layer trusts exactly two on-disk formats, both written by
``launch/complete.py --dump-factors``:

* a ``repro.checkpoint`` step directory — state ``{"factor_<d>": A_d}``
  with the fit's metadata (rank, shape, loss, link) in the manifest; the
  restore path goes through :func:`repro.checkpoint.restore`, so every
  leaf is validated against the manifest's recorded shape/dtype and a
  drifted checkpoint (e.g. rank changed between fit and serve) fails
  fast naming the offending factor;
* a legacy ``.npz`` with keys ``factor_0..factor_{N-1}`` (no metadata —
  the caller supplies the link).

Scoring is the CP model itself:  m(i1..iN) = Σ_r Π_d A_d[i_d, r], with
``link="log"`` mapping to rate space as  exp(clip(m, ±30)) — the same
clamp ``data.streaming.heldout_metrics`` evaluates with, so a served
score is bit-comparable to the fit's held-out metrics.
"""
from __future__ import annotations

import dataclasses
import os
import re
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

LINKS = ("identity", "log")
# rate-space clamp — keep in sync with data.streaming.heldout_metrics
_LOG_CLIP = 30.0


def apply_link(m: jax.Array, link: str) -> jax.Array:
    """Model-space → prediction-space. ``log`` predicts rates exp(m) with
    the heldout_metrics clamp; ``identity`` is a no-op."""
    if link == "identity":
        return m
    if link == "log":
        return jnp.exp(jnp.clip(m, -_LOG_CLIP, _LOG_CLIP))
    raise ValueError(f"unknown link {link!r}; choices: {LINKS}")


def multilinear_scores(factors: Sequence[jax.Array],
                       indices: jax.Array) -> jax.Array:
    """Batched CP entry scores: (B, ndim) int indices → (B,) model values.

    The gather→Hadamard→rank-sum chain of ``core.tttp.multilinear_values``
    without the SparseTensor wrapper — the serving hot path."""
    prod = factors[0][indices[:, 0]]
    for d in range(1, len(factors)):
        prod = prod * factors[d][indices[:, d]]
    return jnp.sum(prod, axis=1)


@dataclasses.dataclass
class ServingModel:
    """Frozen factors + link + fit metadata. Factors are never mutated by
    the serving layer; fold-in returns *new* rows, it does not write back."""

    factors: List[jax.Array]
    link: str = "identity"
    meta: Dict = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        if not self.factors:
            raise ValueError("ServingModel needs at least one factor")
        ranks = {int(f.shape[1]) for f in self.factors}
        if len(ranks) != 1:
            raise ValueError(f"factors disagree on rank: {sorted(ranks)}")
        if self.link not in LINKS:
            raise ValueError(f"unknown link {self.link!r}; choices: {LINKS}")

    @property
    def shape(self) -> Tuple[int, ...]:
        return tuple(int(f.shape[0]) for f in self.factors)

    @property
    def rank(self) -> int:
        return int(self.factors[0].shape[1])

    @property
    def ndim(self) -> int:
        return len(self.factors)

    def raw_scores(self, indices: jax.Array) -> jax.Array:
        """(B,) model-space values at the given (B, ndim) entries."""
        return multilinear_scores(self.factors, indices)

    def predict(self, indices: jax.Array) -> jax.Array:
        """(B,) predictions with the link applied (rates under ``log``)."""
        return apply_link(self.raw_scores(indices), self.link)


def _factors_from_arrays(arrays: Dict[int, np.ndarray]) -> List[jax.Array]:
    modes = sorted(arrays)
    if modes != list(range(len(modes))):
        raise ValueError(f"factor modes not contiguous from 0: {modes}")
    return [jnp.asarray(arrays[d]) for d in modes]


def _load_npz(path: str) -> List[jax.Array]:
    with np.load(path) as z:
        arrays = {}
        for key in z.files:
            m = re.fullmatch(r"factor_(\d+)", key)
            if m:
                arrays[int(m.group(1))] = z[key]
    if not arrays:
        raise ValueError(f"{path}: no factor_<d> arrays found")
    return _factors_from_arrays(arrays)


def _load_checkpoint(path: str, step: Optional[int]):
    from repro import checkpoint as ckpt

    if step is None:
        step = ckpt.latest_step(path)
        if step is None:
            raise ValueError(f"{path}: no committed checkpoint steps found")
    manifest = ckpt.read_manifest(path, step)
    # rebuild the `like` pytree from the manifest alone — the serving
    # process knows nothing about the fit's rank/shape until it reads this
    shapes: Dict[int, tuple] = {}
    for key, ent in manifest.get("leaves", {}).items():
        m = re.search(r"factor_(\d+)", key)
        if m:
            shapes[int(m.group(1))] = (tuple(ent["shape"]),
                                       np.dtype(ent["dtype"]))
    if not shapes:
        raise ValueError(
            f"{path} step {step}: manifest has no factor_<d> leaves "
            f"(records {sorted(manifest.get('leaves', {}))}) — not a "
            f"factor checkpoint")
    like = {f"factor_{d}": jnp.zeros(sh, dt)
            for d, (sh, dt) in shapes.items()}
    state, manifest = ckpt.restore(path, step, like)
    arrays = {d: state[f"factor_{d}"] for d in shapes}
    return _factors_from_arrays(arrays), manifest.get("metadata", {}) or {}


def load_factors(path: str, link: Optional[str] = None,
                 step: Optional[int] = None) -> ServingModel:
    """Restore a :class:`ServingModel` from ``path``.

    A directory is treated as a ``repro.checkpoint`` root (newest step
    unless ``step`` is given; metadata supplies the link unless ``link``
    overrides); a ``.npz`` file as the legacy ``--dump-factors`` format
    (link defaults to identity)."""
    if os.path.isdir(path):
        factors, meta = _load_checkpoint(path, step)
    else:
        factors, meta = _load_npz(path), {}
    resolved = link or meta.get("link") or "identity"
    return ServingModel(factors, link=resolved, meta=meta)
