"""Sharded input pipelines.

For completion workloads the dataset is a SparseTensor ingested once:
shuffle → pad → device_put with nonzeros sharded over the data axes, plus
ingest-time CCSR bucketing per mode for the Pallas kernels.

For LM workloads a host-side iterator yields token batches placed with
batch-over-data sharding; a one-deep prefetch overlaps host generation with
device compute (the CPU-container stand-in for a real multi-host input
service)."""
from __future__ import annotations

import collections
import threading
from typing import Dict, Iterator, Optional, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.sparse_tensor import SparseTensor
from repro.data import synthetic
from repro.sparse import redistribute


class CompletionDataset:
    """Ingested, distribution-ready sparse dataset (+ per-mode bucket views).

    Ingest builds the CCSR bucket pattern for every mode ONCE (the Ω pattern
    is static across completion sweeps, as in Cyclops' runtime layout
    decisions) and attaches it to the tensor; ``omega`` is derived via
    ``with_values`` and therefore SHARES the cached patterns — planner
    dispatch re-gathers bucket values through them instead of re-running the
    host-side bucketize per call (DESIGN.md §9). The cache serves EAGER
    dispatch (benchmarks, interactive solves): it does not cross the tracer
    boundary, so jit'd sweeps fall back to the all-at-once kernels — pass
    ``bucket_modes=()`` to skip the ingest build when every consumer is
    jit'd."""

    def __init__(self, st: SparseTensor, key, mesh: Optional[Mesh] = None,
                 data_axes=("data",), block_rows: Optional[int] = None,
                 bucket_modes: Optional[Sequence[int]] = None):
        num_shards = 1
        if mesh is not None:
            import numpy as np
            num_shards = int(np.prod([mesh.shape[a] for a in data_axes]))
        self.tensor = synthetic.shuffle_and_pad(st, key, num_shards)
        if mesh is not None:
            axes = data_axes if len(data_axes) > 1 else data_axes[0]
            self.tensor = redistribute.shard_nonzeros(self.tensor, mesh, axes)
        if block_rows is None:
            from repro.planner.config import default_config
            block_rows = default_config().block_rows
        self.block_rows = block_rows
        modes = range(self.tensor.ndim) if bucket_modes is None else bucket_modes
        for mode in modes:
            self.tensor.row_buckets(mode, block_rows)
        self.omega = self.tensor.with_values(
            jnp.ones_like(self.tensor.values))
        self.mesh = mesh
        self.data_axes = data_axes


def prefetch(it: Iterator, depth: int = 1) -> Iterator:
    """Background-thread prefetch of host batches (overlap input with step)."""
    q: collections.deque = collections.deque()
    lock = threading.Semaphore(0)
    done = []

    def worker():
        for item in it:
            q.append(item)
            lock.release()
        done.append(True)
        lock.release()

    t = threading.Thread(target=worker, daemon=True)
    t.start()
    while True:
        lock.acquire()
        if q:
            yield q.popleft()
        elif done:
            return


def lm_batches(key, vocab_size: int, batch: int, seq_len: int,
               num_batches: int, mesh: Optional[Mesh] = None,
               batch_axes=("data",)) -> Iterator[Dict[str, jax.Array]]:
    """Sharded token batches for the LM train driver."""
    stream = synthetic.token_stream(key, vocab_size, batch, seq_len,
                                    num_batches)
    if mesh is None:
        yield from prefetch(stream)
        return
    axes = batch_axes if len(batch_axes) > 1 else batch_axes[0]
    sharding = NamedSharding(mesh, P(axes, None))
    for b in prefetch(stream):
        yield {k: jax.device_put(v, sharding) for k, v in b.items()}
