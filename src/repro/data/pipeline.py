"""Sharded input pipelines.

For completion workloads the dataset is a SparseTensor ingested once:
shuffle → pad → device_put with nonzeros sharded over the data axes, plus
ingest-time CCSR bucketing per mode for the Pallas kernels.

Paper-scale tensors never materialize the raw COO: ``CompletionDataset
.from_stream`` ingests a chunk iterator (``repro.data.streaming``) with
chunk-wise dedup, deterministic hash-sharding, optional disk spill and an
incremental bucket-pattern build from streamed occupancy counts — peak
host memory O(chunk), and the streamed stats feed the planner's nnz/
nnz_rows hints (DESIGN.md §10).

For LM workloads a host-side iterator yields token batches placed with
batch-over-data sharding; a one-deep prefetch overlaps host generation with
device compute (the CPU-container stand-in for a real multi-host input
service)."""
from __future__ import annotations

import collections
import threading
from typing import Dict, Iterator, Optional, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.sparse_tensor import SparseTensor
from repro.data import synthetic
from repro.sparse import redistribute


def _mesh_shards(mesh: Optional[Mesh], data_axes) -> int:
    if mesh is None:
        return 1
    import numpy as np
    return int(np.prod([mesh.shape[a] for a in data_axes]))


class CompletionDataset:
    """Ingested, distribution-ready sparse dataset (+ per-mode bucket views).

    Ingest builds the CCSR bucket pattern for every mode ONCE (the Ω pattern
    is static across completion sweeps, as in Cyclops' runtime layout
    decisions) and attaches it to the tensor; ``omega`` is derived via
    ``with_values`` and therefore SHARES the cached patterns — planner
    dispatch re-gathers bucket values through them instead of re-running the
    host-side bucketize per call (DESIGN.md §9). The cache serves EAGER
    dispatch (benchmarks, interactive solves): it does not cross the tracer
    boundary, so jit'd sweeps fall back to the all-at-once kernels — pass
    ``bucket_modes=()`` to skip the ingest build when every consumer is
    jit'd."""

    def __init__(self, st: SparseTensor, key, mesh: Optional[Mesh] = None,
                 data_axes=("data",), block_rows: Optional[int] = None,
                 bucket_modes: Optional[Sequence[int]] = None):
        num_shards = _mesh_shards(mesh, data_axes)
        tensor = synthetic.shuffle_and_pad(st, key, num_shards)
        self._finish(tensor, mesh, data_axes, block_rows, bucket_modes,
                     num_shards=num_shards, stats=None)

    # -- streamed construction (DESIGN.md §10) -----------------------------
    @classmethod
    def from_stream(cls, chunks, shape, num_shards: Optional[int] = None,
                    mesh: Optional[Mesh] = None, data_axes=("data",),
                    block_rows: Optional[int] = None,
                    bucket_modes: Optional[Sequence[int]] = None,
                    spool_dir: Optional[str] = None,
                    test_fraction: float = 0.0) -> "CompletionDataset":
        """Ingest a chunk stream (``repro.data.streaming``) without ever
        materializing the raw COO tensor: chunk-wise dedup + hash-sharding
        + per-shard sort-merge into the canonical shard-block layout, with
        the per-mode bucket patterns built from streamed occupancy counts.
        No shuffle pass: the coordinate hash already balances shards (the
        cyclic-layout argument), and the layout is deterministic — the same
        stream yields bit-identical entries for any shard count."""
        from repro.data import streaming
        if num_shards is None:
            num_shards = _mesh_shards(mesh, data_axes)
        elif mesh is not None and num_shards != _mesh_shards(mesh, data_axes):
            raise ValueError("num_shards conflicts with the mesh data axes")
        if block_rows is None:
            from repro.planner.config import default_config
            block_rows = default_config().block_rows
        want_buckets = bucket_modes is None or len(tuple(bucket_modes)) > 0
        train, test, stats = streaming.ingest(
            chunks, shape, num_shards=num_shards, spool_dir=spool_dir,
            test_fraction=test_fraction,
            block_rows=block_rows if want_buckets else None)
        ds = cls.__new__(cls)
        ds._finish(train, mesh, data_axes, block_rows, bucket_modes,
                   num_shards=num_shards, stats=stats)
        ds.test = test
        return ds

    def _finish(self, tensor: SparseTensor, mesh, data_axes, block_rows,
                bucket_modes, num_shards: int = 1, stats=None):
        self.stats = stats
        self.test = None
        self.num_shards = num_shards
        if block_rows is None:
            from repro.planner.config import default_config
            block_rows = default_config().block_rows
        self.block_rows = block_rows
        if mesh is not None:
            axes = data_axes if len(data_axes) > 1 else data_axes[0]
            tensor = redistribute.shard_nonzeros(tensor, mesh, axes)
        modes = range(tensor.ndim) if bucket_modes is None else bucket_modes
        counts = getattr(stats, "bucket_counts", None) if stats else None
        use_counts = (counts is not None
                      and stats.bucket_block_rows == block_rows)
        for mode in modes:
            if use_counts:
                # incremental build: capacity comes from the occupancy
                # counts streamed at ingest — no extra counting pass
                from repro.sparse.ccsr import bucket_capacity, bucket_pattern
                tensor.attach_pattern(
                    mode, block_rows,
                    bucket_pattern(tensor, mode, block_rows,
                                   capacity=bucket_capacity(counts[mode])))
            else:
                tensor.row_buckets(mode, block_rows)
        self.tensor = tensor
        self.omega = self.tensor.with_values(
            jnp.ones_like(self.tensor.values))
        self.mesh = mesh
        self.data_axes = data_axes

    def gather_global(self):
        """Host-side canonical view of the valid entries — (indices, values)
        sorted by linearized coordinate. Shard layout and padding cancel
        out, so two ingest routes over the same logical tensor compare
        bit-for-bit regardless of shard count (tests/test_streaming.py)."""
        import numpy as np
        idx = np.asarray(jax.device_get(self.tensor.indices))
        vals = np.asarray(jax.device_get(self.tensor.values))
        valid = np.asarray(jax.device_get(self.tensor.valid))
        idx, vals = idx[valid], vals[valid]
        lin = np.zeros(idx.shape[0], np.int64)
        for d, s in enumerate(self.tensor.shape):
            lin = lin * np.int64(s) + idx[:, d].astype(np.int64)
        order = np.argsort(lin, kind="stable")
        return idx[order], vals[order]


def prefetch(it: Iterator, depth: int = 1) -> Iterator:
    """Background-thread prefetch of host batches (overlap input with step)."""
    q: collections.deque = collections.deque()
    lock = threading.Semaphore(0)
    done = []

    def worker():
        for item in it:
            q.append(item)
            lock.release()
        done.append(True)
        lock.release()

    t = threading.Thread(target=worker, daemon=True)
    t.start()
    while True:
        lock.acquire()
        if q:
            yield q.popleft()
        elif done:
            return


def lm_batches(key, vocab_size: int, batch: int, seq_len: int,
               num_batches: int, mesh: Optional[Mesh] = None,
               batch_axes=("data",)) -> Iterator[Dict[str, jax.Array]]:
    """Sharded token batches for the LM train driver."""
    stream = synthetic.token_stream(key, vocab_size, batch, seq_len,
                                    num_batches)
    if mesh is None:
        yield from prefetch(stream)
        return
    axes = batch_axes if len(batch_axes) > 1 else batch_axes[0]
    sharding = NamedSharding(mesh, P(axes, None))
    for b in prefetch(stream):
        yield {k: jax.device_put(v, sharding) for k, v in b.items()}
