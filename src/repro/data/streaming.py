"""Streaming, memory-bounded (out-of-core) ingest for paper-scale tensors.

The paper's headline runs — 10B-nonzero synthetic tensors and the Netflix
data — cannot be *constructed* by an ingest path that materializes the whole
COO tensor at once. This module makes ingest a chunked pipeline whose peak
host memory is O(chunk), not O(nnz) (DESIGN.md §10):

* **chunk generators** — deterministic synthetic streams (the Fig.-7a
  function tensor and the Zipf "netflix-like" ratings tensor) parameterized
  by target nnz with per-chunk RNG folding, plus a triplet-file reader for
  real Netflix-format data. Chunks are plain numpy (host) arrays.
* **StreamingIngest** — per chunk: in-chunk dedup/sort by linearized
  coordinate, deterministic hash-sharding over ``num_shards``, append to
  per-shard runs (in memory, or spilled to a spool directory for
  out-of-core operation). Finalize sort-merges each shard's runs into a
  canonical per-shard CCSR-friendly layout (sorted by linearized
  coordinate, first stream occurrence wins on duplicates) and builds the
  per-mode CCSR bucket patterns incrementally from streamed bucket counts
  (``repro.sparse.ccsr.IncrementalBucketBuilder``).
* **IngestStats** — streamed metadata (exact nnz, per-mode nonzero-row
  counts, bucket occupancies): the planner's nnz hints come from here
  instead of from materialized arrays.
* **split + held-out evaluation** — a deterministic per-coordinate
  train/test split (duplicates of a coordinate always land on one side)
  and RMSE / Poisson-deviance evaluation on the held-out set.

The layout is *canonical*: ingesting the same stream with any shard count
yields the same global entry set bit-for-bit (per-shard entries are sorted
by linearized coordinate; shard membership is a pure hash of the
coordinate), which `tests/test_streaming.py` pins against the in-memory
path.
"""
from __future__ import annotations

import dataclasses
import os
import time
from typing import Callable, Iterable, Iterator, List, Optional, Sequence, Tuple

import numpy as np

from repro import obs
from repro.core.utils import round_up

# ---------------------------------------------------------------------------
# chunks
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Chunk:
    """One host-side slab of COO entries (possibly containing duplicates)."""
    indices: np.ndarray   # (n, ndim) int32
    values: np.ndarray    # (n,) float32

    def __len__(self) -> int:
        return self.indices.shape[0]


def _linearize64(indices: np.ndarray, shape: Sequence[int]) -> np.ndarray:
    """Row-major linearized coordinates in int64 (paper-scale shapes exceed
    int32: the full Netflix tensor has ~1.9e13 cells)."""
    lin = np.zeros(indices.shape[0], np.int64)
    for d, s in enumerate(shape):
        lin = lin * np.int64(s) + indices[:, d].astype(np.int64)
    return lin


def _mix64(x: np.ndarray) -> np.ndarray:
    """splitmix64 finalizer — the deterministic shard-assignment hash."""
    x = x.astype(np.uint64)
    x = (x ^ (x >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
    x = (x ^ (x >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
    return x ^ (x >> np.uint64(31))


def _chunk_rng(seed: int, chunk_id: int) -> np.random.Generator:
    """Per-chunk RNG folding: chunk c of stream ``seed`` is reproducible in
    isolation (workers may generate chunks independently)."""
    return np.random.default_rng(np.random.SeedSequence([int(seed), chunk_id]))


def _zipf_cdf(n: int, a: float) -> np.ndarray:
    w = np.arange(1, n + 1, dtype=np.float64) ** (-a)
    return np.cumsum(w) / np.sum(w)


def function_stream(seed: int, shape: Sequence[int], nnz: int,
                    chunk_size: int = 1 << 20) -> Iterator[Chunk]:
    """The Karlsson et al. model problem (paper Fig. 7a) as a chunk stream:
    t_i = sigmoid(3 Σ_d x_d[i_d]), x_d ~ U[-1, 1]. The per-mode grids are
    O(Σ I_d) host memory; each chunk is O(chunk_size)."""
    rng = np.random.default_rng(np.random.SeedSequence([int(seed)]))
    grids = [rng.uniform(-1.0, 1.0, size=s).astype(np.float32) for s in shape]
    emitted = 0
    chunk_id = 0
    while emitted < nnz:
        n = min(chunk_size, nnz - emitted)
        crng = _chunk_rng(seed, chunk_id)
        idx = np.stack([crng.integers(0, s, size=n, dtype=np.int32)
                        for s in shape], axis=1)
        arg = np.zeros(n, np.float32)
        for d, g in enumerate(grids):
            arg += g[idx[:, d]]
        vals = (1.0 / (1.0 + np.exp(-3.0 * arg))).astype(np.float32)
        yield Chunk(idx, vals)
        emitted += n
        chunk_id += 1


def netflix_stream(seed: int, shape: Sequence[int], nnz: int,
                   chunk_size: int = 1 << 20,
                   zipf_a: float = 1.1) -> Iterator[Chunk]:
    """Netflix-shaped ratings stream (paper Fig. 7b): Zipf-skewed user/movie
    popularity, low-rank bias structure, integer ratings 1..5. Zipf sampling
    can emit repeated coordinates — ``StreamingIngest`` dedups (first stream
    occurrence wins), mirroring the in-memory ``synthetic.netflix_like``."""
    i_dim, j_dim, k_dim = shape
    rng = np.random.default_rng(np.random.SeedSequence([int(seed), 0xB1A5]))
    r = 4
    bu = (0.5 * rng.standard_normal((i_dim, r))).astype(np.float32)
    bv = (0.5 * rng.standard_normal((j_dim, r))).astype(np.float32)
    bw = (0.2 * rng.standard_normal((k_dim, r))).astype(np.float32)
    cdf_i = _zipf_cdf(i_dim, zipf_a)
    cdf_j = _zipf_cdf(j_dim, zipf_a)
    emitted = 0
    chunk_id = 0
    while emitted < nnz:
        n = min(chunk_size, nnz - emitted)
        crng = _chunk_rng(seed, chunk_id)
        ii = np.searchsorted(cdf_i, crng.random(n)).clip(0, i_dim - 1)
        jj = np.searchsorted(cdf_j, crng.random(n)).clip(0, j_dim - 1)
        kk = crng.integers(0, k_dim, size=n)
        base = 3.5 + np.sum(bu[ii] * bv[jj] * (1.0 + bw[kk]), axis=1)
        noise = 0.4 * crng.standard_normal(n).astype(np.float32)
        vals = np.clip(np.round(base + noise), 1.0, 5.0).astype(np.float32)
        idx = np.stack([ii, jj, kk], axis=1).astype(np.int32)
        yield Chunk(idx, vals)
        emitted += n
        chunk_id += 1


def triplet_file_stream(path: str, ndim: int = 3,
                        chunk_size: int = 1 << 20,
                        delimiter: Optional[str] = None,
                        one_based: bool = False,
                        comment: str = "#") -> Iterator[Chunk]:
    """Chunked reader for Netflix-format triplet files: one entry per line,
    ``i_0 ... i_{ndim-1} value`` (whitespace- or ``delimiter``-separated).
    Reads ``chunk_size`` lines at a time — peak memory O(chunk_size)."""
    off = 1 if one_based else 0
    with open(path) as f:
        rows: List[List[float]] = []
        for line in f:
            line = line.strip()
            if not line or line.startswith(comment):
                continue
            parts = line.split(delimiter) if delimiter else line.split()
            if len(parts) < ndim + 1:
                raise ValueError(f"{path}: expected {ndim} coordinates + "
                                 f"value per line, got {line!r}")
            rows.append([float(p) for p in parts[:ndim + 1]])
            if len(rows) >= chunk_size:
                yield _rows_to_chunk(rows, ndim, off)
                rows = []
        if rows:
            yield _rows_to_chunk(rows, ndim, off)


def _rows_to_chunk(rows: List[List[float]], ndim: int, off: int) -> Chunk:
    arr = np.asarray(rows, np.float64)
    idx = arr[:, :ndim].astype(np.int32) - np.int32(off)
    if (idx < 0).any():
        raise ValueError("negative coordinate after one_based adjustment")
    return Chunk(idx, arr[:, ndim].astype(np.float32))


STREAMS: dict = {"function": function_stream, "netflix": netflix_stream}


def make_stream(dataset: str, seed: int, shape: Sequence[int], nnz: int,
                chunk_size: int, path: Optional[str] = None,
                zipf_a: float = 1.1) -> Iterator[Chunk]:
    """Stream factory for the experiment harness / benchmarks."""
    if dataset == "file":
        if path is None:
            raise ValueError("dataset='file' needs a triplet file path")
        return triplet_file_stream(path, ndim=len(shape),
                                   chunk_size=chunk_size)
    if dataset == "netflix":
        return netflix_stream(seed, shape, nnz, chunk_size, zipf_a=zipf_a)
    if dataset == "function":
        return function_stream(seed, shape, nnz, chunk_size)
    raise ValueError(f"unknown dataset {dataset!r}")


# ---------------------------------------------------------------------------
# train/test split
# ---------------------------------------------------------------------------

_SPLIT_SALT = np.uint64(0x5EED5A17)


def split_chunk(chunk: Chunk, shape: Sequence[int],
                test_fraction: float) -> Tuple[Chunk, Chunk]:
    """Deterministic per-coordinate train/test split: every occurrence of a
    coordinate lands on the same side (the split commutes with dedup, so
    train and test are disjoint in Ω)."""
    if test_fraction <= 0.0:
        return chunk, Chunk(chunk.indices[:0], chunk.values[:0])
    lin = _linearize64(chunk.indices, shape)
    h = _mix64(lin.astype(np.uint64) ^ _SPLIT_SALT)
    is_test = (h % np.uint64(1 << 16)) < np.uint64(
        int(test_fraction * (1 << 16)))
    tr, te = ~is_test, is_test
    return (Chunk(chunk.indices[tr], chunk.values[tr]),
            Chunk(chunk.indices[te], chunk.values[te]))


# ---------------------------------------------------------------------------
# streaming ingest
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class IngestStats:
    """Streamed metadata — the planner's nnz hints come from here, not from
    materialized arrays (``SparseTensor.nnz``/``nnz_rows`` are set from this
    at finalize)."""
    shape: Tuple[int, ...]
    num_shards: int
    entries_read: int = 0        # raw stream entries, before any dedup
    entries_kept: int = 0        # after in-chunk dedup (cross-chunk dups
                                 # are removed at finalize; upper bound)
    nnz: Optional[int] = None    # exact global nnz (set at finalize)
    shard_nnz: Tuple[int, ...] = ()
    nnz_rows: Tuple[int, ...] = ()   # exact nonzero-row count per mode
    chunks: int = 0
    duplicates_dropped: int = 0  # in-chunk + (at finalize) cross-chunk
    # streamed CCSR bucket occupancy (per-mode count arrays), accumulated by
    # ccsr.IncrementalBucketBuilder when ``block_rows`` is set at ingest —
    # pattern builds then need no extra counting pass
    bucket_block_rows: Optional[int] = None
    bucket_counts: Optional[Tuple[np.ndarray, ...]] = None
    # ingest telemetry (DESIGN.md §11), set at finalize; mirrored into the
    # obs registry (ingest/* gauges) when tracing is enabled
    ingest_seconds: float = 0.0      # busy time inside add()+finalize
    mnnz_per_s: float = 0.0          # entries_read / ingest_seconds / 1e6
    spills: int = 0                  # spool .npz run files written
    peak_rss_mb: float = 0.0         # process peak RSS (ru_maxrss), host


def _dedup_sorted(lin: np.ndarray, order_hint: Optional[np.ndarray] = None):
    """Stable-sort by linearized coordinate and keep the FIRST occurrence of
    each coordinate (stream order); returns (sort_order, keep_mask)."""
    order = np.argsort(lin, kind="stable") if order_hint is None else order_hint
    lin_s = lin[order]
    keep = np.ones(lin_s.shape[0], bool)
    if lin_s.shape[0] > 1:
        keep[1:] = lin_s[1:] != lin_s[:-1]
    return order, keep


class StreamingIngest:
    """Chunk-wise dedup / hash-shard / sort-merge ingest.

    ``add(chunk)`` is O(chunk) time and memory; runs accumulate in memory or,
    with ``spool_dir``, as .npz spill files (out-of-core: host memory stays
    O(chunk) until a shard is finalized, and finalizing materializes one
    shard at a time). ``finalize()`` returns per-shard
    ``(indices, values)`` in canonical order plus :class:`IngestStats`.
    """

    def __init__(self, shape: Sequence[int], num_shards: int = 1,
                 spool_dir: Optional[str] = None,
                 track_rows: bool = True,
                 block_rows: Optional[int] = None,
                 keep_entries: bool = True):
        self.shape = tuple(int(s) for s in shape)
        self.num_shards = int(num_shards)
        self.keep_entries = keep_entries
        self.spool_dir = spool_dir
        if spool_dir is not None:
            os.makedirs(spool_dir, exist_ok=True)
        self._runs: List[List[Tuple[np.ndarray, np.ndarray]]] = [
            [] for _ in range(self.num_shards)]
        self._spilled: List[List[str]] = [[] for _ in range(self.num_shards)]
        self.stats = IngestStats(self.shape, self.num_shards)
        # per-mode nonzero-row occupancy: O(Σ I_d) host memory, exact
        self._row_seen = ([np.zeros(s, bool) for s in self.shape]
                          if track_rows else None)
        self._bucket_builder = None
        if block_rows is not None:
            from repro.sparse.ccsr import IncrementalBucketBuilder
            self._bucket_builder = IncrementalBucketBuilder(self.shape,
                                                            block_rows)
        self._finalized = False
        self._busy_s = 0.0

    # -- streaming phase ---------------------------------------------------
    def add(self, chunk: Chunk) -> None:
        # repro-lint: disable=JS003 -- host-only ingest accounting (busy_s); no device work in scope
        t0 = time.perf_counter()
        try:
            self._add(chunk)
        finally:
            # repro-lint: disable=JS003 -- host-only ingest accounting (busy_s); no device work in scope
            self._busy_s += time.perf_counter() - t0

    def _add(self, chunk: Chunk) -> None:
        assert not self._finalized, "ingest already finalized"
        n = len(chunk)
        self.stats.entries_read += n
        self.stats.chunks += 1
        if n == 0:
            return
        idx = np.ascontiguousarray(chunk.indices, np.int32)
        vals = np.ascontiguousarray(chunk.values, np.float32)
        lin = _linearize64(idx, self.shape)
        order, keep = _dedup_sorted(lin)
        idx, vals, lin = idx[order][keep], vals[order][keep], lin[order][keep]
        self.stats.duplicates_dropped += n - idx.shape[0]
        self.stats.entries_kept += idx.shape[0]
        if self._row_seen is not None:
            for d in range(len(self.shape)):
                self._row_seen[d][idx[:, d]] = True
        if self._bucket_builder is not None:
            self._bucket_builder.observe(idx)
        if not self.keep_entries:
            # metadata-only mode (``finalize_stats``): the chunk is dropped
            # here — peak host memory is strictly O(chunk)
            return
        shard = (_mix64(lin.astype(np.uint64))
                 % np.uint64(self.num_shards)).astype(np.int64)
        # group by shard with ONE stable sort (preserving the coordinate
        # order within each shard) — O(n log n), not O(num_shards * n)
        by_shard = np.argsort(shard, kind="stable")
        idx, vals, shard = idx[by_shard], vals[by_shard], shard[by_shard]
        bounds = np.searchsorted(shard, np.arange(self.num_shards + 1))
        for s in range(self.num_shards):
            lo, hi = int(bounds[s]), int(bounds[s + 1])
            if lo == hi:
                continue
            run = (idx[lo:hi].copy(), vals[lo:hi].copy())
            if self.spool_dir is None:
                self._runs[s].append(run)
            else:
                path = os.path.join(
                    self.spool_dir,
                    f"shard{s:04d}_run{len(self._spilled[s]):06d}.npz")
                np.savez(path, indices=run[0], values=run[1])
                self._spilled[s].append(path)
                self.stats.spills += 1
                obs.counter_add("ingest/spills")

    def consume(self, chunks: Iterable[Chunk],
                progress: Optional[Callable[[IngestStats], None]] = None
                ) -> "StreamingIngest":
        for c in chunks:
            self.add(c)
            if progress is not None:
                progress(self.stats)
        return self

    # -- finalize ----------------------------------------------------------
    def _shard_runs(self, s: int) -> List[Tuple[np.ndarray, np.ndarray]]:
        if self.spool_dir is None:
            return self._runs[s]
        out = []
        for path in self._spilled[s]:
            with np.load(path) as z:
                out.append((z["indices"], z["values"]))
        return out

    def finalize_shard(self, s: int) -> Tuple[np.ndarray, np.ndarray]:
        """Merge shard ``s``'s runs: concat (stream order), stable-sort by
        linearized coordinate, drop cross-chunk duplicates (first stream
        occurrence wins — runs are appended in chunk order, so within equal
        keys the stable sort keeps the earliest chunk's entry first)."""
        runs = self._shard_runs(s)
        if not runs:
            nd = len(self.shape)
            return (np.zeros((0, nd), np.int32), np.zeros((0,), np.float32))
        idx = np.concatenate([r[0] for r in runs])
        vals = np.concatenate([r[1] for r in runs])
        lin = _linearize64(idx, self.shape)
        order, keep = _dedup_sorted(lin)
        return idx[order][keep], vals[order][keep]

    def finalize(self) -> Tuple[List[Tuple[np.ndarray, np.ndarray]], IngestStats]:
        """All shards, canonical order, plus exact stats.

        Shards are merged one at a time and their runs freed as they go, so
        the transient merge footprint is one shard; the RESULT is the full
        materialized tensor (O(nnz) — it is about to become the dataset).
        A consumer that must never hold the whole tensor (e.g. writing
        per-shard files for a multi-host loader) should instead call
        ``finalize_shard(s)`` per shard, or ``finalize_stats()`` for
        metadata alone — both keep the documented O(chunk)/O(shard)
        streaming bound."""
        # repro-lint: disable=JS003 -- host-only shard-merge accounting; no device work in scope
        t0 = time.perf_counter()
        shards = []
        dropped_cross = 0
        for s in range(self.num_shards):
            merged = self.finalize_shard(s)
            self._runs[s] = []          # free the source runs shard-by-shard
            shards.append(merged)
        # repro-lint: disable=JS003 -- host-only shard-merge accounting; no device work in scope
        self._busy_s += time.perf_counter() - t0
        self._finalized = True
        kept = sum(sh[0].shape[0] for sh in shards)
        dropped_cross = self.stats.entries_kept - kept
        self.stats.duplicates_dropped += dropped_cross
        self.stats.nnz = kept
        self.stats.shard_nnz = tuple(sh[0].shape[0] for sh in shards)
        if self._row_seen is not None:
            self.stats.nnz_rows = tuple(int(r.sum()) for r in self._row_seen)
        if self._bucket_builder is not None:
            self.stats.bucket_block_rows = self._bucket_builder.block_rows
            self.stats.bucket_counts = tuple(self._bucket_builder.counts)
        self._telemetry_finish()
        return shards, self.stats

    def _telemetry_finish(self) -> None:
        """Seal the ingest telemetry: throughput over busy time (generator
        cost excluded — this measures the ingest pipeline, not the source),
        spill count and peak process RSS; mirrored as obs gauges and one
        JSONL event when tracing is enabled."""
        st = self.stats
        st.ingest_seconds = self._busy_s
        st.mnnz_per_s = (st.entries_read / self._busy_s / 1e6
                         if self._busy_s > 0 else 0.0)
        try:
            import resource
            st.peak_rss_mb = (resource.getrusage(resource.RUSAGE_SELF)
                              .ru_maxrss / 1024.0)
        except Exception:            # non-POSIX host: leave the gauge at 0
            pass
        if obs.enabled():
            obs.gauge_set("ingest/mnnz_per_s", st.mnnz_per_s)
            obs.gauge_set("ingest/peak_rss_mb", st.peak_rss_mb)
            obs.gauge_set("ingest/spills", st.spills)
            obs.counter_add("ingest/entries_read", st.entries_read)
            obs.counter_add("ingest/duplicates_dropped",
                            st.duplicates_dropped)
            obs.emit_event({"kind": "ingest", "shape": list(st.shape),
                            "num_shards": st.num_shards, "nnz": st.nnz,
                            "entries_read": st.entries_read,
                            "chunks": st.chunks, "spills": st.spills,
                            "seconds": st.ingest_seconds,
                            "mnnz_per_s": st.mnnz_per_s,
                            "peak_rss_mb": st.peak_rss_mb})

    def finalize_stats(self) -> IngestStats:
        """Metadata-only finalize: stats from the streaming phase without
        loading any run (exact nnz_rows; nnz is the in-chunk-dedup upper
        bound). The out-of-core benchmark path: 'ingest' a paper-scale
        stream and hand the planner its hints with O(chunk) peak memory."""
        self._finalized = True
        self.stats.nnz = self.stats.entries_kept
        self.stats.shard_nnz = ()
        if self._row_seen is not None:
            self.stats.nnz_rows = tuple(int(r.sum()) for r in self._row_seen)
        if self._bucket_builder is not None:
            self.stats.bucket_block_rows = self._bucket_builder.block_rows
            self.stats.bucket_counts = tuple(self._bucket_builder.counts)
        self._telemetry_finish()
        return self.stats


def pack_shards(shards: Sequence[Tuple[np.ndarray, np.ndarray]],
                shape: Sequence[int], stats: Optional[IngestStats] = None,
                pad_multiple: int = 8):
    """Pack per-shard COO arrays into one padded-COO SparseTensor laid out
    in equal-capacity shard blocks ``[shard 0 | shard 1 | ...]`` — the
    layout ``redistribute.shard_nonzeros`` device-puts directly. Attaches
    the streamed nnz / nnz_rows hints for the planner."""
    import jax.numpy as jnp
    from repro.core.sparse_tensor import SparseTensor

    nd = len(shape)
    cap = round_up(max(max((sh[0].shape[0] for sh in shards), default=1), 1),
                   pad_multiple)
    n_sh = len(shards)
    idx = np.zeros((n_sh * cap, nd), np.int32)
    vals = np.zeros((n_sh * cap,), np.float32)
    valid = np.zeros((n_sh * cap,), bool)
    for s, (si, sv) in enumerate(shards):
        n = si.shape[0]
        idx[s * cap:s * cap + n] = si
        vals[s * cap:s * cap + n] = sv
        valid[s * cap:s * cap + n] = True
    nnz = int(valid.sum())
    nnz_rows = (tuple(stats.nnz_rows) if stats is not None and stats.nnz_rows
                else None)
    return SparseTensor(jnp.asarray(idx), jnp.asarray(vals),
                        jnp.asarray(valid), tuple(int(s) for s in shape),
                        nnz=nnz, sorted_mode=(0 if n_sh == 1 else None),
                        nnz_rows=nnz_rows)


def ingest(chunks: Iterable[Chunk], shape: Sequence[int],
           num_shards: int = 1, spool_dir: Optional[str] = None,
           test_fraction: float = 0.0, pad_multiple: int = 8,
           block_rows: Optional[int] = None):
    """One-call streaming ingest: returns ``(train_st, test_st, stats)``
    where ``train_st`` is the packed shard-block SparseTensor and
    ``test_st`` the (single-shard) held-out tensor (None when
    ``test_fraction == 0``). ``block_rows`` additionally streams the CCSR
    bucket occupancy counts into the stats (incremental pattern build)."""
    tr_ing = StreamingIngest(shape, num_shards, spool_dir=spool_dir,
                             block_rows=block_rows)
    te_ing = (StreamingIngest(shape, 1,
                              spool_dir=None if spool_dir is None else
                              os.path.join(spool_dir, "test"))
              if test_fraction > 0 else None)
    for chunk in chunks:
        tr_chunk, te_chunk = split_chunk(chunk, shape, test_fraction)
        tr_ing.add(tr_chunk)
        if te_ing is not None:
            te_ing.add(te_chunk)
    shards, stats = tr_ing.finalize()
    train = pack_shards(shards, shape, stats, pad_multiple=pad_multiple)
    test = None
    if te_ing is not None:
        te_shards, te_stats = te_ing.finalize()
        test = pack_shards(te_shards, shape, te_stats,
                           pad_multiple=pad_multiple)
    return train, test, stats


# ---------------------------------------------------------------------------
# held-out evaluation
# ---------------------------------------------------------------------------

def heldout_metrics(test_st, factors, link: str = "identity") -> dict:
    """RMSE and mean Poisson deviance of the CP model on a held-out
    SparseTensor (masked; padding does not contribute). ``link="log"``
    evaluates in rate space (the model parameterizes log-rates, e.g. the
    ``poisson_log`` loss): predictions are exp(model)."""
    import jax.numpy as jnp
    from repro.core.tttp import multilinear_values

    model = multilinear_values(test_st, list(factors))
    if link == "log":
        model = jnp.exp(jnp.clip(model, -30.0, 30.0))
    elif link != "identity":
        raise ValueError(f"unknown link {link!r}")
    t = test_st.values
    mask = test_st.mask
    n = jnp.maximum(jnp.sum(mask), 1)
    se = jnp.sum(jnp.where(mask, jnp.square(t - model), 0.0))
    eps = 1e-6
    m_pos = jnp.maximum(model, eps)
    tlogt = jnp.where(t > 0, t * jnp.log(jnp.maximum(t, eps) / m_pos), 0.0)
    dev = 2.0 * jnp.sum(jnp.where(mask, tlogt - (t - m_pos), 0.0))
    return {"rmse": float(jnp.sqrt(se / n)),
            "poisson_deviance": float(dev / n),
            "count": int(n)}
