from repro.data import pipeline, streaming, synthetic

__all__ = ["synthetic", "pipeline", "streaming"]
