from repro.data import synthetic, pipeline

__all__ = ["synthetic", "pipeline"]
