"""Synthetic datasets for the paper's experiments and the LM substrate.

* ``function_tensor`` — the Karlsson et al. model problem used in paper
  Fig. 7a: a tensor sampled from a smooth separable-argument function, which
  has rapidly decaying multilinear rank, so a low-rank CP model fits to the
  regularization level. The paper's run: 10B nonzeros at 1e-5 density on 256
  nodes; here sizes are free parameters (scaled in benchmarks, full-size in
  the dry-run).
* ``netflix_like`` — a Netflix-shaped tensor (users × movies × time,
  480,189 × 17,770 × 2,182 at full scale, m=100,477,727): integer ratings
  1..5 with Zipf-distributed user/movie popularity and a user×movie bias
  structure, mirroring the real dataset's statistics (Fig. 7b).
* ``token_stream`` — deterministic synthetic token batches for LM smoke
  tests and the train driver.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.sparse_tensor import SparseTensor
from repro.core.utils import round_up

NETFLIX_SHAPE = (480_189, 17_770, 2_182)
NETFLIX_NNZ = 100_477_727


def function_tensor(key, shape: Tuple[int, ...], nnz: int,
                    cap: Optional[int] = None) -> SparseTensor:
    """t_i = f(Σ_d x_d[i_d]) with f(s) = 1/(1+e^{-s}) and x_d ~ U[-1, 1] —
    smooth ⇒ low effective CP rank (Karlsson et al. model problem)."""
    ks = jax.random.split(key, len(shape) + 2)
    idx_cols = [jax.random.randint(ks[d], (nnz,), 0, s, jnp.int32)
                for d, s in enumerate(shape)]
    grids = [jax.random.uniform(jax.random.fold_in(ks[-2], d), (s,),
                                minval=-1.0, maxval=1.0)
             for d, s in enumerate(shape)]
    arg = sum(g[i] for g, i in zip(grids, idx_cols))
    vals = jax.nn.sigmoid(3.0 * arg)
    return SparseTensor.from_coo(jnp.stack(idx_cols, 1), vals, shape, cap=cap)


def _zipf_choice(key, n: int, size: int, a: float = 1.2) -> jax.Array:
    """Zipf-ish categorical sampling via inverse-CDF on precomputed weights."""
    ranks = jnp.arange(1, n + 1, dtype=jnp.float32)
    w = ranks ** (-a)
    cdf = jnp.cumsum(w) / jnp.sum(w)
    u = jax.random.uniform(key, (size,))
    return jnp.searchsorted(cdf, u).astype(jnp.int32).clip(0, n - 1)


def netflix_like(key, shape: Tuple[int, int, int] = None, nnz: int = 1_000_000,
                 cap: Optional[int] = None, zipf_a: float = 1.1,
                 max_rounds: int = 64) -> SparseTensor:
    """Netflix-shaped ratings tensor with popularity skew and low-rank bias
    structure; values are integer ratings in 1..5.

    Zipf sampling emits repeated coordinates with non-negligible probability
    (popular users × popular movies), which would double-count entries of Ω
    — the observed set must be a *set*. Coordinates are therefore sampled in
    rounds (per-round key folding), deduplicated keeping the first stream
    occurrence, until exactly ``nnz`` unique coordinates exist; the result
    has exactly ``nnz`` valid entries (regression-pinned in
    tests/test_streaming.py)."""
    shape = shape or NETFLIX_SHAPE
    i_dim, j_dim, k_dim = shape
    cells = i_dim * j_dim * k_dim
    if nnz > cells:
        raise ValueError(f"nnz={nnz} exceeds the {cells} cells of {shape}")
    ks = jax.random.split(key, 8)
    seen = np.zeros((0,), np.int64)
    ii_all = np.zeros((0,), np.int32)
    jj_all = np.zeros((0,), np.int32)
    kk_all = np.zeros((0,), np.int32)
    for rnd in range(max_rounds):
        need = nnz - ii_all.shape[0]
        if need <= 0:
            break
        # oversample: dedup discards a fraction that grows with density
        draw = min(max(2 * need, 1024), 8 * nnz)
        kr = jax.random.fold_in(ks[0], rnd)
        k1, k2, k3 = jax.random.split(kr, 3)
        ii = np.asarray(_zipf_choice(k1, i_dim, draw, zipf_a))
        jj = np.asarray(_zipf_choice(k2, j_dim, draw, zipf_a))
        kk = np.asarray(jax.random.randint(k3, (draw,), 0, k_dim, jnp.int32))
        lin = (ii.astype(np.int64) * j_dim + jj) * k_dim + kk
        # first occurrence within the round, then drop already-seen coords
        _, first = np.unique(lin, return_index=True)
        first.sort()
        fresh = first[~np.isin(lin[first], seen, assume_unique=False)][:need]
        ii_all = np.concatenate([ii_all, ii[fresh]])
        jj_all = np.concatenate([jj_all, jj[fresh]])
        kk_all = np.concatenate([kk_all, kk[fresh]])
        seen = np.concatenate([seen, lin[fresh]])
    if ii_all.shape[0] < nnz:
        raise RuntimeError(f"could not collect {nnz} unique coordinates in "
                           f"{max_rounds} rounds (density too high?)")
    ii, jj, kk = jnp.asarray(ii_all), jnp.asarray(jj_all), jnp.asarray(kk_all)
    r = 4
    bu = 0.5 * jax.random.normal(ks[3], (i_dim, r))
    bv = 0.5 * jax.random.normal(ks[4], (j_dim, r))
    bw = 0.2 * jax.random.normal(ks[5], (k_dim, r))
    base = 3.5 + jnp.sum(bu[ii] * bv[jj] * (1.0 + bw[kk]), axis=1)
    noise = 0.4 * jax.random.normal(ks[6], (nnz,))
    vals = jnp.clip(jnp.round(base + noise), 1.0, 5.0)
    return SparseTensor.from_coo(jnp.stack([ii, jj, kk], 1), vals, shape,
                                 cap=cap)


def shuffle_and_pad(st: SparseTensor, key, num_shards: int) -> SparseTensor:
    """Prepare a SparseTensor for distribution: pad capacity to a multiple of
    ``num_shards`` and globally shuffle entries *including padding*, so
    (a) shard loads are balanced (the cyclic-layout analogue, DESIGN.md §3)
    and (b) padding is spread uniformly (unbiased per-shard sampling)."""
    cap = round_up(st.cap, num_shards)
    idx = jnp.pad(st.indices, ((0, cap - st.cap), (0, 0)))
    vals = jnp.pad(st.values, [(0, cap - st.cap)] +
                   [(0, 0)] * (st.values.ndim - 1))
    valid = jnp.pad(st.valid, (0, cap - st.cap))
    perm = jax.random.permutation(key, cap)
    return SparseTensor(idx[perm], vals[perm], valid[perm], st.shape, st.nnz)


def token_stream(key, vocab_size: int, batch: int, seq_len: int,
                 num_batches: int = 1):
    """Synthetic LM batches: Zipf-distributed tokens with shifted labels."""
    for b in range(num_batches):
        k = jax.random.fold_in(key, b)
        toks = _zipf_choice(k, vocab_size, batch * (seq_len + 1), a=1.05)
        toks = toks.reshape(batch, seq_len + 1)
        yield {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
