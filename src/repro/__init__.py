"""repro: distributed-memory tensor completion with new sparse tensor kernels,
in JAX — planner, distributed executor, streaming ingest, telemetry, and a
static-analysis gate (``repro.analysis``)."""

__version__ = "1.0.0"
