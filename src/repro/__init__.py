"""repro: distributed-memory tensor completion with new sparse tensor kernels,
in JAX — plus the assigned LM-architecture zoo, launcher, and dry-run stack."""

__version__ = "1.0.0"
