"""Import-graph dead-code report (DESIGN.md §12.4).

Builds the static import graph over ``src/repro`` (AST-level: absolute
``repro.*`` imports and relative imports, with symbol imports resolved to a
module when one exists) and classifies every module by reachability:

* **product** — reachable from the product entry points (``DEFAULT_ROOTS``:
  the completion/experiment/report CLIs, the public einsum API, and this
  analysis subsystem);
* **bench-only** — reachable only through ``benchmarks/``;
* **test-only** — reachable only through ``tests/`` (listed with the test
  files that touch them: candidates for deletion alongside their tests);
* **unreachable** — imported by nothing at all. These BLOCK ``--all``: dead
  modules rot silently (the seed's LM-architecture zoo sat unreachable for
  five PRs until this report inventoried it).

Importing a submodule executes its parent packages, so ``repro.a.b`` implies
an edge to ``repro.a``; package ``__init__`` edges are followed like any
other import.
"""
from __future__ import annotations

import ast
import dataclasses
import os
from typing import Dict, List, Optional, Sequence, Set

DEFAULT_ROOTS = (
    "repro.launch.complete",      # completion CLI (all algorithms, any mesh)
    "repro.launch.serve_complete",  # serving CLI on frozen factors (§14)
    "repro.launch.experiment",    # named experiment specs / nightly sweeps
    "repro.launch.report",        # PERF.md / dryrun-table renderer
    "repro.core.api",             # the public einsum/TTTP library surface
    "repro.analysis",             # this subsystem (repro-lint entry point)
)


@dataclasses.dataclass
class Report:
    modules: Dict[str, Set[str]]          # module -> direct repro imports
    product: Set[str]
    bench_only: Set[str]
    test_only: Dict[str, Set[str]]        # module -> test files touching it
    unreachable: Set[str]

    def format(self) -> str:
        lines = [f"import graph: {len(self.modules)} modules, "
                 f"{len(self.product)} reachable from product roots"]
        if self.bench_only:
            lines.append("bench-only modules:")
            lines += [f"  {m}" for m in sorted(self.bench_only)]
        if self.test_only:
            lines.append("test-only modules (delete with their tests, or "
                         "wire into a product path):")
            for m in sorted(self.test_only):
                vias = ", ".join(sorted(self.test_only[m]))
                lines.append(f"  {m}  (via {vias})")
        if self.unreachable:
            lines.append("UNREACHABLE modules (imported by nothing):")
            lines += [f"  {m}" for m in sorted(self.unreachable)]
        return "\n".join(lines)


def _module_name(path: str, src_root: str) -> str:
    rel = os.path.relpath(path, src_root)
    parts = rel[:-3].split(os.sep)           # strip .py
    if parts[-1] == "__init__":
        parts = parts[:-1]
    return ".".join(parts)


def _imports_of(path: str, module: str, known: Set[str]) -> Set[str]:
    """Direct repro-module imports of one file, resolved against ``known``."""
    with open(path) as fh:
        try:
            tree = ast.parse(fh.read(), filename=path)
        except SyntaxError:
            return set()
    out: Set[str] = set()

    def add(name: str) -> None:
        # resolve to the deepest known module prefix (symbol imports from a
        # package resolve to the package)
        parts = name.split(".")
        for i in range(len(parts), 0, -1):
            cand = ".".join(parts[:i])
            if cand in known:
                out.add(cand)
                return

    pkg_parts = module.split(".")
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                if a.name.split(".")[0] == "repro":
                    add(a.name)
        elif isinstance(node, ast.ImportFrom):
            if node.level:                    # relative import
                base = pkg_parts[:len(pkg_parts) - node.level + 1] \
                    if path.endswith("__init__.py") else \
                    pkg_parts[:len(pkg_parts) - node.level]
                mod = ".".join(base + ([node.module] if node.module else []))
            else:
                mod = node.module or ""
            if mod.split(".")[0] == "repro":
                add(mod)
                for a in node.names:
                    add(f"{mod}.{a.name}")
    return out


def build_graph(src_root: str) -> Dict[str, Set[str]]:
    paths: Dict[str, str] = {}
    for dirpath, dirnames, filenames in os.walk(src_root):
        dirnames[:] = [d for d in dirnames if d != "__pycache__"]
        for fn in filenames:
            if fn.endswith(".py"):
                p = os.path.join(dirpath, fn)
                paths[_module_name(p, src_root)] = p
    known = set(paths)
    graph: Dict[str, Set[str]] = {}
    for mod, p in paths.items():
        deps = _imports_of(p, mod, known)
        # importing a submodule executes its parents
        parts = mod.split(".")
        for i in range(1, len(parts)):
            parent = ".".join(parts[:i])
            if parent in known:
                deps.add(parent)
        graph[mod] = deps - {mod}
    return graph


def _reach(graph: Dict[str, Set[str]], roots: Sequence[str]) -> Set[str]:
    seen: Set[str] = set()
    stack = [r for r in roots if r in graph]
    while stack:
        m = stack.pop()
        if m in seen:
            continue
        seen.add(m)
        stack.extend(graph.get(m, ()))
    return seen


def _external_imports(dir_: str, known: Set[str]) -> Dict[str, Set[str]]:
    """{module: set(files importing it)} for .py files outside src/repro."""
    out: Dict[str, Set[str]] = {}
    if not os.path.isdir(dir_):
        return out
    for dirpath, dirnames, filenames in os.walk(dir_):
        dirnames[:] = [d for d in dirnames if d != "__pycache__"]
        for fn in filenames:
            if not fn.endswith(".py"):
                continue
            p = os.path.join(dirpath, fn)
            for mod in _imports_of(p, "", known):
                out.setdefault(mod, set()).add(os.path.relpath(p))
    return out


def analyze(repo_root: str = ".",
            roots: Optional[Sequence[str]] = None) -> Report:
    src_root = os.path.join(repo_root, "src")
    graph = build_graph(src_root)
    known = set(graph)
    roots = tuple(roots) if roots else DEFAULT_ROOTS
    # ``python -m pkg`` entry points are roots by construction
    roots += tuple(m for m in graph if m.endswith(".__main__"))
    product = _reach(graph, roots)

    bench = _external_imports(os.path.join(repo_root, "benchmarks"), known)
    tests = _external_imports(os.path.join(repo_root, "tests"), known)
    bench_reach = _reach(graph, list(bench))
    test_reach = _reach(graph, list(tests))

    bench_only, test_only, unreachable = set(), {}, set()
    for mod in known:
        if mod in product or mod == "repro":
            continue
        if mod in bench_reach:
            bench_only.add(mod)
        elif mod in test_reach:
            vias: Set[str] = set()
            for t_mod, files in tests.items():
                if mod == t_mod or mod in _reach(graph, [t_mod]):
                    vias |= files
            test_only[mod] = vias
        else:
            unreachable.add(mod)
    return Report(graph, product, bench_only, test_only, unreachable)
