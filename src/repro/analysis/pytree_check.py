"""Pass 3 — pytree & static-argument hygiene (DESIGN.md §12.3).

Two bug classes, both found the hard way in earlier PRs:

* **Pytree aux defects.** Every ``register_pytree_node_class`` type crosses
  jit boundaries; its aux data becomes part of the *treedef*, which jax
  hashes and compares to decide whether a cached compilation can be reused.
  Aux that is unhashable crashes at the first jit call; aux that contains
  arrays retraces on every value change; aux whose equality is not stable
  across reconstruction silently defeats the compilation cache. This pass
  flattens/unflattens an exemplar of every registered pytree in ``src/repro``
  and certifies: round-trip identity (same leaves, same treedef), hashable
  and array-free aux, and treedef equality across two independently
  constructed identical exemplars.

  Discovery is static (AST scan for the decorator), so a newly registered
  pytree with no exemplar in the registry is itself a finding — the check
  cannot silently lose coverage.

* **Static-arg aliasing (the PR-3 bug class).** Types used as jit
  static arguments or plan-cache key components (``DistInfo``,
  ``PlannerConfig``, ``AxisCtx``, ``OperandInfo``) are compared by
  ``__eq__``/``__hash__``. If equality ignores a semantically meaningful
  field, two distinct configurations alias to one cached artifact — PR 3's
  mesh-aliasing bug was exactly this (same axis *names*, different mesh
  *sizes*, one shared plan). For each static type this pass varies every
  field of a base instance one at a time and certifies each variant
  compares unequal to the base (and that equal instances hash equal).

``--pytree-module`` loads an extra module exposing ``PYTREE_EXEMPLARS``
(a list of pytree instances or zero-arg factories) and runs the same aux
checks on them — the fixture hook the CI tripwire test uses to prove a
corrupted pytree fails the run.
"""
from __future__ import annotations

import ast
import importlib
import os
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.analysis.lint import Finding

# ---------------------------------------------------------------------------
# static discovery of registered pytrees
# ---------------------------------------------------------------------------

_DECORATOR = "register_pytree_node_class"


def discover_registered(src_root: str) -> List[Tuple[str, str]]:
    """(module, classname) for every ``@register_pytree_node_class`` class
    under ``src_root`` (AST-level — nothing is imported)."""
    out: List[Tuple[str, str]] = []
    for dirpath, dirnames, filenames in os.walk(src_root):
        dirnames[:] = sorted(d for d in dirnames if d != "__pycache__")
        for fn in sorted(filenames):
            if not fn.endswith(".py"):
                continue
            path = os.path.join(dirpath, fn)
            rel = os.path.relpath(path, src_root)[:-3].replace(os.sep, ".")
            if rel.endswith(".__init__"):
                rel = rel[: -len(".__init__")]
            with open(path) as fh:
                try:
                    tree = ast.parse(fh.read(), filename=path)
                except SyntaxError:
                    continue
            for node in ast.walk(tree):
                if not isinstance(node, ast.ClassDef):
                    continue
                for dec in node.decorator_list:
                    name = dec.attr if isinstance(dec, ast.Attribute) else \
                        dec.id if isinstance(dec, ast.Name) else None
                    if name == _DECORATOR:
                        out.append((rel, node.name))
    return out


# ---------------------------------------------------------------------------
# exemplar registry
# ---------------------------------------------------------------------------

def _exemplar_sparse():
    from repro.core.sparse_tensor import SparseTensor
    idx = np.stack([(np.arange(8) * (d + 3)) % s
                    for d, s in enumerate((6, 4, 8))], axis=1).astype(np.int32)
    vals = np.linspace(0.5, 1.5, 8, dtype=np.float32)
    return SparseTensor.from_coo(idx, vals, (6, 4, 8))


def _exemplar_ccsr():
    from repro.sparse.ccsr import build_ccsr
    return build_ccsr(_exemplar_sparse().sort_by_mode(0), 0)


def _exemplar_buckets():
    buckets = _exemplar_sparse().row_buckets(0, 4)
    assert buckets is not None, "concrete indices must yield a bucket view"
    return buckets


# module.Class -> zero-arg factory building a representative instance
EXEMPLARS: Dict[str, object] = {
    "core.sparse_tensor.SparseTensor": _exemplar_sparse,
    "sparse.ccsr.CCSRView": _exemplar_ccsr,
    "sparse.ccsr.RowBlockBuckets": _exemplar_buckets,
}


# ---------------------------------------------------------------------------
# aux-data hygiene checks
# ---------------------------------------------------------------------------

def _is_arraylike(x) -> bool:
    return hasattr(x, "shape") and hasattr(x, "dtype")


def _walk_aux(aux):
    yield aux
    if isinstance(aux, (tuple, list)):
        for item in aux:
            yield from _walk_aux(item)
    elif isinstance(aux, dict):
        for item in aux.values():
            yield from _walk_aux(item)


def check_exemplar(name: str, factory) -> List[Finding]:
    import jax

    findings: List[Finding] = []

    def bad(msg):
        findings.append(Finding("pytrees", 0, 0, "PT001", f"[{name}] {msg}"))

    try:
        obj = factory() if callable(factory) else factory
    except Exception as e:
        bad(f"exemplar construction failed: {type(e).__name__}: {e}")
        return findings

    try:
        leaves, treedef = jax.tree_util.tree_flatten(obj)
    except Exception as e:
        bad(f"tree_flatten failed: {type(e).__name__}: {e}")
        return findings

    # treedef (which embeds the aux) must be hashable — jit requires it
    try:
        hash(treedef)
    except TypeError as e:
        bad(f"treedef (aux data) is unhashable — first jit call would "
            f"crash: {e}")
        return findings

    # aux must be hashable in its own right — the plan cache and jit
    # static-argument keys hash aux-bearing tuples directly (jaxlib's
    # treedef hash ignores custom-node aux, so hash(treedef) is no proxy)
    if hasattr(obj, "tree_flatten"):
        _, aux = obj.tree_flatten()
        try:
            hash(aux)
        except TypeError as e:
            bad(f"aux data is unhashable ({e}) — cache keys and jit "
                f"static-arg tuples embedding it would crash")
        # and must not carry arrays: array aux forces a retrace per value
        for item in _walk_aux(aux):
            if _is_arraylike(item):
                bad(f"aux data contains an array ({type(item).__name__}, "
                    f"shape {getattr(item, 'shape', '?')}) — arrays belong "
                    f"in the leaves; aux retraces per value")

    # round trip: unflatten(flatten(x)) must re-flatten identically
    back = jax.tree_util.tree_unflatten(treedef, leaves)
    leaves2, treedef2 = jax.tree_util.tree_flatten(back)
    try:
        differs = treedef2 != treedef
    except Exception as e:  # array-valued aux: `==` is elementwise/ambiguous
        bad(f"treedef comparison raises ({type(e).__name__}: {e}) — aux "
            f"data must compare by plain bool equality")
        return findings
    if differs:
        bad("flatten∘unflatten does not round-trip: treedef changed")
    if len(leaves2) != len(leaves) or any(
            l1 is not l2 and not np.array_equal(np.asarray(l1),
                                                np.asarray(l2))
            for l1, l2 in zip(leaves, leaves2)):
        bad("flatten∘unflatten does not round-trip: leaves changed")

    # equality stability: an independently built identical exemplar must
    # produce an equal treedef with an equal hash (else the jit cache and
    # the plan cache silently miss on every reconstruction)
    if callable(factory):
        try:
            obj2 = factory()
        except Exception as e:
            bad(f"second exemplar construction failed: {e}")
            return findings
        _, treedef3 = jax.tree_util.tree_flatten(obj2)
        try:
            unstable = treedef3 != treedef or hash(treedef3) != hash(treedef)
        except Exception as e:
            bad(f"treedef comparison across constructions raises "
                f"({type(e).__name__}) — aux data must compare by plain "
                f"bool equality")
            unstable = False
        if unstable:
            bad("aux equality is not construction-stable: two identical "
                "exemplars flatten to unequal treedefs — every "
                "reconstruction would force a fresh trace")

    # identity tree_map must preserve structure (catches unflatten ctors
    # that recompute/validate and perturb aux)
    mapped = jax.tree_util.tree_map(lambda x: x, obj)
    if jax.tree_util.tree_structure(mapped) != treedef:
        bad("identity tree_map changes the treedef")
    return findings


def check_pytrees(src_root: str,
                  extra_module: Optional[str] = None) -> List[Finding]:
    findings: List[Finding] = []
    discovered = discover_registered(src_root)
    for mod, cls in discovered:
        key = f"{mod}.{cls}"
        if key not in EXEMPLARS:
            findings.append(Finding(
                "pytrees", 0, 0, "PT001",
                f"registered pytree {key} has no exemplar in "
                f"analysis.pytree_check.EXEMPLARS — add one so its aux "
                f"hygiene is certified"))
    for key, factory in EXEMPLARS.items():
        findings.extend(check_exemplar(key, factory))
    if extra_module:
        m = importlib.import_module(extra_module)
        for i, ex in enumerate(getattr(m, "PYTREE_EXEMPLARS", ())):
            findings.extend(check_exemplar(f"{extra_module}[{i}]", ex))
    return findings


# ---------------------------------------------------------------------------
# static-argument aliasing (PT002)
# ---------------------------------------------------------------------------

def _static_type_grids():
    """(typename, base instance, [(field, variant instance), ...]) for every
    type used as a jit static argument or plan-cache key component. Each
    variant differs from base in exactly one semantically meaningful field."""
    import dataclasses as dc

    from repro.core.distributed import AxisCtx
    from repro.planner.config import PlannerConfig
    from repro.planner.ir import DistInfo, OperandInfo

    grids = []

    base = DistInfo()
    grids.append(("planner.ir.DistInfo", base, [
        ("data_size", dc.replace(base, data_size=2)),
        ("data_size", dc.replace(base, data_size=4)),   # PR-3: sizes, not
        ("model_size", dc.replace(base, model_size=2)),  # just names
        ("rowsharded", dc.replace(base, rowsharded=True)),
    ]))

    base = PlannerConfig()
    grids.append(("planner.config.PlannerConfig", base, [
        ("block_rows", dc.replace(base, block_rows=16)),
        ("h_slices", dc.replace(base, h_slices=2)),
    ]))

    base = AxisCtx()
    grids.append(("core.distributed.AxisCtx", base, [
        ("data", dc.replace(base, data="data")),
        ("data", dc.replace(base, data=("data", "expert"))),
        ("model", dc.replace(base, model="model")),
    ]))

    base = OperandInfo("ijk", True, (6, 4, 8), 8, 8, "float32", None, None)
    grids.append(("planner.ir.OperandInfo", base, [
        ("term", dc.replace(base, term="jik")),
        ("shape", dc.replace(base, shape=(6, 4, 10))),
        ("cap", dc.replace(base, cap=16)),
        ("nnz", dc.replace(base, nnz=4)),
        ("dtype", dc.replace(base, dtype="bfloat16")),
        ("nnz_rows", dc.replace(base, nnz_rows=(3, 4, 5))),
    ]))
    return grids


def check_static_args() -> List[Finding]:
    findings: List[Finding] = []

    def bad(msg):
        findings.append(Finding("static-args", 0, 0, "PT002", msg))

    for name, base, variants in _static_type_grids():
        try:
            h0 = hash(base)
        except TypeError as e:
            bad(f"{name} is unhashable — unusable as a jit static arg or "
                f"cache-key component: {e}")
            continue
        if hash(base) != h0 or base != base:
            bad(f"{name} hash/eq is unstable on the same instance")
        seen = {base: "base"}
        for field, variant in variants:
            try:
                hash(variant)
            except TypeError as e:
                bad(f"{name} variant ({field}) is unhashable: {e}")
                continue
            if variant == base:
                bad(f"{name}: changing {field!r} produces an instance that "
                    f"compares EQUAL to the base — distinct configs would "
                    f"alias one cached plan/compilation (PR-3 mesh-aliasing "
                    f"bug class)")
            for other, olabel in seen.items():
                if variant == other and olabel != "base":
                    bad(f"{name}: variants {field!r} and {olabel!r} alias")
            seen[variant] = field
    return findings


def run(repo_root: str = ".",
        extra_module: Optional[str] = None) -> List[Finding]:
    src_root = os.path.join(repo_root, "src", "repro")
    return check_pytrees(src_root, extra_module) + check_static_args()
