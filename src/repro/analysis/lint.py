"""Pass 1 — jit-safety linter (DESIGN.md §12.1).

AST-based rules over ``src/repro`` that catch tracer-unsafe idioms *before*
XLA does. The planner/kernel/completion layers are reachable from jitted
entry points (``api.einsum`` → ``planner.dispatch`` → ``kernels``), where a
Python-level branch on an array value or a host coercion either crashes with
a ``TracerBoolConversionError`` at first jit or — worse — silently bakes one
concrete value into the compiled program. The telemetry layer (PR 5) adds a
second failure class: un-fenced wall-clock timing of async-dispatched device
work measures dispatch latency, not the kernel.

Rules (applicability depends on the file's scope, see ``scope_rules``):

* ``JS001`` traced-branch     — Python ``if``/``while``/ternary branching on
  a ``jnp.``/``jax.lax`` expression in jit-reachable code; use ``jnp.where``
  / ``lax.cond`` / ``lax.while_loop``.
* ``JS002`` eager-coercion    — ``.item()`` / ``float()`` / ``int()`` /
  ``bool()`` / ``np.asarray()`` of a ``jnp.``-derived value in jit-reachable
  code: a silent host sync eagerly, a crash under jit.
* ``JS003`` unfenced-timing   — ``time.perf_counter``/``time.time`` in a
  function with no ``block_until_ready``/``.fence(`` in scope; library code
  must use ``repro.obs.trace.span`` (jit-aware) + ``sp.fence``.
* ``JS004`` host-io-in-loop   — ``print``/``logging`` calls inside loop
  bodies of library code (sweep loops sync and serialize the device stream);
  emit through ``repro.obs`` counters/spans instead.
* ``JS005`` nondeterminism    — stdlib ``random.*``, legacy global
  ``np.random.*``, or seedless ``np.random.default_rng()`` outside ``data/``
  (where every generator is SeedSequence-derived by construction).
* ``JS000`` bad-suppression   — a suppression comment with no reason string
  or an unknown rule id. Never suppressible.
* ``JS006`` stale-suppression — a reasoned suppression whose rule no longer
  fires on the covered line(s). Advisory in the CLI, an error under
  ``--strict-suppressions`` (CI) — so disables can't outlive their reason.

Suppression syntax (requires a reason after ``--``)::

    x = arr.item()  # repro-lint: disable=JS002 -- eager CLI path, never jitted

A comment-only suppression line applies to the next line as well.
"""
from __future__ import annotations

import ast
import dataclasses
import io
import os
import re
import tokenize
from typing import Dict, Iterator, List, Optional, Sequence, Set, Tuple

RULES: Dict[str, str] = {
    "JS000": "bad-suppression",
    "JS001": "traced-branch",
    "JS002": "eager-coercion",
    "JS003": "unfenced-timing",
    "JS004": "host-io-in-loop",
    "JS005": "nondeterminism",
    "JS006": "stale-suppression",
    # non-lint passes report through the same Finding record; these rule ids
    # are NOT inline-suppressible (they describe structural contracts)
    "CT001": "path-aval-disagreement",
    "CT002": "cost-invariant",
    "CT003": "cache-key",
    "PT001": "pytree-roundtrip",
    "PT002": "static-arg-aliasing",
    "DC001": "dead-code",
    # SPMD collective-soundness analyzer (repro.analysis.spmd, §15): the
    # sharding-propagation certifier (SP0xx), the collective-matching AST
    # lint (SP1xx), and the VMEM resource certifier (SP2xx)
    "SP000": "spmd-analysis-error",
    "SP001": "partial-sum-escape",
    "SP002": "redundant-psum",
    "SP003": "wrong-replication-state",
    "SP004": "sharded-dim-gather",
    "SP101": "collective-divergence",
    "SP102": "collective-under-traced-conditional",
    "SP103": "hardcoded-axis-name",
    "SP201": "vmem-over-budget",
}

# rules an inline disable comment may name: the per-line style/source
# rules. Structural contracts (CT/PT/DC, SP0xx, SP2xx) are properties of
# the program, not of a source line — never suppressible.
SUPPRESSIBLE: Set[str] = {"JS001", "JS002", "JS003", "JS004", "JS005",
                          "SP101", "SP102", "SP103"}

# jit-reachable library layers: everything here may run under a jax trace
_JIT_PREFIXES = ("core/", "kernels/", "planner/", "sparse/")
# host-side layers: eager by design (CLI drivers, ingest, checkpoint I/O)
_HOST_PREFIXES = ("launch/", "runtime/", "checkpoint/", "optim/", "obs/",
                  "analysis/", "data/")
# the sanctioned timing primitives: span measures wall time by design, and
# the tile autotuner's charter is fenced host timing of kernel candidates
_TIMING_EXEMPT = ("obs/trace.py", "planner/tuner.py")

_SUPPRESS_RE = re.compile(
    r"#\s*repro-lint:\s*disable=([A-Za-z0-9_,\s]+?)\s*(?:--\s*(.*\S))?\s*$")
# a line that *looks* like a suppression comment but fails _SUPPRESS_RE is
# malformed; requiring the comment-start form keeps prose mentions inert
_HINT_RE = re.compile(r"#\s*repro-lint:")

_STDLIB_RANDOM = {"random", "randint", "randrange", "choice", "choices",
                  "shuffle", "sample", "uniform", "gauss", "seed",
                  "getrandbits", "betavariate", "normalvariate"}
_NP_RANDOM_LEGACY = {"rand", "randn", "randint", "random", "random_sample",
                     "ranf", "choice", "shuffle", "permutation", "uniform",
                     "normal", "seed", "poisson", "binomial", "standard_normal"}
_LOG_METHODS = {"debug", "info", "warning", "warn", "error", "critical",
                "exception", "log"}
_LOG_ROOTS = {"log", "logger", "logging"}
_TIME_FNS = {"perf_counter", "time", "monotonic", "process_time"}
_FENCE_NAMES = {"block_until_ready", "fence"}


@dataclasses.dataclass(frozen=True)
class Finding:
    file: str
    line: int
    col: int
    rule: str
    message: str
    suppressed: bool = False
    reason: str = ""
    # advisory findings (JS006) warn in the CLI and only block under
    # --strict-suppressions (the CI configuration)
    advisory: bool = False

    def format(self) -> str:
        tag = f" [suppressed: {self.reason}]" if self.suppressed else ""
        return (f"{self.file}:{self.line}:{self.col}: {self.rule} "
                f"({RULES[self.rule]}) {self.message}{tag}")


def scope_rules(path: str) -> Set[str]:
    """Rules applicable to ``path`` (see module docstring). Unknown files
    get the host-side set — timing and determinism hold everywhere."""
    norm = path.replace(os.sep, "/")
    if "src/repro/" in norm:
        rel = norm.split("src/repro/", 1)[1]
    elif norm.startswith("repro/"):
        rel = norm.split("repro/", 1)[1]
    else:
        rel = ""
        if "/benchmarks/" in norm or norm.startswith("benchmarks/"):
            return {"JS003", "JS005"}
    if any(rel.startswith(p) for p in _TIMING_EXEMPT):
        return {"JS005"}
    if any(rel.startswith(p) for p in _JIT_PREFIXES):
        return {"JS001", "JS002", "JS003", "JS004", "JS005"}
    if rel.startswith("data/"):
        # seeded host RNG lives here by charter; JS005 exempt
        return {"JS003", "JS004"}
    if any(rel.startswith(p) for p in _HOST_PREFIXES):
        return {"JS003", "JS005"}
    return {"JS003", "JS005"}


# ---------------------------------------------------------------------------
# expression classification helpers
# ---------------------------------------------------------------------------

def _dotted(node: ast.AST) -> Optional[Tuple[str, ...]]:
    """('np', 'random', 'rand') for ``np.random.rand`` — None when the chain
    is not a pure Name/Attribute path."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return tuple(reversed(parts))
    return None


def _is_traced_call(call: ast.Call) -> bool:
    """A call that produces a jax array in idiomatic repro code: rooted at
    the ``jnp`` alias, ``jax.numpy``, or ``jax.lax``."""
    d = _dotted(call.func)
    if d is None:
        return False
    if d[0] == "jnp":
        return True
    return len(d) >= 2 and d[0] == "jax" and d[1] in ("numpy", "lax")


def _contains_traced_call(node: ast.AST) -> bool:
    return any(isinstance(n, ast.Call) and _is_traced_call(n)
               for n in ast.walk(node))


# ---------------------------------------------------------------------------
# the visitor
# ---------------------------------------------------------------------------

class _Visitor(ast.NodeVisitor):
    def __init__(self, path: str, rules: Set[str]):
        self.path = path
        self.rules = rules
        self.raw: List[Finding] = []
        self.loop_depth = 0
        # stack of per-function state: list of (line, col) of timing calls,
        # and whether a fence call was seen in that function body
        self.fn_stack: List[Dict] = [{"timing": [], "fenced": False}]

    def _emit(self, rule: str, node: ast.AST, msg: str) -> None:
        if rule in self.rules:
            self.raw.append(Finding(self.path, node.lineno, node.col_offset,
                                    rule, msg))

    # -- function scopes (JS003 is resolved per function) -------------------
    def _visit_fn(self, node):
        self.fn_stack.append({"timing": [], "fenced": False})
        self.generic_visit(node)
        st = self.fn_stack.pop()
        if st["fenced"]:
            # a fenced nested closure fences its enclosing timing scope (the
            # idiomatic `def run(): block_until_ready(...)` timing wrapper)
            self.fn_stack[-1]["fenced"] = True
        if not st["fenced"]:
            for line, col, name in st["timing"]:
                self.raw.append(Finding(
                    self.path, line, col, "JS003",
                    f"time.{name}() with no block_until_ready/fence in this "
                    f"function — async dispatch makes the wall time "
                    f"meaningless; use repro.obs.trace.span + sp.fence"))

    visit_FunctionDef = _visit_fn
    visit_AsyncFunctionDef = _visit_fn

    # -- branches (JS001) ---------------------------------------------------
    def _check_branch(self, node, kind: str):
        if _contains_traced_call(node.test):
            self._emit("JS001", node,
                       f"Python {kind} branches on a jnp/jax.lax expression "
                       f"— under jit this is a TracerBoolConversionError; "
                       f"use jnp.where / lax.cond / lax.while_loop")

    def visit_If(self, node):
        self._check_branch(node, "`if`")
        self.generic_visit(node)

    def visit_IfExp(self, node):
        self._check_branch(node, "ternary")
        self.generic_visit(node)

    def visit_While(self, node):
        self._check_branch(node, "`while`")
        self.loop_depth += 1
        for child in node.body + node.orelse:
            self.visit(child)
        self.loop_depth -= 1

    def visit_For(self, node):
        self.visit(node.iter)
        self.loop_depth += 1
        for child in node.body + node.orelse:
            self.visit(child)
        self.loop_depth -= 1

    def visit_Assert(self, node):
        if _contains_traced_call(node.test):
            self._emit("JS001", node,
                       "`assert` on a jnp/jax.lax expression — traced "
                       "asserts are silently constant-folded or crash; use "
                       "checkify or a host-side check on fetched values")
        self.generic_visit(node)

    # -- calls (JS002/JS003/JS004/JS005) ------------------------------------
    def visit_Call(self, node):
        d = _dotted(node.func)

        # JS002: eager coercions of traced values
        if (isinstance(node.func, ast.Attribute) and node.func.attr == "item"
                and not node.args and not node.keywords):
            self._emit("JS002", node,
                       ".item() forces a host sync (and crashes under jit); "
                       "keep the value on device or fetch explicitly via "
                       "jax.device_get at the eager boundary")
        elif (isinstance(node.func, ast.Name)
              and node.func.id in ("float", "int", "bool")
              and len(node.args) == 1
              and _contains_traced_call(node.args[0])):
            self._emit("JS002", node,
                       f"{node.func.id}() of a jnp/jax.lax expression — a "
                       f"TracerConversionError under jit; keep the value as "
                       f"an array or coerce at the eager boundary only")
        elif (d is not None and len(d) >= 2 and d[0] in ("np", "numpy")
              and d[-1] in ("asarray", "array") and node.args
              and _contains_traced_call(node.args[0])):
            self._emit("JS002", node,
                       "np.asarray of a jnp/jax.lax expression pulls the "
                       "value to host (crashes under jit); use jnp or fetch "
                       "via jax.device_get at the eager boundary")

        # JS003: timing calls collected per enclosing function
        if (d is not None and len(d) == 2 and d[0] == "time"
                and d[1] in _TIME_FNS and "JS003" in self.rules):
            self.fn_stack[-1]["timing"].append(
                (node.lineno, node.col_offset, d[1]))
        if (d is not None and d[-1] in _FENCE_NAMES) or (
                isinstance(node.func, ast.Attribute)
                and node.func.attr in _FENCE_NAMES):
            self.fn_stack[-1]["fenced"] = True

        # JS004: host I/O inside loop bodies
        if self.loop_depth > 0:
            if isinstance(node.func, ast.Name) and node.func.id == "print":
                self._emit("JS004", node,
                           "print() inside a loop body in library code — "
                           "syncs and serializes the device stream every "
                           "iteration; emit repro.obs counters/spans instead")
            elif (d is not None and len(d) == 2 and d[0] in _LOG_ROOTS
                  and d[1] in _LOG_METHODS):
                self._emit("JS004", node,
                           f"{'.'.join(d)}() inside a loop body in library "
                           f"code; emit repro.obs counters/spans instead")

        # JS005: nondeterminism sources
        if d is not None:
            if len(d) == 2 and d[0] == "random" and d[1] in _STDLIB_RANDOM:
                self._emit("JS005", node,
                           f"stdlib random.{d[1]}() is unseeded global state "
                           f"— results are irreproducible; thread a "
                           f"jax.random key or np.random.SeedSequence")
            elif (len(d) == 3 and d[0] in ("np", "numpy")
                  and d[1] == "random" and d[2] in _NP_RANDOM_LEGACY):
                self._emit("JS005", node,
                           f"legacy global np.random.{d[2]}() — global-state "
                           f"RNG breaks reproducibility and shard "
                           f"invariance; use np.random.default_rng(seed)")
            elif (len(d) == 3 and d[0] in ("np", "numpy")
                  and d[1] == "random" and d[2] == "default_rng"
                  and not node.args and not node.keywords):
                self._emit("JS005", node,
                           "np.random.default_rng() without a seed is "
                           "entropy-seeded; pass a seed or SeedSequence")

        self.generic_visit(node)


# ---------------------------------------------------------------------------
# suppression handling
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Suppression:
    """One well-formed reasoned suppression comment (for stale tracking)."""
    line: int
    rules: Tuple[str, ...]
    reason: str
    covered: Tuple[int, ...]


def _iter_comments(source: str) -> Iterator[Tuple[int, int, str]]:
    """(line, col, text) of every real COMMENT token. Tokenizing (rather
    than line-scanning) keeps suppression examples inside docstrings inert
    — they are STRING tokens, not comments."""
    try:
        tokens = list(tokenize.generate_tokens(io.StringIO(source).readline))
    except (tokenize.TokenError, IndentationError, SyntaxError):
        # unparsable tail: fall back to the plain line scan
        for i, text in enumerate(source.splitlines(), start=1):
            pos = text.find("#")
            if pos >= 0:
                yield i, pos, text[pos:]
        return
    for tok in tokens:
        if tok.type == tokenize.COMMENT:
            yield tok.start[0], tok.start[1], tok.string


def _parse_suppressions(source: str, path: str):
    """({line: (rules, reason)}, JS000 findings for malformed comments,
    [Suppression] records of the well-formed ones for stale detection)."""
    supp: Dict[int, Tuple[Set[str], str]] = {}
    bad: List[Finding] = []
    records: List[Suppression] = []
    lines = source.splitlines()
    for i, col, text in _iter_comments(source):
        m = _SUPPRESS_RE.search(text)
        if not m:
            if _HINT_RE.search(text):
                bad.append(Finding(path, i, 0, "JS000",
                                   "malformed repro-lint suppression "
                                   "(syntax: `# repro-lint"
                                   ": disable=JSxxx -- reason`)"))
            continue
        rules = {r.strip().upper() for r in m.group(1).split(",") if r.strip()}
        reason = (m.group(2) or "").strip()
        unknown = sorted(r for r in rules if r not in SUPPRESSIBLE)
        if unknown:
            bad.append(Finding(path, i, 0, "JS000",
                               f"suppression names unknown/unsuppressible "
                               f"rule(s) {unknown}"))
            rules -= set(unknown)
        if not reason:
            bad.append(Finding(path, i, 0, "JS000",
                               "suppression without a reason string — every "
                               "disable must say why (`-- <reason>`)"))
            continue  # a reasonless suppression does not suppress
        if rules:
            covered = [i]
            # a comment-only line covers the following statement line too
            before = lines[i - 1][:col] if i - 1 < len(lines) else ""
            if not before.strip():
                covered.append(i + 1)
            records.append(Suppression(i, tuple(sorted(rules)), reason,
                                       tuple(covered)))
            for ln in covered:
                prev = supp.get(ln, (set(), ""))
                supp[ln] = (prev[0] | rules, reason or prev[1])
    return supp, bad, records


def lint_source(source: str, path: str,
                rules: Optional[Set[str]] = None) -> List[Finding]:
    """Lint one file's source. ``rules`` overrides the path-derived scope
    (used by the fixture tests to force the jit-scope rule set)."""
    rules = rules if rules is not None else scope_rules(path)
    try:
        tree = ast.parse(source, filename=path)
    except SyntaxError as e:
        return [Finding(path, e.lineno or 0, e.offset or 0, "JS000",
                        f"file does not parse: {e.msg}")]
    visitor = _Visitor(path, rules)
    visitor.visit(tree)
    supp, findings, records = _parse_suppressions(source, path)
    for f in visitor.raw:
        s = supp.get(f.line)
        if s and f.rule in s[0]:
            findings.append(dataclasses.replace(f, suppressed=True,
                                                reason=s[1]))
        else:
            findings.append(f)
    # JS006: a reasoned suppression whose rule never fired on any covered
    # line is stale — the code was fixed (or moved) and the disable rotted.
    # Only JS rules in this file's active scope are judged here; SP1xx
    # suppressions are the spmd collectives pass's to verify.
    fired = {(f.line, f.rule) for f in visitor.raw}
    for rec in records:
        for r in rec.rules:
            if not r.startswith("JS") or r not in rules:
                continue
            if not any((ln, r) in fired for ln in rec.covered):
                findings.append(Finding(
                    path, rec.line, 0, "JS006",
                    f"stale suppression: {r} no longer fires on "
                    f"line(s) {list(rec.covered)} — remove the disable "
                    f"comment (reason was: {rec.reason!r})",
                    advisory=True))
    return sorted(findings, key=lambda f: (f.line, f.col, f.rule))


def lint_file(path: str, rules: Optional[Set[str]] = None) -> List[Finding]:
    with open(path, "r") as fh:
        return lint_source(fh.read(), path, rules)


def lint_paths(paths: Sequence[str]) -> List[Finding]:
    """Lint every ``.py`` under the given files/directories."""
    findings: List[Finding] = []
    for root in paths:
        if os.path.isfile(root):
            findings.extend(lint_file(root))
            continue
        for dirpath, dirnames, filenames in os.walk(root):
            dirnames[:] = sorted(d for d in dirnames
                                 if d not in ("__pycache__", ".git"))
            for fn in sorted(filenames):
                if fn.endswith(".py"):
                    findings.extend(lint_file(os.path.join(dirpath, fn)))
    return findings
