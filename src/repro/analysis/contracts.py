"""Pass 2 — planner contract checker (DESIGN.md §12.2).

The planner's whole value proposition (paper §5.3) rests on structural
contracts that, until this pass, were enforced only dynamically by tests:

1. **All-candidate-paths-agree**: every legal execution path of a
   :class:`~repro.planner.ir.ContractionIR` computes the same einsum, so it
   must produce identical output *avals* (pytree structure + shape + dtype).
   Checked abstractly — no kernel runs — via ``jax.eval_shape`` semantics:
   ``jax.make_jaxpr(..., return_shape=True)`` with an ``axis_env`` binding
   the distribution signature's mesh axes, so distributed variants
   (psum/all-gather/reduce-scatter schedules) are certified without devices.
2. **Cost-model invariants**: flops/mem/comm are finite and nonnegative for
   every (IR, path); ``comm ≡ 0`` for LOCAL IRs; the densified-fallback
   flops upper-bound every sparse path's flops at sub-saturation density
   (the regime the paper's ranking argument assumes); estimates are
   deterministic.
3. **Cache-key hygiene**: plan-cache signatures are hashable, deterministic,
   and collision-free across a grid of signature-relevant variations
   (shape, cap, nnz, dtype, nnz_rows, forced path, DistInfo *sizes*,
   PlannerConfig) — the static tripwire for the PR-3 mesh-aliasing bug
   class (same-named axes on different-size meshes must not share a plan).

The exhaustive offline sweep (``iter_cases``) covers all 7 IR families —
DENSE, REDUCE, TTTP, TTM, classic MTTKRP, partial/multi-output MTTKRP, and
CG_MATVEC — at orders 3–5, local plus every DistInfo variant the executor
supports (data-sharded, model/column-sharded, row-sharded). The same
certification runs online through ``plan_contraction(..., validate=True)``
(see ``certify_candidates``), which the plan cache consults *before* a new
plan is stored.

The sparse operand's concrete indices are closed over (only values and
factors are abstracted), so the ingest-cached bucketed/fused kernel routes —
not just their tracing fallbacks — are what gets certified.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.analysis.lint import Finding  # shared report record

_LETTERS = "ijklm"
_EXTENTS = {3: (6, 4, 8), 4: (6, 4, 8, 4), 5: (6, 4, 8, 4, 6)}
_RANK = 4
_NNZ = 8

FAMILIES = ("dense", "reduce", "tttp", "ttm", "mttkrp", "mttkrp_partial",
            "cg_matvec")

# deliberate-corruption hook (checker self-test / CI tripwire): when set to a
# path name, that path's evaluated output avals are distorted, which MUST
# make the sweep fail — proving the checker would catch a real violation
_CORRUPT_PATH: Optional[str] = None


def set_corrupt(path: Optional[str]) -> None:
    global _CORRUPT_PATH
    _CORRUPT_PATH = path


class PlanContractError(RuntimeError):
    """A candidate path's output avals disagree with its siblings."""


@dataclasses.dataclass
class Case:
    """One (expression, operands, distribution) point of the sweep grid."""
    name: str
    family: str
    expr: str
    ir: object                 # ContractionIR
    st: object                 # SparseTensor (concrete, tiny)
    denses: Tuple              # dense operands in operand order
    ctx: object                # AxisCtx
    config: object             # PlannerConfig
    axis_env: Tuple = ()       # (("data", 2),) etc.; () = local


# ---------------------------------------------------------------------------
# grid construction
# ---------------------------------------------------------------------------

def _make_sparse(shape, nnz=_NNZ, dense_dim=None):
    """Deterministic tiny sparse tensor (no RNG: the sweep must be
    bit-reproducible across runs and machines)."""
    from repro.core.sparse_tensor import SparseTensor
    idx = np.stack([(np.arange(nnz) * (d + 3)) % s
                    for d, s in enumerate(shape)], axis=1).astype(np.int32)
    if dense_dim is None:
        vals = np.linspace(0.5, 1.5, nnz, dtype=np.float32)
    else:
        vals = np.linspace(0.5, 1.5, nnz * dense_dim,
                           dtype=np.float32).reshape(nnz, dense_dim)
    return SparseTensor.from_coo(idx, vals, shape)


def _make_factor(rows, cols, seed):
    return np.linspace(-1.0, 1.0, rows * cols,
                       dtype=np.float32).reshape(rows, cols) + 0.01 * seed


def _dist_variants(family: str):
    """(variant name, DistInfo fields) pairs legal for this family."""
    base = [("local", None)]
    data = ("data", (2, 1, False))
    model = ("model", (1, 2, False))
    rowsh = ("rowsharded", (2, 1, True))
    return {
        "dense": base,
        "reduce": base + [data],
        "tttp": base + [data, model, rowsh],
        "ttm": base + [data],
        "mttkrp": base + [data, model, rowsh],
        "mttkrp_partial": base + [data],
        "cg_matvec": base + [data, model],
    }[family]


def _family_exprs(family: str, order: int) -> List[str]:
    s = _LETTERS[:order]
    if family == "dense":
        return ["ab,bc->ac"] if order == 3 else []
    if family == "reduce":
        return [f"{s}->{s[-1]}{s[0]}"]
    if family == "tttp":
        facs = ",".join(f"{c}r" for c in s)
        return [f"{s},{facs}->{s}"]
    if family == "ttm":
        out = [f"{s},{s[-1]}r->{s[:-1]}r"]
        if order == 3:
            out.append(f"{s},{s[-1]}r->r{s[:-1]}")   # permuted output
        return out
    if family == "mttkrp":
        facs = ",".join(f"{c}r" for c in s[1:])
        out = [f"{s},{facs}->{s[0]}r"]
        if order == 3:
            out.append(f"{s},{facs}->r{s[0]}")       # permuted output
        return out
    if family == "mttkrp_partial":
        if order < 4:
            return []                    # order-3 partial degenerates to TTM
        kept, contracted = s[:2], s[2:]
        facs = ",".join(f"{c}r" for c in contracted)
        return [f"{s},{facs}->{kept}r"]
    if family == "cg_matvec":
        r_facs = ",".join(f"{c}r" for c in s[1:])
        y_facs = ",".join(f"{c}y" for c in s)
        return [f"{s},{r_facs},{y_facs}->{s[0]}r"]
    raise ValueError(family)


def _build_case(family: str, expr: str, order: int, variant: str,
                dist_fields) -> Case:
    from repro.core.distributed import LOCAL, AxisCtx
    from repro.planner import ir as pir
    from repro.planner.config import default_config

    dist = None if dist_fields is None else pir.DistInfo(*dist_fields)
    ctx, axis_env = LOCAL, ()
    if dist is not None:
        names = []
        if dist.data_size > 1 or dist.rowsharded:
            names.append(("data", max(dist.data_size, 1)))
        if dist.model_size > 1:
            names.append(("model", dist.model_size))
        ctx = AxisCtx(
            data="data" if any(n == "data" for n, _ in names) else None,
            model="model" if any(n == "model" for n, _ in names) else None)
        axis_env = tuple(names)

    lhs, _ = expr.split("->")
    terms = lhs.split(",")
    if family == "dense":
        sizes = {"a": 3, "b": 4, "c": 5}
        denses = tuple(_make_factor(sizes[t[0]], sizes[t[1]], i)
                       for i, t in enumerate(terms))
        ir = pir.build_ir(expr, denses, dist=dist)
        return Case(f"{family}/{variant}", family, expr, ir, None, denses,
                    ctx, default_config(), axis_env)

    shape = _EXTENTS[order]
    sizes = dict(zip(_LETTERS[:order], shape))
    rank = _RANK // dist.model_size if dist is not None else _RANK
    sizes["r"] = sizes["y"] = rank
    st = _make_sparse(shape)
    row_div = dist.data_size if (dist is not None and dist.rowsharded) else 1

    # factor construction with object sharing across the CG halves: one
    # array per sparse mode, reused wherever that mode appears (the fused
    # kernel's legality depends on `is`-sharedness of the two halves)
    per_mode: Dict[str, np.ndarray] = {}
    denses_l: List = []
    for i, t in enumerate(terms[1:]):
        mode_c = t[0]
        if family == "cg_matvec" and t == f"{mode_c}y" and mode_c != lhs[0]:
            arr = per_mode[mode_c]                    # share with the r half
        else:
            arr = _make_factor(sizes[mode_c] // row_div, sizes[t[1]], i)
            per_mode.setdefault(mode_c, arr)
        denses_l.append(arr)
    operands = [st] + denses_l
    ir = pir.build_ir(expr, operands, dist=dist)
    perm = "/perm" if expr.split("->")[1][0] == "r" else ""
    return Case(f"{family}/o{order}/{variant}{perm}", family, expr, ir, st,
                tuple(denses_l), ctx, default_config(), axis_env)


def iter_cases(orders: Sequence[int] = (3, 4, 5),
               families: Sequence[str] = FAMILIES) -> List[Case]:
    """The exhaustive sweep grid: family × order × expression × DistInfo."""
    cases: List[Case] = []
    for family in families:
        for order in orders:
            for expr in _family_exprs(family, order):
                for variant, dist_fields in _dist_variants(family):
                    cases.append(_build_case(family, expr, order, variant,
                                             dist_fields))
    # trailing-dense-axis reductions (values carry an R axis that rides
    # along unreduced — only the REDUCE family admits them)
    if "reduce" in families and 3 in orders:
        from repro.planner import ir as pir
        for variant, df in _dist_variants("reduce"):
            st = _make_sparse(_EXTENTS[3], dense_dim=_RANK)
            dist = None if df is None else pir.DistInfo(*df)
            from repro.core.distributed import LOCAL, AxisCtx
            ctx = LOCAL if dist is None else AxisCtx(data="data")
            env = () if dist is None else (("data", dist.data_size),)
            ir = pir.build_ir("ijk->i", [st], dist=dist)
            from repro.planner.config import default_config
            cases.append(Case(f"reduce/o3+dense/{variant}", "reduce",
                              "ijk->i", ir, st, (), ctx, default_config(),
                              env))
    return cases


# ---------------------------------------------------------------------------
# abstract path evaluation
# ---------------------------------------------------------------------------

def _aval_signature(out) -> Tuple:
    import jax
    leaves, treedef = jax.tree_util.tree_flatten(out)
    return (str(treedef),
            tuple((tuple(l.shape), str(l.dtype)) for l in leaves))


def path_avals(case: Case, path: str) -> Tuple:
    """Abstractly evaluate one candidate path: pytree structure plus leaf
    (shape, dtype) pairs, traced under the case's axis_env (collectives are
    evaluated against the DistInfo's axis sizes; no devices required)."""
    import jax
    import jax.numpy as jnp

    from repro.planner import dispatch as pdispatch

    ir, st = case.ir, case.st

    # dedupe shared dense operands so `is`-identity survives tracing (the
    # fused CG kernel is only legal when the two halves share factors)
    uniq: List = []
    posmap: List[int] = []
    for d in case.denses:
        for k, u in enumerate(uniq):
            if d is u:
                posmap.append(k)
                break
        else:
            posmap.append(len(uniq))
            uniq.append(d)

    def f(*args):
        if st is None:
            ops: List = list(args)
        else:
            values, uds = args[0], args[1:]
            dense = [uds[k] for k in posmap]
            ops = [None] * len(ir.operands)
            ops[ir.sparse_pos] = st.with_values(values)
            for pos, dop in zip(ir.dense_positions, dense):
                ops[pos] = dop
        out = pdispatch.execute(ir, path, ops, ctx=case.ctx,
                                config=case.config)
        if _CORRUPT_PATH is not None and path == _CORRUPT_PATH:
            out = jax.tree.map(lambda a: jnp.expand_dims(a, 0), out)
        return out

    args = (tuple(uniq) if st is None
            else (st.values,) + tuple(uniq))
    env = list(case.axis_env) if case.axis_env else None
    _, shapes = jax.make_jaxpr(f, axis_env=env, return_shape=True)(*args)
    return _aval_signature(shapes)


def check_path_agreement(cases: Sequence[Case]) -> List[Finding]:
    """Contract 1: identical avals across every candidate path, per case."""
    from repro.planner import cost as pcost
    findings: List[Finding] = []
    for case in cases:
        sigs: Dict[str, Tuple] = {}
        for path in pcost.candidate_paths(case.ir):
            try:
                sigs[path] = path_avals(case, path)
            except Exception as e:  # an un-executable candidate IS a finding
                findings.append(Finding(
                    "contracts", 0, 0, "CT001",
                    f"[{case.name}] path {path!r} failed abstract "
                    f"evaluation for {case.expr!r}: {type(e).__name__}: {e}"))
        if len(set(sigs.values())) > 1:
            ref_path, ref = next(iter(sigs.items()))
            for path, sig in sigs.items():
                if sig != ref:
                    findings.append(Finding(
                        "contracts", 0, 0, "CT001",
                        f"[{case.name}] path {path!r} avals {sig} disagree "
                        f"with {ref_path!r} avals {ref} for {case.expr!r}"))
    return findings


# ---------------------------------------------------------------------------
# cost-model invariants
# ---------------------------------------------------------------------------

def check_cost_invariants(cases: Sequence[Case]) -> List[Finding]:
    from repro.planner import cost as pcost
    findings: List[Finding] = []

    def bad(case, msg):
        findings.append(Finding("contracts", 0, 0, "CT002",
                                f"[{case.name}] {msg}"))

    for case in cases:
        ir = case.ir
        costs = {p: pcost.estimate(ir, p)
                 for p in pcost.candidate_paths(ir)}
        for p, c in costs.items():
            again = pcost.estimate(ir, p)
            if c != again:
                bad(case, f"estimate({p!r}) is nondeterministic: "
                          f"{c} vs {again}")
            for field in ("flops", "mem", "comm"):
                v = getattr(c, field)
                if not math.isfinite(v) or v < 0:
                    bad(case, f"path {p!r} has invalid {field}={v!r}")
            if ir.dist is None and c.comm != 0.0:
                bad(case, f"path {p!r} charges comm={c.comm} on a LOCAL IR")
            if not math.isfinite(c.seconds) or c.seconds < 0:
                bad(case, f"path {p!r} has invalid seconds={c.seconds!r}")
        dense = costs.get("dense")
        if dense is not None:
            for p, c in costs.items():
                if p != "dense" and c.flops > dense.flops * (1 + 1e-9):
                    bad(case, f"sparse path {p!r} flops {c.flops} exceed the "
                              f"densified fallback's {dense.flops} at "
                              f"sub-saturation density — the §5.3 ranking "
                              f"premise is violated")
    return findings


# ---------------------------------------------------------------------------
# cache-key hygiene
# ---------------------------------------------------------------------------

def check_cache_keys() -> List[Finding]:
    """Plan-cache signatures over a grid of signature-relevant variations
    must be hashable, deterministic, and pairwise distinct."""
    import dataclasses as dc

    import jax.numpy as jnp

    from repro.core.distributed import LOCAL, AxisCtx
    from repro.planner import ir as pir
    from repro.planner import plan as pplan
    from repro.planner.config import PlannerConfig

    findings: List[Finding] = []
    expr = "ijk,jr,kr->ir"
    shape = (6, 4, 8)
    st = _make_sparse(shape)
    a, b = _make_factor(4, _RANK, 0), _make_factor(8, _RANK, 1)
    ops = (st, a, b)
    from repro.core.sparse_tensor import SparseTensor
    st_cap = SparseTensor.from_coo(np.asarray(st.indices)[:_NNZ],
                                   np.asarray(st.values)[:_NNZ], shape,
                                   cap=2 * _NNZ)

    def sig(label, operands=ops, path=None, ctx=LOCAL, dist=None,
            config=PlannerConfig()):
        return label, pplan._signature(expr, operands, path, ctx, dist,
                                       config)

    variations = [
        sig("base"),
        sig("cap", (st_cap, a, b)),
        sig("nnz", (_make_sparse(shape, nnz=4), a, b)),
        sig("dtype", (st.astype(jnp.bfloat16), a, b)),
        sig("nnz_rows", (dc.replace(st, nnz_rows=(3, 4, 5)), a, b)),
        sig("shape", (_make_sparse((6, 4, 10)), a,
                      _make_factor(10, _RANK, 1))),
        sig("path", path="all_at_once"),
        sig("ctx-data", ctx=AxisCtx(data="data"),
            dist=pir.DistInfo(2, 1, False)),
        sig("ctx-data4", ctx=AxisCtx(data="data"),
            dist=pir.DistInfo(4, 1, False)),       # PR-3 aliasing class:
        sig("ctx-model", ctx=AxisCtx(model="model"),  # same names, new sizes
            dist=pir.DistInfo(1, 2, False)),
        sig("rowsharded", ctx=AxisCtx(data="data"),
            dist=pir.DistInfo(2, 1, True)),
        sig("config", config=PlannerConfig(block_rows=16)),
    ]

    # determinism: rebuilding the same operands from scratch must reproduce
    # the same signature object-for-object (hash and equality)
    _, base_key = variations[0]
    again = pplan._signature(
        expr, (_make_sparse(shape), _make_factor(4, _RANK, 0),
               _make_factor(8, _RANK, 1)), None, LOCAL, None, PlannerConfig())
    try:
        if base_key != again or hash(base_key) != hash(again):
            findings.append(Finding(
                "contracts", 0, 0, "CT003",
                "cache key is nondeterministic: identical configurations "
                "built twice produce different signatures"))
    except TypeError:
        pass  # unhashability is reported per-variation below

    seen: Dict[Tuple, str] = {}
    for label, key in variations:
        try:
            hash(key)
        except TypeError as e:
            findings.append(Finding("contracts", 0, 0, "CT003",
                                    f"cache key {label!r} is unhashable: {e}"))
            continue
        if key in seen:
            findings.append(Finding(
                "contracts", 0, 0, "CT003",
                f"cache-key COLLISION: {label!r} and {seen[key]!r} produce "
                f"the same plan-cache signature — distinct configurations "
                f"would silently share a plan (the PR-3 mesh-aliasing bug "
                f"class)"))
        seen[key] = label
    return findings


# ---------------------------------------------------------------------------
# online certification (the plan-cache validate= hook)
# ---------------------------------------------------------------------------

def certify_candidates(ir, paths: Sequence[str], operands: Sequence,
                       ctx, config) -> None:
    """Raise :class:`PlanContractError` unless every candidate path of this
    concrete call produces identical output avals. Called by
    ``plan_contraction(..., validate=True)`` before a new plan may enter the
    cache; also usable directly on user-constructed IRs."""
    import jax

    from repro.planner import dispatch as pdispatch

    def run_path(path, *ops):
        out = pdispatch.execute(ir, path, list(ops), ctx=ctx, config=config)
        if _CORRUPT_PATH is not None and path == _CORRUPT_PATH:
            import jax.numpy as jnp
            out = jax.tree.map(lambda a: jnp.expand_dims(a, 0), out)
        return out

    sigs: Dict[str, Tuple] = {}
    for path in paths:
        out = jax.eval_shape(
            lambda *ops, _p=path: run_path(_p, *ops), *operands)
        sigs[path] = _aval_signature(out)
    if len(set(sigs.values())) > 1:
        detail = "; ".join(f"{p}: {s}" for p, s in sorted(sigs.items()))
        raise PlanContractError(
            f"candidate paths of {ir.expr!r} disagree on output avals — "
            f"refusing to cache a plan: {detail}")


# ---------------------------------------------------------------------------
# top-level entry
# ---------------------------------------------------------------------------

def run(orders: Sequence[int] = (3, 4, 5)) -> List[Finding]:
    cases = iter_cases(orders)
    findings = check_path_agreement(cases)
    findings += check_cost_invariants(cases)
    findings += check_cache_keys()
    return findings
