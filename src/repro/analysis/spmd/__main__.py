import sys

from repro.analysis.spmd.cli import main

if __name__ == "__main__":
    sys.exit(main())
