"""SPMD pass 2 — collective-matching lint (DESIGN.md §15.2).

Collectives are rendezvous points: every device in a `shard_map` body must
execute the SAME sequence of them, in the same order, over the same axis
names — or the program deadlocks across processes (on 8 host devices the
same bug is merely a wrong number). This AST pass walks the layers that
execute inside `shard_map` (``core/``, ``planner/``, ``runtime/``) and
extracts the collective sequence on each control-flow path:

* ``SP101`` collective-divergence — a Python ``if`` whose test is
  device-varying (contains a traced ``jnp``/``jax.lax`` call, or consults
  ``axis_index``/``process_index``) and whose branches execute *different*
  collective sequences: the classic SPMD deadlock (one branch psums, the
  other doesn't). Uniform tests (``ctx.data is not None``, path-string
  dispatch) are configuration, not data — they branch identically on every
  device and are never flagged.
* ``SP102`` collective-under-traced-conditional — a collective inside a
  ``lax.cond``/``lax.switch`` branch: whether it executes depends on a
  traced predicate, which devices may disagree on. (The jaxpr-level twin of
  this check, including ``while_loop`` predicates, lives in
  ``sharding.py``.)
* ``SP103`` hardcoded-axis-name — a ``jax.lax`` collective whose axis
  argument is a string literal instead of a name threaded from the
  enclosing mesh contract (``AxisCtx`` / ``DistInfo``): the literal works
  on exactly one mesh spelling and silently mismatches any other.

Inline suppressions follow ``lint.py`` discipline (SP101–SP103 are
suppressible with a reason); a suppression naming an SP rule that no
longer fires is reported stale (``JS006``) by this pass.
"""
from __future__ import annotations

import ast
import os
from typing import Dict, List, Optional, Sequence, Set, Tuple

import dataclasses

from repro.analysis.lint import (Finding, _contains_traced_call, _dotted,
                                 _parse_suppressions)

# jax.lax rendezvous collectives (and the axis-dependent axis_index)
COLLECTIVES = {"psum", "pmean", "pmax", "pmin", "all_gather", "psum_scatter",
               "all_to_all", "ppermute", "pshuffle", "pswapaxes"}
# repo helpers that wrap collectives (core/distributed.py): calling one IS
# executing a collective on that control-flow path
CTX_HELPERS = {"psum_data", "psum_model", "sparse_allreduce_butterfly",
               "multilinear_rowsharded", "all_gather_factor"}
# positional index of the axis-name argument per collective
_AXIS_ARG = {"psum": 1, "pmean": 1, "pmax": 1, "pmin": 1, "all_gather": 1,
             "psum_scatter": 1, "all_to_all": 1, "ppermute": 1,
             "pshuffle": 1, "axis_index": 0}

# the layers that execute inside shard_map bodies (repo-root-relative)
DEFAULT_ROOTS = ("src/repro/core", "src/repro/planner", "src/repro/runtime")


def _collective_name(call: ast.Call) -> Optional[str]:
    """The collective this call executes, or None."""
    d = _dotted(call.func)
    if d is None:
        return None
    if d[-1] in COLLECTIVES and (
            d[0] == "lax" or (len(d) >= 2 and d[0] == "jax"
                              and d[-2] == "lax")):
        return d[-1]
    if d[-1] in CTX_HELPERS:
        return d[-1]
    return None


def _is_lax_rooted(d: Tuple[str, ...]) -> bool:
    return d[0] == "lax" or (len(d) >= 2 and d[0] == "jax" and d[-2] == "lax")


def _device_varying_test(test: ast.AST) -> bool:
    """Does this `if` test depend on per-device data? Traced jnp/lax calls
    are device-varying; so is anything consulting the device/process
    identity. Plain attribute/None/string tests are uniform configuration."""
    if _contains_traced_call(test):
        return True
    for n in ast.walk(test):
        if isinstance(n, ast.Call):
            d = _dotted(n.func)
            if d is not None and d[-1] in ("axis_index", "process_index",
                                           "model_index"):
                return True
    return False


def _collective_sequence(stmts: Sequence[ast.stmt]) -> Tuple[str, ...]:
    """Ordered collective names executed by a statement list, recursing
    through uniform structure (loops, with, nested uniform ifs join as
    the union-in-order of their own flagged-or-not bodies)."""
    seq: List[str] = []
    for stmt in stmts:
        for node in ast.walk(stmt):
            if isinstance(node, ast.Call):
                name = _collective_name(node)
                if name is not None:
                    seq.append(name)
    return tuple(seq)


class _Visitor(ast.NodeVisitor):
    def __init__(self, path: str):
        self.path = path
        self.raw: List[Finding] = []

    def _emit(self, rule: str, node: ast.AST, msg: str) -> None:
        self.raw.append(Finding(self.path, node.lineno, node.col_offset,
                                rule, msg))

    def visit_If(self, node: ast.If) -> None:
        if _device_varying_test(node.test):
            body = _collective_sequence(node.body)
            orelse = _collective_sequence(node.orelse)
            if body != orelse:
                self._emit(
                    "SP101", node,
                    f"collective sequences diverge across a device-varying "
                    f"branch: if-branch {list(body)} vs else-branch "
                    f"{list(orelse)} — devices taking different branches "
                    f"rendezvous on different collectives and deadlock")
        self.generic_visit(node)

    def visit_Call(self, node: ast.Call) -> None:
        d = _dotted(node.func)
        if d is not None and _is_lax_rooted(d):
            # SP102: collectives under a traced conditional
            if d[-1] in ("cond", "switch"):
                if any(isinstance(n, ast.Call)
                       and _collective_name(n) is not None
                       for a in node.args[1:] for n in ast.walk(a)):
                    self._emit(
                        "SP102", node,
                        f"collective inside a lax.{d[-1]} branch — whether "
                        f"it executes depends on a traced predicate, which "
                        f"devices may disagree on; hoist the collective out "
                        f"of the conditional (compute both, select after)")
            # SP103: string-literal axis names
            if d[-1] in _AXIS_ARG:
                axis = None
                pos = _AXIS_ARG[d[-1]]
                if len(node.args) > pos:
                    axis = node.args[pos]
                for kw in node.keywords:
                    if kw.arg == "axis_name":
                        axis = kw.value
                if (isinstance(axis, ast.Constant)
                        and isinstance(axis.value, str)):
                    self._emit(
                        "SP103", node,
                        f"lax.{d[-1]} over hardcoded axis name "
                        f"{axis.value!r} — axis names must come from the "
                        f"enclosing mesh contract (AxisCtx/DistInfo), not "
                        f"string literals that bind to one mesh spelling")
        self.generic_visit(node)


def lint_source(source: str, path: str) -> List[Finding]:
    """Collective-matching lint of one file, with lint.py suppression and
    SP-stale (JS006) discipline applied."""
    try:
        tree = ast.parse(source, filename=path)
    except SyntaxError as e:
        return [Finding(path, e.lineno or 0, e.offset or 0, "SP000",
                        f"file does not parse: {e.msg}")]
    visitor = _Visitor(path)
    visitor.visit(tree)
    supp, _bad, records = _parse_suppressions(source, path)
    findings: List[Finding] = []
    for f in visitor.raw:
        s = supp.get(f.line)
        if s and f.rule in s[0]:
            findings.append(dataclasses.replace(f, suppressed=True,
                                                reason=s[1]))
        else:
            findings.append(f)
    fired = {(f.line, f.rule) for f in visitor.raw}
    for rec in records:
        for r in rec.rules:
            if not r.startswith("SP"):
                continue  # JS staleness is lint.py's to judge
            if not any((ln, r) in fired for ln in rec.covered):
                findings.append(Finding(
                    path, rec.line, 0, "JS006",
                    f"stale suppression: {r} no longer fires on "
                    f"line(s) {list(rec.covered)} — remove the disable "
                    f"comment (reason was: {rec.reason!r})",
                    advisory=True))
    return sorted(findings, key=lambda f: (f.line, f.col, f.rule))


def lint_file(path: str) -> List[Finding]:
    with open(path, "r") as fh:
        return lint_source(fh.read(), path)


def run(root: str, roots: Sequence[str] = DEFAULT_ROOTS) -> List[Finding]:
    """Lint every shard_map-executing layer under the repo root."""
    findings: List[Finding] = []
    for rel in roots:
        top = os.path.join(root, rel)
        if os.path.isfile(top):
            findings.extend(lint_file(top))
            continue
        if not os.path.isdir(top):
            continue
        for dirpath, dirnames, filenames in os.walk(top):
            dirnames[:] = sorted(d for d in dirnames
                                 if d not in ("__pycache__", ".git"))
            for fn in sorted(filenames):
                if fn.endswith(".py"):
                    findings.extend(lint_file(os.path.join(dirpath, fn)))
    return findings
