"""SPMD pass 3 — static VMEM certification of the tile lattices
(DESIGN.md §15.3).

Prices every tile candidate in ``planner.tuner.LATTICES`` against the
device VMEM budget using the :mod:`repro.kernels.vmem` footprint model and
reports ``SP201`` for any candidate that cannot fit. The same model backs
the tuner's online pruning (a rejected tile is never timed and never cached
as a winner); this pass is the offline sweep that certifies the *shipped
lattice* against representative geometries before any tuner runs.

Two geometry tiers:

* the default (CI) tier — the benchmark workload plus a large single-host
  study shape; the blocking CI job requires ZERO findings here, so every
  committed lattice candidate is provably runnable on a 16 MiB core.
* ``--paper-scale`` — netflix-full / paper-function mode extents, where the
  full-height resident factors of ``tttp``/``cg_matvec`` legitimately
  exceed VMEM. These findings are *expected* (opt-in, non-blocking): they
  quantify exactly which modes need the ROADMAP's DMA-streamed
  HBM-resident-factor follow-up before paper-scale Pallas runs.
"""
from __future__ import annotations

from typing import List, Optional, Tuple

from repro.analysis.lint import Finding
from repro.kernels.vmem import (KernelGeometry, estimate_vmem,
                                vmem_budget_bytes)

# (name, geometry per family) — capacities chosen as the observed CCSR
# bucket caps for those shapes at the default block_rows
_CI_SHAPES: Tuple[Tuple[str, Tuple[int, ...], int, int], ...] = (
    # (tier label, dims, rank, tttp capacity)
    ("bench", (80, 60, 20), 8, 15_360),
    ("study", (4096, 2048, 1024), 32, 1 << 20),
)
_PAPER_SHAPES: Tuple[Tuple[str, Tuple[int, ...], int, int], ...] = (
    ("netflix-full", (480_189, 17_770, 2_182), 32, 1 << 20),
    ("paper-function", (5_000, 5_000, 5_000, 5_000), 25, 1 << 20),
)


def _geometries(family: str, shapes, block_rows: int
                ) -> List[Tuple[str, KernelGeometry]]:
    out: List[Tuple[str, KernelGeometry]] = []
    for label, dims, rank, cap in shapes:
        if family == "tttp":
            geom = KernelGeometry(nd=len(dims), rank=rank,
                                  factor_rows=tuple(dims), capacity=cap,
                                  block_rows=block_rows)
        else:
            # bucketed kernels stream mode-0 buckets; resident factors are
            # the non-target modes. Bucket capacity scales with occupancy:
            # assume a dense-ish block (capacity = cap / dims[0] rows per
            # bucket, floored at one vector)
            bucket_cap = max(8, (cap // max(dims[0], 1)) * block_rows)
            geom = KernelGeometry(
                nd=len(dims), rank=rank, factor_rows=tuple(dims[1:]),
                capacity=bucket_cap, block_rows=block_rows,
                x_rows=dims[0] if family == "cg_matvec" else None)
        out.append((label, geom))
    return out


def run(budget_mb: Optional[float] = None, paper_scale: bool = False
        ) -> List[Finding]:
    """Certify every lattice candidate of every family. Returns SP201
    findings for candidates that exceed the budget."""
    from repro.planner import tuner

    budget = (int(budget_mb * 2 ** 20) if budget_mb is not None
              else vmem_budget_bytes())
    shapes = _PAPER_SHAPES if paper_scale else _CI_SHAPES
    findings: List[Finding] = []
    for family, lattice in sorted(tuner.LATTICES.items()):
        for tile in lattice:
            for label, geom in _geometries(family, shapes, tile.block_rows):
                est = estimate_vmem(family, tile, geom, budget=budget)
                if not est.fits:
                    findings.append(Finding(
                        "vmem", 0, 0, "SP201",
                        f"[{label}] lattice candidate cannot fit VMEM: "
                        f"{est.format()}"))
    return findings


def check_fixture(mod) -> List[Finding]:
    """Fixture entry: a module declaring FAMILY, TILE (KernelTile kwargs)
    and GEOMETRY (KernelGeometry kwargs), optionally BUDGET_MB."""
    from repro.kernels.tile import KernelTile

    tile = KernelTile(**mod.TILE)
    geom = KernelGeometry(**mod.GEOMETRY)
    budget = int(getattr(mod, "BUDGET_MB", 16) * 2 ** 20)
    est = estimate_vmem(mod.FAMILY, tile, geom, budget=budget)
    if est.fits:
        return []
    return [Finding("vmem", 0, 0, "SP201",
                    f"[fixture] {est.format()}")]
