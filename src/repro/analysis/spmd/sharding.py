"""SPMD pass 1 — static sharding propagation over the planner IR
(DESIGN.md §15.1).

An abstract interpreter over jaxprs that assigns every intermediate a
*replication state* per mesh axis and certifies that each candidate path of
every planner family leaves no partial sum unreduced. The state lattice,
per (value, mesh axis):

* ``("rep",)``        — replicated: every device holds the same value.
* ``("shard", d)``    — device-distinct along dimension ``d`` (row/column
  ownership; ``d=None`` when the owning dimension is unknown). A shard is
  *correct* per device — it must never be psum'd.
* ``("part",)``       — partial sum: the true value is the psum over the
  axis. Sticky through arithmetic; only a psum (or reduce-scatter)
  discharges it.
* ``("over",)``       — over-reduced: a replicated value was psum'd again
  (the result is ``axis_size ×`` the intended value).

Transfer rules: collectives move between states (psum: part→rep;
all_gather: shard→rep; psum_scatter: part→shard); ``reduce_sum`` /
``dot_general`` contraction of a sharded dimension yields ``part``;
``gather`` with sharded indices yields row-sharded gathers, while a gather
that resolves global coordinates against a ROWS-tagged shard (a rowsharded
factor) is flagged (``SP004`` — the all_gather is missing; owner-aligned
gathers within a device's own nnz shard are legal local moves);
``scatter-add`` of device-distinct updates yields ``part``.
Control flow (``while``/``scan``) is handled by monotone fixpoint over the
carry, and a collective under a device-varying predicate is the classic
SPMD deadlock (``SP102``).

Findings:

* ``SP001`` partial-sum escape — an output is ``part``: a psum is missing.
* ``SP002`` redundant psum     — a replicated value was psum'd (``over``),
  or an over-reduced value escapes.
* ``SP003`` wrong replication state — a device-distinct shard was psum'd,
  or a shard escapes from a family whose output must be replicated.
* ``SP004`` sharded-dim gather — indexing into a dimension whose rows live
  on other devices (missing all_gather / rowsharded path).
* ``SP000`` analysis error     — a case/path failed to trace at all.

The exhaustive sweep (``run``/``check_cases``) walks the same
``contracts.iter_cases`` grid as the aval-agreement pass — all seven IR
families × orders 3–5 × local + every distributed variant × every candidate
path — and is exposed online as ``plan_contraction(..., validate_spmd=True)``
via :func:`certify_plan`. ``set_fault`` plants the two seeded defects the
CI tripwires prove the detector catches (``missing-psum``/``double-psum``).
"""
from __future__ import annotations

import dataclasses
import math
import os
from typing import Dict, List, Optional, Sequence, Tuple

from repro.analysis.lint import Finding

REP = ("rep",)
PART = ("part",)
OVER = ("over",)

# the "rows" tag marks a shard whose owning dimension is a GLOBALLY-indexed
# row space split across devices (a rowsharded factor): gathering into it
# with global coordinates is the missing-all_gather bug (SP004). Untagged
# shards are owner-aligned device-local data (the nnz shards of a sparse
# tensor), where intra-shard gathers/permutations are legal local moves.
ROWS = "rows"


def shard(dim: Optional[int] = None, tag: Optional[str] = None) -> Tuple:
    return ("shard", dim) if tag is None else ("shard", dim, tag)


def _shard_tag(v: Tuple) -> Optional[str]:
    return v[2] if len(v) > 2 else None


State = Tuple            # one of REP / PART / OVER / ("shard", d)
AxisStates = Dict[str, State]   # per mesh axis


class SpmdContractError(RuntimeError):
    """A candidate path's collective schedule is unsound (see findings)."""


# deliberate-fault hook (CI tripwire): "missing-psum" turns the AxisCtx
# psums into identity; "double-psum" applies each twice. The sweep MUST
# then fail with SP001 / SP002 respectively — proving the detector fires.
_FAULT: Optional[str] = None

FAULTS = ("missing-psum", "double-psum")


def set_fault(mode: Optional[str]) -> None:
    global _FAULT
    if mode is not None and mode not in FAULTS:
        raise ValueError(f"unknown fault {mode!r}; choose from {FAULTS}")
    _FAULT = mode


class _FaultCtx:
    """Duck-typed AxisCtx wrapper planting a seeded collective bug."""

    def __init__(self, inner, mode: str):
        self._inner, self._mode = inner, mode

    @property
    def data(self):
        return self._inner.data

    @property
    def model(self):
        return self._inner.model

    def data_size(self):
        return self._inner.data_size()

    def model_size(self):
        return self._inner.model_size()

    def model_index(self):
        return self._inner.model_index()

    def _apply(self, psum, x):
        if self._mode == "missing-psum":
            return x
        y = psum(x)
        return psum(y) if self._mode == "double-psum" else y

    def psum_data(self, x):
        return self._apply(self._inner.psum_data, x)

    def psum_model(self, x):
        return self._apply(self._inner.psum_model, x)


def _src(eqn) -> str:
    """Best-effort `file:line` of the traced call site, for messages."""
    try:
        from jax._src import source_info_util
        frame = source_info_util.user_frame(eqn.source_info)
        if frame is not None:
            return f" ({os.path.basename(frame.file_name)}:{frame.start_line})"
    except Exception:
        pass
    return ""


def _axis_names(value) -> Tuple[str, ...]:
    if value is None:
        return ()
    if isinstance(value, (tuple, list)):
        return tuple(v for v in value if isinstance(v, str))
    return (value,) if isinstance(value, str) else ()


# ---------------------------------------------------------------------------
# the abstract interpreter
# ---------------------------------------------------------------------------

class _Interp:
    def __init__(self, axes: Sequence[str], label: str):
        self.axes = tuple(axes)
        self.label = label
        self.findings: List[Finding] = []
        self.notes: List[str] = []

    def _finding(self, rule: str, msg: str, eqn=None) -> None:
        where = _src(eqn) if eqn is not None else ""
        self.findings.append(Finding(
            "spmd", 0, 0, rule, f"[{self.label}] {msg}{where}"))

    def _note(self, msg: str) -> None:
        self.notes.append(f"[{self.label}] {msg}")

    def _rep(self) -> AxisStates:
        return {ax: REP for ax in self.axes}

    # -- jaxpr walk ---------------------------------------------------------
    def run(self, jaxpr, in_states: Sequence[AxisStates],
            const_states: Optional[Sequence[AxisStates]] = None
            ) -> List[AxisStates]:
        import jax
        env: Dict = {}

        def read(atom) -> AxisStates:
            if isinstance(atom, jax.core.Literal):
                return self._rep()
            return env.get(atom, self._rep())

        def write(var, st: AxisStates) -> None:
            env[var] = st

        for cv in jaxpr.constvars:
            write(cv, self._rep())
        if const_states is not None:
            for cv, st in zip(jaxpr.constvars, const_states):
                write(cv, st)
        for iv, st in zip(jaxpr.invars, in_states):
            write(iv, {ax: st.get(ax, REP) for ax in self.axes})

        for eqn in jaxpr.eqns:
            ins = [read(a) for a in eqn.invars]
            for ov, st in zip(eqn.outvars, self._eqn(eqn, ins)):
                write(ov, st)
        return [read(a) for a in jaxpr.outvars]

    # -- one equation -------------------------------------------------------
    def _eqn(self, eqn, ins: List[AxisStates]) -> List[AxisStates]:
        prim = eqn.primitive.name
        if prim in ("psum", "pmax", "pmin", "pmean"):
            return self._psum(eqn, ins)
        if prim == "all_gather":
            return self._all_gather(eqn, ins)
        if prim in ("reduce_scatter", "psum_scatter"):
            return self._psum_scatter(eqn, ins)
        if prim == "ppermute":
            return [dict(ins[0])]
        if prim == "axis_index":
            out = self._rep()
            for ax in _axis_names(eqn.params.get("axis_name")):
                if ax in self.axes:
                    out[ax] = shard(None)
            return [out]
        if prim in ("while", "scan"):
            return self._loop(eqn, ins)
        if prim == "cond":
            return self._cond(eqn, ins)
        sub = self._sub_jaxpr(eqn)
        if sub is not None and len(sub.invars) == len(ins):
            return [dict(s) for s in self.run(sub, ins)]
        if prim == "pallas_call" or sub is not None:
            # opaque body: propagate conservatively, never drop a `part`
            self._note(f"conservative state through `{prim}`")
            return [self._join_all(ins) for _ in eqn.outvars]
        return self._combine(eqn, ins)

    @staticmethod
    def _sub_jaxpr(eqn):
        for key in ("jaxpr", "call_jaxpr", "fun_jaxpr"):
            cj = eqn.params.get(key)
            if cj is None:
                continue
            return cj.jaxpr if hasattr(cj, "jaxpr") else cj
        return None

    def _join_all(self, ins: List[AxisStates]) -> AxisStates:
        out = {}
        for ax in self.axes:
            vals = [s.get(ax, REP) for s in ins]
            if any(v == OVER for v in vals):
                out[ax] = OVER
            elif any(v == PART for v in vals):
                out[ax] = PART
            elif any(v[0] == "shard" for v in vals):
                pairs = {(v[1], _shard_tag(v)) for v in vals
                         if v[0] == "shard"}
                if len(pairs) == 1:
                    d, tag = pairs.pop()
                    out[ax] = shard(d, tag)
                else:
                    out[ax] = shard(None)
            else:
                out[ax] = REP
        return out

    # -- collectives --------------------------------------------------------
    def _psum(self, eqn, ins: List[AxisStates]) -> List[AxisStates]:
        named = [a for a in eqn.params.get("axes", ())
                 if isinstance(a, str)]
        outs = []
        for i, st in enumerate(ins):
            out = dict(st)
            for ax in named:
                if ax not in self.axes:
                    continue
                cur = st.get(ax, REP)
                if cur == PART:
                    out[ax] = REP
                elif cur == OVER:
                    out[ax] = OVER
                elif cur[0] == "shard":
                    self._finding(
                        "SP003",
                        f"psum over axis {ax!r} of a device-distinct "
                        f"sharded value — shards are per-device results, "
                        f"not partial sums; summing them mixes rows",
                        eqn)
                    out[ax] = REP
                else:
                    self._finding(
                        "SP002",
                        f"redundant psum over axis {ax!r}: the operand is "
                        f"already replicated, so the result is "
                        f"axis_size × the intended value", eqn)
                    out[ax] = OVER
            outs.append(out)
        return outs

    def _all_gather(self, eqn, ins: List[AxisStates]) -> List[AxisStates]:
        p = eqn.params
        names = _axis_names(p.get("axis_name"))
        gdim = int(p.get("all_gather_dimension", 0))
        tiled = bool(p.get("tiled", False))
        st = ins[0]
        out: AxisStates = {}
        for ax in self.axes:
            cur = st.get(ax, REP)
            if ax in names:
                out[ax] = PART if cur == PART else (
                    OVER if cur == OVER else REP)
            elif cur[0] == "shard" and cur[1] is not None and not tiled:
                # a new stacked dimension is inserted at gdim
                out[ax] = shard(cur[1] + 1 if cur[1] >= gdim else cur[1])
            else:
                out[ax] = cur
        return [out]

    def _psum_scatter(self, eqn, ins: List[AxisStates]) -> List[AxisStates]:
        p = eqn.params
        names = _axis_names(p.get("axis_name"))
        sdim = int(p.get("scatter_dimension", 0))
        tiled = bool(p.get("tiled", False))
        st = ins[0]
        out: AxisStates = {}
        for ax in self.axes:
            cur = st.get(ax, REP)
            if ax in names:
                if cur == PART:
                    out[ax] = shard(sdim if tiled else None)
                elif cur == REP:
                    self._finding(
                        "SP002",
                        f"psum_scatter over axis {ax!r} of a replicated "
                        f"value — each shard is axis_size × the slice", eqn)
                    out[ax] = OVER
                elif cur[0] == "shard":
                    self._finding(
                        "SP003",
                        f"psum_scatter over axis {ax!r} of a device-"
                        f"distinct shard mixes unrelated rows", eqn)
                    out[ax] = shard(None)
                else:
                    out[ax] = cur
            elif cur[0] == "shard" and cur[1] is not None and not tiled:
                out[ax] = shard(cur[1] - 1 if cur[1] > sdim else
                                (None if cur[1] == sdim else cur[1]))
            else:
                out[ax] = cur
        return [out]

    # -- structured control flow -------------------------------------------
    def _contains_collective(self, jaxpr) -> bool:
        for eqn in jaxpr.eqns:
            if eqn.primitive.name in ("psum", "pmax", "pmin", "pmean",
                                      "all_gather", "reduce_scatter",
                                      "psum_scatter", "all_to_all",
                                      "ppermute"):
                return True
            sub = self._sub_jaxpr(eqn)
            if sub is not None and self._contains_collective(sub):
                return True
            for br in eqn.params.get("branches", ()):
                if self._contains_collective(br.jaxpr):
                    return True
        return False

    @staticmethod
    def _varying(st: AxisStates) -> bool:
        return any(v != REP for v in st.values())

    def _join(self, a: AxisStates, b: AxisStates) -> AxisStates:
        return self._join_all([a, b])

    def _loop(self, eqn, ins: List[AxisStates]) -> List[AxisStates]:
        p = eqn.params
        if eqn.primitive.name == "while":
            cn, bn = p["cond_nconsts"], p["body_nconsts"]
            cond_j, body_j = p["cond_jaxpr"], p["body_jaxpr"]
            cconsts, bconsts = ins[:cn], ins[cn:cn + bn]
            carry = [dict(s) for s in ins[cn + bn:]]
            for _ in range(4):                       # monotone fixpoint
                out = self.run(body_j.jaxpr, list(bconsts) + carry)
                new = [self._join(c, o) for c, o in zip(carry, out)]
                if new == carry:
                    break
                carry = new
            pred = self.run(cond_j.jaxpr, list(cconsts) + carry)
            if (any(self._varying(s) for s in pred)
                    and self._contains_collective(body_j.jaxpr)):
                self._finding(
                    "SP102",
                    "collective inside a while_loop whose continuation "
                    "predicate is device-varying — iteration counts can "
                    "diverge across devices and deadlock the collective",
                    eqn)
            return carry
        # scan: consts + carry + xs; body sees consts + carry + x-slices
        nc, ncar = p["num_consts"], p["num_carry"]
        body = p["jaxpr"]
        consts, carry = ins[:nc], [dict(s) for s in ins[nc:nc + ncar]]
        xs = []
        for s in ins[nc + ncar:]:
            sl = {}
            for ax, v in s.items():
                if v[0] == "shard":
                    # sliced along the scan dim: per-iteration values are
                    # device-distinct (dim identity consumed by the scan)
                    sl[ax] = shard(None) if v[1] in (0, None) else \
                        shard(v[1] - 1)
                else:
                    sl[ax] = v
            xs.append(sl)
        n_y = len(eqn.outvars) - ncar
        ys = [self._rep() for _ in range(n_y)]
        for _ in range(4):
            out = self.run(body.jaxpr, list(consts) + carry + xs)
            new = [self._join(c, o) for c, o in zip(carry, out[:ncar])]
            ys = [self._join(y, o) for y, o in zip(ys, out[ncar:])]
            if new == carry:
                break
            carry = new
        stacked = []
        for y in ys:
            stacked.append({ax: (shard(v[1] + 1) if v[0] == "shard"
                                 and v[1] is not None else v)
                            for ax, v in y.items()})
        return carry + stacked

    def _cond(self, eqn, ins: List[AxisStates]) -> List[AxisStates]:
        branches = eqn.params["branches"]
        pred, rest = ins[0], ins[1:]
        if self._varying(pred) and any(
                self._contains_collective(b.jaxpr) for b in branches):
            self._finding(
                "SP102",
                "collective inside a lax.cond branch selected by a "
                "device-varying predicate — devices take different "
                "branches and the collective deadlocks", eqn)
        outs = None
        for b in branches:
            res = self.run(b.jaxpr, rest)
            outs = res if outs is None else [self._join(a, o)
                                             for a, o in zip(outs, res)]
        return outs if outs is not None else [self._rep()
                                              for _ in eqn.outvars]

    # -- generic data movement ---------------------------------------------
    def _combine(self, eqn, ins: List[AxisStates]) -> List[AxisStates]:
        prim = eqn.primitive.name
        if prim == "gather":
            return self._gather(eqn, ins)
        if prim.startswith("scatter"):
            return self._scatter(eqn, ins)
        outs = []
        for o_i in range(len(eqn.outvars)):
            out: AxisStates = {}
            for ax in self.axes:
                vals = [s.get(ax, REP) for s in ins]
                if any(v == OVER for v in vals):
                    out[ax] = OVER
                elif any(v == PART for v in vals):
                    out[ax] = PART
                elif any(v[0] == "shard" for v in vals):
                    pairs, reduced = set(), False
                    for i, v in enumerate(vals):
                        if v[0] != "shard":
                            continue
                        d = self._map_dim(eqn, i, v[1], o_i)
                        if d == "reduced":
                            reduced = True
                        else:
                            pairs.add((d, _shard_tag(v)))
                    if reduced:
                        out[ax] = PART
                    elif len(pairs) == 1:
                        d, tag = pairs.pop()
                        out[ax] = shard(d, tag)
                    else:
                        out[ax] = shard(None)
                else:
                    out[ax] = REP
            outs.append(out)
        return outs

    def _map_dim(self, eqn, i: int, dim: Optional[int], o_i: int):
        """Where input ``i``'s sharded dimension ``dim`` lands in output
        ``o_i``: a new dim index, ``"reduced"`` (summed away → partial), or
        None (unknown — stays device-distinct with unknown dim)."""
        if dim is None:
            return None
        prim, p = eqn.primitive.name, eqn.params
        in_shape = tuple(getattr(eqn.invars[i].aval, "shape", ()))
        out_shape = tuple(getattr(eqn.outvars[o_i].aval, "shape", ()))
        if prim in ("reduce_sum", "reduce_max", "reduce_min", "reduce_prod",
                    "reduce_and", "reduce_or", "argmax", "argmin"):
            axes = tuple(p.get("axes", ()))
            if dim in axes:
                return "reduced"
            return dim - sum(1 for a in axes if a < dim)
        if prim == "broadcast_in_dim":
            bd = p["broadcast_dimensions"]
            return bd[dim] if dim < len(bd) else None
        if prim == "transpose":
            return list(p["permutation"]).index(dim)
        if prim == "squeeze":
            dims = p["dimensions"]
            if dim in dims:
                return None
            return dim - sum(1 for a in dims if a < dim)
        if prim == "reshape":
            b = math.prod(in_shape[:dim]) if in_shape else 1
            acc = 1
            for j, s in enumerate(out_shape):
                if acc == b and dim < len(in_shape) and s == in_shape[dim]:
                    return j
                acc *= s
            return None
        if prim == "concatenate":
            return None if dim == p["dimension"] else dim
        if prim == "dot_general":
            (lc, rc), (lb, rb) = p["dimension_numbers"]
            lhs_rank = len(getattr(eqn.invars[0].aval, "shape", ()))
            rhs_rank = len(getattr(eqn.invars[1].aval, "shape", ()))
            if i == 0:
                if dim in lc:
                    return "reduced"
                if dim in lb:
                    return list(lb).index(dim)
                free = [d for d in range(lhs_rank)
                        if d not in lc and d not in lb]
                return len(lb) + free.index(dim)
            if i == 1:
                if dim in rc:
                    return "reduced"
                if dim in rb:
                    return list(rb).index(dim)
                free_l = lhs_rank - len(lc) - len(lb)
                free = [d for d in range(rhs_rank)
                        if d not in rc and d not in rb]
                return len(lb) + free_l + free.index(dim)
            return None
        if in_shape == out_shape:
            return dim
        if len(in_shape) == len(out_shape):
            return dim
        return None

    def _gather(self, eqn, ins: List[AxisStates]) -> List[AxisStates]:
        p = eqn.params
        dn = p["dimension_numbers"]
        slice_sizes = tuple(p["slice_sizes"])
        op_shape = tuple(eqn.invars[0].aval.shape)
        idx_shape = tuple(eqn.invars[1].aval.shape)
        out_rank = len(eqn.outvars[0].aval.shape)
        offset = tuple(dn.offset_dims)
        collapsed = tuple(dn.collapsed_slice_dims)
        start_map = tuple(dn.start_index_map)
        batch_out = [k for k in range(out_rank) if k not in offset]
        pass_dims = [d for d in range(len(op_shape)) if d not in collapsed]
        out: AxisStates = {}
        for ax in self.axes:
            op_st = ins[0].get(ax, REP)
            ix_st = ins[1].get(ax, REP)
            if OVER in (op_st, ix_st):
                out[ax] = OVER
                continue
            if PART in (op_st, ix_st):
                out[ax] = PART
                continue
            pairs = set()
            if op_st[0] == "shard":
                d = op_st[1]
                indexed = (d is not None and d in start_map
                           and d < len(slice_sizes)
                           and slice_sizes[d] < op_shape[d])
                if indexed and _shard_tag(op_st) == ROWS:
                    # globally-indexed rows split across devices: each
                    # device resolves global coordinates against its LOCAL
                    # shard — the missing-all_gather bug
                    self._finding(
                        "SP004",
                        f"gather indexes into dimension {d} of a value "
                        f"row-sharded over axis {ax!r} — each device "
                        f"resolves global indices against its local "
                        f"shard; all_gather the operand (or use the "
                        f"rowsharded path) first", eqn)
                    pairs.add((None, None))
                elif indexed:
                    # owner-aligned local gather (sort/permutation within
                    # the device's own nnz shard): device-distinct result
                    pairs.add((None, None))
                elif (d is not None and d in pass_dims
                      and pass_dims.index(d) < len(offset)):
                    pairs.add((offset[pass_dims.index(d)],
                               _shard_tag(op_st)))
                else:
                    pairs.add((None, None))
            if ix_st[0] == "shard":
                d = ix_st[1]
                # the trailing index-vector dim is consumed; others batch
                if (d is not None and d < len(idx_shape) - 1
                        and d < len(batch_out)):
                    pairs.add((batch_out[d], None))
                else:
                    pairs.add((None, None))
            if len(pairs) == 1:
                d, tag = pairs.pop()
                out[ax] = shard(d, tag)
            elif pairs:
                out[ax] = shard(None)
            else:
                out[ax] = REP
        return [out]

    def _scatter(self, eqn, ins: List[AxisStates]) -> List[AxisStates]:
        additive = eqn.primitive.name in ("scatter-add", "scatter-mul")
        dn = eqn.params["dimension_numbers"]
        uw = tuple(dn.update_window_dims)
        iw = tuple(dn.inserted_window_dims)
        op_rank = len(eqn.invars[0].aval.shape)
        window_op_dims = [d for d in range(op_rank) if d not in iw]
        out: AxisStates = {}
        for ax in self.axes:
            op_st = ins[0].get(ax, REP)
            ix_st = ins[1].get(ax, REP)
            up_st = ins[2].get(ax, REP) if len(ins) > 2 else REP
            if any(v == OVER for v in (op_st, ix_st, up_st)):
                out[ax] = OVER
                continue
            if any(v == PART for v in (op_st, ix_st, up_st)):
                out[ax] = PART
                continue
            part, dims = False, set()
            if up_st[0] == "shard":
                d = up_st[1]
                if d is not None and d in uw and uw.index(d) < len(
                        window_op_dims):
                    dims.add(window_op_dims[uw.index(d)])
                else:
                    # device-distinct updates scattered into shared slots:
                    # each device accumulates only its own contributions
                    part = additive
                    if not additive:
                        dims.add(None)
            if ix_st[0] == "shard":
                part = additive
                if not additive:
                    dims.add(None)
            if op_st[0] == "shard":
                dims.add(op_st[1])
            if part:
                out[ax] = PART
            elif dims:
                out[ax] = shard(dims.pop() if len(dims) == 1 else None)
            else:
                out[ax] = REP
        return [out]


# ---------------------------------------------------------------------------
# entry points
# ---------------------------------------------------------------------------

def analyze_jaxpr(closed_jaxpr, in_states: Sequence[AxisStates],
                  axis_sizes: Dict[str, int], label: str = "jaxpr"
                  ) -> Tuple[List[AxisStates], List[Finding], List[str]]:
    """Run the interpreter over a ClosedJaxpr. Returns (output states,
    findings raised during propagation, conservativeness notes)."""
    interp = _Interp(tuple(axis_sizes), label)
    outs = interp.run(closed_jaxpr.jaxpr, list(in_states))
    return outs, interp.findings, interp.notes


def _check_outputs(interp_label: str, out_states: Sequence[AxisStates],
                   allowed_shard_axes: Sequence[str]) -> List[Finding]:
    """Final-state certification: no partial sums or over-reductions may
    escape; shards may escape only over explicitly allowed axes."""
    findings: List[Finding] = []

    def f(rule, msg):
        findings.append(Finding("spmd", 0, 0, rule,
                                f"[{interp_label}] {msg}"))

    for leaf_i, st in enumerate(out_states):
        for ax, v in st.items():
            if v == PART:
                f("SP001", f"partial-sum ESCAPE: output leaf {leaf_i} is "
                           f"an unreduced partial over axis {ax!r} — a "
                           f"psum({ax!r}) is missing")
            elif v == OVER:
                f("SP002", f"output leaf {leaf_i} is over-reduced over "
                           f"axis {ax!r} (a redundant psum upstream)")
            elif v[0] == "shard" and ax not in allowed_shard_axes:
                f("SP003", f"output leaf {leaf_i} is device-distinct over "
                           f"axis {ax!r} but this output must be "
                           f"replicated")
    return findings


def analyze_fn(fn, args: Sequence, in_states: Sequence[AxisStates],
               axis_env: Sequence[Tuple[str, int]],
               expected: Optional[Dict[str, object]] = None,
               label: str = "fn") -> List[Finding]:
    """Fixture/unit entry: trace ``fn(*args)`` under ``axis_env`` and
    certify its outputs. ``in_states`` align with the positional args;
    ``expected`` maps each axis to ``"rep"`` (shards escaping are SP003) or
    ``"shard"`` (device-distinct outputs are legal)."""
    import jax
    env = [tuple(a) for a in axis_env] or None
    try:
        closed = jax.make_jaxpr(fn, axis_env=env)(*args)
    except Exception as e:
        return [Finding("spmd", 0, 0, "SP000",
                        f"[{label}] failed to trace: "
                        f"{type(e).__name__}: {e}")]
    sizes = dict(axis_env)
    outs, findings, _ = analyze_jaxpr(closed, in_states, sizes, label)
    expected = expected or {}
    allowed = [ax for ax in sizes
               if str(expected.get(ax, "shard")).startswith("shard")]
    return findings + _check_outputs(label, outs, allowed)


# ---------------------------------------------------------------------------
# the planner-IR sweep
# ---------------------------------------------------------------------------

def _dedupe(denses: Sequence) -> Tuple[List, List[int]]:
    uniq: List = []
    posmap: List[int] = []
    for d in denses:
        for k, u in enumerate(uniq):
            if d is u:
                posmap.append(k)
                break
        else:
            posmap.append(len(uniq))
            uniq.append(d)
    return uniq, posmap


def _operand_states(axes: Sequence[str], data_axes: Sequence[str],
                    model_axes: Sequence[str], rowsharded: bool,
                    n_dense: int) -> Tuple[List[AxisStates],
                                           List[AxisStates]]:
    """(sparse-leaf states [values, indices, valid], per-dense states).

    Data axes shard the nonzeros (every sparse leaf is row-sharded along
    its leading nnz dim); factor rows are additionally sharded when
    ``rowsharded``. Model axes shard factor COLUMNS (dim 1) while the
    sparse leaves are replicated (the local arrays hold local rank)."""
    sp = {ax: REP for ax in axes}
    dn = {ax: REP for ax in axes}
    for ax in data_axes:
        sp[ax] = shard(0)
        # rowsharded factors are GLOBALLY-indexed row spaces split across
        # devices (the ROWS tag arms the SP004 gather check); the sparse
        # leaves are owner-aligned nnz shards, untagged
        dn[ax] = shard(0, ROWS) if rowsharded else REP
    for ax in model_axes:
        dn[ax] = shard(1)
    sparse_states = [dict(sp) for _ in range(3)]
    return sparse_states, [dict(dn) for _ in range(n_dense)]


def _allowed_shard_axes(family: str, path: str,
                        data_axes: Sequence[str],
                        model_axes: Sequence[str]) -> List[str]:
    """Mesh axes over which a device-distinct OUTPUT is legal for this
    family: TTTP outputs ride the data-sharded nonzeros; the rowsharded
    MTTKRP's reduce-scatter leaves row-ownership on the data axes; MTTKRP/
    CG outputs stay column-sharded under model parallelism (the caller
    all-gathers or keeps rank-local factors)."""
    allowed: List[str] = []
    if family == "tttp" or path == "rowsharded":
        allowed += list(data_axes)
    if family in ("mttkrp", "mttkrp_partial", "cg_matvec", "ttm"):
        allowed += list(model_axes)
    return allowed


def _trace_execution(ir, path: str, st, denses: Sequence, ctx, config,
                     axis_env: Sequence[Tuple[str, int]]):
    """make_jaxpr of one (IR, path) execution with the sparse tensor's
    values/indices/valid AND the dense operands as jaxpr inputs — so every
    operand carries its replication state into the interpreter (unlike the
    contracts sweep, which closes over concrete indices)."""
    import jax

    from repro.core.sparse_tensor import SparseTensor
    from repro.planner import dispatch as pdispatch

    run_ctx = _FaultCtx(ctx, _FAULT) if _FAULT is not None else ctx
    uniq, posmap = _dedupe(denses)

    def aval(a):
        return jax.ShapeDtypeStruct(tuple(a.shape), a.dtype)

    if st is None:
        def f(*args):
            return pdispatch.execute(ir, path, list(args), ctx=run_ctx,
                                     config=config)
        args = tuple(aval(d) for d in uniq)
    else:
        def f(values, indices, valid, *uds):
            st2 = SparseTensor(indices, values, valid, st.shape, st.nnz,
                               st.sorted_mode, st.nnz_rows)
            ops: List = [None] * len(ir.operands)
            ops[ir.sparse_pos] = st2
            for pos, k in zip(ir.dense_positions, posmap):
                ops[pos] = uds[k]
            return pdispatch.execute(ir, path, ops, ctx=run_ctx,
                                     config=config)
        args = (aval(st.values), aval(st.indices),
                aval(st.valid)) + tuple(aval(d) for d in uniq)

    env = [tuple(a) for a in axis_env] or None
    try:
        closed = jax.make_jaxpr(f, axis_env=env)(*args)
    except Exception:
        if env is None:
            raise
        # ambient axis frames (inside shard_map) already bind the names
        closed = jax.make_jaxpr(f)(*args)
    return closed, posmap


def _analyze_execution(ir, path: str, st, denses: Sequence, ctx, config,
                       axis_env: Sequence[Tuple[str, int]], family: str,
                       rowsharded: bool, label: str) -> List[Finding]:
    try:
        closed, posmap = _trace_execution(ir, path, st, denses, ctx,
                                          config, axis_env)
    except Exception as e:
        return [Finding("spmd", 0, 0, "SP000",
                        f"[{label}] failed to trace: "
                        f"{type(e).__name__}: {e}")]
    sizes = dict(axis_env)
    axes = tuple(sizes)
    data_axes = tuple(ax for ax in _axis_names(ctx.data) if ax in axes)
    model_axes = tuple(ax for ax in _axis_names(ctx.model) if ax in axes)
    sp_states, base_dense = _operand_states(axes, data_axes, model_axes,
                                            rowsharded, len(denses))
    uniq_states = {}
    for k, s in zip(posmap, base_dense):
        uniq_states.setdefault(k, s)
    dense_states = [uniq_states[k] for k in sorted(uniq_states)]
    in_states = (dense_states if st is None
                 else sp_states + dense_states)
    outs, findings, _ = analyze_jaxpr(closed, in_states, sizes, label)
    allowed = _allowed_shard_axes(family, path, data_axes, model_axes)
    return findings + _check_outputs(label, outs, allowed)


def check_cases(cases=None, orders: Sequence[int] = (3, 4, 5)
                ) -> List[Finding]:
    """The exhaustive sweep: every candidate path of every
    ``contracts.iter_cases`` grid point, certified for collective
    soundness. Pallas dispatch is forced OFF during tracing so the jaxprs
    contain the jnp reference paths the interpreter models (the Pallas
    kernels compute identically and are certified separately by the VMEM
    pass)."""
    from repro.analysis import contracts
    from repro.planner import cost as pcost

    if cases is None:
        cases = contracts.iter_cases(orders)
    findings: List[Finding] = []
    old = os.environ.get("REPRO_USE_PALLAS")
    os.environ["REPRO_USE_PALLAS"] = "0"
    try:
        for case in cases:
            rowsh = (case.ir.dist.rowsharded
                     if case.ir.dist is not None else False)
            for path in pcost.candidate_paths(case.ir):
                findings += _analyze_execution(
                    case.ir, path, case.st, case.denses, case.ctx,
                    case.config, case.axis_env, case.family, rowsh,
                    label=f"{case.name}/{path}")
    finally:
        if old is None:
            os.environ.pop("REPRO_USE_PALLAS", None)
        else:
            os.environ["REPRO_USE_PALLAS"] = old
    return findings


def run(orders: Sequence[int] = (3, 4, 5)) -> List[Finding]:
    return check_cases(orders=orders)


# ---------------------------------------------------------------------------
# online certification (plan_contraction(..., validate_spmd=True))
# ---------------------------------------------------------------------------

def _family_tag(ir) -> str:
    from repro.planner import ir as pir
    if ir.kind == pir.TTTP:
        return "tttp"
    if ir.kind == pir.REDUCE:
        return "reduce"
    if ir.kind == pir.TTM:
        return "ttm"
    if ir.kind == pir.MTTKRP:
        return "mttkrp" if pir.is_classic_mttkrp(ir) else "mttkrp_partial"
    if ir.kind == pir.CG_MATVEC:
        return "cg_matvec"
    return "dense"


def certify_plan(ir, paths: Sequence[str], operands: Sequence, ctx,
                 config) -> None:
    """Raise :class:`SpmdContractError` unless every candidate path of this
    concrete call is collective-sound: no partial-sum escapes, no redundant
    or wrong-axis psums, no gathers into sharded dimensions. Called by
    ``plan_contraction(..., validate_spmd=True)``; safe under tracing
    (only operand avals are consulted)."""
    dist = ir.dist
    if dist is None:
        axis_env: List[Tuple[str, int]] = []
    else:
        axis_env = []
        data_names = _axis_names(ctx.data)
        model_names = _axis_names(ctx.model)
        if data_names:
            per = max(1, round(dist.data_size ** (1 / len(data_names)))) \
                if len(data_names) > 1 else dist.data_size
            axis_env += [(n, per) for n in data_names]
        if model_names:
            axis_env += [(n, dist.model_size) for n in model_names]
    if not axis_env:
        return  # local: no mesh axes, nothing to certify
    st = operands[ir.sparse_pos] if ir.sparse_pos is not None else None
    denses = [operands[i] for i in ir.dense_positions]
    family = _family_tag(ir)
    findings: List[Finding] = []
    for path in paths:
        findings += _analyze_execution(
            ir, path, st, denses, ctx, config, axis_env, family,
            dist.rowsharded, label=f"{ir.expr}/{path}")
    if findings:
        detail = "\n".join(f.format() for f in findings)
        raise SpmdContractError(
            f"SPMD certification failed for {ir.expr!r} — the plan's "
            f"collective schedule is unsound:\n{detail}")
