"""``python -m repro.analysis.spmd`` / ``repro-spmd`` — the SPMD
collective-soundness CLI (DESIGN.md §15).

Runs any combination of the three passes and exits nonzero when any
unsuppressed finding survives:

* ``--sharding``     replay every candidate path of all seven planner
  families (orders 3–5, local + distributed) through the replication-state
  interpreter; partial-sum escapes / redundant psums / wrong-axis psums /
  sharded-dim gathers are findings (SP001–SP004)
* ``--collectives``  AST collective-matching lint over the shard_map-
  executing layers: branch-divergent sequences, collectives under traced
  conditionals, hardcoded axis names (SP101–SP103, suppressible with a
  reason; stale SP suppressions surface as JS006)
* ``--vmem``         certify every tuner lattice candidate against the
  device VMEM budget (SP201); ``--paper-scale`` opts into the paper-extent
  geometries whose expected over-budget findings scope the DMA-streaming
  follow-up
* ``--all``          everything above (the blocking CI configuration)

``--fault missing-psum|double-psum`` plants a collective bug in the sharding
sweep (CI tripwire: the run must then fail). ``--fixture PATH --expect
RULE`` analyzes one seeded-bug fixture and exits 0 iff exactly that rule is
reported — the detectors' proof-they-fire harness.
"""
from __future__ import annotations

import argparse
import importlib.util
import os
import sys
from typing import List

from repro.analysis.cli import _repo_root


def _load_fixture(path: str):
    spec = importlib.util.spec_from_file_location(
        "spmd_fixture_" + os.path.splitext(os.path.basename(path))[0], path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def check_fixture(path: str) -> List:
    """Analyze one fixture with the detector its declarations select:
    ``run``+``IN_STATES`` → sharding; ``FAMILY``+``TILE`` → vmem; anything
    else → the collectives AST lint on the file itself."""
    from repro.analysis.spmd import collectives as ccheck
    from repro.analysis.spmd import sharding, vmem

    if path.endswith(".py"):
        mod = _load_fixture(path)
        if hasattr(mod, "run") and hasattr(mod, "IN_STATES"):
            return sharding.analyze_fn(
                mod.run, mod.ARGS, mod.IN_STATES, mod.AXIS_ENV,
                expected=getattr(mod, "EXPECTED", None),
                label=os.path.basename(path))
        if hasattr(mod, "FAMILY") and hasattr(mod, "TILE"):
            return vmem.check_fixture(mod)
    return [f for f in ccheck.lint_file(path) if not f.suppressed]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro-spmd",
        description="SPMD collective-soundness analyzer: sharding "
                    "propagation, collective matching, VMEM certification")
    ap.add_argument("--all", action="store_true",
                    help="run every pass (CI configuration)")
    ap.add_argument("--sharding", action="store_true")
    ap.add_argument("--collectives", action="store_true")
    ap.add_argument("--vmem", action="store_true")
    ap.add_argument("--root", default=".",
                    help="repo root (default: auto-detect from cwd)")
    ap.add_argument("--orders", default="3,4,5",
                    help="tensor orders for the sharding sweep")
    ap.add_argument("--fault", default=None,
                    choices=["missing-psum", "double-psum"],
                    help="plant a collective bug in the sharding sweep "
                         "(self-test: the sweep must then fail)")
    ap.add_argument("--budget-mb", type=float, default=None,
                    help="VMEM budget override in MiB for --vmem")
    ap.add_argument("--paper-scale", action="store_true",
                    help="certify --vmem against paper-extent geometries "
                         "(over-budget findings expected; non-CI)")
    ap.add_argument("--fixture", default=None, metavar="PATH",
                    help="analyze one seeded-bug fixture file")
    ap.add_argument("--expect", default=None, metavar="RULE",
                    help="with --fixture: exit 0 iff exactly this rule "
                         "is reported")
    ap.add_argument("--show-suppressed", action="store_true",
                    help="also print suppressed findings")
    ap.add_argument("--strict-suppressions", action="store_true",
                    help="advisory findings (stale suppressions) become "
                         "errors (CI configuration)")
    args = ap.parse_args(argv)

    if args.fixture is not None:
        findings = check_fixture(args.fixture)
        for f in findings:
            print(f.format())
        rules = {f.rule for f in findings}
        if args.expect is not None:
            ok = rules == {args.expect}
            print(f"[fixture] {args.fixture}: reported {sorted(rules)}, "
                  f"expected exactly {{{args.expect!r}}}: "
                  f"{'OK' if ok else 'FAILED'}")
            return 0 if ok else 1
        return 0 if not findings else 1

    if args.all:
        args.sharding = args.collectives = args.vmem = True
    if not (args.sharding or args.collectives or args.vmem):
        ap.error("nothing to do: pass --all or at least one pass flag")

    root = _repo_root(args.root)
    failures = 0

    def report(pass_name: str, findings: List) -> None:
        nonlocal failures
        blocking, advisory, suppressed = [], [], []
        for f in findings:
            if f.suppressed:
                suppressed.append(f)
            elif f.advisory and not args.strict_suppressions:
                advisory.append(f)
            else:
                blocking.append(f)
        for f in blocking:
            print(f.format())
        for f in advisory:
            print("warning: " + f.format())
        if args.show_suppressed:
            for f in suppressed:
                print(f.format())
        failures += len(blocking)
        notes = []
        if advisory:
            notes.append(f"{len(advisory)} advisory")
        if suppressed:
            notes.append(f"{len(suppressed)} suppressed")
        note = (", " + ", ".join(notes)) if notes else ""
        print(f"[{pass_name}] {len(blocking)} finding(s){note}")

    if args.sharding:
        from repro.analysis.spmd import sharding
        orders = tuple(int(o) for o in args.orders.split(","))
        sharding.set_fault(args.fault)
        try:
            report("sharding", sharding.run(orders))
        finally:
            sharding.set_fault(None)

    if args.collectives:
        from repro.analysis.spmd import collectives
        report("collectives", collectives.run(root))

    if args.vmem:
        from repro.analysis.spmd import vmem
        report("vmem", vmem.run(budget_mb=args.budget_mb,
                                paper_scale=args.paper_scale))

    print("OK" if failures == 0 else f"FAILED: {failures} finding(s)")
    return 0 if failures == 0 else 1


if __name__ == "__main__":
    sys.exit(main())
