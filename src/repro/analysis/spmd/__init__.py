"""SPMD collective-soundness analyzer (DESIGN.md §15).

Three coordinated static passes over the distributed execution stack:

* :mod:`repro.analysis.spmd.sharding` — replication-state propagation over
  the planner IR (abstract interpretation of the traced jaxprs; exposed
  online as ``plan_contraction(..., validate_spmd=True)``);
* :mod:`repro.analysis.spmd.collectives` — AST collective-matching lint of
  the shard_map-executing layers (deadlock shapes, axis-name hygiene);
* :mod:`repro.analysis.spmd.vmem` — static VMEM certification of the tuner
  tile lattices (the model also backs the tuner's online pruning).

CLI: ``python -m repro.analysis.spmd`` / ``repro-spmd``.
"""
from repro.analysis.spmd.cli import main

__all__ = ["main"]
