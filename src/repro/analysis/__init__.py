"""Static-analysis subsystem (DESIGN.md §12): jit-safety linter, planner
contract checker, pytree/static-arg hygiene, and an import-graph dead-code
report, behind one CLI (``python -m repro.analysis`` / ``repro-lint``).

The passes are imported lazily by the CLI — importing this package must stay
cheap (it is a dead-code analysis root and a console entry point).
"""
from repro.analysis.cli import main

__all__ = ["main"]
