"""``python -m repro.analysis`` / ``repro-lint`` — the static-analysis CLI.

Runs any combination of the four passes (DESIGN.md §12) and exits nonzero
when any unsuppressed finding survives:

* ``--lint``      jit-safety linter over ``src/repro`` + ``benchmarks``
* ``--contracts`` planner contract sweep (all 7 IR families × candidate
  paths × local/distributed, cost invariants, cache-key hygiene)
* ``--pytrees``   registered-pytree aux hygiene + static-arg aliasing
* ``--deadcode``  import-graph reachability report (unreachable modules
  are findings; test-only modules are reported but do not fail the run)
* ``--all``       everything above (the blocking CI configuration)

``--corrupt PATH`` / ``--pytree-module MOD`` are the deliberate-fault hooks:
CI's tripwire test uses them to prove a corrupted candidate path or a
corrupted pytree aux actually fails the run (ISSUE acceptance criterion).
"""
from __future__ import annotations

import argparse
import os
import sys
from typing import List


def _repo_root(start: str) -> str:
    """Nearest ancestor containing ``src/repro`` (supports running from
    anywhere inside the repo)."""
    cur = os.path.abspath(start)
    while True:
        if os.path.isdir(os.path.join(cur, "src", "repro")):
            return cur
        parent = os.path.dirname(cur)
        if parent == cur:  # fell off the filesystem: fall back to cwd
            return os.path.abspath(start)
        cur = parent


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro-lint",
        description="static analysis for the repro tensor-completion stack")
    ap.add_argument("--all", action="store_true",
                    help="run every pass (CI configuration)")
    ap.add_argument("--lint", action="store_true")
    ap.add_argument("--contracts", action="store_true")
    ap.add_argument("--pytrees", action="store_true")
    ap.add_argument("--deadcode", action="store_true")
    ap.add_argument("--root", default=".",
                    help="repo root (default: auto-detect from cwd)")
    ap.add_argument("--orders", default="3,4,5",
                    help="tensor orders for the contract sweep")
    ap.add_argument("--corrupt", default=None, metavar="PATH",
                    help="deliberately corrupt this candidate path's avals "
                         "(self-test: the sweep must then fail)")
    ap.add_argument("--pytree-module", default=None, metavar="MOD",
                    help="extra importable module exposing PYTREE_EXEMPLARS")
    ap.add_argument("--show-suppressed", action="store_true",
                    help="also print suppressed lint findings")
    ap.add_argument("--strict-suppressions", action="store_true",
                    help="advisory findings (JS006 stale suppressions) "
                         "become errors (CI configuration)")
    args = ap.parse_args(argv)

    if args.all:
        args.lint = args.contracts = args.pytrees = args.deadcode = True
    if not (args.lint or args.contracts or args.pytrees or args.deadcode):
        ap.error("nothing to do: pass --all or at least one pass flag")

    root = _repo_root(args.root)
    failures = 0

    def report(pass_name: str, findings: List) -> None:
        nonlocal failures
        blocking, advisory, suppressed = [], [], []
        for f in findings:
            if f.suppressed:
                suppressed.append(f)
            elif (getattr(f, "advisory", False)
                  and not args.strict_suppressions):
                advisory.append(f)
            else:
                blocking.append(f)
        for f in blocking:
            print(f.format())
        for f in advisory:
            print("warning: " + f.format())
        if args.show_suppressed:
            for f in suppressed:
                print(f.format())
        failures += len(blocking)
        notes = []
        if advisory:
            notes.append(f"{len(advisory)} advisory")
        if suppressed:
            notes.append(f"{len(suppressed)} suppressed")
        note = (", " + ", ".join(notes)) if notes else ""
        print(f"[{pass_name}] {len(blocking)} finding(s){note}")

    if args.lint:
        from repro.analysis import lint
        targets = [os.path.join(root, "src", "repro"),
                   os.path.join(root, "benchmarks")]
        report("lint", lint.lint_paths([t for t in targets
                                        if os.path.exists(t)]))

    if args.contracts:
        from repro.analysis import contracts
        orders = tuple(int(o) for o in args.orders.split(","))
        contracts.set_corrupt(args.corrupt)
        try:
            report("contracts", contracts.run(orders))
        finally:
            contracts.set_corrupt(None)

    if args.pytrees:
        from repro.analysis import pytree_check
        report("pytrees", pytree_check.run(root, args.pytree_module))

    if args.deadcode:
        from repro.analysis import deadcode
        from repro.analysis.lint import Finding
        rep = deadcode.analyze(root)
        print(rep.format())
        report("deadcode", [
            Finding("imports", 0, 0, "DC001",
                    f"module {m} is unreachable from product, benchmark, "
                    f"and test roots — delete it or wire it in")
            for m in sorted(rep.unreachable)])

    print("OK" if failures == 0 else f"FAILED: {failures} finding(s)")
    return 0 if failures == 0 else 1


if __name__ == "__main__":
    sys.exit(main())
