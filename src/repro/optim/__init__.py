from repro.optim.compression import compressed_psum, ef_state_init

__all__ = ["compressed_psum", "ef_state_init"]
