from repro.optim.adamw import AdamWState, adamw_init, adamw_update
from repro.optim.schedule import cosine_warmup
from repro.optim.compression import (compressed_psum, ef_state_init)

__all__ = ["AdamWState", "adamw_init", "adamw_update", "cosine_warmup",
           "compressed_psum", "ef_state_init"]
