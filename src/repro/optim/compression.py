"""Gradient compression for bandwidth-bound all-reduces.

Error-feedback int8 quantized psum: shards agree on a global scale (scalar
pmax), quantize (grad + error-feedback) to int8, psum the integer payload
(4× fewer wire bytes than f32, 2× vs bf16), and dequantize exactly with the
shared scale. The local quantization error is carried to the next step
(EF-SGD), preserving convergence. Used for SGD/GCP completion gradients and
available to the LM driver for DP gradient reduction."""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def ef_state_init(grads_like) -> Any:
    return jax.tree.map(lambda g: jnp.zeros_like(g, jnp.float32), grads_like)


def compressed_psum(grad: jax.Array, err: jax.Array, axis_name
                    ) -> Tuple[jax.Array, jax.Array]:
    """Error-feedback int8 psum of one tensor over ``axis_name``.

    Returns (all-reduced grad, new error-feedback state)."""
    comp = grad.astype(jnp.float32) + err
    # shared scale => psum of int8 payloads dequantizes exactly
    scale = jax.lax.pmax(jnp.max(jnp.abs(comp)), axis_name) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(comp / scale), -127, 127).astype(jnp.int8)
    new_err = comp - q.astype(jnp.float32) * scale
    summed = jax.lax.psum(q.astype(jnp.int32), axis_name)
    return summed.astype(jnp.float32) * scale, new_err


def compressed_psum_tree(grads, err_tree, axis_name):
    flat_g, tree = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(err_tree)
    outs, errs = [], []
    for g, e in zip(flat_g, flat_e):
        o, ne = compressed_psum(g, e, axis_name)
        outs.append(o)
        errs.append(ne)
    return jax.tree.unflatten(tree, outs), jax.tree.unflatten(tree, errs)
