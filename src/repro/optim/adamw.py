"""AdamW, hand-rolled (no optax in this container), pytree-generic.

Optimizer state shards like the parameters (the caller's shardings flow
through pjit); used by both the LM train driver and as an option for the
generalized-loss completion path."""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    mu: Any
    nu: Any
    count: jax.Array


def adamw_init(params) -> AdamWState:
    zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
    return AdamWState(jax.tree.map(zeros, params),
                      jax.tree.map(zeros, params),
                      jnp.zeros((), jnp.int32))


def adamw_update(grads, state: AdamWState, params, lr,
                 b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
                 weight_decay: float = 0.1):
    count = state.count + 1
    mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
                      state.mu, grads)
    nu = jax.tree.map(lambda v, g: b2 * v +
                      (1 - b2) * jnp.square(g.astype(jnp.float32)),
                      state.nu, grads)
    bc1 = 1 - b1 ** count
    bc2 = 1 - b2 ** count

    def upd(p, m, v):
        step = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
        return (p.astype(jnp.float32) - lr * (step + weight_decay * p)
                ).astype(p.dtype)

    new_params = jax.tree.map(upd, params, mu, nu)
    return new_params, AdamWState(mu, nu, count)
