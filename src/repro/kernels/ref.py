"""Pure-jnp oracles for the Pallas kernels. Every kernel in this package is
validated against these references (tests/test_kernels.py sweeps shapes and
dtypes)."""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp


def tttp_ref(values: jax.Array, indices: jax.Array,
             factors: Sequence[Optional[jax.Array]]) -> jax.Array:
    """x_n = values_n · Σ_r Π_j factors[j][indices[n, j], r]."""
    prod = None
    for d, f in enumerate(factors):
        if f is None:
            continue
        rows = f[indices[:, d]]
        prod = rows if prod is None else prod * rows
    return values * jnp.sum(prod, axis=1)


def mttkrp_bucketed_ref(bvalues: jax.Array, bindices: jax.Array,
                        blocal: jax.Array,
                        factors: Sequence[Optional[jax.Array]],
                        mode: int, block_rows: int) -> jax.Array:
    """Bucketed MTTKRP oracle.

    Inputs are RowBlockBuckets fields: (nb, C) values, (nb, C, nd) indices,
    (nb, C) local rows for ``mode``. Output (nb*block_rows, R)."""
    nb, c = bvalues.shape
    r = next(f.shape[1] for f in factors if f is not None)
    prod = jnp.broadcast_to(bvalues[..., None], (nb, c, r))
    for d, f in enumerate(factors):
        if f is None or d == mode:
            continue
        prod = prod * f[bindices[:, :, d]]
    # scatter within each block by local row
    seg = blocal + jnp.arange(nb)[:, None] * block_rows
    out = jax.ops.segment_sum(prod.reshape(nb * c, r), seg.reshape(-1),
                              num_segments=nb * block_rows)
    return out


def cg_matvec_bucketed_ref(bomega: jax.Array, bindices: jax.Array,
                           blocal: jax.Array,
                           factors: Sequence[Optional[jax.Array]],
                           x: jax.Array, mode: int,
                           block_rows: int) -> jax.Array:
    """Fused implicit-CG Gram matvec oracle (paper eq. 3, one pass):

        z_n = ω_n Σ_s (Π_{d≠mode} A_d[i_d, s]) x[i_mode, s]
        y[i, r] = Σ_{n in rows(i)} z_n Π_{d≠mode} A_d[i_d, r]

    Output (nb*block_rows, R) — caller slices to the true row count."""
    nb, c = bomega.shape
    r = x.shape[1]
    kr = jnp.ones((nb, c, r), x.dtype)
    for d, f in enumerate(factors):
        if f is None or d == mode:
            continue
        kr = kr * f[bindices[:, :, d]]
    xrows = x[bindices[:, :, mode]]                      # (nb, C, R)
    z = bomega * jnp.sum(kr * xrows, axis=-1)            # (nb, C)
    contrib = z[..., None] * kr                          # (nb, C, R)
    seg = blocal + jnp.arange(nb)[:, None] * block_rows
    return jax.ops.segment_sum(contrib.reshape(nb * c, r), seg.reshape(-1),
                               num_segments=nb * block_rows)
