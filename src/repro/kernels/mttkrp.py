"""Pallas TPU kernel for bucketed all-at-once MTTKRP (tiled tier).

The scatter-add of MTTKRP is the part with no TPU-native analogue (the paper
uses CPU dense-buffer row accumulation). Our adaptation (DESIGN.md §3, §13):
the ingest-time CCSR bucketing (``repro.sparse.ccsr.bucketize``) groups
sorted nonzeros into fixed-capacity buckets spanning ``block_rows``
consecutive output rows, and the in-bucket scatter runs as either the
one-hot ``(block_rows × C) @ (C × block_r)`` MXU matmul or the segmented
cumsum reduction — chosen per :class:`~repro.kernels.tile.KernelTile`
(``schedule='auto'`` resolves by the break-even point).

Grid: (num_buckets / buckets_per_step, R blocks). Each step processes
``buckets_per_step`` buckets; within each bucket a ``fori_loop`` walks the
capacity in ``block_m`` tiles, so VMEM transients are Θ(block_m·block_r)
regardless of bucket capacity:

  1. gather factor rows for the tile's nonzeros (VPU),
  2. Hadamard-product with values in the input dtype (bf16 stays bf16),
  3. scatter into a (block_rows, block_r) accumulator in ``accum_dtype``
     (fp32 MXU accumulation for bf16 inputs).

Padding slots (``valid == False``) carry ``local_row == 0`` at the bucket
tail, which would break both schedules' key assumptions — the kernel scatter
key is ``where(valid, local_row, block_rows)``: monotone for the segmented
prefix trick, and matching no output row in the one-hot comparison.
"""
from __future__ import annotations

import functools
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.utils import pad_axis, round_up
from repro.kernels.tile import KernelTile, scatter_rows
from repro.sparse.ccsr import RowBlockBuckets


def _mttkrp_kernel(other_slots, block_rows, block_m, num_tiles, g, schedule,
                   acc_dtype, vals_ref, idx_ref, key_ref, *refs):
    factor_refs, out_ref = refs[:-1], refs[-1]
    block_r = out_ref.shape[-1]
    for gi in range(g):                      # static unroll over buckets

        def tile_body(t, acc, gi=gi):
            sl = pl.dslice(t * block_m, block_m)
            vals = vals_ref[gi, sl]          # (block_m,)
            idx = idx_ref[gi, sl, :]         # (block_m, nd)
            key = key_ref[gi, sl]            # (block_m,)
            prod = None
            for slot, f_ref in zip(other_slots, factor_refs):
                rows = jnp.take(f_ref[...], idx[:, slot], axis=0)
                prod = rows if prod is None else prod * rows
            prod = prod * vals[:, None]      # (block_m, block_r), input dtype
            return acc + scatter_rows(prod, key, block_rows, schedule,
                                      acc_dtype)

        acc = jax.lax.fori_loop(
            0, num_tiles, tile_body,
            jnp.zeros((block_rows, block_r), acc_dtype))
        out_ref[gi * block_rows:(gi + 1) * block_rows, :] = acc


def _pad_buckets(values, indices, key, block_m, g, fill_key):
    """Pad the capacity axis to a block_m multiple and the bucket axis to a
    buckets_per_step multiple; padding slots get value 0 / index 0 / key
    ``fill_key`` (past the valid local-row range)."""
    nb, c = values.shape
    cp, nbp = round_up(c, block_m), round_up(nb, g)
    if cp != c:
        values = pad_axis(values, cp, axis=1)
        indices = pad_axis(indices, cp, axis=1)
        key = pad_axis(key, cp, axis=1, value=fill_key)
    if nbp != nb:
        values = pad_axis(values, nbp, axis=0)
        indices = pad_axis(indices, nbp, axis=0)
        key = pad_axis(key, nbp, axis=0, value=fill_key)
    return values, indices, key, nbp, cp


def mttkrp_pallas(buckets: RowBlockBuckets,
                  factors: Sequence[Optional[jax.Array]],
                  block_r: Optional[int] = None,
                  tile: Optional[KernelTile] = None,
                  interpret: bool = True) -> jax.Array:
    """Bucketed MTTKRP. Returns (padded rows, R) in ``tile.accum_dtype``;
    callers slice to ``shape[mode]`` rows and cast. R must be a multiple of
    the resolved ``block_r`` (ops.py pads); capacity and bucket-count
    padding happen here."""
    tile = tile if tile is not None else KernelTile()
    nd = buckets.indices.shape[-1]
    mode = buckets.mode
    block_rows = buckets.block_rows
    other = tuple(d for d in range(nd) if d != mode and factors[d] is not None)
    fs = [factors[d] for d in other]
    r = fs[0].shape[1]
    block_r = min(block_r if block_r is not None else tile.block_r, r)
    if r % block_r:
        raise ValueError(f"R={r} % block_r={block_r} nonzero; pad first")
    c = buckets.values.shape[1]
    block_m = min(tile.block_m, round_up(c, 8))
    g = tile.buckets_per_step
    schedule = tile.resolved_schedule(block_rows, block_m)
    key = jnp.where(buckets.valid, buckets.local_row,
                    jnp.int32(block_rows)).astype(jnp.int32)
    values, indices, key, nbp, cp = _pad_buckets(
        buckets.values, buckets.indices, key, block_m, g, block_rows)
    grid = (nbp // g, r // block_r)
    in_specs = [
        pl.BlockSpec((g, cp), lambda b, j: (b, 0)),
        pl.BlockSpec((g, cp, nd), lambda b, j: (b, 0, 0)),
        pl.BlockSpec((g, cp), lambda b, j: (b, 0)),
    ] + [
        pl.BlockSpec((f.shape[0], block_r), lambda b, j: (0, j)) for f in fs
    ]
    kernel = functools.partial(_mttkrp_kernel, other, block_rows, block_m,
                               cp // block_m, g, schedule, tile.acc)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((g * block_rows, block_r),
                               lambda b, j: (b, j)),
        out_shape=jax.ShapeDtypeStruct((nbp * block_rows, r), tile.acc),
        interpret=interpret,
    )(values, indices, key, *fs)
    return out
