"""Pallas TPU kernel for bucketed all-at-once MTTKRP.

The scatter-add of MTTKRP is the part with no TPU-native analogue (the paper
uses CPU dense-buffer row accumulation). Our adaptation (DESIGN.md §3): the
ingest-time CCSR bucketing (``repro.sparse.ccsr.bucketize``) groups sorted
nonzeros into fixed-capacity buckets spanning ``block_rows`` consecutive
output rows, and the in-bucket scatter becomes a one-hot
``(block_rows × capacity) @ (capacity × block_r)`` matmul on the MXU.

Grid: (num_buckets, R blocks). Each step:
  1. gather factor rows for the bucket's nonzeros (VPU),
  2. Hadamard-product with values (VPU),
  3. one-hot segment matmul into the (block_rows, block_r) output tile (MXU).

Trade-off: the one-hot matmul performs block_rows× more MACs than a scalar
scatter would, but runs at MXU rate; for block_rows ≤ 256 this is the winning
schedule on TPU (see EXPERIMENTS.md §Perf for the napkin math).
"""
from __future__ import annotations

import functools
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.sparse.ccsr import RowBlockBuckets


def _mttkrp_kernel(other_slots, block_rows,
                   vals_ref, idx_ref, local_ref, *refs):
    factor_refs, out_ref = refs[:-1], refs[-1]
    idx = idx_ref[0]              # (C, nd)
    vals = vals_ref[0]            # (C,)
    local = local_ref[0]          # (C,)
    prod = None
    for slot, f_ref in zip(other_slots, factor_refs):
        rows = jnp.take(f_ref[...], idx[:, slot], axis=0)  # (C, block_r)
        prod = rows if prod is None else prod * rows
    prod = prod * vals[:, None]                            # (C, block_r)
    onehot = (local[None, :] == jax.lax.iota(jnp.int32, block_rows)[:, None])
    out_ref[...] = jnp.dot(onehot.astype(prod.dtype), prod,
                           preferred_element_type=jnp.float32).astype(out_ref.dtype)


def mttkrp_pallas(buckets: RowBlockBuckets,
                  factors: Sequence[Optional[jax.Array]],
                  block_r: int = 128, interpret: bool = True) -> jax.Array:
    """Bucketed MTTKRP. Returns (num_blocks * block_rows, R); callers slice
    to ``shape[mode]`` rows."""
    nb, c = buckets.values.shape
    nd = buckets.indices.shape[-1]
    mode = buckets.mode
    block_rows = buckets.block_rows
    other = tuple(d for d in range(nd) if d != mode and factors[d] is not None)
    fs = [factors[d] for d in other]
    r = fs[0].shape[1]
    block_r = min(block_r, r)
    if r % block_r:
        raise ValueError(f"R={r} % block_r={block_r} nonzero; pad first")
    grid = (nb, r // block_r)
    in_specs = [
        pl.BlockSpec((1, c), lambda b, j: (b, 0)),
        pl.BlockSpec((1, c, nd), lambda b, j: (b, 0, 0)),
        pl.BlockSpec((1, c), lambda b, j: (b, 0)),
    ] + [
        pl.BlockSpec((f.shape[0], block_r), lambda b, j: (0, j)) for f in fs
    ]
    kernel = functools.partial(_mttkrp_kernel, other, block_rows)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((block_rows, block_r), lambda b, j: (b, j)),
        out_shape=jax.ShapeDtypeStruct((nb * block_rows, r),
                                       buckets.values.dtype),
        interpret=interpret,
    )(buckets.values, buckets.indices, buckets.local_row, *fs)
    return out
