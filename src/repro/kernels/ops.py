"""jit'd wrappers dispatching between the Pallas kernels and the pure-jnp
reference paths, with shape padding to block multiples.

Dispatch policy: Pallas (interpret on CPU, compiled on TPU) when
``use_pallas`` or the per-call default says so; pure jnp otherwise. The
device probe is resolved lazily PER CALL (never at import): late device
initialization (``--force-host-devices``) and tests that flip
``REPRO_USE_PALLAS`` both see the current state, not an import-time
snapshot.

All wrappers are shape-polymorphic over padding: inputs are padded to block
multiples and outputs sliced back. Block sizes come from a
:class:`~repro.kernels.tile.KernelTile` — explicit ``tile=`` wins, the
legacy ``block_m``/``block_r`` kwargs override individual fields, and with
neither the per-family process-wide table (``tile.current_tile``, where the
planner's autotuner installs measured winners) supplies the default. The
Pallas kernels accumulate in ``tile.accum_dtype`` (fp32 for bf16 inputs)
and the wrappers cast back to the jnp reference path's result dtype, so
both routes return identical dtypes.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from repro import obs
from repro.core.sparse_tensor import SparseTensor
from repro.core.utils import pad_axis, round_up
from repro.kernels import ref as kref
from repro.kernels import tile as ktile
from repro.kernels.cg_matvec import cg_matvec_pallas
from repro.kernels.mttkrp import mttkrp_pallas
from repro.kernels.tttp import tttp_pallas


def _on_tpu() -> bool:
    return any(d.platform == "tpu" for d in jax.devices())


def _default_use_pallas() -> bool:
    return os.environ.get("REPRO_USE_PALLAS", "0") == "1" or _on_tpu()


def _interpret() -> bool:
    return not _on_tpu()


def _resolve_tile(family: str, tile: Optional[ktile.KernelTile],
                  block_m: Optional[int] = None,
                  block_r: Optional[int] = None) -> ktile.KernelTile:
    tile = tile if tile is not None else ktile.current_tile(family)
    overrides = {}
    if block_m is not None:
        overrides["block_m"] = block_m
    if block_r is not None:
        overrides["block_r"] = block_r
    return dataclasses.replace(tile, **overrides) if overrides else tile


def _pad_factors(factors, block_r):
    r = next(f.shape[1] for f in factors if f is not None)
    rp = round_up(r, block_r)
    if rp == r:
        return factors, r
    return [None if f is None else pad_axis(f, rp, axis=1) for f in factors], r


def _out_dtype(values_dtype, factors) -> jnp.dtype:
    """The jnp reference path's result dtype (promotion over the Hadamard
    chain) — the Pallas accumulator casts back to it."""
    return jnp.result_type(values_dtype,
                           *[f.dtype for f in factors if f is not None])


def tttp_values(st: SparseTensor, factors: Sequence[Optional[jax.Array]],
                use_pallas: Optional[bool] = None,
                block_m: Optional[int] = None,
                block_r: Optional[int] = None,
                tile: Optional[ktile.KernelTile] = None) -> jax.Array:
    """TTTP output values for a padded-COO SparseTensor. Vector factors are
    promoted to single-column matrices (paper's vector-list form)."""
    use_pallas = _default_use_pallas() if use_pallas is None else use_pallas
    factors = [None if f is None else (f[:, None] if f.ndim == 1 else f)
               for f in factors]
    t = _resolve_tile("tttp", tile, block_m=block_m, block_r=block_r)
    with obs.span("kernel/tttp", cap=st.cap, nnz=st.nnz,
                  pallas=use_pallas, tile=t.short()) as sp:
        vals = st.values * st.mask
        if not use_pallas:
            return sp.fence(kref.tttp_ref(vals, st.indices, factors))
        bm = min(t.block_m, round_up(st.cap, 8))
        mp = round_up(st.cap, bm * t.buckets_per_step)
        fs, r = _pad_factors(factors, t.block_r)
        out = tttp_pallas(pad_axis(vals, mp), pad_axis(st.indices, mp), fs,
                          block_m=bm,
                          block_r=min(t.block_r, round_up(r, 128)),
                          tile=t, interpret=_interpret())
        return sp.fence(out[:st.cap].astype(_out_dtype(vals.dtype, factors)))


def tttp(st: SparseTensor, factors, **kw) -> SparseTensor:
    return st.with_values(tttp_values(st, factors, **kw))


def mttkrp_bucketed(buckets, factors: Sequence[Optional[jax.Array]],
                    num_rows: Optional[int] = None,
                    use_pallas: Optional[bool] = None,
                    block_r: Optional[int] = None,
                    tile: Optional[ktile.KernelTile] = None) -> jax.Array:
    """All-at-once MTTKRP over ingest-time buckets; returns (num_rows, R)."""
    use_pallas = _default_use_pallas() if use_pallas is None else use_pallas
    num_rows = num_rows or buckets.shape[buckets.mode]
    t = _resolve_tile("mttkrp", tile, block_r=block_r)
    with obs.span("kernel/mttkrp_bucketed", mode=buckets.mode,
                  rows=num_rows, pallas=use_pallas, tile=t.short()) as sp:
        if use_pallas:
            fs, r = _pad_factors(factors, t.block_r)
            out = mttkrp_pallas(buckets, fs, tile=t, interpret=_interpret())
            dt = _out_dtype(buckets.values.dtype, factors)
            return sp.fence(out[:num_rows, :r].astype(dt))
        out = kref.mttkrp_bucketed_ref(buckets.values, buckets.indices,
                                       buckets.local_row, factors,
                                       buckets.mode, buckets.block_rows)
        return sp.fence(out[:num_rows])


def cg_matvec_bucketed(buckets, factors: Sequence[Optional[jax.Array]],
                       x: jax.Array, num_rows: Optional[int] = None,
                       use_pallas: Optional[bool] = None,
                       tile: Optional[ktile.KernelTile] = None) -> jax.Array:
    """Fused implicit-CG Gram matvec; buckets hold the Ω indicator values."""
    use_pallas = _default_use_pallas() if use_pallas is None else use_pallas
    num_rows = num_rows or buckets.shape[buckets.mode]
    t = _resolve_tile("cg_matvec", tile)
    with obs.span("kernel/cg_matvec_bucketed", mode=buckets.mode,
                  rows=num_rows, pallas=use_pallas, tile=t.short()) as sp:
        if use_pallas:
            out = cg_matvec_pallas(buckets, factors, x, tile=t,
                                   interpret=_interpret())
            dt = _out_dtype(x.dtype, factors)
            return sp.fence(out[:num_rows].astype(dt))
        out = kref.cg_matvec_bucketed_ref(buckets.values, buckets.indices,
                                          buckets.local_row, factors, x,
                                          buckets.mode, buckets.block_rows)
        return sp.fence(out[:num_rows])
