"""jit'd wrappers dispatching between the Pallas kernels and the pure-jnp
reference paths, with shape padding to block multiples.

Dispatch policy: Pallas (interpret on CPU, compiled on TPU) when
``use_pallas`` or the global default says so; pure jnp otherwise. All
wrappers are shape-polymorphic over padding: inputs are padded to block
multiples and outputs sliced back.
"""
from __future__ import annotations

import functools
import os
from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from repro import obs
from repro.core.sparse_tensor import SparseTensor
from repro.core.utils import pad_axis, round_up
from repro.kernels import ref as kref
from repro.kernels.cg_matvec import cg_matvec_pallas
from repro.kernels.mttkrp import mttkrp_pallas
from repro.kernels.tttp import tttp_pallas
from repro.sparse.ccsr import RowBlockBuckets

_ON_TPU = any(d.platform == "tpu" for d in jax.devices())
_DEFAULT_USE_PALLAS = os.environ.get("REPRO_USE_PALLAS", "0") == "1" or _ON_TPU
_INTERPRET = not _ON_TPU


def _pad_factors(factors, block_r):
    r = next(f.shape[1] for f in factors if f is not None)
    rp = round_up(r, block_r)
    if rp == r:
        return factors, r
    return [None if f is None else pad_axis(f, rp, axis=1) for f in factors], r


def tttp_values(st: SparseTensor, factors: Sequence[Optional[jax.Array]],
                use_pallas: Optional[bool] = None,
                block_m: int = 1024, block_r: int = 128) -> jax.Array:
    """TTTP output values for a padded-COO SparseTensor. Vector factors are
    promoted to single-column matrices (paper's vector-list form)."""
    use_pallas = _DEFAULT_USE_PALLAS if use_pallas is None else use_pallas
    factors = [None if f is None else (f[:, None] if f.ndim == 1 else f)
               for f in factors]
    with obs.span("kernel/tttp", cap=st.cap, nnz=st.nnz,
                  pallas=use_pallas) as sp:
        vals = st.values * st.mask
        if not use_pallas:
            return sp.fence(kref.tttp_ref(vals, st.indices, factors))
        block_m = min(block_m, round_up(st.cap, 8))
        mp = round_up(st.cap, block_m)
        fs, r = _pad_factors(factors, block_r)
        out = tttp_pallas(pad_axis(vals, mp), pad_axis(st.indices, mp), fs,
                          block_m=block_m,
                          block_r=min(block_r, round_up(r, 128)),
                          interpret=_INTERPRET)
        return sp.fence(out[:st.cap])


def tttp(st: SparseTensor, factors, **kw) -> SparseTensor:
    return st.with_values(tttp_values(st, factors, **kw))


def mttkrp_bucketed(buckets: RowBlockBuckets,
                    factors: Sequence[Optional[jax.Array]],
                    num_rows: Optional[int] = None,
                    use_pallas: Optional[bool] = None,
                    block_r: int = 128) -> jax.Array:
    """All-at-once MTTKRP over ingest-time buckets; returns (num_rows, R)."""
    use_pallas = _DEFAULT_USE_PALLAS if use_pallas is None else use_pallas
    num_rows = num_rows or buckets.shape[buckets.mode]
    with obs.span("kernel/mttkrp_bucketed", mode=buckets.mode,
                  rows=num_rows, pallas=use_pallas) as sp:
        if use_pallas:
            fs, r = _pad_factors(factors, block_r)
            out = mttkrp_pallas(buckets, fs, block_r=block_r,
                                interpret=_INTERPRET)
            return sp.fence(out[:num_rows, :r])
        out = kref.mttkrp_bucketed_ref(buckets.values, buckets.indices,
                                       buckets.local_row, factors,
                                       buckets.mode, buckets.block_rows)
        return sp.fence(out[:num_rows])


def cg_matvec_bucketed(buckets: RowBlockBuckets,
                       factors: Sequence[Optional[jax.Array]],
                       x: jax.Array, num_rows: Optional[int] = None,
                       use_pallas: Optional[bool] = None) -> jax.Array:
    """Fused implicit-CG Gram matvec; buckets hold the Ω indicator values."""
    use_pallas = _DEFAULT_USE_PALLAS if use_pallas is None else use_pallas
    num_rows = num_rows or buckets.shape[buckets.mode]
    with obs.span("kernel/cg_matvec_bucketed", mode=buckets.mode,
                  rows=num_rows, pallas=use_pallas) as sp:
        if use_pallas:
            out = cg_matvec_pallas(buckets, factors, x, interpret=_INTERPRET)
            return sp.fence(out[:num_rows])
        out = kref.cg_matvec_bucketed_ref(buckets.values, buckets.indices,
                                          buckets.local_row, factors, x,
                                          buckets.mode, buckets.block_rows)
        return sp.fence(out[:num_rows])
