"""Kernel tile configurations for the parameterized Pallas tier (DESIGN.md §13).

A :class:`KernelTile` carries the static blocking knobs shared by every
kernel in this package: the CCSR bucket granularity the tuner evaluates, the
capacity (nonzero) tile each ``fori_loop`` step consumes, the rank tile, how
many buckets one grid step processes, the accumulator dtype, and the
in-bucket scatter schedule. Tiles are frozen/hashable (safe as jit static
args and dict keys) and JSON-round-trippable (the on-disk plan cache,
``repro.planner.tuner``).

Scatter schedules
-----------------
``onehot``     — the in-bucket scatter as a ``(block_rows × C) @ (C × R)``
                 matmul against the one-hot local-row indicator: block_rows×
                 more MACs than a scalar scatter, but they run at MXU rate.
``segmented``  — cumulative-sum segmented reduction on the VPU: one cumsum
                 over the capacity axis plus a per-row boundary gather and
                 adjacent difference — Θ(C·R) work independent of block_rows.
``auto``       — pick by the break-even point: one-hot costs
                 ``block_rows·C·R`` MACs at MXU rate vs the segmented
                 schedule's ``≈C·R·(log2(C)+4)`` VPU ops; with the MXU's
                 ~16× MAC-rate advantage the one-hot matmul wins while
                 ``block_rows ≤ 16·(log2(C)+4)`` (≈224 at C=1024).

The per-family process-wide tile table below is what ``kernels.ops`` resolves
when a caller passes no explicit tile; ``repro.planner.tuner`` installs
measured winners into it. NOTE: jit'd callers bake the resolved tile in at
trace time — retuning after compilation changes future traces only (tune at
startup, before compiling; see DESIGN.md §13).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict

import jax
import jax.numpy as jnp

FAMILIES = ("tttp", "mttkrp", "cg_matvec")

_SCHEDULES = ("auto", "onehot", "segmented")


@dataclasses.dataclass(frozen=True)
class KernelTile:
    """Static blocking config for one kernel family.

    ``block_rows``       — CCSR bucket granularity (scatter height) the tuner
                           evaluates; the kernels themselves honor the
                           ``block_rows`` of whatever buckets they are given;
    ``block_m``          — capacity tile: nonzeros consumed per ``fori_loop``
                           step (bounds VMEM at Θ(block_m·block_r) transients
                           instead of whole-bucket blocks);
    ``block_r``          — rank (lane) tile;
    ``buckets_per_step`` — buckets one grid step processes (amortizes grid
                           overhead for many small buckets);
    ``accum_dtype``      — accumulator dtype (string, for hashability and
                           JSON); inputs may be bf16 — the Hadamard chain
                           runs in the input dtype, accumulation in this one;
    ``schedule``         — in-bucket scatter schedule (see module docstring).
    """
    block_rows: int = 8
    block_m: int = 1024
    block_r: int = 128
    buckets_per_step: int = 1
    accum_dtype: str = "float32"
    schedule: str = "auto"

    def __post_init__(self):
        if self.schedule not in _SCHEDULES:
            raise ValueError(f"schedule {self.schedule!r} not in {_SCHEDULES}")
        for field in ("block_rows", "block_m", "block_r", "buckets_per_step"):
            if getattr(self, field) < 1:
                raise ValueError(f"{field} must be positive")

    @property
    def acc(self):
        return jnp.dtype(self.accum_dtype)

    def resolved_schedule(self, block_rows: int, block_m: int) -> str:
        """Concrete schedule for a kernel instance ('auto' resolved by the
        break-even point against the actual bucket/tile geometry)."""
        if self.schedule != "auto":
            return self.schedule
        return ("segmented" if block_rows > onehot_break_even(block_m)
                else "onehot")

    def short(self) -> str:
        """Compact label for spans/benchmarks: br8.m1024.r128.g1.f32.auto"""
        acc = {"float32": "f32", "bfloat16": "bf16",
               "float64": "f64"}.get(self.accum_dtype, self.accum_dtype)
        return (f"br{self.block_rows}.m{self.block_m}.r{self.block_r}"
                f".g{self.buckets_per_step}.{acc}.{self.schedule}")

    def to_json(self) -> Dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_json(cls, d: Dict) -> "KernelTile":
        fields = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in fields})


def onehot_break_even(block_m: int) -> int:
    """block_rows above which the segmented schedule beats the one-hot
    matmul: block_rows·C MACs at MXU rate vs ≈C·(log2(C)+4) VPU ops per
    output column — the MXU's ~16× rate advantage sets the crossover."""
    return int(16 * (math.log2(max(block_m, 2)) + 4))


def scatter_rows(prod, key, block_rows: int, schedule: str, acc_dtype):
    """Scatter-add ``prod`` (C, R) rows into (block_rows, R) output rows by
    ``key`` (C,) — the in-bucket scatter primitive both bucketed kernels
    share, usable inside Pallas kernel bodies (pure jnp).

    ``key`` must be monotone nondecreasing with padding slots mapped PAST
    the valid range (``key == block_rows``): CCSR buckets store sorted
    nonzeros but their padding tail carries ``local_row == 0``, so callers
    build ``key = where(valid, local_row, block_rows)``. Monotonicity is
    what lets the segmented schedule express "rows with key ≤ i" as a
    prefix of the cumulative sum.
    """
    if schedule == "onehot":
        onehot = (key[None, :]
                  == jax.lax.iota(jnp.int32, block_rows)[:, None])
        return jnp.dot(onehot.astype(prod.dtype), prod,
                       preferred_element_type=acc_dtype)
    if schedule != "segmented":
        raise ValueError(f"unresolved scatter schedule {schedule!r}")
    # segmented reduction: prefix-sum along the capacity axis, then for each
    # output row gather the boundary prefix E[i] = csum[last j with key ≤ i]
    # and take adjacent differences — rows with no entries contribute 0
    csum = jnp.cumsum(prod.astype(acc_dtype), axis=0)           # (C, R)
    rows = jax.lax.iota(jnp.int32, block_rows)
    ends = jnp.sum((key[None, :] <= rows[:, None]).astype(jnp.int32),
                   axis=1)                                       # (block_rows,)
    gathered = jnp.take(csum, jnp.maximum(ends - 1, 0), axis=0)
    e = jnp.where((ends > 0)[:, None], gathered,
                  jnp.zeros_like(gathered))
    prev = jnp.concatenate([jnp.zeros_like(e[:1]), e[:-1]], axis=0)
    return e - prev


# ---------------------------------------------------------------------------
# process-wide per-family tile table (the tuner's output seam)
# ---------------------------------------------------------------------------

DEFAULT_TILE = KernelTile()

_TILE_TABLE: Dict[str, KernelTile] = {f: DEFAULT_TILE for f in FAMILIES}


def current_tile(family: str) -> KernelTile:
    """The tile ``kernels.ops`` resolves for ``family`` when the caller
    passes none — the default until ``repro.planner.tuner`` installs a
    measured winner."""
    return _TILE_TABLE[family]


def set_tile(family: str, tile: KernelTile) -> None:
    if family not in _TILE_TABLE:
        raise KeyError(f"unknown kernel family {family!r}; "
                       f"families: {FAMILIES}")
    _TILE_TABLE[family] = tile


def reset_tiles() -> None:
    for f in FAMILIES:
        _TILE_TABLE[f] = DEFAULT_TILE
