"""Pallas TPU kernel: fused implicit-CG Gram matvec (paper §2.2 + eq. 3).

Computes, in ONE pass over the nonzeros (per bucket),

    z_n    = ω_n Σ_s (Π_{d≠mode} A_d[i_d(n), s]) · x[i_mode(n), s]   (TTTP)
    y[i,r] = Σ_{n: i_mode(n)=i} z_n · Π_{d≠mode} A_d[i_d(n), r]      (MTTKRP)

This is the paper's key insight made kernel-level: the Khatri-Rao gather
(Π A_d rows) is computed once and reused for both the TTTP and MTTKRP halves,
and the (m, R) intermediate that pairwise contraction would materialize never
exists. The scatter half uses the tile's schedule — one-hot MXU matmul or
segmented cumsum reduction — exactly as in ``mttkrp.py``.

Grid: (num_buckets / buckets_per_step,). Full-R factor/x tiles are held in
VMEM — implicit-CG ranks (R ≤ ~512) fit comfortably (the TTTP half reduces
over all of R, so R-slicing would need two passes; ``tile.block_r`` is
ignored here). The capacity axis is walked in ``block_m`` tiles by a
``fori_loop`` with a (block_rows, R) accumulator in ``accum_dtype``, so
VMEM transients stay Θ(block_m·R) regardless of bucket capacity.
"""
from __future__ import annotations

import functools
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.utils import round_up
from repro.kernels.mttkrp import _pad_buckets
from repro.kernels.tile import KernelTile, scatter_rows
from repro.sparse.ccsr import RowBlockBuckets


def _cg_matvec_kernel(other_slots, mode, block_rows, block_m, num_tiles, g,
                      schedule, acc_dtype,
                      omega_ref, idx_ref, key_ref, *refs):
    x_ref = refs[-2]
    out_ref = refs[-1]
    factor_refs = refs[:-2]
    r = out_ref.shape[-1]
    for gi in range(g):                      # static unroll over buckets

        def tile_body(t, acc, gi=gi):
            sl = pl.dslice(t * block_m, block_m)
            omega = omega_ref[gi, sl]        # (block_m,)
            idx = idx_ref[gi, sl, :]         # (block_m, nd)
            key = key_ref[gi, sl]            # (block_m,)
            kr = None
            for slot, f_ref in zip(other_slots, factor_refs):
                rows = jnp.take(f_ref[...], idx[:, slot], axis=0)
                kr = rows if kr is None else kr * rows     # input dtype
            xrows = jnp.take(x_ref[...], idx[:, mode], axis=0)
            z = (omega.astype(acc_dtype)
                 * jnp.sum((kr * xrows).astype(acc_dtype), axis=1))
            contrib = z[:, None] * kr.astype(acc_dtype)    # (block_m, R)
            return acc + scatter_rows(contrib, key, block_rows, schedule,
                                      acc_dtype)

        acc = jax.lax.fori_loop(
            0, num_tiles, tile_body, jnp.zeros((block_rows, r), acc_dtype))
        out_ref[gi * block_rows:(gi + 1) * block_rows, :] = acc


def cg_matvec_pallas(buckets: RowBlockBuckets,
                     factors: Sequence[Optional[jax.Array]],
                     x: jax.Array, tile: Optional[KernelTile] = None,
                     interpret: bool = True) -> jax.Array:
    """Fused Gram matvec over Ω-pattern buckets (bucketed over ``mode``).

    ``buckets.values`` must hold the Ω indicator (1.0 at observed entries,
    0 padding). Returns (padded rows, R) in ``tile.accum_dtype``; callers
    slice to the true row count and cast."""
    tile = tile if tile is not None else KernelTile()
    nd = buckets.indices.shape[-1]
    mode = buckets.mode
    block_rows = buckets.block_rows
    other = tuple(d for d in range(nd) if d != mode and factors[d] is not None)
    fs = [factors[d] for d in other]
    r = x.shape[1]
    c = buckets.values.shape[1]
    block_m = min(tile.block_m, round_up(c, 8))
    g = tile.buckets_per_step
    schedule = tile.resolved_schedule(block_rows, block_m)
    key = jnp.where(buckets.valid, buckets.local_row,
                    jnp.int32(block_rows)).astype(jnp.int32)
    values, indices, key, nbp, cp = _pad_buckets(
        buckets.values, buckets.indices, key, block_m, g, block_rows)
    grid = (nbp // g,)
    in_specs = [
        pl.BlockSpec((g, cp), lambda b: (b, 0)),
        pl.BlockSpec((g, cp, nd), lambda b: (b, 0, 0)),
        pl.BlockSpec((g, cp), lambda b: (b, 0)),
    ] + [
        pl.BlockSpec((f.shape[0], r), lambda b: (0, 0)) for f in fs
    ] + [
        pl.BlockSpec((x.shape[0], r), lambda b: (0, 0)),
    ]
    kernel = functools.partial(_cg_matvec_kernel, other, mode, block_rows,
                               block_m, cp // block_m, g, schedule, tile.acc)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((g * block_rows, r), lambda b: (b, 0)),
        out_shape=jax.ShapeDtypeStruct((nbp * block_rows, r), tile.acc),
        interpret=interpret,
    )(values, indices, key, *fs, x)
