"""Pallas TPU kernel: fused implicit-CG Gram matvec (paper §2.2 + eq. 3).

Computes, in ONE pass over the nonzeros (per bucket),

    z_n    = ω_n Σ_s (Π_{d≠mode} A_d[i_d(n), s]) · x[i_mode(n), s]   (TTTP)
    y[i,r] = Σ_{n: i_mode(n)=i} z_n · Π_{d≠mode} A_d[i_d(n), r]      (MTTKRP)

This is the paper's key insight made kernel-level: the Khatri-Rao gather
(Π A_d rows) is computed once and reused for both the TTTP and MTTKRP halves,
and the (m, R) intermediate that pairwise contraction would materialize never
exists. The scatter half is the one-hot segment matmul on the MXU, as in
``mttkrp.py``.

Grid: (num_buckets,). Full-R tiles are held in VMEM — implicit-CG ranks
(R ≤ ~512) fit comfortably; the R-sliced variant used for larger ranks
composes two ``pallas_call``s sharing the bucket layout.
"""
from __future__ import annotations

import functools
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.sparse.ccsr import RowBlockBuckets


def _cg_matvec_kernel(other_slots, mode, block_rows,
                      omega_ref, idx_ref, local_ref, *refs):
    x_ref = refs[-2]
    out_ref = refs[-1]
    factor_refs = refs[:-2]
    idx = idx_ref[0]            # (C, nd)
    omega = omega_ref[0]        # (C,)
    local = local_ref[0]        # (C,)
    kr = None
    for slot, f_ref in zip(other_slots, factor_refs):
        rows = jnp.take(f_ref[...], idx[:, slot], axis=0)   # (C, R)
        kr = rows if kr is None else kr * rows
    xrows = jnp.take(x_ref[...], idx[:, mode], axis=0)      # (C, R)
    z = omega * jnp.sum(kr * xrows, axis=1)                 # (C,)
    contrib = z[:, None] * kr                               # (C, R)
    onehot = (local[None, :] == jax.lax.iota(jnp.int32, block_rows)[:, None])
    out_ref[...] = jnp.dot(onehot.astype(contrib.dtype), contrib,
                           preferred_element_type=jnp.float32).astype(out_ref.dtype)


def cg_matvec_pallas(buckets: RowBlockBuckets,
                     factors: Sequence[Optional[jax.Array]],
                     x: jax.Array, interpret: bool = True) -> jax.Array:
    """Fused Gram matvec over Ω-pattern buckets (bucketed over ``mode``).

    ``buckets.values`` must hold the Ω indicator (1.0 at observed entries,
    0 padding). Returns (num_blocks * block_rows, R)."""
    nb, c = buckets.values.shape
    nd = buckets.indices.shape[-1]
    mode = buckets.mode
    block_rows = buckets.block_rows
    other = tuple(d for d in range(nd) if d != mode and factors[d] is not None)
    fs = [factors[d] for d in other]
    r = x.shape[1]
    grid = (nb,)
    in_specs = [
        pl.BlockSpec((1, c), lambda b: (b, 0)),
        pl.BlockSpec((1, c, nd), lambda b: (b, 0, 0)),
        pl.BlockSpec((1, c), lambda b: (b, 0)),
    ] + [
        pl.BlockSpec((f.shape[0], r), lambda b: (0, 0)) for f in fs
    ] + [
        pl.BlockSpec((x.shape[0], r), lambda b: (0, 0)),
    ]
    kernel = functools.partial(_cg_matvec_kernel, other, mode, block_rows)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((block_rows, r), lambda b: (b, 0)),
        out_shape=jax.ShapeDtypeStruct((nb * block_rows, r),
                                       x.dtype),
        interpret=interpret,
    )(buckets.values, buckets.indices, buckets.local_row, *fs, x)
