"""Static VMEM footprint model for the tiled Pallas kernel tier.

Every kernel in this package declares its VMEM residency through BlockSpecs
(DESIGN.md §13, §15): grid-streamed value/index/key/output windows plus
VMEM-resident factor column slices, with Θ(block_m · block_r) ``fori_loop``
transients on top. This module prices that residency *statically* — from a
:class:`KernelTile` and the workload geometry alone, no tracing — so a tile
candidate that cannot fit the ~16 MiB/core TPU VMEM budget is rejected
BEFORE ``planner.tuner`` spends a timing on it (and before a real TPU run
dies in the Mosaic allocator).

The model mirrors the BlockSpec geometry of ``tttp.py`` / ``mttkrp.py`` /
``cg_matvec.py`` exactly (same block_m/block_r clamping and padding as
``ops.py``), charges grid-streamed windows twice (the Pallas pipeline
double-buffers them), charges resident factor windows once, and adds the
scatter-schedule extras (the one-hot indicator or the segmented cumsum).
It is deliberately a slight over-estimate: pruning a tile that would
barely fit is cheap; timing a tile that then OOMs on hardware is not.

Consumed by ``planner.tuner`` (lattice pruning, plan-cache key validity)
and by ``repro.analysis.spmd`` (the SP201 certification pass).
"""
from __future__ import annotations

import dataclasses
import os
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.utils import round_up
from repro.kernels.tile import KernelTile

# TPU cores expose ~16 MiB of VMEM (see the Pallas TPU notes); compiled
# kernels get a slice of it after the compiler's own reservations.
DEFAULT_VMEM_BYTES = 16 * 2 ** 20


def vmem_budget_bytes() -> int:
    """The device VMEM budget the certifier prunes against.

    ``REPRO_VMEM_MB`` overrides (useful for sizing against a partial
    per-kernel allowance, or for forcing prunes in tests/CI tripwires)."""
    mb = os.environ.get("REPRO_VMEM_MB")
    if mb:
        return int(float(mb) * 2 ** 20)
    return DEFAULT_VMEM_BYTES


@dataclasses.dataclass(frozen=True)
class KernelGeometry:
    """Static workload geometry one kernel instance runs against.

    ``factor_rows`` are the row extents of the VMEM-resident (non-target)
    factors; ``capacity`` is the padded-COO cap (tttp) or the CCSR bucket
    capacity (bucketed kernels); ``x_rows`` is the CG direction's row
    extent (cg_matvec only)."""
    nd: int
    rank: int
    factor_rows: Tuple[int, ...]
    capacity: int
    block_rows: int = 8
    x_rows: Optional[int] = None
    value_bytes: int = 4
    index_bytes: int = 4


@dataclasses.dataclass(frozen=True)
class VmemEstimate:
    family: str
    tile_short: str
    total: int
    budget: int
    breakdown: Tuple[Tuple[str, int], ...]
    block_m: int
    block_r: int
    schedule: str

    @property
    def fits(self) -> bool:
        return self.total <= self.budget

    def format(self) -> str:
        parts = " + ".join(f"{k}={v}" for k, v in self.breakdown)
        verdict = "fits" if self.fits else "OVER"
        return (f"{self.family}[{self.tile_short}]: {self.total} B "
                f"({verdict} budget {self.budget} B): {parts}")


def _sched_bytes(schedule: str, block_rows: int, block_m: int, block_r: int,
                 vb: int, ab: int) -> int:
    """Scatter-schedule extras of ``tile.scatter_rows``: the one-hot
    indicator matmul operand vs the segmented cumsum buffer."""
    if schedule == "onehot":
        return block_rows * block_m * vb
    return block_m * block_r * ab


def estimate_vmem(family: str, tile: KernelTile,
                  geom: KernelGeometry,
                  budget: Optional[int] = None) -> VmemEstimate:
    """Per-grid-step VMEM bytes for ``family`` under ``tile`` on ``geom``,
    following each kernel's BlockSpecs (see module docstring)."""
    budget = vmem_budget_bytes() if budget is None else int(budget)
    vb, ib = geom.value_bytes, geom.index_bytes
    ab = np.dtype(tile.accum_dtype).itemsize
    g = tile.buckets_per_step
    parts: List[Tuple[str, int]] = []

    if family == "tttp":
        # ops.py: bm = min(block_m, round_up(cap, 8)); step = bm·g;
        # factors padded to round_up(R, block_r); block_r clamped to R-pad
        bm = min(tile.block_m, round_up(geom.capacity, 8))
        rp = round_up(geom.rank, tile.block_r)
        br = min(tile.block_r, rp)
        step = bm * g
        schedule = "none"
        parts.append(("values", 2 * step * vb))
        parts.append(("indices", 2 * step * geom.nd * ib))
        parts.append(("out", 2 * step * ab))
        parts.append(("factors", 2 * sum(geom.factor_rows) * br * vb))
        parts.append(("transients", 2 * bm * br * vb + bm * ab))
    elif family in ("mttkrp", "cg_matvec"):
        bm = min(tile.block_m, round_up(geom.capacity, 8))
        cp = round_up(geom.capacity, bm)
        if family == "mttkrp":
            rp = round_up(geom.rank, tile.block_r)
            br = min(tile.block_r, rp)
        else:
            br = geom.rank          # cg holds full R (block_r ignored)
        schedule = tile.resolved_schedule(geom.block_rows, bm)
        parts.append(("values", 2 * g * cp * vb))
        parts.append(("indices", 2 * g * cp * geom.nd * ib))
        parts.append(("key", 2 * g * cp * 4))
        parts.append(("out", 2 * g * geom.block_rows * br * ab))
        resident = sum(geom.factor_rows) * br * vb
        if family == "cg_matvec":
            resident += (geom.x_rows or 0) * geom.rank * vb
        parts.append(("factors", resident))
        trans = 2 * bm * br * vb + geom.block_rows * br * ab
        if family == "cg_matvec":
            trans += bm * br * ab + bm * ab   # contrib (block_m, R) + z
        parts.append(("transients", trans))
        parts.append(("schedule",
                      _sched_bytes(schedule, geom.block_rows, bm, br,
                                   vb, ab)))
    else:
        raise KeyError(f"unknown kernel family {family!r}")

    return VmemEstimate(family=family, tile_short=tile.short(),
                        total=sum(v for _, v in parts), budget=budget,
                        breakdown=tuple(parts),
                        block_m=bm, block_r=br, schedule=schedule)


def workload_geometry(family: str, st, factors, tile: KernelTile,
                      x=None) -> KernelGeometry:
    """Geometry for one concrete tuner workload. For the bucketed families
    the capacity is the CCSR bucket capacity this ``tile.block_rows``
    implies (mode 0, matching ``tuner._family_runner``) — computed on host
    from the concrete indices, same rounding as ``ccsr.bucket_pattern``."""
    nd = len(st.shape)
    rank = next(int(f.shape[1]) for f in factors if f is not None)
    if family == "tttp":
        rows = tuple(int(f.shape[0]) for f in factors if f is not None)
        return KernelGeometry(nd=nd, rank=rank, factor_rows=rows,
                              capacity=int(st.cap),
                              block_rows=tile.block_rows,
                              value_bytes=st.values.dtype.itemsize)
    rows = tuple(int(f.shape[0]) for d, f in enumerate(factors)
                 if d != 0 and f is not None)
    idx = np.asarray(st.indices[:, 0])[np.asarray(st.valid)]
    occ = np.bincount(idx // tile.block_rows) if idx.size else np.zeros(1)
    cap = round_up(max(int(occ.max()) if occ.size else 1, 1), 8)
    x_rows = int(x.shape[0]) if (family == "cg_matvec" and x is not None) \
        else (int(st.shape[0]) if family == "cg_matvec" else None)
    return KernelGeometry(nd=nd, rank=rank, factor_rows=rows, capacity=cap,
                          block_rows=tile.block_rows, x_rows=x_rows,
                          value_bytes=st.values.dtype.itemsize)


def prune_lattice(family: str, lattice: Sequence[KernelTile],
                  geom_fn: Callable[[KernelTile], KernelGeometry],
                  budget: Optional[int] = None
                  ) -> Tuple[List[KernelTile],
                             List[Tuple[KernelTile, VmemEstimate]]]:
    """Split a tile lattice into (fits, pruned-with-estimates) against the
    VMEM budget. ``geom_fn`` maps each tile to its geometry (bucket
    capacity depends on the tile's block_rows)."""
    kept: List[KernelTile] = []
    pruned: List[Tuple[KernelTile, VmemEstimate]] = []
    for tile in lattice:
        est = estimate_vmem(family, tile, geom_fn(tile), budget=budget)
        (kept if est.fits else pruned).append(
            tile if est.fits else (tile, est))
    return kept, pruned
