from repro.kernels import ops, ref
from repro.kernels.tttp import tttp_pallas
from repro.kernels.mttkrp import mttkrp_pallas
from repro.kernels.cg_matvec import cg_matvec_pallas

__all__ = ["ops", "ref", "tttp_pallas", "mttkrp_pallas", "cg_matvec_pallas"]
