from repro.kernels import ops, ref, tile
from repro.kernels.tile import KernelTile, current_tile, reset_tiles, set_tile
from repro.kernels.tttp import tttp_pallas
from repro.kernels.mttkrp import mttkrp_pallas
from repro.kernels.cg_matvec import cg_matvec_pallas

__all__ = ["ops", "ref", "tile", "KernelTile", "current_tile", "set_tile",
           "reset_tiles", "tttp_pallas", "mttkrp_pallas", "cg_matvec_pallas"]
