"""Pallas TPU kernel for TTTP (paper §3.2), tiled tier.

Grid: (value super-blocks, R blocks). Each grid step owns a super-block of
``block_m · buckets_per_step`` nonzeros and walks it in ``block_m`` tiles
with a ``fori_loop`` — VMEM transients are Θ(block_m · block_r) regardless
of the super-block size. Per tile the kernel gathers up to ``block_m``
factor rows per mode from VMEM-resident factor column-slices, forms the
Hadamard product on the VPU in the input dtype (bf16 stays bf16), reduces
the R tile in ``accum_dtype`` (fp32 for bf16 inputs), and accumulates into
the per-nonzero output slice. Accumulation over the R grid dimension
follows the standard revisiting-grid pattern (init at r==0); the output is
in ``accum_dtype`` — ops.py casts back.

Blocking / memory notes (TPU target, validated in interpret mode on CPU):
* value/index tiles are (block_m,) / (block_m, ndim) VMEM slices; block_m is
  a multiple of 8 (sublane) — default 1024;
* factor tiles are (I_d, block_r) column slices; block_r multiple of 128
  (lane) — the R grid axis is the paper's H-slicing realized as a grid
  dimension, bounding VMEM at Θ(Σ I_d · block_r);
* for factor matrices too large for VMEM the production path keeps factors in
  HBM (``memory_space=ANY``) and DMA-streams gathered rows; on this CPU
  container we validate the VMEM-resident variant only (DESIGN.md §3).
* the row gather uses ``jnp.take`` along axis 0, which lowers to TPU dynamic
  row-gather; padded entries carry value 0 and index 0, so they contribute 0.
"""
from __future__ import annotations

import functools
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.tile import KernelTile


def _tttp_kernel(nd_present, block_m, num_tiles, acc_dtype,
                 vals_ref, idx_ref, *refs):
    factor_refs, out_ref = refs[:-1], refs[-1]
    r_idx = pl.program_id(1)

    @pl.when(r_idx == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    def tile_body(t, carry):
        sl = pl.dslice(t * block_m, block_m)
        idx = idx_ref[sl, :]
        prod = None
        for slot, f_ref in enumerate(factor_refs):
            rows = jnp.take(f_ref[...], idx[:, nd_present[slot]], axis=0)
            prod = rows if prod is None else prod * rows
        partial = jnp.sum(prod.astype(acc_dtype), axis=1)   # (block_m,)
        out_ref[sl] += vals_ref[sl].astype(acc_dtype) * partial
        return carry

    jax.lax.fori_loop(0, num_tiles, tile_body, 0)


def tttp_pallas(values: jax.Array, indices: jax.Array,
                factors: Sequence[Optional[jax.Array]],
                block_m: Optional[int] = None,
                block_r: Optional[int] = None,
                tile: Optional[KernelTile] = None,
                interpret: bool = True) -> jax.Array:
    """TTTP on padded COO arrays. ``values (m,)``, ``indices (m, nd)``;
    ``factors[d]`` is ``(shape[d], R)`` or None. m must be a multiple of
    ``block_m · buckets_per_step`` and R of ``block_r`` (ops.py pads).
    Returns (m,) in ``tile.accum_dtype``."""
    tile = tile if tile is not None else KernelTile()
    m = values.shape[0]
    nd = indices.shape[1]
    present = tuple(d for d, f in enumerate(factors) if f is not None)
    fs = [factors[d] for d in present]
    r = fs[0].shape[1]
    block_m = min(block_m if block_m is not None else tile.block_m, m)
    block_r = min(block_r if block_r is not None else tile.block_r, r)
    step = block_m * tile.buckets_per_step
    if m % step or r % block_r:
        raise ValueError(f"m={m} % (block_m·g)={step} or R={r} % block_r="
                         f"{block_r} nonzero; pad first")
    grid = (m // step, r // block_r)
    in_specs = [
        pl.BlockSpec((step,), lambda i, j: (i,)),
        pl.BlockSpec((step, nd), lambda i, j: (i, 0)),
    ] + [
        pl.BlockSpec((f.shape[0], block_r), lambda i, j: (0, j)) for f in fs
    ]
    kernel = functools.partial(_tttp_kernel, present, block_m,
                               step // block_m, tile.acc)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((step,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((m,), tile.acc),
        interpret=interpret,
    )(values, indices, *fs)
