"""Pallas TPU kernel for TTTP (paper §3.2).

Grid: (nonzero blocks, R blocks). Per step the kernel gathers up to
``block_m`` factor rows per mode from VMEM-resident factor column-slices,
forms the Hadamard product on the VPU, reduces the R tile, and accumulates
into the per-nonzero output block. Output accumulation over the R grid
dimension follows the standard revisiting-grid pattern (init at r==0).

Blocking / memory notes (TPU target, validated in interpret mode on CPU):
* value/index blocks are (block_m,) / (block_m, ndim) VMEM tiles; block_m is
  a multiple of 8 (sublane) — default 1024;
* factor tiles are (I_d, block_r) column slices; block_r multiple of 128
  (lane) — the R grid axis is the paper's H-slicing realized as a grid
  dimension, bounding VMEM at Θ(Σ I_d · block_r);
* for factor matrices too large for VMEM the production path keeps factors in
  HBM (``memory_space=ANY``) and DMA-streams gathered rows; on this CPU
  container we validate the VMEM-resident variant only (DESIGN.md §3).
* the row gather uses ``jnp.take`` along axis 0, which lowers to TPU dynamic
  row-gather; padded entries carry value 0 and index 0, so they contribute 0.
"""
from __future__ import annotations

import functools
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.utils import cdiv


def _tttp_kernel(nd_present, vals_ref, idx_ref, *refs):
    factor_refs, out_ref = refs[:-1], refs[-1]
    r_idx = pl.program_id(1)
    idx = idx_ref[...]
    prod = None
    for slot, f_ref in enumerate(factor_refs):
        rows = jnp.take(f_ref[...], idx[:, nd_present[slot]], axis=0)
        prod = rows if prod is None else prod * rows
    partial = jnp.sum(prod, axis=1)  # (block_m,)

    @pl.when(r_idx == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += vals_ref[...] * partial


def tttp_pallas(values: jax.Array, indices: jax.Array,
                factors: Sequence[Optional[jax.Array]],
                block_m: int = 1024, block_r: int = 128,
                interpret: bool = True) -> jax.Array:
    """TTTP on padded COO arrays. ``values (m,)``, ``indices (m, nd)``;
    ``factors[d]`` is ``(shape[d], R)`` or None. m % block_m == 0 and
    R % block_r == 0 are required (ops.py pads)."""
    m = values.shape[0]
    nd = indices.shape[1]
    present = tuple(d for d, f in enumerate(factors) if f is not None)
    fs = [factors[d] for d in present]
    r = fs[0].shape[1]
    block_m = min(block_m, m)
    block_r = min(block_r, r)
    if m % block_m or r % block_r:
        raise ValueError(f"m={m} % block_m={block_m} or R={r} % block_r="
                         f"{block_r} nonzero; pad first")
    grid = (m // block_m, r // block_r)
    in_specs = [
        pl.BlockSpec((block_m,), lambda i, j: (i,)),
        pl.BlockSpec((block_m, nd), lambda i, j: (i, 0)),
    ] + [
        pl.BlockSpec((f.shape[0], block_r), lambda i, j: (0, j)) for f in fs
    ]
    kernel = functools.partial(_tttp_kernel, present)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((block_m,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((m,), values.dtype),
        interpret=interpret,
    )(values, indices, *fs)
