"""Top-level model: embeddings, decoder (± encoder), LM head, train/serve
step factories. Covers all 10 assigned architectures through ArchConfig.

Inputs per family (modality frontends are STUBS per the assignment —
``input_specs`` provides precomputed embeddings):
* LM:        {"tokens" (B,S), "labels" (B,S)}
* audio:     + {"frames" (B,S_enc,D)} — whisper conv frontend output
* vlm:       + {"patch_embeds" (B,P,D)} — CLIP patch embeddings; the text
             sequence is shortened so patches+text = S.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.models import transformer as T


def init_params(key, cfg: ArchConfig, dtype=jnp.float32) -> Dict:
    ks = jax.random.split(key, 4)
    p: Dict[str, Any] = {
        "embed": (cfg.d_model ** -0.5) *
        jax.random.normal(ks[0], (cfg.vocab, cfg.d_model), dtype),
        "final_norm": jnp.zeros((cfg.d_model,), dtype),
        "decoder": T.init_stack_params(ks[1], cfg,
                                       cross_attn=cfg.encoder_layers > 0,
                                       dtype=dtype),
    }
    if not cfg.tie_embeddings:
        p["unembed"] = (cfg.d_model ** -0.5) * \
            jax.random.normal(ks[2], (cfg.d_model, cfg.vocab), dtype)
    if cfg.encoder_layers > 0:
        enc_cfg = _encoder_cfg(cfg)
        p["encoder"] = T.init_stack_params(ks[3], enc_cfg, dtype=dtype)
        p["enc_norm"] = jnp.zeros((cfg.d_model,), dtype)
    return p


def _encoder_cfg(cfg: ArchConfig) -> ArchConfig:
    import dataclasses
    from repro.configs.base import BlockSpec
    return dataclasses.replace(cfg, n_layers=cfg.encoder_layers,
                               group=(BlockSpec("attn"),), n_experts=0)


def _logits(p, cfg: ArchConfig, x: jax.Array) -> jax.Array:
    x = L.rms_norm(x, p["final_norm"], cfg.norm_eps)
    w = p["embed"].T if cfg.tie_embeddings else p["unembed"]
    logits = x @ w
    return L.softcap(logits.astype(jnp.float32), cfg.final_softcap)


def _embed(p, cfg: ArchConfig, tokens: jax.Array) -> jax.Array:
    x = p["embed"][tokens]
    if cfg.tie_embeddings:   # gemma-style embedding scaling
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    return x


def encode(p, cfg: ArchConfig, frames: jax.Array) -> jax.Array:
    """Encoder stack over precomputed frame embeddings (whisper)."""
    enc_cfg = _encoder_cfg(cfg)
    b, s, _ = frames.shape
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    h = T.stack_forward(p["encoder"], enc_cfg, frames, pos, causal=False)
    return L.rms_norm(h, p["enc_norm"], cfg.norm_eps)


def forward_hidden(p, cfg: ArchConfig, batch: Dict[str, jax.Array],
                   remat: bool = True) -> jax.Array:
    """Final hidden states (pre-LM-head)."""
    tokens = batch["tokens"]
    x = _embed(p, cfg, tokens)
    enc_out = None
    if cfg.encoder_layers > 0:
        enc_out = encode(p, cfg, batch["frames"].astype(x.dtype))
    if cfg.frontend == "patch":
        x = jnp.concatenate([batch["patch_embeds"].astype(x.dtype), x], 1)
    b, s, _ = x.shape
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    x = T.stack_forward(p["decoder"], cfg, x, pos, enc_out, remat=remat)
    if cfg.frontend == "patch":
        x = x[:, batch["patch_embeds"].shape[1]:]
    return x


def forward(p, cfg: ArchConfig, batch: Dict[str, jax.Array],
            remat: bool = True) -> jax.Array:
    """Full-sequence logits (training / prefill)."""
    return _logits(p, cfg, forward_hidden(p, cfg, batch, remat))


def prefill_logits(p, cfg: ArchConfig, batch: Dict[str, jax.Array]
                   ) -> jax.Array:
    """Next-token logits after prompt processing: the LM head runs on the
    LAST position only — never materializes (B, S, V)."""
    x = forward_hidden(p, cfg, batch, remat=False)
    return _logits(p, cfg, x[:, -1:])[:, 0]


def loss_fn(p, cfg: ArchConfig, batch: Dict[str, jax.Array],
            remat: bool = True) -> jax.Array:
    logits = forward(p, cfg, batch, remat)
    labels = batch["labels"]
    # CE via logsumexp + iota-comparison contraction: shards cleanly over a
    # vocab-sharded logits tensor (a take_along_axis gather would force the
    # partitioner to all-gather the full vocab dim).
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    onehot = (labels[..., None] ==
              jax.lax.iota(jnp.int32, logits.shape[-1])[None, None, :])
    picked = jnp.sum(jnp.where(onehot, logits, 0.0), axis=-1)
    nll = lse - picked
    mask = (labels >= 0).astype(jnp.float32)
    loss = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    if cfg.n_experts > 0:
        # lightweight load-balance term on the embedding stream
        from repro.models import moe as M
        first = next(b for b in p["decoder"]["blocks"] if b is not None)
        router0 = jax.tree.map(lambda a: a[0], first)
        if "ffn" in router0 and "router" in router0["ffn"]:
            x = _embed(p, cfg, batch["tokens"])
            loss = loss + 0.01 * M.moe_aux_loss(router0["ffn"], cfg, x)
    return loss


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def cache_init(cfg: ArchConfig, batch: int, max_len: int,
               dtype=jnp.float32) -> Tuple:
    return T.stack_cache_init(cfg, batch, max_len, dtype)


def decode_step(p, cfg: ArchConfig, tokens: jax.Array, pos: jax.Array,
                caches: Tuple, enc_out: Optional[jax.Array] = None
                ) -> Tuple[jax.Array, Tuple]:
    """One-token decode: tokens (B,1), pos (B,1) absolute positions."""
    x = _embed(p, cfg, tokens)
    x, caches = T.stack_decode(p["decoder"], cfg, x, pos, caches, enc_out)
    return _logits(p, cfg, x), caches


def param_count(params) -> int:
    import math
    return sum(int(math.prod(l.shape)) for l in jax.tree.leaves(params)
               if hasattr(l, "shape"))
