"""FFN and Mixture-of-Experts with sort-based static-capacity dispatch.

The MoE dispatch is a sparse gather→compute→scatter with exactly the sorted-
segment structure of this paper's hypersparse kernels (DESIGN.md §5): tokens
are sorted by routed expert, placed into fixed-capacity per-expert buffers
(static shapes ⇒ SPMD-safe; capacity overflow drops tokens, standard
capacity-factor semantics), batched through the expert FFNs as one
(E, C, D) × (E, D, F) einsum, and combined back with router weights. Under
expert-parallel sharding (E over the model axis) XLA lowers the
dispatch/undispatch scatters to all-to-alls.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig


def _act(kind: str, x_gate: jax.Array, x_lin: jax.Array) -> jax.Array:
    if kind == "swiglu":
        return jax.nn.silu(x_gate) * x_lin
    if kind == "geglu":
        return jax.nn.gelu(x_gate) * x_lin
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# dense gated FFN
# ---------------------------------------------------------------------------

def init_ffn_params(key, cfg: ArchConfig, dtype=jnp.float32) -> Dict:
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    s = d ** -0.5
    return {"w_gate": s * jax.random.normal(ks[0], (d, f), dtype),
            "w_lin": s * jax.random.normal(ks[1], (d, f), dtype),
            "w_out": f ** -0.5 * jax.random.normal(ks[2], (f, d), dtype)}


def ffn_forward(p: Dict, cfg: ArchConfig, x: jax.Array) -> jax.Array:
    from repro.models.layers import constrain
    # pin the per-layer weight slices to their (fsdp, tp) layout inside the
    # scan body — otherwise the partitioner all-reduces full-size weight
    # gradients (observed on qwen2: 145 GB/step of f32[8192,29568] ARs).
    wg = constrain(p["w_gate"], "dp", "tp")
    wl = constrain(p["w_lin"], "dp", "tp")
    wo = constrain(p["w_out"], "tp", "dp")
    return _act(cfg.ffn_kind, x @ wg, x @ wl) @ wo


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------

def init_moe_params(key, cfg: ArchConfig, dtype=jnp.float32) -> Dict:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 5)
    s = d ** -0.5
    p = {"router": s * jax.random.normal(ks[0], (d, e), dtype),
         "w_gate": s * jax.random.normal(ks[1], (e, d, f), dtype),
         "w_lin": s * jax.random.normal(ks[2], (e, d, f), dtype),
         "w_out": f ** -0.5 * jax.random.normal(ks[3], (e, f, d), dtype)}
    if cfg.n_shared_experts:
        sub = dataclasses.replace(cfg, d_ff=cfg.d_ff * cfg.n_shared_experts)
        p["shared"] = init_ffn_params(ks[4], sub, dtype)
    return p


def moe_forward(p: Dict, cfg: ArchConfig, x: jax.Array) -> jax.Array:
    """x (B, S, D) → (B, S, D). Top-k routing with sort-based dispatch done
    PER BATCH ROW (vmap), so the token sort stays device-local under
    batch-over-data sharding; only the expert einsum crosses the expert-
    parallel (model) axis — XLA lowers the (B,E,C,D) dispatch/undispatch to
    all-to-alls. The sorted-segment structure mirrors the paper's sparse
    kernels (DESIGN.md §5)."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    cap = int(cfg.capacity_factor * s * k / e)
    cap = max(8, min(cap, s * k))

    def route_row(xf):                               # xf (S, D)
        logits = xf @ p["router"]                    # (S, E)
        gates = jax.nn.softmax(logits.astype(jnp.float32), -1)
        topw, tope = jax.lax.top_k(gates, k)         # (S, k)
        topw = topw / jnp.maximum(topw.sum(-1, keepdims=True), 1e-9)
        flat_e = tope.reshape(-1)                    # (S*k,)
        flat_t = jnp.repeat(jnp.arange(s), k)
        flat_w = topw.reshape(-1)
        order = jnp.argsort(flat_e, stable=True)
        se, st_, sw = flat_e[order], flat_t[order], flat_w[order]
        seg_start = jnp.searchsorted(se, jnp.arange(e))
        pos_in_e = jnp.arange(s * k) - seg_start[se]
        keep = pos_in_e < cap
        buf = jnp.zeros((e, cap, d), xf.dtype)
        buf = buf.at[se, jnp.where(keep, pos_in_e, cap)].set(
            xf[st_], mode="drop")
        return buf, (se, st_, sw, keep, pos_in_e)

    def combine_row(out_buf, meta):
        se, st_, sw, keep, pos_in_e = meta
        contrib = out_buf[se, jnp.where(keep, pos_in_e, 0)] * \
            (sw * keep).astype(out_buf.dtype)[:, None]
        return jnp.zeros((s, d), out_buf.dtype).at[st_].add(contrib)

    from repro.models.layers import constrain
    bufs, metas = jax.vmap(route_row)(x)             # (B, E, C, D)
    # expert-parallel layout pins: dispatch buffers batch-over-dp then
    # expert-over-tp (the transition is the all-to-all); routing metadata
    # stays dp-sharded (otherwise the partitioner replicates the sort and
    # all-reduces its outputs across the model axis).
    metas = tuple(constrain(m, "dp") for m in metas)
    bufs = constrain(bufs, "dp", "tp", None, None)
    h = _act(cfg.ffn_kind,
             jnp.einsum("becd,edf->becf", bufs, p["w_gate"]),
             jnp.einsum("becd,edf->becf", bufs, p["w_lin"]))
    out_bufs = jnp.einsum("becf,efd->becd", h, p["w_out"])
    out_bufs = constrain(out_bufs, "dp", "tp", None, None)
    y = jax.vmap(combine_row)(out_bufs, metas)
    if cfg.n_shared_experts:
        y = y + ffn_forward(p["shared"], cfg, x.reshape(b * s, d)
                            ).reshape(b, s, d)
    return y


def moe_aux_loss(p: Dict, cfg: ArchConfig, x: jax.Array) -> jax.Array:
    """Load-balance auxiliary loss (Switch-style): E·Σ_e f_e·P_e."""
    b, s, d = x.shape
    logits = x.reshape(-1, d) @ p["router"]
    gates = jax.nn.softmax(logits.astype(jnp.float32), -1)
    top1 = jnp.argmax(gates, -1)
    f = jnp.mean(jax.nn.one_hot(top1, cfg.n_experts), 0)
    pmean = jnp.mean(gates, 0)
    return cfg.n_experts * jnp.sum(f * pmean)
