"""Mamba2 (SSD — state-space duality) block, minimal chunked implementation.

Follows the Mamba-2 paper's "minimal SSD" formulation: the selective SSM
    h_t = exp(A·dt_t)·h_{t-1} + dt_t·B_t x_t ,  y_t = C_tᵀ h_t + D x_t
is computed chunk-parallel: intra-chunk terms as masked (attention-like)
matmuls on the MXU, inter-chunk recurrence as a short scan over S/chunk
states. Exact (up to fp assoc.) — validated against the step-by-step
recurrent reference in tests. Decode is the single-step recurrence on a
(B, H, P, N) state cache — O(1) per token, which is why SSD archs run the
``long_500k`` cell (DESIGN.md §5).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig


def _dims(cfg: ArchConfig):
    d_in = cfg.ssm_expand * cfg.d_model
    head_p = 64 if d_in % 64 == 0 else d_in // max(1, d_in // 64)
    n_heads = d_in // head_p
    return d_in, n_heads, head_p, cfg.ssm_state


def init_mamba2_params(key, cfg: ArchConfig, dtype=jnp.float32) -> Dict:
    d = cfg.d_model
    d_in, h, p_dim, n = _dims(cfg)
    ks = jax.random.split(key, 6)
    s = d ** -0.5
    return {
        # fused input projection: [z (gate), x, B, C, dt]
        "w_in": s * jax.random.normal(ks[0], (d, 2 * d_in + 2 * n + h), dtype),
        "w_out": d_in ** -0.5 * jax.random.normal(ks[1], (d_in, d), dtype),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, h)).astype(dtype),
        "d_skip": jnp.ones((h,), dtype),
        "dt_bias": jnp.zeros((h,), dtype),
        "norm": jnp.zeros((d_in,), dtype),
    }


def _split_proj(p, cfg, u):
    d_in, h, p_dim, n = _dims(cfg)
    proj = u @ p["w_in"]
    z, x, bmat, cmat, dt = jnp.split(
        proj, [d_in, 2 * d_in, 2 * d_in + n, 2 * d_in + 2 * n], axis=-1)
    dt = jax.nn.softplus(dt + p["dt_bias"])          # (..., H)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))     # (H,) negative
    return z, x, bmat, cmat, dt, a


def mamba2_forward(p: Dict, cfg: ArchConfig, x_seq: jax.Array) -> jax.Array:
    """x_seq (B, S, D) → (B, S, D); chunked SSD as ONE scan over chunks —
    the per-step working set is Θ(B·Q²·H), never Θ(B·S·Q·H)."""
    b, s, d = x_seq.shape
    d_in, h, p_dim, n = _dims(cfg)
    q = min(cfg.ssm_chunk, s)
    while s % q:          # largest divisor of s not exceeding ssm_chunk
        q -= 1
    nc = s // q
    z, xg, bmat, cmat, dt, a = _split_proj(p, cfg, x_seq)
    xh = xg.reshape(b, nc, q, h, p_dim).transpose(1, 0, 2, 3, 4)
    bm = bmat.reshape(b, nc, q, n).transpose(1, 0, 2, 3)
    cm = cmat.reshape(b, nc, q, n).transpose(1, 0, 2, 3)
    dtc = dt.reshape(b, nc, q, h).transpose(1, 0, 2, 3).astype(jnp.float32)
    causal = jnp.tril(jnp.ones((q, q), bool))

    def chunk_step(hstate, inp):
        xc, bc, cc, dtk = inp          # (B,Q,H,P) (B,Q,N) (B,Q,N) (B,Q,H)
        da = dtk * a                                       # (B,Q,H)
        cum = jnp.cumsum(da, axis=1)
        # intra-chunk attention-like term (double-where: exp never sees the
        # positive masked-out entries, keeping the gradient finite)
        decay = cum[:, :, None, :] - cum[:, None, :, :]    # (B,Q,K,H)
        cmask = causal[None, :, :, None]
        gmat = jnp.where(cmask, jnp.exp(jnp.where(cmask, decay, 0.0)), 0.0)
        cb = jnp.einsum("bqn,bkn->bqk", cc, bc).astype(jnp.float32)
        att = cb[..., None] * gmat * dtk[:, None, :, :]    # (B,Q,K,H)
        y_intra = jnp.einsum("bqkh,bkhp->bqhp", att, xc.astype(jnp.float32))
        # inter-chunk contribution from the carried state
        y_inter = jnp.einsum("bqh,bqn,bhnp->bqhp",
                             jnp.exp(cum), cc.astype(jnp.float32), hstate)
        # state update
        last = cum[:, -1:, :]
        w_state = jnp.exp(last - cum) * dtk                # (B,Q,H)
        new_state = hstate * jnp.exp(last[:, 0])[:, :, None, None] + \
            jnp.einsum("bqh,bqn,bqhp->bhnp", w_state,
                       bc.astype(jnp.float32), xc.astype(jnp.float32))
        return new_state, y_intra + y_inter

    init = jnp.zeros((b, h, n, p_dim), jnp.float32)
    _, ys = jax.lax.scan(chunk_step, init, (xh, bm, cm, dtc))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, s, h, p_dim)
    y = y + p["d_skip"].astype(jnp.float32)[None, None, :, None] * \
        xg.reshape(b, s, h, p_dim).astype(jnp.float32)
    y = y.reshape(b, s, d_in).astype(x_seq.dtype)
    from repro.models.layers import rms_norm
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                 p["norm"], cfg.norm_eps)
    return y @ p["w_out"]


def mamba2_cache_init(cfg: ArchConfig, batch: int, dtype=jnp.float32) -> Dict:
    d_in, h, p_dim, n = _dims(cfg)
    return {"state": jnp.zeros((batch, h, n, p_dim), jnp.float32)}


def mamba2_decode(p: Dict, cfg: ArchConfig, x_tok: jax.Array,
                  cache: Dict) -> Tuple[jax.Array, Dict]:
    """x_tok (B, 1, D); single-step recurrence."""
    b = x_tok.shape[0]
    d_in, h, p_dim, n = _dims(cfg)
    z, xg, bmat, cmat, dt, a = _split_proj(p, cfg, x_tok[:, 0])
    xh = xg.reshape(b, h, p_dim).astype(jnp.float32)
    dtf = dt.astype(jnp.float32)                            # (B,H)
    dec = jnp.exp(dtf * a)                                  # (B,H)
    state = cache["state"] * dec[:, :, None, None] + \
        jnp.einsum("bh,bn,bhp->bhnp", dtf, bmat.astype(jnp.float32), xh)
    y = jnp.einsum("bn,bhnp->bhp", cmat.astype(jnp.float32), state)
    y = y + p["d_skip"].astype(jnp.float32)[None, :, None] * xh
    y = y.reshape(b, d_in).astype(x_tok.dtype)
    from repro.models.layers import rms_norm
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                 p["norm"], cfg.norm_eps)
    return (y @ p["w_out"])[:, None], {"state": state}
