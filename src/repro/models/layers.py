"""Transformer layer primitives: norms, RoPE, attention family.

Attention scopes (DESIGN.md §5):
* global  — full causal; blockwise-streamed (flash-style running-softmax scan
            over KV chunks) above a sequence threshold so the S×S score
            matrix is never materialized at 32k+;
* local   — sliding window W via re-blocking: queries in block b attend to
            blocks {b−1, b} with an exact window mask (gemma2);
* chunked — block-diagonal attention within chunks (llama4 iRoPE-style local
            layers).

Decode paths operate on a KV cache laid out (B, S_cache, KVH, hd); local
layers keep only a ring buffer of W positions. Logit soft-capping (gemma2)
and QKV bias (qwen2) are supported. MLA (minicpm3) caches the compressed
latent instead of full K/V.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, BlockSpec


# ---------------------------------------------------------------------------
# sharding-constraint context (set by launch drivers; no-op by default)
# ---------------------------------------------------------------------------

_SHARD_CTX: Dict = {"dp": None, "dp_size": 1, "tp": None, "tp_size": 1}


def set_sharding_ctx(dp=None, dp_size=1, tp=None, tp_size=1):
    """Activation-sharding hints: dp = data axes (batch dims), tp = model
    axis (head/ffn dims). Constraints are applied only where the dim is
    divisible — this pins XLA to the intended layout and stops it from
    inventing head-dim shardings when heads don't divide the model axis."""
    _SHARD_CTX.update(dp=dp, dp_size=dp_size, tp=tp, tp_size=tp_size)


def clear_sharding_ctx():
    _SHARD_CTX.update(dp=None, dp_size=1, tp=None, tp_size=1)


def constrain(x: jax.Array, *dims: str) -> jax.Array:
    """dims per axis: 'dp' | 'tp' | None. No-op when ctx unset or indivisible."""
    from jax.sharding import PartitionSpec as P
    if _SHARD_CTX["dp"] is None and _SHARD_CTX["tp"] is None:
        return x
    spec = []
    for d, kind in zip(x.shape, dims):
        if kind == "dp" and _SHARD_CTX["dp"] and d % _SHARD_CTX["dp_size"] == 0:
            spec.append(_SHARD_CTX["dp"])
        elif kind == "tp" and _SHARD_CTX["tp"] and d % _SHARD_CTX["tp_size"] == 0:
            spec.append(_SHARD_CTX["tp"])
        else:
            spec.append(None)
    spec += [None] * (x.ndim - len(spec))
    try:
        return jax.lax.with_sharding_constraint(x, P(*spec))
    except Exception:
        return x


# ---------------------------------------------------------------------------
# basics
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(jnp.square(x), -1, keepdims=True) + eps)
    return (x * (1.0 + w.astype(jnp.float32))).astype(dtype)


def softcap(x: jax.Array, cap: float) -> jax.Array:
    return jnp.tanh(x / cap) * cap if cap > 0 else x


def rope(x: jax.Array, pos: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, hd); pos: (..., S) int32. Rotates the full head dim."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = pos[..., :, None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(ang)[..., None, :].astype(x.dtype)
    sin = jnp.sin(ang)[..., None, :].astype(x.dtype)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)


def _repeat_kv(k: jax.Array, n_rep: int) -> jax.Array:
    if n_rep == 1:
        return k
    b, s, h, d = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, h, n_rep, d)
                            ).reshape(b, s, h * n_rep, d)


# ---------------------------------------------------------------------------
# core softmax attention (dense / blockwise / decode)
# ---------------------------------------------------------------------------

def _sdpa(q, k, v, mask, scale, cap) -> jax.Array:
    """q (B,Sq,H,hd), k/v (B,Sk,H,hd), mask (B|1, 1, Sq, Sk) bool."""
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k) * scale
    scores = softcap(scores.astype(jnp.float32), cap)
    scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


def _flash_stream(q, k, v, q_pos, kv_pos, scale, cap, kv_block: int,
                  window: int = 0, layout: str = "auto"):
    """Running-softmax streamed attention over KV blocks (causal, optional
    sliding window, kv positions < 0 treated as invalid).

    Exact; never materializes (Sq, Sk). Memory Θ(Sq·hd + kv_block·Sq).
    ``layout`` pins the score/accumulator sharding inside the scan ('head' =
    heads over model axis, 'seq' = query rows over model axis) — without the
    pin XLA re-shards between layouts every block (all-to-all storms)."""
    b, sq, h, hd = q.shape
    sk = k.shape[1]
    nb = sk // kv_block
    if layout == "auto":
        layout = "head" if h % max(_SHARD_CTX["tp_size"], 1) == 0 else "seq"
    hdim, qdim = ("tp", None) if layout == "head" else (None, "tp")

    def pin(x):  # (b, h, sq, ...) accumulators / scores
        return constrain(x, "dp", hdim, qdim, None)

    qf = q.astype(jnp.float32)

    def body(carry, blk):
        m, l, acc = carry
        kb, vb, pb = blk  # (B, kvb, H, hd), (B, kvb, H, hd), (B, kvb)
        s = jnp.einsum("bqhd,bkhd->bhqk", qf, kb.astype(jnp.float32)) * scale
        s = pin(softcap(s, cap))
        diff = q_pos[:, None, :, None] - pb[:, None, None, :]
        mask = (diff >= 0) & (pb[:, None, None, :] >= 0)
        if window:
            mask &= diff < window
        s = jnp.where(mask, s, -1e30)
        m_new = jnp.maximum(m, jnp.max(s, -1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + jnp.sum(p, -1)
        acc = pin(acc * corr[..., None] + jnp.einsum(
            "bhqk,bkhd->bhqd", p, vb.astype(jnp.float32)))
        return (m_new, l, acc), None

    kb = k.reshape(b, nb, kv_block, h, hd).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(b, nb, kv_block, h, hd).transpose(1, 0, 2, 3, 4)
    pb = kv_pos.reshape(b, nb, kv_block).transpose(1, 0, 2)
    init = (constrain(jnp.full((b, h, sq), -1e30, jnp.float32),
                      "dp", hdim, qdim),
            constrain(jnp.zeros((b, h, sq), jnp.float32), "dp", hdim, qdim),
            pin(jnp.zeros((b, h, sq, hd), jnp.float32)))
    # checkpointed body = flash-attention backward semantics: block scores
    # are recomputed in the bwd pass instead of being saved per iteration.
    (m, l, acc), _ = jax.lax.scan(jax.checkpoint(body), init, (kb, vb, pb))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 2, 1, 3).astype(q.dtype)  # (B, Sq, H, hd)


_FLASH_THRESHOLD = 2048


def causal_attention(q, k, v, q_pos, kv_pos, scale, cap,
                     scope: str = "global", window: int = 4096,
                     chunk: int = 8192, kv_block: int = 1024) -> jax.Array:
    """Dispatch over scope; all paths exact. Shapes: q (B,S,H,hd) with
    k/v already head-repeated to H. Long sequences stream (flash-style);
    local/chunked scopes re-block so streamed length is O(window|chunk)."""
    b, s, h, hd = q.shape
    if scope == "chunked" and s > chunk and s % chunk == 0:
        nc = s // chunk
        qc = q.reshape(b * nc, chunk, h, hd)
        kc = k.reshape(b * nc, chunk, h, hd)
        vc = v.reshape(b * nc, chunk, h, hd)
        pc = q_pos.reshape(b * nc, chunk)
        out = causal_attention(qc, kc, vc, pc, pc, scale, cap, "global",
                               kv_block=kv_block)
        return out.reshape(b, s, h, hd)
    if scope == "local" and s > window and s % window == 0:
        nb = s // window
        qb = q.reshape(b, nb, window, h, hd)
        kb = k.reshape(b, nb, window, h, hd)
        vb = v.reshape(b, nb, window, h, hd)
        k_prev = jnp.concatenate([jnp.zeros_like(kb[:, :1]), kb[:, :-1]], 1)
        v_prev = jnp.concatenate([jnp.zeros_like(vb[:, :1]), vb[:, :-1]], 1)
        k2 = jnp.concatenate([k_prev, kb], 2)  # (B, nb, 2W, H, hd)
        v2 = jnp.concatenate([v_prev, vb], 2)
        qp = q_pos.reshape(b, nb, window)
        kp_prev = jnp.where(jnp.arange(nb)[None, :, None] > 0,
                            qp - window, -jnp.ones_like(qp))
        kp = jnp.concatenate([kp_prev, qp], 2)
        out = _flash_stream(qb.reshape(b * nb, window, h, hd),
                            k2.reshape(b * nb, 2 * window, h, hd),
                            v2.reshape(b * nb, 2 * window, h, hd),
                            qp.reshape(b * nb, window),
                            kp.reshape(b * nb, 2 * window),
                            scale, cap, min(kv_block, window),
                            window=window)
        return out.reshape(b, s, h, hd)
    if s > _FLASH_THRESHOLD:
        return _flash_stream(q, k, v, q_pos, kv_pos, scale, cap, kv_block)
    mask = q_pos[:, None, :, None] >= kv_pos[:, None, None, :]
    if scope == "local":
        mask &= (q_pos[:, None, :, None] - kv_pos[:, None, None, :]) < window
    if scope == "chunked":
        mask &= (q_pos[:, None, :, None] // chunk) == \
                (kv_pos[:, None, None, :] // chunk)
    return _sdpa(q, k, v, mask, scale, cap)


# ---------------------------------------------------------------------------
# GQA attention block (with cache) — covers gemma2 / qwen2 / llama4 / phi
# ---------------------------------------------------------------------------

def init_gqa_params(key, cfg: ArchConfig, dtype=jnp.float32) -> Dict:
    hd = cfg.head_dim_()
    d = cfg.d_model
    ks = jax.random.split(key, 5)
    scale = d ** -0.5
    p = {
        "wq": scale * jax.random.normal(ks[0], (d, cfg.n_heads * hd), dtype),
        "wk": scale * jax.random.normal(ks[1], (d, cfg.n_kv_heads * hd), dtype),
        "wv": scale * jax.random.normal(ks[2], (d, cfg.n_kv_heads * hd), dtype),
        "wo": scale * jax.random.normal(ks[3], (cfg.n_heads * hd, d), dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.n_heads * hd,), dtype)
        p["bk"] = jnp.zeros((cfg.n_kv_heads * hd,), dtype)
        p["bv"] = jnp.zeros((cfg.n_kv_heads * hd,), dtype)
    return p


def gqa_forward(p: Dict, cfg: ArchConfig, spec: BlockSpec, x: jax.Array,
                pos: jax.Array) -> jax.Array:
    """Full-sequence forward (train / prefill)."""
    b, s, d = x.shape
    hd = cfg.head_dim_()
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, s, cfg.n_heads, hd)
    k = k.reshape(b, s, cfg.n_kv_heads, hd)
    v = v.reshape(b, s, cfg.n_kv_heads, hd)
    tp_div = cfg.n_heads % max(_SHARD_CTX["tp_size"], 1) == 0 and \
        cfg.n_kv_heads % max(_SHARD_CTX["tp_size"], 1) == 0 and \
        not cfg.seq_sharded_residual
    if tp_div:
        # tensor-parallel attention: heads over the model axis
        q = constrain(q, "dp", None, "tp")
        k = constrain(k, "dp", None, "tp")
        v = constrain(v, "dp", None, "tp")
    else:
        # sequence-parallel attention: query rows over the model axis,
        # K/V replicated — avoids XLA inventing head-dim shardings when
        # heads don't divide the axis
        q = constrain(q, "dp", "tp", None)
        k = constrain(k, "dp", None, None)
        v = constrain(v, "dp", None, None)
    q = rope(q, pos, cfg.rope_theta)
    k = rope(k, pos, cfg.rope_theta)
    n_rep = cfg.n_heads // cfg.n_kv_heads
    k = _repeat_kv(k, n_rep)
    v = _repeat_kv(v, n_rep)
    out = causal_attention(q, k, v, pos, pos, hd ** -0.5, cfg.attn_softcap,
                           spec.attn_scope, cfg.local_window, cfg.chunk_size)
    out = constrain(out, "dp", None, "tp")
    return out.reshape(b, s, cfg.n_heads * hd) @ p["wo"]


def gqa_cache_init(cfg: ArchConfig, spec: BlockSpec, batch: int,
                   max_len: int, dtype=jnp.float32) -> Dict:
    size = min(max_len, cfg.local_window) if spec.attn_scope == "local" \
        else (min(max_len, cfg.chunk_size) if spec.attn_scope == "chunked"
              else max_len)
    hd = cfg.head_dim_()
    shape = (batch, size, cfg.n_kv_heads, hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype),
            "pos": jnp.full((batch, size), -1, jnp.int32)}


def gqa_decode(p: Dict, cfg: ArchConfig, spec: BlockSpec, x: jax.Array,
               pos: jax.Array, cache: Dict) -> Tuple[jax.Array, Dict]:
    """Single-token decode. x (B, 1, D); pos (B, 1) absolute position.
    Cache is a ring buffer for local/chunked scopes (exact window semantics
    via stored absolute positions)."""
    b, _, d = x.shape
    hd = cfg.head_dim_()
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = rope(q.reshape(b, 1, cfg.n_heads, hd), pos, cfg.rope_theta)
    k = rope(k.reshape(b, 1, cfg.n_kv_heads, hd), pos, cfg.rope_theta)
    v = v.reshape(b, 1, cfg.n_kv_heads, hd)
    size = cache["k"].shape[1]
    # synchronized decode: all sequences share the slot (pos[0]); a single
    # dynamic_update_slice keeps the sharded-cache update SPMD-efficient
    # (per-batch scatters trigger involuntary rematerialization in the
    # partitioner). Per-sequence masking still uses the stored positions.
    slot = pos[0, 0] % size
    upd = lambda buf, new: jax.lax.dynamic_update_slice_in_dim(
        buf, new, slot, 1)
    cache = {"k": upd(cache["k"], k), "v": upd(cache["v"], v),
             "pos": upd(cache["pos"], pos)}
    n_rep = cfg.n_heads // cfg.n_kv_heads
    kk = _repeat_kv(cache["k"], n_rep)
    vv = _repeat_kv(cache["v"], n_rep)
    kv_pos = cache["pos"]
    mask = (kv_pos >= 0)[:, None, None, :] & \
           (pos[:, None, :, None] >= kv_pos[:, None, None, :])
    if spec.attn_scope == "local":
        mask &= (pos[:, None, :, None] - kv_pos[:, None, None, :]) < cfg.local_window
    if spec.attn_scope == "chunked":
        mask &= (pos[:, None, :, None] // cfg.chunk_size) == \
                (kv_pos[:, None, None, :] // cfg.chunk_size)
    out = _sdpa(q, kk, vv, mask, hd ** -0.5, cfg.attn_softcap)
    return out.reshape(b, 1, cfg.n_heads * hd) @ p["wo"], cache


# ---------------------------------------------------------------------------
# MLA (multi-head latent attention, minicpm3/deepseek style)
# ---------------------------------------------------------------------------

def init_mla_params(key, cfg: ArchConfig, dtype=jnp.float32) -> Dict:
    d = cfg.d_model
    ks = jax.random.split(key, 8)
    s = d ** -0.5
    qd = cfg.qk_nope_dim + cfg.qk_rope_dim
    return {
        "wq_a": s * jax.random.normal(ks[0], (d, cfg.q_lora_rank), dtype),
        "wq_b": s * jax.random.normal(ks[1], (cfg.q_lora_rank,
                                              cfg.n_heads * qd), dtype),
        "wkv_a": s * jax.random.normal(ks[2], (d, cfg.kv_lora_rank +
                                               cfg.qk_rope_dim), dtype),
        "wkv_b": s * jax.random.normal(
            ks[3], (cfg.kv_lora_rank,
                    cfg.n_heads * (cfg.qk_nope_dim + cfg.v_head_dim)), dtype),
        "wo": s * jax.random.normal(ks[4], (cfg.n_heads * cfg.v_head_dim, d),
                                    dtype),
        "q_norm": jnp.zeros((cfg.q_lora_rank,), dtype),
        "kv_norm": jnp.zeros((cfg.kv_lora_rank,), dtype),
    }


def _mla_qkv(p, cfg: ArchConfig, x, pos):
    b, s, _ = x.shape
    h, nd, rd, vd = cfg.n_heads, cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    q = rms_norm(x @ p["wq_a"], p["q_norm"], cfg.norm_eps) @ p["wq_b"]
    q = q.reshape(b, s, h, nd + rd)
    q_nope, q_rope = q[..., :nd], rope(q[..., nd:], pos, cfg.rope_theta)
    kv = x @ p["wkv_a"]
    latent = rms_norm(kv[..., :cfg.kv_lora_rank], p["kv_norm"], cfg.norm_eps)
    k_rope = rope(kv[..., None, cfg.kv_lora_rank:], pos, cfg.rope_theta)
    return q_nope, q_rope, latent, k_rope


def _mla_attend(p, cfg: ArchConfig, q_nope, q_rope, latent, k_rope,
                q_pos, kv_pos, valid_mask=None):
    b, sq, h = q_nope.shape[:3]
    nd, vd = cfg.qk_nope_dim, cfg.v_head_dim
    kvb = p["wkv_b"].reshape(cfg.kv_lora_rank, h, nd + vd)
    k_nope = jnp.einsum("bsl,lhd->bshd", latent, kvb[..., :nd])
    v = jnp.einsum("bsl,lhd->bshd", latent, kvb[..., nd:])
    k = jnp.concatenate([k_nope,
                         jnp.broadcast_to(k_rope[:, :, None, :],
                                          (*k_nope.shape[:3],
                                           cfg.qk_rope_dim))], -1)
    q = jnp.concatenate([q_nope, q_rope], -1)
    mask = q_pos[:, None, :, None] >= kv_pos[:, None, None, :]
    if valid_mask is not None:
        mask &= valid_mask[:, None, None, :]
    scale = (nd + cfg.qk_rope_dim) ** -0.5
    out = _sdpa(q, k, v, mask, scale, cfg.attn_softcap)
    return out.reshape(b, sq, h * vd) @ p["wo"]


def mla_forward(p: Dict, cfg: ArchConfig, spec: BlockSpec, x: jax.Array,
                pos: jax.Array) -> jax.Array:
    q_nope, q_rope, latent, k_rope = _mla_qkv(p, cfg, x, pos)
    return _mla_attend(p, cfg, q_nope, q_rope, latent,
                       k_rope[:, :, 0], pos, pos)


def mla_cache_init(cfg: ArchConfig, batch: int, max_len: int,
                   dtype=jnp.float32) -> Dict:
    return {"latent": jnp.zeros((batch, max_len, cfg.kv_lora_rank), dtype),
            "k_rope": jnp.zeros((batch, max_len, cfg.qk_rope_dim), dtype),
            "pos": jnp.full((batch, max_len), -1, jnp.int32)}


def mla_decode(p: Dict, cfg: ArchConfig, spec: BlockSpec, x: jax.Array,
               pos: jax.Array, cache: Dict) -> Tuple[jax.Array, Dict]:
    """MLA decode with WEIGHT ABSORPTION (beyond-paper §Perf optimization):
    instead of decompressing the whole latent cache to K/V every step
    (Θ(S·L·H·(nd+vd)) flops — the naive path's dominant cost), fold the
    up-projections into the query/output sides and attend in latent space:

        score_h(u) = (q_nope_h · Wk_hᵀ) · latent_u + q_rope · k_rope_u
        out_h      = (Σ_u p_u latent_u) · Wv_h

    Θ(S·H·L) flops per step — ~(nd+vd)/2 ≈ 64× fewer for minicpm3."""
    b = x.shape[0]
    h, nd, rd, vd = cfg.n_heads, cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    q_nope, q_rope, latent, k_rope = _mla_qkv(p, cfg, x, pos)
    slot = pos[0, 0]  # synchronized decode (see gqa_decode)
    upd = lambda buf, new: jax.lax.dynamic_update_slice_in_dim(
        buf, new, slot, 1)
    cache = {"latent": upd(cache["latent"], latent),
             "k_rope": upd(cache["k_rope"], k_rope[:, :, 0]),
             "pos": upd(cache["pos"], pos)}
    kvb = p["wkv_b"].reshape(cfg.kv_lora_rank, h, nd + vd)
    q_abs = jnp.einsum("bqhd,lhd->bqhl", q_nope, kvb[..., :nd])  # (B,1,H,L)
    scores = jnp.einsum("bqhl,bsl->bhqs", q_abs, cache["latent"]) + \
        jnp.einsum("bqhd,bsd->bhqs", q_rope,
                   jnp.asarray(cache["k_rope"]))
    scale = (nd + rd) ** -0.5
    scores = softcap(scores.astype(jnp.float32) * scale, cfg.attn_softcap)
    mask = (cache["pos"] >= 0)[:, None, None, :] & \
        (pos[:, None, :, None] >= cache["pos"][:, None, None, :])
    scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    ctx = jnp.einsum("bhqs,bsl->bqhl", probs, cache["latent"])
    out = jnp.einsum("bqhl,lhd->bqhd", ctx, kvb[..., nd:])
    out = out.reshape(b, 1, h * vd) @ p["wo"]
    return out, cache
