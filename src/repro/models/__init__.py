from repro.models import layers, model, moe, ssm, transformer, xlstm

__all__ = ["layers", "model", "moe", "ssm", "transformer", "xlstm"]
