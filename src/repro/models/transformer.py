"""Composable decoder/encoder stacks over a uniform Block protocol.

A model is ``n_groups`` repetitions of a static layer *group* (e.g. gemma2:
(local attn, global attn); zamba2: (mamba2 ×5, shared attn); xlstm:
(mlstm, slstm)). Parameters are stacked over groups and the stack runs as a
single ``lax.scan`` (with optional remat) — one compiled group body
regardless of depth, which keeps dry-run compiles fast and HLO small; the
roofline parser multiplies by the known trip count (DESIGN.md §6).

Block kinds: "attn" (GQA or MLA per config), "mamba2", "mlstm", "slstm".
Shared blocks (zamba2) hold one parameter set applied every group, with
per-application KV caches stacked over groups. Decoder blocks grow a
cross-attention sub-block in encoder-decoder configs (whisper).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, BlockSpec
from repro.models import layers as L
from repro.models import moe as M
from repro.models import ssm as S
from repro.models import xlstm as X


# ---------------------------------------------------------------------------
# single block
# ---------------------------------------------------------------------------

def _is_moe(cfg: ArchConfig) -> bool:
    return cfg.n_experts > 0


def init_block_params(key, cfg: ArchConfig, spec: BlockSpec,
                      cross_attn: bool = False, dtype=jnp.float32) -> Dict:
    ks = jax.random.split(key, 6)
    p: Dict[str, Any] = {"norm1": jnp.zeros((cfg.d_model,), dtype)}
    if spec.kind == "attn":
        p["attn"] = (L.init_mla_params(ks[0], cfg, dtype)
                     if cfg.attn_kind == "mla"
                     else L.init_gqa_params(ks[0], cfg, dtype))
        if cfg.ffn_kind != "none" and cfg.d_ff > 0:
            p["norm2"] = jnp.zeros((cfg.d_model,), dtype)
            p["ffn"] = (M.init_moe_params(ks[1], cfg, dtype) if _is_moe(cfg)
                        else M.init_ffn_params(ks[1], cfg, dtype))
    elif spec.kind == "mamba2":
        p["inner"] = S.init_mamba2_params(ks[0], cfg, dtype)
    elif spec.kind == "mlstm":
        p["inner"] = X.init_mlstm_params(ks[0], cfg, dtype)
    elif spec.kind == "slstm":
        p["inner"] = X.init_slstm_params(ks[0], cfg, dtype)
    else:
        raise ValueError(spec.kind)
    if cross_attn:
        p["norm_x"] = jnp.zeros((cfg.d_model,), dtype)
        p["cross"] = L.init_gqa_params(ks[2], cfg, dtype)
    return p


def block_forward(p: Dict, cfg: ArchConfig, spec: BlockSpec, x: jax.Array,
                  pos: jax.Array, enc_out: Optional[jax.Array] = None,
                  causal: bool = True) -> jax.Array:
    h = L.rms_norm(x, p["norm1"], cfg.norm_eps)
    if spec.kind == "attn":
        if cfg.attn_kind == "mla":
            x = x + L.mla_forward(p["attn"], cfg, spec, h, pos)
        else:
            x = x + (L.gqa_forward(p["attn"], cfg, spec, h, pos) if causal
                     else _bidir_attn(p["attn"], cfg, h, pos))
        if "cross" in p and enc_out is not None:
            hx = L.rms_norm(x, p["norm_x"], cfg.norm_eps)
            x = x + _cross_attn(p["cross"], cfg, hx, enc_out)
        if "ffn" in p:
            h2 = L.rms_norm(x, p["norm2"], cfg.norm_eps)
            x = x + (M.moe_forward(p["ffn"], cfg, h2) if _is_moe(cfg)
                     else M.ffn_forward(p["ffn"], cfg, h2))
        return x
    if spec.kind == "mamba2":
        return x + S.mamba2_forward(p["inner"], cfg, h)
    if spec.kind == "mlstm":
        return x + X.mlstm_forward(p["inner"], cfg, h)
    if spec.kind == "slstm":
        return x + X.slstm_forward(p["inner"], cfg, h)
    raise ValueError(spec.kind)


def _bidir_attn(p, cfg: ArchConfig, x, pos):
    b, s, d = x.shape
    hd = cfg.head_dim_()
    q = (x @ p["wq"]).reshape(b, s, cfg.n_heads, hd)
    k = (x @ p["wk"]).reshape(b, s, cfg.n_kv_heads, hd)
    v = (x @ p["wv"]).reshape(b, s, cfg.n_kv_heads, hd)
    q, k = L.rope(q, pos, cfg.rope_theta), L.rope(k, pos, cfg.rope_theta)
    rep = cfg.n_heads // cfg.n_kv_heads
    k, v = L._repeat_kv(k, rep), L._repeat_kv(v, rep)
    mask = jnp.ones((b, 1, s, s), bool)
    out = L._sdpa(q, k, v, mask, hd ** -0.5, cfg.attn_softcap)
    return out.reshape(b, s, cfg.n_heads * hd) @ p["wo"]


def _cross_attn(p, cfg: ArchConfig, x, enc_out):
    b, s, d = x.shape
    se = enc_out.shape[1]
    hd = cfg.head_dim_()
    q = (x @ p["wq"]).reshape(b, s, cfg.n_heads, hd)
    k = (enc_out @ p["wk"]).reshape(b, se, cfg.n_kv_heads, hd)
    v = (enc_out @ p["wv"]).reshape(b, se, cfg.n_kv_heads, hd)
    rep = cfg.n_heads // cfg.n_kv_heads
    k, v = L._repeat_kv(k, rep), L._repeat_kv(v, rep)
    mask = jnp.ones((b, 1, s, se), bool)
    out = L._sdpa(q, k, v, mask, hd ** -0.5, 0.0)
    return out.reshape(b, s, cfg.n_heads * hd) @ p["wo"]


def block_cache_init(cfg: ArchConfig, spec: BlockSpec, batch: int,
                     max_len: int, dtype=jnp.float32) -> Dict:
    if spec.kind == "attn":
        if cfg.attn_kind == "mla":
            return L.mla_cache_init(cfg, batch, max_len, dtype)
        return L.gqa_cache_init(cfg, spec, batch, max_len, dtype)
    if spec.kind == "mamba2":
        return S.mamba2_cache_init(cfg, batch, dtype)
    if spec.kind == "mlstm":
        return X.mlstm_cache_init(cfg, batch)
    if spec.kind == "slstm":
        return X.slstm_cache_init(cfg, batch, dtype)
    raise ValueError(spec.kind)


def block_decode(p: Dict, cfg: ArchConfig, spec: BlockSpec, x: jax.Array,
                 pos: jax.Array, cache: Dict,
                 enc_out: Optional[jax.Array] = None
                 ) -> Tuple[jax.Array, Dict]:
    h = L.rms_norm(x, p["norm1"], cfg.norm_eps)
    if spec.kind == "attn":
        if cfg.attn_kind == "mla":
            y, cache = L.mla_decode(p["attn"], cfg, spec, h, pos, cache)
        else:
            y, cache = L.gqa_decode(p["attn"], cfg, spec, h, pos, cache)
        x = x + y
        if "cross" in p and enc_out is not None:
            hx = L.rms_norm(x, p["norm_x"], cfg.norm_eps)
            x = x + _cross_attn(p["cross"], cfg, hx, enc_out)
        if "ffn" in p:
            h2 = L.rms_norm(x, p["norm2"], cfg.norm_eps)
            x = x + (M.moe_forward(p["ffn"], cfg, h2) if _is_moe(cfg)
                     else M.ffn_forward(p["ffn"], cfg, h2))
        return x, cache
    if spec.kind == "mamba2":
        y, cache = S.mamba2_decode(p["inner"], cfg, h, cache)
    elif spec.kind == "mlstm":
        y, cache = X.mlstm_decode(p["inner"], cfg, h, cache)
    elif spec.kind == "slstm":
        y, cache = X.slstm_decode(p["inner"], cfg, h, cache)
    else:
        raise ValueError(spec.kind)
    return x + y, cache


# ---------------------------------------------------------------------------
# stacks
# ---------------------------------------------------------------------------

def init_stack_params(key, cfg: ArchConfig, cross_attn: bool = False,
                      dtype=jnp.float32) -> Dict:
    """Stacked group params: blocks[slot] has leaves (n_groups, ...)."""
    g = cfg.n_groups
    blocks: List[Any] = []
    shared = None
    for slot, spec in enumerate(cfg.group):
        if spec.shared:
            shared = init_block_params(jax.random.fold_in(key, 1000 + slot),
                                       cfg, spec, cross_attn, dtype)
            blocks.append(None)
            continue
        ks = jax.random.split(jax.random.fold_in(key, slot), g)
        per = [init_block_params(k, cfg, spec, cross_attn, dtype) for k in ks]
        blocks.append(jax.tree.map(lambda *xs: jnp.stack(xs), *per))
    return {"blocks": blocks, "shared": shared}


def stack_forward(params: Dict, cfg: ArchConfig, x: jax.Array,
                  pos: jax.Array, enc_out: Optional[jax.Array] = None,
                  causal: bool = True, remat: bool = True) -> jax.Array:
    specs = cfg.group
    scanned = tuple(b for b in params["blocks"] if b is not None)

    res_spec = ("dp", "tp", None) if cfg.seq_sharded_residual \
        else ("dp", None, None)

    def group_body(x, slices):
        it = iter(slices)
        for spec, stacked in zip(specs, params["blocks"]):
            p = params["shared"] if stacked is None else next(it)
            x = block_forward(p, cfg, spec, x, pos, enc_out, causal)
            x = L.constrain(x, *res_spec)
        return x, None

    body = jax.checkpoint(group_body) if remat else group_body
    x, _ = jax.lax.scan(body, x, scanned)
    return x


def stack_cache_init(cfg: ArchConfig, batch: int, max_len: int,
                     dtype=jnp.float32) -> Tuple:
    """Caches stacked over groups for every slot (incl. shared slots)."""
    g = cfg.n_groups
    caches = []
    for spec in cfg.group:
        one = block_cache_init(cfg, spec, batch, max_len, dtype)
        caches.append(jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (g, *x.shape)).copy(), one))
    return tuple(caches)


def stack_decode(params: Dict, cfg: ArchConfig, x: jax.Array, pos: jax.Array,
                 caches: Tuple, enc_out: Optional[jax.Array] = None
                 ) -> Tuple[jax.Array, Tuple]:
    specs = cfg.group

    def group_body(x, slices_and_caches):
        slices, caches_g = slices_and_caches
        it = iter(slices)
        new_caches = []
        for slot, (spec, stacked) in enumerate(zip(specs, params["blocks"])):
            p = params["shared"] if stacked is None else next(it)
            x, c = block_decode(p, cfg, spec, x, pos, caches_g[slot], enc_out)
            new_caches.append(c)
        return x, tuple(new_caches)

    scanned = tuple(b for b in params["blocks"] if b is not None)
    x, new_caches = jax.lax.scan(group_body, x, (scanned, caches))
    return x, new_caches
