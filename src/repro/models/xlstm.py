"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory, parallelizable)
and sLSTM (scalar memory, strictly recurrent), with exponential gating and
the paper's max-state stabilization.

Both are implemented as exact recurrences via ``lax.scan`` over time (one
compiled body regardless of sequence length); the chunkwise-parallel mLSTM
form is a §Perf candidate, not needed for correctness. Decode is the same
step function on a carried state — O(1) per token, so xlstm runs the
``long_500k`` cell.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import rms_norm


def _hd(cfg: ArchConfig) -> int:
    return cfg.d_model // cfg.n_heads


# ---------------------------------------------------------------------------
# mLSTM: matrix memory C (H, hd_k, hd_v), exp input gate, sig forget gate
# ---------------------------------------------------------------------------

def init_mlstm_params(key, cfg: ArchConfig, dtype=jnp.float32) -> Dict:
    d, h, hd = cfg.d_model, cfg.n_heads, _hd(cfg)
    ks = jax.random.split(key, 6)
    s = d ** -0.5
    return {
        "wq": s * jax.random.normal(ks[0], (d, h * hd), dtype),
        "wk": s * jax.random.normal(ks[1], (d, h * hd), dtype),
        "wv": s * jax.random.normal(ks[2], (d, h * hd), dtype),
        "w_gates": s * jax.random.normal(ks[3], (d, 2 * h), dtype),
        "b_gates": jnp.concatenate([jnp.zeros((h,), dtype),
                                    3.0 * jnp.ones((h,), dtype)]),
        "wo": s * jax.random.normal(ks[4], (h * hd, d), dtype),
        "norm": jnp.zeros((h * hd,), dtype),
    }


def _mlstm_step(carry, qkvif, hd):
    c, nrm, mstab = carry            # (B,H,hdk,hdv), (B,H,hdk), (B,H)
    q, k, v, i_pre, f_pre = qkvif    # (B,H,hd) ×3, (B,H) ×2
    logf = jax.nn.log_sigmoid(f_pre)
    m_new = jnp.maximum(logf + mstab, i_pre)
    i_g = jnp.exp(i_pre - m_new)
    f_g = jnp.exp(logf + mstab - m_new)
    c = f_g[..., None, None] * c + i_g[..., None, None] * \
        (k[..., :, None] * v[..., None, :])
    nrm = f_g[..., None] * nrm + i_g[..., None] * k
    num = jnp.einsum("bhk,bhkv->bhv", q, c)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", q, nrm)),
                      jnp.exp(-m_new))
    out = num / den[..., None]
    return (c, nrm, m_new), out


def _mlstm_qkvif(p, cfg, x):
    b, s, d = x.shape
    h, hd = cfg.n_heads, _hd(cfg)
    q = (x @ p["wq"]).reshape(b, s, h, hd) * hd ** -0.5
    k = (x @ p["wk"]).reshape(b, s, h, hd) * hd ** -0.5
    v = (x @ p["wv"]).reshape(b, s, h, hd)
    gates = (x @ p["w_gates"] + p["b_gates"]).astype(jnp.float32)
    i_pre, f_pre = gates[..., :h], gates[..., h:]
    return q, k, v, i_pre, f_pre


def mlstm_forward(p: Dict, cfg: ArchConfig, x: jax.Array) -> jax.Array:
    if cfg.xlstm_chunk and x.shape[1] % cfg.xlstm_chunk == 0 \
            and x.shape[1] > cfg.xlstm_chunk:
        return _mlstm_forward_chunked(p, cfg, x, cfg.xlstm_chunk)
    b, s, d = x.shape
    h, hd = cfg.n_heads, _hd(cfg)
    q, k, v, i_pre, f_pre = _mlstm_qkvif(p, cfg, x)
    xs = tuple(a.transpose(1, 0, 2, 3) if a.ndim == 4 else a.transpose(1, 0, 2)
               for a in (q, k, v, i_pre, f_pre))
    init = (jnp.zeros((b, h, hd, hd), jnp.float32),
            jnp.zeros((b, h, hd), jnp.float32),
            -jnp.inf * jnp.ones((b, h), jnp.float32))
    step = lambda c, inp: _mlstm_step(
        c, tuple(a.astype(jnp.float32) for a in inp), hd)
    _, ys = jax.lax.scan(step, init, xs)
    y = ys.transpose(1, 0, 2, 3).reshape(b, s, h * hd).astype(x.dtype)
    return rms_norm(y, p["norm"], cfg.norm_eps) @ p["wo"]


def _mlstm_forward_chunked(p: Dict, cfg: ArchConfig, x: jax.Array,
                           q_chunk: int) -> jax.Array:
    """Chunkwise-parallel mLSTM (§Perf optimization): intra-chunk terms as
    decay-masked matmuls on the MXU, inter-chunk recurrence as a scan over
    S/chunk matrix-memory states — the SSD-style schedule applied to mLSTM.
    Exact up to the running-max stabilizer, which is applied per chunk
    (log-gates accumulate in f32; validated against the recurrent reference
    in tests). Per-step work Θ(B·Q²·H) on the MXU vs Θ(S) sequential steps.
    """
    b, s, d = x.shape
    h, hd = cfg.n_heads, _hd(cfg)
    nc = s // q_chunk
    q, k, v, i_pre, f_pre = _mlstm_qkvif(p, cfg, x)
    qc = q.reshape(b, nc, q_chunk, h, hd).transpose(1, 0, 2, 3, 4)
    kc = k.reshape(b, nc, q_chunk, h, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, nc, q_chunk, h, hd).transpose(1, 0, 2, 3, 4)
    ic = i_pre.reshape(b, nc, q_chunk, h).transpose(1, 0, 2, 3)
    fc = f_pre.reshape(b, nc, q_chunk, h).transpose(1, 0, 2, 3)
    causal = jnp.tril(jnp.ones((q_chunk, q_chunk), bool))

    def chunk_step(carry, inp):
        # carry: scaled state  C = exp(M_s)·c̃ ,  n = exp(M_s)·ñ
        cmat, nvec, m_s = inp_c = carry
        qb, kb, vb, ib, fb = inp
        qb = qb.astype(jnp.float32)
        kb = kb.astype(jnp.float32)
        vb = vb.astype(jnp.float32)
        logf = jax.nn.log_sigmoid(fb)            # (B,Q,H)
        cumf = jnp.cumsum(logf, axis=1)          # F_t (includes t)
        a_u = ib - cumf                          # i_u − F_u (chunk-local)
        m_chunk = jax.lax.cummax(a_u, axis=1)    # running max of a_u
        m_t = jnp.maximum(m_chunk, m_s[:, None, :])   # (B,Q,H) global stab.
        # intra-chunk: coefficient exp(F_t − F_u + i_u − (F_t + m_t))
        #            = exp(a_u − m_t)
        dec = a_u[:, None, :, :] - m_t[:, :, None, :]
        cmask = causal[None, :, :, None]
        gmat = jnp.where(cmask, jnp.exp(jnp.where(cmask, dec, 0.0)), 0.0)
        att = jnp.einsum("bqhd,bkhd->bqkh", qb, kb) * gmat
        y_intra = jnp.einsum("bqkh,bkhd->bqhd", att, vb)
        n_intra = jnp.einsum("bqkh,bkhd->bqhd", gmat, kb)
        # inter-chunk: C contribution scaled exp(F_t) (u ≤ chunk start);
        # stabilized coefficient exp(M_s − m_t)  (C̃ already /exp(M_s))
        inter_w = jnp.exp(m_s[:, None, :] - m_t)      # (B,Q,H)
        y_inter = jnp.einsum("bqh,bqhk,bhkv->bqhv", inter_w, qb, cmat)
        n_inter = jnp.einsum("bqh,bhk->bqhk", inter_w, nvec)
        num = y_intra + y_inter
        den = jnp.abs(jnp.einsum("bqhk,bqhk->bqh", qb, n_intra + n_inter))
        # global m at position t is F_t + m_t; out denominator floor exp(−m)
        floor = jnp.exp(-(cumf + m_t))
        out = num / jnp.maximum(den, floor)[..., None]
        # state update. Invariant: μ = max_u a_u in the NEXT chunk's local
        # frame; frames shift by f_tot (= F at chunk end) between chunks:
        #   a^frame(c+1) = a^frame(c) + f_tot.
        f_tot = cumf[:, -1]                      # (B,H)
        m_end = jnp.maximum(m_s, m_chunk[:, -1])     # frame-c max
        scale_old = jnp.exp(m_s - m_end)
        w_u = jnp.exp(a_u - m_end[:, None])          # exp(a_u − M_end)
        c_new = scale_old[:, :, None, None] * cmat + \
            jnp.einsum("bqh,bqhk,bqhv->bhkv", w_u, kb, vb)
        n_new = scale_old[:, :, None] * nvec + \
            jnp.einsum("bqh,bqhk->bhk", w_u, kb)
        m_new = m_end + f_tot                        # re-expressed in c+1
        return (c_new, n_new, m_new), out

    init = (jnp.zeros((b, h, hd, hd), jnp.float32),
            jnp.zeros((b, h, hd), jnp.float32),
            jnp.full((b, h), -1e30, jnp.float32))
    _, ys = jax.lax.scan(chunk_step, init, (qc, kc, vc, ic, fc))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, s, h * hd).astype(x.dtype)
    return rms_norm(y, p["norm"], cfg.norm_eps) @ p["wo"]


def mlstm_cache_init(cfg: ArchConfig, batch: int) -> Dict:
    h, hd = cfg.n_heads, _hd(cfg)
    return {"c": jnp.zeros((batch, h, hd, hd), jnp.float32),
            "n": jnp.zeros((batch, h, hd), jnp.float32),
            "m": -jnp.inf * jnp.ones((batch, h), jnp.float32)}


def mlstm_decode(p: Dict, cfg: ArchConfig, x: jax.Array,
                 cache: Dict) -> Tuple[jax.Array, Dict]:
    b = x.shape[0]
    h, hd = cfg.n_heads, _hd(cfg)
    q, k, v, i_pre, f_pre = _mlstm_qkvif(p, cfg, x)
    carry = (cache["c"], cache["n"], cache["m"])
    inp = tuple(a[:, 0].astype(jnp.float32) for a in (q, k, v, i_pre, f_pre))
    (c, nrm, m), out = _mlstm_step(carry, inp, hd)
    y = out.reshape(b, 1, h * hd).astype(x.dtype)
    y = rms_norm(y, p["norm"], cfg.norm_eps) @ p["wo"]
    return y, {"c": c, "n": nrm, "m": m}


# ---------------------------------------------------------------------------
# sLSTM: scalar memory per head-unit, exp gating with stabilizer state
# ---------------------------------------------------------------------------

def init_slstm_params(key, cfg: ArchConfig, dtype=jnp.float32) -> Dict:
    d = cfg.d_model
    ks = jax.random.split(key, 3)
    s = d ** -0.5
    # fused [z, i, f, o] projections
    return {"w": s * jax.random.normal(ks[0], (d, 4 * d), dtype),
            "r": s * jax.random.normal(ks[1], (d, 4 * d), dtype),
            "b": jnp.concatenate([jnp.zeros((d,), dtype),
                                  jnp.zeros((d,), dtype),
                                  3.0 * jnp.ones((d,), dtype),
                                  jnp.zeros((d,), dtype)]),
            "wo": s * jax.random.normal(ks[2], (d, d), dtype),
            "norm": jnp.zeros((d,), dtype)}


def _slstm_step(p, cfg, carry, wx):
    c, nrm, m, y_prev = carry
    d = cfg.d_model
    pre = (wx + y_prev @ p["r"] + p["b"]).astype(jnp.float32)
    z, i_pre, f_pre, o_pre = jnp.split(pre, 4, axis=-1)
    logf = jax.nn.log_sigmoid(f_pre)
    m_new = jnp.maximum(logf + m, i_pre)
    i_g = jnp.exp(i_pre - m_new)
    f_g = jnp.exp(logf + m - m_new)
    c = f_g * c + i_g * jnp.tanh(z)
    nrm = f_g * nrm + i_g
    hval = jax.nn.sigmoid(o_pre) * c / jnp.maximum(nrm, 1.0)
    return (c, nrm, m_new, hval.astype(wx.dtype)), hval


def slstm_forward(p: Dict, cfg: ArchConfig, x: jax.Array) -> jax.Array:
    b, s, d = x.shape
    wx = (x @ p["w"]).transpose(1, 0, 2)      # (S, B, 4D)
    init = (jnp.zeros((b, d), jnp.float32), jnp.zeros((b, d), jnp.float32),
            -jnp.inf * jnp.ones((b, d), jnp.float32),
            jnp.zeros((b, d), x.dtype))
    step = lambda c, inp: _slstm_step(p, cfg, c, inp)
    _, ys = jax.lax.scan(step, init, wx)
    y = ys.transpose(1, 0, 2).astype(x.dtype)
    return rms_norm(y, p["norm"], cfg.norm_eps) @ p["wo"]


def slstm_cache_init(cfg: ArchConfig, batch: int, dtype=jnp.float32) -> Dict:
    d = cfg.d_model
    return {"c": jnp.zeros((batch, d), jnp.float32),
            "n": jnp.zeros((batch, d), jnp.float32),
            "m": -jnp.inf * jnp.ones((batch, d), jnp.float32),
            "y": jnp.zeros((batch, d), dtype)}


def slstm_decode(p: Dict, cfg: ArchConfig, x: jax.Array,
                 cache: Dict) -> Tuple[jax.Array, Dict]:
    wx = (x @ p["w"])[:, 0]
    carry = (cache["c"], cache["n"], cache["m"], cache["y"])
    (c, nrm, m, yc), h = _slstm_step(p, cfg, carry, wx)
    y = rms_norm(h[:, None].astype(x.dtype), p["norm"], cfg.norm_eps) @ p["wo"]
    return y, {"c": c, "n": nrm, "m": m, "y": yc}
