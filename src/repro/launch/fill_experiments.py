"""Inject the generated dry-run/roofline tables into EXPERIMENTS.md.

    PYTHONPATH=src python -m repro.launch.fill_experiments \
        --dir experiments/dryrun_final --doc EXPERIMENTS.md
"""
from __future__ import annotations

import argparse

from repro.launch.report import dryrun_table, load, roofline_table


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun_final")
    ap.add_argument("--doc", default="EXPERIMENTS.md")
    args = ap.parse_args()
    recs = load(args.dir)
    text = open(args.doc).read()
    text = text.replace("<!-- DRYRUN_TABLE -->", dryrun_table(recs))
    text = text.replace("<!-- ROOFLINE_TABLE -->", roofline_table(recs))
    open(args.doc, "w").write(text)
    print(f"injected {len(recs)} records into {args.doc}")


if __name__ == "__main__":
    main()
