"""Roofline-term extraction from compiled HLO (DESIGN.md §6).

``compiled.cost_analysis()`` on the CPU backend is per-device and counts
while (lax.scan) bodies ONCE; exact trip counts live in each while op's
``backend_config.known_trip_count``. This parser therefore derives all three
roofline terms directly from ``compiled.as_text()``:

* compute   — Σ dot flops (2·|out|·contracted), weighted by enclosing-loop
              trip counts;
* memory    — Σ top-level op buffer traffic (operand+output bytes; fusion
              internals excluded: fusion outputs are materialized buffers),
              weighted likewise;
* collective— Σ wire bytes of all-gather/all-reduce/reduce-scatter/
              all-to-all/collective-permute with standard ring-cost factors
              and replica-group-local sizes, weighted likewise.

Cross-checks: unweighted flops must match cost_analysis()['flops']; the
MODEL_FLOPS/HLO_FLOPS ratio is reported per cell in EXPERIMENTS.md.
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, List, Optional, Tuple

import numpy as np

# TPU v5e hardware constants (per chip)
PEAK_FLOPS = 197e12        # bf16
HBM_BW = 819e9             # bytes/s
LINK_BW = 50e9             # bytes/s per ICI link

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3": 1,
                "f8e5m2": 1, "s64": 8, "u64": 8, "s32": 4, "u32": 4,
                "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
                "c64": 8, "c128": 16, "token": 0, "opaque": 0}

_SHAPE_RE = re.compile(r"\b([a-z]\d*[a-z0-9]*)\[([\d,]*)\]")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _parse_shapes(type_str: str) -> List[Tuple[str, Tuple[int, ...]]]:
    """All dtype[shape] tokens in a type string (tuples flattened)."""
    out = []
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        shape = tuple(int(x) for x in dims.split(",") if x) if dims else ()
        out.append((dt, shape))
    return out


def _nbytes(type_str: str) -> int:
    return sum(_DTYPE_BYTES[dt] * int(np.prod(sh, dtype=np.int64)) if sh
               else _DTYPE_BYTES[dt]
               for dt, sh in _parse_shapes(type_str))


@dataclasses.dataclass
class OpInfo:
    name: str
    out_type: str
    op: str
    line: str


class HloModule:
    def __init__(self, text: str):
        self.computations: Dict[str, List[OpInfo]] = {}
        self.entry: Optional[str] = None
        self.def_types: Dict[str, str] = {}
        cur = None
        for line in text.splitlines():
            m = re.match(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->", line)
            if m and not line.startswith(" "):
                cur = m.group(2)
                self.computations[cur] = []
                if m.group(1):
                    self.entry = cur
                continue
            if cur is None:
                continue
            om = re.match(r"\s+(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(\(.*?\)|\S+)\s+"
                          r"([\w\-]+)\(", line)
            if om:
                name, out_type, op = om.group(1), om.group(2), om.group(3)
                self.computations[cur].append(OpInfo(name, out_type, op, line))
                self.def_types[name] = out_type

        # while bodies -> (parent computation, trip count)
        self.body_info: List[Tuple[str, str, int]] = []
        for comp, ops in self.computations.items():
            for op in ops:
                if op.op == "while":
                    bm = re.search(r"body=%?([\w.\-]+)", op.line)
                    tm = re.search(r'known_trip_count[^}]*?"n":"(\d+)"',
                                   op.line)
                    trip = int(tm.group(1)) if tm else 1
                    if bm:
                        self.body_info.append((comp, bm.group(1), trip))

        # weights: entry = 1, while body = parent weight * trip (iterated)
        self.weights: Dict[str, float] = {}
        if self.entry:
            self.weights[self.entry] = 1.0
        for _ in range(8):  # propagate through nesting
            changed = False
            for parent, body, trip in self.body_info:
                if parent in self.weights:
                    w = self.weights[parent] * trip
                    if self.weights.get(body) != w:
                        self.weights[body] = w
                        changed = True
            if not changed:
                break

    # -- per-op costs -------------------------------------------------------

    def _operands(self, line: str, opname: str) -> List[str]:
        parts = line.split(opname + "(", 1)
        if len(parts) < 2:
            return []
        args = parts[1].split(")", 1)[0]
        return re.findall(r"%([\w.\-]+)", args)

    def _dot_flops(self, op: OpInfo) -> float:
        out_elems = int(np.prod(_parse_shapes(op.out_type)[0][1],
                                dtype=np.int64))
        ops_ = self._operands(op.line, op.op)
        lhs_type = self.def_types.get(ops_[0], "")
        lhs_shapes = _parse_shapes(lhs_type)
        if not lhs_shapes:
            return 0.0
        lhs_shape = lhs_shapes[0][1]
        cm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.line)
        cdims = [int(x) for x in cm.group(1).split(",") if x] if cm else []
        contracted = int(np.prod([lhs_shape[d] for d in cdims],
                                 dtype=np.int64)) if cdims else 1
        return 2.0 * out_elems * contracted

    def _collective_wire_bytes(self, op: OpInfo) -> float:
        gm = re.search(r"replica_groups=\[(\d+),(\d+)\]", op.line)
        if gm:
            gsize = int(gm.group(2))
        else:
            lm = re.search(r"replica_groups=\{\{([\d,]+)\}", op.line)
            gsize = len(lm.group(1).split(",")) if lm else 2
        payload = _nbytes(op.out_type)
        k = op.op
        if gsize <= 1:
            return 0.0
        if k == "all-reduce":
            return 2.0 * (gsize - 1) / gsize * payload
        if k == "all-gather":
            return (gsize - 1) / gsize * payload
        if k == "reduce-scatter":
            in_bytes = sum(_nbytes(self.def_types.get(o, ""))
                           for o in self._operands(op.line, op.op))
            return (gsize - 1) / gsize * max(in_bytes, payload)
        if k == "all-to-all":
            return (gsize - 1) / gsize * payload
        return float(payload)  # collective-permute

    _SKIP_TRAFFIC = {"tuple", "get-tuple-element", "parameter", "bitcast",
                     "constant", "after-all", "iota", "while", "conditional"}
    # ops that address a window of their operands rather than the whole
    # buffer: charging full operand bytes would bill a 32k-step scan for
    # re-reading loop-invariant weights every iteration, which VMEM
    # residency / in-place slicing avoids on TPU. Operand traffic for these
    # is capped at 4× the output size (elementwise fusions are unaffected;
    # dots are standalone ops and always pay full operand traffic).
    _SLICED_ACCESS = {"fusion", "dynamic-slice", "dynamic-update-slice",
                      "gather", "scatter", "copy"}

    def _op_traffic(self, op: OpInfo) -> float:
        if op.op in self._SKIP_TRAFFIC:
            return 0.0
        out_b = _nbytes(op.out_type)
        cap = 4 * out_b if op.op in self._SLICED_ACCESS else None
        in_b = 0
        for o in self._operands(op.line, op.op):
            b = _nbytes(self.def_types.get(o, ""))
            in_b += min(b, cap) if cap is not None else b
        return float(out_b + in_b)

    # -- module totals --------------------------------------------------------

    def totals(self) -> Dict[str, float]:
        flops_w = flops_u = bytes_w = coll_w = 0.0
        coll_by_kind: Dict[str, float] = {}
        coll_counts: Dict[str, int] = {}
        for comp, w in self.weights.items():
            for op in self.computations.get(comp, []):
                if op.op == "dot":
                    f = self._dot_flops(op)
                    flops_w += w * f
                    flops_u += f
                if op.op in _COLLECTIVES:
                    b = self._collective_wire_bytes(op)
                    coll_w += w * b
                    coll_by_kind[op.op] = coll_by_kind.get(op.op, 0.0) + w * b
                    coll_counts[op.op] = coll_counts.get(op.op, 0) + 1
                bytes_w += w * self._op_traffic(op)
        return {"flops": flops_w, "flops_body_once": flops_u,
                "bytes": bytes_w, "collective_bytes": coll_w,
                "collective_by_kind": coll_by_kind,
                "collective_counts": coll_counts}


def roofline_terms(hlo_text: str, chips: int,
                   model_flops_total: Optional[float] = None
                   ) -> Dict[str, float]:
    """Per-device roofline terms in seconds (+ metadata).

    HLO is already SPMD-partitioned ⇒ parsed quantities are per-device."""
    mod = HloModule(hlo_text)
    t = mod.totals()
    compute_s = t["flops"] / PEAK_FLOPS
    memory_s = t["bytes"] / HBM_BW
    collective_s = t["collective_bytes"] / LINK_BW
    dominant = max(("compute", compute_s), ("memory", memory_s),
                   ("collective", collective_s), key=lambda kv: kv[1])[0]
    out = {"compute_s": compute_s, "memory_s": memory_s,
           "collective_s": collective_s, "dominant": dominant,
           "hlo_flops_per_device": t["flops"],
           "hlo_bytes_per_device": t["bytes"],
           "collective_bytes_per_device": t["collective_bytes"],
           "collective_by_kind": t["collective_by_kind"],
           "collective_counts": t["collective_counts"]}
    if model_flops_total:
        out["model_flops_total"] = model_flops_total
        out["useful_flops_ratio"] = model_flops_total / max(
            t["flops"] * chips, 1.0)
    bound = max(compute_s, memory_s, collective_s)
    out["roofline_fraction"] = compute_s / bound if bound > 0 else 0.0
    return out


def model_flops(cfg, cell) -> float:
    """MODEL_FLOPS = 6·N·D (dense) / 6·N_active·D (MoE); decode counts one
    token per sequence."""
    n_active = active_params(cfg)
    if cell.kind == "train":
        tokens = cell.global_batch * cell.seq_len
        return 6.0 * n_active * tokens
    if cell.kind == "prefill":
        tokens = cell.global_batch * cell.seq_len
        return 2.0 * n_active * tokens
    return 2.0 * n_active * cell.global_batch  # decode: 1 token/seq


def active_params(cfg) -> float:
    """Per-token active parameter count from the config (embeddings included
    once; MoE counts top_k + shared experts)."""
    d, f, v = cfg.d_model, cfg.d_ff, cfg.vocab
    hd = cfg.head_dim_()
    per_attn = d * hd * (cfg.n_heads * 2 + cfg.n_kv_heads * 2)
    if cfg.attn_kind == "mla":
        qd = cfg.qk_nope_dim + cfg.qk_rope_dim
        per_attn = (d * cfg.q_lora_rank + cfg.q_lora_rank * cfg.n_heads * qd +
                    d * (cfg.kv_lora_rank + cfg.qk_rope_dim) +
                    cfg.kv_lora_rank * cfg.n_heads *
                    (cfg.qk_nope_dim + cfg.v_head_dim) +
                    cfg.n_heads * cfg.v_head_dim * d)
    ffn_active = 3 * d * f
    if cfg.n_experts:
        ffn_active = 3 * d * f * (cfg.top_k + cfg.n_shared_experts)
    n = 0.0
    for spec in cfg.group:
        if spec.kind == "attn":
            n += per_attn + (ffn_active if cfg.ffn_kind != "none" and f else 0)
        elif spec.kind == "mamba2":
            d_in = cfg.ssm_expand * d
            n += d * (2 * d_in + 2 * cfg.ssm_state) + d_in * d
        elif spec.kind == "mlstm":
            n += 3 * d * hd * cfg.n_heads + cfg.n_heads * hd * d
        elif spec.kind == "slstm":
            n += 9 * d * d
    n *= cfg.n_groups
    n += 2 * d * v if not cfg.tie_embeddings else d * v
    if cfg.encoder_layers:
        n += cfg.encoder_layers * (per_attn + 3 * d * f) + \
            cfg.n_layers * per_attn  # cross-attention
    return n
