"""Paper-scale experiment harness: named (algorithm × loss × rank × dataset)
sweeps with per-sweep JSON metrics — the reproduction of the paper's study
shapes (Figures 6–8):

    python -m repro.launch.experiment --spec netflix-small --out results
    python -m repro.launch.experiment --list

Each spec streams its dataset through the out-of-core ingest
(``repro.data.streaming`` → ``CompletionDataset.from_stream``) with a
deterministic held-out split, then runs every requested (algorithm, loss)
pair through the existing solvers and ``RestartableLoop`` checkpointing
(per-sweep metric history rides in the checkpoint manifest, so an
interrupted experiment resumes with its metrics intact). Output is one JSON
file per spec: fit time, train/held-out RMSE, Poisson deviance and the
generalized-loss objective per sweep.

Algorithm × loss semantics (paper §2): ``ggn`` and ``gcp`` optimize the
requested loss natively (second-/first-order generalized-loss solvers);
``als``/``ccd``/``sgd`` are quadratic-update solvers — under a non-quadratic
loss they run their quadratic surrogate while the metrics report the
requested loss, which is exactly the paper's Fig.-8 comparison of quadratic
methods against Poisson methods on count data. The JSON records ``loss``
(evaluated), ``update_loss`` (optimized) and ``link`` (identity, or log for
the ``*_log`` losses, where held-out metrics evaluate exp(model) in rate
space).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time
from typing import Optional, Tuple

from repro import obs

ALGORITHMS = ("als", "ccd", "sgd", "ggn", "gcp")


@dataclasses.dataclass(frozen=True)
class ExperimentSpec:
    """One named experiment family (a paper figure's study shape)."""
    name: str
    dataset: str                       # "function" | "netflix" | "file"
    shape: Tuple[int, ...]
    nnz: int
    chunk_size: int
    rank: int
    sweeps: int
    algorithms: Tuple[str, ...] = ("als", "ccd", "sgd", "ggn")
    # "poisson_log" is the Poisson loss with log link — the well-posed
    # pairing for unconstrained solvers (identity-link "poisson" is
    # unbounded below for negative models and available via --losses)
    losses: Tuple[str, ...] = ("quadratic", "poisson_log")
    test_fraction: float = 0.1
    lam: float = 1e-4
    lr: float = 1e-3
    sample_rate: float = 0.5
    cg_iters: int = 20
    # initial Levenberg-Marquardt damping for ggn; None = per-loss default
    # (the fast-varying exp curvature of the *_log losses needs a stiff
    # start — the adaptive schedule relaxes it once steps are trusted)
    damping: Optional[float] = None
    seed: int = 0
    zipf_a: float = 1.1
    num_shards: int = 1
    file: Optional[str] = None         # triplet path for dataset="file"
    note: str = ""


SPECS = {s.name: s for s in [
    ExperimentSpec(
        "function-small", "function", (60, 50, 40), nnz=20_000,
        chunk_size=8_192, rank=8, sweeps=6,
        note="scaled-down Fig. 7a model problem"),
    ExperimentSpec(
        "netflix-small", "netflix", (150, 120, 40), nnz=40_000,
        chunk_size=8_192, rank=8, sweeps=6,
        note="scaled-down Fig. 7b/8 netflix-like ratings"),
    ExperimentSpec(
        "netflix-ci", "netflix", (80, 60, 20), nnz=15_000,
        chunk_size=4_096, rank=6, sweeps=4,
        note="nightly-CI shape: every algorithm under both losses"),
    ExperimentSpec(
        "paper-netflix", "netflix", (480_189, 17_770, 2_182),
        nnz=100_477_727, chunk_size=1 << 22, rank=32, sweeps=20,
        num_shards=256, lam=1e-2,
        note="full Netflix scale (paper Fig. 7b); needs a real mesh"),
    ExperimentSpec(
        "paper-function", "function", (16_384, 16_384, 16_384),
        nnz=10_000_000_000, chunk_size=1 << 24, rank=10, sweeps=10,
        num_shards=1024,
        note="paper headline: 10B nonzeros at ~2e-3 density on 256 nodes"),
]}


# ---------------------------------------------------------------------------
# solver construction (LOCAL ctx; the mesh path lives in launch/complete.py)
# ---------------------------------------------------------------------------

def make_solver(algorithm: str, loss_name: str, st, omega, factors,
                spec: ExperimentSpec):
    """Returns ``(state0, step, get_factors, update_loss_name, link)`` for
    one (algorithm, loss) run; ``step(i, state) -> state`` is jit-backed.

    ``als``/``ccd``/``sgd`` optimize their quadratic surrogate (identity
    link) whatever the evaluated loss; ``ggn``/``gcp`` optimize the
    requested loss — for ``*_log`` losses the model parameterizes
    log-rates, so held-out evaluation uses the exp (``log``) link."""
    import jax

    from repro.core import losses as LOSS
    from repro.core.completion import (als_sweep, ccd_sweep, gcp_adam_init,
                                       gcp_step, ggn_init, ggn_sweep,
                                       sgd_sweep)
    from repro.core.completion.ccd import residual_values

    loss = LOSS.LOSSES[loss_name]
    key = jax.random.PRNGKey(spec.seed + 1)

    link = ("log" if algorithm in ("ggn", "gcp")
            and loss_name.endswith("_log") else "identity")
    if algorithm == "als":
        fn = jax.jit(lambda s, o, fs: tuple(als_sweep(
            s, o, list(fs), spec.lam, cg_iters=spec.cg_iters)))
        return (tuple(factors),
                lambda i, fs: fn(st, omega, tuple(fs)),
                lambda state: list(state), "quadratic", link)
    if algorithm == "ccd":
        fn = jax.jit(lambda s, fs, rho: (lambda f, r_: (tuple(f), r_))(
            *ccd_sweep(s, list(fs), rho, spec.lam)))
        rho0 = residual_values(st, list(factors))
        return ((tuple(factors), rho0),
                lambda i, state: fn(st, state[0], state[1]),
                lambda state: list(state[0]), "quadratic", link)
    if algorithm == "sgd":
        sample = max(1024, int(spec.sample_rate * (st.nnz or st.cap)))
        fn = jax.jit(lambda k, s, fs: tuple(sgd_sweep(
            k, s, list(fs), spec.lam, spec.lr, sample)))
        return (tuple(factors),
                lambda i, fs: fn(jax.random.fold_in(key, i), st, tuple(fs)),
                lambda state: list(state), "quadratic", link)
    if algorithm == "ggn":
        damping = spec.damping
        if damping is None:
            damping = 10.0 if loss_name.endswith("_log") else 1e-5
        fn = jax.jit(lambda s, state: ggn_sweep(
            s, state, loss, spec.lam, cg_iters=spec.cg_iters))
        return (ggn_init(list(factors), damping=damping),
                lambda i, state: fn(st, state),
                lambda state: list(state.factors), loss_name, link)
    if algorithm == "gcp":
        fn = jax.jit(lambda s, fs, ad: (lambda f, a: (tuple(f), a))(
            *gcp_step(s, list(fs), loss, spec.lam, spec.lr, ad)))
        return ((tuple(factors), gcp_adam_init(list(factors))),
                lambda i, state: fn(st, tuple(state[0]), state[1]),
                lambda state: list(state[0]), loss_name, link)
    raise ValueError(f"unknown algorithm {algorithm!r}; "
                     f"choices: {ALGORITHMS}")


# ---------------------------------------------------------------------------
# runner
# ---------------------------------------------------------------------------

def run_experiment(spec: ExperimentSpec, out_dir: str = "experiments",
                   ckpt_root: Optional[str] = None,
                   algorithms: Optional[Tuple[str, ...]] = None,
                   losses: Optional[Tuple[str, ...]] = None,
                   spool_dir: Optional[str] = None,
                   trace: bool = False,
                   plan_cache: Optional[str] = None) -> dict:
    """Run every (algorithm, loss) pair of ``spec`` and write
    ``<out_dir>/experiment_<name>.json``; returns the report dict.
    ``trace=True`` enables obs tracing with a JSONL event stream at
    ``<out_dir>/trace_<name>.jsonl`` (per-sweep span trees additionally
    ride the metric history in the checkpoint manifest). ``plan_cache``
    autotunes the kernel tiles right after ingest (before any solver
    jit-traces) and persists the winners to that JSON file — a rerun of
    the same spec restores them with zero timings."""
    import jax

    if trace:
        os.makedirs(out_dir, exist_ok=True)
        obs.enable(jsonl=os.path.join(out_dir, f"trace_{spec.name}.jsonl"))
        obs.get_registry().reset()     # summary scoped to this experiment

    from repro.core import losses as LOSS
    from repro.core.completion.gcp import gcp_loss
    from repro.data import streaming
    from repro.data.pipeline import CompletionDataset
    from repro.runtime.fault_tolerance import RestartableLoop

    algorithms = tuple(algorithms or spec.algorithms)
    losses = tuple(losses or spec.losses)
    for a in algorithms:
        if a not in ALGORITHMS:
            raise ValueError(f"unknown algorithm {a!r}")
    for l in losses:
        if l not in LOSS.LOSSES:
            raise ValueError(f"unknown loss {l!r}")

    t_ing = time.perf_counter()
    chunks = streaming.make_stream(spec.dataset, spec.seed, spec.shape,
                                   spec.nnz, spec.chunk_size,
                                   path=spec.file, zipf_a=spec.zipf_a)
    ds = CompletionDataset.from_stream(
        chunks, spec.shape, num_shards=spec.num_shards,
        test_fraction=spec.test_fraction, spool_dir=spool_dir,
        bucket_modes=())
    ingest_seconds = time.perf_counter() - t_ing
    st, omega, test_st = ds.tensor, ds.omega, ds.test
    stats = ds.stats
    print(f"spec={spec.name} dataset={spec.dataset} shape={spec.shape} "
          f"train_nnz={st.nnz} test_nnz={test_st.nnz if test_st else 0} "
          f"dups_dropped={stats.duplicates_dropped} "
          f"ingest={ingest_seconds:.1f}s")

    plan_cache = plan_cache or os.environ.get("REPRO_PLAN_CACHE")
    tune_summary = None
    if plan_cache:
        # must precede make_solver: the jit'd sweeps bake the tile table in
        # at trace time (DESIGN.md §13)
        from repro.planner import tuner
        tune_key = jax.random.fold_in(jax.random.PRNGKey(spec.seed), 97)
        tks = jax.random.split(tune_key, len(spec.shape))
        tune_factors = [jax.random.normal(k, (d, spec.rank)) / spec.rank ** 0.5
                        for k, d in zip(tks, spec.shape)]
        tune_summary = tuner.ensure_tuned(st, tune_factors, omega=omega,
                                          cache_path=plan_cache)
        print(f"plan-cache: hits={tune_summary['hits']} "
              f"measured={tune_summary['measured']} "
              f"vmem_pruned={tune_summary['vmem_pruned']} "
              f"winners={tune_summary['winners']}")

    report = {
        "spec": {**dataclasses.asdict(spec), "shape": list(spec.shape)},
        "ingest": {
            "seconds": ingest_seconds,
            "nnz": stats.nnz,
            "test_nnz": int(test_st.nnz) if test_st is not None else 0,
            "chunks": stats.chunks,
            "entries_read": stats.entries_read,
            "duplicates_dropped": stats.duplicates_dropped,
            "nnz_rows": list(stats.nnz_rows),
            "shard_nnz": list(stats.shard_nnz),
            "busy_seconds": stats.ingest_seconds,
            "mnnz_per_s": stats.mnnz_per_s,
            "spills": stats.spills,
            "peak_rss_mb": stats.peak_rss_mb,
        },
        "runs": [],
    }
    if tune_summary is not None:
        report["plan_cache"] = {"path": plan_cache,
                                "hits": tune_summary["hits"],
                                "measured": tune_summary["measured"],
                                "vmem_pruned": tune_summary["vmem_pruned"],
                                "winners": tune_summary["winners"]}

    for loss_name in losses:
        loss = LOSS.LOSSES[loss_name]
        for algorithm in algorithms:
            import zlib
            run_key = jax.random.fold_in(
                jax.random.PRNGKey(spec.seed),
                zlib.crc32(f"{algorithm}/{loss_name}".encode()) % (2 ** 31))
            ks = jax.random.split(run_key, len(spec.shape))
            factors = [jax.random.normal(k, (d, spec.rank)) / spec.rank ** 0.5
                       for k, d in zip(ks, spec.shape)]
            state0, step, get_factors, update_loss, link = make_solver(
                algorithm, loss_name, st, omega, factors, spec)
            # the objective tracks what the solver actually minimizes (the
            # quadratic surrogate for als/ccd/sgd) — a meaningful monotone
            # quantity; the held-out metrics evaluate the requested loss
            upd_loss = LOSS.LOSSES[update_loss]
            obj_fn = jax.jit(
                lambda fs, _l=upd_loss: gcp_loss(st, list(fs), _l, spec.lam))

            metrics: list = []

            def loop_step(i, state, _m=metrics, _step=step,
                          _get=get_factors, _obj=obj_fn, _link=link):
                if i > 0 and not _m:
                    # resumed: rebuild the pre-failure metric history from
                    # the checkpoint manifest (RestartableLoop.last_metadata)
                    _m.extend(loop.last_metadata.get("metrics", [])[:i])
                t0 = time.perf_counter()
                with obs.span("sweep", algorithm=algorithm, loss=loss_name,
                              sweep=i) as sp:
                    state = _step(i, state)
                    sp.fence(jax.tree.leaves(state)[0])
                dt = time.perf_counter() - t0
                fs = _get(state)
                train = streaming.heldout_metrics(st, fs, link=_link)
                entry = {"sweep": i, "seconds": dt,
                         "objective": float(_obj(tuple(fs))),
                         "rmse_train": train["rmse"]}
                if test_st is not None:
                    test = streaming.heldout_metrics(test_st, fs, link=_link)
                    entry["rmse_test"] = test["rmse"]
                    entry["poisson_deviance_test"] = test["poisson_deviance"]
                if sp.record is not None:
                    # per-sweep span tree (nested planner/kernel spans when
                    # the solver ran any eager dispatch) rides the metric
                    # history into the checkpoint manifest, so a resumed
                    # experiment keeps its telemetry (DESIGN.md §11)
                    entry["trace"] = sp.record
                _m.append(entry)
                print(f"  [{algorithm}/{loss_name}] sweep {i:3d} "
                      f"{dt * 1e3:8.1f} ms  obj={entry['objective']:.5g}  "
                      f"rmse_test={entry.get('rmse_test', float('nan')):.5f}")
                return state

            ckpt_dir = os.path.join(
                ckpt_root or os.path.join(out_dir, "ckpt"),
                spec.name, f"{algorithm}-{loss_name}")
            loop = RestartableLoop(ckpt_dir, loop_step, ckpt_every=5,
                                   metadata_fn=lambda step, _m=metrics:
                                   {"metrics": _m})
            t0 = time.perf_counter()
            loop.run(state0, spec.sweeps)
            if not metrics:
                # resumed past the end (experiment already complete): no
                # sweep ran, so rebuild the history from the manifest
                metrics.extend(loop.last_metadata.get("metrics", []))
            report["runs"].append({
                "algorithm": algorithm, "loss": loss_name,
                "update_loss": update_loss, "link": link, "rank": spec.rank,
                "total_seconds": time.perf_counter() - t0,
                "sweeps": metrics,
                "final": metrics[-1] if metrics else None,
            })

    if trace:
        report["obs"] = obs.get_registry().summary()
        obs.emit_event({"kind": "experiment_summary", "spec": spec.name,
                        "obs": report["obs"]})
    os.makedirs(out_dir, exist_ok=True)
    out_path = os.path.join(out_dir, f"experiment_{spec.name}.json")
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2)
    print(f"wrote {out_path} ({len(report['runs'])} runs)")
    if trace:
        obs.disable()
    return report


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(description=__doc__.split("\n\n")[0])
    ap.add_argument("--spec", default=None, choices=sorted(SPECS),
                    help="named experiment spec")
    ap.add_argument("--list", action="store_true",
                    help="list available specs and exit")
    ap.add_argument("--out", default="experiments", metavar="DIR")
    ap.add_argument("--algorithms", default=None,
                    help="comma list overriding the spec's algorithms")
    ap.add_argument("--losses", default=None,
                    help="comma list overriding the spec's losses")
    ap.add_argument("--sweeps", type=int, default=None)
    ap.add_argument("--rank", type=int, default=None)
    ap.add_argument("--nnz", type=int, default=None)
    ap.add_argument("--num-shards", type=int, default=None)
    ap.add_argument("--spool-dir", default=None,
                    help="spill ingest runs to disk (out-of-core)")
    ap.add_argument("--ckpt-root", default=None)
    ap.add_argument("--trace", action="store_true",
                    help="enable obs tracing; writes trace_<spec>.jsonl "
                         "next to the experiment JSON")
    ap.add_argument("--plan-cache", default=None, metavar="PATH",
                    help="on-disk kernel-tile plan cache (JSON): autotune "
                         "the Pallas tiles after ingest and persist the "
                         "winners (default: $REPRO_PLAN_CACHE; unset "
                         "disables tuning)")
    return ap


def main():
    args = build_parser().parse_args()
    if args.list or args.spec is None:
        for name, s in sorted(SPECS.items()):
            print(f"{name:16s} {s.dataset:9s} shape={s.shape} nnz={s.nnz} "
                  f"rank={s.rank} sweeps={s.sweeps} — {s.note}")
        if args.spec is None and not args.list:
            raise SystemExit("pick one with --spec NAME")
        return
    spec = SPECS[args.spec]
    overrides = {k: getattr(args, k) for k in
                 ("sweeps", "rank", "nnz", "num_shards")
                 if getattr(args, k) is not None}
    if overrides:
        spec = dataclasses.replace(spec, **overrides)
    run_experiment(
        spec, out_dir=args.out, ckpt_root=args.ckpt_root,
        algorithms=tuple(args.algorithms.split(",")) if args.algorithms
        else None,
        losses=tuple(args.losses.split(",")) if args.losses else None,
        spool_dir=args.spool_dir, trace=args.trace,
        plan_cache=args.plan_cache)


if __name__ == "__main__":
    main()
