"""Production mesh definitions.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state; the dry-run sets
XLA_FLAGS before any jax import to materialize 512 host devices.

Mesh shapes: single pod = (16, 16) ("data", "model") = 256 chips;
multi-pod = (2, 16, 16) ("pod", "data", "model") = 512 chips. The "pod" axis
is an extra data-parallel (FSDP) axis with slower links — collectives over
it are what the multi-pod dry-run proves out.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh over however many (fake) devices tests configured."""
    return jax.make_mesh(shape, axes)


def dp_axes(mesh) -> tuple:
    """The data-parallel axes of a production mesh ('pod' included)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def dp_size(mesh) -> int:
    import numpy as np
    return int(np.prod([mesh.shape[a] for a in dp_axes(mesh)]))
