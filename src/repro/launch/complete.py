"""Tensor-completion driver (the paper's workload):

    python -m repro.launch.complete --dataset function --algorithm als \
        --rank 10 --sweeps 10 [--nnz 200000 --dims 200,180,160]

Algorithms: ``als`` (implicit-CG, quadratic loss), ``ccd``/``ccd_tttp``
(CCD++, einsum or TTTP-routed), ``sgd`` (sampled subgradient), ``gcp``
(first-order generalized-loss GD/Adam), and ``ggn`` (damped generalized
Gauss-Newton / Levenberg–Marquardt on the eq.-3 weighted Gram matvec —
second-order, any ``--loss``; see ``completion.gauss_newton`` and
DESIGN.md §8). Runs on a synthetic function tensor or Netflix-shaped
tensor, with checkpoint/restart via the fault-tolerant runner. Distribution
(when devices are available) follows DESIGN.md §4; on one CPU device the
identical code runs with the LOCAL ctx — parallelism-oblivious, as the
paper prescribes."""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.core import losses as LOSS
from repro.core.completion import (als_sweep, ccd_sweep, ccd_sweep_tttp,
                                   gcp_adam_init, gcp_step, ggn_init,
                                   ggn_sweep, sgd_sweep)
from repro.core.completion.ccd import residual_values
from repro.core.distributed import LOCAL
from repro.core.sparse_tensor import SparseTensor
from repro.core.tttp import multilinear_values
from repro.data import synthetic
from repro.runtime.fault_tolerance import RestartableLoop


def rmse(st: SparseTensor, factors) -> float:
    model = multilinear_values(st, factors)
    d = (st.values - model) * st.mask
    n = jnp.maximum(jnp.sum(st.mask), 1)
    return float(jnp.sqrt(jnp.sum(jnp.square(d)) / n))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="function",
                    choices=["function", "netflix"])
    ap.add_argument("--algorithm", default="als",
                    choices=["als", "ccd", "ccd_tttp", "sgd", "gcp", "ggn"])
    ap.add_argument("--loss", default="quadratic",
                    choices=list(LOSS.LOSSES))
    ap.add_argument("--dims", default="200,180,160")
    ap.add_argument("--nnz", type=int, default=200_000)
    ap.add_argument("--rank", type=int, default=10)
    ap.add_argument("--lam", type=float, default=1e-5)
    ap.add_argument("--sweeps", type=int, default=10)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--sample-rate", type=float, default=0.1)
    ap.add_argument("--cg-iters", type=int, default=20)
    ap.add_argument("--damping", type=float, default=1e-5,
                    help="initial Levenberg-Marquardt damping (ggn)")
    ap.add_argument("--matvec-path", default=None,
                    choices=["auto", "fused", "tttp_mttkrp", "sliced",
                             "dense"],
                    help="planner path for the ggn weighted Gram matvec "
                         "(DESIGN.md §8); default: direct kernel "
                         "composition. NOTE: the sweep is jit'd, where "
                         "'fused' falls back to the tttp_mttkrp "
                         "composition (host bucketize needs concrete "
                         "data); the fused kernel itself is exercised "
                         "eagerly by benchmarks/bench_gauss_newton.py")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_completion_ckpt")
    args = ap.parse_args()

    shape = tuple(int(x) for x in args.dims.split(","))
    key = jax.random.PRNGKey(0)
    if args.dataset == "function":
        st = synthetic.function_tensor(key, shape, args.nnz)
    else:
        st = synthetic.netflix_like(key, shape, args.nnz)
    st = synthetic.shuffle_and_pad(st, key, 1)
    omega = st.with_values(jnp.ones_like(st.values))

    r = args.rank
    ks = jax.random.split(key, len(shape))
    factors = [jax.random.normal(k, (d, r)) / r ** 0.5
               for k, d in zip(ks, shape)]
    print(f"dataset={args.dataset} shape={shape} nnz={st.nnz} rank={r} "
          f"algorithm={args.algorithm} loss={args.loss}")

    loss = LOSS.LOSSES[args.loss]
    sample = max(1024, int(args.sample_rate * st.nnz))

    if args.algorithm == "als":
        fn = jax.jit(lambda s, o, fs: als_sweep(
            s, o, fs, args.lam, cg_iters=args.cg_iters, ctx=LOCAL))
        state0 = tuple(factors)
        step = lambda i, fs: tuple(fn(st, omega, list(fs)))
    elif args.algorithm in ("ccd", "ccd_tttp"):
        sweep = ccd_sweep if args.algorithm == "ccd" else ccd_sweep_tttp
        fn = jax.jit(lambda s, fs, rho: sweep(s, list(fs), rho, args.lam))
        rho0 = residual_values(st, factors)
        state0 = (tuple(factors), rho0)
        step = lambda i, stt: (lambda fs, rho: (tuple(fs), rho))(
            *fn(st, stt[0], stt[1]))
    elif args.algorithm == "sgd":
        fn = jax.jit(lambda k, s, fs: sgd_sweep(
            k, s, list(fs), args.lam, args.lr, sample))
        state0 = tuple(factors)
        step = lambda i, fs: tuple(fn(jax.random.fold_in(key, i), st,
                                      list(fs)))
    elif args.algorithm == "ggn":
        if args.matvec_path == "fused":
            print("note: under jit the 'fused' matvec path falls back to "
                  "the tttp_mttkrp composition (see --help)")
        fn = jax.jit(lambda s, stt: ggn_sweep(
            s, stt, loss, args.lam, cg_iters=args.cg_iters,
            matvec_path=args.matvec_path))
        state0 = ggn_init(factors, damping=args.damping)
        step = lambda i, stt: fn(st, stt)
    else:  # gcp
        ad0 = gcp_adam_init(factors)
        fn = jax.jit(lambda s, fs, ad: gcp_step(
            s, list(fs), loss, args.lam, args.lr, ad))
        state0 = (tuple(factors), ad0)
        step = lambda i, stt: (lambda fs, ad: (tuple(fs), ad))(
            *fn(st, list(stt[0]), stt[1]))

    def get_factors(state):
        return list(state[0]) if isinstance(state, tuple) and \
            isinstance(state[0], tuple) else list(state)

    hist = []

    def loop_step(i, state):
        t0 = time.perf_counter()
        state = step(i, state)
        jax.block_until_ready(jax.tree.leaves(state)[0])
        dt = time.perf_counter() - t0
        e = rmse(st, get_factors(state))
        hist.append((i, dt, e))
        print(f"sweep {i:3d}  {dt*1e3:8.1f} ms  rmse={e:.6f}")
        return state

    loop = RestartableLoop(args.ckpt_dir, loop_step, ckpt_every=5)
    loop.run(state0, args.sweeps)
    print(f"final rmse={hist[-1][2]:.6f} "
          f"(mean sweep {sum(h[1] for h in hist)/len(hist)*1e3:.1f} ms)")


if __name__ == "__main__":
    main()
