"""Tensor-completion driver (the paper's workload):

    python -m repro.launch.complete --dataset function --algorithm als \
        --rank 10 --sweeps 10 [--nnz 200000 --dims 200,180,160] \
        [--mesh 4,2 --force-host-devices 8]

Algorithms: ``als`` (implicit-CG, quadratic loss), ``ccd``/``ccd_tttp``
(CCD++, einsum or TTTP-routed), ``sgd`` (sampled subgradient), ``gcp``
(first-order generalized-loss GD/Adam), and ``ggn`` (damped generalized
Gauss-Newton / Levenberg–Marquardt on the eq.-3 weighted Gram matvec —
second-order, any ``--loss``; see ``completion.gauss_newton`` and
DESIGN.md §8). Runs on a synthetic function tensor or Netflix-shaped
tensor, with checkpoint/restart via the fault-tolerant runner.

Distribution (DESIGN.md §4, §9): ``--mesh R,C`` builds a ``("data",
"model")`` mesh (shapes per ``--mesh-axes``), ingests the dataset through
``data.pipeline.CompletionDataset`` (nonzeros sharded over the data axes,
ingest-time CCSR bucket views attached), and runs every sweep under
``shard_map`` with the matching ``AxisCtx`` — the identical algorithm code,
contractions dispatched through ``planner.execute`` with the ctx's psums.
On CPU containers ``--force-host-devices N`` materializes N host devices
(must be set before jax initializes — hence the deferred imports below).
Without ``--mesh`` the same code runs with the LOCAL ctx — parallelism-
oblivious, as the paper prescribes."""
from __future__ import annotations

import argparse
import os
import time


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="function",
                    choices=["function", "netflix"])
    ap.add_argument("--algorithm", default="als",
                    choices=["als", "ccd", "ccd_tttp", "sgd", "gcp", "ggn"])
    ap.add_argument("--loss", default="quadratic")
    ap.add_argument("--dims", default="200,180,160")
    ap.add_argument("--nnz", type=int, default=200_000)
    ap.add_argument("--rank", type=int, default=10)
    ap.add_argument("--lam", type=float, default=1e-5)
    ap.add_argument("--sweeps", type=int, default=10)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--sample-rate", type=float, default=0.1)
    ap.add_argument("--cg-iters", type=int, default=20)
    ap.add_argument("--cg-tol", type=float, default=1e-4,
                    help="batched-CG relative residual tolerance (als/ggn)")
    ap.add_argument("--damping", type=float, default=1e-5,
                    help="initial Levenberg-Marquardt damping (ggn)")
    ap.add_argument("--matvec-path", default=None,
                    choices=["auto", "fused", "tttp_mttkrp", "sliced",
                             "dense"],
                    help="planner path for the ggn weighted Gram matvec "
                         "(DESIGN.md §8); default: direct kernel "
                         "composition. Under jit/shard_map 'fused' falls "
                         "back to the tttp_mttkrp composition (the cached "
                         "bucket pattern does not cross the tracer "
                         "boundary); the fused kernel itself is exercised "
                         "eagerly by benchmarks/bench_gauss_newton.py")
    ap.add_argument("--mesh", default=None, metavar="R,C",
                    help="mesh shape, e.g. '4,2' = 4-way data x 2-way "
                         "model; requires that many devices "
                         "(--force-host-devices on CPU)")
    ap.add_argument("--mesh-axes", default="data,model",
                    help="axis names matching --mesh (comma list)")
    ap.add_argument("--data-axes", default="data",
                    help="which mesh axes shard the nonzeros (comma list); "
                         "remaining axes column-shard the factors (model)")
    ap.add_argument("--force-host-devices", type=int, default=0,
                    metavar="N",
                    help="force N XLA host (CPU) devices before jax "
                         "initializes — the CPU stand-in for a real "
                         "multi-chip platform")
    ap.add_argument("--block-rows", type=int, default=None,
                    help="CCSR bucket granularity for the ingest-time "
                         "bucket views (default: PlannerConfig.block_rows)")
    ap.add_argument("--plan-cache", default=None, metavar="PATH",
                    help="on-disk kernel-tile plan cache (JSON). Autotunes "
                         "the Pallas kernel tiles at startup — before the "
                         "jit'd sweeps trace, which bake the tiles in — and "
                         "persists the measured winners; a second run of "
                         "the same workload re-installs them with zero "
                         "timings. Default: $REPRO_PLAN_CACHE; unset "
                         "disables tuning")
    ap.add_argument("--dump-factors", default=None, metavar="PATH",
                    help="write the final factor matrices to PATH. A .npz "
                         "path keeps the legacy flat format (keys "
                         "factor_0..factor_{N-1}); any other path becomes "
                         "a repro.checkpoint step directory with the fit "
                         "metadata (rank/shape/loss/link) in the manifest "
                         "— the format launch/serve_complete.py restores")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_completion_ckpt")
    return ap


def main():
    args = build_parser().parse_args()
    if args.force_host_devices:
        flags = os.environ.get("XLA_FLAGS", "")
        os.environ["XLA_FLAGS"] = (
            f"{flags} --xla_force_host_platform_device_count="
            f"{args.force_host_devices}").strip()
        os.environ.setdefault("JAX_PLATFORMS", "cpu")

    # deferred: repro.kernels probes jax.devices() at import, which pins the
    # backend — XLA_FLAGS must be in the environment first
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.core import losses as LOSS
    from repro.core.completion import (als_sweep, ccd_sweep, ccd_sweep_tttp,
                                       gcp_adam_init, gcp_step, ggn_init,
                                       ggn_sweep, sgd_sweep)
    from repro.core.completion.gcp import AdamState
    from repro.core.completion.ccd import residual_values
    from repro.core.completion.gauss_newton import GGNState
    from repro.core.distributed import AxisCtx, DistLayout, LOCAL
    from repro.core.sparse_tensor import SparseTensor
    from repro.core.tttp import multilinear_values
    from repro.data import synthetic
    from repro.data.pipeline import CompletionDataset
    from repro.runtime.fault_tolerance import RestartableLoop

    if args.loss not in LOSS.LOSSES:
        raise SystemExit(f"unknown --loss {args.loss}; "
                         f"choices: {sorted(LOSS.LOSSES)}")

    if args.block_rows is not None:
        # retune the process-wide default so ingest (CompletionDataset) and
        # planner dispatch agree on the bucket granularity
        from repro.planner import PlannerConfig, set_default_config
        set_default_config(PlannerConfig(block_rows=args.block_rows))

    def rmse(st: SparseTensor, factors) -> float:
        model = multilinear_values(st, factors)
        d = (st.values - model) * st.mask
        n = jnp.maximum(jnp.sum(st.mask), 1)
        return float(jnp.sqrt(jnp.sum(jnp.square(d)) / n))

    # ---- mesh / ctx ------------------------------------------------------
    mesh, ctx = None, LOCAL
    data_axes = ("data",)
    f_spec = None
    if args.mesh:
        mesh_shape = tuple(int(x) for x in args.mesh.split(","))
        axes = tuple(a.strip() for a in args.mesh_axes.split(","))
        need = int(np.prod(mesh_shape))
        have = len(jax.devices())
        if need > have:
            raise SystemExit(
                f"--mesh {args.mesh} needs {need} devices but only {have} "
                f"are visible; on CPU pass --force-host-devices {need}")
        mesh = jax.make_mesh(mesh_shape, axes)
        data_axes = tuple(a for a in args.data_axes.split(",") if a)
        model_axes = [a for a in axes if a not in data_axes]
        model_axis = model_axes[0] if model_axes else None
        if args.algorithm in ("ccd", "ccd_tttp"):
            # CCD updates one column at a time — factors stay replicated
            # (no model axis), nonzeros/residuals shard over data
            model_axis = None
        layout = DistLayout(mesh, data_axes, model_axis)
        ctx = layout.ctx
        f_spec = (P(None, model_axis) if args.algorithm
                  not in ("ccd", "ccd_tttp") else P(None, None))
        print(f"mesh={dict(zip(axes, mesh_shape))} data_axes={data_axes} "
              f"model_axis={model_axis} devices={have}")
    elif len(jax.devices()) > 1:
        print(f"note: {len(jax.devices())} devices visible but no --mesh "
              f"given — running LOCAL (single-device semantics); pass "
              f"--mesh to distribute")

    # ---- dataset ingest (shared shuffle/pad/shard + bucket views) --------
    shape = tuple(int(x) for x in args.dims.split(","))
    key = jax.random.PRNGKey(0)
    if args.dataset == "function":
        raw = synthetic.function_tensor(key, shape, args.nnz)
    else:
        raw = synthetic.netflix_like(key, shape, args.nnz)
    # every sweep below is jit'd/shard_map'd, where the host-side bucket
    # pattern cache cannot cross the tracer boundary — skip the ingest
    # build (bucket_modes=()); eager consumers (benchmarks, interactive
    # solves) keep CompletionDataset's default per-mode build
    ds = CompletionDataset(raw, key, mesh=mesh, data_axes=data_axes,
                           block_rows=args.block_rows, bucket_modes=())
    st, omega = ds.tensor, ds.omega

    r = args.rank
    ks = jax.random.split(key, len(shape))
    factors = [jax.random.normal(k, (d, r)) / r ** 0.5
               for k, d in zip(ks, shape)]
    nd = len(shape)
    print(f"dataset={args.dataset} shape={shape} nnz={st.nnz} rank={r} "
          f"algorithm={args.algorithm} loss={args.loss}")

    # ---- kernel-tile autotuning (must precede the jit'd sweeps: the tile
    # table is read at trace time, so tuning later would not retile them) --
    plan_cache = args.plan_cache or os.environ.get("REPRO_PLAN_CACHE")
    if plan_cache:
        if mesh is not None:
            print("note: --plan-cache tuning skipped under --mesh (tiles "
                  "are tuned on single-device eager kernels)")
        else:
            from repro.planner import tuner
            summary = tuner.ensure_tuned(st, factors, omega=omega,
                                         cache_path=plan_cache)
            print(f"plan-cache: hits={summary['hits']} "
                  f"measured={summary['measured']} "
                  f"vmem_pruned={summary['vmem_pruned']} "
                  f"winners={summary['winners']}")

    loss = LOSS.LOSSES[args.loss]
    sample = max(1024, int(args.sample_rate * st.nnz))

    def wrap(fn, in_specs, out_specs):
        """jit, under shard_map when a mesh is configured."""
        if mesh is None:
            return jax.jit(fn)
        return jax.jit(shard_map(fn, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_rep=False))

    if mesh is not None:
        st_spec = layout.sparse_specs(st)
        fs_spec = (f_spec,) * nd
    else:
        st_spec = fs_spec = None

    if args.algorithm == "als":
        fn = wrap(lambda s, o, fs: tuple(als_sweep(
                      s, o, list(fs), args.lam, cg_tol=args.cg_tol,
                      cg_iters=args.cg_iters, ctx=ctx)),
                  (st_spec, st_spec, fs_spec), fs_spec)
        state0 = tuple(factors)
        step = lambda i, fs: tuple(fn(st, omega, tuple(fs)))
    elif args.algorithm in ("ccd", "ccd_tttp"):
        sweep = ccd_sweep if args.algorithm == "ccd" else ccd_sweep_tttp
        fn = wrap(lambda s, fs, rho: (lambda f, r_: (tuple(f), r_))(
                      *sweep(s, list(fs), rho, args.lam, ctx=ctx)),
                  (st_spec, fs_spec, None if mesh is None
                   else st_spec.values),
                  (fs_spec, None if mesh is None else st_spec.values))
        rho0 = residual_values(st, factors)
        state0 = (tuple(factors), rho0)
        step = lambda i, stt: fn(st, stt[0], stt[1])
    elif args.algorithm == "sgd":
        fn = wrap(lambda k, s, fs: tuple(sgd_sweep(
                      k, s, list(fs), args.lam, args.lr, sample, ctx=ctx)),
                  (P() if mesh is not None else None, st_spec, fs_spec),
                  fs_spec)
        state0 = tuple(factors)
        step = lambda i, fs: tuple(fn(jax.random.fold_in(key, i), st,
                                      tuple(fs)))
    elif args.algorithm == "ggn":
        if args.matvec_path == "fused":
            print("note: under jit/shard_map the 'fused' matvec path falls "
                  "back to the tttp_mttkrp composition (see --help)")
        matvec_path = args.matvec_path
        if matvec_path in ("fused", "dense") and ctx.model is not None:
            print(f"note: matvec path {matvec_path!r} cannot insert the "
                  f"inter-half psum(model); using the cost-model choice")
            matvec_path = "auto"
        fn = wrap(lambda s, stt: ggn_sweep(
                      s, stt, loss, args.lam, cg_tol=args.cg_tol,
                      cg_iters=args.cg_iters, ctx=ctx,
                      matvec_path=matvec_path),
                  (st_spec, None if mesh is None
                   else GGNState(fs_spec, P())),
                  None if mesh is None else GGNState(fs_spec, P()))
        state0 = ggn_init(factors, damping=args.damping)
        step = lambda i, stt: fn(st, stt)
    else:  # gcp
        ad0 = gcp_adam_init(factors)
        ad_spec = None if mesh is None else AdamState(
            [f_spec] * nd, [f_spec] * nd, P())
        fn = wrap(lambda s, fs, ad: (lambda f, a: (tuple(f), a))(
                      *gcp_step(s, list(fs), loss, args.lam, args.lr, ad,
                                ctx=ctx)),
                  (st_spec, fs_spec, ad_spec), (fs_spec, ad_spec))
        state0 = (tuple(factors), ad0)
        step = lambda i, stt: fn(st, tuple(stt[0]), stt[1])

    def get_factors(state):
        if isinstance(state, GGNState):
            return list(state.factors)
        if isinstance(state, tuple) and isinstance(state[0], tuple):
            return list(state[0])
        return list(state)

    hist = []

    def loop_step(i, state):
        t0 = time.perf_counter()
        state = step(i, state)
        jax.block_until_ready(jax.tree.leaves(state)[0])
        dt = time.perf_counter() - t0
        e = rmse(st, get_factors(state))
        hist.append((i, dt, e))
        print(f"sweep {i:3d}  {dt*1e3:8.1f} ms  rmse={e:.6f}")
        return state

    loop = RestartableLoop(args.ckpt_dir, loop_step, ckpt_every=5)
    final = loop.run(state0, args.sweeps)
    if hist:
        print(f"final rmse={hist[-1][2]:.6f} "
              f"(mean sweep {sum(h[1] for h in hist)/len(hist)*1e3:.1f} ms)")
    else:  # checkpoint resume found every sweep already done
        print(f"final rmse={rmse(st, get_factors(final)):.6f} "
              f"(all {args.sweeps} sweeps restored from {args.ckpt_dir})")
    if args.dump_factors:
        fs = get_factors(final)
        if args.dump_factors.endswith(".npz"):
            np.savez(args.dump_factors,
                     **{f"factor_{d}": np.asarray(f)
                        for d, f in enumerate(fs)})
        else:
            from repro import checkpoint as ckpt
            link = "log" if args.loss.endswith("_log") else "identity"
            ckpt.save(args.dump_factors, args.sweeps,
                      {f"factor_{d}": f for d, f in enumerate(fs)},
                      metadata={"kind": "cp_factors", "rank": r,
                                "shape": list(shape),
                                "algorithm": args.algorithm,
                                "loss": args.loss, "link": link,
                                "dataset": args.dataset,
                                "nnz": int(st.nnz), "sweeps": args.sweeps})
        print(f"wrote factors to {args.dump_factors}")


if __name__ == "__main__":
    main()
