"""LM training driver: ``python -m repro.launch.train --arch <id> [--smoke]``.

End-to-end: config → params → sharded data pipeline → jit'd train step
(loss, grad, AdamW) → fault-tolerant loop (checkpoint/restart, straggler
watchdog). On this CPU container run with ``--smoke`` (reduced config); the
full configs are exercised via the dry-run."""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import base as cfgs
from repro.data import pipeline
from repro.models import model as M
from repro.optim.adamw import adamw_init, adamw_update
from repro.optim.schedule import cosine_warmup
from repro.runtime.fault_tolerance import RestartableLoop, StepWatchdog


def make_train_step(cfg, base_lr: float, total_steps: int):
    def train_step(params, opt, batch):
        loss, grads = jax.value_and_grad(M.loss_fn)(params, cfg, batch)
        lr = cosine_warmup(opt.count, base_lr, warmup_steps=10,
                           total_steps=total_steps)
        params, opt = adamw_update(grads, opt, params, lr)
        return params, opt, loss
    return jax.jit(train_step)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=20)
    args = ap.parse_args()

    cfg = cfgs.get_smoke(args.arch) if args.smoke else cfgs.get(args.arch)
    key = jax.random.PRNGKey(0)
    params = M.init_params(key, cfg)
    opt = adamw_init(params)
    print(f"arch={cfg.name} params={M.param_count(params):,}")

    step_fn = make_train_step(cfg, args.lr, args.steps)
    batches = list(pipeline.lm_batches(key, cfg.vocab, args.batch, args.seq,
                                       num_batches=args.steps))

    def add_frontends(b):
        if cfg.frontend == "frames":
            b = dict(b, frames=0.02 * jax.random.normal(
                key, (args.batch, args.seq, cfg.d_model)))
        if cfg.frontend == "patch":
            tp = cfg.num_patches
            b = dict(b, tokens=b["tokens"][:, tp:], labels=b["labels"][:, tp:],
                     patch_embeds=0.02 * jax.random.normal(
                         key, (args.batch, tp, cfg.d_model)))
        return b

    losses = []

    def loop_step(i, state):
        params, opt = state
        batch = add_frontends(batches[i % len(batches)])
        params, opt, loss = step_fn(params, opt, batch)
        losses.append(float(loss))
        if i % 10 == 0:
            print(f"step {i:4d} loss {float(loss):.4f}")
        return (params, opt)

    loop = RestartableLoop(args.ckpt_dir, loop_step,
                           ckpt_every=args.ckpt_every,
                           watchdog=StepWatchdog())
    t0 = time.time()
    params, opt = loop.run((params, opt), args.steps)
    dt = time.time() - t0
    print(f"done: {args.steps} steps in {dt:.1f}s; "
          f"loss {losses[0]:.4f} -> {losses[-1]:.4f}; "
          f"stragglers flagged: {len(loop.watchdog.events)}")


if __name__ == "__main__":
    main()
