"""Render EXPERIMENTS.md §Dry-run and §Roofline tables from the JSON records
emitted by ``repro.launch.dryrun``.

    PYTHONPATH=src python -m repro.launch.report --dir experiments/dryrun
"""
from __future__ import annotations

import argparse
import json
import os
from typing import Dict, List


def load(dir_: str) -> List[Dict]:
    recs = []
    for f in sorted(os.listdir(dir_)):
        if f.endswith(".json"):
            recs.append(json.load(open(os.path.join(dir_, f))))
    return recs


def _fmt_bytes(b):
    return f"{b / 2**30:.2f}"


def _note(r) -> str:
    dom = r["dominant"]
    if r["arch"].startswith("completion/"):
        if dom == "collective":
            return ("psum(model) of TTTP partials dominates; H-slice or "
                    "row-shard factors to shrink payloads")
        return ("gather/segment traffic dominates; fuse via the bucketed "
                "Pallas kernels (no (m,R) intermediates)")
    kinds = r.get("collective_by_kind", {})
    top = max(kinds, key=kinds.get) if kinds else "none"
    if dom == "collective":
        return (f"{top} dominates wire bytes; overlap with compute or move "
                "to reduce-scatter/seq-parallel residual")
    if dom == "memory":
        return ("HBM traffic bound; fuse elementwise chains / cast "
                "accumulators bf16 / chunk the LM-head loss")
    return "near compute roofline; improve MXU utilization (layout/fusion)"


def dryrun_table(recs: List[Dict]) -> str:
    lines = ["| arch | shape | mesh | GiB/dev | HLO GFLOP/dev | coll GB/dev "
             "| collective mix |",
             "|---|---|---|---|---|---|---|"]
    for r in recs:
        mix = ", ".join(f"{k.replace('all-', 'a')}×{v}"
                        for k, v in sorted(
                            r.get("collective_counts", {}).items()))
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{_fmt_bytes(r['bytes_per_device'])} | "
            f"{r['hlo_flops_per_device'] / 1e9:.1f} | "
            f"{r['collective_bytes_per_device'] / 1e9:.2f} | {mix} |")
    return "\n".join(lines)


def roofline_table(recs: List[Dict]) -> str:
    lines = ["| arch | shape | compute s | memory s | collective s | "
             "dominant | useful-flops ratio | roofline frac | note |",
             "|---|---|---|---|---|---|---|---|---|"]
    for r in recs:
        if r["mesh"] != "16x16":
            continue
        uf = r.get("useful_flops_ratio")
        # ratio is meaningless for gather/segment workloads (HLO dot flops≈0)
        uf_s = f"{uf:.3f}" if uf is not None and uf < 50 else "n/a"
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.4f} | "
            f"{r['memory_s']:.4f} | {r['collective_s']:.4f} | "
            f"{r['dominant']} | {uf_s} | "
            f"{r['roofline_fraction']:.3f} | {_note(r)} |")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--section", default="both",
                    choices=["dryrun", "roofline", "both"])
    args = ap.parse_args()
    recs = load(args.dir)
    if args.section in ("dryrun", "both"):
        print("### Dry-run records (both meshes)\n")
        print(dryrun_table(recs))
        print()
    if args.section in ("roofline", "both"):
        print("### Roofline (single pod, 16×16 = 256 chips)\n")
        print(roofline_table(recs))


if __name__ == "__main__":
    main()
