"""Render EXPERIMENTS.md §Dry-run and §Roofline tables from previously committed
dry-run JSON records, and the in-repo perf trajectory.

    PYTHONPATH=src python -m repro.launch.report --dir experiments/dryrun
    PYTHONPATH=src python -m repro.launch.report --perf   # writes PERF.md

``--perf`` builds the named CI dataset, runs the planned MTTKRP / TTTP /
fused CG-matvec eagerly with tracing enabled (populating the planner's
predicted-vs-measured table), profiles the jitted kernels against the
machine roofline (``repro.obs.profile_jitted``), folds in the committed
``BENCH_*.json`` trajectory, and writes it all to ``PERF.md``.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List


def load(dir_: str) -> List[Dict]:
    recs = []
    for f in sorted(os.listdir(dir_)):
        if f.endswith(".json"):
            recs.append(json.load(open(os.path.join(dir_, f))))
    return recs


def _fmt_bytes(b):
    return f"{b / 2**30:.2f}"


def _note(r) -> str:
    dom = r["dominant"]
    if r["arch"].startswith("completion/"):
        if dom == "collective":
            return ("psum(model) of TTTP partials dominates; H-slice or "
                    "row-shard factors to shrink payloads")
        return ("gather/segment traffic dominates; fuse via the bucketed "
                "Pallas kernels (no (m,R) intermediates)")
    kinds = r.get("collective_by_kind", {})
    top = max(kinds, key=kinds.get) if kinds else "none"
    if dom == "collective":
        return (f"{top} dominates wire bytes; overlap with compute or move "
                "to reduce-scatter/seq-parallel residual")
    if dom == "memory":
        return ("HBM traffic bound; fuse elementwise chains / cast "
                "accumulators bf16 / chunk the LM-head loss")
    return "near compute roofline; improve MXU utilization (layout/fusion)"


def dryrun_table(recs: List[Dict]) -> str:
    lines = ["| arch | shape | mesh | GiB/dev | HLO GFLOP/dev | coll GB/dev "
             "| collective mix |",
             "|---|---|---|---|---|---|---|"]
    for r in recs:
        mix = ", ".join(f"{k.replace('all-', 'a')}×{v}"
                        for k, v in sorted(
                            r.get("collective_counts", {}).items()))
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{_fmt_bytes(r['bytes_per_device'])} | "
            f"{r['hlo_flops_per_device'] / 1e9:.1f} | "
            f"{r['collective_bytes_per_device'] / 1e9:.2f} | {mix} |")
    return "\n".join(lines)


def roofline_table(recs: List[Dict]) -> str:
    lines = ["| arch | shape | compute s | memory s | collective s | "
             "dominant | useful-flops ratio | roofline frac | note |",
             "|---|---|---|---|---|---|---|---|---|"]
    for r in recs:
        if r["mesh"] != "16x16":
            continue
        uf = r.get("useful_flops_ratio")
        # ratio is meaningless for gather/segment workloads (HLO dot flops≈0)
        uf_s = f"{uf:.3f}" if uf is not None and uf < 50 else "n/a"
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.4f} | "
            f"{r['memory_s']:.4f} | {r['collective_s']:.4f} | "
            f"{r['dominant']} | {uf_s} | "
            f"{r['roofline_fraction']:.3f} | {_note(r)} |")
    return "\n".join(lines)


# ---------------------------------------------------------------------------
# --perf: measured kernel/planner performance -> PERF.md (DESIGN.md §11)
# ---------------------------------------------------------------------------

def _fmt_us(seconds: float) -> str:
    return f"{seconds * 1e6:.1f}"


def collect_perf(spec_name: str = "netflix-ci", repeats: int = 5) -> Dict:
    """Run the planned kernels on the named experiment spec with tracing on;
    returns ``{"plans": ..., "rooflines": ..., "machine": ...}``.

    Eager planned_* calls feed the predicted-vs-measured table (planner
    dispatch spans + §5.3 cost estimates); ``profile_jitted`` reports each
    kernel's achieved-vs-peak roofline fraction from the compiled HLO."""
    import jax

    from repro import obs, planner
    from repro.data import streaming
    from repro.data.pipeline import CompletionDataset
    from repro.kernels import ops as kops
    from repro.launch.experiment import SPECS

    spec = SPECS[spec_name]
    chunks = streaming.make_stream(spec.dataset, spec.seed, spec.shape,
                                   spec.nnz, spec.chunk_size,
                                   zipf_a=spec.zipf_a)
    ds = CompletionDataset.from_stream(chunks, spec.shape,
                                       num_shards=spec.num_shards,
                                       bucket_modes=(0,))
    st, omega = ds.tensor, ds.omega
    ks = jax.random.split(jax.random.PRNGKey(spec.seed), st.ndim + 1)
    factors = [jax.random.normal(k, (d, spec.rank)) / spec.rank ** 0.5
               for k, d in zip(ks, spec.shape)]
    x = jax.random.normal(ks[-1], (spec.shape[0], spec.rank))

    was_enabled = obs.enabled()
    if not was_enabled:
        obs.enable()
    try:
        # eager planned runs -> predicted-vs-measured plan table. One warmup
        # round pays per-plan tracing/compile, then the registry is reset so
        # the table reports steady-state eager dispatch only.
        for _ in range(2):
            planner.planned_mttkrp(st, [None] + factors[1:], mode=0)
            planner.planned_tttp(st, factors)
            planner.planned_cg_matvec(omega, factors, 0, x)
        obs.get_registry().reset()
        for _ in range(repeats):
            planner.planned_mttkrp(st, [None] + factors[1:], mode=0)
            planner.planned_tttp(st, factors)
            planner.planned_cg_matvec(omega, factors, 0, x)
        plans = obs.get_registry().summary()["plans"]

        # jitted roofline profiles: the same kernels the planner dispatches
        # to, compiled standalone so the HLO terms are attributable
        buckets = st.row_buckets(0, 64)
        rooflines = [
            obs.profile_jitted(
                lambda b, fs: kops.mttkrp_bucketed(
                    b, [None] + fs, num_rows=spec.shape[0]),
                buckets, factors[1:], name="mttkrp_bucketed"),
            obs.profile_jitted(
                lambda s, fs: kops.tttp_values(s, fs), st, factors,
                name="tttp"),
            obs.profile_jitted(
                lambda b, fs, x_: kops.cg_matvec_bucketed(
                    b, fs, x_, num_rows=spec.shape[0]),
                omega.row_buckets(0, 64), factors, x,
                name="cg_matvec_bucketed"),
        ]
    finally:
        if not was_enabled:
            obs.disable()
    return {"spec": spec_name, "plans": plans, "rooflines": rooflines,
            "machine": rooflines[0]["machine"]}


def plan_table(plans: Dict[str, Dict]) -> str:
    lines = ["| plan (expr \\| path \\| size) | kind | predicted s | "
             "measured mean s | measured min s | meas/pred |",
             "|---|---|---|---|---|---|"]
    for key in sorted(plans):
        p = plans[key]
        meas = p["measured"]
        lines.append(
            f"| `{key}` | {p['kind']} | {p['predicted']['seconds']:.2e} | "
            f"{meas['mean_s']:.2e} | {meas['min_s']:.2e} | "
            f"{p['measured_over_predicted']:.1f} |")
    return "\n".join(lines)


def kernel_roofline_table(rooflines: List[Dict]) -> str:
    lines = ["| kernel | measured µs | HLO GFLOP | HLO MiB | dominant | "
             "frac peak compute | frac peak memory | roofline frac |",
             "|---|---|---|---|---|---|---|---|"]
    for r in rooflines:
        lines.append(
            f"| {r['name']} | {_fmt_us(r['measured_s'])} | "
            f"{r['hlo_flops'] / 1e9:.4f} | {r['hlo_bytes'] / 2**20:.2f} | "
            f"{r['dominant']} | {r['frac_peak_compute']:.2e} | "
            f"{r['frac_peak_memory']:.2e} | {r['frac_roofline']:.2e} |")
    return "\n".join(lines)


def trajectory_tables(bench_dir: str) -> str:
    """One table per committed BENCH_*.json (the perf trajectory the
    regression gate compares fresh runs against)."""
    parts = []
    for path in sorted(glob.glob(os.path.join(bench_dir, "BENCH_*.json"))):
        group = os.path.basename(path)[len("BENCH_"):-len(".json")]
        with open(path) as f:
            entries = json.load(f)
        lines = [f"#### {group}", "", "| benchmark | µs/call |", "|---|---|"]
        for name in sorted(entries):
            v = entries[name]
            lines.append(f"| {name} | "
                         f"{'skipped' if v < 0 else f'{v:.1f}'} |")
        parts.append("\n".join(lines))
    return "\n\n".join(parts) if parts else "_no committed BENCH_*.json_"


def render_perf_md(perf: Dict, bench_dir: str) -> str:
    m = perf["machine"]
    return f"""# Performance report

Generated by `python -m repro.launch.report --perf` on the `{perf['spec']}`
spec. All numbers are host-dependent; the regression gate
(`benchmarks/compare.py`) compares like-for-like against the committed
baselines below rather than trusting absolute values.

Machine model (override via `REPRO_PEAK_FLOPS` / `REPRO_HBM_BW` /
`REPRO_LINK_BW`): peak {m['peak_flops']:.3g} FLOP/s, HBM
{m['hbm_bw']:.3g} B/s, link {m['link_bw']:.3g} B/s.

## Planner: predicted vs measured

The §5.3 cost model's per-plan prediction next to measured eager wall time
(best and mean over repeated runs; the first call includes compile). The constants matter only up to ranking — what this table
validates is that meas/pred is stable within a kernel family.

{plan_table(perf['plans'])}

## Kernels: achieved vs roofline

Compiled-HLO terms (dot FLOPs weighted by trip counts, HBM buffer traffic,
collective wire bytes — `repro.launch.roofline`) against the machine model.
`roofline frac` is best-case-bound-time / measured-time: 1.0 means running
at the machine-model bound. On CPU containers with TPU-default constants
these fractions are small; their trajectory over commits is the signal.

{kernel_roofline_table(perf['rooflines'])}

## Benchmark trajectory (committed baselines)

{trajectory_tables(bench_dir)}
"""


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--section", default="both",
                    choices=["dryrun", "roofline", "both"])
    ap.add_argument("--perf", action="store_true",
                    help="measure kernels + planner on --spec and write "
                         "--out (default PERF.md)")
    ap.add_argument("--spec", default="netflix-ci",
                    help="experiment spec for --perf")
    ap.add_argument("--out", default="PERF.md",
                    help="output path for --perf")
    ap.add_argument("--bench-dir", default=".",
                    help="directory holding committed BENCH_*.json")
    ap.add_argument("--repeats", type=int, default=5,
                    help="eager planned runs per kernel for --perf")
    args = ap.parse_args()
    if args.perf:
        perf = collect_perf(args.spec, repeats=args.repeats)
        text = render_perf_md(perf, args.bench_dir)
        with open(args.out, "w") as f:
            f.write(text)
        print(f"wrote {args.out}: {len(perf['plans'])} plan rows, "
              f"{len(perf['rooflines'])} kernel rooflines")
        return
    recs = load(args.dir)
    if args.section in ("dryrun", "both"):
        print("### Dry-run records (both meshes)\n")
        print(dryrun_table(recs))
        print()
    if args.section in ("roofline", "both"):
        print("### Roofline (single pod, 16×16 = 256 chips)\n")
        print(roofline_table(recs))


if __name__ == "__main__":
    main()
