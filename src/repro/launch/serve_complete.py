"""Serving driver on frozen factors (DESIGN.md §14):

    python -m repro.launch.complete --dataset netflix --rank 8 --sweeps 3 \
        --dump-factors /tmp/serve_ckpt
    python -m repro.launch.serve_complete --factors /tmp/serve_ckpt \
        --num-queries 100000 --batch-size 1024 --topk 10 --foldin-users 32

Restores the checkpoint (``repro.checkpoint`` step directory or legacy
``.npz``), then drives the three serving endpoints through
``repro.serve.ServeEngine``:

* a load generator streaming ``--num-queries`` random entry-scoring
  queries in ``--batch-size`` batches, reporting QPS and p50/p95/p99
  per-batch latency;
* ``--topk K`` retrievals over ``--topk-mode`` for ``--topk-users``
  sampled queries;
* ``--foldin-users`` cold-user fold-ins with ``--foldin-nnz``-entry
  synthetic histories (damped one-row ALS on the frozen factors).

``--verify`` asserts correctness before any timing is trusted: served
scores must match ``core.tttp.multilinear_values`` to 1e-6 and fold-in
rows must match an explicit (Gram-forming) one-row ALS solve to 1e-4 —
the process exits nonzero otherwise, which is what the ``serve-smoke``
CI job gates on. ``--json`` writes the full report.
"""
from __future__ import annotations

import argparse
import json
import sys
import time


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser()
    ap.add_argument("--factors", required=True, metavar="PATH",
                    help="checkpoint directory (repro.checkpoint step dirs) "
                         "or .npz written by complete.py --dump-factors")
    ap.add_argument("--step", type=int, default=None,
                    help="checkpoint step to restore (default: newest)")
    ap.add_argument("--link", default=None, choices=["identity", "log"],
                    help="prediction link; default: the checkpoint "
                         "metadata's link (identity for .npz)")
    ap.add_argument("--num-queries", type=int, default=10_000)
    ap.add_argument("--batch-size", type=int, default=1024)
    ap.add_argument("--score-path", default=None,
                    choices=["all_at_once", "sliced", "pairwise", "dense"],
                    help="force the scoring contraction through a planner "
                         "TTTP path (default: direct gather chain)")
    ap.add_argument("--topk", type=int, default=0, metavar="K",
                    help="also run top-k retrieval (0 disables)")
    ap.add_argument("--topk-mode", type=int, default=1,
                    help="mode retrieved over (the 'items')")
    ap.add_argument("--topk-users", type=int, default=32)
    ap.add_argument("--topk-block", type=int, default=4096,
                    help="item-factor rows per streaming top-k block")
    ap.add_argument("--foldin-users", type=int, default=0, metavar="B",
                    help="fold in B cold users (0 disables)")
    ap.add_argument("--foldin-mode", type=int, default=0,
                    help="mode the cold rows belong to (the 'users')")
    ap.add_argument("--foldin-nnz", type=int, default=16,
                    help="history length per cold user")
    ap.add_argument("--foldin-lam", type=float, default=1e-2,
                    help="fold-in ridge damping λ")
    ap.add_argument("--matvec-path", default=None,
                    choices=["tttp_mttkrp", "sliced", "dense"],
                    help="planner CG_MATVEC path for the fold-in Gram "
                         "matvec (default: direct kernel composition)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--verify", action="store_true",
                    help="assert score parity (1e-6) and fold-in parity "
                         "vs an explicit one-row solve (1e-4); nonzero "
                         "exit on failure")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write the load-generator report as JSON")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="enable obs tracing with a JSONL sink")
    return ap


def _gen_queries(rng, shape, n: int):
    import numpy as np
    return np.stack([rng.integers(0, s, size=n) for s in shape],
                    axis=1).astype(np.int32)


def _gen_histories(rng, shape, mode: int, users: int, nnz: int):
    import numpy as np
    others = [d for d in range(len(shape)) if d != mode]
    out = []
    for _ in range(users):
        oidx = np.stack([rng.integers(0, shape[d], size=nnz)
                         for d in others], axis=1).astype(np.int32)
        vals = rng.standard_normal(nnz).astype(np.float32)
        out.append((oidx, vals))
    return out


def _verify_scores(model, idx, scores) -> float:
    import numpy as np
    from repro.core.sparse_tensor import SparseTensor
    from repro.core.tttp import multilinear_values
    from repro.serve.model import apply_link

    st = SparseTensor.from_coo(idx, np.ones(idx.shape[0], np.float32),
                               model.shape)
    ref = apply_link(multilinear_values(st, model.factors), model.link)
    return float(np.abs(np.asarray(ref)[:idx.shape[0]] - scores).max())


def _verify_foldin(model, histories, mode, lam, rows) -> float:
    """Max |Δ| vs the explicit (Gram-forming) fresh one-row ALS solve."""
    import numpy as np

    err = 0.0
    others = [d for d in range(model.ndim) if d != mode]
    fs = [np.asarray(f) for f in model.factors]
    for u, (oidx, vals) in enumerate(histories):
        kr = fs[others[0]][oidx[:, 0]]
        for c, d in enumerate(others[1:], start=1):
            kr = kr * fs[d][oidx[:, c]]
        gram = kr.T @ kr + lam * np.eye(model.rank, dtype=kr.dtype)
        ref = np.linalg.solve(gram, kr.T @ vals)
        err = max(err, float(np.abs(rows[u] - ref).max()))
    return err


def main() -> None:
    args = build_parser().parse_args()

    import jax
    import numpy as np

    from repro import obs
    from repro.serve import ServeEngine, load_factors, percentiles

    if args.trace:
        obs.enable(jsonl=args.trace)

    model = load_factors(args.factors, link=args.link, step=args.step)
    engine = ServeEngine(model, max_batch=args.batch_size,
                         topk_block=args.topk_block,
                         score_path=args.score_path,
                         foldin_lam=args.foldin_lam,
                         foldin_matvec_path=args.matvec_path)
    print(f"restored factors: shape={model.shape} rank={model.rank} "
          f"link={model.link} meta={ {k: model.meta[k] for k in sorted(model.meta) if k != 'shape'} }")
    report = {"shape": list(model.shape), "rank": model.rank,
              "link": model.link, "batch_size": args.batch_size}
    rng = np.random.default_rng(args.seed)
    failures = []

    # ---- entry-scoring load generator -----------------------------------
    queries = _gen_queries(rng, model.shape, args.num_queries)
    jax.block_until_ready(model.factors)       # exclude H2D from batch 0
    engine.score(queries[:args.batch_size])    # compile outside the clock
    lat = []
    scores = np.empty((args.num_queries,), np.float32)
    t_all = time.perf_counter()
    for lo in range(0, args.num_queries, args.batch_size):
        t0 = time.perf_counter()
        out = engine.score(queries[lo:lo + args.batch_size])
        lat.append(time.perf_counter() - t0)
        scores[lo:lo + out.shape[0]] = out
    wall = time.perf_counter() - t_all
    stats = percentiles(lat)
    stats["qps"] = args.num_queries / wall
    report["score"] = stats
    print(f"score: {args.num_queries} queries in {wall*1e3:.1f} ms -> "
          f"{stats['qps']:,.0f} QPS  p50={stats['p50_us']:.0f}us "
          f"p99={stats['p99_us']:.0f}us  (batch {args.batch_size})")

    if args.verify:
        err = _verify_scores(model, queries, scores)
        print(f"verify score parity vs multilinear_values: max|d|={err:.2e}")
        if err > 1e-6 * max(1.0, float(np.abs(scores).max())):
            failures.append(f"score parity {err:.3e} > 1e-6")

    # ---- top-k retrieval -------------------------------------------------
    if args.topk:
        fixed_modes = [d for d in range(model.ndim) if d != args.topk_mode]
        fixed = {d: rng.integers(0, model.shape[d], size=args.topk_users)
                 for d in fixed_modes}
        engine.top_k(fixed, args.topk_mode, args.topk)   # compile
        t0 = time.perf_counter()
        vals, idx = engine.top_k(fixed, args.topk_mode, args.topk)
        dt = time.perf_counter() - t0
        report["topk"] = {"k": args.topk, "users": args.topk_users,
                          "us_per_call": dt * 1e6}
        print(f"top-{args.topk} over mode {args.topk_mode} for "
              f"{args.topk_users} queries: {dt*1e3:.2f} ms/batch; "
              f"sample user0 -> items {idx[0, :5].tolist()} "
              f"scores {np.round(vals[0, :5], 3).tolist()}")

    # ---- cold-user fold-in ----------------------------------------------
    if args.foldin_users:
        hists = _gen_histories(rng, model.shape, args.foldin_mode,
                               args.foldin_users, args.foldin_nnz)
        engine.fold_in(hists, args.foldin_mode)   # compile
        t0 = time.perf_counter()
        rows = engine.fold_in(hists, args.foldin_mode)
        dt = time.perf_counter() - t0
        report["foldin"] = {"users": args.foldin_users,
                            "nnz": args.foldin_nnz,
                            "us_per_call": dt * 1e6}
        print(f"fold-in: {args.foldin_users} cold users x "
              f"{args.foldin_nnz} obs in {dt*1e3:.2f} ms "
              f"({dt*1e6/args.foldin_users:.0f} us/user)")
        if args.verify:
            err = _verify_foldin(model, hists, args.foldin_mode,
                                 args.foldin_lam, rows)
            print(f"verify fold-in vs explicit one-row ALS: "
                  f"max|d|={err:.2e}")
            if err > 1e-4:
                failures.append(f"fold-in parity {err:.3e} > 1e-4")

    if args.json:
        with open(args.json, "w") as f:
            json.dump(report, f, indent=2, sort_keys=True)
        print(f"wrote {args.json}")
    if failures:
        print("VERIFY FAILED: " + "; ".join(failures))
        sys.exit(1)
    if args.verify:
        print("verify OK")


if __name__ == "__main__":
    main()
