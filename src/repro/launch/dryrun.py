"""Multi-pod dry-run: lower + compile every (architecture × shape × mesh)
cell on placeholder devices and extract memory/cost/roofline records.

MUST set the host-device-count flag before ANY other import (jax locks the
device count at first init)."""
import os
os.environ["XLA_FLAGS"] = (os.environ.get("REPRO_XLA_EXTRA", "") +
                           " --xla_force_host_platform_device_count=512").strip()

import argparse        # noqa: E402
import json            # noqa: E402
import time            # noqa: E402
import traceback       # noqa: E402

import jax             # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import base as cfgs               # noqa: E402
from repro.configs.base import SHAPES                # noqa: E402
from repro.configs.completion import COMPLETION_CONFIGS  # noqa: E402
from repro.launch import roofline as RL              # noqa: E402
from repro.launch import specs as SP                 # noqa: E402
from repro.launch.mesh import make_production_mesh, dp_size  # noqa: E402
from repro.models import model as M                  # noqa: E402
from repro.optim.adamw import adamw_init, adamw_update  # noqa: E402


def _sharded(mesh, tree_struct, tree_specs):
    return jax.tree.map(
        lambda s, sp: jax.ShapeDtypeStruct(
            s.shape, s.dtype, sharding=NamedSharding(mesh, sp)),
        tree_struct, tree_specs,
        is_leaf=lambda x: hasattr(x, "shape") or x is None)


def lower_lm_cell(arch: str, shape_name: str, multi_pod: bool,
                  overrides: dict = None):
    """Lower + compile one LM cell; returns the record dict."""
    import dataclasses
    mesh = make_production_mesh(multi_pod=multi_pod)
    cfg = cfgs.get(arch)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    cell = SHAPES[shape_name]
    chips = int(mesh.devices.size)

    params_struct = jax.eval_shape(
        lambda: M.init_params(jax.random.PRNGKey(0), cfg,
                              dtype=SP.PARAM_DTYPE))
    p_specs = SP.param_specs(mesh, cfg, params_struct)

    from repro.launch.mesh import dp_axes
    from repro.models.layers import set_sharding_ctx, clear_sharding_ctx
    dp = dp_axes(mesh)
    set_sharding_ctx(dp=dp if len(dp) > 1 else dp[0], dp_size=dp_size(mesh),
                     tp="model", tp_size=mesh.shape["model"])

    with jax.set_mesh(mesh):
        if cell.kind in ("train", "prefill"):
            b_struct = SP.batch_struct(cfg, cell)
            b_specs = SP.batch_specs(mesh, cfg, cell)
            if cell.kind == "train":
                from repro.optim.adamw import AdamWState
                opt_struct = jax.eval_shape(adamw_init, params_struct)
                o_specs = AdamWState(p_specs, p_specs, P())

                def train_step(params, opt, batch):
                    loss, grads = jax.value_and_grad(M.loss_fn)(
                        params, cfg, batch)
                    # pin gradient layout = parameter layout, so the scan
                    # backward accumulates reduce-scattered shards instead
                    # of all-reducing full weight gradients
                    grads = jax.lax.with_sharding_constraint(grads, p_specs)
                    params, opt = adamw_update(grads, opt, params, 1e-4)
                    return params, opt, loss

                fn = jax.jit(
                    train_step,
                    in_shardings=(p_specs, o_specs, b_specs),
                    out_shardings=(p_specs, o_specs, P()))
                args = (params_struct, opt_struct, b_struct)
            else:
                def prefill_step(params, batch):
                    return M.prefill_logits(params, cfg, batch)

                fn = jax.jit(prefill_step, in_shardings=(p_specs, b_specs))
                args = (params_struct, b_struct)
        else:
            toks, pos, caches, enc = SP.decode_structs(cfg, cell)
            c_specs = SP.cache_specs(mesh, cfg, caches)
            t_spec, p_spec = SP.token_specs(mesh, cell)

            if enc is not None:
                def serve_step(params, tokens, pos, caches, enc_out):
                    return M.decode_step(params, cfg, tokens, pos, caches,
                                         enc_out)
                e_spec = P(t_spec[0], None, None)
                fn = jax.jit(serve_step, in_shardings=(
                    p_specs, t_spec, p_spec, c_specs, e_spec),
                    out_shardings=(P(), c_specs))
                args = (params_struct, toks, pos, caches, enc)
            else:
                def serve_step(params, tokens, pos, caches):
                    return M.decode_step(params, cfg, tokens, pos, caches)
                fn = jax.jit(serve_step, in_shardings=(
                    p_specs, t_spec, p_spec, c_specs),
                    out_shardings=(P(), c_specs))
                args = (params_struct, toks, pos, caches)

        t0 = time.time()
        lowered = fn.lower(*args)
        compiled = lowered.compile()
        compile_s = time.time() - t0
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        if isinstance(cost, list):
            cost = cost[0]
        hlo = compiled.as_text()
    clear_sharding_ctx()
    terms = RL.roofline_terms(hlo, chips, RL.model_flops(cfg, cell))
    bytes_per_dev = (mem.argument_size_in_bytes + mem.output_size_in_bytes +
                     mem.temp_size_in_bytes)
    record = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16", "chips": chips,
        "compile_s": round(compile_s, 1),
        "bytes_per_device": int(bytes_per_dev),
        "arg_bytes": int(mem.argument_size_in_bytes),
        "temp_bytes": int(mem.temp_size_in_bytes),
        "cost_flops": float(cost.get("flops", -1)) if cost else -1,
        **{k: v for k, v in terms.items() if not isinstance(v, dict)},
        "collective_by_kind": terms["collective_by_kind"],
        "collective_counts": terms["collective_counts"],
    }
    return record, hlo


def lower_completion(name: str, multi_pod: bool, h_slices: int = 1,
                     scale: float = 1.0, factor_sharding: str = "column"):
    """Lower + compile one ALS-CG sweep of a paper workload.

    factor_sharding:
      * "column"     — paper-faithful H-slicing as a mesh axis: factor
                       columns over "model", nonzeros over the data axes;
      * "replicated" — beyond-paper: factors replicated, nonzeros sharded
                       over ALL axes (psum payloads drop from O(m_local)
                       per CG matvec to O(I·R) per mode).
    h_slices > 1 additionally applies the paper's H-sliced schedule to
    bound the (m, R) transients at Θ(m·R/H)."""
    from jax.sharding import PartitionSpec as P
    from repro.core.completion import als_sweep
    from repro.core.distributed import AxisCtx
    from repro.core.sparse_tensor import SparseTensor
    from repro.core.utils import round_up

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(mesh.devices.size)
    ccfg = COMPLETION_CONFIGS[name]
    shape = tuple(max(64, int(d * scale)) for d in ccfg.shape)
    nnz = max(1024, int(ccfg.nnz * scale ** len(ccfg.shape)))
    model_ax = mesh.axis_names[-1]
    if factor_sharding == "column":
        rank = round_up(ccfg.rank, mesh.shape[model_ax])
        st_struct, f_structs = SP.completion_structs(shape, nnz, rank, mesh)
        st_spec, f_specs = SP.completion_specs(mesh, st_struct, f_structs)
        dp = tuple(a for a in mesh.axis_names if a != model_ax)
        ctx = AxisCtx(data=dp if len(dp) > 1 else dp[0], model=model_ax)
    else:  # replicated factors, nonzeros over every axis
        rank = ccfg.rank
        st_struct, f_structs = SP.completion_structs(shape, nnz, rank, mesh)
        all_ax = tuple(mesh.axis_names)
        st_spec = SparseTensor(P(all_ax, None), P(all_ax), P(all_ax),
                               st_struct.shape, st_struct.nnz, None)
        f_specs = [P(None, None) for _ in f_structs]
        ctx = AxisCtx(data=all_ax, model=None)

    from jax.experimental.shard_map import shard_map

    def sweep(st, omega, factors):
        return tuple(als_sweep(st, omega, list(factors), ccfg.lam,
                               cg_tol=ccfg.cg_tol, cg_iters=ccfg.cg_iters,
                               ctx=ctx, h_slices=h_slices))

    fn = shard_map(sweep, mesh=mesh,
                   in_specs=(st_spec, st_spec, tuple(f_specs)),
                   out_specs=tuple(f_specs), check_rep=False)
    with jax.set_mesh(mesh):
        t0 = time.time()
        lowered = jax.jit(fn).lower(st_struct, st_struct, tuple(f_structs))
        compiled = lowered.compile()
        compile_s = time.time() - t0
        mem = compiled.memory_analysis()
        hlo = compiled.as_text()
    # model flops: ALS sweep ≈ 3 modes × (mttkrp + cg_iters×(tttp+mttkrp))
    r = rank
    mf = 3 * (2 * 3 * nnz * r) * (1 + ccfg.cg_iters)
    terms = RL.roofline_terms(hlo, chips, mf)
    record = {
        "arch": f"completion/{name}", "shape": f"scale={scale}",
        "mesh": "2x16x16" if multi_pod else "16x16", "chips": chips,
        "compile_s": round(compile_s, 1),
        "bytes_per_device": int(mem.argument_size_in_bytes +
                                mem.output_size_in_bytes +
                                mem.temp_size_in_bytes),
        "arg_bytes": int(mem.argument_size_in_bytes),
        "temp_bytes": int(mem.temp_size_in_bytes),
        **{k: v for k, v in terms.items() if not isinstance(v, dict)},
        "collective_by_kind": terms["collective_by_kind"],
        "collective_counts": terms["collective_counts"],
    }
    return record, hlo


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None,
                    help="arch id, 'all', or completion/<name>")
    ap.add_argument("--shape", default=None, help="shape cell or 'all'")
    ap.add_argument("--mesh", default="both", choices=["single", "multi",
                                                       "both"])
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--hlo-dir", default=None,
                    help="also dump compiled HLO text here")
    ap.add_argument("--completion-scale", type=float, default=1.0)
    ap.add_argument("--h-slices", type=int, default=1)
    ap.add_argument("--factor-sharding", default="column",
                    choices=["column", "replicated"])
    ap.add_argument("--tag", default="", help="suffix for output records")
    ap.add_argument("--override", action="append", default=[],
                    help="config overrides key=value (int/float)")
    args = ap.parse_args()
    overrides = {}
    for kv in args.override:
        k, v = kv.split("=", 1)
        overrides[k] = float(v) if "." in v else int(v)

    os.makedirs(args.out, exist_ok=True)
    meshes = {"single": [False], "multi": [True], "both": [False, True]}
    archs = cfgs.names() if args.arch in (None, "all") else [args.arch]

    failures = []
    for arch in archs:
        if arch.startswith("completion/"):
            name = arch.split("/", 1)[1]
            for mp in meshes[args.mesh]:
                tag = f"{name}_{'multi' if mp else 'single'}{args.tag}"
                try:
                    rec, hlo = lower_completion(
                        name, mp, scale=args.completion_scale,
                        h_slices=args.h_slices,
                        factor_sharding=args.factor_sharding)
                    _emit(args, tag, rec, hlo)
                except Exception as e:
                    failures.append((tag, repr(e)))
                    traceback.print_exc()
            continue
        shapes = (cfgs.cells_for(arch) if args.shape in (None, "all")
                  else [args.shape])
        for shape in shapes:
            for mp in meshes[args.mesh]:
                tag = f"{arch}_{shape}_{'multi' if mp else 'single'}{args.tag}"
                try:
                    rec, hlo = lower_lm_cell(arch, shape, mp, overrides)
                    _emit(args, tag, rec, hlo)
                except Exception as e:
                    failures.append((tag, repr(e)))
                    traceback.print_exc()
    if failures:
        print(f"\nFAILURES ({len(failures)}):")
        for tag, err in failures:
            print(" ", tag, err[:200])
        raise SystemExit(1)
    print("\nALL CELLS COMPILED")


def _emit(args, tag, rec, hlo):
    path = os.path.join(args.out, tag + ".json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    if args.hlo_dir:
        os.makedirs(args.hlo_dir, exist_ok=True)
        with open(os.path.join(args.hlo_dir, tag + ".hlo.txt"), "w") as f:
            f.write(hlo)
    print(f"OK {tag}: {rec['bytes_per_device']/2**30:.2f} GiB/dev, "
          f"compute={rec['compute_s']*1e3:.2f}ms "
          f"memory={rec['memory_s']*1e3:.2f}ms "
          f"collective={rec['collective_s']*1e3:.2f}ms "
          f"dominant={rec['dominant']} compile={rec['compile_s']}s")


if __name__ == "__main__":
    main()
