"""LM serving driver: ``python -m repro.launch.serve --arch <id> --smoke``.

Batched greedy decoding with KV caches (prefill via teacher-forced steps,
then generation). Demonstrates the serve path end-to-end on CPU with reduced
configs; full-size decode cells are exercised via the dry-run."""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import base as cfgs
from repro.models import model as M


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen-len", type=int, default=32)
    args = ap.parse_args()

    cfg = cfgs.get_smoke(args.arch) if args.smoke else cfgs.get(args.arch)
    key = jax.random.PRNGKey(0)
    params = M.init_params(key, cfg)
    max_len = args.prompt_len + args.gen_len
    caches = M.cache_init(cfg, args.batch, max_len)
    prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                 cfg.vocab)
    enc_out = None
    if cfg.encoder_layers > 0:
        frames = 0.02 * jax.random.normal(key, (args.batch, args.prompt_len,
                                                cfg.d_model))
        enc_out = M.encode(params, cfg, frames)

    step = jax.jit(lambda p, t, pos, c, e: M.decode_step(p, cfg, t, pos, c, e))

    t0 = time.time()
    tok = prompts[:, :1]
    out_tokens = []
    for i in range(max_len - 1):
        pos = jnp.full((args.batch, 1), i, jnp.int32)
        logits, caches = step(params, tok, pos, caches, enc_out)
        nxt = jnp.argmax(logits, -1)
        tok = prompts[:, i + 1:i + 2] if i + 1 < args.prompt_len else nxt
        if i + 1 >= args.prompt_len:
            out_tokens.append(tok)
    jax.block_until_ready(tok)
    dt = time.time() - t0
    gen = jnp.concatenate(out_tokens, 1)
    print(f"arch={cfg.name} generated {gen.shape} tokens in {dt:.2f}s "
          f"({args.batch * gen.shape[1] / dt:.1f} tok/s)")
    print("sample:", gen[0, :16].tolist())


if __name__ == "__main__":
    main()
