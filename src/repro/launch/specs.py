"""Sharding specs and ShapeDtypeStruct input stand-ins for every
(architecture × shape cell), plus the paper's completion workloads.

Param rule (TP over "model", FSDP over the data axes, layer-group leading
dim unsharded), with a divisibility guard: any dim not divisible by its
axis-size product falls back to replication — this single rule covers all
10 architectures (heads like 40 or 8 that don't divide 16 simply stay
replicated on that dim and XLA inserts the matching collectives; those show
up in the roofline and are hillclimb targets)."""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeCell
from repro.launch.mesh import dp_axes, dp_size
from repro.models import model as M

PARAM_DTYPE = jnp.bfloat16
ACT_DTYPE = jnp.bfloat16


def _axsize(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    axes = axes if isinstance(axes, tuple) else (axes,)
    return int(np.prod([mesh.shape[a] for a in axes]))


def _guard(mesh: Mesh, dim: int, axes):
    """axes if dim divisible by their size product else None."""
    return axes if axes and dim % _axsize(mesh, axes) == 0 else None


# -- parameter specs ---------------------------------------------------------

_COL_NAMES = {"wq", "wk", "wv", "w_gate", "w_lin", "w_in", "wq_b", "wkv_b",
              "w", "r", "w_gates"}
_ROW_NAMES = {"wo", "w_out"}
_REP_NAMES = {"router", "wq_a", "wkv_a"}


def _leaf_spec(mesh: Mesh, path: Tuple, leaf, fsdp) -> P:
    names = [getattr(p, "key", getattr(p, "name", str(p))) for p in path]
    name = names[-1]
    shape = leaf.shape
    nd = len(shape)
    stacked = "blocks" in names  # leading group dim
    core = shape[1:] if stacked and nd >= 2 else shape
    lead = (None,) if stacked and nd >= 2 else ()

    def spec(*dims):
        return P(*lead, *dims)

    if name == "embed":
        return P(_guard(mesh, shape[0], "model"), _guard(mesh, shape[1], fsdp))
    if name == "unembed":
        return P(_guard(mesh, shape[0], fsdp), _guard(mesh, shape[1], "model"))
    if len(core) == 3 and name in (_COL_NAMES | _ROW_NAMES):  # MoE (E, d, f)
        e, d1, d2 = core
        if name in _ROW_NAMES:
            return spec(_guard(mesh, e, "model"), None,
                        _guard(mesh, d2, fsdp))
        return spec(_guard(mesh, e, "model"), _guard(mesh, d1, fsdp), None)
    if len(core) == 2 and name in _COL_NAMES:
        return spec(_guard(mesh, core[0], fsdp), _guard(mesh, core[1], "model"))
    if len(core) == 2 and name in _ROW_NAMES:
        return spec(_guard(mesh, core[0], "model"), _guard(mesh, core[1], fsdp))
    if len(core) == 2 and name in _REP_NAMES:
        return spec(_guard(mesh, core[0], fsdp), None)
    if len(core) == 1 and name in ("bq", "bk", "bv"):
        return spec(_guard(mesh, core[0], "model"))
    return spec(*([None] * len(core)))


def param_specs(mesh: Mesh, cfg: ArchConfig, params_shape) -> Any:
    """Map an eval_shape'd param tree to PartitionSpecs."""
    fsdp = dp_axes(mesh)
    fsdp = fsdp if len(fsdp) > 1 else fsdp[0]
    flat = jax.tree_util.tree_flatten_with_path(params_shape)[0]
    specs = [_leaf_spec(mesh, path, leaf, fsdp) for path, leaf in flat]
    treedef = jax.tree_util.tree_structure(params_shape)
    return jax.tree_util.tree_unflatten(treedef, specs)


# -- input specs -------------------------------------------------------------

def batch_struct(cfg: ArchConfig, cell: ShapeCell) -> Dict[str, Any]:
    """ShapeDtypeStructs for one train/prefill batch (weak-type-correct,
    shardable, no allocation)."""
    b, s = cell.global_batch, cell.seq_len
    out: Dict[str, Any] = {}
    if cfg.frontend == "patch":
        s_text = s - cfg.num_patches
        out["tokens"] = jax.ShapeDtypeStruct((b, s_text), jnp.int32)
        out["labels"] = jax.ShapeDtypeStruct((b, s_text), jnp.int32)
        out["patch_embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.num_patches, cfg.d_model), ACT_DTYPE)
    else:
        out["tokens"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
        out["labels"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
    if cfg.frontend == "frames":
        out["frames"] = jax.ShapeDtypeStruct((b, s, cfg.d_model), ACT_DTYPE)
    return out


def batch_specs(mesh: Mesh, cfg: ArchConfig, cell: ShapeCell) -> Dict[str, P]:
    dp = dp_axes(mesh)
    dp = dp if len(dp) > 1 else dp[0]
    bdim = cell.global_batch
    baxes = _guard(mesh, bdim, dp)
    out = {"tokens": P(baxes, None), "labels": P(baxes, None)}
    if cfg.frontend == "patch":
        out["patch_embeds"] = P(baxes, None, None)
    if cfg.frontend == "frames":
        out["frames"] = P(baxes, None, None)
    return out


# -- decode (serve) specs ----------------------------------------------------

def decode_structs(cfg: ArchConfig, cell: ShapeCell):
    """(tokens, pos, caches[, enc_out]) structs for serve_step."""
    b, s = cell.global_batch, cell.seq_len
    caches = jax.eval_shape(
        lambda: M.cache_init(cfg, b, s, dtype=ACT_DTYPE))
    toks = jax.ShapeDtypeStruct((b, 1), jnp.int32)
    pos = jax.ShapeDtypeStruct((b, 1), jnp.int32)
    enc = (jax.ShapeDtypeStruct((b, s, cfg.d_model), ACT_DTYPE)
           if cfg.encoder_layers > 0 else None)
    return toks, pos, caches, enc


def _cache_leaf_spec(mesh: Mesh, leaf, dp) -> P:
    """Caches: (G, B, S, ...) KV-style or (G, B, ...) state-style.
    Shard batch over dp when divisible; shard the sequence axis (KV caches)
    over 'model' (flash-decoding style), else fall back to sharding seq over
    all axes for batch-1 long-context."""
    shape = leaf.shape
    nd = len(shape)
    if nd >= 3:
        bdim, sdim = shape[1], shape[2]
        b_axes = _guard(mesh, bdim, dp)
        if nd >= 4:  # (G, B, S, H?, d?) — treat dim 2 as sequence
            if b_axes is not None:
                s_axes = _guard(mesh, sdim, "model")
            else:
                all_ax = dp + ("model",) if isinstance(dp, tuple) \
                    else (dp, "model")
                s_axes = _guard(mesh, sdim, all_ax)
            return P(None, b_axes, s_axes, *([None] * (nd - 3)))
        # (G, B, D) state
        return P(None, b_axes, _guard(mesh, sdim, "model"))
    if nd == 2:
        return P(None, _guard(mesh, shape[1], dp))
    return P(*([None] * nd))


def cache_specs(mesh: Mesh, cfg: ArchConfig, caches_shape) -> Any:
    dp = dp_axes(mesh)
    dp = dp if len(dp) > 1 else dp[0]
    return jax.tree.map(lambda l: _cache_leaf_spec(mesh, l, dp), caches_shape,
                        is_leaf=lambda x: hasattr(x, "shape"))


def token_specs(mesh: Mesh, cell: ShapeCell) -> Tuple[P, P]:
    dp = dp_axes(mesh)
    dp = dp if len(dp) > 1 else dp[0]
    baxes = _guard(mesh, cell.global_batch, dp)
    return P(baxes, None), P(baxes, None)


# -- completion workload specs ----------------------------------------------

def completion_structs(shape: Tuple[int, ...], nnz: int, rank: int,
                       mesh: Mesh):
    """SparseTensor + factor ShapeDtypeStructs for the paper's workloads."""
    from repro.core.sparse_tensor import SparseTensor
    from repro.core.utils import round_up
    cap = round_up(nnz, int(mesh.devices.size) * 8)
    st = SparseTensor(
        jax.ShapeDtypeStruct((cap, len(shape)), jnp.int32),
        jax.ShapeDtypeStruct((cap,), jnp.float32),
        jax.ShapeDtypeStruct((cap,), jnp.bool_),
        tuple(shape), nnz)
    factors = [jax.ShapeDtypeStruct((d, rank), jnp.float32) for d in shape]
    return st, factors


def completion_specs(mesh: Mesh, st_shape, factors_shape):
    from repro.core.sparse_tensor import SparseTensor
    dp = dp_axes(mesh)
    dp = dp if len(dp) > 1 else dp[0]
    st_spec = SparseTensor(P(dp, None), P(dp), P(dp),
                           st_shape.shape, st_shape.nnz, st_shape.sorted_mode)
    f_specs = [P(None, _guard(mesh, f.shape[1], "model"))
               for f in factors_shape]
    return st_spec, f_specs
