"""Mesh-independent checkpointing with atomic commit and async save.

Format: a step directory ``step_<n>/`` holding one ``.npy`` per pytree leaf
plus ``manifest.json`` (treedef, shapes, dtypes, user metadata). Writes go to
``step_<n>.tmp`` and are committed by atomic rename — a crash mid-save never
corrupts the latest checkpoint (restart-safety). Restore rebuilds the pytree
and (optionally) re-shards every leaf onto a target mesh, so a job may
restart on a *different* device count (elastic scaling, DESIGN.md §8).

On a real multi-host cluster each host would write only its local shards;
this single-host implementation gathers leaves (``np.asarray``) and notes the
distinction here rather than hiding it.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any, Callable, Dict, Optional

import jax
import numpy as np


def _leaf_paths(tree) -> Dict[str, Any]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(str(p) for p in path) or "root"
        key = re.sub(r"[^A-Za-z0-9_.\-]", "_", key)
        out[key] = leaf
    return out


def save(directory: str, step: int, state, metadata: Optional[dict] = None,
         keep_last: int = 3) -> str:
    """Atomic checkpoint save; returns the committed path."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:09d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    leaves = _leaf_paths(state)
    manifest = {"step": step, "metadata": metadata or {}, "leaves": {}}
    for key, leaf in leaves.items():
        arr = np.asarray(jax.device_get(leaf))
        np.save(os.path.join(tmp, key + ".npy"), arr)
        manifest["leaves"][key] = {"shape": list(arr.shape),
                                   "dtype": str(arr.dtype)}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)          # atomic commit
    _gc(directory, keep_last)
    return final


def _gc(directory: str, keep_last: int):
    steps = sorted(_list_steps(directory))
    # keep_last=0 means "keep nothing": steps[:-0] is the EMPTY slice, which
    # silently kept everything — slice only when there is a tail to keep
    doomed = steps[:-keep_last] if keep_last > 0 else steps
    for s in doomed:
        shutil.rmtree(os.path.join(directory, f"step_{s:09d}"),
                      ignore_errors=True)


def _list_steps(directory: str):
    if not os.path.isdir(directory):
        return []
    out = []
    for name in os.listdir(directory):
        m = re.fullmatch(r"step_(\d+)", name)
        if m and os.path.exists(os.path.join(directory, name, "manifest.json")):
            out.append(int(m.group(1)))
    return out


def latest_step(directory: str) -> Optional[int]:
    steps = _list_steps(directory)
    return max(steps) if steps else None


def read_manifest(directory: str, step: int) -> dict:
    """The committed manifest of one step (treedef keys, per-leaf
    shape/dtype, user metadata) — the structure-discovery entry point for
    consumers that must rebuild a ``like`` pytree from disk alone (the
    serving layer restoring frozen factors, ``repro.serve.model``)."""
    path = os.path.join(directory, f"step_{step:09d}", "manifest.json")
    with open(path) as f:
        return json.load(f)


def _validate_leaf(path: str, key: str, arr: np.ndarray, entry: dict,
                   like_leaf) -> None:
    """Fail fast, naming the offending leaf: (a) the on-disk array must match
    the manifest record (corruption / partial write), (b) the manifest record
    must match the restore target (structure drift — e.g. the rank changed
    between fit and serve, which previously surfaced only as an opaque jit
    shape error much later)."""
    m_shape = tuple(entry["shape"])
    if tuple(arr.shape) != m_shape or str(arr.dtype) != entry["dtype"]:
        raise ValueError(
            f"checkpoint {path}: leaf {key!r} on disk is "
            f"{tuple(arr.shape)}/{arr.dtype} but the manifest records "
            f"{m_shape}/{entry['dtype']} — corrupted or partially written")
    like_shape = tuple(np.shape(like_leaf))
    if like_shape != m_shape:
        raise ValueError(
            f"checkpoint {path}: leaf {key!r} has shape {m_shape} but the "
            f"restore target expects {like_shape} — checkpoint/structure "
            f"drift (e.g. rank changed between fit and serve)")
    if hasattr(like_leaf, "dtype") and np.dtype(like_leaf.dtype) != arr.dtype:
        raise ValueError(
            f"checkpoint {path}: leaf {key!r} has dtype {arr.dtype} but the "
            f"restore target expects {np.dtype(like_leaf.dtype)}")


def restore(directory: str, step: int, like,
            shard_fn: Optional[Callable[[str, np.ndarray], Any]] = None):
    """Restore into the structure of ``like``. ``shard_fn(key, arr)`` may
    device_put each leaf with a target sharding (elastic restore path);
    default is plain host arrays fed to jnp. Every loaded leaf is validated
    against the manifest's recorded shape/dtype AND the ``like`` structure —
    a drifted checkpoint fails here with the leaf named, not later inside
    jit."""
    path = os.path.join(directory, f"step_{step:09d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    leaves = _leaf_paths(like)
    recorded = manifest.get("leaves", {})
    missing = sorted(set(leaves) - set(recorded))
    if missing:
        raise ValueError(
            f"checkpoint {path}: leaves {missing} absent from the manifest "
            f"(it records {sorted(recorded)}) — structure drift")
    out = {}
    for key, like_leaf in leaves.items():
        arr = np.load(os.path.join(path, key + ".npy"))
        _validate_leaf(path, key, arr, recorded[key], like_leaf)
        out[key] = shard_fn(key, arr) if shard_fn else arr
    flat, treedef = jax.tree_util.tree_flatten(like)
    paths = list(_leaf_paths(like).keys())
    restored = [out[k] for k in paths]
    return jax.tree_util.tree_unflatten(treedef, restored), manifest


class Checkpointer:
    """Async checkpointer: save() returns immediately, the write happens on a
    background thread (overlaps I/O with the next steps); wait() joins.

    A failed background write (disk full, bad leaf) is NOT silently
    swallowed: the worker exception is captured and re-raised — prefixed
    with the step it belongs to — at the next ``wait()`` or ``save_async()``
    call, so a caller cannot keep training against a checkpoint directory
    that is quietly serving a stale step."""

    def __init__(self, directory: str, keep_last: int = 3):
        self.directory = directory
        self.keep_last = keep_last
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None
        self._error_step: Optional[int] = None

    def save_async(self, step: int, state, metadata: Optional[dict] = None):
        self.wait()
        # snapshot to host before returning so the caller may mutate state
        host_state = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                  state)
        # snapshot metadata too — callers pass live dicts (e.g. a growing
        # metric history) that must reflect THIS step in the manifest
        if metadata is not None:
            metadata = json.loads(json.dumps(metadata))

        def work():
            try:
                save(self.directory, step, host_state, metadata,
                     self.keep_last)
            except BaseException as e:   # re-raised on the caller's thread
                self._error = e
                self._error_step = step

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, step = self._error, self._error_step
            self._error = self._error_step = None
            raise RuntimeError(
                f"async checkpoint save of step {step} failed; the newest "
                f"on-disk checkpoint is stale") from err

    def latest(self) -> Optional[int]:
        return latest_step(self.directory)

    def restore_latest(self, like, shard_fn=None):
        step = self.latest()
        if step is None:
            return None
        state, manifest = restore(self.directory, step, like, shard_fn)
        return step, state, manifest
