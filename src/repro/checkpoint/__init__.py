from repro.checkpoint.checkpointer import (Checkpointer, latest_step,
                                           read_manifest, restore, save)

__all__ = ["Checkpointer", "latest_step", "read_manifest", "restore", "save"]
