"""Elastic scaling: re-plan a checkpointed job for a different device count.

Checkpoints are mesh-independent logical arrays (repro.checkpoint), so
elasticity reduces to re-partitioning at restore:

* dense state (factor matrices, LM params): device_put with the new mesh's
  shardings — no data transformation needed;
* sparse datasets: nonzero shards must be re-balanced to the new shard count
  (capacity is padded to the new multiple, entries re-shuffled so each new
  shard is equally loaded).
"""
from __future__ import annotations

from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.sparse_tensor import SparseTensor
from repro.data.synthetic import shuffle_and_pad
from repro.sparse.redistribute import shard_nonzeros


def replan_sparse(st: SparseTensor, key, mesh: Optional[Mesh],
                  data_axes=("data",)) -> SparseTensor:
    """Re-balance a sparse dataset for a new mesh (or None ⇒ single device)."""
    num = 1
    if mesh is not None:
        import numpy as np
        num = int(np.prod([mesh.shape[a] for a in data_axes]))
    out = shuffle_and_pad(st, key, num)
    if mesh is not None:
        axes = data_axes if len(data_axes) > 1 else data_axes[0]
        out = shard_nonzeros(out, mesh, axes)
    return out


def replan_dense(tree, mesh: Optional[Mesh], spec_fn=None):
    """Re-shard dense state onto a new mesh; spec_fn(path-str, leaf) -> P."""
    if mesh is None:
        return tree
    flat = jax.tree_util.tree_flatten_with_path(tree)
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    out = []
    for (path, leaf), raw in zip(flat[0], leaves):
        spec = spec_fn("/".join(map(str, path)), leaf) if spec_fn else P()
        out.append(jax.device_put(raw, NamedSharding(mesh, spec)))
    return jax.tree_util.tree_unflatten(treedef, out)
