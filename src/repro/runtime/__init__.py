from repro.runtime.fault_tolerance import RestartableLoop, StepWatchdog
from repro.runtime.elastic import replan_sparse, replan_dense

__all__ = ["RestartableLoop", "StepWatchdog", "replan_sparse", "replan_dense"]
