"""Fault tolerance: restartable training/completion loops and straggler
handling.

At 1000+ nodes the failure model is: (a) node loss ⇒ job restart from the
last checkpoint (possibly on fewer nodes — see ``runtime.elastic``);
(b) stragglers ⇒ detect via step-time watchdog, mitigate by eviction+restart
or, for the sparse workloads, by construction (equal-capacity shuffled
shards make per-device work identical — DESIGN.md §3).

``RestartableLoop`` drives a jit'd step function with periodic async
checkpoints, resumes from the newest valid manifest (falling back to older
ones if the newest is corrupt), and exposes failure injection for tests.
"""
from __future__ import annotations

import logging
import os
import time
from typing import Any, Callable, Optional

import jax

from repro import obs
from repro.checkpoint.checkpointer import Checkpointer, restore, _list_steps

log = logging.getLogger(__name__)


class StepWatchdog:
    """Flags steps slower than ``threshold × median`` (straggler signal).

    On a real cluster this feeds the controller's evict/restart policy; here
    it records events for inspection and tests."""

    def __init__(self, threshold: float = 3.0, warmup: int = 5):
        self.threshold = threshold
        self.warmup = warmup
        self.times = []
        self.events = []

    def observe(self, seconds: float, step: int):
        self.times.append(seconds)
        if len(self.times) > self.warmup:
            hist = sorted(self.times[:-1])
            med = hist[len(hist) // 2]
            if seconds > self.threshold * med:
                self.events.append((step, seconds, med))
                log.warning("straggler step %d: %.3fs vs median %.3fs",
                            step, seconds, med)


class RestartableLoop:
    """Checkpoint/restart driver.

    step_fn: (step_idx, state) -> state   (jit'd by the caller)
    state is any pytree. Checkpoints every ``ckpt_every`` steps (async) and
    at completion. ``fail_at`` raises mid-run after the step executes —
    used by tests to prove restart-resume equivalence."""

    def __init__(self, directory: str, step_fn: Callable[[int, Any], Any],
                 ckpt_every: int = 10, keep_last: int = 3,
                 watchdog: Optional[StepWatchdog] = None,
                 metadata_fn: Optional[Callable[[int], dict]] = None):
        self.ckpt = Checkpointer(directory, keep_last)
        self.step_fn = step_fn
        self.ckpt_every = ckpt_every
        self.watchdog = watchdog or StepWatchdog()
        # metadata_fn(step) -> JSON-able dict stored in the checkpoint
        # manifest (e.g. the experiment harness's per-sweep metric history);
        # on resume the newest manifest's metadata lands in last_metadata
        # BEFORE the first step runs, so callers can rebuild their history
        self.metadata_fn = metadata_fn
        self.last_metadata: dict = {}

    def _resume(self, init_state):
        """Newest-first restore with corrupted-checkpoint fallback."""
        steps = sorted(_list_steps(self.ckpt.directory), reverse=True)
        for s in steps:
            try:
                state, manifest = restore(self.ckpt.directory, s, init_state)
                log.info("resumed from step %d", s)
                self.last_metadata = manifest.get("metadata", {}) or {}
                return s + 1, state
            except Exception as e:  # corrupt/partial: fall back
                log.warning("checkpoint step %d unreadable (%s); falling back",
                            s, e)
        return 0, init_state

    def run(self, init_state, num_steps: int, fail_at: Optional[int] = None):
        start, state = self._resume(init_state)
        for step in range(start, num_steps):
            t0 = time.perf_counter()
            # the span tree of everything the step does (planner dispatch,
            # kernels, the caller's own sweep spans) lands in the obs
            # registry and, for the experiment harness, in the per-sweep
            # metric history riding the checkpoint manifest
            with obs.span("loop/step", step=step):
                state = self.step_fn(step, state)
                jax.block_until_ready(jax.tree.leaves(state)[0])
            self.watchdog.observe(time.perf_counter() - t0, step)
            if (step + 1) % self.ckpt_every == 0:
                self.ckpt.save_async(step, state, self._metadata(step))
            if fail_at is not None and step == fail_at:
                self.ckpt.wait()
                raise RuntimeError(f"injected failure at step {step}")
        self.ckpt.wait()
        final = num_steps - 1
        if final >= 0 and start <= final:
            # skip the final re-save when the resume point was already past
            # the end: no step ran, and re-writing would clobber the stored
            # manifest metadata with this process's (empty) metadata_fn view
            from repro.checkpoint.checkpointer import save
            save(self.ckpt.directory, final, state,
                 metadata=self._metadata(final))
        return state

    def _metadata(self, step: int) -> Optional[dict]:
        return None if self.metadata_fn is None else self.metadata_fn(step)
