"""NumPy-style user-facing facade — the Cyclops-Python-interface analogue.

Mirrors the paper's Listings 1–7 surface: tensor constructors, einsum over
mixed sparse/dense operands (the contraction patterns arising in tensor
completion), and TTTP. Distribution is invisible at this layer — arrays may
be sharded; ops run identically (the paper's parallelism-obliviousness).

    import repro.core.api as ctf
    T = ctf.random_sparse((I, J, K), nnz, key)     # fill_sp_random
    S = ctf.TTTP(T, [U, V, W])                     # Listing 3
    y = ctf.einsum("ijk,jr,kr->ir", T, V, W)       # MTTKRP
    a = ctf.einsum("ijk->i", S)                    # sparse reduction

Both ``einsum`` and ``TTTP`` route through ``repro.planner``: the expression
is parsed into a typed contraction IR, candidate execution paths (all-at-once,
pairwise T-first / KR-first, bucketed Pallas, dense fallback, …) are ranked by
the paper-§5.3 cost model, and the winner is dispatched onto the kernel
library. Plans are cached on the static call signature. ``path=`` forces a
specific candidate; ``plan=`` reuses a caller-held plan; ``autotune=True``
times the candidates once and pins the measured winner (DESIGN.md §5).
"""
from __future__ import annotations

from typing import Optional, Sequence, Union

import jax
import jax.numpy as jnp

from repro.core.distributed import AxisCtx, LOCAL
from repro.core.sparse_tensor import SparseTensor
from repro import planner as _planner

Tensor = Union[SparseTensor, jax.Array]


def tensor(shape, sp: bool = False, cap: Optional[int] = None) -> Tensor:
    """ctf.tensor analogue; sparse tensors start empty with capacity cap."""
    if not sp:
        return jnp.zeros(shape)
    cap = cap or 1
    return SparseTensor(jnp.zeros((cap, len(shape)), jnp.int32),
                        jnp.zeros((cap,)), jnp.zeros((cap,), bool),
                        tuple(shape), nnz=0)


def random_sparse(shape, nnz: int, key, cap: Optional[int] = None) -> SparseTensor:
    return SparseTensor.random(key, shape, nnz, cap=cap)


def ones(shape) -> jax.Array:
    return jnp.ones(shape)


def eye(n: int) -> jax.Array:
    return jnp.eye(n)


def TTTP(st: SparseTensor, factors: Sequence[Optional[jax.Array]],
         path: Optional[str] = None, autotune: bool = False,
         ctx: AxisCtx = LOCAL) -> SparseTensor:
    """Paper Listing 3; accepts None entries and vector factors."""
    return _planner.planned_tttp(st, factors, path=path, autotune=autotune,
                                 ctx=ctx)


def einsum(expr: str, *operands: Tensor, path: Optional[str] = None,
           plan: Optional["_planner.Plan"] = None,
           autotune: bool = False, ctx: AxisCtx = LOCAL) -> Tensor:
    """Einstein summation over mixed sparse/dense operands.

    Supported sparse patterns (any tensor order, one sparse operand):
      * pure-dense expressions — delegated to jnp.einsum;
      * sparse reductions over arbitrary mode subsets:  "ijkl->li", "ijk->"
      * TTM (one dense matrix, any output order):       "ijk,kr->ijr"
      * MTTKRP family (classic and partial/multi-out):  "ijk,jr,kr->ir",
                                                        "ijkl,kr,lr->ijr"
      * TTTP / SDDMM (sampled multilinear, sparse out): "ijk,ir,jr,kr->ijk"

    ``path=`` forces one of the plan's candidate paths (see
    ``repro.planner.candidate_paths``); the default lets the cost model pick.
    ``ctx=`` names the mesh axes the call runs under (inside ``shard_map``):
    dispatch applies the matching collectives and the ranking includes the
    communication terms (DESIGN.md §9).
    """
    return _planner.planned_einsum(expr, *operands, path=path, plan=plan,
                                   autotune=autotune, ctx=ctx)


def plan(expr: str, *operands: Tensor, path: Optional[str] = None,
         autotune: bool = False, ctx: AxisCtx = LOCAL) -> "_planner.Plan":
    """Inspect/precompute the plan ``einsum`` would use for this call."""
    return _planner.plan_contraction(expr, operands, path=path,
                                     autotune=autotune, ctx=ctx)
