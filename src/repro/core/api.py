"""NumPy-style user-facing facade — the Cyclops-Python-interface analogue.

Mirrors the paper's Listings 1–7 surface: tensor constructors, einsum over
mixed sparse/dense operands (the contraction patterns arising in tensor
completion), and TTTP. Distribution is invisible at this layer — arrays may
be sharded; ops run identically (the paper's parallelism-obliviousness).

    import repro.core.api as ctf
    T = ctf.random_sparse((I, J, K), nnz, key)     # fill_sp_random
    S = ctf.TTTP(T, [U, V, W])                     # Listing 3
    y = ctf.einsum("ijk,jr,kr->ir", T, V, W)       # MTTKRP
    a = ctf.einsum("ijk->i", S)                    # sparse reduction
"""
from __future__ import annotations

import re
from typing import Optional, Sequence, Union

import jax
import jax.numpy as jnp

from repro.core.sparse_tensor import SparseTensor
from repro.core import tttp as _tttp
from repro.sparse import ops as sops

Tensor = Union[SparseTensor, jax.Array]


def tensor(shape, sp: bool = False, cap: Optional[int] = None) -> Tensor:
    """ctf.tensor analogue; sparse tensors start empty with capacity cap."""
    if not sp:
        return jnp.zeros(shape)
    cap = cap or 1
    return SparseTensor(jnp.zeros((cap, len(shape)), jnp.int32),
                        jnp.zeros((cap,)), jnp.zeros((cap,), bool),
                        tuple(shape), nnz=0)


def random_sparse(shape, nnz: int, key, cap: Optional[int] = None) -> SparseTensor:
    return SparseTensor.random(key, shape, nnz, cap=cap)


def ones(shape) -> jax.Array:
    return jnp.ones(shape)


def eye(n: int) -> jax.Array:
    return jnp.eye(n)


def TTTP(st: SparseTensor, factors: Sequence[Optional[jax.Array]]) -> SparseTensor:
    """Paper Listing 3; accepts None entries and vector factors."""
    return _tttp.tttp(st, factors)


def _parse(expr: str):
    lhs, rhs = expr.replace(" ", "").split("->")
    return lhs.split(","), rhs


def einsum(expr: str, *operands: Tensor) -> Tensor:
    """Einstein summation over mixed sparse/dense operands.

    Supported sparse patterns (those arising in the paper's algorithms):
      * pure-dense expressions — delegated to jnp.einsum;
      * one sparse operand, reduction only:        "ijk->i"
      * one sparse + one dense matrix (TTM):        "ijk,kr->ijr"
      * MTTKRP family (sparse + N−1 factors):       "ijk,jr,kr->ir"
    """
    terms, out = _parse(expr)
    sparse_pos = [i for i, op in enumerate(operands)
                  if isinstance(op, SparseTensor)]
    if not sparse_pos:
        return jnp.einsum(expr, *operands)
    if len(sparse_pos) != 1 or sparse_pos[0] != 0:
        raise NotImplementedError(
            "sparse einsum supports a single sparse operand in first position")
    st: SparseTensor = operands[0]
    s_term = terms[0]
    if len(operands) == 1:
        if len(out) == 1 and out in s_term:
            return st.reduce_mode(s_term.index(out))
        if out == "":
            return st.sum()
        raise NotImplementedError(f"unsupported sparse reduction {expr}")
    # factor operands must be (dim, r)-shaped with shared output rank index
    if len(out) == 2 and out[0] in s_term:
        mode = s_term.index(out[0])
        r_idx = out[1]
        factors: list = [None] * st.ndim
        for term, op in zip(terms[1:], operands[1:]):
            if len(term) != 2 or term[1] != r_idx or term[0] not in s_term:
                raise NotImplementedError(f"unsupported term {term} in {expr}")
            factors[s_term.index(term[0])] = op
        return sops.mttkrp(st, factors, mode)
    if len(out) == len(s_term) and set(out) - set(s_term):
        # TTM: "ijk,kr->ijr"-style (one contracted mode, output keeps r)
        (term2, w), = [(t, o) for t, o in zip(terms[1:], operands[1:])]
        mode = s_term.index(term2[0])
        return sops.ttm_dense_output(st, w, mode)
    raise NotImplementedError(f"unsupported sparse einsum pattern {expr}")
