"""TTTP — tensor-times-tensor-product (paper §3.2), the core new kernel.

    x_{i1..iN} = s_{i1..iN} · Σ_r Π_j A^(j)[i_j, r]

with ``None`` allowed in the factor list (product iterates only over provided
modes), and a list of vectors accepted instead of matrices (R=1).

Three implementations:
* ``tttp``          — all-at-once (Θ(mR) work, Θ(m + ΣI_jR) memory); jnp path
                      here, Pallas path in ``repro.kernels`` (dispatched by
                      ``repro.kernels.ops.tttp``);
* ``tttp_pairwise`` — the pairwise-contraction baseline the paper compares
                      against (Fig. 6): materializes Θ(mR) intermediates;
* ``tttp_sliced``   — H-sliced variant (paper's parallel algorithm): R is cut
                      into H column slices processed sequentially, bounding
                      transient memory at Θ(m + ΣI_jR/H).
"""
from __future__ import annotations

from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core.sparse_tensor import SparseTensor


def _normalize_factors(factors: Sequence[Optional[jax.Array]]):
    """Promote vectors to single-column matrices; return (list, R)."""
    out: List[Optional[jax.Array]] = []
    r = None
    for f in factors:
        if f is None:
            out.append(None)
            continue
        if f.ndim == 1:
            f = f[:, None]
        if r is None:
            r = f.shape[1]
        elif f.shape[1] != r:
            raise ValueError("TTTP factors must share the rank dimension")
        out.append(f)
    if r is None:
        raise ValueError("TTTP requires at least one factor")
    return out, r


def multilinear_values(st: SparseTensor,
                       factors: Sequence[Optional[jax.Array]]) -> jax.Array:
    """Σ_r Π_j A^(j)[idx_j, r] per nonzero — the inner products of TTTP."""
    fs, r = _normalize_factors(factors)
    prod = None
    for d, f in enumerate(fs):
        if f is None:
            continue
        rows = f[st.indices[:, d]]
        prod = rows if prod is None else prod * rows
    return jnp.sum(prod, axis=1)


def tttp(st: SparseTensor, factors: Sequence[Optional[jax.Array]]) -> SparseTensor:
    """All-at-once TTTP (reference jnp path)."""
    return st.with_values(st.values * multilinear_values(st, factors))


def tttp_sliced(st: SparseTensor, factors: Sequence[Optional[jax.Array]],
                num_slices: int) -> SparseTensor:
    """H-sliced TTTP: paper's memory-bounded schedule. Equivalent output."""
    fs, r = _normalize_factors(factors)
    if r % num_slices != 0:
        raise ValueError(f"R={r} not divisible by H={num_slices}")
    rs = r // num_slices
    acc = jnp.zeros((st.cap,), st.values.dtype)

    for h in range(num_slices):
        sl = [None if f is None else f[:, h * rs:(h + 1) * rs] for f in fs]
        acc = acc + multilinear_values(st, sl)
    return st.with_values(st.values * acc)


def tttp_pairwise(st: SparseTensor,
                  factors: Sequence[Optional[jax.Array]]) -> SparseTensor:
    """Pairwise-contraction baseline (paper Fig. 6): forms the order-(N+1)
    sparse intermediate x_{i..r} = s_{i..} a^(1)_{i1 r}, contracts one factor
    at a time (Θ(mR) intermediate memory), then reduces over r."""
    fs, r = _normalize_factors(factors)
    inter = jnp.broadcast_to((st.values * st.mask)[:, None], (st.cap, r))
    for d, f in enumerate(fs):
        if f is None:
            continue
        inter = inter * f[st.indices[:, d]]   # materialized (cap, R) each step
    return st.with_values(jnp.sum(inter, axis=1))


def cp_residual_norm(st: SparseTensor,
                     factors: Sequence[jax.Array],
                     lambda_reg: float = 0.0) -> jax.Array:
    """‖T - [[U,V,W]]‖_F over observed entries via TTTP (paper §3.2 use case)."""
    model = multilinear_values(st, factors)
    diff = (st.values - model) * st.mask
    return jnp.sqrt(jnp.sum(jnp.square(diff)))
