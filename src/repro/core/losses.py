"""Generalized elementwise losses (the assigned-title revision of the paper).

Tensor completion minimizes  Σ_{n∈Ω} ℓ(t_n, m_n) + λ Σ_d ‖A_d‖²_F  where
m_n = Σ_r Π_d A_d[i_d(n), r] is the CP model value at a nonzero. For
quadratic ℓ this is the classic problem (§2); generalized ℓ (GCP) needs only
elementwise value/grad at the observed entries — the same TTTP/MTTKRP kernels
apply with the loss gradient in place of the residual.

Each loss provides value(t, m) and grad(t, m) = ∂ℓ/∂m; grads are hand-written
and property-tested against jax.grad.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Loss:
    name: str
    value: Callable  # (t, m) -> elementwise loss
    grad: Callable   # (t, m) -> dloss/dm


quadratic = Loss(
    "quadratic",
    value=lambda t, m: jnp.square(t - m),
    grad=lambda t, m: 2.0 * (m - t),
)

# Poisson log-likelihood with identity link: ℓ = m - t·log(max(m,ε)).
# The floor keeps value/grad finite when an unconstrained optimizer pushes
# the model negative (the log link below is the unconstrained alternative).
_EPS = 1e-6
poisson = Loss(
    "poisson",
    value=lambda t, m: m - t * jnp.log(jnp.maximum(m, _EPS)),
    grad=lambda t, m: 1.0 - t / jnp.maximum(m, _EPS),
)

# Poisson with log link: ℓ = exp(m) - t·m  (model logs the rate; always valid)
poisson_log = Loss(
    "poisson_log",
    value=lambda t, m: jnp.exp(m) - t * m,
    grad=lambda t, m: jnp.exp(m) - t,
)

# Bernoulli logit: t ∈ {0,1}; ℓ = log(1+exp(m)) - t·m
logistic = Loss(
    "logistic",
    value=lambda t, m: jnp.logaddexp(0.0, m) - t * m,
    grad=lambda t, m: jax.nn.sigmoid(m) - t,
)


def _huber_val(t, m, delta=1.0):
    a = jnp.abs(t - m)
    return jnp.where(a <= delta, 0.5 * jnp.square(a), delta * (a - 0.5 * delta))


def _huber_grad(t, m, delta=1.0):
    d = m - t
    return jnp.clip(d, -delta, delta)


huber = Loss("huber", value=_huber_val, grad=_huber_grad)

LOSSES = {l.name: l for l in (quadratic, poisson, poisson_log, logistic, huber)}
