"""Generalized elementwise losses (the assigned-title revision of the paper).

Tensor completion minimizes  Σ_{n∈Ω} ℓ(t_n, m_n) + λ Σ_d ‖A_d‖²_F  where
m_n = Σ_r Π_d A_d[i_d(n), r] is the CP model value at a nonzero. For
quadratic ℓ this is the classic problem (§2); generalized ℓ (GCP) needs only
elementwise value/grad at the observed entries — the same TTTP/MTTKRP kernels
apply with the loss gradient in place of the residual. The generalized
Gauss-Newton solver (``completion.gauss_newton``) additionally needs the
elementwise curvature ∂²ℓ/∂m², which weights the implicit Gram matvec
(paper eq. 3) at the observed entries.

Each loss provides value(t, m), grad(t, m) = ∂ℓ/∂m and hess(t, m) = ∂²ℓ/∂m²;
grads/hessians are hand-written and property-tested against jax.grad
(including the clamp regions of the clipped losses).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Loss:
    name: str
    value: Callable  # (t, m) -> elementwise loss
    grad: Callable   # (t, m) -> dloss/dm
    hess: Callable   # (t, m) -> d²loss/dm² (GGN curvature weight)


quadratic = Loss(
    "quadratic",
    value=lambda t, m: jnp.square(t - m),
    grad=lambda t, m: 2.0 * (m - t),
    hess=lambda t, m: jnp.full_like(m, 2.0),
)

# Poisson log-likelihood with identity link: ℓ = m - t·log(max(m,ε)).
# The floor keeps value/grad finite when an unconstrained optimizer pushes
# the model negative (the log link below is the unconstrained alternative).
# Below the floor the log term is constant in m, so the true derivative of
# the clamped value is 1 (and the curvature 0) — grad/hess must match the
# clamp, not the unclamped formula.
_EPS = 1e-6
poisson = Loss(
    "poisson",
    value=lambda t, m: m - t * jnp.log(jnp.maximum(m, _EPS)),
    grad=lambda t, m: jnp.where(m > _EPS, 1.0 - t / jnp.maximum(m, _EPS), 1.0),
    hess=lambda t, m: jnp.where(m > _EPS,
                                t / jnp.square(jnp.maximum(m, _EPS)), 0.0),
)

# Poisson with log link: ℓ = exp(m) - t·m  (model logs the rate; always valid)
poisson_log = Loss(
    "poisson_log",
    value=lambda t, m: jnp.exp(m) - t * m,
    grad=lambda t, m: jnp.exp(m) - t,
    hess=lambda t, m: jnp.exp(m),
)

# Bernoulli logit: t ∈ {0,1}; ℓ = log(1+exp(m)) - t·m
logistic = Loss(
    "logistic",
    value=lambda t, m: jnp.logaddexp(0.0, m) - t * m,
    grad=lambda t, m: jax.nn.sigmoid(m) - t,
    hess=lambda t, m: jax.nn.sigmoid(m) * jax.nn.sigmoid(-m),
)


def _huber_val(t, m, delta=1.0):
    a = jnp.abs(t - m)
    return jnp.where(a <= delta, 0.5 * jnp.square(a), delta * (a - 0.5 * delta))


def _huber_grad(t, m, delta=1.0):
    d = m - t
    return jnp.clip(d, -delta, delta)


def _huber_hess(t, m, delta=1.0):
    return jnp.where(jnp.abs(m - t) < delta, 1.0, 0.0)


huber = Loss("huber", value=_huber_val, grad=_huber_grad, hess=_huber_hess)

LOSSES = {l.name: l for l in (quadratic, poisson, poisson_log, logistic, huber)}
