"""Stochastic gradient descent for tensor completion (paper §2.4, Listing 7).

Each sweep samples S observed entries (uniformly, with replacement — the
static-shape analogue of Cyclops' sample rate), computes the sampled
subgradient for every factor via MTTKRP on the sample, and applies a plain
SGD update:

    s_ir = 2 Σ_{sample} v_jr w_kr (⟨u_i,v_j,w_k⟩ − t_n) · (m/S) + 2λ u_ir

The (m/S) factor unbiases the data term. Under shard_map the sample is drawn
per-shard from the local nonzeros (equal-size shuffled shards ⇒ uniform
globally) and gradients are psum'd over the data axes.
"""
from __future__ import annotations

from typing import List, Sequence

import jax
import jax.numpy as jnp

from repro.core.distributed import AxisCtx, LOCAL
from repro.core.sparse_tensor import SparseTensor
from repro.core.utils import axis_size


def sample_entries(key, st: SparseTensor, sample_size: int) -> SparseTensor:
    """Uniform with-replacement sample of the *valid* entries (Listing 7's
    getomega-style sampling, static output shape). Exact uniformity over
    valid entries via probability-weighted choice.

    A shard with ZERO valid entries (possible under sharded SGD when a
    shard is all padding) would yield an all-zero probability vector —
    invalid for ``jax.random.choice`` (garbage indices / NaNs). Fall back
    to a uniform distribution over the capacity and mark every sampled
    entry invalid; the caller's (valid-count / sample_size) scaling already
    zeroes the shard's gradient contribution."""
    p = st.valid.astype(jnp.float32)
    total = jnp.sum(p)
    p = jnp.where(total > 0, p / jnp.maximum(total, 1.0), 1.0 / st.cap)
    pick = jax.random.choice(key, st.cap, (sample_size,), replace=True, p=p)
    valid = jnp.broadcast_to(total > 0, (sample_size,))
    return SparseTensor(st.indices[pick], st.values[pick],
                        valid, st.shape, nnz=sample_size)


def sgd_sweep(key, st: SparseTensor, factors: Sequence[jax.Array],
              lam: float, lr: float, sample_size: int,
              ctx: AxisCtx = LOCAL) -> List[jax.Array]:
    """One SGD sweep: sample once, update every factor matrix.

    The data-term estimator is unbiased per shard: each shard samples its
    local valid entries and scales by (local_valid / sample_size); the psum
    over data axes then sums the per-shard expectations."""
    from repro.core.distributed import mttkrp_ctx
    from repro.core.tttp import multilinear_values
    if ctx.data is not None and ctx.data_size() > 1:
        # decorrelate per-shard sampling (single-shard data axes keep the
        # caller's key, so a size-1 data axis reproduces the LOCAL run)
        names = ctx.data if isinstance(ctx.data, tuple) else (ctx.data,)
        idx = 0
        for n in names:
            idx = idx * axis_size(n) + jax.lax.axis_index(n)
        key = jax.random.fold_in(key, idx)
    sample = sample_entries(key, st, sample_size)
    scale = st.count_valid().astype(jnp.float32) / sample_size
    fs = list(factors)
    for d in range(st.ndim):
        model = ctx.psum_model(multilinear_values(sample, fs))
        # fold the per-shard (local_valid / S) unbiasing into the residual
        # values: MTTKRP is linear in them, so the executor's psum(data)
        # sums the per-shard expectations
        resid = sample.with_values((model - sample.values) * scale)
        g_fs = list(fs)
        g_fs[d] = None
        grad = mttkrp_ctx(resid, g_fs, d, ctx)
        grad = 2.0 * grad + 2.0 * lam * fs[d]
        fs[d] = fs[d] - lr * grad
    return fs
