"""ALS for tensor completion with implicit batched conjugate gradient —
the paper's new algorithm (§2.2), plus the explicit (Gram-forming) baseline
it improves upon (Karlsson/Smith-style).

Implicit CG: for each mode, solve the I independent R×R SPD systems
    (G^(i) + λI) u_i = b_i,   b = MTTKRP(T, factors)
without ever forming G^(i). The batched matvec (paper eq. 3) is

    Y = MTTKRP( TTTP(Ω, [..., X at mode, ...]), factors ) + λX

i.e. one TTTP + one MTTKRP per CG iteration — O(mR) each. CG touches rows
only through the matvec, so all I systems run batched in lockstep; converged
rows are frozen by masking. Everything is ctx-parameterized: the identical
code runs single-device or under shard_map (DESIGN.md §4).
"""
from __future__ import annotations

import functools
from typing import List, NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core.distributed import (AxisCtx, LOCAL, mttkrp_ctx, rowdot_ctx,
                                    tttp_ctx)
from repro.core.sparse_tensor import SparseTensor


def gram_matvec(omega: SparseTensor, factors: Sequence[jax.Array], mode: int,
                x: jax.Array, lam: float, ctx: AxisCtx = LOCAL,
                h_slices: int = 1,
                mttkrp_path: Optional[str] = None,
                matvec_path: Optional[str] = None) -> jax.Array:
    """(G_ω + λI) x via implicit TTTP+MTTKRP (paper eq. 3).

    ``omega.values`` are the per-entry weights ω_n — the Ω indicator for
    plain ALS, the loss curvature ℓ'' for the generalized Gauss-Newton
    solver (``completion.gauss_newton``).

    ``h_slices > 1`` applies the paper's H-slicing schedule to BOTH halves:
    the (m, R) Khatri-Rao intermediates are never materialized wider than
    R/H columns, bounding transient memory at Θ(m·R/H) (paper §3.2).
    ``mttkrp_path`` opts the MTTKRP half into planner dispatch (DESIGN.md §5).
    ``matvec_path`` routes the WHOLE weighted matvec through the planner's
    ``cg_matvec`` family instead — ``"fused"`` (single-pass
    ``kernels.ops.cg_matvec_bucketed``), ``"tttp_mttkrp"``, ``"sliced"``,
    ``"dense"``, or ``"auto"`` (§5.3 cost model decides). Works under any
    ctx: dispatch inserts the psum(model) between the halves and the
    psum(data) on the output (under a model axis the fused/dense candidates
    are excluded — the intermediate psum cannot be fused)."""
    if matvec_path is not None:
        from repro.planner import planned_cg_matvec
        path = None if matvec_path == "auto" else matvec_path
        if path in ("fused", "dense") and ctx.model is not None:
            # neither candidate can express the inter-half psum(model);
            # degrade to the cost-model choice rather than raising (the
            # fused path's local-fallback story, applied to the mesh)
            path = None
        y = planned_cg_matvec(omega, list(factors), mode, x, path=path,
                              ctx=ctx)
        return y + lam * x
    fs = list(factors)
    fs[mode] = x
    if h_slices <= 1:
        z = tttp_ctx(omega, fs, ctx)        # z_n = Σ_s Π a_ds · x_is  (TTTP)
        fs[mode] = None
        y = mttkrp_ctx(z, fs, mode, ctx, path=mttkrp_path)
        return y + lam * x
    from repro.core.tttp import multilinear_values
    r = x.shape[1]
    rs = -(-r // h_slices)
    acc = jnp.zeros((omega.cap,), omega.values.dtype)
    for h in range(h_slices):
        sl = [None if f is None else f[:, h * rs:(h + 1) * rs] for f in fs]
        acc = acc + multilinear_values(omega, sl)
    z = omega.with_values(omega.values * ctx.psum_model(acc))
    fs[mode] = None
    from repro.planner import mttkrp_fn
    mv_kernel = mttkrp_fn(mttkrp_path)
    cols = []
    for h in range(h_slices):
        sl = [None if f is None else f[:, h * rs:(h + 1) * rs] for f in fs]
        cols.append(mv_kernel(z, sl, mode))
    y = ctx.psum_data(jnp.concatenate(cols, axis=1)[:, :r])
    return y + lam * x


def batched_pcg(matvec, b: jax.Array, x0: jax.Array, precond=None,
                tol: float = 1e-4, max_iters: int = 32,
                ctx: AxisCtx = LOCAL):
    """Preconditioned batched-rows CG on SPD systems; rows converge
    independently (converged rows are frozen by masking).

    ``precond`` is M⁻¹ applied elementwise over the (rows, R) batch —
    block-Jacobi when M is each row's block diagonal; ``None`` is the
    identity (plain CG). Stops (whole batch) when every row residual²
    ≤ tol²·‖b_row‖², or at max_iters (≤ R guarantees exact solve modulo
    roundoff, §2.2)."""
    if precond is None:
        precond = lambda v: v
    bnorm2 = rowdot_ctx(b, b, ctx)
    thresh = (tol ** 2) * jnp.maximum(bnorm2, 1e-30)

    r0 = b - matvec(x0)
    z0 = precond(r0)

    def cond(state):
        i, x, r, p, rz, rs = state
        return (i < max_iters) & jnp.any(rs > thresh)

    def body(state):
        i, x, r, p, rz, rs = state
        ap = matvec(p)
        pap = rowdot_ctx(p, ap, ctx)
        active = rs > thresh
        alpha = jnp.where(active, rz / jnp.where(pap > 0, pap, 1.0), 0.0)
        x = x + alpha[:, None] * p
        r = r - alpha[:, None] * ap
        z = precond(r)
        rz_new = rowdot_ctx(r, z, ctx)
        beta = jnp.where(active, rz_new / jnp.where(rz != 0, rz, 1.0), 0.0)
        p = z + beta[:, None] * p
        return i + 1, x, r, p, rz_new, rowdot_ctx(r, r, ctx)

    init = (jnp.int32(0), x0, r0, z0, rowdot_ctx(r0, z0, ctx),
            rowdot_ctx(r0, r0, ctx))
    iters, x, r, p, rz, rs = jax.lax.while_loop(cond, body, init)
    return x, iters


def batched_cg(matvec, b: jax.Array, x0: jax.Array, tol: float = 1e-4,
               max_iters: int = 32, ctx: AxisCtx = LOCAL):
    """Unpreconditioned :func:`batched_pcg` (z = r makes rz ≡ rs)."""
    return batched_pcg(matvec, b, x0, precond=None, tol=tol,
                       max_iters=max_iters, ctx=ctx)


def als_update_mode(st: SparseTensor, omega: SparseTensor,
                    factors: List[jax.Array], mode: int, lam: float,
                    cg_tol: float = 1e-4, cg_iters: int = 32,
                    ctx: AxisCtx = LOCAL, h_slices: int = 1,
                    mttkrp_path: Optional[str] = None) -> jax.Array:
    """One ALS factor update by implicit CG. ``mttkrp_path`` opts the
    MTTKRP contractions into planner dispatch (repro.planner)."""
    fs = list(factors)
    fs[mode] = None
    b = mttkrp_ctx(st, fs, mode, ctx, path=mttkrp_path)
    mv = functools.partial(gram_matvec, omega, factors, mode, lam=lam,
                           ctx=ctx, h_slices=h_slices,
                           mttkrp_path=mttkrp_path)
    x, _ = batched_cg(mv, b, factors[mode], tol=cg_tol, max_iters=cg_iters,
                      ctx=ctx)
    return x


def als_sweep(st: SparseTensor, omega: SparseTensor,
              factors: Sequence[jax.Array], lam: float,
              cg_tol: float = 1e-4, cg_iters: int = 32,
              ctx: AxisCtx = LOCAL, h_slices: int = 1,
              mttkrp_path: Optional[str] = None) -> List[jax.Array]:
    """Full ALS sweep (all modes, in order) — paper Algorithm of §2.2."""
    fs = list(factors)
    for d in range(st.ndim):
        fs[d] = als_update_mode(st, omega, fs, d, lam, cg_tol, cg_iters,
                                ctx, h_slices, mttkrp_path=mttkrp_path)
    return fs


# ---------------------------------------------------------------------------
# Explicit baseline: form all G^(i), solve with batched direct solves.
# O(mR²) work, O(IR²) memory — the bottleneck the implicit method removes.
# ---------------------------------------------------------------------------

def als_update_mode_explicit(st: SparseTensor, factors: List[jax.Array],
                             mode: int, lam: float,
                             ctx: AxisCtx = LOCAL) -> jax.Array:
    others = [d for d in range(st.ndim) if d != mode]
    kr = None
    for d in others:
        rows = factors[d][st.indices[:, d]]
        kr = rows if kr is None else kr * rows                  # (cap, R)
    kr = kr * st.mask[:, None]
    rows = st.indices[:, mode]
    n_rows = st.shape[mode]
    # G^(i) = Σ_n kr_n kr_nᵀ  — the O(mR²) contraction
    outer = kr[:, :, None] * kr[:, None, :]
    gram = jax.ops.segment_sum(outer, rows, num_segments=n_rows)
    gram = ctx.psum_data(gram)
    b = jax.ops.segment_sum((st.values * st.mask)[:, None] * kr, rows,
                            num_segments=n_rows)
    b = ctx.psum_data(b)
    r = kr.shape[1]
    gram = gram + lam * jnp.eye(r, dtype=gram.dtype)
    return jax.vmap(jnp.linalg.solve)(gram, b)


def als_sweep_explicit(st: SparseTensor, factors: Sequence[jax.Array],
                       lam: float, ctx: AxisCtx = LOCAL) -> List[jax.Array]:
    fs = list(factors)
    for d in range(st.ndim):
        fs[d] = als_update_mode_explicit(st, fs, d, lam, ctx)
    return fs
