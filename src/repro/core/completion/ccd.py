"""CCD++ for tensor completion (paper §2.3, Listings 5–6).

Maintains the sparse residual ρ_n = t_n − ⟨u_i, v_j, w_k⟩ on the Ω pattern and
updates one factor column at a time, alternating modes per column (CCD++
ordering [Yu et al.]). Closed-form column update:

    u_ir ← ( Σ_{(j,k)∈Ω_i} v_jr w_kr ρ^(r)_n ) / ( λ + Σ_{(j,k)∈Ω_i} v²_jr w²_kr )
    with ρ^(r)_n = ρ_n + u_ir v_jr w_kr  (add the old rank-1 term back)

Two implementations, as in the paper:
* ``ccd_sweep``      — einsum-style gather/segment-sum contractions (Listing 5);
* ``ccd_sweep_tttp`` — routed through the TTTP kernel + sparse mode reduction
                       (Listing 6), which the paper found 1.40–1.84× faster.
Both are ctx-parameterized (nonzeros sharded over data ⇒ psum of segment
sums; factors replicated — CCD's column updates leave no model axis).
"""
from __future__ import annotations

import functools
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.distributed import AxisCtx, LOCAL
from repro.core.sparse_tensor import SparseTensor


def residual_values(st: SparseTensor, factors: Sequence[jax.Array],
                    ctx: AxisCtx = LOCAL) -> jax.Array:
    """ρ_n = t_n − model_n on the Ω pattern (via TTTP machinery)."""
    from repro.core.tttp import multilinear_values
    model = ctx.psum_model(multilinear_values(st, list(factors)))
    return (st.values - model) * st.mask


def _column(f: jax.Array, r) -> jax.Array:
    return jax.lax.dynamic_slice_in_dim(f, r, 1, axis=1)[:, 0]


def _set_column(f: jax.Array, r, col: jax.Array) -> jax.Array:
    return jax.lax.dynamic_update_slice_in_dim(f, col[:, None], r, axis=1)


def _ccd_column_update_einsum(rho, st, cols, mode, lam, ctx):
    """Numerator/denominator via direct gather→multiply→segment-sum."""
    other = [d for d in range(st.ndim) if d != mode]
    vw = jnp.ones_like(rho)
    vw2 = jnp.ones_like(rho)
    for d in other:
        c = cols[d][st.indices[:, d]]
        vw = vw * c
        vw2 = vw2 * jnp.square(c)
    rows = st.indices[:, mode]
    n = st.shape[mode]
    a = ctx.psum_data(jax.ops.segment_sum(vw * rho, rows, num_segments=n))
    den0 = ctx.psum_data(jax.ops.segment_sum(vw2 * st.mask, rows, num_segments=n))
    new_col = (a + cols[mode] * den0) / (lam + den0)
    # residual update: ρ += (old − new) v w  at each nonzero
    delta = (cols[mode] - new_col)[rows] * vw
    return new_col, (rho + delta) * st.mask


def _ccd_column_update_tttp(rho, st, cols, mode, lam, ctx, path=None):
    """Same update routed through TTTP + sparse mode-reduction (Listing 6),
    both dispatched through the planner executor with ``ctx`` (DESIGN.md
    §9). ``path`` forces the TTTP contractions onto a planner candidate.

    Two TTTP kernel calls per column update: vw = TTTP(Ω, [None,v,w]) is
    computed once and reused for both the numerator reduction
    (a = Σ_i ρ·vw, since TTTP(ρ,·).values ≡ ρ·vw on the shared Ω pattern)
    and the residual update."""
    from repro.core.distributed import reduce_mode_ctx, tttp_ctx
    other = [d for d in range(st.ndim) if d != mode]
    fac = [None] * st.ndim
    fac2 = [None] * st.ndim
    for d in other:
        fac[d] = cols[d]
        fac2[d] = jnp.square(cols[d])
    omega = st.with_values(jnp.ones_like(rho) * st.mask)
    vw_sp = tttp_ctx(omega, fac, ctx, path=path)      # vw = TTTP(Ω,[None,v,w])
    vw = vw_sp.values
    a_sp = vw_sp.with_values(rho * vw)                # ≡ TTTP(ρ,[None,v,w])
    a = reduce_mode_ctx(a_sp, mode, ctx)              # a = einsum('ijk->i', A)
    b_sp = tttp_ctx(omega, fac2, ctx, path=path)      # B = TTTP(Ω,[None,v²,w²])
    den0 = reduce_mode_ctx(b_sp, mode, ctx)
    new_col = (a + cols[mode] * den0) / (lam + den0)
    rows = st.indices[:, mode]
    delta = (cols[mode] - new_col)[rows] * vw
    return new_col, (rho + delta) * st.mask


def _ccd_sweep_impl(update_fn, st, factors, rho, lam, ctx):
    ndim = st.ndim
    rank = factors[0].shape[1]
    fs = list(factors)

    def body(r, carry):
        fs, rho = carry
        fs = list(fs)
        for d in range(ndim):
            cols = [_column(f, r) for f in fs]
            new_col, rho = update_fn(rho, st, cols, d, lam, ctx)
            fs[d] = _set_column(fs[d], r, new_col)
        return tuple(fs), rho

    fs, rho = jax.lax.fori_loop(0, rank, body, (tuple(fs), rho))
    return list(fs), rho


def ccd_sweep(st: SparseTensor, factors: Sequence[jax.Array], rho: jax.Array,
              lam: float, ctx: AxisCtx = LOCAL
              ) -> Tuple[List[jax.Array], jax.Array]:
    """One CCD++ sweep (every column × every mode), einsum variant."""
    return _ccd_sweep_impl(_ccd_column_update_einsum, st, factors, rho, lam, ctx)


def ccd_sweep_tttp(st: SparseTensor, factors: Sequence[jax.Array],
                   rho: jax.Array, lam: float, ctx: AxisCtx = LOCAL,
                   tttp_path: Optional[str] = None
                   ) -> Tuple[List[jax.Array], jax.Array]:
    """One CCD++ sweep, TTTP-based variant (paper Listing 6).
    ``tttp_path`` opts the TTTP kernels into planner dispatch."""
    update = functools.partial(_ccd_column_update_tttp, path=tttp_path)
    return _ccd_sweep_impl(update, st, factors, rho, lam, ctx)
