"""Generalized Gauss-Newton (damped Levenberg–Marquardt) tensor completion —
the paper's quasi-Newton method, matrix-free on the eq.-3 Gram matvec.

Minimizes  Σ_{n∈Ω} ℓ(t_n, m_n) + λ Σ_d ‖A_d‖²_F  for any elementwise loss
with first and second derivatives (``repro.core.losses``). The model values
m_n = Σ_r Π_d A_d[i_d, r] are multilinear, so with J = [J_1 … J_N] the
per-mode Jacobians (J_d's rows are the Khatri-Rao rows Π_{e≠d} A_e[i_e, :])
the generalized Gauss-Newton Hessian is

    H = Jᵀ diag(ω) J + (2λ + μ) I,    ω_n = max(ℓ''(t_n, m_n), 0)

with μ the Levenberg–Marquardt damping. Its diagonal blocks H_dd are
EXACTLY the paper's eq.-3 implicit Gram matvec with curvature weights ω at
the observed entries; the off-diagonal blocks share the same TTTP/MTTKRP
structure. One GGN iteration is:

1. **Joint LM step** — solve H Δ = −∇ with flexible CG whose matvec is
   jx_n = Σ_e ⟨KR-row, X_e⟩ (N fused TTTP-halves summed once) followed by N
   MTTKRPs, and whose **block-Jacobi preconditioner applies the per-mode
   blocks H_dd⁻¹, each by a fixed number of batched-CG iterations on the
   weighted Gram matvec** (``als.gram_matvec``); a static line search picks
   the step length (Gauss-Newton directions overshoot on multilinear
   problems far from the optimum).
2. **Per-mode damped pass** — Gauss-Seidel over modes, each solving
   (H_dd + (2λ+μ)I) Δ_d = −∇_d with block-Jacobi(diagonal)-preconditioned
   batched CG. For quadratic loss (ω ≡ 2, μ → 0) this pass coincides with
   the ALS implicit-CG sweep.
3. **Accept/reject** — an iteration that does not decrease the objective is
   rolled back and μ increased; accepted full steps decrease μ.

Every weighted Gram matvec goes through :func:`als.gram_matvec`, whose
``matvec_path`` routes it through the planner's ``cg_matvec`` family
(DESIGN.md §8): the fused single-pass ``kernels.ops.cg_matvec_bucketed``,
the TTTP+MTTKRP composition, or the H-sliced variant — §5.3 cost model
deciding. Everything is ctx-parameterized (AxisCtx psums): the identical
code runs single-device or under shard_map; jit-safe throughout (static
line-search grid, jnp.where acceptance, fori_loop solvers).
"""
from __future__ import annotations

import functools
from typing import Callable, List, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.completion.als import batched_pcg, gram_matvec
from repro.core.completion.gcp import gcp_loss
from repro.core.distributed import AxisCtx, LOCAL, mttkrp_ctx, rowdot_ctx
from repro.core.losses import Loss
from repro.core.sparse_tensor import SparseTensor

# Levenberg–Marquardt damping schedule: decrease on a full accepted step,
# increase on rejection / a heavily truncated line search.
DAMPING_MIN = 1e-9
DAMPING_MAX = 1e6
DAMPING_DECREASE = 0.5
DAMPING_INCREASE = 10.0
DAMPING_TRUNCATED = 3.0

# static line-search grid for the joint step (0 ⇒ reject the step)
LINE_SEARCH_ALPHAS = (2.0, 1.5, 1.25, 1.0, 0.8, 0.65, 0.5, 0.4, 0.3,
                      0.2, 0.1)


class GGNState(NamedTuple):
    """Solver state threaded through sweeps (and RestartableLoop)."""
    factors: Tuple[jax.Array, ...]
    damping: jax.Array   # () — current LM μ


def ggn_init(factors: Sequence[jax.Array], damping: float = 1e-5) -> GGNState:
    return GGNState(tuple(factors), jnp.asarray(damping, factors[0].dtype))


# ---------------------------------------------------------------------------
# solvers (batched_pcg — the masked-convergence PCG — lives in als.py next
# to the unpreconditioned wrapper it generalizes)
# ---------------------------------------------------------------------------

def _block_cg_fixed(matvec: Callable, b: jax.Array, iters: int,
                    ctx: AxisCtx) -> jax.Array:
    """Fixed-iteration batched CG from zero — the block-Jacobi APPLY for the
    joint solve (a fixed operator, as a preconditioner must be; the outer
    loop uses flexible CG to absorb the residual nonlinearity)."""
    def body(_, state):
        x, r, p, rs = state
        ap = matvec(p)
        pap = rowdot_ctx(p, ap, ctx)
        alpha = rs / jnp.where(pap > 0, pap, 1.0)
        x = x + alpha[:, None] * p
        r = r - alpha[:, None] * ap
        rs_new = rowdot_ctx(r, r, ctx)
        beta = rs_new / jnp.where(rs > 0, rs, 1.0)
        p = r + beta[:, None] * p
        return x, r, p, rs_new

    init = (jnp.zeros_like(b), b, b, rowdot_ctx(b, b, ctx))
    x, _, _, _ = jax.lax.fori_loop(0, iters, body, init)
    return x


def _tree_dot(a, b, ctx: AxisCtx):
    return ctx.psum_model(sum(jnp.sum(x * y) for x, y in zip(a, b)))


def _flexible_pcg(matvec: Callable, b, precond: Callable, iters: int,
                  ctx: AxisCtx):
    """Flexible (Polak–Ribière) PCG over a tuple-of-factors unknown; the
    preconditioner may itself be an inexact iterative solve."""
    x0 = tuple(jnp.zeros_like(v) for v in b)
    z0 = precond(b)

    def body(_, state):
        x, r, z, p, rz = state
        ap = matvec(p)
        alpha = rz / jnp.maximum(_tree_dot(p, ap, ctx), 1e-30)
        x = tuple(xx + alpha * pp for xx, pp in zip(x, p))
        r_new = tuple(rr - alpha * aa for rr, aa in zip(r, ap))
        z_new = precond(r_new)
        rz_new = _tree_dot(r_new, z_new, ctx)
        # flexible beta: (rz_new − ⟨r_old, z_new⟩) / rz_old
        beta = (rz_new - _tree_dot(r, z_new, ctx)) / jnp.maximum(rz, 1e-30)
        p = tuple(zz + beta * pp for zz, pp in zip(z_new, p))
        return x, r_new, z_new, p, rz_new

    init = (x0, tuple(b), z0, tuple(z0), _tree_dot(b, z0, ctx))
    x, _, _, _, _ = jax.lax.fori_loop(0, iters, body, init)
    return x


# ---------------------------------------------------------------------------
# GGN pieces
# ---------------------------------------------------------------------------

def curvature_tensor(st: SparseTensor, factors: Sequence[jax.Array],
                     loss: Loss, ctx: AxisCtx = LOCAL
                     ) -> Tuple[SparseTensor, jax.Array]:
    """(ω-valued tensor, model values): ω_n = max(ℓ''(t_n, m_n), 0) on Ω.

    The clip keeps the GGN system PSD for losses whose clamped second
    derivative vanishes (poisson below the floor, huber outside δ)."""
    from repro.core.tttp import multilinear_values
    model = ctx.psum_model(multilinear_values(st, list(factors)))
    w = jnp.where(st.mask, loss.hess(st.values, model), 0.0)
    return st.with_values(jnp.maximum(w, 0.0)), model


def _gradients(st: SparseTensor, factors: List[jax.Array], model: jax.Array,
               loss: Loss, lam: float, ctx: AxisCtx,
               mttkrp_path: Optional[str]) -> List[jax.Array]:
    g_st = st.with_values(jnp.where(st.mask,
                                    loss.grad(st.values, model), 0.0))
    grads = []
    for d in range(st.ndim):
        fs = list(factors)
        fs[d] = None
        grads.append(mttkrp_ctx(g_st, fs, d, ctx, path=mttkrp_path)
                     + 2.0 * lam * factors[d])
    return grads


def joint_ggn_matvec(st: SparseTensor, w_st: SparseTensor,
                     factors: List[jax.Array], xs: Sequence[jax.Array],
                     shift, ctx: AxisCtx = LOCAL,
                     mttkrp_path: Optional[str] = None
                     ) -> Tuple[jax.Array, ...]:
    """(H X)_d for the JOINT system: jx_n = Σ_e ⟨KR-row, X_e⟩ computed in
    one fused accumulation (N TTTP halves share the pattern), then one
    MTTKRP per mode — Θ(N·mR) total, same asymptotics as N diagonal-block
    matvecs but covering all N² blocks."""
    from repro.core.tttp import multilinear_values
    jx = jnp.zeros((st.cap,), st.values.dtype)
    for e in range(st.ndim):
        fs = list(factors)
        fs[e] = xs[e]
        jx = jx + multilinear_values(st, fs)
    z = w_st.with_values(w_st.values * ctx.psum_model(jx))
    out = []
    for d in range(st.ndim):
        fs = [None if e == d else factors[e] for e in range(st.ndim)]
        out.append(mttkrp_ctx(z, fs, d, ctx, path=mttkrp_path)
                   + shift * xs[d])
    return tuple(out)


def ggn_update_mode(st: SparseTensor, factors: List[jax.Array], mode: int,
                    loss: Loss, lam: float, damping,
                    cg_tol: float = 1e-4, cg_iters: int = 32,
                    ctx: AxisCtx = LOCAL, h_slices: int = 1,
                    matvec_path: Optional[str] = None,
                    mttkrp_path: Optional[str] = None) -> jax.Array:
    """One damped per-mode GGN update: solve (H_dd + (2λ+μ)I) Δ = −∇_d with
    diagonal-preconditioned batched CG, return A_d + Δ."""
    w_st, model = curvature_tensor(st, factors, loss, ctx)
    g_st = st.with_values(jnp.where(st.mask,
                                    loss.grad(st.values, model), 0.0))
    fs_g = list(factors)
    fs_g[mode] = None
    g = mttkrp_ctx(g_st, fs_g, mode, ctx, path=mttkrp_path) \
        + 2.0 * lam * factors[mode]
    shift = 2.0 * lam + damping
    mv = functools.partial(gram_matvec, w_st, list(factors), mode,
                           lam=shift, ctx=ctx, h_slices=h_slices,
                           mttkrp_path=mttkrp_path, matvec_path=matvec_path)
    # diagonal of each row's R×R Gram block, one MTTKRP with squared factors:
    # diag_i[r] = Σ_{n∈Ω_i} ω_n Π_{e≠d} A_e[i_e, r]²
    sq = [None if d == mode else jnp.square(f)
          for d, f in enumerate(factors)]
    diag = mttkrp_ctx(w_st, sq, mode, ctx, path=mttkrp_path) + shift
    delta, _ = batched_pcg(mv, -g, jnp.zeros_like(g),
                           precond=lambda v: v / diag,
                           tol=cg_tol, max_iters=cg_iters, ctx=ctx)
    return factors[mode] + delta


def joint_ggn_step(st: SparseTensor, factors: List[jax.Array], loss: Loss,
                   lam: float, damping, joint_iters: int = 15,
                   precond_iters: int = 8, ctx: AxisCtx = LOCAL,
                   h_slices: int = 1, matvec_path: Optional[str] = None,
                   mttkrp_path: Optional[str] = None
                   ) -> Tuple[List[jax.Array], jax.Array]:
    """One joint LM step with line search. Returns (new factors, step α);
    α = 0 means the step was rejected (no objective decrease)."""
    w_st, model = curvature_tensor(st, factors, loss, ctx)
    g = _gradients(st, factors, model, loss, lam, ctx, mttkrp_path)
    shift = 2.0 * lam + damping
    mv = functools.partial(joint_ggn_matvec, st, w_st, list(factors),
                           shift=shift, ctx=ctx, mttkrp_path=mttkrp_path)

    def precond(rs):
        # block-Jacobi: apply each H_dd⁻¹ by a fixed number of batched-CG
        # iterations on the eq.-3 weighted Gram matvec
        out = []
        for d in range(st.ndim):
            mvd = functools.partial(gram_matvec, w_st, list(factors), d,
                                    lam=shift, ctx=ctx, h_slices=h_slices,
                                    mttkrp_path=mttkrp_path,
                                    matvec_path=matvec_path)
            out.append(_block_cg_fixed(mvd, rs[d], precond_iters, ctx))
        return tuple(out)

    delta = _flexible_pcg(mv, tuple(-gg for gg in g), precond,
                          joint_iters, ctx)
    f0 = gcp_loss(st, list(factors), loss, lam, ctx)
    objs = jnp.stack([gcp_loss(st, [f + a * d_ for f, d_ in
                                    zip(factors, delta)], loss, lam, ctx)
                      for a in LINE_SEARCH_ALPHAS])
    best = jnp.argmin(objs)
    alphas = jnp.asarray(LINE_SEARCH_ALPHAS, f0.dtype)
    alpha = jnp.where(objs[best] < f0, alphas[best], 0.0)
    new = [f + alpha * d_ for f, d_ in zip(factors, delta)]
    return new, alpha


def ggn_sweep(st: SparseTensor, state: GGNState, loss: Loss, lam: float,
              cg_tol: float = 1e-4, cg_iters: int = 32,
              joint_iters: int = 15, precond_iters: int = 8,
              use_joint: bool = True, ctx: AxisCtx = LOCAL,
              h_slices: int = 1, matvec_path: Optional[str] = None,
              mttkrp_path: Optional[str] = None,
              adapt_damping: bool = True) -> GGNState:
    """One GGN iteration: joint LM step (optional), then a per-mode damped
    pass (Gauss-Seidel), then LM accept/reject of the whole iteration.
    jit-safe (static line-search grid, jnp.where acceptance)."""
    fs = list(state.factors)
    mu = state.damping
    if use_joint:
        fs, alpha = joint_ggn_step(st, fs, loss, lam, mu,
                                   joint_iters=joint_iters,
                                   precond_iters=precond_iters, ctx=ctx,
                                   h_slices=h_slices,
                                   matvec_path=matvec_path,
                                   mttkrp_path=mttkrp_path)
    else:
        alpha = jnp.asarray(1.0, fs[0].dtype)
    for d in range(st.ndim):
        fs[d] = ggn_update_mode(st, fs, d, loss, lam, mu,
                                cg_tol, cg_iters, ctx, h_slices,
                                matvec_path=matvec_path,
                                mttkrp_path=mttkrp_path)
    if not adapt_damping:
        return GGNState(tuple(fs), mu)
    f_old = gcp_loss(st, list(state.factors), loss, lam, ctx)
    f_new = gcp_loss(st, fs, loss, lam, ctx)
    ok = f_new <= f_old
    factors = tuple(jnp.where(ok, new, old)
                    for new, old in zip(fs, state.factors))
    # μ schedule: shrink on a full step, grow when the line search had to
    # truncate hard (the GN direction overshot), grow harder on rejection
    mu_acc = jnp.where(alpha >= 1.0, mu * DAMPING_DECREASE,
                       jnp.where(alpha >= 0.4, mu, mu * DAMPING_TRUNCATED))
    mu = jnp.clip(jnp.where(ok, mu_acc, mu * DAMPING_INCREASE),
                  DAMPING_MIN, DAMPING_MAX)
    return GGNState(factors, mu)
