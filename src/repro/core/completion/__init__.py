from repro.core.completion.als import (als_sweep, als_sweep_explicit,
                                       batched_cg, batched_pcg)
from repro.core.completion.ccd import ccd_sweep, ccd_sweep_tttp
from repro.core.completion.gauss_newton import GGNState, ggn_init, ggn_sweep
from repro.core.completion.sgd import sgd_sweep
from repro.core.completion.gcp import gcp_step, gcp_adam_init

__all__ = ["als_sweep", "als_sweep_explicit", "batched_cg", "batched_pcg",
           "ccd_sweep", "ccd_sweep_tttp", "sgd_sweep", "gcp_step",
           "gcp_adam_init", "GGNState", "ggn_init", "ggn_sweep"]
