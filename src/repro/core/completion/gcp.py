"""Generalized-loss tensor completion (GCP) — the assigned-title revision.

Minimizes  Σ_{n∈Ω} ℓ(t_n, m_n) + λ Σ_d ‖A_d‖²  for any elementwise loss
(``repro.core.losses``). The gradient w.r.t. factor ``d`` is

    ∇_{A_d} = MTTKRP( Ω-pattern tensor with values ∂ℓ/∂m |_n , factors≠d )
              + 2λ A_d

— i.e. exactly the paper's kernels with the loss gradient in place of the
residual; quadratic loss recovers §2.4's (2×) gradient. Optimized with plain
GD or Adam (both deterministic full-batch; combine with ``sgd.sample_entries``
for the stochastic variant).
"""
from __future__ import annotations

from typing import List, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.distributed import AxisCtx, LOCAL
from repro.core.losses import Loss
from repro.core.sparse_tensor import SparseTensor


class AdamState(NamedTuple):
    mu: List[jax.Array]
    nu: List[jax.Array]
    count: jax.Array


def gcp_adam_init(factors: Sequence[jax.Array]) -> AdamState:
    return AdamState([jnp.zeros_like(f) for f in factors],
                     [jnp.zeros_like(f) for f in factors],
                     jnp.zeros((), jnp.int32))


def gcp_loss(st: SparseTensor, factors: Sequence[jax.Array], loss: Loss,
             lam: float, ctx: AxisCtx = LOCAL) -> jax.Array:
    from repro.core.tttp import multilinear_values
    model = ctx.psum_model(multilinear_values(st, list(factors)))
    data = ctx.psum_data(jnp.sum(jnp.where(st.mask,
                                           loss.value(st.values, model), 0.0)))
    reg = lam * sum(jnp.sum(jnp.square(f)) for f in factors)
    return data + reg


def gcp_gradients(st: SparseTensor, factors: Sequence[jax.Array], loss: Loss,
                  lam: float, ctx: AxisCtx = LOCAL,
                  mttkrp_path: Optional[str] = None) -> List[jax.Array]:
    """Per-factor gradients, MTTKRPs dispatched through the planner
    executor with ``ctx`` (psum(data) inside dispatch — DESIGN.md §9);
    ``mttkrp_path`` forces a planner candidate."""
    from repro.core.distributed import mttkrp_ctx
    from repro.core.tttp import multilinear_values
    model = ctx.psum_model(multilinear_values(st, list(factors)))
    g_vals = jnp.where(st.mask, loss.grad(st.values, model), 0.0)
    g_st = st.with_values(g_vals)
    grads = []
    for d in range(st.ndim):
        fs = list(factors)
        fs[d] = None
        grads.append(mttkrp_ctx(g_st, fs, d, ctx, path=mttkrp_path)
                     + 2.0 * lam * factors[d])
    return grads


def gcp_step(st: SparseTensor, factors: Sequence[jax.Array], loss: Loss,
             lam: float, lr: float, state: AdamState,
             use_adam: bool = True, b1: float = 0.9, b2: float = 0.999,
             eps: float = 1e-8, ctx: AxisCtx = LOCAL,
             mttkrp_path: Optional[str] = None
             ) -> Tuple[List[jax.Array], AdamState]:
    """One full-batch generalized-loss update (GD or Adam)."""
    grads = gcp_gradients(st, factors, loss, lam, ctx,
                          mttkrp_path=mttkrp_path)
    fs = list(factors)
    if not use_adam:
        return [f - lr * g for f, g in zip(fs, grads)], state
    count = state.count + 1
    mus, nus, out = [], [], []
    for f, g, mu, nu in zip(fs, grads, state.mu, state.nu):
        mu = b1 * mu + (1 - b1) * g
        nu = b2 * nu + (1 - b2) * jnp.square(g)
        mu_hat = mu / (1 - b1 ** count)
        nu_hat = nu / (1 - b2 ** count)
        out.append(f - lr * mu_hat / (jnp.sqrt(nu_hat) + eps))
        mus.append(mu)
        nus.append(nu)
    return out, AdamState(mus, nus, count)
