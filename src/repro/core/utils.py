"""Small shared utilities: padding, rounding, tree helpers."""
from __future__ import annotations

import math
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np


def round_up(x: int, mult: int) -> int:
    """Round ``x`` up to the nearest multiple of ``mult``."""
    return ((x + mult - 1) // mult) * mult


def axis_size(name) -> int:
    """Static size of a mapped mesh axis. ``jax.lax.axis_size`` only exists
    in newer jax; ``psum`` of a Python scalar is special-cased to return the
    axis size as a static int on every version we support."""
    return jax.lax.psum(1, name)


def cdiv(a: int, b: int) -> int:
    return (a + b - 1) // b


def pad_axis(x: jax.Array, size: int, axis: int = 0, value=0) -> jax.Array:
    """Pad ``x`` along ``axis`` up to ``size`` with ``value``."""
    cur = x.shape[axis]
    if cur == size:
        return x
    if cur > size:
        raise ValueError(f"cannot pad axis {axis} of size {cur} down to {size}")
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, size - cur)
    return jnp.pad(x, widths, constant_values=value)


def linearize(indices: jax.Array, shape: Sequence[int]) -> jax.Array:
    """Row-major linearization of an ``(..., ndim)`` int index array.

    Requires ``prod(shape)`` to fit the widest available integer (int64 with
    jax x64 enabled, int32 otherwise) — guarded explicitly. Key-comparison
    call sites use :func:`lex_sort_perm` instead, which has no such limit."""
    total = int(np.prod([int(s) for s in shape]))
    itype = jnp.int64 if jax.config.jax_enable_x64 else jnp.int32
    if total > np.iinfo(np.dtype(itype.dtype.name)).max:
        raise ValueError(
            f"linearize: prod(shape)={total} overflows {itype.dtype.name}; "
            "enable jax x64 or avoid linearized indexing at this scale")
    strides = np.ones(len(shape), dtype=np.int64)
    for d in range(len(shape) - 2, -1, -1):
        strides[d] = strides[d + 1] * shape[d + 1]
    return jnp.sum(indices.astype(itype) * jnp.asarray(strides, itype.dtype.name),
                   axis=-1)


def lex_sort_perm(indices: jax.Array, mask: jax.Array,
                  cols: Sequence[int]) -> jax.Array:
    """Permutation sorting rows of ``indices`` lexicographically by ``cols``
    (first col most significant), invalid (mask=False) rows last. Multi-pass
    stable argsort — overflow-free at any tensor scale."""
    n = indices.shape[0]
    perm = jnp.arange(n)
    for c in reversed(list(cols)):
        key = indices[perm, c]
        perm = perm[jnp.argsort(key, stable=True)]
    # push invalid rows to the end (stable)
    perm = perm[jnp.argsort(~mask[perm], stable=True)]
    return perm


def rows_equal(a: jax.Array, b: jax.Array) -> jax.Array:
    """Elementwise row equality for (n, k) int arrays."""
    return jnp.all(a == b, axis=-1)


def delinearize(lin: jax.Array, shape: Sequence[int]) -> jax.Array:
    """Inverse of :func:`linearize`; returns ``(..., ndim)`` int32 indices."""
    out = []
    itype = jnp.int64 if jax.config.jax_enable_x64 else jnp.int32
    rem = lin.astype(itype)
    strides = np.ones(len(shape), dtype=np.int64)
    for d in range(len(shape) - 2, -1, -1):
        strides[d] = strides[d + 1] * shape[d + 1]
    for d in range(len(shape)):
        out.append((rem // strides[d]).astype(jnp.int32))
        rem = rem % strides[d]
    return jnp.stack(out, axis=-1)


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def param_count(tree) -> int:
    return sum(int(math.prod(l.shape)) for l in jax.tree_util.tree_leaves(tree))
