"""SparseTensor: the distributed sparse-tensor container of the framework.

Representation (TPU adaptation of Cyclops' COO + CCSR, see DESIGN.md §3):

* padded COO — ``indices (cap, ndim) int32``, ``values (cap,) + optional
  trailing dense axis``, and an explicit ``valid (cap,) bool`` mask. Padded
  entries carry ``index = 0`` and ``value = 0`` so gathers stay in-bounds and
  linear reductions are unaffected; the mask guards the nonlinear paths
  (residuals, generalized-loss gradients). ``cap`` is static, making every
  operation SPMD-compatible; the mask is a pytree child, so it shards with
  the data — inside ``shard_map`` each shard sees its *local* validity,
  which static metadata could not express.
* storage is Θ(cap) = Θ(m) — never Θ(rows) — preserving the paper's
  hypersparse Θ(m) guarantee.

Sorting by a mode produces the CCSR-style view used by the bucketed Pallas
kernels (see ``repro.sparse.ccsr``).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.utils import delinearize, linearize, pad_axis, round_up


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class SparseTensor:
    """Padded-COO sparse tensor (optionally with a trailing dense axis of
    size R, used for pairwise-contraction intermediates à la paper §3.2)."""

    indices: jax.Array  # (cap, ndim) int32
    values: jax.Array   # (cap,) or (cap, R)
    valid: jax.Array    # (cap,) bool
    shape: Tuple[int, ...]             # static logical shape (sparse modes)
    nnz: Optional[int] = None          # static GLOBAL nonzero count hint
    sorted_mode: Optional[int] = None  # mode by which entries are sorted
    # static per-mode nonzero-row-count hint (hypersparse metadata) — set by
    # streaming ingest (data.streaming.IngestStats) and consumed by the
    # planner's cost model, which bounds segment/bucket output traffic by the
    # number of rows actually touched rather than the mode extent
    nnz_rows: Optional[Tuple[int, ...]] = None
    # Ingest-time CCSR bucket patterns, keyed (mode, block_rows). Shared by
    # reference across value-preserving derivations (``with_values`` — the
    # Ω pattern is identical) and dropped by pattern-changing ops and by the
    # pytree protocol (inside jit the host-side views don't apply anyway).
    _pattern_cache: Optional[dict] = dataclasses.field(
        default=None, repr=False, compare=False)

    # -- pytree protocol ---------------------------------------------------
    def tree_flatten(self):
        return ((self.indices, self.values, self.valid),
                (self.shape, self.nnz, self.sorted_mode, self.nnz_rows))

    @classmethod
    def tree_unflatten(cls, aux, children):
        indices, values, valid = children
        shape, nnz, sorted_mode, nnz_rows = aux
        return cls(indices, values, valid, shape, nnz, sorted_mode, nnz_rows)

    # -- basic properties ---------------------------------------------------
    @property
    def cap(self) -> int:
        return self.indices.shape[0]

    @property
    def ndim(self) -> int:
        return len(self.shape)

    @property
    def dense_dim(self) -> Optional[int]:
        return None if self.values.ndim == 1 else self.values.shape[1]

    @property
    def mask(self) -> jax.Array:
        """(cap,) validity mask."""
        return self.valid

    def _vmask(self) -> jax.Array:
        return self.valid if self.values.ndim == 1 else self.valid[:, None]

    def masked_values(self) -> jax.Array:
        return jnp.where(self._vmask(), self.values, 0)

    def count_valid(self) -> jax.Array:
        return jnp.sum(self.valid)

    # -- constructors ---------------------------------------------------------
    @classmethod
    def from_coo(cls, indices, values, shape, cap: Optional[int] = None,
                 pad_multiple: int = 1) -> "SparseTensor":
        indices = jnp.asarray(indices, jnp.int32)
        values = jnp.asarray(values)
        nnz = int(indices.shape[0])
        if cap is None:
            cap = round_up(max(nnz, 1), pad_multiple)
        valid = jnp.arange(cap) < nnz
        indices = pad_axis(indices, cap, axis=0, value=0)
        values = pad_axis(values, cap, axis=0, value=0)
        return cls(indices, values, valid, tuple(int(s) for s in shape), nnz)

    @classmethod
    def random(cls, key, shape, nnz: int, cap: Optional[int] = None,
               dtype=jnp.float32, low=-1.0, high=1.0) -> "SparseTensor":
        """Uniform-random sparse tensor (paper's ``fill_sp_random``).

        Indices are sampled i.i.d. uniformly (collisions possible but
        vanishingly rare at the densities of interest, matching Cyclops)."""
        kidx, kval = jax.random.split(key)
        idx_cols = []
        for d, s in enumerate(shape):
            kidx, kd = jax.random.split(kidx)
            idx_cols.append(jax.random.randint(kd, (nnz,), 0, s, jnp.int32))
        indices = jnp.stack(idx_cols, axis=1)
        values = jax.random.uniform(kval, (nnz,), dtype, low, high)
        return cls.from_coo(indices, values, shape, cap=cap)

    # -- transformations ------------------------------------------------------
    def sort_by_mode(self, mode: int) -> "SparseTensor":
        """Sort entries so that ``indices[:, mode]`` is non-decreasing, with
        padded entries moved to the end (they sort to ``shape[mode]``)."""
        key = jnp.where(self.valid, self.indices[:, mode], self.shape[mode])
        perm = jnp.argsort(key, stable=True)
        return SparseTensor(self.indices[perm], self.values[perm],
                            self.valid[perm], self.shape, self.nnz,
                            sorted_mode=mode, nnz_rows=self.nnz_rows)

    def with_values(self, values: jax.Array) -> "SparseTensor":
        """Same pattern, new values (zeroed on padding). Shares the cached
        bucket patterns — the Ω pattern is unchanged, so cached views stay
        valid and only the bucket values are re-gathered on use."""
        vmask = self.valid if values.ndim == 1 else self.valid[:, None]
        return SparseTensor(self.indices, jnp.where(vmask, values, 0),
                            self.valid, self.shape, self.nnz, self.sorted_mode,
                            self.nnz_rows, _pattern_cache=self._pattern_cache)

    def astype(self, dtype) -> "SparseTensor":
        return SparseTensor(self.indices, self.values.astype(dtype),
                            self.valid, self.shape, self.nnz, self.sorted_mode,
                            self.nnz_rows, _pattern_cache=self._pattern_cache)

    def row_buckets(self, mode: int, block_rows: int):
        """Cached CCSR bucket view over ``mode`` (``repro.sparse.ccsr``).

        The host-side pattern build runs once per (mode, block_rows) —
        normally at ingest (``data.pipeline.CompletionDataset``) — and is
        reused across ``with_values`` derivations; each call re-gathers the
        current values through the cached pattern (jit-safe in values).
        Returns ``None`` when the pattern is unavailable because the
        indices are abstract (tracing) and nothing was cached — callers
        fall back to the all-at-once kernels."""
        if self.dense_dim is not None:
            # trailing-dense values have no bucket view — checked before the
            # cache lookup: a with_values derivation can widen the values
            # while sharing a pattern built from the scalar-valued sibling
            return None
        if self._pattern_cache is None:
            object.__setattr__(self, "_pattern_cache", {})
        key = (int(mode), int(block_rows))
        pat = self._pattern_cache.get(key)
        if pat is None:
            if (isinstance(self.indices, jax.core.Tracer)
                    or isinstance(self.valid, jax.core.Tracer)):
                return None
            from repro.sparse.ccsr import bucket_pattern
            pat = bucket_pattern(self, mode, block_rows)
            self._pattern_cache[key] = pat
        return pat.gather(self)

    def attach_pattern(self, mode: int, block_rows: int, pattern) -> None:
        """Install an externally built CCSR bucket pattern (ingest-time
        incremental build, ``repro.sparse.ccsr.IncrementalBucketBuilder``)
        so later ``row_buckets`` calls skip the host-side build."""
        if self._pattern_cache is None:
            object.__setattr__(self, "_pattern_cache", {})
        self._pattern_cache[(int(mode), int(block_rows))] = pattern

    def todense(self) -> jax.Array:
        """Materialize (small tensors / tests only)."""
        out_shape = self.shape if self.dense_dim is None else (*self.shape, self.dense_dim)
        out = jnp.zeros(out_shape, self.values.dtype)
        return out.at[tuple(self.indices[:, d] for d in range(self.ndim))].add(
            self.masked_values())

    def transpose(self, perm: Sequence[int]) -> "SparseTensor":
        """Permute sparse modes (paper Fig. 4 'transpose'); returns new tensor."""
        perm = tuple(perm)
        new_idx = self.indices[:, list(perm)]
        new_shape = tuple(self.shape[p] for p in perm)
        new_rows = (None if self.nnz_rows is None
                    else tuple(self.nnz_rows[p] for p in perm))
        return SparseTensor(new_idx, self.values, self.valid, new_shape,
                            self.nnz, None, new_rows)

    def reshape(self, new_shape: Sequence[int]) -> "SparseTensor":
        """Reshape preserving row-major global order (paper Fig. 4 'reshape')."""
        new_shape = tuple(int(s) for s in new_shape)
        if int(np.prod(new_shape)) != int(np.prod(self.shape)):
            raise ValueError(f"reshape {self.shape} -> {new_shape}: size mismatch")
        lin = linearize(self.indices, self.shape)
        lin = jnp.where(self.valid, lin, 0)
        new_idx = delinearize(lin, new_shape)
        new_idx = jnp.where(self.valid[:, None], new_idx, 0)
        return SparseTensor(new_idx, self.values, self.valid, new_shape,
                            self.nnz, None)

    def scale(self, alpha) -> "SparseTensor":
        return self.with_values(self.values * alpha)

    def add(self, other: "SparseTensor") -> "SparseTensor":
        """Sparse + sparse with identical pattern (same indices)."""
        assert self.shape == other.shape
        return self.with_values(self.values + other.values)

    def reduce_mode(self, mode: int, num_segments: Optional[int] = None) -> jax.Array:
        """``einsum('ijk->i')``-style reduction onto one mode (dense output).

        Works for scalar or trailing-dense values."""
        num_segments = num_segments or self.shape[mode]
        return jax.ops.segment_sum(self.masked_values(),
                                   self.indices[:, mode],
                                   num_segments=num_segments)

    def sum(self) -> jax.Array:
        return jnp.sum(self.masked_values())

    def norm(self) -> jax.Array:
        return jnp.sqrt(jnp.sum(jnp.square(self.masked_values())))
