from repro.core.sparse_tensor import SparseTensor
from repro.core import api, distributed, losses, tttp, utils
from repro.core import completion

__all__ = ["SparseTensor", "api", "distributed", "losses", "tttp", "utils",
           "completion"]
