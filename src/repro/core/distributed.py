"""Distributed execution layer: the Cyclops role, played by shard_map.

The completion algorithms (``repro.core.completion``) are written against an
:class:`AxisCtx` that abstracts over local vs. distributed execution — user
algorithm code is *parallelism-oblivious*, the paper's central thesis. The
ctx primitives here (``tttp_ctx``/``mttkrp_ctx``/``reduce_mode_ctx``/
``mttkrp_rowsharded``) are shims over the planner executor
(``repro.planner``, DESIGN.md §9): the ctx rides into the plan's
distribution signature and dispatch applies the collectives. The mapping
(DESIGN.md §4):

* nonzeros sharded over the data axes (flattened ``("pod","data")`` on the
  multi-pod mesh) — the paper's distribution of observed entries;
* factor matrices **column-sharded over the model axis** — the paper's
  H-slicing of R realized as a mesh axis — and replicated over data axes;
* TTTP ⇒ local partial inner products + ``psum(model)``;
* MTTKRP ⇒ local segment-sum + ``psum(data)`` (column slices stay local);
* CG row-wise dots ⇒ ``psum(model)``.

Also provides the paper-faithful **butterfly sparse all-reduce** (Fig. 1):
recursive-halving reduce-scatter over linearized-coordinate ranges with local
hypersparse summation at each step, followed by an all-gather — used for
reducing sparse blocks with device-dependent patterns.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.sparse_tensor import SparseTensor
from repro.core.utils import axis_size
from repro.sparse import ops as sops


@dataclasses.dataclass(frozen=True)
class AxisCtx:
    """Names of mesh axes inside a shard_map region (None ⇒ local run)."""
    data: Optional[object] = None   # axis name or tuple of names
    model: Optional[str] = None

    def psum_data(self, x):
        return jax.lax.psum(x, self.data) if self.data is not None else x

    def psum_model(self, x):
        return jax.lax.psum(x, self.model) if self.model is not None else x

    def data_size(self) -> int:
        if self.data is None:
            return 1
        names = self.data if isinstance(self.data, tuple) else (self.data,)
        return int(np.prod([axis_size(n) for n in names]))

    def model_size(self) -> int:
        return axis_size(self.model) if self.model is not None else 1

    def model_index(self):
        return jax.lax.axis_index(self.model) if self.model is not None else 0


LOCAL = AxisCtx()


@dataclasses.dataclass
class DistLayout:
    """Mesh + specs for the completion workload."""
    mesh: Mesh
    data_axes: tuple            # e.g. ("data",) or ("pod", "data")
    model_axis: Optional[str]   # e.g. "model"; None = replicated factors

    @property
    def ctx(self) -> AxisCtx:
        data = self.data_axes if len(self.data_axes) > 1 else self.data_axes[0]
        return AxisCtx(data=data, model=self.model_axis)

    def nnz_spec(self) -> P:
        return P(self.data_axes if len(self.data_axes) > 1 else self.data_axes[0])

    def sparse_specs(self, st: SparseTensor):
        """SparseTensor-shaped pytree of PartitionSpecs (nonzeros over the
        data axes; the valid mask shards with the values)."""
        d = self.data_axes if len(self.data_axes) > 1 else self.data_axes[0]
        idx_spec = P(d, None)
        val_spec = P(d) if st.values.ndim == 1 else P(d, None)
        return SparseTensor(idx_spec, val_spec, P(d), st.shape, st.nnz,
                            st.sorted_mode, st.nnz_rows)

    def factor_spec(self) -> P:
        return P(None, self.model_axis)  # rows replicated, columns H-sliced

    def shard(self, fn: Callable, in_specs, out_specs) -> Callable:
        return shard_map(fn, mesh=self.mesh, in_specs=in_specs,
                         out_specs=out_specs, check_rep=False)


# ---------------------------------------------------------------------------
# ctx-parameterized primitives (used inside completion algorithms)
#
# These are thin shims over the planner executor (DESIGN.md §9): the
# contraction is classified, candidate paths ranked with the communication
# terms the ctx implies, and the winner dispatched with the ctx's psums
# applied inside dispatch — a single execution layer from IR to mesh.
# ---------------------------------------------------------------------------

def tttp_ctx(st: SparseTensor, factors, ctx: AxisCtx,
             kernel_fn=None, path: Optional[str] = None) -> SparseTensor:
    """TTTP under AxisCtx: factors column-sharded over the model axis ⇒
    local partial inner products + psum(model), via planner dispatch.
    ``path`` forces a planner candidate; ``kernel_fn`` bypasses the planner
    with a raw values-kernel (benchmark escape hatch)."""
    if kernel_fn is not None:
        partial = kernel_fn(st, factors)
        return st.with_values(st.values * ctx.psum_model(partial))
    from repro.planner import planned_tttp
    return planned_tttp(st, factors, path=path, ctx=ctx)


def mttkrp_ctx(st: SparseTensor, factors, mode: int, ctx: AxisCtx,
               path: Optional[str] = None) -> jax.Array:
    """MTTKRP under AxisCtx via planner dispatch: local contraction + psum
    over data axes (applied inside dispatch). Output is (rows, R_local):
    replicated over data, column-sharded over model."""
    from repro.planner import planned_mttkrp
    return planned_mttkrp(st, factors, mode, path=path, ctx=ctx)


def reduce_mode_ctx(st: SparseTensor, mode: int, ctx: AxisCtx) -> jax.Array:
    """``einsum('ijk->i')``-style sparse mode reduction under AxisCtx (local
    segment-sum + psum(data)), via planner dispatch."""
    from repro.planner import planned_reduce
    return planned_reduce(st, (mode,), ctx=ctx)


def rowdot_ctx(a: jax.Array, b: jax.Array, ctx: AxisCtx) -> jax.Array:
    """Row-wise inner products of column-sharded (rows, R_local) matrices."""
    return ctx.psum_model(jnp.sum(a * b, axis=-1))


def sqnorm_ctx(a: jax.Array, ctx: AxisCtx) -> jax.Array:
    return ctx.psum_model(jnp.sum(jnp.square(a)))


# ---------------------------------------------------------------------------
# butterfly sparse all-reduce (paper Fig. 1), k=2
# ---------------------------------------------------------------------------

def sparse_allreduce_butterfly(st: SparseTensor, axis_name: str) -> SparseTensor:
    """All-reduce sparse blocks with device-dependent patterns over a mesh
    axis: recursive halving on linearized-coordinate ranges (reduce-scatter)
    with hypersparse local summation per step, then recursive doubling
    (all-gather). Static capacities throughout; per-step message capacity is
    the full block capacity (mask-padded), so the win vs. dense all-reduce is
    the Θ(m) payload, as in the paper."""
    size = axis_size(axis_name)
    rank = jax.lax.axis_index(axis_name)
    steps = int(np.log2(size))
    assert 2 ** steps == size, "butterfly requires power-of-two axis"
    # Owned range is tracked via mode-0 coordinate intervals.
    lo, hi = jnp.int32(0), jnp.int32(st.shape[0])
    cur = st
    # reduce-scatter (recursive halving)
    for s in range(steps):
        bit = (rank >> s) & 1
        mid = lo + (hi - lo) // 2
        # partner differs in bit s
        perm = [(i, i ^ (1 << s)) for i in range(size)]
        keep_lo = jnp.where(bit == 0, lo, mid)
        keep_hi = jnp.where(bit == 0, mid, hi)
        rows = cur.indices[:, 0]
        mine = (rows >= keep_lo) & (rows < keep_hi) & cur.mask
        theirs = ~mine & cur.mask
        vals = cur.masked_values()
        recv_idx = jax.lax.ppermute(cur.indices, axis_name, perm)
        recv_vals = jax.lax.ppermute(jnp.where(theirs, vals, 0.0),
                                     axis_name, perm)
        recv_valid = jax.lax.ppermute(theirs, axis_name, perm)
        a = SparseTensor(cur.indices, jnp.where(mine, vals, 0.0), mine,
                         cur.shape)
        b = SparseTensor(recv_idx, recv_vals, recv_valid, cur.shape)
        cur = sops.sparse_add_union(a, b)
        # halve capacity: after the union-sort, valid owned entries are first
        cur = SparseTensor(cur.indices[:st.cap], cur.values[:st.cap],
                           cur.valid[:st.cap], cur.shape)
        lo, hi = keep_lo, keep_hi
    # all-gather (recursive doubling): owned ranges are disjoint, so the
    # union-sum is exact; per-step capacity doubles back up to size*cap.
    out = cur
    for s in range(steps - 1, -1, -1):
        perm = [(i, i ^ (1 << s)) for i in range(size)]
        recv_idx = jax.lax.ppermute(out.indices, axis_name, perm)
        recv_vals = jax.lax.ppermute(out.masked_values(), axis_name, perm)
        recv_valid = jax.lax.ppermute(out.valid, axis_name, perm)
        out = sops.sparse_add_union(
            out, SparseTensor(recv_idx, recv_vals, recv_valid, out.shape))
    return out


# ---------------------------------------------------------------------------
# Row-sharded factors with H-sliced, overlap-friendly gathers (paper Fig. 2)
#
# ``multilinear_rowsharded`` / ``_mttkrp_rowsharded_impl`` are the raw
# collective kernels the planner's "rowsharded" path dispatches onto;
# ``mttkrp_rowsharded`` is the public planner shim.
# ---------------------------------------------------------------------------

def multilinear_rowsharded(st: SparseTensor, factors_local, ctx: AxisCtx,
                           h_slices: int = 1) -> jax.Array:
    """Σ_r Π_d A_d[i_d, r] with factor ROWS sharded over the data axes —
    the paper's memory-scalable distribution: each slice's columns are
    all-gathered (payload Θ(I·R/H)), used, and discarded; the gather for
    slice h+1 is issued before slice h's compute consumes its operand, so
    the latency-hiding scheduler overlaps communication with compute
    (paper Fig. 2's per-slice redistribution, plus overlap)."""
    r = next(f.shape[1] for f in factors_local if f is not None)
    rs = -(-r // max(h_slices, 1))
    axis = ctx.data

    def gather_slice(h):
        out = []
        for f in factors_local:
            if f is None:
                out.append(None)
                continue
            sl = f[:, h * rs:(h + 1) * rs]
            out.append(jax.lax.all_gather(sl, axis, axis=0, tiled=True))
        return out

    acc = jnp.zeros((st.cap,), st.values.dtype)
    nxt = gather_slice(0)
    for h in range(max(h_slices, 1)):
        cur = nxt
        if h + 1 < h_slices:
            nxt = gather_slice(h + 1)   # independent of cur's consumers
        prod = None
        for d, f in enumerate(cur):
            if f is None:
                continue
            rows = f[st.indices[:, d]]
            prod = rows if prod is None else prod * rows
        acc = acc + jnp.sum(prod, axis=1)
    return acc


def mttkrp_rowsharded(st: SparseTensor, factors_local, mode: int,
                      ctx: AxisCtx, h_slices: int = 1) -> jax.Array:
    """MTTKRP with factor ROWS sharded over the data axes, via the planner's
    ``rowsharded`` path: per slice, gather the non-target factors' columns,
    segment-sum locally, then reduce-scatter output rows to their owners
    (Θ(I·R/H) transients and payloads). Output is (rows_local, R)."""
    from repro.planner import planned_mttkrp
    return planned_mttkrp(st, factors_local, mode, ctx=ctx, rowsharded=True,
                          h_slices=h_slices)


def _mttkrp_rowsharded_impl(st: SparseTensor, factors_local, mode: int,
                            ctx: AxisCtx, h_slices: int = 1) -> jax.Array:
    """Raw gather/compute/reduce-scatter kernel behind
    :func:`mttkrp_rowsharded` (invoked by planner dispatch)."""
    r = next(f.shape[1] for f in factors_local if f is not None)
    rs = -(-r // max(h_slices, 1))
    axis = ctx.data
    n_rows = st.shape[mode]
    # the target mode's rows are sharded evenly over the data axes (the
    # target factor itself is not an operand of the contraction)
    p = ctx.data_size()
    if n_rows % p:
        raise ValueError(
            f"row-sharded MTTKRP needs mode {mode}'s extent ({n_rows}) "
            f"divisible by the data-axis size ({p}) — the reduce-scatter "
            f"returns equal row blocks to their owners")
    n_rows_local = n_rows // p
    rows = st.indices[:, mode]
    cols = []
    for h in range(max(h_slices, 1)):
        prod = (st.values * st.mask)[:, None]
        for d, f in enumerate(factors_local):
            if d == mode or f is None:
                continue
            sl = jax.lax.all_gather(f[:, h * rs:(h + 1) * rs], axis,
                                    axis=0, tiled=True)
            prod = prod * sl[st.indices[:, d]]
        part = jax.ops.segment_sum(prod, rows, num_segments=n_rows)
        part = part.reshape(-1, n_rows_local, part.shape[1])
        cols.append(jax.lax.psum_scatter(part, axis, scatter_dimension=0,
                                         tiled=False))
    return jnp.concatenate(cols, axis=-1)[:, :r] if len(cols) > 1 \
        else cols[0][:, :r]
