"""Hypersparse (CCSR/DCSR) row-compressed views — TPU adaptation.

The paper extends Cyclops with a doubly-compressed 'CCSR' layout: CSR over the
*nonzero rows only*, plus a map from compressed rows to original rows, giving
Θ(m) storage for m nonzeros (vs Θ(rows + m) for CSR). On TPU there is no
efficient pointer-chasing, so we realize the same two guarantees differently
(DESIGN.md §3):

* **Θ(m) storage** — `CCSRView` stores `row_ids` (the nonzero rows) and
  `row_ptr` over the *sorted* COO entries, both with capacity O(m), never
  O(rows).
* **MXU-friendly traversal** — `RowBlockBuckets` groups sorted entries into
  fixed-capacity buckets of `block_rows` consecutive rows. Inside a Pallas
  kernel a bucket's scatter-add becomes a one-hot ``(block_rows × capacity)``
  matmul: the doubly-compressed scatter runs on the systolic array.

Bucketing happens once at ingest (the Ω pattern is static across completion
iterations, as in Cyclops' runtime layout decisions), so the host-side numpy
path is the fast path; a jit-able jnp path is provided for dynamic patterns.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.sparse_tensor import SparseTensor
from repro.core.utils import cdiv, round_up


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class CCSRView:
    """Doubly-compressed view over a mode of a sorted SparseTensor.

    ``row_ids[c]`` is the original row of compressed row ``c`` (padded with
    ``num_rows``); entries of compressed row ``c`` occupy the slice
    ``row_ptr[c]:row_ptr[c+1]`` of the sorted COO arrays."""

    row_ids: jax.Array   # (rows_cap,) int32, padded with num_rows
    row_ptr: jax.Array   # (rows_cap + 1,) int32
    num_rows: int        # original (uncompressed) number of rows
    nnz_rows: jax.Array  # () int32 — number of nonzero rows

    def tree_flatten(self):
        return (self.row_ids, self.row_ptr, self.nnz_rows), (self.num_rows,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        row_ids, row_ptr, nnz_rows = children
        return cls(row_ids, row_ptr, aux[0], nnz_rows)

    @property
    def rows_cap(self) -> int:
        return self.row_ids.shape[0]


def build_ccsr(st: SparseTensor, mode: int, rows_cap: Optional[int] = None) -> CCSRView:
    """Build a CCSR view for ``mode``; ``st`` must be sorted by that mode.

    jit-compatible; ``rows_cap`` (static) defaults to ``min(cap, num_rows)``
    — Θ(m), the hypersparse storage bound."""
    if st.sorted_mode != mode:
        raise ValueError(f"SparseTensor must be sorted by mode {mode} "
                         f"(got sorted_mode={st.sorted_mode})")
    num_rows = st.shape[mode]
    cap = st.cap
    if rows_cap is None:
        rows_cap = min(cap, num_rows)
    rows = jnp.where(st.mask, st.indices[:, mode], num_rows)
    prev = jnp.concatenate([jnp.full((1,), -1, rows.dtype), rows[:-1]])
    is_start = (rows != prev) & st.mask
    # compressed-row index for each entry
    crow = jnp.cumsum(is_start) - 1
    nnz_rows = jnp.sum(is_start).astype(jnp.int32)
    # row_ids: scatter the starting rows into compressed slots
    row_ids = jnp.full((rows_cap,), num_rows, jnp.int32)
    safe_crow = jnp.where(is_start, crow, rows_cap)  # drop non-starts
    row_ids = row_ids.at[safe_crow].set(rows.astype(jnp.int32), mode="drop")
    # row_ptr via counts per compressed row
    counts = jax.ops.segment_sum(st.mask.astype(jnp.int32),
                                 jnp.where(st.mask, crow, rows_cap),
                                 num_segments=rows_cap + 1)[:rows_cap]
    row_ptr = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                               jnp.cumsum(counts).astype(jnp.int32)])
    return CCSRView(row_ids, row_ptr, num_rows, nnz_rows)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class RowBlockBuckets:
    """Fixed-capacity buckets of sorted nonzeros over row blocks of one mode.

    Bucket ``b`` holds all entries with ``row // block_rows == b`` (padded to
    ``capacity`` with value-0 entries). ``local_row = row - b*block_rows`` is
    the in-block scatter target for the one-hot matmul."""

    values: jax.Array     # (nb, capacity)
    indices: jax.Array    # (nb, capacity, ndim) int32 (global indices)
    local_row: jax.Array  # (nb, capacity) int32 in [0, block_rows); padding -> 0
    valid: jax.Array      # (nb, capacity) bool
    mode: int             # bucketed mode
    block_rows: int
    shape: Tuple[int, ...]

    def tree_flatten(self):
        return ((self.values, self.indices, self.local_row, self.valid),
                (self.mode, self.block_rows, self.shape))

    @classmethod
    def tree_unflatten(cls, aux, children):
        values, indices, local_row, valid = children
        mode, block_rows, shape = aux
        return cls(values, indices, local_row, valid, mode, block_rows, shape)

    @property
    def num_blocks(self) -> int:
        return self.values.shape[0]

    @property
    def capacity(self) -> int:
        return self.values.shape[1]

    def with_values_from(self, st: SparseTensor, perm: np.ndarray,
                         scatter: jax.Array) -> jax.Array:
        """(helper for rebuilding values when the pattern is reused)"""
        raise NotImplementedError


@dataclasses.dataclass
class BucketPattern:
    """Ingest-time bucket layout over one mode of a fixed Ω pattern.

    Everything index-derived (the sorted bucket assignment, local rows,
    validity and the ``sel`` map from bucket slot back to its source COO
    position) is precomputed from *concrete* indices once; bucket VALUES
    are re-gathered per call through ``sel``, so tensors that share the
    pattern (``SparseTensor.with_values``) rebuild their bucket view with
    one jit-safe gather instead of a host-side sort."""

    sel: jax.Array        # (nb, capacity) int32 source COO slot; padding → 0
    indices: jax.Array    # (nb, capacity, ndim) int32 (global indices)
    local_row: jax.Array  # (nb, capacity) int32 in [0, block_rows)
    valid: jax.Array      # (nb, capacity) bool
    mode: int
    block_rows: int
    shape: Tuple[int, ...]
    cap: int              # source capacity the pattern was built against

    def gather(self, st: SparseTensor) -> RowBlockBuckets:
        """Bucket view of ``st``'s values through this pattern. ``st`` must
        share the Ω pattern (indices/valid/shape) the pattern was built
        from; jit-safe in ``st.values``."""
        if st.cap != self.cap or st.shape != self.shape:
            raise ValueError(f"pattern built for cap={self.cap} shape="
                             f"{self.shape}, got cap={st.cap} shape={st.shape}")
        vals = jnp.where(self.valid, st.masked_values()[self.sel], 0)
        return RowBlockBuckets(vals, self.indices, self.local_row, self.valid,
                               self.mode, self.block_rows, self.shape)


def bucket_pattern(st: SparseTensor, mode: int, block_rows: int,
                   capacity: Optional[int] = None,
                   capacity_multiple: int = 8) -> BucketPattern:
    """Host-side (numpy) bucket-pattern build; done once at ingest per
    (Ω pattern, mode, block_rows) — requires concrete indices.

    Capacity defaults to the max bucket occupancy rounded up — with shuffled
    (cyclic-equivalent) data this is ≈ mean + O(√mean), the load-balance
    argument of the paper's cyclic layout."""
    if st.dense_dim is not None:
        raise ValueError("bucket views require scalar values")
    idx = np.asarray(st.indices)
    keep = np.asarray(st.valid)
    orig = np.nonzero(keep)[0].astype(np.int32)
    idx = idx[keep]
    nnz = idx.shape[0]
    rows = idx[:, mode]
    if st.sorted_mode != mode:
        order = np.argsort(rows, kind="stable")
        idx, rows, orig = idx[order], rows[order], orig[order]
    # else: entries already non-decreasing in this mode (streamed canonical
    # layouts are sorted by linearized coordinate ⇒ by mode 0) — a stable
    # argsort would be the identity, so skip it
    num_rows = st.shape[mode]
    nb = cdiv(num_rows, block_rows)
    bucket = rows // block_rows
    counts = np.bincount(bucket, minlength=nb)
    if capacity is None:
        capacity = round_up(max(int(counts.max(initial=1)), 1), capacity_multiple)
    elif counts.max(initial=0) > capacity:
        raise ValueError(f"bucket overflow: max occupancy {counts.max()} > "
                         f"capacity {capacity}; increase capacity")
    pos = np.arange(nnz) - np.concatenate([[0], np.cumsum(counts)])[:-1][bucket]
    bsel = np.zeros((nb, capacity), np.int32)
    bidx = np.zeros((nb, capacity, idx.shape[1]), np.int32)
    blocal = np.zeros((nb, capacity), np.int32)
    bvalid = np.zeros((nb, capacity), bool)
    bsel[bucket, pos] = orig
    bidx[bucket, pos] = idx
    blocal[bucket, pos] = rows - bucket * block_rows
    bvalid[bucket, pos] = True
    return BucketPattern(jnp.asarray(bsel), jnp.asarray(bidx),
                         jnp.asarray(blocal), jnp.asarray(bvalid),
                         mode, block_rows, st.shape, st.cap)


def bucket_capacity(counts: np.ndarray, capacity_multiple: int = 8) -> int:
    """Bucket capacity from an occupancy-count array (streamed counts are
    over-estimates under cross-chunk duplicates — a safe padded bound)."""
    return round_up(max(int(np.max(counts, initial=1)), 1), capacity_multiple)


class IncrementalBucketBuilder:
    """Incremental CCSR bucket-pattern construction at ingest time.

    The streaming pipeline (``repro.data.streaming``) cannot afford a
    whole-tensor counting pass per mode once chunks have been spilled:
    instead this builder ``observe``s each (deduped) chunk's indices as it
    streams by, accumulating per-mode bucket occupancy counts in
    O(Σ I_d / block_rows) host memory. At finalize, :meth:`build` hands
    :func:`bucket_pattern` the capacity derived from the streamed counts,
    so the pattern build needs no extra occupancy scan. Cross-chunk
    duplicates (removed later, at shard merge) can only make the streamed
    counts an over-estimate — a safe (slightly padded) capacity."""

    def __init__(self, shape, block_rows: int):
        self.shape = tuple(int(s) for s in shape)
        self.block_rows = int(block_rows)
        self.counts = [np.zeros(cdiv(s, block_rows), np.int64)
                       for s in self.shape]

    def observe(self, indices: np.ndarray) -> None:
        """Accumulate bucket occupancy for one chunk's (n, ndim) indices."""
        for d in range(len(self.shape)):
            b = indices[:, d] // self.block_rows
            self.counts[d] += np.bincount(b, minlength=self.counts[d].shape[0]
                                          ).astype(np.int64)

    def capacity(self, mode: int, capacity_multiple: int = 8) -> int:
        return bucket_capacity(self.counts[mode], capacity_multiple)

    def build(self, st: SparseTensor, mode: int) -> BucketPattern:
        """Pattern for ``st`` (the finalized tensor sharing the observed Ω)
        with the streamed capacity bound."""
        return bucket_pattern(st, mode, self.block_rows,
                              capacity=self.capacity(mode))


def bucketize(st: SparseTensor, mode: int, block_rows: int,
              capacity: Optional[int] = None,
              capacity_multiple: int = 8) -> RowBlockBuckets:
    """One-shot bucket view: pattern build + value gather (see
    :func:`bucket_pattern`; prefer ``SparseTensor.row_buckets`` which caches
    the pattern across value updates)."""
    return bucket_pattern(st, mode, block_rows, capacity,
                          capacity_multiple).gather(st)
