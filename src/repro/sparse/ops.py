"""Sparse×dense contraction paths and hypersparse block summation.

Implements the paper's §3.1 kernel set, TPU-adapted:

* TTM (tensor-times-matrix) with three output representations, mirroring
  Fig. 5a: fully-dense, sparse-input/dense-output, and hypersparse
  (sparse-input/sparse-output with compressed keys);
* all-at-once and pairwise MTTKRP (Fig. 5b);
* summation of sparse blocks with *different* patterns (union pattern), the
  local kernel of the paper's butterfly sparse reduction (Fig. 1).

All functions are jit-compatible with static capacities.
"""
from __future__ import annotations

from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core.sparse_tensor import SparseTensor
from repro.core.utils import lex_sort_perm, linearize, rows_equal


def _other_modes(ndim: int, mode: int) -> List[int]:
    return [d for d in range(ndim) if d != mode]


# ---------------------------------------------------------------------------
# TTM: z_{i..r} = sum_k t_{i..k..} w_{kr}
# ---------------------------------------------------------------------------

def ttm_dense_output(st: SparseTensor, w: jax.Array, mode: int) -> jax.Array:
    """Sparse input, dense output: scatter-add into the full dense tensor.

    Memory Θ(Π_{d≠mode} I_d · R): fast while it fits (paper Fig. 5a 'sparse,
    dense output' variant)."""
    others = _other_modes(st.ndim, mode)
    contrib = (st.values * st.mask)[:, None] * w[st.indices[:, mode]]  # (cap, R)
    out_shape = tuple(st.shape[d] for d in others) + (w.shape[1],)
    out = jnp.zeros(out_shape, contrib.dtype)
    return out.at[tuple(st.indices[:, d] for d in others)].add(contrib)


def ttm_hypersparse(st: SparseTensor, w: jax.Array, mode: int) -> SparseTensor:
    """Sparse input, *sparse* output over compressed uncontracted keys.

    This is the hypersparse path: output entries exist only for observed
    (uncontracted) key combinations — Θ(m) storage with a trailing dense R
    axis, never Θ(Π I_d). Implementation: sort by the merged key, identify
    unique keys (CCSR compression), segment-sum contributions."""
    others = _other_modes(st.ndim, mode)
    key_shape = tuple(st.shape[d] for d in others)
    perm = lex_sort_perm(st.indices, st.mask, others)
    idx_s = st.indices[perm]
    contrib = ((st.values * st.mask)[:, None] * w[st.indices[:, mode]])[perm]
    keys_s = idx_s[:, others]
    prev = jnp.concatenate([jnp.full((1, len(others)), -1, keys_s.dtype),
                            keys_s[:-1]], axis=0)
    mask_s = st.mask[perm]
    is_start = ~rows_equal(keys_s, prev) & mask_s
    crow = jnp.cumsum(is_start) - 1
    cap = st.cap
    out_vals = jax.ops.segment_sum(contrib, jnp.where(mask_s, crow, cap),
                                   num_segments=cap + 1)[:cap]
    out_idx = jnp.zeros((cap, len(others)), jnp.int32)
    safe = jnp.where(is_start, crow, cap)
    out_idx = out_idx.at[safe].set(idx_s[:, others], mode="drop")
    n_unique = jnp.sum(is_start)
    out_valid = jnp.arange(cap) < n_unique
    out_vals = jnp.where(out_valid[:, None], out_vals, 0)
    return SparseTensor(out_idx, out_vals, out_valid, key_shape,
                        sorted_mode=None)


def ttm_fully_dense(t_dense: jax.Array, w: jax.Array, mode: int) -> jax.Array:
    """Dense baseline (paper Fig. 5a 'dense' variant)."""
    t_moved = jnp.moveaxis(t_dense, mode, -1)
    return jnp.einsum("...k,kr->...r", t_moved, w)


# ---------------------------------------------------------------------------
# MTTKRP: y_{ir} = sum_{jk} t_{ijk} v_{jr} w_{kr}  (order-N generalization)
# ---------------------------------------------------------------------------

def mttkrp(st: SparseTensor, factors: Sequence[jax.Array], mode: int) -> jax.Array:
    """All-at-once MTTKRP via gather → product → segment-sum (Θ(mR) work,
    no Θ(mR)-sized *persistent* intermediate; the jnp fallback materializes a
    transient (cap, R) product, the Pallas kernel does not)."""
    others = _other_modes(st.ndim, mode)
    prod = (st.values * st.mask)[:, None]
    for d in others:
        prod = prod * factors[d][st.indices[:, d]]
    return jax.ops.segment_sum(prod, st.indices[:, mode],
                               num_segments=st.shape[mode])


def mttkrp_pairwise_t_first(st: SparseTensor, factors: Sequence[jax.Array],
                            mode: int) -> jax.Array:
    """Pairwise path contracting T with one factor first (hypersparse
    intermediate), then the rest — paper Fig. 5b 'contract with T first'."""
    others = _other_modes(st.ndim, mode)
    last = others[-1]
    z = ttm_hypersparse(st, factors[last], last)  # keys = modes except `last`
    rem = [d for d in range(st.ndim) if d not in (mode, last)]
    prod = z.values
    key_modes = _other_modes(st.ndim, last)  # z's key axes, in order
    for d in rem:
        col = key_modes.index(d)
        prod = prod * factors[d][z.indices[:, col]]
    out_col = key_modes.index(mode)
    return jax.ops.segment_sum(prod, z.indices[:, out_col],
                               num_segments=st.shape[mode])


def mttkrp_pairwise_kr_first(st: SparseTensor, factors: Sequence[jax.Array],
                             mode: int) -> jax.Array:
    """Pairwise path forming the Khatri-Rao product first (dense Θ(Π I_d · R)
    intermediate) — efficient only for relatively dense tensors (paper §5.3)."""
    others = _other_modes(st.ndim, mode)
    kr = factors[others[0]]
    for d in others[1:]:
        kr = (kr[:, None, :] * factors[d][None, :, :]).reshape(-1, kr.shape[-1])
    key_shape = tuple(st.shape[d] for d in others)
    key = linearize(st.indices[:, others], key_shape)
    contrib = (st.values * st.mask)[:, None] * kr[key]
    return jax.ops.segment_sum(contrib, st.indices[:, mode],
                               num_segments=st.shape[mode])


# ---------------------------------------------------------------------------
# Hypersparse block summation (union of patterns) — paper Fig. 1 local kernel
# ---------------------------------------------------------------------------

def sparse_add_union(a: SparseTensor, b: SparseTensor) -> SparseTensor:
    """Sum two sparse tensors with (possibly) different patterns.

    Static output capacity = a.cap + b.cap; duplicate coordinates are merged
    by sorted-segment summation (the TPU analogue of the paper's dense-buffer
    row merge)."""
    assert a.shape == b.shape, (a.shape, b.shape)
    idx = jnp.concatenate([a.indices, b.indices], axis=0)
    vals = jnp.concatenate([a.values * a.mask, b.values * b.mask], axis=0)
    mask = jnp.concatenate([a.mask, b.mask], axis=0)
    cap = idx.shape[0]
    perm = lex_sort_perm(idx, mask, range(idx.shape[1]))
    idx_s, vals_s, mask_s = idx[perm], vals[perm], mask[perm]
    prev = jnp.concatenate([jnp.full((1, idx.shape[1]), -1, idx_s.dtype),
                            idx_s[:-1]], axis=0)
    is_start = ~rows_equal(idx_s, prev) & mask_s
    crow = jnp.cumsum(is_start) - 1
    out_vals = jax.ops.segment_sum(vals_s, jnp.where(mask_s, crow, cap),
                                   num_segments=cap + 1)[:cap]
    out_idx = jnp.zeros((cap, a.indices.shape[1]), jnp.int32)
    out_idx = out_idx.at[jnp.where(is_start, crow, cap)].set(idx_s, mode="drop")
    n_unique = jnp.sum(is_start)
    out_valid = jnp.arange(cap) < n_unique
    out_vals = jnp.where(out_valid, out_vals, 0)
    return SparseTensor(out_idx, out_vals, out_valid, a.shape,
                        sorted_mode=None)


# ---------------------------------------------------------------------------
# SDDMM — TTTP with N=2 (paper §3.2): X = S ⊙ (U Vᵀ)
# ---------------------------------------------------------------------------

def sddmm(s: SparseTensor, u: jax.Array, v: jax.Array) -> SparseTensor:
    assert s.ndim == 2
    ii, jj = s.indices[:, 0], s.indices[:, 1]
    out = s.values * jnp.sum(u[ii] * v[jj], axis=-1)
    return s.with_values(out)
