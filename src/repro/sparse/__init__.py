from repro.sparse.ccsr import CCSRView, RowBlockBuckets, build_ccsr, bucketize
from repro.sparse import ops, redistribute

__all__ = ["CCSRView", "RowBlockBuckets", "build_ccsr", "bucketize", "ops",
           "redistribute"]
