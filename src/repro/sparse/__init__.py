from repro.sparse.ccsr import (BucketPattern, CCSRView, RowBlockBuckets,
                               bucket_pattern, bucketize, build_ccsr)
from repro.sparse import ops, redistribute

__all__ = ["BucketPattern", "CCSRView", "RowBlockBuckets", "bucket_pattern",
           "bucketize", "build_ccsr", "ops", "redistribute"]
