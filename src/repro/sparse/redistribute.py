"""Distributed redistribution of sparse/dense tensors (paper Fig. 4).

Cyclops' redistribution moves tensor data between processor-grid mappings; the
JAX analogue is resharding between ``NamedSharding``s (XLA emits the
collective-permute/all-to-all schedule). We expose the paper's benchmarked
operations — transpose and reshape of sparse and dense distributed tensors —
plus the shard-boundary rebalancing used after transposition (a transposed
sparse tensor is no longer sorted/balanced by its new leading mode).
"""
from __future__ import annotations

from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.sparse_tensor import SparseTensor
from repro.core.utils import lex_sort_perm


def shard_nonzeros(st: SparseTensor, mesh: Mesh, axes) -> SparseTensor:
    """Place a SparseTensor with nonzeros sharded over mesh ``axes`` (paper's
    distribution of observed entries). Capacity must divide the axis size —
    callers pad via ``SparseTensor.from_coo(pad_multiple=...)``."""
    sharding_idx = NamedSharding(mesh, P(axes, None))
    sharding_1d = NamedSharding(mesh, P(axes))
    sharding_val = (sharding_1d if st.values.ndim == 1
                    else NamedSharding(mesh, P(axes, None)))
    return SparseTensor(jax.device_put(st.indices, sharding_idx),
                        jax.device_put(st.values, sharding_val),
                        jax.device_put(st.valid, sharding_1d),
                        st.shape, st.nnz, st.sorted_mode, st.nnz_rows)


def replicate(x: jax.Array, mesh: Mesh) -> jax.Array:
    return jax.device_put(x, NamedSharding(mesh, P()))


def transpose_distributed(st: SparseTensor, perm: Sequence[int],
                          resort: bool = True) -> SparseTensor:
    """Distributed sparse transpose: permute index columns then (optionally)
    globally re-sort by the new leading mode so downstream CCSR views and
    shard balance hold. The global sort is the redistribution step Cyclops
    performs; under jit XLA lowers it to a distributed sort."""
    out = st.transpose(perm)
    if resort:
        p = lex_sort_perm(out.indices, out.mask, range(out.ndim))
        out = SparseTensor(out.indices[p], out.values[p], out.valid[p],
                           out.shape, out.nnz, sorted_mode=0)
    return out


def reshape_distributed(st: SparseTensor, new_shape: Sequence[int],
                        resort: bool = True) -> SparseTensor:
    """Distributed sparse reshape preserving global row-major order (paper
    notes order preservation makes this cheaper than transpose)."""
    out = st.reshape(new_shape)
    if resort:
        # order is preserved by construction; only padding positions move
        out = SparseTensor(out.indices, out.values, out.valid, out.shape,
                           out.nnz, sorted_mode=0 if st.sorted_mode == 0 else None)
    return out


def reshard_dense(x: jax.Array, mesh: Mesh, spec: P) -> jax.Array:
    """Dense redistribution between arbitrary meshes/specs (Cyclops §3.2
    'efficient mechanisms for redistribution of dense matrices')."""
    return jax.device_put(x, NamedSharding(mesh, spec))
