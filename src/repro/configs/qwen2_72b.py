"""qwen2-72b [arXiv:2407.10671; hf].

80L d_model=8192 64H (GQA kv=8) d_ff=29568 vocab=152064, QKV bias.
Full attention -> long_500k skipped."""
from repro.configs.base import ArchConfig, BlockSpec, register

CONFIG = ArchConfig(
    name="qwen2-72b", family="dense",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=29_568,
    vocab=152_064, head_dim=128,
    group=(BlockSpec("attn"),),
    qkv_bias=True, rope_theta=1_000_000.0, ffn_kind="swiglu",
    supports_long_context=False,
)

SMOKE = ArchConfig(
    name="qwen2-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=96,
    vocab=512, head_dim=16,
    group=(BlockSpec("attn"),),
    qkv_bias=True, ffn_kind="swiglu",
)

register(CONFIG, SMOKE)
