"""Architecture config system: one dataclass covers the 10 assigned LM
architectures; per-arch modules instantiate it with the exact public-
literature values and register it under its ``--arch`` id.

Each arch also provides a ``smoke()`` reduction (same family, tiny dims)
used by per-arch CPU smoke tests.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence, Tuple


@dataclasses.dataclass(frozen=True)
class BlockSpec:
    """One block within a repeating layer group."""
    kind: str            # "attn" | "mamba2" | "mlstm" | "slstm"
    attn_scope: str = "global"   # "global" | "local" | "chunked"
    shared: bool = False         # zamba2-style shared weights


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None

    # layer-group structure: `group` repeats n_layers/len(group) times
    group: Tuple[BlockSpec, ...] = (BlockSpec("attn"),)

    # attention
    attn_kind: str = "gqa"       # gqa | mla
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    local_window: int = 4096
    chunk_size: int = 8192
    attn_softcap: float = 0.0
    final_softcap: float = 0.0

    # MLA (minicpm3)
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0

    # ffn / moe
    ffn_kind: str = "swiglu"     # swiglu | geglu | none
    n_experts: int = 0           # 0 = dense FFN
    top_k: int = 1
    n_shared_experts: int = 0
    capacity_factor: float = 1.25

    # ssm
    ssm_state: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 64
    xlstm_chunk: int = 0   # 0 = recurrent mLSTM; >0 = chunkwise-parallel

    # encoder-decoder (whisper)
    encoder_layers: int = 0

    # modality frontend stub
    frontend: Optional[str] = None    # None | "patch" | "frames"
    num_patches: int = 256

    # norms / misc
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    seq_sharded_residual: int = 0  # 1 = Megatron-SP style x sharded over tp

    # which shape cells are lowered; long_500k handled per DESIGN.md §5
    supports_long_context: bool = False

    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim_()

    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def group_size(self) -> int:
        return len(self.group)

    @property
    def n_groups(self) -> int:
        assert self.n_layers % self.group_size == 0, \
            f"{self.name}: n_layers {self.n_layers} % group {self.group_size}"
        return self.n_layers // self.group_size


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    """One assigned input-shape cell."""
    name: str
    seq_len: int
    global_batch: int
    kind: str          # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524_288, 1, "decode"),
}

_REGISTRY: Dict[str, "ArchEntry"] = {}


@dataclasses.dataclass(frozen=True)
class ArchEntry:
    config: ArchConfig
    smoke: ArchConfig


def register(config: ArchConfig, smoke: ArchConfig):
    _REGISTRY[config.name] = ArchEntry(config, smoke)


def get(name: str) -> ArchConfig:
    _ensure_loaded()
    return _REGISTRY[name].config


def get_smoke(name: str) -> ArchConfig:
    _ensure_loaded()
    return _REGISTRY[name].smoke


def names():
    _ensure_loaded()
    return sorted(_REGISTRY)


def cells_for(name: str) -> Sequence[str]:
    """Which shape cells are lowered for this arch (DESIGN.md §5 skips)."""
    cfg = get(name)
    cells = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.supports_long_context:
        cells.append("long_500k")
    return cells


def _ensure_loaded():
    if _REGISTRY:
        return
    from repro.configs import (gemma2_2b, gemma2_27b, llama4_scout,  # noqa
                               minicpm3_4b, phi35_moe, phi3_vision,
                               qwen2_72b, whisper_base, xlstm_125m,
                               zamba2_2p7b)
