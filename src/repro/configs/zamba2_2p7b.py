"""zamba2-2.7b [arXiv:2411.15242; hf].

54L d_model=2560 Mamba2 backbone (ssm_state=64) with a SHARED attention
(+FFN) block applied every 6th layer (32H MHA, d_ff=10240). Hybrid ->
long_500k runs."""
from repro.configs.base import ArchConfig, BlockSpec, register

CONFIG = ArchConfig(
    name="zamba2-2.7b", family="hybrid",
    n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32, d_ff=10_240,
    vocab=32_000,
    group=(BlockSpec("mamba2"),) * 5 + (BlockSpec("attn", shared=True),),
    ssm_state=64, ssm_expand=2, ssm_chunk=64, ffn_kind="swiglu",
    supports_long_context=True,
)

SMOKE = ArchConfig(
    name="zamba2-smoke", family="hybrid",
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=4, d_ff=96,
    vocab=512,
    group=(BlockSpec("mamba2"),) * 1 + (BlockSpec("attn", shared=True),),
    ssm_state=16, ssm_expand=2, ssm_chunk=16, ffn_kind="swiglu",
)

register(CONFIG, SMOKE)
