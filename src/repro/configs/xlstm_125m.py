"""xlstm-125m [arXiv:2405.04517; unverified].

12L d_model=768 4H d_ff=0 (no separate FFN; blocks carry their own
projections) vocab=50304; alternating mLSTM / sLSTM blocks. Recurrent ->
long_500k runs."""
from repro.configs.base import ArchConfig, BlockSpec, register

CONFIG = ArchConfig(
    name="xlstm-125m", family="ssm",
    n_layers=12, d_model=768, n_heads=4, n_kv_heads=4, d_ff=0,
    vocab=50_304,
    group=(BlockSpec("mlstm"), BlockSpec("slstm")),
    ffn_kind="none",
    supports_long_context=True,
)

SMOKE = ArchConfig(
    name="xlstm-smoke", family="ssm",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=0,
    vocab=512,
    group=(BlockSpec("mlstm"), BlockSpec("slstm")),
    ffn_kind="none",
)

register(CONFIG, SMOKE)
