"""llama4-scout-17b-a16e [hf:meta-llama/Llama-4-Scout-17B-16E; unverified].

48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048, MoE 16e top-1 +
shared expert; iRoPE-style 3 chunked-local : 1 global layer pattern ->
sub-quadratic on 3/4 layers, long_500k runs."""
from repro.configs.base import ArchConfig, BlockSpec, register

CONFIG = ArchConfig(
    name="llama4-scout-17b-a16e", family="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, d_ff=8192,
    vocab=202_048, head_dim=128,
    group=(BlockSpec("attn", attn_scope="chunked"),
           BlockSpec("attn", attn_scope="chunked"),
           BlockSpec("attn", attn_scope="chunked"),
           BlockSpec("attn", attn_scope="global")),
    chunk_size=8192,
    n_experts=16, top_k=1, n_shared_experts=1, ffn_kind="swiglu",
    rope_theta=500_000.0,
    supports_long_context=True,
)

SMOKE = ArchConfig(
    name="llama4-scout-smoke", family="moe",
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, d_ff=96,
    vocab=512, head_dim=16,
    group=(BlockSpec("attn", attn_scope="chunked"),
           BlockSpec("attn", attn_scope="chunked"),
           BlockSpec("attn", attn_scope="chunked"),
           BlockSpec("attn", attn_scope="global")),
    chunk_size=16,
    n_experts=4, top_k=1, n_shared_experts=1, ffn_kind="swiglu",
)

register(CONFIG, SMOKE)
