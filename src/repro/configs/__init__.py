from repro.configs import base
from repro.configs.base import ArchConfig, ShapeCell, SHAPES, get, get_smoke, names, cells_for

__all__ = ["base", "ArchConfig", "ShapeCell", "SHAPES", "get", "get_smoke",
           "names", "cells_for"]
