"""gemma2-2b [arXiv:2408.00118; hf].

26L d_model=2304 8H (GQA kv=4) d_ff=9216 vocab=256000; alternating
local(4096)/global attention, attn softcap 50, final softcap 30, GeGLU,
tied embeddings. Local layers sub-quadratic -> long_500k runs (global-layer
KV sharded over data)."""
from repro.configs.base import ArchConfig, BlockSpec, register

CONFIG = ArchConfig(
    name="gemma2-2b", family="dense",
    n_layers=26, d_model=2304, n_heads=8, n_kv_heads=4, d_ff=9216,
    vocab=256_000, head_dim=256,
    group=(BlockSpec("attn", attn_scope="local"),
           BlockSpec("attn", attn_scope="global")),
    local_window=4096, attn_softcap=50.0, final_softcap=30.0,
    ffn_kind="geglu", tie_embeddings=True,
    supports_long_context=True,
)

SMOKE = ArchConfig(
    name="gemma2-2b-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=96,
    vocab=512, head_dim=16,
    group=(BlockSpec("attn", attn_scope="local"),
           BlockSpec("attn", attn_scope="global")),
    local_window=16, attn_softcap=50.0, final_softcap=30.0,
    ffn_kind="geglu", tie_embeddings=True,
)

register(CONFIG, SMOKE)
