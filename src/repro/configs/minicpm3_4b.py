"""minicpm3-4b [hf:openbmb/MiniCPM3-4B].

62L d_model=2560 40H d_ff=6400 vocab=73448; MLA attention (q_lora=768,
kv_lora=256, qk_nope=64, qk_rope=32, v_head=64). Full attention ->
long_500k skipped."""
from repro.configs.base import ArchConfig, BlockSpec, register

CONFIG = ArchConfig(
    name="minicpm3-4b", family="dense",
    n_layers=62, d_model=2560, n_heads=40, n_kv_heads=40, d_ff=6400,
    vocab=73_448,
    group=(BlockSpec("attn"),),
    attn_kind="mla", q_lora_rank=768, kv_lora_rank=256,
    qk_nope_dim=64, qk_rope_dim=32, v_head_dim=64,
    ffn_kind="swiglu",
    supports_long_context=False,
)

SMOKE = ArchConfig(
    name="minicpm3-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=96,
    vocab=512,
    group=(BlockSpec("attn"),),
    attn_kind="mla", q_lora_rank=32, kv_lora_rank=16,
    qk_nope_dim=8, qk_rope_dim=8, v_head_dim=8,
    ffn_kind="swiglu",
)

register(CONFIG, SMOKE)
