"""gemma2-27b [arXiv:2408.00118; hf].

46L d_model=4608 32H (GQA kv=16) d_ff=36864 vocab=256000; same alternating
local/global + softcap structure as gemma2-2b. long_500k runs."""
from repro.configs.base import ArchConfig, BlockSpec, register

CONFIG = ArchConfig(
    name="gemma2-27b", family="dense",
    n_layers=46, d_model=4608, n_heads=32, n_kv_heads=16, d_ff=36_864,
    vocab=256_000, head_dim=128,
    group=(BlockSpec("attn", attn_scope="local"),
           BlockSpec("attn", attn_scope="global")),
    local_window=4096, attn_softcap=50.0, final_softcap=30.0,
    ffn_kind="geglu", tie_embeddings=True,
    supports_long_context=True,
)

SMOKE = ArchConfig(
    name="gemma2-27b-smoke", family="dense",
    n_layers=2, d_model=96, n_heads=6, n_kv_heads=3, d_ff=128,
    vocab=512, head_dim=16,
    group=(BlockSpec("attn", attn_scope="local"),
           BlockSpec("attn", attn_scope="global")),
    local_window=16, attn_softcap=50.0, final_softcap=30.0,
    ffn_kind="geglu", tie_embeddings=True,
)

register(CONFIG, SMOKE)
