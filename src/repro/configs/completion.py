"""The paper's own completion workloads as configs (Fig. 7a/7b + dry-run).

``function_10b`` is the paper's flagship run: 10^10 observed entries at 1e-5
density (⇒ dims 10^5 each), rank 10, on 256 nodes. ``netflix`` is the real
dataset's shape with rank 100. Both are exercised full-size only through the
dry-run (ShapeDtypeStructs); benchmarks scale them down.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class CompletionConfig:
    name: str
    shape: Tuple[int, ...]
    nnz: int
    rank: int
    lam: float = 1e-5
    algorithm: str = "als"      # als | ccd | sgd | gcp
    loss: str = "quadratic"
    cg_tol: float = 1e-4
    cg_iters: int = 20
    sgd_lr: float = 3e-5
    sgd_sample: float = 3e-3    # sample rate (fraction of nnz)
    h_slices: int = 1           # TTTP H-slicing factor


FUNCTION_10B = CompletionConfig(
    name="function_10b",
    shape=(100_000, 100_000, 100_000),
    nnz=10_000_000_000,
    rank=10, lam=1e-5,
)

NETFLIX = CompletionConfig(
    name="netflix",
    shape=(480_189, 17_770, 2_182),
    nnz=100_477_727,
    rank=100, lam=1e-2,
    sgd_lr=3e-5, sgd_sample=3e-3,
)

COMPLETION_CONFIGS = {c.name: c for c in (FUNCTION_10B, NETFLIX)}
