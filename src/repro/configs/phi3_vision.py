"""phi-3-vision-4.2b [hf:microsoft/Phi-3-vision-128k-instruct].

phi3-mini backbone: 32L d_model=3072 32H d_ff=8192 vocab=32064; CLIP
frontend STUBBED: input_specs provides patch embeddings (B, 256, D) which
are prepended to the text stream. Full attention -> long_500k skipped."""
from repro.configs.base import ArchConfig, BlockSpec, register

CONFIG = ArchConfig(
    name="phi-3-vision-4.2b", family="vlm",
    n_layers=32, d_model=3072, n_heads=32, n_kv_heads=32, d_ff=8192,
    vocab=32_064, head_dim=96,
    group=(BlockSpec("attn"),),
    frontend="patch", num_patches=256, ffn_kind="swiglu",
    supports_long_context=False,
)

SMOKE = ArchConfig(
    name="phi3-vision-smoke", family="vlm",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=96,
    vocab=512, head_dim=16,
    group=(BlockSpec("attn"),),
    frontend="patch", num_patches=8, ffn_kind="swiglu",
)

register(CONFIG, SMOKE)
