"""whisper-base [arXiv:2212.04356; unverified].

6L enc + 6L dec, d_model=512 8H (MHA) d_ff=2048 vocab=51865; conv frontend
STUBBED: input_specs provides precomputed frame embeddings (B,S,D).
Enc-dec; 500k decode not meaningful for 30s windows -> long_500k skipped."""
from repro.configs.base import ArchConfig, BlockSpec, register

CONFIG = ArchConfig(
    name="whisper-base", family="audio",
    n_layers=6, d_model=512, n_heads=8, n_kv_heads=8, d_ff=2048,
    vocab=51_865,
    group=(BlockSpec("attn"),),
    encoder_layers=6, frontend="frames", ffn_kind="geglu",
    supports_long_context=False,
)

SMOKE = ArchConfig(
    name="whisper-smoke", family="audio",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=96,
    vocab=512,
    group=(BlockSpec("attn"),),
    encoder_layers=2, frontend="frames", ffn_kind="geglu",
)

register(CONFIG, SMOKE)
