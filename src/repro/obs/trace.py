"""Low-overhead, jit-aware tracing spans (DESIGN.md §11).

``span(name, **attrs)`` yields a live Span when (a) tracing is enabled and
(b) the call is NOT under a jax trace; otherwise it yields a shared no-op
span. The no-op path is safe inside ``jax.jit``-traced code: it touches no
tracers, performs no host sync, and `fence` returns its argument untouched
— so instrumented library code compiles identically with tracing on or
off. Live spans nest through a thread-local stack: each finished span
folds its record into its parent, and a finished ROOT span's full tree is
retained (``last_root``) for the experiment harness to attach to its
per-sweep metric history.

Timing discipline: a live span's duration is wall time between ``__enter__``
and ``__exit__``; for device work the caller must fence the result
(``sp.fence(out)``) so async dispatch doesn't end the span early. Every
finished span feeds the registry's timing histogram under its slash-joined
path and, when a JSONL sink is installed, emits one flat event line.
"""
from __future__ import annotations

import contextlib
import os
import threading
import time
from typing import Any, Dict, Iterator, Optional

from repro.obs.metrics import JsonlSink, MetricsRegistry, _jsonable

_REGISTRY = MetricsRegistry()
_SINK: Optional[JsonlSink] = None
_ENABLED = os.environ.get("REPRO_TRACE", "0") == "1"
_TLS = threading.local()


def get_registry() -> MetricsRegistry:
    return _REGISTRY


def enabled() -> bool:
    return _ENABLED


def enable(jsonl: Optional[str] = None) -> None:
    """Turn tracing on process-wide; ``jsonl`` installs an event sink."""
    global _ENABLED, _SINK
    if jsonl is not None:
        if _SINK is not None:
            _SINK.close()
        _SINK = JsonlSink(jsonl)
    _ENABLED = True


def disable() -> None:
    """Turn tracing off and close any installed sink."""
    global _ENABLED, _SINK
    _ENABLED = False
    if _SINK is not None:
        _SINK.close()
        _SINK = None


def sink() -> Optional[JsonlSink]:
    return _SINK


def emit_event(record: Dict[str, Any]) -> None:
    """Write one non-span event (counter snapshot, ingest stats, …) to the
    sink, if one is installed."""
    if _SINK is not None:
        _SINK.emit(record)


def trace_clean() -> bool:
    """True when NOT under a jax trace (jit/grad/vmap/shard_map tracing).
    Deferred jax import: obs must stay importable before jax initializes
    (the launch drivers set XLA flags first)."""
    try:
        import jax
        return jax.core.trace_state_clean()
    except Exception:
        return True


_trace_clean = trace_clean


def _stack() -> list:
    st = getattr(_TLS, "stack", None)
    if st is None:
        st = _TLS.stack = []
    return st


def last_root() -> Optional[Dict[str, Any]]:
    """The most recently FINISHED root span's nested record (this thread)."""
    return getattr(_TLS, "last_root", None)


class Span:
    """A live span. ``record`` holds the finished nested dict after exit."""

    __slots__ = ("name", "path", "attrs", "children", "record")
    live = True

    def __init__(self, name: str, path: str, attrs: Dict[str, Any]):
        self.name = name
        self.path = path
        self.attrs = {k: _jsonable(v) for k, v in attrs.items()}
        self.children: list = []
        self.record: Optional[Dict[str, Any]] = None

    def annotate(self, **kv) -> None:
        self.attrs.update({k: _jsonable(v) for k, v in kv.items()})

    def fence(self, x):
        """block_until_ready the pytree ``x`` so the span's duration covers
        the device work that produced it; returns ``x``."""
        import jax
        return jax.block_until_ready(x)


class _NoopSpan:
    """Shared no-op span: used when disabled or under a jax trace."""

    __slots__ = ()
    live = False
    record = None
    children: list = []

    def annotate(self, **kv) -> None:
        pass

    def fence(self, x):
        return x


_NOOP = _NoopSpan()


@contextlib.contextmanager
def span(name: str, **attrs) -> Iterator[Any]:
    """Context manager for one traced region (see module docstring)."""
    if not _ENABLED or not _trace_clean():
        yield _NOOP
        return
    st = _stack()
    path = (st[-1].path + "/" + name) if st else name
    sp = Span(name, path, attrs)
    st.append(sp)
    t0 = time.perf_counter()
    try:
        yield sp
    finally:
        dur = time.perf_counter() - t0
        st.pop()
        rec: Dict[str, Any] = {"kind": "span", "name": sp.name,
                               "path": sp.path, "dur_s": dur}
        if sp.attrs:
            rec["attrs"] = sp.attrs
        if sp.children:
            rec["children"] = sp.children
        sp.record = rec
        _REGISTRY.observe(sp.path, dur)
        if st:
            st[-1].children.append(rec)
        else:
            _TLS.last_root = rec
        if _SINK is not None:
            flat = dict(rec)
            flat.pop("children", None)
            flat["depth"] = len(st) + 1          # 1-based: roots at depth 1
            _SINK.emit(flat)


def counter_add(name: str, value: float = 1.0) -> None:
    """Registry counter bump; no-op while tracing is disabled."""
    if _ENABLED:
        _REGISTRY.counter_add(name, value)


def gauge_set(name: str, value: float) -> None:
    """Registry gauge set; no-op while tracing is disabled."""
    if _ENABLED:
        _REGISTRY.gauge_set(name, value)
