"""Observability layer: spans, metrics, and kernel roofline profiling
(DESIGN.md §11).

Disabled by default — every instrumentation point in the library routes
through :func:`span` / :func:`counter_add` / :func:`gauge_set`, which are
no-ops until :func:`enable` is called (or ``REPRO_TRACE=1`` is set) and
are always no-ops under a jax trace, so instrumented code jit-compiles
unchanged.

    from repro import obs
    obs.enable(jsonl="trace.jsonl")
    ...                                  # planner/kernel/ingest spans record
    print(obs.get_registry().summary())  # counters, timings, plan table
"""
from repro.obs.metrics import (JsonlSink, MetricsRegistry, PlanRecord,
                               Timing, read_jsonl)
from repro.obs.profile import Machine, hlo_terms, profile_jitted
from repro.obs import trace
from repro.obs.trace import (counter_add, disable, emit_event, enable,
                             enabled, gauge_set, get_registry, last_root,
                             sink, span, trace_clean)

__all__ = [
    "span", "enable", "disable", "enabled", "get_registry", "last_root",
    "sink", "emit_event", "counter_add", "gauge_set", "trace_clean",
    "MetricsRegistry", "Timing", "PlanRecord", "JsonlSink", "read_jsonl",
    "Machine", "hlo_terms", "profile_jitted",
]
