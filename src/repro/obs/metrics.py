"""Process-local metrics: counters, gauges, timing histograms, and the
planner's predicted-vs-measured accounting table, with a JSONL event sink.

The registry is plain-Python and lock-protected — cheap enough to update
from eager hot paths (a dict write per event) and entirely outside jax, so
nothing here can leak tracers. Aggregation (`summary()`) is pull-based:
callers snapshot whenever they want a report; `repro.launch.report --perf`
and the experiment harness are the two in-repo consumers (DESIGN.md §11).
"""
from __future__ import annotations

import dataclasses
import json
import math
import threading
from typing import Any, Dict, List, Optional

# per-timing reservoir: enough for stable p50/p95 on sweep-grade event
# rates without unbounded growth on long runs
_MAX_SAMPLES = 512


def _jsonable(v: Any) -> Any:
    """Coerce annotation values to JSON-able scalars (numpy scalars, jax
    weak types and the like become plain float/int/str)."""
    if isinstance(v, (bool, int, float, str)) or v is None:
        return v
    if isinstance(v, (list, tuple)):
        return [_jsonable(x) for x in v]
    if isinstance(v, dict):
        return {str(k): _jsonable(x) for k, x in v.items()}
    item = getattr(v, "item", None)     # numpy/jax 0-d arrays and scalars
    if callable(item):
        try:
            got = item()
            if isinstance(got, (bool, int, float, str)):
                return got
        except (TypeError, ValueError):
            pass
    for cast in (int, float):
        try:
            return cast(v)
        except (TypeError, ValueError):
            continue
    return str(v)


class Timing:
    """Streaming timing histogram: exact count/total/min/max plus a fixed
    reservoir of samples for quantiles (deterministic ring replacement)."""

    __slots__ = ("count", "total", "min", "max", "samples")

    def __init__(self):
        self.count = 0
        self.total = 0.0
        self.min = math.inf
        self.max = 0.0
        self.samples: List[float] = []

    def observe(self, seconds: float) -> None:
        self.count += 1
        self.total += seconds
        self.min = min(self.min, seconds)
        self.max = max(self.max, seconds)
        if len(self.samples) < _MAX_SAMPLES:
            self.samples.append(seconds)
        else:
            self.samples[self.count % _MAX_SAMPLES] = seconds

    def quantile(self, q: float) -> float:
        if not self.samples:
            return float("nan")
        s = sorted(self.samples)
        return s[min(int(q * len(s)), len(s) - 1)]

    def summary(self) -> Dict[str, float]:
        return {"count": self.count, "total_s": self.total,
                "mean_s": self.total / max(self.count, 1),
                "min_s": self.min if self.count else float("nan"),
                "max_s": self.max,
                "p50_s": self.quantile(0.50), "p95_s": self.quantile(0.95)}


@dataclasses.dataclass
class PlanRecord:
    """One (expression, path, distribution) cell of the predicted-vs-measured
    table: the §5.3 cost-model prediction frozen at first execution, with a
    timing histogram of every measured eager run of that plan."""
    kind: str
    path: str
    expr: str
    predicted: Dict[str, float]          # flops / mem / comm / seconds
    measured: Timing = dataclasses.field(default_factory=Timing)

    def summary(self) -> Dict[str, Any]:
        meas = self.measured.summary()
        pred_s = self.predicted.get("seconds", 0.0)
        # >1 ⇒ the cost model was optimistic by that factor; the constants
        # only matter up to ranking, so drift is expected — what the table
        # validates is that the RATIO is stable across paths of one family
        ratio = (meas["mean_s"] / pred_s) if pred_s > 0 else float("nan")
        return {"kind": self.kind, "path": self.path, "expr": self.expr,
                "predicted": dict(self.predicted), "measured": meas,
                "measured_over_predicted": ratio}


class MetricsRegistry:
    """Counters + gauges + named timing histograms + plan table."""

    def __init__(self):
        self._lock = threading.Lock()
        self.counters: Dict[str, float] = {}
        self.gauges: Dict[str, float] = {}
        self.timings: Dict[str, Timing] = {}
        self.plans: Dict[str, PlanRecord] = {}

    def counter_add(self, name: str, value: float = 1.0) -> None:
        with self._lock:
            self.counters[name] = self.counters.get(name, 0.0) + value

    def gauge_set(self, name: str, value: float) -> None:
        with self._lock:
            self.gauges[name] = float(value)

    def observe(self, name: str, seconds: float) -> None:
        with self._lock:
            t = self.timings.get(name)
            if t is None:
                t = self.timings[name] = Timing()
            t.observe(seconds)

    def record_plan(self, key: str, kind: str, path: str, expr: str,
                    predicted: Dict[str, float], seconds: float) -> None:
        """One measured eager execution of a planned contraction; the
        prediction is frozen on first sight of the key (it is a pure
        function of the static signature, so later calls agree)."""
        with self._lock:
            rec = self.plans.get(key)
            if rec is None:
                rec = self.plans[key] = PlanRecord(
                    kind, path, expr, {k: float(v)
                                       for k, v in predicted.items()})
            rec.measured.observe(seconds)

    def reset(self) -> None:
        with self._lock:
            self.counters.clear()
            self.gauges.clear()
            self.timings.clear()
            self.plans.clear()

    def summary(self) -> Dict[str, Any]:
        with self._lock:
            return {
                "counters": dict(self.counters),
                "gauges": dict(self.gauges),
                "timings": {k: t.summary() for k, t in self.timings.items()},
                "plans": {k: r.summary() for k, r in self.plans.items()},
            }


class JsonlSink:
    """Append-only JSONL event stream (one dict per line)."""

    def __init__(self, path: str):
        self.path = path
        self._lock = threading.Lock()
        self._f = open(path, "a")

    def emit(self, record: Dict[str, Any]) -> None:
        line = json.dumps(_jsonable(record), sort_keys=True)
        with self._lock:
            self._f.write(line + "\n")

    def flush(self) -> None:
        with self._lock:
            self._f.flush()

    def close(self) -> None:
        with self._lock:
            if not self._f.closed:
                self._f.flush()
                self._f.close()


def read_jsonl(path: str) -> List[Dict[str, Any]]:
    """Round-trip reader for JsonlSink files (skips blank lines)."""
    out = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                out.append(json.loads(line))
    return out
