"""Kernel-level roofline profiling — the wiring between the HLO term
extractor (``repro.launch.roofline``) and the live telemetry layer.

``profile_jitted(fn, *args)`` lowers + compiles one jitted callable,
parses the compiled HLO into flop / HBM-byte / collective-byte terms,
times the compiled executable with ``block_until_ready`` fencing, and
reports achieved-vs-peak fractions:

* ``frac_peak_compute``  — (HLO flops / measured s) / peak FLOP/s
* ``frac_peak_memory``   — (HLO bytes / measured s) / peak HBM B/s
* ``frac_roofline``      — roofline-implied best-case time / measured time
  (1.0 = running exactly at the machine-model bound; the per-kernel
  "achieved vs peak" number in PERF.md)

Machine constants default to the TPU-v5e numbers in ``launch/roofline``;
``REPRO_PEAK_FLOPS`` / ``REPRO_HBM_BW`` / ``REPRO_LINK_BW`` override them
so CPU-container runs can report against realistic host ceilings. The
fractions are only comparable within one machine model — the report
records the constants used.
"""
from __future__ import annotations

import dataclasses
import os
import time
from typing import Any, Callable, Dict, Optional

from repro.obs import trace as _trace


@dataclasses.dataclass(frozen=True)
class Machine:
    peak_flops: float
    hbm_bw: float
    link_bw: float

    @classmethod
    def from_env(cls) -> "Machine":
        from repro.launch import roofline as rl
        return cls(
            peak_flops=float(os.environ.get("REPRO_PEAK_FLOPS",
                                            rl.PEAK_FLOPS)),
            hbm_bw=float(os.environ.get("REPRO_HBM_BW", rl.HBM_BW)),
            link_bw=float(os.environ.get("REPRO_LINK_BW", rl.LINK_BW)))


def hlo_terms(compiled) -> Dict[str, float]:
    """Parse a compiled executable's HLO into roofline terms (per device)
    plus the XLA cost-analysis flop count for cross-checking: the parser's
    unweighted dot flops must match ``cost_analysis()['flops']`` up to the
    elementwise flops XLA additionally counts (tests/test_roofline.py)."""
    from repro.launch.roofline import HloModule
    t = HloModule(compiled.as_text()).totals()
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    t["cost_analysis_flops"] = float(ca.get("flops", 0.0)) if ca else 0.0
    return t


def _time_compiled(run: Callable[[], Any], iters: int) -> float:
    import jax
    jax.block_until_ready(run())                # warmup
    best = float("inf")
    for _ in range(max(iters, 1)):
        t0 = time.perf_counter()
        jax.block_until_ready(run())
        best = min(best, time.perf_counter() - t0)
    return best


def profile_jitted(fn: Callable, *args, name: str = "kernel",
                   iters: int = 5,
                   machine: Optional[Machine] = None) -> Dict[str, Any]:
    """Compile ``fn(*args)``, extract HLO roofline terms, measure best-of-N
    wall time, and return the achieved-vs-peak report dict. Also lands the
    measurement in the obs registry (gauge per fraction, timing under
    ``roofline/<name>``) and the JSONL sink when tracing is enabled."""
    import jax
    machine = machine or Machine.from_env()
    jfn = jax.jit(fn)
    compiled = jfn.lower(*args).compile()
    terms = hlo_terms(compiled)
    measured_s = _time_compiled(lambda: jfn(*args), iters)

    compute_s = terms["flops"] / machine.peak_flops
    memory_s = terms["bytes"] / machine.hbm_bw
    collective_s = terms["collective_bytes"] / machine.link_bw
    bound_s = max(compute_s, memory_s, collective_s)
    dominant = max(("compute", compute_s), ("memory", memory_s),
                   ("collective", collective_s), key=lambda kv: kv[1])[0]
    out = {
        "name": name,
        "measured_s": measured_s,
        "hlo_flops": terms["flops"],
        "hlo_bytes": terms["bytes"],
        "hlo_collective_bytes": terms["collective_bytes"],
        "cost_analysis_flops": terms["cost_analysis_flops"],
        "compute_s": compute_s, "memory_s": memory_s,
        "collective_s": collective_s, "dominant": dominant,
        "frac_peak_compute": (terms["flops"] / measured_s
                              / machine.peak_flops if measured_s else 0.0),
        "frac_peak_memory": (terms["bytes"] / measured_s
                             / machine.hbm_bw if measured_s else 0.0),
        "frac_roofline": bound_s / measured_s if measured_s else 0.0,
        "machine": dataclasses.asdict(machine),
    }
    if _trace.enabled():
        reg = _trace.get_registry()
        reg.observe(f"roofline/{name}", measured_s)
        reg.gauge_set(f"roofline/{name}/frac_roofline",
                      out["frac_roofline"])
        _trace.emit_event({"kind": "roofline", **out})
    return out
