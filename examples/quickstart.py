"""Quickstart: the paper's workflow end-to-end in ~40 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

import repro.core.api as ctf                       # Cyclops-style facade
from repro.core.completion import als_sweep
from repro.core.tttp import cp_residual_norm
from repro.data import synthetic

key = jax.random.PRNGKey(0)

# 1. a sparse observed tensor (Karlsson function-tensor model problem)
T = synthetic.function_tensor(key, (80, 70, 60), nnz=30_000)
Omega = T.with_values(jnp.ones_like(T.values))
print(f"tensor {T.shape}, nnz={T.nnz}, density={T.nnz/(80*70*60):.3%}")

# 2. the paper's kernels through the high-level API (Listings 2-3)
R = 8
U, V, W = (jax.random.normal(jax.random.fold_in(key, d), (s, R)) / R ** 0.5
           for d, s in enumerate(T.shape))
S = ctf.TTTP(T, [U, V, W])                          # sparse ⊙ CP model
y = ctf.einsum("ijk,jr,kr->ir", T, V, W)            # MTTKRP
print("TTTP nnz-values:", S.values[:3], "\nMTTKRP row0:", y[0, :4])

# 3. tensor completion by ALS with implicit batched CG (paper §2.2)
fs = [U, V, W]
sweep = jax.jit(lambda a, b, c: als_sweep(T, Omega, [a, b, c], 1e-6,
                                          cg_iters=R + 4))
for it in range(10):
    fs = sweep(*fs)
    err = float(cp_residual_norm(T, fs) / T.norm())
    print(f"sweep {it:2d}: relative residual {err:.5f}")
print("done — see examples/function_tensor_als.py for the full driver")
