"""Paper Fig. 7a (scaled): ALS vs CCD++ vs SGD on the function-tensor model
problem, with fault-tolerant checkpointing.

    PYTHONPATH=src python examples/function_tensor_als.py
"""
import subprocess, sys, os
root = os.path.join(os.path.dirname(__file__), "..")
for algo in ("als", "ccd_tttp", "sgd"):
    print(f"=== {algo} ===", flush=True)
    subprocess.run([sys.executable, "-m", "repro.launch.complete",
                    "--dataset", "function", "--algorithm", algo,
                    "--dims", "120,110,100", "--nnz", "120000",
                    "--rank", "10", "--sweeps", "6",
                    "--ckpt-dir", f"/tmp/repro_ex_{algo}"],
                   cwd=root, env={**os.environ, "PYTHONPATH": "src"},
                   check=True)
