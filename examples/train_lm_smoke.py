"""Train a reduced LM architecture end-to-end (any of the 10 assigned archs):

    PYTHONPATH=src python examples/train_lm_smoke.py [arch]
"""
import subprocess, sys, os
arch = sys.argv[1] if len(sys.argv) > 1 else "gemma2-2b"
root = os.path.join(os.path.dirname(__file__), "..")
subprocess.run([sys.executable, "-m", "repro.launch.train",
                "--arch", arch, "--smoke", "--steps", "30",
                "--batch", "4", "--seq", "64",
                "--ckpt-dir", "/tmp/repro_ex_train"],
               cwd=root, env={**os.environ, "PYTHONPATH": "src"}, check=True)
