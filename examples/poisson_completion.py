"""Generalized-loss completion (the assigned title's extension): fit a count
tensor under Poisson loss with Adam — same sparse kernels, new objective.

    PYTHONPATH=src python examples/poisson_completion.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.core import losses as L
from repro.core.completion import gcp_adam_init, gcp_step
from repro.core.completion.gcp import gcp_loss
from repro.data import synthetic

key = jax.random.PRNGKey(0)
base = synthetic.function_tensor(key, (60, 50, 40), nnz=20_000)
counts = base.with_values(jax.random.poisson(
    key, 5.0 * base.values).astype(jnp.float32))

R = 8
fs = [jnp.abs(jax.random.normal(jax.random.fold_in(key, d), (s, R))) * 0.3
      + 0.05 for d, s in enumerate(counts.shape)]
ad = gcp_adam_init(fs)
step = jax.jit(lambda s, f, a: gcp_step(s, list(f), L.poisson, 1e-7, 5e-3, a))
for it in range(120):
    fs, ad = step(counts, tuple(fs), ad)
    if it % 20 == 0:
        print(f"iter {it:3d} poisson loss "
              f"{float(gcp_loss(counts, list(fs), L.poisson, 1e-7)):.1f}")
print("final loss:", float(gcp_loss(counts, list(fs), L.poisson, 1e-7)))
