"""Perf regression gate: a fresh ``benchmarks.run --json`` output directory
vs the ``BENCH_*.json`` baselines committed at the repo root.

    PYTHONPATH=src python -m benchmarks.compare \
        --baseline . --current bench-json --tolerance 1.2

Exits nonzero when any benchmarked kernel in the current run is slower
than its committed baseline by more than the configured tolerance
(default 1.2 = a >20% slowdown fails the build). Policy details:

* entries are compared by (group, name) intersection — a renamed or newly
  added benchmark never fails the gate (it is reported as unmatched so the
  baseline can be refreshed deliberately);
* timings below ``--min-us`` are skipped: at tens of microseconds the
  dispatch jitter on shared CI runners swamps any real signal;
* ``*_qps``-suffixed entries are throughputs (higher is better) and
  ``*_p99`` entries are tail percentiles (max-statistics at CI sample
  counts, far noisier than medians) — both are recorded for the
  trajectory but never gated by the slower-than ratio;
* negative timings are sentinels (``-1`` = OOM-budget skip) and ignored;
* ``--normalize median`` divides every ratio by the median ratio across
  all compared entries before applying the tolerance. A uniformly slower
  machine (different CI runner class, thermal throttling) shifts ALL
  ratios equally and still passes; a single regressed kernel sticks out
  against the fleet. This is the recommended mode for cross-machine
  gating; the default (``none``) is a strict absolute ratio.
* ``--current`` accepts SEVERAL directories and gates on the per-entry
  minimum across them. Timing noise on shared runners is one-sided (other
  tenants only ever slow you down), so best-of-N runs is the standard
  variance killer — two or three ``benchmarks.run`` invocations tighten a
  ~1.5x single-run spread to a few percent. Committed baselines should be
  produced the same way (``--update`` min-merges too).
* ``--update`` rewrites the baseline files from the (min-merged) current
  run(s) instead of gating — the one-command way to advance the committed
  trajectory.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys
from typing import Dict, List, Tuple


def load_groups(dir_: str) -> Dict[str, Dict[str, float]]:
    """{group: {name: us_per_call}} from every BENCH_*.json in ``dir_``."""
    out: Dict[str, Dict[str, float]] = {}
    for path in sorted(glob.glob(os.path.join(dir_, "BENCH_*.json"))):
        group = os.path.basename(path)[len("BENCH_"):-len(".json")]
        try:
            with open(path) as f:
                out[group] = {str(k): float(v)
                              for k, v in json.load(f).items()}
        except (OSError, ValueError) as e:
            print(f"warning: unreadable {path}: {e}", file=sys.stderr)
    return out


def min_merge(dirs: List[str]) -> Dict[str, Dict[str, float]]:
    """Best-of-N across run directories: per-entry minimum (sentinels <= 0
    win only when every run agrees the entry was skipped)."""
    merged: Dict[str, Dict[str, float]] = {}
    for d in dirs:
        for group, entries in load_groups(d).items():
            g = merged.setdefault(group, {})
            for name, v in entries.items():
                old = g.get(name)
                if old is None or old <= 0 or (0 < v < old):
                    g[name] = v
    return merged


def compare(baseline: Dict[str, Dict[str, float]],
            current: Dict[str, Dict[str, float]],
            tolerance: float = 1.2, min_us: float = 50.0,
            normalize: str = "none") -> Tuple[List[dict], List[str]]:
    """Returns ``(rows, regressions)``: every compared entry with its ratio,
    and the formatted failures. Only groups present in BOTH sides gate."""
    rows: List[dict] = []
    for group in sorted(set(baseline) & set(current)):
        base, cur = baseline[group], current[group]
        for name in sorted(set(base) & set(cur)):
            b, c = base[name], cur[name]
            if b <= 0 or c <= 0:          # sentinel (-1 = skipped/OOM)
                continue
            # *_qps entries are throughput (higher is better) and *_p99
            # tail percentiles are max-statistics at CI sample counts —
            # both recorded for the trajectory, neither ratio-gated
            skip = (b < min_us and c < min_us) \
                or name.endswith(("_qps", "_p99"))
            rows.append({"group": group, "name": name, "baseline_us": b,
                         "current_us": c, "ratio": c / b, "skipped": skip})
    gated = [r for r in rows if not r["skipped"]]
    if normalize == "median" and gated:
        ratios = sorted(r["ratio"] for r in gated)
        med = ratios[len(ratios) // 2]
        for r in rows:
            r["median_ratio"] = med
            r["normalized_ratio"] = r["ratio"] / med if med > 0 else r["ratio"]
    regressions = []
    for r in rows:
        if r["skipped"]:
            continue
        eff = r.get("normalized_ratio", r["ratio"])
        if eff > tolerance:
            regressions.append(
                f"{r['group']}/{r['name']}: {r['baseline_us']:.1f}us -> "
                f"{r['current_us']:.1f}us (x{r['ratio']:.2f}"
                + (f", normalized x{eff:.2f}" if "normalized_ratio" in r
                   else "") + f" > {tolerance:.2f})")
    return rows, regressions


def report_unmatched(baseline, current) -> List[str]:
    notes = []
    for group in sorted(set(baseline) ^ set(current)):
        side = "baseline" if group in baseline else "current"
        notes.append(f"group {group!r} only in {side}")
    for group in sorted(set(baseline) & set(current)):
        for name in sorted(set(baseline[group]) ^ set(current[group])):
            side = "baseline" if name in baseline[group] else "current"
            notes.append(f"{group}/{name} only in {side}")
    return notes


def main() -> None:
    ap = argparse.ArgumentParser(
        description="gate a fresh benchmark run against committed baselines")
    ap.add_argument("--baseline", default=".", metavar="DIR",
                    help="directory holding the committed BENCH_*.json")
    ap.add_argument("--current", required=True, metavar="DIR", nargs="+",
                    help="director(ies) holding fresh BENCH_*.json runs; "
                         "several dirs gate on the per-entry best-of-N")
    ap.add_argument("--tolerance", type=float, default=1.2,
                    help="max allowed current/baseline ratio (1.2 = +20%%)")
    ap.add_argument("--min-us", type=float, default=50.0,
                    help="ignore entries where both sides are faster than "
                         "this (dispatch jitter floor)")
    ap.add_argument("--normalize", choices=["none", "median"], default="none",
                    help="'median' normalizes out a uniform machine-speed "
                         "shift before gating (cross-runner mode)")
    ap.add_argument("--update", action="store_true",
                    help="copy the current BENCH_*.json over the baselines "
                         "instead of gating")
    args = ap.parse_args()

    if args.update:
        merged = min_merge(args.current)
        for group, entries in sorted(merged.items()):
            path = os.path.join(args.baseline, f"BENCH_{group}.json")
            with open(path, "w") as f:
                json.dump(entries, f, indent=2, sort_keys=True)
        print(f"updated {len(merged)} baseline file(s) in {args.baseline}")
        return

    baseline = load_groups(args.baseline)
    current = min_merge(args.current)
    if not baseline:
        raise SystemExit(f"no BENCH_*.json baselines in {args.baseline!r}")
    if not current:
        raise SystemExit(f"no BENCH_*.json results in {args.current!r}")

    rows, regressions = compare(baseline, current, args.tolerance,
                                args.min_us, args.normalize)
    print(f"{'group':14s} {'name':44s} {'base_us':>10s} {'cur_us':>10s} "
          f"{'ratio':>7s}")
    for r in rows:
        eff = r.get("normalized_ratio", r["ratio"])
        flag = ("  [skip<min-us]" if r["skipped"] else
                "  <-- REGRESSION" if eff > args.tolerance else "")
        print(f"{r['group']:14s} {r['name']:44s} {r['baseline_us']:10.1f} "
              f"{r['current_us']:10.1f} {r['ratio']:7.2f}{flag}")
    for note in report_unmatched(baseline, current):
        print(f"note: {note}")
    if args.normalize == "median" and rows:
        print(f"median ratio (machine-speed normalizer): "
              f"{rows[0].get('median_ratio', 1.0):.2f}")
    if not rows:
        print("warning: no comparable entries between baseline and current")
    if regressions:
        print(f"\n{len(regressions)} regression(s) beyond tolerance "
              f"x{args.tolerance:.2f}:")
        for line in regressions:
            print("  " + line)
        sys.exit(1)
    print(f"\nOK: {sum(not r['skipped'] for r in rows)} entries within "
          f"tolerance x{args.tolerance:.2f}")


if __name__ == "__main__":
    main()
