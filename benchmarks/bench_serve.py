"""Serving-layer load generator (DESIGN.md §14).

Drives the three ``repro.serve.ServeEngine`` endpoints against a frozen
synthetic model at serving-ish scale and emits the latency trajectory:

* ``score_b{B}_p50`` / ``score_b{B}_p99`` — per-batch entry-scoring wall
  latency over a batch-size sweep (the load generator streams a fixed
  query budget through each batch size);
* ``score_b{B}_qps`` — achieved end-to-end throughput for the same sweep.
  QPS is higher-is-better, so these entries are informational only:
  ``benchmarks.compare`` skips ``*_qps`` names when gating;
* ``topk_*`` — blocked streaming top-k retrieval per batch of queries;
* ``foldin_*`` — batched cold-user fold-in (one-row damped ALS) per batch.

The model is synthesized (seeded) rather than fitted — the serving layer
never looks at how factors were produced, and a deterministic model keeps
the benchmark self-contained. Correctness parity vs the training kernels
is covered by tests/test_serve.py and the serve-smoke CI job, not here.
"""
from __future__ import annotations

import os
import time

import jax
import numpy as np

from benchmarks.common import emit, time_fn
from repro.serve import ServeEngine, ServingModel, percentiles


def _model(shape, rank: int, seed: int = 0) -> ServingModel:
    import jax.numpy as jnp
    rng = np.random.default_rng(seed)
    factors = [jnp.asarray(rng.standard_normal((s, rank)).astype(np.float32)
                           / np.sqrt(rank)) for s in shape]
    return ServingModel(factors, link="identity",
                        meta={"kind": "bench_synthetic"})


def _score_sweep(engine: ServeEngine, shape, batch_sizes, num_queries: int,
                 seed: int):
    rng = np.random.default_rng(seed)
    queries = np.stack([rng.integers(0, s, size=num_queries) for s in shape],
                       axis=1).astype(np.int32)
    jax.block_until_ready(engine.model.factors)
    for bs in batch_sizes:
        engine.score(queries[:bs])                 # compile outside the clock
        if num_queries % bs:                       # ...and the tail's bucket
            engine.score(queries[:num_queries % bs])
        lat = []
        t_all = time.perf_counter()
        for lo in range(0, num_queries, bs):
            t0 = time.perf_counter()
            engine.score(queries[lo:lo + bs])
            lat.append(time.perf_counter() - t0)
        wall = time.perf_counter() - t_all
        stats = percentiles(lat)
        qps = num_queries / wall
        emit(f"score_b{bs}_p50", stats["p50_us"],
             f"batches={stats['calls']} p95={stats['p95_us']:.0f}us")
        emit(f"score_b{bs}_p99", stats["p99_us"],
             f"max={stats['max_us']:.0f}us")
        emit(f"score_b{bs}_qps", qps,
             "informational (higher is better; not perf-gated)")


def run(quick: bool = False):
    shape = (4_000, 2_000, 100) if quick else (40_000, 20_000, 200)
    rank = 16
    num_queries = 20_000 if quick else 100_000
    model = _model(shape, rank)
    engine = ServeEngine(model, max_batch=4096)

    _score_sweep(engine, shape, (256, 1024, 4096), num_queries, seed=1)

    # top-k retrieval over the largest mode ("items" = mode 0)
    rng = np.random.default_rng(2)
    b_users, k = 64, 10
    fixed = {d: rng.integers(0, shape[d], size=b_users)
             for d in range(1, len(shape))}
    us = time_fn(lambda: engine.top_k(fixed, 0, k),
                 warmup=2, iters=3 if quick else 7)
    emit(f"topk_k{k}_b{b_users}", us,
         f"mode0={shape[0]} rows, block={engine.topk_block}")

    # cold-user fold-in: B users x nnz-entry histories through batched CG
    b_cold, nnz = 64, 32
    others = [d for d in range(len(shape)) if d != 0]
    hists = []
    for _ in range(b_cold):
        oidx = np.stack([rng.integers(0, shape[d], size=nnz)
                         for d in others], axis=1).astype(np.int32)
        hists.append((oidx, rng.standard_normal(nnz).astype(np.float32)))
    us = time_fn(lambda: engine.fold_in(hists, 0),
                 warmup=2, iters=3 if quick else 7)
    emit(f"foldin_b{b_cold}_nnz{nnz}", us,
         f"{us / b_cold:.0f}us/user, rank={rank}")


if __name__ == "__main__":
    run(quick=os.environ.get("QUICK", "0") == "1")
