"""Paper Fig. 7: tensor completion — ALS (implicit CG) vs CCD++ vs SGD on
(a) the Karlsson function-tensor model problem and (b) a Netflix-shaped
tensor, laptop scale. Derived = final RMSE after the sweep budget; the
paper's qualitative claims to reproduce: ALS reaches the lowest RMSE in the
fewest sweeps; CCD++/SGD are cheaper per sweep but converge slower."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.core.completion import als_sweep, ccd_sweep_tttp, sgd_sweep
from repro.core.completion.ccd import residual_values
from repro.core.sparse_tensor import SparseTensor
from repro.core.tttp import multilinear_values
from repro.data import synthetic


def _rmse(st, fs):
    model = multilinear_values(st, fs)
    d = (st.values - model) * st.mask
    return float(jnp.sqrt(jnp.sum(d ** 2) / jnp.sum(st.mask)))


def _bench_dataset(tag, st, rank, lam, sweeps, quick, sgd_lr=1e-3):
    key = jax.random.PRNGKey(9)
    ks = jax.random.split(key, st.ndim)
    init = [jax.random.normal(k, (d, rank)) / rank ** 0.5
            for k, d in zip(ks, st.shape)]
    omega = st.with_values(jnp.ones_like(st.values))

    als = jax.jit(lambda s, o, fs: tuple(als_sweep(s, o, list(fs), lam,
                                                   cg_iters=rank + 4)))
    fs = tuple(init)
    us = time_fn(lambda: als(st, omega, fs), warmup=1, iters=3)
    for _ in range(sweeps):
        fs = als(st, omega, fs)
    emit(f"fig7_{tag}_als_sweep", us, f"rmse={_rmse(st, list(fs)):.5f}")

    ccd = jax.jit(lambda s, fs, rho: ccd_sweep_tttp(s, list(fs), rho, lam))
    fs2, rho = tuple(init), residual_values(st, init)
    us = time_fn(lambda: ccd(st, fs2, rho), warmup=1, iters=3)
    for _ in range(sweeps):
        out = ccd(st, fs2, rho)
        fs2, rho = tuple(out[0]), out[1]
    emit(f"fig7_{tag}_ccd_sweep", us, f"rmse={_rmse(st, list(fs2)):.5f}")

    sample = max(1024, st.nnz // 10)
    sgd = jax.jit(lambda k, s, fs: tuple(sgd_sweep(k, s, list(fs), lam,
                                                   lr=sgd_lr,
                                                   sample_size=sample)))
    fs3 = tuple(init)
    us = time_fn(lambda: sgd(key, st, fs3), warmup=1, iters=3)
    for i in range(sweeps * 3):
        fs3 = sgd(jax.random.fold_in(key, i), st, fs3)
    emit(f"fig7_{tag}_sgd_sweep", us, f"rmse={_rmse(st, list(fs3)):.5f}")


def run(quick: bool = False):
    key = jax.random.PRNGKey(4)
    nnz = 20_000 if quick else 120_000
    sweeps = 4 if quick else 10
    st = synthetic.function_tensor(key, (120, 110, 100), nnz)
    _bench_dataset("function", st, rank=10, lam=1e-5, sweeps=sweeps,
                   quick=quick)
    stn = synthetic.netflix_like(key, (2000, 800, 50), nnz=nnz)
    # the paper uses lr=3e-5 for Netflix (SGD diverges at higher rates, §5.5)
    _bench_dataset("netflix", stn, rank=16 if quick else 32, lam=1e-2,
                   sweeps=sweeps, quick=quick, sgd_lr=3e-5)
