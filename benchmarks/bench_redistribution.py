"""Paper Fig. 4: transpose/reshape throughput for sparse and dense tensors.

Derived column = achieved bandwidth in MB/s (paper's metric: bytes needed to
store the tensor / execution time; 16 B per sparse nonzero, 8 B per dense
value)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.core.sparse_tensor import SparseTensor
from repro.sparse.redistribute import reshape_distributed, transpose_distributed


def run(quick: bool = False):
    key = jax.random.PRNGKey(0)
    nnz = 50_000 if quick else 400_000
    shape3 = (512, 512, 512)
    st = SparseTensor.random(key, shape3, nnz)
    sp_bytes = 16 * nnz

    f_t = jax.jit(lambda s: transpose_distributed(s, (2, 0, 1)).values)
    us = time_fn(f_t, st)
    emit("fig4_sparse_transpose_o3", us, f"{sp_bytes / us:.1f}MBps")

    f_r = jax.jit(lambda s: reshape_distributed(
        s, (512 * 512, 512)).values)
    us = time_fn(f_r, st)
    emit("fig4_sparse_reshape_o3", us, f"{sp_bytes / us:.1f}MBps")

    st4 = SparseTensor.random(key, (128, 128, 128, 128), nnz)
    f_t4 = jax.jit(lambda s: transpose_distributed(s, (3, 1, 0, 2)).values)
    us = time_fn(f_t4, st4)
    emit("fig4_sparse_transpose_o4", us, f"{sp_bytes / us:.1f}MBps")

    n = 128 if quick else 224
    dense = jax.random.normal(key, (n, n, n))
    d_bytes = 8 * n ** 3
    f_dt = jax.jit(lambda x: jnp.transpose(x, (2, 0, 1)))
    us = time_fn(f_dt, dense)
    emit("fig4_dense_transpose_o3", us, f"{d_bytes / us:.1f}MBps")

    f_dr = jax.jit(lambda x: x.reshape(n * n, n) + 0.0)
    us = time_fn(f_dr, dense)
    emit("fig4_dense_reshape_o3", us, f"{d_bytes / us:.1f}MBps")
