"""Paper Fig. 5b: MTTKRP — all-at-once vs the two pairwise contraction
orders, across density (fixed nnz), averaged over the three output modes."""
from __future__ import annotations

import jax

from benchmarks.common import emit, time_fn
from repro.core.sparse_tensor import SparseTensor
from repro.sparse import ops as sops

MEM_BUDGET = 2 ** 28


def run(quick: bool = False):
    key = jax.random.PRNGKey(2)
    nnz = 20_000 if quick else 100_000
    r = 32
    densities = [1e-2, 1e-4] if quick else [1e-2, 1e-3, 1e-4, 1e-5]
    for dens in densities:
        dim = max(8, int(round((nnz / dens) ** (1 / 3))))
        st = SparseTensor.random(key, (dim,) * 3, nnz)
        ks = jax.random.split(key, 3)
        factors = [jax.random.normal(k, (dim, r)) for k in ks]

        def avg(fn):
            tot = 0.0
            for mode in range(3):
                fac = list(factors)
                fac[mode] = None
                f = jax.jit(lambda s, a, b, c, m=mode: fn(
                    s, [x if i != m else None
                        for i, x in enumerate([a, b, c])], m))
                tot += time_fn(f, st, *factors)
            return tot / 3

        emit(f"fig5b_mttkrp_allatonce_d{dens:g}", avg(sops.mttkrp),
             f"dim={dim}")
        emit(f"fig5b_mttkrp_pairwise_Tfirst_d{dens:g}",
             avg(sops.mttkrp_pairwise_t_first), f"dim={dim}")
        if 4 * dim * dim * r <= MEM_BUDGET:
            emit(f"fig5b_mttkrp_pairwise_KRfirst_d{dens:g}",
                 avg(sops.mttkrp_pairwise_kr_first), f"dim={dim}")
        else:
            emit(f"fig5b_mttkrp_pairwise_KRfirst_d{dens:g}", -1, "OOM-budget")
