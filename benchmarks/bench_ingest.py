"""Streaming-ingest throughput + peak-host-memory benchmark (DESIGN.md §10).

The paper-scale claim under test: a synthetic ingest of N nonzeros completes
with peak host memory bounded by the CHUNK size, not by N. Full mode runs
the 50M-nnz configuration of the acceptance criterion; ``--quick`` scales
nnz down for CI smoke.

Modes measured (per-chunk RSS sampling via psutil, delta over the
pre-ingest baseline):

* ``stats``   — metadata-only ingest (``keep_entries=False``): exact
  nnz_rows / bucket-occupancy planner hints, strictly O(chunk) resident;
* ``spool``   — out-of-core ingest with per-shard spill runs on disk
  (streaming phase O(chunk); shard merge deferred);
* ``full``    — in-memory ingest + shard merge + packed SparseTensor
  (the small-tensor path; peak O(nnz) by design, shown for contrast).

The emitted ``derived`` column carries Mentries/s, the peak-RSS delta and
the chunk budget so BENCH_ingest.json tracks the perf trajectory.
"""
from __future__ import annotations

import gc
import os
import shutil
import tempfile
import time

import numpy as np

from benchmarks.common import emit
from repro.data import streaming

# generous sandbox: per-chunk work set is several transient copies of the
# (idx, vals, lin, hash) arrays during dedup/sort, plus generator output
CHUNK_BYTES_PER_ENTRY = 16           # int32[3] indices + float32 value
PEAK_BUDGET_CHUNKS = 12.0            # peak must stay under this many chunks


def _ingest_once(shape, nnz, chunk, num_shards, mode, spool_root):
    import psutil                    # deferred: keep run.py importable
    proc = psutil.Process()
    gc.collect()
    base = proc.memory_info().rss
    peak = [0]

    def sample(_stats):
        peak[0] = max(peak[0], proc.memory_info().rss - base)

    spool = None
    if mode == "spool":
        spool = tempfile.mkdtemp(dir=spool_root, prefix="ingest_spool_")
    ing = streaming.StreamingIngest(
        shape, num_shards, spool_dir=spool, block_rows=64,
        keep_entries=(mode != "stats"))
    # repro-lint: disable=JS003 -- host-side ingest throughput benchmark; device untouched
    t0 = time.perf_counter()
    ing.consume(streaming.function_stream(11, shape, nnz, chunk),
                progress=sample)
    if mode == "full":
        shards, stats = ing.finalize()
        st = streaming.pack_shards(shards, shape, stats)
        assert st.nnz == stats.nnz
    else:
        stats = ing.finalize_stats()
    sample(stats)
    # repro-lint: disable=JS003 -- host-side ingest throughput benchmark; device untouched
    seconds = time.perf_counter() - t0
    if spool is not None:
        shutil.rmtree(spool, ignore_errors=True)
    assert stats.nnz and stats.nnz > 0.9 * nnz     # dups are rare at 1e-5ish
    return seconds, peak[0]


def run(quick: bool = False):
    shape = (30_000, 20_000, 2_000)
    chunk = 500_000 if quick else 2_000_000
    spool_root = tempfile.mkdtemp(prefix="bench_ingest_")
    cases = [
        ("stats", 2_000_000 if quick else 50_000_000),
        ("spool", 1_000_000 if quick else 50_000_000),
        ("full", 300_000 if quick else 4_000_000),
    ]
    try:
        for mode, nnz in cases:
            seconds, peak = _ingest_once(shape, nnz, min(chunk, nnz),
                                         num_shards=8, mode=mode,
                                         spool_root=spool_root)
            chunk_mb = min(chunk, nnz) * CHUNK_BYTES_PER_ENTRY / 2 ** 20
            peak_mb = peak / 2 ** 20
            bounded = peak_mb <= PEAK_BUDGET_CHUNKS * chunk_mb
            emit(f"ingest_{mode}_{nnz // 1_000_000}M", seconds * 1e6,
                 f"{nnz / seconds / 1e6:.2f}Mnnz/s peak={peak_mb:.0f}MB "
                 f"chunk={chunk_mb:.0f}MB "
                 f"chunk_bounded={'yes' if bounded else 'NO'}")
            if mode in ("stats", "spool") and not bounded:
                raise AssertionError(
                    f"ingest mode {mode!r}: peak RSS {peak_mb:.0f}MB exceeds "
                    f"{PEAK_BUDGET_CHUNKS:.0f}x chunk ({chunk_mb:.0f}MB) — "
                    f"the O(chunk) memory bound regressed")
    finally:
        shutil.rmtree(spool_root, ignore_errors=True)


if __name__ == "__main__":
    run(quick=os.environ.get("QUICK", "0") == "1")
