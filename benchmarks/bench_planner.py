"""Planner dispatch overhead + cost-model quality on the MTTKRP and TTTP
shapes of bench_mttkrp / bench_tttp: planned (cost-model-chosen) einsum vs
the hard-coded kernel calls, plus every forced path so the CSV shows whether
the model picked the measured winner (DESIGN.md §5)."""
from __future__ import annotations

import time

import jax

from benchmarks.common import emit, time_fn
from repro import planner
from repro.core import api as ctf
from repro.core.sparse_tensor import SparseTensor
from repro.sparse import ops as sops

MEM_BUDGET = 2 ** 28


def _mttkrp(quick: bool) -> None:
    key = jax.random.PRNGKey(2)
    nnz = 20_000 if quick else 100_000
    r = 32
    densities = [1e-2, 1e-4] if quick else [1e-2, 1e-3, 1e-4, 1e-5]
    for dens in densities:
        dim = max(8, int(round((nnz / dens) ** (1 / 3))))
        st = SparseTensor.random(key, (dim,) * 3, nnz)
        ks = jax.random.split(key, 2)
        v, w = [jax.random.normal(k, (dim, r)) for k in ks]

        plan = ctf.plan("ijk,jr,kr->ir", st, v, w)
        f_hard = jax.jit(lambda s, a, b: sops.mttkrp(s, [None, a, b], 0))
        us_hard = time_fn(f_hard, st, v, w)
        emit(f"planner_mttkrp_hardcoded_d{dens:g}", us_hard, "sops.mttkrp")

        f_plan = jax.jit(lambda s, a, b:
                         ctf.einsum("ijk,jr,kr->ir", s, a, b))
        us_plan = time_fn(f_plan, st, v, w)
        emit(f"planner_mttkrp_planned_d{dens:g}", us_plan,
             f"chose={plan.path};overhead={us_plan / max(us_hard, 1):.2f}x")

        for path in plan.candidates:
            if path == "kr_first" and 4 * dim * dim * r > MEM_BUDGET:
                emit(f"planner_mttkrp_path_{path}_d{dens:g}", -1, "OOM-budget")
                continue
            if path == "dense" and 4 * dim ** 3 > MEM_BUDGET:
                emit(f"planner_mttkrp_path_{path}_d{dens:g}", -1, "OOM-budget")
                continue
            note = f"est={plan.cost(path).seconds * 1e6:.1f}us"
            if path == "bucketed":
                # under jit the bucketed path silently falls back to
                # all_at_once (the cached pattern does not cross the tracer
                # boundary), so time it eagerly: the first call builds the
                # ingest-time pattern, every timed call re-gathers values
                # through the cache — no per-call host bucketize
                # repro-lint: disable=JS003 -- one-time host-side bucket pattern build; no device work timed
                t0 = time.perf_counter()
                st.row_buckets(0, planner.default_config().block_rows)
                emit(f"planner_mttkrp_bucketize_ingest_d{dens:g}",
                     # repro-lint: disable=JS003 -- one-time host-side bucket pattern build; no device work timed
                     (time.perf_counter() - t0) * 1e6,
                     "one-time pattern build, amortized across sweeps")
                f = lambda s, a, b: ctf.einsum("ijk,jr,kr->ir", s, a, b,
                                               path="bucketed")
                note += ";eager-cached-buckets"
            else:
                f = jax.jit(lambda s, a, b, p=path:
                            ctf.einsum("ijk,jr,kr->ir", s, a, b, path=p))
            emit(f"planner_mttkrp_path_{path}_d{dens:g}", time_fn(f, st, v, w),
                 note)


def _tttp(quick: bool) -> None:
    key = jax.random.PRNGKey(3)
    nnz = 20_000 if quick else 100_000
    r = 32
    densities = [1e-2, 1e-4] if quick else [1e-2, 1e-4]
    for dens in densities:
        dim = max(8, int(round((nnz / dens) ** (1 / 3))))
        st = SparseTensor.random(key, (dim,) * 3, nnz)
        ks = jax.random.split(key, 3)
        u, v, w = [jax.random.normal(k, (dim, r)) for k in ks]

        plan = ctf.plan("ijk,ir,jr,kr->ijk", st, u, v, w)
        f_hard = jax.jit(lambda s, a, b, c:
                         ctf.TTTP(s, [a, b, c], path="all_at_once").values)
        us_hard = time_fn(f_hard, st, u, v, w)
        emit(f"planner_tttp_hardcoded_d{dens:g}", us_hard, "kernels.ops.tttp")

        f_plan = jax.jit(lambda s, a, b, c:
                         ctf.einsum("ijk,ir,jr,kr->ijk", s, a, b, c).values)
        us_plan = time_fn(f_plan, st, u, v, w)
        emit(f"planner_tttp_planned_d{dens:g}", us_plan,
             f"chose={plan.path};overhead={us_plan / max(us_hard, 1):.2f}x")

        for path in plan.candidates:
            if path == "dense" and 4 * dim ** 3 > MEM_BUDGET:
                emit(f"planner_tttp_path_{path}_d{dens:g}", -1, "OOM-budget")
                continue
            f = jax.jit(lambda s, a, b, c, p=path:
                        ctf.einsum("ijk,ir,jr,kr->ijk", s, a, b, c,
                                   path=p).values)
            emit(f"planner_tttp_path_{path}_d{dens:g}",
                 time_fn(f, st, u, v, w),
                 f"est={plan.cost(path).seconds * 1e6:.1f}us")


def run(quick: bool = False):
    planner.clear_plan_cache()
    _mttkrp(quick)
    _tttp(quick)
    emit("planner_cache_entries", float(planner.plan_cache_size()),
         "plans built once per static signature")
