"""Paper §5.5: CCD++ einsum-contraction vs TTTP-based implementation.

The paper reports the TTTP-based variant 1.40× (function tensor) / 1.84×
(Netflix) faster per iteration; derived = measured speedup."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.core.completion import ccd_sweep, ccd_sweep_tttp
from repro.core.completion.ccd import residual_values
from repro.data import synthetic


def run(quick: bool = False):
    key = jax.random.PRNGKey(5)
    nnz = 20_000 if quick else 100_000
    rank = 8 if quick else 16
    for tag, st in (
        ("function", synthetic.function_tensor(key, (100, 90, 80), nnz)),
        ("netflix", synthetic.netflix_like(key, (2000, 800, 50), nnz=nnz)),
    ):
        ks = jax.random.split(key, 3)
        fs = [jax.random.normal(k, (d, rank)) / rank ** 0.5
              for k, d in zip(ks, st.shape)]
        rho = residual_values(st, fs)
        f1 = jax.jit(lambda s, f, r: ccd_sweep(s, list(f), r, 1e-4))
        f2 = jax.jit(lambda s, f, r: ccd_sweep_tttp(s, list(f), r, 1e-4))
        us1 = time_fn(f1, st, tuple(fs), rho, warmup=1, iters=3)
        us2 = time_fn(f2, st, tuple(fs), rho, warmup=1, iters=3)
        emit(f"ccd_einsum_{tag}", us1, "")
        emit(f"ccd_tttp_{tag}", us2,
             f"speedup={us1 / max(us2, 1):.2f}x(paper:1.40/1.84)")
