"""Generalized Gauss-Newton completion: per-iteration cost and convergence
vs ALS on the function tensor, plus the planner paths of the weighted Gram
matvec (fused cg_matvec_bucketed vs TTTP+MTTKRP vs H-sliced). Entries land
in the ``completion`` JSON group (BENCH_completion.json) next to als/ccd."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro import planner
from repro.core import losses as L
from repro.core.completion import ggn_init, ggn_sweep
from repro.core.tttp import multilinear_values
from repro.data import synthetic


def _rmse(st, fs):
    model = multilinear_values(st, fs)
    d = (st.values - model) * st.mask
    return float(jnp.sqrt(jnp.sum(d ** 2) / jnp.sum(st.mask)))


def run(quick: bool = False):
    key = jax.random.PRNGKey(11)
    nnz = 10_000 if quick else 60_000
    shape = (60, 55, 50) if quick else (120, 110, 100)
    rank = 6 if quick else 10
    iters = 2 if quick else 5
    lam = 1e-5
    st = synthetic.function_tensor(key, shape, nnz)
    ks = jax.random.split(key, st.ndim)
    init = [jax.random.normal(k, (d, rank)) / rank ** 0.5
            for k, d in zip(ks, shape)]

    # GGN iteration cost + convergence (quadratic)
    step = jax.jit(lambda s, stt: ggn_sweep(s, stt, L.quadratic, lam,
                                            cg_iters=rank + 10))
    state = ggn_init(init)
    us = time_fn(lambda: step(st, state), warmup=1, iters=3)
    for _ in range(iters):
        state = step(st, state)
    emit("ggn_function_quadratic_iter", us,
         f"rmse={_rmse(st, list(state.factors)):.5f}")

    # generalized loss (second-order GCP counterpart)
    stp = st.with_values(jnp.round(jnp.abs(st.values) * 4))
    stepp = jax.jit(lambda s, stt: ggn_sweep(s, stt, L.poisson_log, lam,
                                             cg_iters=rank + 10,
                                             joint_iters=8,
                                             precond_iters=4))
    statep = ggn_init([0.3 * f for f in init], damping=1e-3)
    us = time_fn(lambda: stepp(stp, statep), warmup=1, iters=3)
    emit("ggn_function_poisson_log_iter", us)

    # weighted Gram matvec: planner path shoot-out (eager — the fused path
    # consumes the ingest-time cached bucket pattern; the first call builds
    # it, every timed call re-gathers values through the cache)
    w_st = st.with_values(jnp.full((st.cap,), 2.0) * st.mask)
    w_st.row_buckets(0, planner.default_config().block_rows)   # "ingest"
    x = init[0]
    for path in ("tttp_mttkrp", "fused", "sliced"):
        fn = lambda: planner.planned_cg_matvec(w_st, init, 0, x, path=path)
        us = time_fn(fn, warmup=1, iters=3)
        emit(f"ggn_gram_matvec_{path}", us)


if __name__ == "__main__":
    run()
