"""Kernel-tile tier (DESIGN.md §13): measured tile sweep on the netflix-ci
study shape. For each kernel family, times every lattice candidate of the
planner's autotuner eagerly (Pallas interpret mode on CPU) and emits the
default-tile config next to the measured winner — the acceptance bound is
``tuned <= default`` on every shape, which holds by construction because
the default tile is a lattice member."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.core.sparse_tensor import SparseTensor
from repro.kernels import vmem as kvmem
from repro.planner import tuner

SHAPE, NNZ, RANK = (80, 60, 20), 15_000, 6   # netflix-ci study shape


def run(quick: bool = False):
    key = jax.random.PRNGKey(5)
    st = SparseTensor.random(key, SHAPE, NNZ)
    ks = jax.random.split(key, len(SHAPE))
    factors = [jax.random.normal(k, (d, RANK)) for k, d in zip(ks, SHAPE)]
    omega = st.with_values(jnp.ones_like(st.values))
    x = factors[0]
    iters = 3 if quick else 5
    for family, lattice in tuner.LATTICES.items():
        # quick mode still includes the default (index 0) so the
        # default-vs-tuned pair stays comparable
        cands = lattice[:2] if quick else lattice
        # the same VMEM pre-check the tuner applies: an over-budget
        # candidate is never timed, and the pruned count rides the record
        src = omega if family == "cg_matvec" else st
        cands, pruned = kvmem.prune_lattice(
            family, cands,
            lambda t: kvmem.workload_geometry(family, src, factors, t, x=x))
        if pruned:
            print(f"sec5_kernel_tiles_{family}: vmem_pruned="
                  f"{[t.short() for t, _ in pruned]}")
        default_us, best_us, best_tile = None, float("inf"), None
        for tile in cands:
            fn = tuner._family_runner(family, tile, st, omega, factors, x)
            us = time_fn(fn, warmup=1, iters=iters)
            if tile == lattice[0]:
                default_us = us
            if us < best_us:
                best_us, best_tile = us, tile
        emit(f"sec5_kernel_tiles_{family}_default", default_us,
             f"tile={lattice[0].short()} vmem_pruned={len(pruned)}")
        emit(f"sec5_kernel_tiles_{family}_tuned", best_us,
             f"tile={best_tile.short()} vmem_pruned={len(pruned)}")
