"""Distributed completion benchmark: LOCAL vs mesh sweeps on forced host
devices (DESIGN.md §9).

The forced-device XLA flag must be set before jax initializes, so the
measurements run in a SUBPROCESS (one jax init with 8 host devices); the
parent parses its ``name us`` lines into benchmark records. On a CPU
container the mesh numbers measure collective overhead, not speedup — the
point of the record is the trajectory of the distributed path itself.
"""
from __future__ import annotations

import os
import subprocess
import sys
import textwrap

from benchmarks.common import emit

_SCRIPT = textwrap.dedent("""
    import os, sys, time
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    sys.path.insert(0, "src")
    import jax, jax.numpy as jnp
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.core.completion import als_sweep
    from repro.core.distributed import AxisCtx, DistLayout, LOCAL
    from repro.data.pipeline import CompletionDataset
    from repro.data import synthetic

    quick = bool(int(sys.argv[1]))
    dims = (48, 40, 32) if quick else (96, 80, 64)
    nnz = 8000 if quick else 40000
    r = 8
    sweeps = 3

    key = jax.random.PRNGKey(0)
    raw = synthetic.function_tensor(key, dims, nnz)
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    layout = DistLayout(mesh, ("data",), "model")
    ctx = layout.ctx
    ds = CompletionDataset(raw, key, mesh=mesh, data_axes=("data",))
    st, omega = ds.tensor, ds.omega
    ks = jax.random.split(key, 3)
    factors = tuple(jax.random.normal(k, (d, r)) / r ** 0.5
                    for k, d in zip(ks, dims))

    def timeit(fn, *args):
        jax.block_until_ready(fn(*args))          # compile
        ts = []
        for _ in range(sweeps):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(*args))
            ts.append(time.perf_counter() - t0)
        return sorted(ts)[len(ts) // 2] * 1e6

    local_fn = jax.jit(lambda s, o, fs: tuple(
        als_sweep(s, o, list(fs), 1e-6, cg_iters=10, ctx=LOCAL)))
    print(f"dist_als_sweep_local {timeit(local_fn, st, omega, factors):.1f}")

    st_spec = layout.sparse_specs(st)
    f_spec = layout.factor_spec()
    mesh_fn = jax.jit(shard_map(
        lambda s, o, fs: tuple(als_sweep(s, o, list(fs), 1e-6,
                                         cg_iters=10, ctx=ctx)),
        mesh=mesh, in_specs=(st_spec, st_spec, (f_spec,) * 3),
        out_specs=((f_spec,) * 3), check_rep=False))
    print(f"dist_als_sweep_mesh4x2 {timeit(mesh_fn, st, omega, factors):.1f}")
    print("BENCH-DIST-DONE")
""")


def run(quick: bool = False):
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run([sys.executable, "-c", _SCRIPT, str(int(quick))],
                         env=env, capture_output=True, text=True,
                         timeout=1200,
                         cwd=os.path.dirname(os.path.dirname(
                             os.path.abspath(__file__))))
    if "BENCH-DIST-DONE" not in out.stdout:
        raise RuntimeError("distributed bench subprocess failed:\n"
                           + out.stdout + "\n---\n" + out.stderr)
    for line in out.stdout.splitlines():
        parts = line.split()
        if len(parts) == 2 and parts[0].startswith("dist_"):
            emit(parts[0], float(parts[1]),
                 "8 forced host devices; shard_map ALS via planner executor"
                 if "mesh" in parts[0] else "same problem, LOCAL ctx")
