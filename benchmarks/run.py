"""Benchmark harness — one module per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run [--quick] [--only fig6]``
prints ``name,us_per_call,derived`` CSV lines (paper mapping in DESIGN.md §7).
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

from benchmarks import (bench_ccd_variants, bench_completion, bench_gcp,
                        bench_mttkrp, bench_planner, bench_redistribution,
                        bench_ttm, bench_tttp)

MODULES = [
    ("fig4_redistribution", bench_redistribution),
    ("fig5a_ttm", bench_ttm),
    ("fig5b_mttkrp", bench_mttkrp),
    ("fig6_tttp", bench_tttp),
    ("fig7_completion", bench_completion),
    ("sec5.5_ccd_variants", bench_ccd_variants),
    ("gcp_generalized_losses", bench_gcp),
    ("planner_dispatch", bench_planner),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    print("name,us_per_call,derived")
    failures = 0
    for name, mod in MODULES:
        if args.only and args.only not in name:
            continue
        t0 = time.time()
        print(f"# -- {name} --", flush=True)
        try:
            mod.run(quick=args.quick)
        except Exception:
            failures += 1
            traceback.print_exc()
        print(f"# {name} done in {time.time() - t0:.1f}s", flush=True)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
