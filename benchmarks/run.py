"""Benchmark harness — one module per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run [--quick] [--only fig6] [--json DIR]``
prints ``name,us_per_call,derived`` CSV lines (paper mapping in DESIGN.md §7).

``--json DIR`` additionally writes one ``BENCH_<group>.json`` file per
module group into DIR, each a flat ``{name: us_per_call}`` object — the
machine-readable perf trajectory. The completion solvers (als/ccd/sgd from
``bench_completion``, ggn from ``bench_gauss_newton``) share the
``completion`` group and land together in ``BENCH_completion.json``.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
import traceback

from benchmarks import (bench_ccd_variants, bench_completion,
                        bench_distributed, bench_gauss_newton, bench_gcp,
                        bench_ingest, bench_kernels, bench_mttkrp,
                        bench_planner, bench_redistribution, bench_serve,
                        bench_ttm, bench_tttp)
from benchmarks.common import drain_records

# (csv prefix, module, json group)
MODULES = [
    ("fig4_redistribution", bench_redistribution, "redistribution"),
    ("fig5a_ttm", bench_ttm, "ttm"),
    ("fig5b_mttkrp", bench_mttkrp, "mttkrp"),
    ("fig6_tttp", bench_tttp, "tttp"),
    ("fig7_completion", bench_completion, "completion"),
    ("sec5.5_ccd_variants", bench_ccd_variants, "ccd_variants"),
    ("gcp_generalized_losses", bench_gcp, "gcp"),
    ("planner_dispatch", bench_planner, "planner"),
    ("sec6_streaming_ingest", bench_ingest, "ingest"),
    ("sec5_kernel_tiles", bench_kernels, "kernels"),
    ("ggn_gauss_newton", bench_gauss_newton, "completion"),
    ("sec4_distributed_completion", bench_distributed, "distributed"),
    ("serve_endpoints", bench_serve, "serve"),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None)
    ap.add_argument("--json", default=None, metavar="DIR",
                    help="write BENCH_<group>.json files with "
                         "{name: us_per_call} into DIR")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    failures = 0
    groups: dict = {}
    for name, mod, group in MODULES:
        if args.only and args.only not in name:
            continue
        # repro-lint: disable=JS003 -- coarse per-module progress wall time, not a measurement
        t0 = time.time()
        print(f"# -- {name} --", flush=True)
        try:
            mod.run(quick=args.quick)
        except Exception:
            failures += 1
            traceback.print_exc()
        # a module that fails midway keeps whatever it managed to emit
        groups.setdefault(group, {}).update(drain_records())
        # repro-lint: disable=JS003 -- coarse per-module progress wall time, not a measurement
        print(f"# {name} done in {time.time() - t0:.1f}s", flush=True)
    if args.json:
        os.makedirs(args.json, exist_ok=True)
        for group, records in groups.items():
            if not records:
                continue
            path = os.path.join(args.json, f"BENCH_{group}.json")
            # merge with existing entries so a filtered run (--only) updates
            # its slice of a shared group (e.g. completion = als/ccd/sgd
            # from fig7 + ggn) without clobbering the rest
            merged = {}
            if os.path.exists(path):
                try:
                    with open(path) as f:
                        merged = json.load(f)
                except (OSError, ValueError):
                    merged = {}
            merged.update(records)
            with open(path, "w") as f:
                json.dump(merged, f, indent=2, sort_keys=True)
            print(f"# wrote {path} ({len(records)} new/{len(merged)} total "
                  f"entries)", flush=True)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
