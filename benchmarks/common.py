"""Benchmark helpers: timing and CSV emission."""
from __future__ import annotations

import time
from typing import Callable

import jax


def time_fn(fn: Callable, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall time per call in microseconds (jit'd callables)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def emit(name: str, us: float, derived: str = "") -> str:
    line = f"{name},{us:.1f},{derived}"
    print(line, flush=True)
    return line
