"""Benchmark helpers: timing, CSV emission, and machine-readable records."""
from __future__ import annotations

import time
from typing import Callable, Dict

import jax

# name -> us_per_call for every emit() since the last drain_records();
# benchmarks.run drains this per module to build the BENCH_*.json files
_RECORDS: Dict[str, float] = {}


def time_fn(fn: Callable, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall time per call in microseconds (jit'd callables)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def emit(name: str, us: float, derived: str = "") -> str:
    line = f"{name},{us:.1f},{derived}"
    print(line, flush=True)
    _RECORDS[name] = us
    return line


def drain_records() -> Dict[str, float]:
    """Return and clear the {name: us_per_call} records emitted so far."""
    out = dict(_RECORDS)
    _RECORDS.clear()
    return out
