"""Generalized-loss completion (assigned-title revision): per-sweep cost and
loss descent for Poisson / logistic / Huber objectives on a count tensor."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.core import losses as L
from repro.core.completion import gcp_adam_init, gcp_step
from repro.core.completion.gcp import gcp_loss
from repro.data import synthetic


def run(quick: bool = False):
    key = jax.random.PRNGKey(6)
    nnz = 10_000 if quick else 60_000
    st = synthetic.function_tensor(key, (80, 70, 60), nnz)
    counts = st.with_values(jnp.round(6.0 * st.values))
    for name in ("poisson", "logistic", "huber"):
        loss = L.LOSSES[name]
        data = counts if name == "poisson" else (
            st.with_values((st.values > 0.5).astype(jnp.float32))
            if name == "logistic" else st)
        ks = jax.random.split(key, 3)
        fs = [jnp.abs(jax.random.normal(k, (d, 8))) * 0.3 + 0.05
              for k, d in zip(ks, data.shape)]
        ad = gcp_adam_init(fs)
        step = jax.jit(lambda s, f, a: gcp_step(s, list(f), loss, 1e-7,
                                                5e-3, a))
        l0 = float(gcp_loss(data, fs, loss, 1e-7))
        us = time_fn(lambda: step(data, tuple(fs), ad), warmup=1, iters=3)
        fs_t, ad_t = tuple(fs), ad
        for _ in range(30 if quick else 80):
            fs_t, ad_t = step(data, fs_t, ad_t)
        l1 = float(gcp_loss(data, list(fs_t), loss, 1e-7))
        emit(f"gcp_{name}_step", us, f"loss:{l0:.1f}->{l1:.1f}")
