"""Paper Fig. 6: TTTP all-at-once vs pairwise contraction, R=1 and R=60,
across density. Also exercises the H-sliced schedule and the Pallas kernel
path (interpret mode on CPU)."""
from __future__ import annotations

import jax

from benchmarks.common import emit, time_fn
from repro.core.sparse_tensor import SparseTensor
from repro.core import tttp as T
from repro.kernels import ops as kops


def run(quick: bool = False):
    key = jax.random.PRNGKey(3)
    nnz = 20_000 if quick else 100_000
    densities = [1e-2, 1e-4] if quick else [1e-2, 1e-3, 1e-4, 1e-5]
    for r in (1, 60):
        for dens in densities:
            dim = max(8, int(round((nnz / dens) ** (1 / 3))))
            st = SparseTensor.random(key, (dim,) * 3, nnz)
            ks = jax.random.split(key, 3)
            factors = [jax.random.normal(k, (dim, r)) for k in ks]

            f_all = jax.jit(lambda s, a, b, c: T.tttp(s, [a, b, c]).values)
            us = time_fn(f_all, st, *factors)
            emit(f"fig6_tttp_allatonce_r{r}_d{dens:g}", us, f"dim={dim}")

            f_pw = jax.jit(lambda s, a, b, c:
                           T.tttp_pairwise(s, [a, b, c]).values)
            us_pw = time_fn(f_pw, st, *factors)
            emit(f"fig6_tttp_pairwise_r{r}_d{dens:g}", us_pw,
                 f"slowdown={us_pw / max(us, 1):.2f}x")

            if r == 60:
                f_sl = jax.jit(lambda s, a, b, c:
                               T.tttp_sliced(s, [a, b, c], 4).values)
                us_sl = time_fn(f_sl, st, *factors)
                emit(f"fig6_tttp_sliced_h4_r{r}_d{dens:g}", us_sl, "")
