"""Paper Fig. 5a: TTM variants across density at fixed nonzero count.

Variants: fully dense, sparse-input/dense-output, hypersparse (sparse
output). Derived = density; the dense variants stop being reported where
their memory would exceed the budget (the paper's OOM points)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.core.sparse_tensor import SparseTensor
from repro.sparse import ops as sops

MEM_BUDGET = 2 ** 28  # 256 MB proxy for the per-node budget


def run(quick: bool = False):
    key = jax.random.PRNGKey(1)
    nnz = 20_000 if quick else 100_000
    r = 32
    densities = [1e-2, 1e-3, 1e-4] if quick else [1e-2, 1e-3, 1e-4, 1e-5]
    for dens in densities:
        dim = max(8, int(round((nnz / dens) ** (1 / 3))))
        shape = (dim, dim, dim)
        st = SparseTensor.random(key, shape, nnz)
        w = jax.random.normal(key, (dim, r))
        if 8 * dim ** 3 <= MEM_BUDGET:
            f = jax.jit(lambda d, w: sops.ttm_fully_dense(d, w, 2))
            us = time_fn(f, st.todense(), w)
            emit(f"fig5a_ttm_dense_d{dens:g}", us, f"dim={dim}")
        else:
            emit(f"fig5a_ttm_dense_d{dens:g}", -1, "OOM-budget")
        if 4 * dim * dim * r <= MEM_BUDGET:
            f = jax.jit(lambda s, w: sops.ttm_dense_output(s, w, 2))
            us = time_fn(f, st, w)
            emit(f"fig5a_ttm_sparse_denseout_d{dens:g}", us, f"dim={dim}")
        else:
            emit(f"fig5a_ttm_sparse_denseout_d{dens:g}", -1, "OOM-budget")
        f = jax.jit(lambda s, w: sops.ttm_hypersparse(s, w, 2).values)
        us = time_fn(f, st, w)
        emit(f"fig5a_ttm_hypersparse_d{dens:g}", us, f"dim={dim}")
