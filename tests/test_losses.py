"""Loss derivative checks that run without hypothesis: hand-written
grad/hess vs jax.grad for all five losses, clamp regions included.
(The hypothesis-driven versions in test_properties.py fuzz the same
invariants when hypothesis is available.)"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import losses as L


def _sample(name, seed):
    key = jax.random.PRNGKey(seed)
    t = jnp.abs(jax.random.normal(key, (64,))) + 0.1
    if name == "logistic":
        t = (t > 0.5).astype(jnp.float32)
    if name == "poisson":
        t = jnp.round(t * 3)
    m = 2.0 * jax.random.normal(jax.random.fold_in(key, 1), (64,))
    # clamp-region probes, strictly off the boundaries: below/above the
    # poisson floor ε and inside/outside the huber δ
    m_probe = jnp.array([-2.0, -1e-3, 1e-8, 1e-7, L._EPS * 0.5,
                         L._EPS * 3.0, 1e-4, 0.3, 2.5, 4.0])
    t_probe = jnp.ones_like(m_probe) * (t[0] if name != "logistic" else 1.0)
    return jnp.concatenate([t, t_probe]), jnp.concatenate([m, m_probe])


@pytest.mark.parametrize("name", sorted(L.LOSSES))
@pytest.mark.parametrize("seed", [0, 7, 123])
def test_grad_matches_autodiff(name, seed):
    loss = L.LOSSES[name]
    t, m = _sample(name, seed)
    got = loss.grad(t, m)
    want = jax.vmap(jax.grad(lambda mm, tt: loss.value(tt, mm)))(m, t)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("name", sorted(L.LOSSES))
@pytest.mark.parametrize("seed", [0, 7, 123])
def test_hess_matches_autodiff(name, seed):
    loss = L.LOSSES[name]
    t, m = _sample(name, seed)
    got = loss.hess(t, m)
    want = jax.vmap(jax.grad(lambda mm, tt: loss.grad(tt, mm)))(m, t)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_poisson_grad_is_one_below_floor():
    """Regression: the clamped poisson grad is exactly 1 where m ≤ ε (the
    log(max(m, ε)) term is constant in m there), not 1 − t/ε; curvature 0."""
    t = jnp.array([3.0, 1.0, 7.0])
    m = jnp.array([-1.0, 0.0, L._EPS * 0.25])
    np.testing.assert_allclose(L.poisson.grad(t, m), jnp.ones(3))
    np.testing.assert_allclose(L.poisson.hess(t, m), jnp.zeros(3))


def test_hess_nonnegative_on_domain():
    """Every loss curvature is ≥ 0 (the GGN weights are PSD-safe)."""
    for name, loss in L.LOSSES.items():
        t, m = _sample(name, 3)
        assert bool(jnp.all(loss.hess(t, m) >= 0)), name
