"""Cross-check the HLO roofline parser against XLA's own cost analysis.

``launch.roofline.HloModule`` counts dot flops from the compiled HLO text;
``compiled.cost_analysis()['flops']`` is XLA's count of the SAME program
and additionally includes elementwise flops. So on a pure-dot program the
two must agree exactly, and on a jitted MTTKRP the parsed dot flops must
lower-bound cost analysis within the elementwise margin (Θ(output · R)
adds/multiplies around the matmuls). A parser regression (wrong shape
product, missed dot, broken trip-count weighting) breaks these bounds."""
import jax
import jax.numpy as jnp
import pytest

from repro.obs import hlo_terms, profile_jitted


def _compile(fn, *args):
    return jax.jit(fn).lower(*args).compile()


def test_pure_dot_flops_exact():
    a = jnp.ones((32, 48))
    b = jnp.ones((48, 16))
    t = hlo_terms(_compile(lambda x, y: x @ y, a, b))
    assert t["flops"] == 2 * 32 * 48 * 16
    assert t["cost_analysis_flops"] == t["flops"]


def test_dense_mttkrp_flops_within_elementwise_margin():
    # dense MTTKRP via reshape+dot: T_(0) @ khatri_rao(B, C).
    I, J, K, R = 16, 12, 8, 4
    T = jnp.ones((I, J, K))
    B = jnp.ones((J, R))
    C = jnp.ones((K, R))

    def mttkrp(T, B, C):
        kr = (B[:, None, :] * C[None, :, :]).reshape(J * K, R)
        return T.reshape(I, J * K) @ kr

    t = hlo_terms(_compile(mttkrp, T, B, C))
    parsed, ca = t["flops"], t["cost_analysis_flops"]
    assert parsed == 2 * I * J * K * R            # the dot dominates
    assert ca >= parsed                           # XLA adds elementwise
    # the khatri-rao product is the only elementwise work: J*K*R multiplies
    assert ca - parsed <= 2 * J * K * R, (parsed, ca)


def test_gather_segment_kernel_has_no_dot_flops():
    """The sparse gather/segment paths run on no MXU dots at all — the
    parser must report 0 rather than inventing flops (report.py renders
    their roofline from the memory term instead)."""
    idx = jnp.arange(64) % 8
    vals = jnp.ones((64,))

    def seg(vals, idx):
        return jax.ops.segment_sum(vals, idx, num_segments=8)

    t = hlo_terms(_compile(seg, vals, idx))
    assert t["flops"] == 0.0
    assert t["cost_analysis_flops"] > 0.0         # XLA still counts the adds
    assert t["bytes"] > 0.0


def test_profile_jitted_report_shape():
    a = jnp.ones((64, 64))
    rep = profile_jitted(lambda x: x @ x, a, name="sq", iters=2)
    assert rep["measured_s"] > 0
    assert rep["hlo_flops"] == 2 * 64 ** 3
    assert rep["dominant"] in ("compute", "memory", "collective")
    assert 0 < rep["frac_roofline"] <= 1.5        # bound time <= measured
    assert rep["machine"]["peak_flops"] > 0
    for k in ("frac_peak_compute", "frac_peak_memory"):
        assert rep[k] >= 0


def test_bucketed_mttkrp_cross_check():
    """End-to-end: the repo's own bucketed MTTKRP compiled under jit —
    the parser must never exceed XLA's count (it omits elementwise work,
    never invents dot work), and the memory term must be positive."""
    from repro.core.sparse_tensor import SparseTensor
    from repro.kernels import ops as kops

    st = SparseTensor.random(jax.random.PRNGKey(0), (40, 30, 20), 500)
    buckets = st.row_buckets(0, 16)
    fs = [None] + [jax.random.normal(jax.random.PRNGKey(i), (d, 4))
                   for i, d in enumerate(st.shape[1:], 1)]
    t = hlo_terms(_compile(
        lambda b, f1, f2: kops.mttkrp_bucketed(b, [None, f1, f2],
                                               num_rows=40),
        buckets, fs[1], fs[2]))
    assert t["flops"] <= t["cost_analysis_flops"]
    assert t["bytes"] > 0
