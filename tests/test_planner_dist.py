"""Unit tests for the distribution-aware planner executor (ISSUE 3):
ingest-time bucket reuse on SparseTensor, PlannerConfig in plan cache keys,
DistInfo-driven candidate restriction and communication cost terms."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import planner
from repro.core.sparse_tensor import SparseTensor
from repro.planner import ir as pir
from repro.planner.config import PlannerConfig
from repro.sparse import ccsr
from repro.sparse import ops as sops


def _problem(key=None, shape=(32, 24, 16), nnz=600, r=8):
    key = key or jax.random.PRNGKey(0)
    st = SparseTensor.random(key, shape, nnz)
    ks = jax.random.split(key, len(shape))
    fs = [jax.random.normal(k, (d, r)) for k, d in zip(ks, shape)]
    return st, fs


# ---------------------------------------------------------------------------
# ingest-time bucket cache
# ---------------------------------------------------------------------------

def test_row_buckets_match_one_shot_bucketize():
    st, _ = _problem()
    bk = st.row_buckets(0, 8)
    ref = ccsr.bucketize(st, 0, block_rows=8)
    np.testing.assert_array_equal(np.asarray(bk.values), np.asarray(ref.values))
    np.testing.assert_array_equal(np.asarray(bk.indices), np.asarray(ref.indices))
    np.testing.assert_array_equal(np.asarray(bk.valid), np.asarray(ref.valid))


def test_pattern_built_once_and_shared_by_with_values(monkeypatch):
    """The host-side pattern build runs once per (mode, block_rows); tensors
    derived with with_values (same Ω) re-gather values through it."""
    st, _ = _problem()
    builds = []
    orig = ccsr.bucket_pattern

    def counting(*a, **kw):
        builds.append(1)
        return orig(*a, **kw)

    monkeypatch.setattr(ccsr, "bucket_pattern", counting)
    bk1 = st.row_buckets(0, 8)
    omega = st.with_values(jnp.ones_like(st.values))
    bk2 = omega.row_buckets(0, 8)          # shared pattern, fresh values
    st.row_buckets(0, 8)                   # cached
    assert len(builds) == 1
    np.testing.assert_array_equal(np.asarray(bk2.valid), np.asarray(bk1.valid))
    vals = np.asarray(bk2.values)
    assert set(np.unique(vals)) <= {0.0, 1.0}
    assert vals.sum() == np.asarray(st.valid).sum()
    # a different granularity is a different pattern (and a different plan key)
    st.row_buckets(0, 16)
    assert len(builds) == 2


def test_pattern_cache_not_shared_across_pattern_changes():
    st, _ = _problem()
    st.row_buckets(0, 8)
    assert st.transpose((1, 0, 2))._pattern_cache is None
    assert st.sort_by_mode(0)._pattern_cache is None


def test_row_buckets_none_under_tracing_without_pattern():
    st, _ = _problem()

    def probe(s):
        assert s.row_buckets(0, 8) is None   # trace-time, no cached pattern
        return s.values

    jax.jit(probe)(st)


def test_bucketed_dispatch_consumes_cache_no_per_call_bucketize(monkeypatch):
    """Acceptance: no host bucketize inside the sweep loop — dispatch
    re-gathers through the ingest-time pattern on every call."""
    st, fs = _problem()
    st.row_buckets(0, PlannerConfig().block_rows)   # "ingest"
    builds = []
    orig = ccsr.bucket_pattern
    monkeypatch.setattr(ccsr, "bucket_pattern",
                        lambda *a, **kw: builds.append(1) or orig(*a, **kw))
    want = sops.mttkrp(st, [None, fs[1], fs[2]], 0)
    for vals in (st.values, st.values * 2.0):
        got = planner.planned_mttkrp(st.with_values(vals), fs, 0,
                                     path="bucketed")
        assert not builds, "dispatch re-ran the host bucketize"
    np.testing.assert_allclose(np.asarray(got), 2.0 * np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_block_rows_recorded_in_plan_cache_key():
    st, fs = _problem()
    planner.clear_plan_cache()
    p8 = planner.plan_contraction("abc,bz,cz->az", (st, fs[1], fs[2]),
                                  config=PlannerConfig(block_rows=8))
    p16 = planner.plan_contraction("abc,bz,cz->az", (st, fs[1], fs[2]),
                                   config=PlannerConfig(block_rows=16))
    assert p8 is not p16
    assert p8.config.block_rows == 8 and p16.config.block_rows == 16
    assert planner.plan_contraction(
        "abc,bz,cz->az", (st, fs[1], fs[2]),
        config=PlannerConfig(block_rows=8)) is p8


# ---------------------------------------------------------------------------
# distribution-aware planning
# ---------------------------------------------------------------------------

def _ir_with_dist(st, fs, dist):
    return pir.build_ir("abc,bz,cz->az", (st, fs[1], fs[2]), dist=dist)


def test_candidate_paths_under_model_sharding():
    st, fs = _problem()
    x = fs[0]
    ops = (st, fs[1], fs[2], x, fs[1], fs[2])
    expr = "abc,bz,cz,ay,by,cy->az"
    local = pir.build_ir(expr, ops)
    assert "fused" in planner.candidate_paths(local)
    dist = pir.build_ir(expr, ops, dist=pir.DistInfo(data_size=4,
                                                     model_size=2))
    cands = planner.candidate_paths(dist)
    assert "fused" not in cands and "dense" not in cands
    assert "tttp_mttkrp" in cands


def test_rowsharded_is_the_only_candidate():
    st, fs = _problem()
    local_fs = [f[: f.shape[0] // 4] for f in fs]
    ir = _ir_with_dist(st, local_fs,
                       pir.DistInfo(data_size=4, rowsharded=True))
    assert planner.candidate_paths(ir) == ["rowsharded"]


def test_rowsharded_ir_scales_local_factor_rows():
    """Row-sharded factors carry local row counts; the IR validates them
    against local_rows * data_size."""
    st, fs = _problem()
    local_fs = [f[: f.shape[0] // 4] for f in fs]
    ir = pir.build_ir("abc,bz,cz->az", (st, local_fs[1], local_fs[2]),
                      dist=pir.DistInfo(data_size=4, rowsharded=True))
    assert ir.size_of("b") == st.shape[1]
    with pytest.raises(ValueError):
        pir.build_ir("abc,bz,cz->az", (st, local_fs[1], local_fs[2]))


def test_comm_terms_rank_distributed_against_local():
    st, fs = _problem()
    local = _ir_with_dist(st, fs, None)
    dist = _ir_with_dist(st, fs, pir.DistInfo(data_size=4, model_size=1))
    c_local = planner.estimate(local, "all_at_once")
    c_dist = planner.estimate(dist, "all_at_once")
    assert c_local.comm == 0.0
    assert c_dist.comm > 0.0                      # psum(data) of the output
    assert c_dist.seconds > c_local.seconds
    # the psum volume is the (rows, R) output, twice (ring all-reduce)
    assert c_dist.comm == pytest.approx(2.0 * st.shape[0] * fs[0].shape[1])


def test_ctx_in_plan_cache_key():
    from repro.core.distributed import AxisCtx, LOCAL
    st, fs = _problem()
    planner.clear_plan_cache()
    ops = (st, fs[1], fs[2])
    p_local = planner.plan_contraction("abc,bz,cz->az", ops)
    assert p_local.ctx is LOCAL and p_local.ir.dist is None
    # a named-axis ctx outside shard_map cannot resolve axis sizes — the
    # cache key still separates it (checked via the LOCAL hit below)
    assert planner.plan_contraction("abc,bz,cz->az", ops) is p_local
    with pytest.raises(Exception):
        planner.plan_contraction("abc,bz,cz->az", ops,
                                 ctx=AxisCtx(data="data"))
