"""SPMD collective-soundness analyzer (repro.analysis.spmd, DESIGN.md §15):
replication-state transfer units, the seeded-bug fixture corpus (each must
report exactly its planted rule), fault-injection tripwires over the planner
sweep, the collective-matching AST lint, static VMEM certification, tuner
pruning (a rejected candidate is NEVER timed — asserted on obs counters),
the ``validate_spmd`` planner hook, the ServeEngine replication guard, and
the JS006 stale-suppression detector."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs
from repro.analysis import contracts
from repro.analysis import lint
from repro.analysis.spmd import cli as spmd_cli
from repro.analysis.spmd import collectives
from repro.analysis.spmd import sharding
from repro.analysis.spmd import vmem as spmd_vmem
from repro.analysis.spmd.sharding import (REP, ROWS, SpmdContractError,
                                          analyze_fn, shard)
from repro.core.sparse_tensor import SparseTensor
from repro.kernels import tile as ktile
from repro.kernels import vmem as kvmem
from repro.kernels.tile import KernelTile
from repro.planner import cost as pcost
from repro.planner import tuner
from repro.planner.plan import clear_plan_cache, plan_contraction
from repro.serve.engine import ServeEngine
from repro.serve.model import ServingModel

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
FIXDIR = os.path.join(REPO_ROOT, "tests", "analysis_fixtures")

ENV1 = (("data", 2),)
V = (jax.ShapeDtypeStruct((8,), jnp.float32),)
V_SHARDED = ({"data": shard(0)},)
WANT_REP = {"data": "rep"}


def rules_of(findings):
    return {f.rule for f in findings}


# ---------------------------------------------------------------------------
# replication-state transfer units (analyze_fn)
# ---------------------------------------------------------------------------

class TestTransfer:
    def test_reduce_then_psum_is_clean(self):
        def f(v):
            return jax.lax.psum(jnp.sum(v), "data")
        assert analyze_fn(f, V, V_SHARDED, ENV1, expected=WANT_REP) == []

    def test_missing_psum_is_partial_sum_escape(self):
        def f(v):
            return jnp.sum(v)
        fs = analyze_fn(f, V, V_SHARDED, ENV1, expected=WANT_REP)
        assert rules_of(fs) == {"SP001"}

    def test_double_psum_is_over_reduction(self):
        def f(v):
            return jax.lax.psum(jax.lax.psum(jnp.sum(v), "data"), "data")
        fs = analyze_fn(f, V, V_SHARDED, ENV1, expected=WANT_REP)
        assert "SP002" in rules_of(fs) and "SP001" not in rules_of(fs)

    def test_wrong_axis_psum_flags_both_sides(self):
        """psum over the WRONG mesh axis: the reduced axis stays a partial
        sum (SP001) while the named axis gets a redundant psum (SP002)."""
        env = (("data", 2), ("model", 2))
        states = ({"data": shard(0), "model": REP},)

        def f(v):
            return jax.lax.psum(jnp.sum(v), "model")
        fs = analyze_fn(f, V, states, env,
                        expected={"data": "rep", "model": "rep"})
        assert rules_of(fs) == {"SP001", "SP002"}

    def test_sharded_escape_when_replication_expected(self):
        def f(v):
            return v * 2.0
        fs = analyze_fn(f, V, V_SHARDED, ENV1, expected=WANT_REP)
        assert rules_of(fs) == {"SP003"}

    def test_all_gather_discharges_shard(self):
        def f(v):
            return jax.lax.all_gather(v, "data")
        assert analyze_fn(f, V, V_SHARDED, ENV1, expected=WANT_REP) == []

    def test_gather_into_rowsharded_factor_flags_sp004(self):
        """Global row indexing into a ROWS-sharded factor without an
        all_gather resolves against the local shard — SP004."""
        args = (jax.ShapeDtypeStruct((8, 4), jnp.float32),
                jax.ShapeDtypeStruct((6,), jnp.int32))
        states = ({"data": shard(0, ROWS)}, {"data": REP})

        def f(factor, rows):
            return jax.lax.psum(jnp.sum(factor[rows], axis=0), "data")
        fs = analyze_fn(f, args, states, ENV1, expected=WANT_REP)
        assert "SP004" in rules_of(fs)

    def test_gather_into_local_nnz_shard_is_legal(self):
        """The same gather into an UNTAGGED shard (owner-aligned nnz data,
        e.g. a sort permutation) is a local move, not a finding."""
        args = (jax.ShapeDtypeStruct((8,), jnp.float32),
                jax.ShapeDtypeStruct((8,), jnp.int32))
        states = ({"data": shard(0)}, {"data": shard(0)})

        def f(vals, perm):
            return vals[perm]
        fs = analyze_fn(f, args, states, ENV1, expected={"data": "shard"})
        assert fs == []

    def test_untraceable_fn_is_sp000(self):
        def f(v):
            raise RuntimeError("boom")
        fs = analyze_fn(f, V, V_SHARDED, ENV1)
        assert rules_of(fs) == {"SP000"}


# ---------------------------------------------------------------------------
# the seeded-bug fixture corpus: exactly ONE planted defect each
# ---------------------------------------------------------------------------

class TestFixtures:
    @pytest.mark.parametrize("fixture,planted", [
        ("spmd_missing_psum.py", "SP001"),
        ("spmd_branch_divergent.py", "SP101"),
        ("spmd_over_vmem.py", "SP201"),
    ])
    def test_fixture_reports_exactly_its_planted_rule(self, fixture,
                                                      planted):
        fs = spmd_cli.check_fixture(os.path.join(FIXDIR, fixture))
        assert rules_of(fs) == {planted}, \
            f"{fixture}: {[f.format() for f in fs]}"

    @pytest.mark.parametrize("fixture,planted", [
        ("spmd_missing_psum.py", "SP001"),
        ("spmd_branch_divergent.py", "SP101"),
        ("spmd_over_vmem.py", "SP201"),
    ])
    def test_cli_expect_contract(self, fixture, planted):
        path = os.path.join(FIXDIR, fixture)
        assert spmd_cli.main(["--fixture", path, "--expect", planted]) == 0
        assert spmd_cli.main(["--fixture", path, "--expect", "SP999"]) == 1


# ---------------------------------------------------------------------------
# the planner-IR sweep + fault injection
# ---------------------------------------------------------------------------

class TestShardingSweep:
    def test_order3_sweep_is_clean(self):
        assert sharding.check_cases(orders=(3,)) == []

    @pytest.mark.parametrize("fault,rule", [
        ("missing-psum", "SP001"),
        ("double-psum", "SP002"),
    ])
    def test_planted_fault_trips_the_sweep(self, fault, rule):
        sub = [c for c in contracts.iter_cases((3,))
               if c.axis_env and c.family in ("mttkrp", "tttp")]
        sharding.set_fault(fault)
        try:
            fs = sharding.check_cases(cases=sub)
        finally:
            sharding.set_fault(None)
        assert fs, f"fault {fault!r} produced no findings"
        assert rule in rules_of(fs)

    def test_certify_plan_distributed(self, monkeypatch):
        monkeypatch.setenv("REPRO_USE_PALLAS", "0")
        case = next(c for c in contracts.iter_cases((3,))
                    if c.axis_env and c.family == "mttkrp")
        paths = pcost.candidate_paths(case.ir)
        operands = [case.st, *case.denses]
        sharding.certify_plan(case.ir, paths, operands, case.ctx,
                              case.config)  # sound: no raise
        sharding.set_fault("missing-psum")
        try:
            with pytest.raises(SpmdContractError, match="SP001"):
                sharding.certify_plan(case.ir, paths, operands, case.ctx,
                                      case.config)
        finally:
            sharding.set_fault(None)

    def test_plan_contraction_validate_spmd_wiring(self):
        st = SparseTensor.random(jax.random.PRNGKey(0), (12, 10, 8), 40,
                                 cap=48)
        factors = [np.linspace(-1, 1, d * 4, dtype=np.float32).reshape(d, 4)
                   for d in st.shape]
        clear_plan_cache()
        plan = plan_contraction("ijk,jr,kr->ir", [st] + factors[1:],
                                validate_spmd=True)
        assert plan.path in pcost.candidate_paths(plan.ir)


# ---------------------------------------------------------------------------
# collective-matching AST lint
# ---------------------------------------------------------------------------

class TestCollectives:
    PATH = "src/repro/core/x.py"

    def test_branch_divergence_on_device_varying_test(self):
        src = ("import jax\nimport jax.numpy as jnp\n\n"
               "def exchange(x, axis):\n"
               "    if jnp.any(x > 0):\n"
               "        x = jax.lax.psum(x, axis)\n"
               "    return x\n")
        assert "SP101" in rules_of(collectives.lint_source(src, self.PATH))

    def test_uniform_host_guard_is_legal(self):
        """`if ctx.data is not None:` is the same on every device — a
        collective under it is NOT divergent."""
        src = ("import jax\n\n"
               "def maybe(ctx, x, axis):\n"
               "    if ctx.data is not None:\n"
               "        x = jax.lax.psum(x, axis)\n"
               "    return x\n")
        fs = [f for f in collectives.lint_source(src, self.PATH)
              if not f.suppressed]
        assert fs == []

    def test_collective_under_traced_conditional(self):
        src = ("import jax\n\n"
               "def pick(p, x, axis):\n"
               "    return jax.lax.cond(p,\n"
               "                        lambda v: jax.lax.psum(v, axis),\n"
               "                        lambda v: v, x)\n")
        assert "SP102" in rules_of(collectives.lint_source(src, self.PATH))

    def test_hardcoded_axis_name(self):
        src = ("import jax\n\n"
               "def f(x):\n"
               "    return jax.lax.psum(x, 'data')\n")
        assert "SP103" in rules_of(collectives.lint_source(src, self.PATH))

    def test_sp_suppression_with_reason_is_honored(self):
        src = ("import jax\n\n"
               "def f(x):\n"
               "    # repro-lint: disable=SP103 -- single-mesh helper; "
               "axis fixed by the launch contract\n"
               "    return jax.lax.psum(x, 'data')\n")
        fs = collectives.lint_source(src, self.PATH)
        sp = [f for f in fs if f.rule == "SP103"]
        assert sp and all(f.suppressed for f in sp)

    def test_repo_is_collective_clean(self):
        fs = [f for f in collectives.run(REPO_ROOT) if not f.suppressed]
        assert fs == [], "\n".join(f.format() for f in fs)


# ---------------------------------------------------------------------------
# static VMEM certification
# ---------------------------------------------------------------------------

class TestVmem:
    GEOM = kvmem.KernelGeometry(nd=3, rank=32, factor_rows=(60, 20),
                                capacity=4096)

    def test_estimate_monotone_in_tile(self):
        small = kvmem.estimate_vmem("tttp", KernelTile(block_m=256,
                                                       block_r=32),
                                    self.GEOM)
        big = kvmem.estimate_vmem("tttp", KernelTile(block_m=512,
                                                     block_r=64), self.GEOM)
        assert small.fits and big.fits
        assert big.total > small.total

    def test_paper_scale_cg_overflows_16mib(self):
        geom = kvmem.KernelGeometry(nd=3, rank=64,
                                    factor_rows=(17_770, 2_182),
                                    capacity=4096, x_rows=480_189)
        est = kvmem.estimate_vmem("cg_matvec",
                                  KernelTile(block_m=1024, block_r=128),
                                  geom)
        assert not est.fits and est.total > est.budget

    def test_ci_lattices_all_fit(self):
        assert spmd_vmem.run() == []

    def test_paper_scale_findings_are_expected(self):
        fs = spmd_vmem.run(paper_scale=True)
        assert fs and rules_of(fs) == {"SP201"}


# ---------------------------------------------------------------------------
# tuner pruning: a VMEM-rejected candidate is NEVER timed
# ---------------------------------------------------------------------------

@pytest.fixture
def tuned_problem():
    key = jax.random.PRNGKey(0)
    st = SparseTensor.random(key, (24, 18, 12), 120, cap=140)
    ks = jax.random.split(key, 3)
    factors = [jax.random.normal(k, (d, 8)) for k, d in zip(ks, st.shape)]
    yield st, factors
    ktile.reset_tiles()
    pcost.reset_rates()


@pytest.fixture
def registry():
    obs.enable()
    reg = obs.get_registry()
    reg.reset()
    yield reg
    obs.disable()


class TestTunerPruning:
    def test_rejected_candidate_is_never_timed(self, tuned_problem,
                                               registry, monkeypatch):
        st, factors = tuned_problem
        keep = KernelTile(block_r=8)
        drop = KernelTile(block_r=128)
        geom = kvmem.workload_geometry("tttp", st, factors, keep)
        lo = kvmem.estimate_vmem("tttp", keep, geom).total
        hi = kvmem.estimate_vmem("tttp", drop, geom).total
        assert lo < hi
        monkeypatch.setenv("REPRO_VMEM_MB", str((lo + hi) / 2 / 2 ** 20))
        result = tuner.tune_family("tttp", st, factors,
                                   lattice=(keep, drop), iters=1)
        timed = [t for t, _ in result["timings"]]
        assert drop.short() not in timed and timed == [keep.short()]
        assert result["vmem_pruned"] == [(drop.short(), hi)]
        assert registry.counters.get("tuner/vmem_pruned") == 1
        assert registry.counters.get("tuner/measurements") == 1

    def test_all_pruned_is_an_error(self, tuned_problem, registry,
                                    monkeypatch):
        st, factors = tuned_problem
        monkeypatch.setenv("REPRO_VMEM_MB", "0.001")
        with pytest.raises(ValueError, match="VMEM"):
            tuner.tune_family("tttp", st, factors,
                              lattice=(KernelTile(),), iters=1)

    def test_cache_key_carries_vmem_budget(self, tuned_problem,
                                           monkeypatch):
        st, factors = tuned_problem
        k16 = tuner.cache_key("tttp", st, factors)
        monkeypatch.setenv("REPRO_VMEM_MB", "8")
        k8 = tuner.cache_key("tttp", st, factors)
        assert k16 != k8 and k8.endswith(f"|vmem={8 * 2 ** 20}")


# ---------------------------------------------------------------------------
# ServeEngine replication guard
# ---------------------------------------------------------------------------

class _FakeSharding:
    is_fully_replicated = False

    def __repr__(self):
        return "FakeSharding(mode=0)"


class _FakeShardedFactor:
    def __init__(self, rows, rank):
        self.shape = (rows, rank)
        self.sharding = _FakeSharding()


class TestServeReplicationGuard:
    def test_sharded_factor_is_refused_with_remedy(self):
        model = ServingModel(factors=[_FakeShardedFactor(8, 4),
                                      _FakeShardedFactor(6, 4),
                                      _FakeShardedFactor(5, 4)])
        with pytest.raises(ValueError, match="fully replicated"):
            ServeEngine(model)
        with pytest.raises(ValueError, match="all-gather"):
            ServeEngine(model)

    def test_replicated_factors_construct(self):
        key = jax.random.PRNGKey(1)
        factors = [jax.random.normal(k, (d, 4))
                   for k, d in zip(jax.random.split(key, 3), (8, 6, 5))]
        engine = ServeEngine(ServingModel(factors=list(factors)))
        out = engine.score(np.zeros((3, 3), np.int32))
        assert out.shape == (3,)


# ---------------------------------------------------------------------------
# JS006: stale-suppression detection
# ---------------------------------------------------------------------------

class TestStaleSuppressions:
    PATH = "src/repro/launch/x.py"   # scope: JS003 + JS005

    def test_dead_suppression_is_flagged_advisory(self):
        src = ("import time\nimport jax\n\n"
               "def f(x):\n"
               "    jax.block_until_ready(x)\n"
               "    # repro-lint: disable=JS003 -- legacy reason\n"
               "    t = time.perf_counter()\n"
               "    return t\n")
        fs = lint.lint_source(src, self.PATH)
        js6 = [f for f in fs if f.rule == "JS006"]
        assert len(js6) == 1 and js6[0].advisory
        assert "legacy reason" in js6[0].message

    def test_live_suppression_is_not_flagged(self):
        src = ("import time\n\n"
               "def f():\n"
               "    # repro-lint: disable=JS003 -- host-only accounting\n"
               "    t = time.perf_counter()\n"
               "    return t\n")
        fs = lint.lint_source(src, self.PATH)
        assert not any(f.rule == "JS006" for f in fs)
        assert any(f.rule == "JS003" and f.suppressed for f in fs)

    def test_docstring_example_is_not_a_suppression(self):
        src = ('"""Docs showing the idiom:\n\n'
               "    # repro-lint: disable=JS003 -- why it is safe\n"
               '"""\n')
        assert lint.lint_source(src, self.PATH) == []
