"""Golden kernel-regression fixtures: checked-in float64 reference outputs
for MTTKRP / TTTP / cg_matvec on tiny serialized COO tensors
(tests/golden/*.npz, regenerated only by tests/golden/make_golden.py).

Every kernel route — the direct ops, the bucketed Pallas-backed views and
every planner candidate path — must reproduce the stored references to
GOLDEN_TOL, so silent numeric drift anywhere in the kernel stack fails
loudly instead of degrading convergence quietly."""
import glob
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import planner
from repro.core.sparse_tensor import SparseTensor
from repro.kernels import ops as kops
from repro.sparse import ops as sops
from repro.sparse.ccsr import bucketize

GOLDEN_DIR = os.path.join(os.path.dirname(__file__), "golden")
GOLDEN_FILES = sorted(glob.glob(os.path.join(GOLDEN_DIR, "golden_*.npz")))
GOLDEN_TOL = dict(rtol=1e-4, atol=1e-4)


def _load(path):
    z = np.load(path)
    shape = tuple(int(s) for s in z["shape"])
    st = SparseTensor(jnp.asarray(z["indices"]), jnp.asarray(z["values"]),
                      jnp.asarray(z["valid"]), shape,
                      nnz=int(z["valid"].sum()))
    factors = [jnp.asarray(z[f"factor_{d}"]) for d in range(len(shape))]
    return z, st, factors


def _ids(paths):
    return [os.path.splitext(os.path.basename(p))[0] for p in paths]


def test_fixtures_exist():
    assert GOLDEN_FILES, f"no golden fixtures under {GOLDEN_DIR}"


@pytest.mark.parametrize("path", GOLDEN_FILES, ids=_ids(GOLDEN_FILES))
def test_golden_mttkrp_all_modes_all_paths(path):
    z, st, factors = _load(path)
    for mode in range(st.ndim):
        want = z[f"mttkrp_m{mode}"]
        fs = [None if d == mode else factors[d] for d in range(st.ndim)]
        np.testing.assert_allclose(sops.mttkrp(st, fs, mode), want,
                                   err_msg=f"direct mttkrp mode {mode}",
                                   **GOLDEN_TOL)
        buckets = bucketize(st, mode, block_rows=8)
        np.testing.assert_allclose(
            kops.mttkrp_bucketed(buckets, fs, num_rows=st.shape[mode]), want,
            err_msg=f"bucketed mttkrp mode {mode}", **GOLDEN_TOL)
        plan = planner.plan_contraction(
            *_mttkrp_call(st, factors, mode))
        for p in plan.candidates:
            got = planner.planned_mttkrp(st, fs, mode, path=p)
            np.testing.assert_allclose(
                got, want, err_msg=f"mttkrp mode {mode} path {p}",
                **GOLDEN_TOL)


def _mttkrp_call(st, factors, mode):
    letters = "abcdefghij"
    s_term = letters[:st.ndim]
    others = [d for d in range(st.ndim) if d != mode]
    expr = ",".join([s_term] + [s_term[d] + "z" for d in others]) \
        + "->" + s_term[mode] + "z"
    return expr, (st, *[factors[d] for d in others])


@pytest.mark.parametrize("path", GOLDEN_FILES, ids=_ids(GOLDEN_FILES))
def test_golden_tttp_all_paths(path):
    z, st, factors = _load(path)
    want = z["tttp_vals"]
    np.testing.assert_allclose(kops.tttp_values(st, factors), want,
                               err_msg="kernels.ops.tttp", **GOLDEN_TOL)
    letters = "abcdefghij"
    s_term = letters[:st.ndim]
    expr = ",".join([s_term] + [s_term[d] + "z" for d in range(st.ndim)]) \
        + "->" + s_term
    plan = planner.plan_contraction(expr, (st, *factors))
    for p in plan.candidates:
        got = planner.planned_tttp(st, factors, path=p)
        np.testing.assert_allclose(got.values, want,
                                   err_msg=f"tttp path {p}", **GOLDEN_TOL)


@pytest.mark.parametrize("path", GOLDEN_FILES, ids=_ids(GOLDEN_FILES))
def test_golden_cg_matvec_all_paths(path):
    z, st, factors = _load(path)
    want = z["cg_m0"]
    x = jnp.asarray(z["x"])
    got_default = planner.planned_cg_matvec(st, factors, 0, x)
    np.testing.assert_allclose(got_default, want,
                               err_msg="planned_cg_matvec default",
                               **GOLDEN_TOL)
    for p in ("fused", "tttp_mttkrp", "sliced", "dense"):
        got = planner.planned_cg_matvec(st, factors, 0, x, path=p)
        np.testing.assert_allclose(got, want,
                                   err_msg=f"cg_matvec path {p}", **GOLDEN_TOL)
    # the raw fused bucketed kernel (ingest-time view)
    buckets = st.row_buckets(0, block_rows=8)
    fs = [None, *factors[1:]]
    got = kops.cg_matvec_bucketed(buckets, fs, x, num_rows=st.shape[0])
    np.testing.assert_allclose(got, want, err_msg="cg_matvec_bucketed",
                               **GOLDEN_TOL)


# ---------------------------------------------------------------------------
# tile tier (DESIGN.md §13): every lattice candidate must match the goldens
# ---------------------------------------------------------------------------

from repro.planner import tuner  # noqa: E402  (tier tests extend this file)

# §13's documented bf16 bound: bf16 inputs, fp32 accumulation. Measured
# worst case on the golden fixtures is ~0.037 (relative, |w|+1 denominator).
BF16_TOL = dict(rtol=6e-2, atol=6e-2)


@pytest.mark.parametrize("path", GOLDEN_FILES, ids=_ids(GOLDEN_FILES))
def test_golden_tile_lattice_fp32(path):
    """Every autotuner lattice candidate reproduces the goldens to
    GOLDEN_TOL — tile choice moves time, never numerics."""
    z, st, factors = _load(path)
    x = jnp.asarray(z["x"])
    fs = [None, *factors[1:]]
    for tile in tuner.LATTICES["tttp"]:
        np.testing.assert_allclose(
            kops.tttp_values(st, factors, use_pallas=True, tile=tile),
            z["tttp_vals"], err_msg=f"tttp tile {tile.short()}",
            **GOLDEN_TOL)
    for tile in tuner.LATTICES["mttkrp"]:
        buckets = bucketize(st, 0, block_rows=tile.block_rows)
        np.testing.assert_allclose(
            kops.mttkrp_bucketed(buckets, fs, num_rows=st.shape[0],
                                 use_pallas=True, tile=tile),
            z["mttkrp_m0"], err_msg=f"mttkrp tile {tile.short()}",
            **GOLDEN_TOL)
    for tile in tuner.LATTICES["cg_matvec"]:
        buckets = bucketize(st, 0, block_rows=tile.block_rows)
        np.testing.assert_allclose(
            kops.cg_matvec_bucketed(buckets, fs, x, num_rows=st.shape[0],
                                    use_pallas=True, tile=tile),
            z["cg_m0"], err_msg=f"cg_matvec tile {tile.short()}",
            **GOLDEN_TOL)


@pytest.mark.parametrize("path", GOLDEN_FILES, ids=_ids(GOLDEN_FILES))
def test_golden_bf16_within_documented_bound(path):
    """bf16 inputs with fp32 accumulation stay within the §13 bound of the
    float64 references (and return bf16, like the jnp reference path)."""
    z, st, factors = _load(path)
    st16 = st.astype(jnp.bfloat16)
    f16 = [f.astype(jnp.bfloat16) for f in factors]
    got = kops.tttp_values(st16, f16, use_pallas=True)
    assert got.dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(got, np.float32), z["tttp_vals"],
                               err_msg="bf16 tttp", **BF16_TOL)
    buckets = bucketize(st16, 0, block_rows=8)
    fs16 = [None, *f16[1:]]
    got = kops.mttkrp_bucketed(buckets, fs16, num_rows=st.shape[0],
                               use_pallas=True)
    np.testing.assert_allclose(np.asarray(got, np.float32), z["mttkrp_m0"],
                               err_msg="bf16 mttkrp", **BF16_TOL)
    x16 = jnp.asarray(z["x"]).astype(jnp.bfloat16)
    got = kops.cg_matvec_bucketed(buckets, fs16, x16, num_rows=st.shape[0],
                                  use_pallas=True)
    np.testing.assert_allclose(np.asarray(got, np.float32), z["cg_m0"],
                               err_msg="bf16 cg_matvec", **BF16_TOL)
