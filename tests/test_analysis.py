"""Tests for the static-analysis subsystem (``repro.analysis``).

Every lint rule is exercised against a known-bad fixture snippet it must
flag and a known-good twin it must not; the contract checker and pytree
pass are exercised both clean (repo passes) and corrupted (the deliberate
fault hooks must fail the run — the ISSUE acceptance tripwire).
"""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.analysis import contracts, deadcode, lint, pytree_check
from repro.analysis.cli import main as cli_main

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
FIXTURES = os.path.join(REPO, "tests", "analysis_fixtures")
JIT_RULES = {"JS001", "JS002", "JS003", "JS004", "JS005"}


def lint_fixture(name, rules=JIT_RULES):
    return lint.lint_file(os.path.join(FIXTURES, name), rules=rules)


def rules_hit(findings, suppressed=False):
    return {f.rule for f in findings if f.suppressed == suppressed}


# ---------------------------------------------------------------------------
# pass 1: lint rules, bad fixtures vs good twins
# ---------------------------------------------------------------------------

class TestLintRules:
    def test_bad_fixture_hits_every_rule(self):
        assert rules_hit(lint_fixture("bad_lint.py")) == JIT_RULES

    def test_good_twin_is_clean(self):
        assert lint_fixture("good_lint.py") == []

    @pytest.mark.parametrize("snippet,rule", [
        ("def f(x):\n    if jnp.sum(x) > 0:\n        return x\n", "JS001"),
        ("def f(x):\n    while jnp.any(x):\n        x = x * 0.5\n", "JS001"),
        ("def f(x):\n    return x if jnp.any(x) else -x\n", "JS001"),
        ("def f(x):\n    assert jnp.all(x)\n", "JS001"),
        ("def f(x):\n    return jnp.sum(x).item()\n", "JS002"),
        ("def f(x):\n    return float(jnp.sum(x))\n", "JS002"),
        ("def f(x):\n    return int(jax.lax.psum(x, 'd'))\n", "JS002"),
        ("def f(x):\n    return np.asarray(jnp.exp(x))\n", "JS002"),
        ("import time\ndef f(g):\n    t = time.perf_counter()\n    g()\n"
         "    return time.perf_counter() - t\n", "JS003"),
        ("def f(xs):\n    for x in xs:\n        print(x)\n", "JS004"),
        ("def f(xs):\n    for x in xs:\n        logging.info('%s', x)\n",
         "JS004"),
        ("def f():\n    return random.random()\n", "JS005"),
        ("def f():\n    return np.random.rand(3)\n", "JS005"),
        ("def f():\n    return np.random.default_rng()\n", "JS005"),
    ])
    def test_bad_snippet_flagged(self, snippet, rule):
        findings = lint.lint_source(snippet, "snippet.py", rules=JIT_RULES)
        assert rule in rules_hit(findings)

    @pytest.mark.parametrize("snippet", [
        "def f(x):\n    return jnp.where(jnp.sum(x) > 0, x, -x)\n",
        "def f(n, x):\n    if n > 3:\n        return x\n    return -x\n",
        # fence via jax.block_until_ready in the same function
        "import time\ndef f(g):\n    jax.block_until_ready(g())\n"
        "    t = time.perf_counter()\n    jax.block_until_ready(g())\n"
        "    return time.perf_counter() - t\n",
        # fence inside a nested timing closure (planner autotune idiom)
        "import time\ndef f(g):\n"
        "    def run():\n        return jax.block_until_ready(g())\n"
        "    run()\n    t = time.perf_counter()\n    run()\n"
        "    return time.perf_counter() - t\n",
        "def f(xs):\n    print('done', sum(xs))\n",
        "def f():\n    return np.random.default_rng(7).standard_normal(3)\n",
    ])
    def test_good_snippet_clean(self, snippet):
        assert lint.lint_source(snippet, "snippet.py", rules=JIT_RULES) == []

    def test_np_asarray_of_attribute_not_flagged(self):
        # np.asarray(st.indices) is the idiomatic eager fetch of a concrete
        # field — only jnp/jax.lax *calls* inside the argument are flagged
        src = "def f(st):\n    return np.asarray(st.indices)\n"
        assert lint.lint_source(src, "s.py", rules=JIT_RULES) == []


class TestScopes:
    def test_jit_prefixes_get_all_rules(self):
        assert lint.scope_rules("src/repro/planner/dispatch.py") == JIT_RULES
        assert lint.scope_rules("src/repro/kernels/mttkrp.py") == JIT_RULES

    def test_data_layer_exempts_nondeterminism(self):
        rules = lint.scope_rules("src/repro/data/streaming.py")
        assert "JS005" not in rules and "JS003" in rules

    def test_host_layers_keep_timing_and_rng(self):
        assert lint.scope_rules("src/repro/launch/complete.py") == \
            {"JS003", "JS005"}

    def test_trace_module_timing_exempt(self):
        assert "JS003" not in lint.scope_rules("src/repro/obs/trace.py")

    def test_benchmarks_scope(self):
        assert lint.scope_rules("benchmarks/bench_planner.py") == \
            {"JS003", "JS005"}


class TestSuppressions:
    def test_fixture(self):
        findings = lint_fixture("bad_suppress.py", rules={"JS003"})
        blocking = [f for f in findings if not f.suppressed]
        suppressed = [f for f in findings if f.suppressed]
        # reasonless + unknown-rule suppressions each yield a JS000, and the
        # reasonless one does NOT suppress its JS003
        assert {f.rule for f in blocking} == {"JS000", "JS003"}
        assert sum(f.rule == "JS000" for f in blocking) == 2
        assert sum(f.rule == "JS003" for f in blocking) >= 2
        # the valid suppressions took effect, with their reasons recorded
        assert {f.rule for f in suppressed} == {"JS003"}
        assert all(f.reason for f in suppressed)

    def test_comment_only_line_covers_next_line(self):
        src = ("import time\n"
               "def f(g):\n"
               "    # repro-lint: disable=JS003 -- host-only accounting\n"
               "    t = time.perf_counter()\n"
               "    return t\n")
        findings = lint.lint_source(src, "s.py", rules={"JS003"})
        assert findings and all(f.suppressed for f in findings)

    def test_js000_is_never_suppressible(self):
        src = "x = 1  # repro-lint: disable=JS000 -- please\n"
        findings = lint.lint_source(src, "s.py", rules=JIT_RULES)
        assert [f.rule for f in findings if not f.suppressed] == ["JS000"]

    def test_repo_lints_clean_with_reasons(self):
        findings = lint.lint_paths([os.path.join(REPO, "src", "repro"),
                                    os.path.join(REPO, "benchmarks")])
        blocking = [f.format() for f in findings if not f.suppressed]
        assert blocking == []
        assert all(f.reason for f in findings if f.suppressed)


# ---------------------------------------------------------------------------
# pass 2: planner contracts
# ---------------------------------------------------------------------------

class TestContractSweep:
    def test_grid_covers_all_families_and_orders(self):
        cases = contracts.iter_cases()
        fams = {c.family for c in cases}
        assert fams == set(contracts.FAMILIES) and len(fams) == 7
        orders = {len(c.st.shape) for c in cases if c.family == "tttp"}
        assert orders == {3, 4, 5}

    def test_grid_covers_distributed_variants(self):
        cases = contracts.iter_cases(orders=(3,))
        names = {c.name for c in cases}
        assert "tttp/o3/rowsharded" in names
        assert "mttkrp/o3/model" in names
        assert "cg_matvec/o3/data" in names

    def test_path_agreement_order3_clean(self):
        assert contracts.check_path_agreement(
            contracts.iter_cases(orders=(3,))) == []

    def test_fused_cg_path_is_certified_not_fallback(self):
        # the closure-over-concrete-indices design must let the bucketed
        # fused kernel trace (tracer indices would silently fall back)
        case = [c for c in contracts.iter_cases(orders=(3,))
                if c.name == "cg_matvec/o3/local"][0]
        assert case.st.row_buckets(0, case.config.block_rows) is not None
        contracts.path_avals(case, "fused")

    def test_corrupt_path_fails_sweep(self):
        contracts.set_corrupt("all_at_once")
        try:
            findings = contracts.check_path_agreement(
                contracts.iter_cases(orders=(3,), families=("mttkrp",)))
        finally:
            contracts.set_corrupt(None)
        assert findings and all(f.rule == "CT001" for f in findings)

    def test_cost_invariants_clean(self):
        assert contracts.check_cost_invariants(
            contracts.iter_cases(orders=(3, 4))) == []

    def test_cache_keys_clean(self):
        assert contracts.check_cache_keys() == []

    def test_dist_sizes_distinguish_cache_keys(self):
        # PR-3 mesh-aliasing class: same axis names, different sizes
        from repro.core.distributed import AxisCtx
        from repro.planner import ir as pir
        from repro.planner import plan as pplan
        from repro.planner.config import PlannerConfig
        ctx = AxisCtx(data="data")
        k2 = pplan._signature("ijk,jr,kr->ir", (), None, ctx,
                              pir.DistInfo(2, 1, False), PlannerConfig())
        k4 = pplan._signature("ijk,jr,kr->ir", (), None, ctx,
                              pir.DistInfo(4, 1, False), PlannerConfig())
        assert k2 != k4


class TestValidateHook:
    def _operands(self):
        from repro.core.sparse_tensor import SparseTensor
        idx = np.stack([(np.arange(8) * (d + 3)) % s
                        for d, s in enumerate((6, 4, 8))],
                       axis=1).astype(np.int32)
        st = SparseTensor.from_coo(
            idx, np.linspace(0.5, 1.5, 8, dtype=np.float32), (6, 4, 8))
        return [st, np.ones((4, 4), np.float32), np.ones((8, 4), np.float32)]

    def test_validate_clean_plan(self):
        from repro.planner.plan import clear_plan_cache, plan_contraction
        clear_plan_cache()
        plan = plan_contraction("ijk,jr,kr->ir", self._operands(),
                                validate=True)
        assert plan.path in plan.candidates

    def test_validate_raises_on_corruption(self):
        from repro.planner.plan import clear_plan_cache, plan_contraction
        clear_plan_cache()
        contracts.set_corrupt("kr_first")
        try:
            with pytest.raises(contracts.PlanContractError):
                plan_contraction("ijk,jr,kr->ir", self._operands(),
                                 validate=True)
        finally:
            contracts.set_corrupt(None)
            clear_plan_cache()

    def test_certify_candidates_direct(self):
        from repro.planner import cost as pcost
        from repro.planner import ir as pir
        from repro.core.distributed import LOCAL
        from repro.planner.config import default_config
        ops = self._operands()
        ir = pir.build_ir("ijk,jr,kr->ir", ops)
        contracts.certify_candidates(
            ir, [c.path for c in pcost.rank_paths(ir)], ops, LOCAL,
            default_config())


# ---------------------------------------------------------------------------
# pass 3: pytrees and static args
# ---------------------------------------------------------------------------

class TestPytrees:
    def test_repo_pytrees_clean(self):
        src = os.path.join(REPO, "src", "repro")
        assert pytree_check.check_pytrees(src) == []

    def test_every_registered_pytree_has_exemplar(self):
        src = os.path.join(REPO, "src", "repro")
        discovered = {f"{m}.{c}"
                      for m, c in pytree_check.discover_registered(src)}
        assert discovered  # SparseTensor/CCSRView/RowBlockBuckets at least
        assert discovered <= set(pytree_check.EXEMPLARS)

    def test_corrupted_pytrees_detected(self):
        sys.path.insert(0, FIXTURES)
        try:
            import bad_pytree
            per_exemplar = [
                pytree_check.check_exemplar(f"bad[{i}]", ex)
                for i, ex in enumerate(bad_pytree.PYTREE_EXEMPLARS)]
        finally:
            sys.path.remove(FIXTURES)
        # every corrupted exemplar produces at least one PT001 finding
        assert all(fs and all(f.rule == "PT001" for f in fs)
                   for fs in per_exemplar)

    def test_static_args_clean(self):
        assert pytree_check.check_static_args() == []

    def test_static_arg_aliasing_detected(self, monkeypatch):
        import dataclasses

        @dataclasses.dataclass(frozen=True, eq=False)
        class Lossy:
            name: str = "axis"
            size: int = 1

            def __eq__(self, other):   # ignores size: the PR-3 bug shape
                return isinstance(other, Lossy) and self.name == other.name

            def __hash__(self):
                return hash(self.name)

        monkeypatch.setattr(
            pytree_check, "_static_type_grids",
            lambda: [("Lossy", Lossy(), [("size", Lossy(size=2))])])
        findings = pytree_check.check_static_args()
        assert findings and all(f.rule == "PT002" for f in findings)
        assert any("alias" in f.message for f in findings)


# ---------------------------------------------------------------------------
# dead-code report
# ---------------------------------------------------------------------------

class TestDeadcode:
    def test_repo_has_no_unreachable_modules(self):
        rep = deadcode.analyze(REPO)
        assert rep.unreachable == set()

    def test_orphan_module_detected(self, tmp_path):
        pkg = tmp_path / "src" / "repro"
        pkg.mkdir(parents=True)
        (pkg / "__init__.py").write_text("")
        (pkg / "used.py").write_text("import repro\n")
        (pkg / "orphan.py").write_text("X = 1\n")
        rep = deadcode.analyze(str(tmp_path), roots=("repro.used",))
        assert "repro.orphan" in rep.unreachable
        assert "repro.used" in rep.product

    def test_main_modules_are_entry_points(self):
        rep = deadcode.analyze(REPO)
        assert "repro.analysis.__main__" in rep.product

    def test_deleted_seed_zoo_stays_deleted(self):
        rep = deadcode.analyze(REPO)
        assert not any(m.startswith(("repro.models", "repro.configs"))
                       for m in rep.modules)


# ---------------------------------------------------------------------------
# CLI / CI gate
# ---------------------------------------------------------------------------

class TestCli:
    def test_lint_pytrees_deadcode_exit_zero(self, capsys):
        assert cli_main(["--lint", "--pytrees", "--deadcode",
                         "--root", REPO]) == 0
        assert "OK" in capsys.readouterr().out

    def test_contracts_order3_exit_zero(self, capsys):
        assert cli_main(["--contracts", "--orders", "3",
                         "--root", REPO]) == 0

    def test_corrupt_exits_nonzero(self, capsys):
        rc = cli_main(["--contracts", "--orders", "3",
                       "--corrupt", "all_at_once", "--root", REPO])
        assert rc == 1
        assert "CT001" in capsys.readouterr().out
        assert contracts._CORRUPT_PATH is None   # hook reset afterwards

    def test_bad_pytree_module_exits_nonzero(self, capsys):
        sys.path.insert(0, FIXTURES)
        try:
            rc = cli_main(["--pytrees", "--pytree-module", "bad_pytree",
                           "--root", REPO])
        finally:
            sys.path.remove(FIXTURES)
        assert rc == 1
        assert "PT001" in capsys.readouterr().out

    def test_module_entry_point(self):
        env = dict(os.environ,
                   PYTHONPATH=os.path.join(REPO, "src")
                   + os.pathsep + os.environ.get("PYTHONPATH", ""))
        out = subprocess.run(
            [sys.executable, "-m", "repro.analysis", "--deadcode"],
            cwd=REPO, env=env, capture_output=True, text=True, timeout=300)
        assert out.returncode == 0, out.stdout + out.stderr
        assert "OK" in out.stdout

    @pytest.mark.slow
    def test_full_gate_exits_zero(self, capsys):
        assert cli_main(["--all", "--root", REPO]) == 0
