"""Per-kernel validation: Pallas (interpret mode) vs the pure-jnp oracles in
repro.kernels.ref, swept over shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.sparse_tensor import SparseTensor
from repro.kernels import ops as kops
from repro.kernels import ref as kref
from repro.sparse.ccsr import bucketize

SHAPES = [((13, 9, 7), 50), ((64, 32, 16), 500), ((40, 40, 40, 40), 300),
          ((128, 8), 200)]
RANKS = [1, 8, 96]
DTYPES = [jnp.float32, jnp.bfloat16]


def _mk(key, shape, nnz, r, dtype):
    st = SparseTensor.random(key, shape, nnz, cap=nnz + 37, dtype=jnp.float32)
    st = st.astype(dtype)
    ks = jax.random.split(key, len(shape))
    factors = [jax.random.normal(k, (d, r), dtype) for k, d in zip(ks, shape)]
    return st, factors


@pytest.mark.parametrize("shape,nnz", SHAPES)
@pytest.mark.parametrize("r", RANKS)
@pytest.mark.parametrize("dtype", DTYPES)
def test_tttp_kernel_matches_ref(shape, nnz, r, dtype):
    st, factors = _mk(jax.random.PRNGKey(0), shape, nnz, r, dtype)
    got = kops.tttp_values(st, factors, use_pallas=True, block_m=64,
                           block_r=32)
    want = kref.tttp_ref(st.values * st.mask, st.indices, factors)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol * 10)


@pytest.mark.parametrize("shape,nnz", SHAPES[:3])
@pytest.mark.parametrize("r", RANKS)
def test_tttp_partial_factors(shape, nnz, r):
    st, factors = _mk(jax.random.PRNGKey(1), shape, nnz, r, jnp.float32)
    factors[1] = None
    got = kops.tttp_values(st, factors, use_pallas=True, block_m=64,
                           block_r=32)
    want = kref.tttp_ref(st.values * st.mask, st.indices, factors)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("shape,nnz", SHAPES[:3])
@pytest.mark.parametrize("r", [8, 96])
@pytest.mark.parametrize("mode", [0, 1])
def test_mttkrp_kernel_matches_dense_oracle(shape, nnz, r, mode):
    st, factors = _mk(jax.random.PRNGKey(2), shape, nnz, r, jnp.float32)
    bk = bucketize(st, mode, block_rows=8)
    fac = list(factors)
    fac[mode] = None
    got = kops.mttkrp_bucketed(bk, fac, use_pallas=True, block_r=32)
    dense = st.todense()
    letters = "ijkl"[:st.ndim]
    expr = (letters + "," +
            ",".join(f"{letters[d]}r" for d in range(st.ndim) if d != mode)
            + f"->{letters[mode]}r")
    want = jnp.einsum(expr, dense, *[factors[d] for d in range(st.ndim)
                                     if d != mode])
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("shape,nnz", SHAPES[:2])
@pytest.mark.parametrize("r", [4, 32])
def test_cg_matvec_kernel_matches_gram(shape, nnz, r):
    """Fused implicit matvec == explicit Gram matvec (paper eq. 3)."""
    key = jax.random.PRNGKey(3)
    st, factors = _mk(key, shape, nnz, r, jnp.float32)
    omega = st.with_values(jnp.ones_like(st.values))
    bk = bucketize(omega, 0, block_rows=8)
    fac = [None] + factors[1:]
    x = jax.random.normal(key, (shape[0], r))
    got = kops.cg_matvec_bucketed(bk, fac, x, use_pallas=True)
    # explicit G^(i): kr_n = prod of other-mode rows
    kr = jnp.ones((omega.cap, r))
    for d in range(1, st.ndim):
        kr = kr * factors[d][st.indices[:, d]]
    kr = kr * omega.mask[:, None]
    gram = jax.ops.segment_sum(kr[:, :, None] * kr[:, None, :],
                               st.indices[:, 0], num_segments=shape[0])
    want = jnp.einsum("irs,is->ir", gram, x)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_bucket_capacity_validation():
    st = SparseTensor.random(jax.random.PRNGKey(4), (16, 8, 4), 100)
    with pytest.raises(ValueError):
        bucketize(st, 0, block_rows=4, capacity=2)


def test_pallas_vs_jnp_dispatch_agree():
    st, factors = _mk(jax.random.PRNGKey(5), (32, 16, 8), 200, 16,
                      jnp.float32)
    a = kops.tttp_values(st, factors, use_pallas=True, block_m=64, block_r=16)
    b = kops.tttp_values(st, factors, use_pallas=False)
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# tile tier (DESIGN.md §13): KernelTile-parameterized schedules and blocking
# ---------------------------------------------------------------------------

from repro.kernels.tile import KernelTile, onehot_break_even, scatter_rows


def test_scatter_schedules_agree():
    """The segmented-reduction scatter is a drop-in for the one-hot matmul,
    including padding slots (key == block_rows falls off the end)."""
    key = jax.random.PRNGKey(7)
    prod = jax.random.normal(key, (64, 16))
    rows = jnp.sort(jax.random.randint(key, (64,), 0, 9))  # 8 = padding
    a = scatter_rows(prod, rows, 8, "onehot", jnp.float32)
    b = scatter_rows(prod, rows, 8, "segmented", jnp.float32)
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)


def test_break_even_monotone():
    assert onehot_break_even(2048) > onehot_break_even(256) > 0
    assert KernelTile(schedule="auto").resolved_schedule(8, 1024) == "onehot"
    big = onehot_break_even(1024) + 8
    assert KernelTile(schedule="auto").resolved_schedule(big, 1024) \
        == "segmented"


@pytest.mark.parametrize("schedule", ["onehot", "segmented"])
@pytest.mark.parametrize("g", [1, 3])
def test_mttkrp_tile_schedules_match_ref(schedule, g):
    st, factors = _mk(jax.random.PRNGKey(8), (64, 32, 16), 500, 16,
                      jnp.float32)
    bk = bucketize(st, 0, block_rows=8)
    fac = [None] + factors[1:]
    tile = KernelTile(block_m=64, schedule=schedule, buckets_per_step=g)
    got = kops.mttkrp_bucketed(bk, fac, num_rows=64, use_pallas=True,
                               tile=tile)
    want = kops.mttkrp_bucketed(bk, fac, num_rows=64, use_pallas=False)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("schedule", ["onehot", "segmented"])
@pytest.mark.parametrize("g", [1, 2])
def test_cg_matvec_tile_schedules_match_ref(schedule, g):
    key = jax.random.PRNGKey(9)
    st, factors = _mk(key, (64, 32, 16), 500, 16, jnp.float32)
    omega = st.with_values(jnp.ones_like(st.values))
    bk = bucketize(omega, 0, block_rows=8)
    fac = [None] + factors[1:]
    x = jax.random.normal(key, (64, 16))
    tile = KernelTile(block_m=64, schedule=schedule, buckets_per_step=g)
    got = kops.cg_matvec_bucketed(bk, fac, x, num_rows=64, use_pallas=True,
                                  tile=tile)
    want = kops.cg_matvec_bucketed(bk, fac, x, num_rows=64, use_pallas=False)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_capacity_not_multiple_of_block_m():
    """Bucket capacity that doesn't divide the capacity tile gets padded
    inside the pallas wrappers (padding slots carry valid=0)."""
    st, factors = _mk(jax.random.PRNGKey(10), (40, 24, 12), 300, 8,
                      jnp.float32)
    bk = bucketize(st, 0, block_rows=8)
    fac = [None] + factors[1:]
    for bm in (16, 24):
        got = kops.mttkrp_bucketed(bk, fac, num_rows=40, use_pallas=True,
                                   tile=KernelTile(block_m=bm))
        want = kops.mttkrp_bucketed(bk, fac, num_rows=40, use_pallas=False)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4,
                                   err_msg=f"block_m={bm}")


@pytest.mark.parametrize("r", [10, 5])
def test_rank_not_multiple_of_block_r(r):
    """R that doesn't divide block_r: ops pads the factors' rank axis and
    slices the result back."""
    st, factors = _mk(jax.random.PRNGKey(11), (32, 16, 8), 200, r,
                      jnp.float32)
    tile = KernelTile(block_m=64, block_r=32)
    got = kops.tttp_values(st, factors, use_pallas=True, tile=tile)
    want = kref.tttp_ref(st.values * st.mask, st.indices, factors)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)
    bk = bucketize(st, 0, block_rows=8)
    fac = [None] + factors[1:]
    got = kops.mttkrp_bucketed(bk, fac, num_rows=32, use_pallas=True,
                               tile=tile)
    want = kops.mttkrp_bucketed(bk, fac, num_rows=32, use_pallas=False)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_single_factor_mttkrp_matrix_case():
    """2-D tensor: the Hadamard chain degenerates to ONE other factor."""
    st, factors = _mk(jax.random.PRNGKey(12), (128, 8), 200, 8, jnp.float32)
    bk = bucketize(st, 0, block_rows=8)
    fac = [None, factors[1]]
    got = kops.mttkrp_bucketed(bk, fac, num_rows=128, use_pallas=True)
    dense = st.todense()
    want = jnp.einsum("ij,jr->ir", dense, factors[1])
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


# §13's documented bf16 bound: bf16 Hadamard chain, fp32 MXU accumulation
BF16_TOL = dict(rtol=6e-2, atol=6e-2)


def test_mttkrp_bf16_accumulates_fp32():
    st, factors = _mk(jax.random.PRNGKey(13), (64, 32, 16), 500, 16,
                      jnp.bfloat16)
    bk = bucketize(st, 0, block_rows=8)
    fac = [None] + factors[1:]
    got = kops.mttkrp_bucketed(bk, fac, num_rows=64, use_pallas=True)
    assert got.dtype == jnp.bfloat16
    f32 = [None] + [f.astype(jnp.float32) for f in factors[1:]]
    bk32 = bucketize(st.astype(jnp.float32), 0, block_rows=8)
    want = kops.mttkrp_bucketed(bk32, f32, num_rows=64, use_pallas=False)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **BF16_TOL)


def test_cg_matvec_bf16_accumulates_fp32():
    key = jax.random.PRNGKey(14)
    st, factors = _mk(key, (64, 32, 16), 500, 16, jnp.bfloat16)
    omega = st.with_values(jnp.ones_like(st.values))
    bk = bucketize(omega, 0, block_rows=8)
    fac = [None] + factors[1:]
    x = jax.random.normal(key, (64, 16), jnp.bfloat16)
    got = kops.cg_matvec_bucketed(bk, fac, x, num_rows=64, use_pallas=True)
    assert got.dtype == jnp.bfloat16
    f32 = [None] + [f.astype(jnp.float32) for f in factors[1:]]
    bk32 = bucketize(omega.astype(jnp.float32), 0, block_rows=8)
    want = kops.cg_matvec_bucketed(bk32, f32, x.astype(jnp.float32),
                                   num_rows=64, use_pallas=False)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **BF16_TOL)
