"""Per-kernel validation: Pallas (interpret mode) vs the pure-jnp oracles in
repro.kernels.ref, swept over shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.sparse_tensor import SparseTensor
from repro.kernels import ops as kops
from repro.kernels import ref as kref
from repro.sparse.ccsr import bucketize

SHAPES = [((13, 9, 7), 50), ((64, 32, 16), 500), ((40, 40, 40, 40), 300),
          ((128, 8), 200)]
RANKS = [1, 8, 96]
DTYPES = [jnp.float32, jnp.bfloat16]


def _mk(key, shape, nnz, r, dtype):
    st = SparseTensor.random(key, shape, nnz, cap=nnz + 37, dtype=jnp.float32)
    st = st.astype(dtype)
    ks = jax.random.split(key, len(shape))
    factors = [jax.random.normal(k, (d, r), dtype) for k, d in zip(ks, shape)]
    return st, factors


@pytest.mark.parametrize("shape,nnz", SHAPES)
@pytest.mark.parametrize("r", RANKS)
@pytest.mark.parametrize("dtype", DTYPES)
def test_tttp_kernel_matches_ref(shape, nnz, r, dtype):
    st, factors = _mk(jax.random.PRNGKey(0), shape, nnz, r, dtype)
    got = kops.tttp_values(st, factors, use_pallas=True, block_m=64,
                           block_r=32)
    want = kref.tttp_ref(st.values * st.mask, st.indices, factors)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol * 10)


@pytest.mark.parametrize("shape,nnz", SHAPES[:3])
@pytest.mark.parametrize("r", RANKS)
def test_tttp_partial_factors(shape, nnz, r):
    st, factors = _mk(jax.random.PRNGKey(1), shape, nnz, r, jnp.float32)
    factors[1] = None
    got = kops.tttp_values(st, factors, use_pallas=True, block_m=64,
                           block_r=32)
    want = kref.tttp_ref(st.values * st.mask, st.indices, factors)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("shape,nnz", SHAPES[:3])
@pytest.mark.parametrize("r", [8, 96])
@pytest.mark.parametrize("mode", [0, 1])
def test_mttkrp_kernel_matches_dense_oracle(shape, nnz, r, mode):
    st, factors = _mk(jax.random.PRNGKey(2), shape, nnz, r, jnp.float32)
    bk = bucketize(st, mode, block_rows=8)
    fac = list(factors)
    fac[mode] = None
    got = kops.mttkrp_bucketed(bk, fac, use_pallas=True, block_r=32)
    dense = st.todense()
    letters = "ijkl"[:st.ndim]
    expr = (letters + "," +
            ",".join(f"{letters[d]}r" for d in range(st.ndim) if d != mode)
            + f"->{letters[mode]}r")
    want = jnp.einsum(expr, dense, *[factors[d] for d in range(st.ndim)
                                     if d != mode])
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("shape,nnz", SHAPES[:2])
@pytest.mark.parametrize("r", [4, 32])
def test_cg_matvec_kernel_matches_gram(shape, nnz, r):
    """Fused implicit matvec == explicit Gram matvec (paper eq. 3)."""
    key = jax.random.PRNGKey(3)
    st, factors = _mk(key, shape, nnz, r, jnp.float32)
    omega = st.with_values(jnp.ones_like(st.values))
    bk = bucketize(omega, 0, block_rows=8)
    fac = [None] + factors[1:]
    x = jax.random.normal(key, (shape[0], r))
    got = kops.cg_matvec_bucketed(bk, fac, x, use_pallas=True)
    # explicit G^(i): kr_n = prod of other-mode rows
    kr = jnp.ones((omega.cap, r))
    for d in range(1, st.ndim):
        kr = kr * factors[d][st.indices[:, d]]
    kr = kr * omega.mask[:, None]
    gram = jax.ops.segment_sum(kr[:, :, None] * kr[:, None, :],
                               st.indices[:, 0], num_segments=shape[0])
    want = jnp.einsum("irs,is->ir", gram, x)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_bucket_capacity_validation():
    st = SparseTensor.random(jax.random.PRNGKey(4), (16, 8, 4), 100)
    with pytest.raises(ValueError):
        bucketize(st, 0, block_rows=4, capacity=2)


def test_pallas_vs_jnp_dispatch_agree():
    st, factors = _mk(jax.random.PRNGKey(5), (32, 16, 8), 200, 16,
                      jnp.float32)
    a = kops.tttp_values(st, factors, use_pallas=True, block_m=64, block_r=16)
    b = kops.tttp_values(st, factors, use_pallas=False)
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)
