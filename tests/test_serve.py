"""Serving layer (repro.serve, DESIGN.md §14): batched scoring parity vs
the training kernels across every planner path, fold-in vs a fresh
explicit one-row ALS solve, streaming top-k vs a full sort, engine
padding/bucketing invariants, and checkpoint/npz restore — plus the
end-to-end fit → dump → serve CLI under ``--verify`` (slow)."""
import json
import os
import subprocess
import sys

import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint as ckpt
from repro.core.sparse_tensor import SparseTensor
from repro.core.tttp import multilinear_values
from repro.serve import (ServeEngine, ServingModel, apply_link, fold_in,
                         fold_in_single, load_factors, pack_histories,
                         query_rows, topk_over_mode)

SHAPE = (30, 24, 10)
RANK = 6


def _factors(seed=0, shape=SHAPE, rank=RANK):
    rng = np.random.default_rng(seed)
    return [jnp.asarray(rng.standard_normal((s, rank)).astype(np.float32)
                        / np.sqrt(rank)) for s in shape]


def _queries(rng, n, shape=SHAPE):
    return np.stack([rng.integers(0, s, size=n) for s in shape],
                    axis=1).astype(np.int32)


def _ref_scores(factors, idx, link="identity"):
    st = SparseTensor.from_coo(idx, np.ones(idx.shape[0], np.float32), SHAPE)
    m = multilinear_values(st, list(factors))
    return np.asarray(apply_link(m, link))[:idx.shape[0]]


def _histories(rng, mode, users, nnz, shape=SHAPE):
    others = [d for d in range(len(shape)) if d != mode]
    return [(np.stack([rng.integers(0, shape[d], size=nnz) for d in others],
                      axis=1).astype(np.int32),
             rng.standard_normal(nnz).astype(np.float32))
            for _ in range(users)]


def _explicit_rows(factors, histories, mode, lam):
    """Fresh one-row ALS by explicit Gram assembly (the reference the
    batched CG path must reproduce)."""
    fs = [np.asarray(f) for f in factors]
    others = [d for d in range(len(fs)) if d != mode]
    rows = []
    for oidx, vals in histories:
        kr = fs[others[0]][oidx[:, 0]]
        for c, d in enumerate(others[1:], start=1):
            kr = kr * fs[d][oidx[:, c]]
        gram = kr.T @ kr + lam * np.eye(kr.shape[1], dtype=kr.dtype)
        rows.append(np.linalg.solve(gram, kr.T @ vals))
    return np.stack(rows)


# ---------------------------------------------------------------------------
# entry scoring: engine == multilinear_values across every dispatch path
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("path",
                         [None, "all_at_once", "sliced", "pairwise", "dense"])
def test_score_matches_multilinear_values(path):
    model = ServingModel(_factors())
    engine = ServeEngine(model, max_batch=64, min_batch=8, score_path=path)
    idx = _queries(np.random.default_rng(1), 200)   # > max_batch: chunks
    got = engine.score(idx)
    np.testing.assert_allclose(got, _ref_scores(model.factors, idx),
                               rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("n", [1, 7, 63, 64, 65, 130])
def test_score_padding_buckets(n):
    """Every batch size — including bucket boundaries and chunk tails —
    returns exactly n untainted scores."""
    model = ServingModel(_factors(2))
    engine = ServeEngine(model, max_batch=64, min_batch=8)
    idx = _queries(np.random.default_rng(n), n)
    got = engine.score(idx)
    assert got.shape == (n,)
    np.testing.assert_allclose(got, _ref_scores(model.factors, idx),
                               rtol=1e-6, atol=1e-6)


def test_score_log_link_and_raw():
    model = ServingModel(_factors(3), link="log")
    engine = ServeEngine(model, max_batch=32, min_batch=8)
    idx = _queries(np.random.default_rng(5), 50)
    np.testing.assert_allclose(
        engine.score(idx), _ref_scores(model.factors, idx, link="log"),
        rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(                       # link=False: model space
        engine.score(idx, link=False), _ref_scores(model.factors, idx),
        rtol=1e-6, atol=1e-6)


def test_score_rejects_bad_shape():
    engine = ServeEngine(ServingModel(_factors()))
    with pytest.raises(ValueError, match="indices"):
        engine.score(np.zeros((5, 2), np.int32))      # ndim is 3


# ---------------------------------------------------------------------------
# fold-in: batched CG on the eq.-3 Gram matvec == explicit fresh solve
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("matvec_path",
                         [None, "tttp_mttkrp", "sliced", "dense"])
def test_fold_in_matches_explicit_solve(matvec_path):
    fs = _factors(7)
    rng = np.random.default_rng(7)
    lam = 1e-2
    hists = _histories(rng, mode=0, users=9, nnz=12)
    st = pack_histories(hists, SHAPE, mode=0)
    rows, iters = fold_in(st, fs, mode=0, lam=lam, matvec_path=matvec_path)
    ref = _explicit_rows(fs, hists, mode=0, lam=lam)
    assert int(iters) <= 4 * RANK
    np.testing.assert_allclose(np.asarray(rows), ref, rtol=1e-4, atol=1e-4)


def test_fold_in_nonzero_mode_and_single():
    fs = _factors(8)
    rng = np.random.default_rng(8)
    hists = _histories(rng, mode=1, users=5, nnz=10)
    st = pack_histories(hists, SHAPE, mode=1)
    rows, _ = fold_in(st, fs, mode=1, lam=5e-2)
    ref = _explicit_rows(fs, hists, mode=1, lam=5e-2)
    np.testing.assert_allclose(np.asarray(rows), ref, rtol=1e-4, atol=1e-4)
    # single-user wrapper == the corresponding batched row
    row0 = fold_in_single(fs, 1, hists[0][0], hists[0][1], SHAPE, lam=5e-2)
    np.testing.assert_allclose(np.asarray(row0), ref[0], rtol=1e-4,
                               atol=1e-4)


def test_fold_in_engine_endpoint():
    model = ServingModel(_factors(9))
    engine = ServeEngine(model, min_batch=8, foldin_lam=1e-2)
    rng = np.random.default_rng(9)
    hists = _histories(rng, mode=0, users=6, nnz=8)
    rows = engine.fold_in(hists, 0)
    ref = _explicit_rows(model.factors, hists, mode=0, lam=1e-2)
    assert rows.shape == (6, RANK)
    np.testing.assert_allclose(rows, ref, rtol=1e-4, atol=1e-4)


def test_pack_histories_bounds_check():
    bad = [(np.array([[99, 0]], np.int32), np.ones(1, np.float32))]
    with pytest.raises(ValueError, match="out of range"):
        pack_histories(bad, SHAPE, mode=0)    # mode-1 extent is 24 < 99


# ---------------------------------------------------------------------------
# top-k: streaming blocked merge == full sort, non-divisible blocks
# ---------------------------------------------------------------------------

def test_topk_matches_full_sort_nondivisible():
    rng = np.random.default_rng(11)
    j, r, b, k = 37, 5, 4, 6                 # 37 % block(8) != 0
    vf = jnp.asarray(rng.standard_normal((j, r)).astype(np.float32))
    q = jnp.asarray(rng.standard_normal((b, r)).astype(np.float32))
    vals, idx = topk_over_mode(vf, q, k, block_rows=8)
    full = np.asarray(q) @ np.asarray(vf).T             # (B, J)
    ref_idx = np.argsort(-full, axis=1)[:, :k]
    np.testing.assert_array_equal(np.asarray(idx), ref_idx)
    np.testing.assert_allclose(np.asarray(vals),
                               np.take_along_axis(full, ref_idx, axis=1),
                               rtol=1e-5, atol=1e-6)
    # scores descending per row
    assert np.all(np.diff(np.asarray(vals), axis=1) <= 1e-6)


def test_topk_k_clamped_and_log_link():
    rng = np.random.default_rng(12)
    vf = jnp.asarray(rng.standard_normal((9, 4)).astype(np.float32))
    q = jnp.asarray(rng.standard_normal((3, 4)).astype(np.float32))
    vals, idx = topk_over_mode(vf, q, 50, block_rows=4, link="log")
    assert vals.shape == (3, 9)              # k clamped to J
    full = np.asarray(q) @ np.asarray(vf).T
    ref_idx = np.argsort(-full, axis=1)      # monotone link: same winners
    np.testing.assert_array_equal(np.asarray(idx), ref_idx)
    np.testing.assert_allclose(np.asarray(vals),
                               np.exp(np.take_along_axis(full, ref_idx, 1)),
                               rtol=1e-5)


def test_engine_topk_with_foldin_rows():
    """Retrieval for brand-new users: fixed mode given as explicit (B, R)
    fold-in rows instead of indices into a frozen factor."""
    model = ServingModel(_factors(13))
    engine = ServeEngine(model, topk_block=8)
    rng = np.random.default_rng(13)
    b, k = 4, 5
    rows = rng.standard_normal((b, RANK)).astype(np.float32)
    kidx = rng.integers(0, SHAPE[2], size=b)
    vals, idx = engine.top_k({0: rows, 2: kidx}, target_mode=1, k=k)
    q = rows * np.asarray(model.factors[2])[kidx]
    full = q @ np.asarray(model.factors[1]).T
    ref_idx = np.argsort(-full, axis=1)[:, :k]
    np.testing.assert_array_equal(idx, ref_idx)
    np.testing.assert_allclose(vals, np.take_along_axis(full, ref_idx, 1),
                               rtol=1e-5, atol=1e-6)


def test_engine_topk_rejects_fixed_target_and_ragged_batch():
    engine = ServeEngine(ServingModel(_factors()))
    with pytest.raises(ValueError, match="cannot be fixed"):
        engine.top_k({0: np.zeros(2, np.int32), 1: np.zeros(2, np.int32)},
                     target_mode=1, k=3)
    with pytest.raises(ValueError, match="disagree"):
        engine.top_k({0: np.zeros(2, np.int32), 2: np.zeros(3, np.int32)},
                     target_mode=1, k=3)


def test_query_rows_needs_a_fixed_mode():
    with pytest.raises(ValueError, match="fixed mode"):
        query_rows(_factors(), {})


# ---------------------------------------------------------------------------
# restore: checkpoint directory and legacy npz
# ---------------------------------------------------------------------------

def test_load_factors_checkpoint_roundtrip(tmp_path):
    fs = _factors(21)
    ckpt.save(str(tmp_path), 4,
              {f"factor_{d}": f for d, f in enumerate(fs)},
              metadata={"link": "log", "rank": RANK})
    model = load_factors(str(tmp_path))
    assert model.shape == SHAPE and model.rank == RANK
    assert model.link == "log"               # resolved from metadata
    for a, b in zip(model.factors, fs):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # explicit link overrides metadata
    assert load_factors(str(tmp_path), link="identity").link == "identity"


def test_load_factors_npz(tmp_path):
    fs = _factors(22)
    path = tmp_path / "factors.npz"
    np.savez(path, **{f"factor_{d}": np.asarray(f)
                      for d, f in enumerate(fs)})
    model = load_factors(str(path))
    assert model.link == "identity" and model.shape == SHAPE
    idx = _queries(np.random.default_rng(2), 20)
    np.testing.assert_allclose(np.asarray(model.predict(jnp.asarray(idx))),
                               _ref_scores(fs, idx), rtol=1e-6, atol=1e-6)


def test_load_factors_rejects_non_factor_checkpoint(tmp_path):
    ckpt.save(str(tmp_path), 1, {"weights": jnp.ones((3, 2))})
    with pytest.raises(ValueError, match="not a factor checkpoint"):
        load_factors(str(tmp_path))


def test_serving_model_validation():
    with pytest.raises(ValueError, match="rank"):
        ServingModel([jnp.ones((3, 2)), jnp.ones((4, 5))])
    with pytest.raises(ValueError, match="link"):
        ServingModel([jnp.ones((3, 2))], link="probit")


# ---------------------------------------------------------------------------
# end-to-end: fit -> checkpoint dump -> fresh-process serve --verify
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_cli_fit_dump_serve_verify(tmp_path):
    env = dict(os.environ)
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    cwd = os.path.dirname(os.path.dirname(__file__))
    ckdir = str(tmp_path / "ck")
    fit = subprocess.run(
        [sys.executable, "-m", "repro.launch.complete", "--dataset",
         "function", "--dims", "24,20,16", "--nnz", "3000", "--rank", "4",
         "--sweeps", "2", "--algorithm", "als", "--dump-factors", ckdir],
        env=env, cwd=cwd, capture_output=True, text=True, timeout=900)
    assert fit.returncode == 0, fit.stdout + "\n---\n" + fit.stderr
    report = str(tmp_path / "report.json")
    srv = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve_complete", "--factors",
         ckdir, "--num-queries", "1000", "--batch-size", "128", "--topk",
         "5", "--foldin-users", "4", "--verify", "--json", report],
        env=env, cwd=cwd, capture_output=True, text=True, timeout=900)
    assert srv.returncode == 0, srv.stdout + "\n---\n" + srv.stderr
    assert "verify OK" in srv.stdout
    with open(report) as f:
        rep = json.load(f)
    assert rep["rank"] == 4 and rep["score"]["qps"] > 0
    assert {"p50_us", "p99_us"} <= set(rep["score"])
