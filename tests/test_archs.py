"""Per-architecture smoke tests (deliverable f): reduced config of the same
family, one forward/train step on CPU, output shapes + no NaNs; plus
decode-vs-forward consistency for the cache paths."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import base as cfgs
from repro.models import model as M

B, S = 2, 24


def _batch(cfg, key):
    b = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab),
         "labels": jax.random.randint(key, (B, S), 0, cfg.vocab)}
    if cfg.frontend == "frames":
        b["frames"] = 0.02 * jax.random.normal(key, (B, S, cfg.d_model))
    if cfg.frontend == "patch":
        b["patch_embeds"] = 0.02 * jax.random.normal(
            key, (B, cfg.num_patches, cfg.d_model))
    return b


@pytest.mark.parametrize("name", cfgs.names())
def test_smoke_train_step(name):
    cfg = cfgs.get_smoke(name)
    key = jax.random.PRNGKey(0)
    params = M.init_params(key, cfg)
    batch = _batch(cfg, key)
    loss, grads = jax.jit(jax.value_and_grad(
        lambda p: M.loss_fn(p, cfg, batch)))(params)
    assert jnp.isfinite(loss)
    gn = sum(jnp.sum(jnp.square(g)) for g in jax.tree.leaves(grads))
    assert jnp.isfinite(gn)
    logits = M.forward(params, cfg, batch, remat=False)
    assert logits.shape == (B, batch["tokens"].shape[1], cfg.vocab)
    assert bool(jnp.isfinite(logits).all())


@pytest.mark.parametrize("name", cfgs.names())
def test_smoke_decode_shapes(name):
    cfg = cfgs.get_smoke(name)
    key = jax.random.PRNGKey(1)
    params = M.init_params(key, cfg)
    batch = _batch(cfg, key)
    enc = M.encode(params, cfg, batch["frames"]) if cfg.encoder_layers else None
    caches = M.cache_init(cfg, B, max_len=S)
    tok = batch["tokens"][:, :1]
    for i in range(3):
        pos = jnp.full((B, 1), i, jnp.int32)
        logits, caches = M.decode_step(params, cfg, tok, pos, caches, enc)
        assert logits.shape == (B, 1, cfg.vocab)
        assert bool(jnp.isfinite(logits).all())
        tok = jnp.argmax(logits, -1)


@pytest.mark.parametrize("name", [n for n in cfgs.names()
                                  if cfgs.get_smoke(n).frontend != "patch"])
def test_decode_matches_forward(name):
    cfg = cfgs.get_smoke(name)
    if cfg.n_experts:  # capacity-drop semantics differ; lift the cap
        cfg = dataclasses.replace(cfg, capacity_factor=100.0)
    key = jax.random.PRNGKey(2)
    params = M.init_params(key, cfg)
    batch = _batch(cfg, key)
    toks = batch["tokens"]
    full = M.forward(params, cfg, batch, remat=False)
    enc = M.encode(params, cfg, batch["frames"]) if cfg.encoder_layers else None
    caches = M.cache_init(cfg, B, max_len=S)
    outs = []
    for i in range(S):
        pos = jnp.full((B, 1), i, jnp.int32)
        logits, caches = M.decode_step(params, cfg, toks[:, i:i + 1], pos,
                                       caches, enc)
        outs.append(logits)
    dec = jnp.concatenate(outs, 1)
    scale = float(jnp.max(jnp.abs(full))) + 1e-9
    assert float(jnp.max(jnp.abs(full - dec))) / scale < 2e-2


def test_prefill_logits_match_forward_last():
    cfg = cfgs.get_smoke("qwen2-72b")
    key = jax.random.PRNGKey(3)
    params = M.init_params(key, cfg)
    batch = _batch(cfg, key)
    a = M.prefill_logits(params, cfg, batch)
    b = M.forward(params, cfg, batch, remat=False)[:, -1]
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)


def test_full_configs_match_assignment():
    """The registered full configs carry the exact assigned hyperparameters."""
    expect = {
        "phi3.5-moe-42b-a6.6b": (32, 4096, 32, 8, 6400, 32064),
        "llama4-scout-17b-a16e": (48, 5120, 40, 8, 8192, 202048),
        "xlstm-125m": (12, 768, 4, 4, 0, 50304),
        "whisper-base": (6, 512, 8, 8, 2048, 51865),
        "zamba2-2.7b": (54, 2560, 32, 32, 10240, 32000),
        "minicpm3-4b": (62, 2560, 40, 40, 6400, 73448),
        "qwen2-72b": (80, 8192, 64, 8, 29568, 152064),
        "gemma2-2b": (26, 2304, 8, 4, 9216, 256000),
        "gemma2-27b": (46, 4608, 32, 16, 36864, 256000),
        "phi-3-vision-4.2b": (32, 3072, 32, 32, 8192, 32064),
    }
    for name, (l, d, h, kv, ff, v) in expect.items():
        c = cfgs.get(name)
        assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
                c.vocab) == (l, d, h, kv, ff, v), name
    assert cfgs.get("phi3.5-moe-42b-a6.6b").n_experts == 16
    assert cfgs.get("phi3.5-moe-42b-a6.6b").top_k == 2
    assert cfgs.get("llama4-scout-17b-a16e").top_k == 1
    assert cfgs.get("zamba2-2.7b").ssm_state == 64
    assert cfgs.get("gemma2-2b").attn_softcap == 50.0


def test_ssd_matches_recurrent_reference():
    """Chunked SSD == step-by-step recurrence (mamba2 correctness)."""
    from repro.configs.base import ArchConfig, BlockSpec
    from repro.models import ssm
    cfg = cfgs.get_smoke("zamba2-2.7b")
    key = jax.random.PRNGKey(4)
    p = ssm.init_mamba2_params(key, cfg)
    x = 0.5 * jax.random.normal(key, (2, 12, cfg.d_model))
    full = ssm.mamba2_forward(p, cfg, x)
    cache = ssm.mamba2_cache_init(cfg, 2)
    outs = []
    for t in range(12):
        y, cache = ssm.mamba2_decode(p, cfg, x[:, t:t + 1], cache)
        outs.append(y)
    step = jnp.concatenate(outs, 1)
    np.testing.assert_allclose(full, step, rtol=2e-3, atol=2e-3)


def test_moe_capacity_drop_and_combine():
    """MoE: with ample capacity, output == dense mixture of expert FFNs."""
    from repro.models import moe
    cfg = dataclasses.replace(cfgs.get_smoke("phi3.5-moe-42b-a6.6b"),
                              capacity_factor=100.0)
    key = jax.random.PRNGKey(5)
    p = moe.init_moe_params(key, cfg)
    x = jax.random.normal(key, (2, 8, cfg.d_model))
    got = moe.moe_forward(p, cfg, x)
    # dense reference: evaluate every expert on every token, combine by gate
    logits = x @ p["router"]
    gates = jax.nn.softmax(logits.astype(jnp.float32), -1)
    topw, tope = jax.lax.top_k(gates, cfg.top_k)
    topw = topw / topw.sum(-1, keepdims=True)
    h = jax.nn.silu(jnp.einsum("bsd,edf->besf", x, p["w_gate"])) * \
        jnp.einsum("bsd,edf->besf", x, p["w_lin"])
    every = jnp.einsum("besf,efd->besd", h, p["w_out"])
    combine = jnp.zeros_like(gates)
    for k in range(cfg.top_k):
        combine = combine + topw[..., k:k + 1] * \
            jax.nn.one_hot(tope[..., k], cfg.n_experts)
    want = jnp.einsum("bse,besd->bsd", combine.astype(x.dtype), every)
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


def test_mlstm_chunked_matches_recurrent():
    """§Perf optimization: chunkwise-parallel mLSTM == recurrent reference."""
    from repro.models import xlstm as X
    cfg = cfgs.get_smoke("xlstm-125m")
    key = jax.random.PRNGKey(6)
    p = X.init_mlstm_params(key, cfg)
    for seq, chunk in [(48, 8), (64, 16)]:
        x = 0.5 * jax.random.normal(key, (2, seq, cfg.d_model))
        ref = X.mlstm_forward(p, cfg, x)
        got = X.mlstm_forward(
            p, dataclasses.replace(cfg, xlstm_chunk=chunk), x)
        np.testing.assert_allclose(got, ref, rtol=2e-4, atol=2e-4)
