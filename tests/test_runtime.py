"""Checkpoint/restart, elastic resharding, straggler watchdog, data
pipeline, and the NumPy-style facade."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core.api as ctf
from repro.checkpoint.checkpointer import Checkpointer, latest_step, restore, save
from repro.core.sparse_tensor import SparseTensor
from repro.data import synthetic
from repro.runtime.elastic import replan_sparse
from repro.runtime.fault_tolerance import RestartableLoop, StepWatchdog


def test_checkpoint_roundtrip(tmp_path):
    state = {"a": jnp.arange(12.0).reshape(3, 4),
             "b": [jnp.ones((2,)), jnp.zeros((5,), jnp.int32)]}
    save(str(tmp_path), 7, state, metadata={"note": "x"})
    assert latest_step(str(tmp_path)) == 7
    like = jax.tree.map(jnp.zeros_like, state)
    got, manifest = restore(str(tmp_path), 7, like)
    assert manifest["metadata"]["note"] == "x"
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(got)):
        np.testing.assert_allclose(a, b)


def test_checkpoint_gc_keeps_last(tmp_path):
    for s in range(6):
        save(str(tmp_path), s, {"x": jnp.ones(3) * s}, keep_last=2)
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(tmp_path)
                   if d.startswith("step_"))
    assert steps == [4, 5]


def test_restart_resume_equivalence(tmp_path):
    """Run with injected failure, restart, final state == uninterrupted."""
    def step(i, state):
        return state + (i + 1)

    loop = RestartableLoop(str(tmp_path / "a"), step, ckpt_every=3)
    state = loop.run(jnp.zeros(2), 10)

    loop2 = RestartableLoop(str(tmp_path / "b"), step, ckpt_every=3)
    with pytest.raises(RuntimeError):
        loop2.run(jnp.zeros(2), 10, fail_at=5)
    loop3 = RestartableLoop(str(tmp_path / "b"), step, ckpt_every=3)
    state2 = loop3.run(jnp.zeros(2), 10)
    np.testing.assert_allclose(state, state2)


def test_corrupt_checkpoint_fallback(tmp_path):
    def step(i, state):
        return state + 1

    loop = RestartableLoop(str(tmp_path), step, ckpt_every=2, keep_last=5)
    with pytest.raises(RuntimeError):
        loop.run(jnp.zeros(1), 10, fail_at=7)
    # corrupt the newest checkpoint's arrays
    newest = max(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    for f in os.listdir(os.path.join(tmp_path, newest)):
        if f.endswith(".npy"):
            os.remove(os.path.join(tmp_path, newest, f))
    loop2 = RestartableLoop(str(tmp_path), step, ckpt_every=2, keep_last=5)
    state = loop2.run(jnp.zeros(1), 10)
    assert float(state[0]) == 10.0


def test_async_checkpointer(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save_async(3, {"w": jnp.ones((4, 4))})
    ck.wait()
    assert ck.latest() == 3


def test_watchdog_flags_stragglers():
    wd = StepWatchdog(threshold=2.0, warmup=3)
    for i in range(8):
        wd.observe(0.1, i)
    wd.observe(1.0, 8)
    assert wd.events and wd.events[-1][0] == 8


def test_elastic_replan_preserves_data():
    key = jax.random.PRNGKey(0)
    st = SparseTensor.random(key, (30, 20, 10), 500)
    total = float(st.sum())
    for shards in (1, 2, 4):
        re = replan_sparse(st, key, None)
        assert abs(float(re.sum()) - total) < 1e-3
        assert int(jnp.sum(re.valid)) == 500


def test_shuffle_and_pad_balances(tmp_path):
    key = jax.random.PRNGKey(1)
    st = SparseTensor.random(key, (64, 64), 1000)
    out = synthetic.shuffle_and_pad(st, key, 8)
    assert out.cap % 8 == 0
    per = np.asarray(out.valid).reshape(8, -1).sum(1)
    assert per.std() < per.mean() * 0.2  # padding spread evenly


def test_function_tensor_low_rank():
    """Karlsson model problem really is low-rank: ALS rank 6 fits well."""
    from repro.core.completion import als_sweep
    key = jax.random.PRNGKey(2)
    st = synthetic.function_tensor(key, (40, 40, 40), 6000)
    omega = st.with_values(jnp.ones_like(st.values))
    fs = [jax.random.normal(jax.random.fold_in(key, d), (40, 6)) * 0.4
          for d in range(3)]
    sweep = jax.jit(lambda s, o, a, b, c: als_sweep(s, o, [a, b, c], 1e-6,
                                                    cg_iters=12))
    for _ in range(12):
        fs = sweep(st, omega, *fs)
    from repro.core.tttp import multilinear_values
    model = multilinear_values(st, fs)
    resid = (st.values - model) * st.mask
    rmse = float(jnp.sqrt(jnp.sum(resid ** 2) / jnp.sum(st.mask)))
    assert rmse < 0.02


def test_netflix_like_statistics():
    st = synthetic.netflix_like(jax.random.PRNGKey(3),
                                (1000, 500, 50), nnz=20000)
    vals = np.asarray(st.masked_values())[np.asarray(st.valid)]
    assert vals.min() >= 1.0 and vals.max() <= 5.0
    assert 2.0 < vals.mean() < 5.0


def test_api_facade_listings():
    """The paper's Listings 1–3 surface works."""
    key = jax.random.PRNGKey(4)
    T = ctf.random_sparse((12, 10, 8), 100, key)
    U = jnp.ones((12, 4))
    V = jnp.ones((10, 4))
    W = jnp.ones((8, 4))
    S = ctf.TTTP(T, [U, V, W])                      # Listing 3
    np.testing.assert_allclose(S.masked_values(),
                               4.0 * T.masked_values(), rtol=1e-6)
    S2 = ctf.TTTP(T, [U, None, W])
    np.testing.assert_allclose(S2.masked_values(),
                               4.0 * T.masked_values(), rtol=1e-6)
    y = ctf.einsum("ijk,jr,kr->ir", T, V, W)        # MTTKRP
    assert y.shape == (12, 4)
    a = ctf.einsum("ijk->i", S)                     # sparse reduction
    assert a.shape == (12,)
    dense = ctf.einsum("ijk,kr->ijr", T, W)         # TTM
    assert dense.shape == (12, 10, 4)


def test_compression_error_feedback_converges():
    """EF-int8: accumulated compressed sums track the true sums."""
    from repro.optim.compression import compressed_psum
    # single-device psum over trivial axis via vmap-style emulation is
    # covered in the distributed subprocess test; here check quantizer error
    # feedback: repeated compression of a constant recovers it on average.
    import jax
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    g = jnp.array([1.234e-3] * 64)
    err = jnp.zeros_like(g)
    mesh = jax.make_mesh((1,), ("x",))
    f = shard_map(lambda gg, ee: compressed_psum(gg, ee, "x"),
                  mesh=mesh, in_specs=(P(), P()), out_specs=(P(), P()),
                  check_rep=False)
    acc = jnp.zeros_like(g)
    for _ in range(20):
        out, err = f(g, err)
        acc = acc + out
    np.testing.assert_allclose(acc / 20, g, rtol=5e-2)
