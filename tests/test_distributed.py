"""Distributed-equivalence tests. These need multiple XLA host devices, so
they run in a SUBPROCESS with XLA_FLAGS set (the main test process keeps the
single-device view per the harness contract)."""
import os
import subprocess
import sys
import textwrap

import pytest

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    sys.path.insert(0, "src")
    import jax, jax.numpy as jnp
    import numpy as np
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.core.sparse_tensor import SparseTensor
    from repro.core.completion import als_sweep, sgd_sweep
    from repro.core.distributed import (AxisCtx, LOCAL,
                                        sparse_allreduce_butterfly,
                                        tttp_ctx, mttkrp_ctx)
    from repro.data.synthetic import shuffle_and_pad
    from repro.optim.compression import compressed_psum, ef_state_init

    mesh = jax.make_mesh((4, 2), ("data", "model"))
    ctx = AxisCtx(data="data", model="model")

    key = jax.random.PRNGKey(0)
    I, J, K, R, m = 32, 24, 16, 8, 2000
    st = SparseTensor.random(key, (I, J, K), m, cap=2048)
    st = shuffle_and_pad(st, key, 4)
    omega = st.with_values(jnp.ones_like(st.values))
    ks = jax.random.split(key, 3)
    factors = [jax.random.normal(k, (d, R)) for k, d in
               zip(ks, (I, J, K))]

    st_spec = SparseTensor(P("data", None), P("data"), P("data"),
                           st.shape, st.nnz, None)
    f_spec = P(None, "model")

    # 1) distributed TTTP == local
    def d_tttp(s, fs):
        return tttp_ctx(s, list(fs), ctx).values
    got = jax.jit(shard_map(d_tttp, mesh=mesh,
                            in_specs=(st_spec, (f_spec,) * 3),
                            out_specs=P("data"), check_rep=False))(
        st, tuple(factors))
    want = tttp_ctx(st, factors, LOCAL).values
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)
    print("TTTP-dist-ok")

    # 2) distributed MTTKRP == local
    def d_mttkrp(s, fs):
        return mttkrp_ctx(s, [None, fs[1], fs[2]], 0, ctx)
    got = jax.jit(shard_map(d_mttkrp, mesh=mesh,
                            in_specs=(st_spec, (f_spec,) * 3),
                            out_specs=P(None, "model"), check_rep=False))(
        st, tuple(factors))
    want = mttkrp_ctx(st, [None, factors[1], factors[2]], 0, LOCAL)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)
    print("MTTKRP-dist-ok")

    # 3) full distributed ALS sweep == local sweep
    def d_als(s, o, fs):
        return tuple(als_sweep(s, o, list(fs), 1e-6, cg_iters=12, ctx=ctx))
    got = jax.jit(shard_map(d_als, mesh=mesh,
                            in_specs=(st_spec, st_spec, (f_spec,) * 3),
                            out_specs=(f_spec,) * 3, check_rep=False))(
        st, omega, tuple(factors))
    want = als_sweep(st, omega, list(factors), 1e-6, cg_iters=12, ctx=LOCAL)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=5e-3, atol=5e-3)
    print("ALS-dist-ok")

    # 3b) planner-routed weighted Gram matvec (cg_matvec family) under data
    #     AND model sharding == local: dispatch inserts the inter-half
    #     psum(model) and the output psum(data)
    from repro.core.completion.als import gram_matvec
    x0 = factors[0]
    def d_gram(s, fs, x):
        return gram_matvec(s, list(fs), 0, x, lam=1e-6, ctx=ctx,
                           matvec_path="auto")
    got = jax.jit(shard_map(d_gram, mesh=mesh,
                            in_specs=(st_spec, (f_spec,) * 3, f_spec),
                            out_specs=P(None, "model"), check_rep=False))(
        omega, tuple(factors), x0)
    want = gram_matvec(omega, factors, 0, x0, lam=1e-6, ctx=LOCAL,
                       matvec_path="auto")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)
    print("GRAM-planner-dist-ok")

    # 4) butterfly sparse all-reduce == sum of per-shard blocks
    blocks = [SparseTensor.random(jax.random.fold_in(key, i), (32, 8), 40,
                                  cap=64) for i in range(8)]
    idx = jnp.stack([b.indices for b in blocks])
    vals = jnp.stack([b.values for b in blocks])
    valid = jnp.stack([b.valid for b in blocks])

    def d_butterfly(idx, vals, valid):
        local = SparseTensor(idx[0], vals[0], valid[0], (32, 8), None)
        out = sparse_allreduce_butterfly(local, "x")
        return out.todense()
    mesh1 = jax.make_mesh((8,), ("x",))
    got = jax.jit(shard_map(d_butterfly, mesh=mesh1,
                            in_specs=(P("x"), P("x"), P("x")),
                            out_specs=P("x"), check_rep=False))(
        idx, vals, valid)
    want = np.asarray(sum(b.todense() for b in blocks))
    got0 = np.asarray(got).reshape(8, 32, 8)
    for d in range(8):   # every device ends with the full reduced block
        np.testing.assert_allclose(got0[d], want, rtol=1e-5, atol=1e-5)
    print("butterfly-ok")

    # 5) error-feedback int8 compressed psum ~= exact psum
    g = jax.random.normal(key, (8, 64))
    def d_comp(g):
        out, err = compressed_psum(g[0], jnp.zeros_like(g[0]), "x")
        return out
    got = jax.jit(shard_map(d_comp, mesh=mesh1, in_specs=P("x"),
                            out_specs=P("x"), check_rep=False))(g)
    want = g.sum(0)
    rel = float(jnp.max(jnp.abs(got[:64] - want)) /
                (jnp.max(jnp.abs(want)) + 1e-9))
    assert rel < 0.1, rel
    print("compressed-psum-ok")

    print("ALL-DIST-OK")
""")


@pytest.mark.slow
def test_distributed_equivalence_subprocess(tmp_path):
    script = tmp_path / "dist_check.py"
    script.write_text(_SCRIPT)
    env = dict(os.environ)
    # force the host (CPU) platform: the XLA_FLAGS device-count override only
    # applies to it, and letting jax probe an accelerator plugin here burns
    # minutes in init retries on accelerator-less CI machines
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    out = subprocess.run([sys.executable, str(script)], env=env,
                         capture_output=True, text=True, timeout=900,
                         cwd=os.path.dirname(os.path.dirname(__file__)))
    assert "ALL-DIST-OK" in out.stdout, out.stdout + "\n---\n" + out.stderr


_ROWSHARD_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    sys.path.insert(0, "src")
    import jax, jax.numpy as jnp
    import numpy as np
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    from repro.core.sparse_tensor import SparseTensor
    from repro.core.distributed import (AxisCtx, multilinear_rowsharded,
                                        mttkrp_rowsharded)
    from repro.core.tttp import multilinear_values
    from repro.sparse import ops as sops
    from repro.data.synthetic import shuffle_and_pad

    mesh = jax.make_mesh((8,), ("data",))
    ctx = AxisCtx(data="data", model=None)
    key = jax.random.PRNGKey(0)
    I, J, K, R, m = 64, 48, 32, 8, 2000
    st = shuffle_and_pad(SparseTensor.random(key, (I, J, K), m, cap=2048),
                         key, 8)
    ks = jax.random.split(key, 3)
    factors = [jax.random.normal(k, (d, R)) for k, d in zip(ks, (I, J, K))]
    st_spec = SparseTensor(P("data", None), P("data"), P("data"), st.shape,
                           st.nnz, None)
    f_spec = P("data", None)  # the paper's Fig.2 row distribution

    got = jax.jit(shard_map(
        lambda s, fs: multilinear_rowsharded(s, list(fs), ctx, h_slices=2),
        mesh=mesh, in_specs=(st_spec, (f_spec,) * 3), out_specs=P("data"),
        check_rep=False))(st, tuple(factors))
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(multilinear_values(st, factors)),
                               rtol=1e-4, atol=1e-4)

    got2 = jax.jit(shard_map(
        lambda s, fs: mttkrp_rowsharded(s, list(fs), 0, ctx, h_slices=2),
        mesh=mesh, in_specs=(st_spec, (f_spec,) * 3),
        out_specs=P("data", None), check_rep=False))(st, tuple(factors))
    want2 = sops.mttkrp(st, [None, factors[1], factors[2]], 0)
    np.testing.assert_allclose(np.asarray(got2), np.asarray(want2),
                               rtol=1e-4, atol=1e-4)
    print("ROWSHARD-OK")
""")


@pytest.mark.slow
def test_rowsharded_factors_subprocess(tmp_path):
    """Paper Fig. 2 row distribution: H-sliced gathers + reduce-scatter."""
    script = tmp_path / "rowshard_check.py"
    script.write_text(_ROWSHARD_SCRIPT)
    env = dict(os.environ)
    env["JAX_PLATFORMS"] = "cpu"    # see test_distributed_equivalence_subprocess
    out = subprocess.run([sys.executable, str(script)], env=env,
                         capture_output=True, text=True, timeout=900,
                         cwd=os.path.dirname(os.path.dirname(__file__)))
    assert "ROWSHARD-OK" in out.stdout, out.stdout + "\n---\n" + out.stderr
