"""Kernel-tile autotuner (repro.planner.tuner, DESIGN.md §13): lattice
sweep, winner installation, obs counter accounting, and the persistent
on-disk plan cache — the second run of a cached workload must perform
ZERO timings, asserted on the tuner's obs counters."""
import json
import os
import re
import subprocess
import sys

import jax
import jax.numpy as jnp
import pytest

from repro import obs
from repro.core.sparse_tensor import SparseTensor
from repro.kernels import tile as ktile
from repro.kernels.tile import KernelTile
from repro.planner import cost as pcost
from repro.planner import tuner

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# small lattices keep the interpret-mode sweeps fast; default-first ordering
# mirrors the production lattices (winner <= default by construction)
TEST_LATTICES = {
    "tttp": (KernelTile(), KernelTile(block_m=64)),
    "mttkrp": (KernelTile(), KernelTile(block_m=64, schedule="segmented")),
    "cg_matvec": (KernelTile(), KernelTile(block_m=64)),
}


@pytest.fixture
def problem(monkeypatch):
    monkeypatch.setattr(tuner, "LATTICES", TEST_LATTICES)
    key = jax.random.PRNGKey(0)
    st = SparseTensor.random(key, (24, 18, 12), 120, cap=140)
    ks = jax.random.split(key, 3)
    factors = [jax.random.normal(k, (d, 8)) for k, d in zip(ks, st.shape)]
    omega = st.with_values(jnp.ones_like(st.values))
    yield st, factors, omega
    ktile.reset_tiles()
    pcost.reset_rates()


@pytest.fixture
def registry():
    obs.enable()
    reg = obs.get_registry()
    reg.reset()
    yield reg
    obs.disable()


def _counter(reg, name):
    return reg.counters.get(name, 0.0)


def test_tune_family_installs_winner(problem, registry):
    st, factors, omega = problem
    result = tuner.tune_family("mttkrp", st, factors, omega=omega, iters=1)
    assert result["tile"] in TEST_LATTICES["mttkrp"]
    assert ktile.current_tile("mttkrp") == result["tile"]
    assert result["seconds"] == min(s for _, s in result["timings"])
    assert result["seconds"] > 0


def test_tune_family_counters_and_plan_records(problem, registry):
    st, factors, omega = problem
    tuner.tune_family("tttp", st, factors, iters=1)
    assert _counter(registry, "tuner/measurements") \
        == len(TEST_LATTICES["tttp"])
    keys = [k for k in registry.plans if k.startswith("autotune/tttp|")]
    assert len(keys) == len(TEST_LATTICES["tttp"])
    for k in keys:
        rec = registry.plans[k]
        assert rec.measured.count >= 1
        assert rec.predicted["seconds"] > 0


def test_second_run_zero_measurements(problem, registry, tmp_path):
    """The acceptance bound: a rerun against the populated cache performs
    no timings at all — every family is a cache hit."""
    st, factors, omega = problem
    cache = str(tmp_path / "plan_cache.json")
    s1 = tuner.ensure_tuned(st, factors, omega=omega, cache_path=cache,
                            iters=1)
    assert s1["hits"] == 0 and s1["measured"] == 6
    measured_after_first = _counter(registry, "tuner/measurements")
    winners1 = dict(s1["winners"])

    ktile.reset_tiles()
    pcost.reset_rates()
    s2 = tuner.ensure_tuned(st, factors, omega=omega, cache_path=cache,
                            iters=1)
    assert s2["measured"] == 0
    assert s2["hits"] == 3
    assert _counter(registry, "tuner/measurements") == measured_after_first
    assert _counter(registry, "tuner/cache_hits") == 3
    assert s2["winners"] == winners1
    # the cached run restores the calibrated rates too
    assert s2["rates"] == s1["rates"]
    for f in ("tttp", "mttkrp", "cg_matvec"):
        assert ktile.current_tile(f).short() == winners1[f]


def test_cache_misses_on_lattice_version_bump(problem, registry, tmp_path,
                                              monkeypatch):
    st, factors, omega = problem
    cache = str(tmp_path / "plan_cache.json")
    tuner.ensure_tuned(st, factors, omega=omega, cache_path=cache, iters=1)
    monkeypatch.setattr(tuner, "LATTICE_VERSION", tuner.LATTICE_VERSION + 1)
    s = tuner.ensure_tuned(st, factors, omega=omega, cache_path=cache,
                           iters=1)
    assert s["hits"] == 0 and s["measured"] > 0


def test_cache_misses_on_device_kind_change(problem, registry, tmp_path,
                                            monkeypatch):
    st, factors, omega = problem
    cache = str(tmp_path / "plan_cache.json")
    tuner.ensure_tuned(st, factors, omega=omega, cache_path=cache, iters=1)
    monkeypatch.setattr(tuner, "device_kind", lambda: "TPU v9000")
    s = tuner.ensure_tuned(st, factors, omega=omega, cache_path=cache,
                           iters=1)
    assert s["hits"] == 0 and s["measured"] > 0


def test_cache_misses_on_signature_change(problem, registry, tmp_path):
    st, factors, omega = problem
    cache = str(tmp_path / "plan_cache.json")
    tuner.ensure_tuned(st, factors, omega=omega, cache_path=cache, iters=1)
    f2 = [f[:, :4] for f in factors]  # different rank => different signature
    s = tuner.ensure_tuned(st, f2, omega=omega, cache_path=cache, iters=1)
    assert s["hits"] == 0 and s["measured"] > 0


def test_cache_file_shape(problem, tmp_path):
    st, factors, omega = problem
    cache = str(tmp_path / "plan_cache.json")
    tuner.ensure_tuned(st, factors, omega=omega, cache_path=cache, iters=1)
    with open(cache) as f:
        data = json.load(f)
    assert data["lattice_version"] == tuner.LATTICE_VERSION
    assert len(data["entries"]) == 3
    for key, entry in data["entries"].items():
        dev, ver, family, sig = key.split("|", 3)
        assert ver == f"v{tuner.LATTICE_VERSION}"
        assert family in ("tttp", "mttkrp", "cg_matvec")
        assert "shape=24x18x12" in sig
        tile = KernelTile.from_json(entry["tile"])  # round-trips
        assert tile in TEST_LATTICES[family]
    assert data["rates"]["flop"] > 0


def test_corrupt_cache_file_is_remeasured(problem, tmp_path):
    st, factors, omega = problem
    cache = str(tmp_path / "plan_cache.json")
    with open(cache, "w") as f:
        f.write("{not json")
    s = tuner.ensure_tuned(st, factors, omega=omega, cache_path=cache,
                           iters=1)
    assert s["measured"] > 0
    with open(cache) as f:
        json.load(f)  # rewritten valid


def test_no_cache_path_always_measures(problem):
    st, factors, omega = problem
    s1 = tuner.ensure_tuned(st, factors, omega=omega, cache_path="", iters=1,
                            families=("tttp",))
    s2 = tuner.ensure_tuned(st, factors, omega=omega, cache_path="", iters=1,
                            families=("tttp",))
    assert s1["measured"] > 0 and s2["measured"] > 0


def test_cg_matvec_skipped_without_omega(problem):
    st, factors, _ = problem
    s = tuner.ensure_tuned(st, factors, iters=1)
    assert set(s["winners"]) == {"tttp", "mttkrp"}


def test_fenced_time_lands_in_registry(registry):
    t = tuner.fenced_time(lambda: jnp.zeros(8), iters=2,
                          span_name="tuner/unit")
    assert t > 0
    assert any(k.startswith("tuner/unit") for k in registry.timings)


def test_calibrate_roundtrip():
    try:
        before = pcost.rates()
        got = pcost.calibrate([(1e6, 1e5, 1e-3), (4e6, 2e5, 3.5e-3)])
        assert got["flop"] > 0 and got["mem"] > 0
        assert pcost.rates() == got
        with pytest.raises(ValueError):
            pcost.set_rates(flop=-1.0)
    finally:
        pcost.reset_rates()
    assert pcost.rates() == {"flop": pcost.FLOP_RATE, "mem": pcost.MEM_RATE,
                             "comm": pcost.COMM_RATE}
    assert before == pcost.rates()


@pytest.mark.slow
def test_complete_cli_plan_cache_round_trip(tmp_path):
    """Second `launch.complete --plan-cache` run reports zero measurements
    (cache hit on every family)."""
    cache = tmp_path / "plan.json"
    env = {**os.environ, "PYTHONPATH": os.path.join(REPO_ROOT, "src")}

    def run(ck):
        cmd = [sys.executable, "-m", "repro.launch.complete",
               "--dims", "24,18,12", "--nnz", "500", "--rank", "6",
               "--sweeps", "1", "--plan-cache", str(cache),
               "--ckpt-dir", str(tmp_path / ck)]
        p = subprocess.run(cmd, capture_output=True, text=True, env=env,
                           cwd=REPO_ROOT, timeout=900)
        assert p.returncode == 0, p.stderr
        m = re.search(r"plan-cache: hits=(\d+) measured=(\d+)", p.stdout)
        assert m, p.stdout
        return int(m.group(1)), int(m.group(2))

    hits1, measured1 = run("ck1")
    assert hits1 == 0 and measured1 > 0
    hits2, measured2 = run("ck2")
    assert hits2 == 3 and measured2 == 0
