"""Regenerate the golden kernel-regression fixtures.

    PYTHONPATH=src python tests/golden/make_golden.py

Each .npz holds a tiny deterministic padded-COO tensor (indices / values /
valid / shape), factor matrices, a CG direction, and float64 *reference*
outputs for MTTKRP (every mode), TTTP and the weighted Gram matvec
(cg_matvec), computed here with plain numpy in double precision — NO repro
kernel is involved in producing the expectations, so a silent numeric drift
in any kernel or planner path fails tests/test_golden.py loudly.

Only rerun this script when the fixture *definition* changes; the checked-in
files are the regression baseline.
"""
import os

import numpy as np

OUT_DIR = os.path.dirname(os.path.abspath(__file__))


def _reference_outputs(idx, vals, valid, shape, factors, x):
    """Float64 references: per-entry Khatri-Rao accumulation (duplicate
    coordinates each contribute — matching COO kernel semantics)."""
    nd = len(shape)
    r = factors[0].shape[1]
    v = np.where(valid, vals, 0.0).astype(np.float64)
    fs64 = [f.astype(np.float64) for f in factors]
    out = {}
    # MTTKRP onto every mode
    for mode in range(nd):
        kr = np.ones((idx.shape[0], r))
        for d in range(nd):
            if d != mode:
                kr = kr * fs64[d][idx[:, d]]
        acc = np.zeros((shape[mode], r))
        np.add.at(acc, idx[:, mode], v[:, None] * kr)
        out[f"mttkrp_m{mode}"] = acc
    # TTTP values (all modes covered)
    kr = np.ones((idx.shape[0], r))
    for d in range(nd):
        kr = kr * fs64[d][idx[:, d]]
    out["tttp_vals"] = v * kr.sum(axis=1)
    # weighted Gram matvec onto mode 0 (paper eq. 3): weights are `vals`,
    # the contracted-rank side uses x on mode 0 and the factors elsewhere
    x64 = x.astype(np.float64)
    inner = x64[idx[:, 0]]
    for d in range(1, nd):
        inner = inner * fs64[d][idx[:, d]]
    z = v * inner.sum(axis=1)                       # TTTP half
    kr0 = np.ones((idx.shape[0], r))
    for d in range(1, nd):
        kr0 = kr0 * fs64[d][idx[:, d]]
    acc = np.zeros((shape[0], r))
    np.add.at(acc, idx[:, 0], z[:, None] * kr0)     # MTTKRP half
    out["cg_m0"] = acc
    return out


def make_case(name: str, shape, nnz: int, cap: int, r: int, seed: int):
    rng = np.random.default_rng(seed)
    nd = len(shape)
    idx = np.zeros((cap, nd), np.int32)
    for d, s in enumerate(shape):
        idx[:nnz, d] = rng.integers(0, s, size=nnz)
    vals = np.zeros((cap,), np.float32)
    vals[:nnz] = rng.uniform(-1.0, 1.0, size=nnz).astype(np.float32)
    valid = np.zeros((cap,), bool)
    valid[:nnz] = True
    factors = [rng.standard_normal((s, r)).astype(np.float32) for s in shape]
    x = rng.standard_normal((shape[0], r)).astype(np.float32)
    ref = _reference_outputs(idx, vals, valid, shape, factors, x)
    path = os.path.join(OUT_DIR, f"{name}.npz")
    np.savez(path, indices=idx, values=vals, valid=valid,
             shape=np.asarray(shape, np.int64),
             x=x, **{f"factor_{d}": f for d, f in enumerate(factors)}, **ref)
    print(f"wrote {path}: shape={shape} nnz={nnz} cap={cap} r={r}")


if __name__ == "__main__":
    make_case("golden_o3", (17, 13, 9), nnz=80, cap=88, r=6, seed=1234)
    make_case("golden_o4", (9, 8, 7, 6), nnz=60, cap=64, r=4, seed=5678)
