"""Integration tests: the three completion algorithms converge on a low-rank
synthetic tensor (paper Fig. 7a protocol, laptop scale), generalized losses
descend, and the two CCD++ variants agree exactly."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import losses as L
from repro.core.completion import (als_sweep, als_sweep_explicit, ccd_sweep,
                                   ccd_sweep_tttp, gcp_adam_init, gcp_step,
                                   sgd_sweep)
from repro.core.completion.ccd import residual_values
from repro.core.completion.gcp import gcp_loss
from repro.core.sparse_tensor import SparseTensor
from repro.core.tttp import multilinear_values


def make_problem(key, shape=(40, 35, 30), r_true=3, r=6, nnz=4000):
    ks = jax.random.split(key, 8)
    true = [jax.random.normal(k, (d, r_true)) / r_true ** 0.5
            for k, d in zip(ks, shape)]
    idx = jnp.stack([jax.random.randint(ks[3 + d], (nnz,), 0, s)
                     for d, s in enumerate(shape)], 1)
    vals = jnp.sum(true[0][idx[:, 0]] * true[1][idx[:, 1]] *
                   true[2][idx[:, 2]], 1)
    st = SparseTensor.from_coo(idx, vals, shape, cap=nnz + 96)
    init = [jax.random.normal(jax.random.fold_in(ks[6], d), (s, r)) / r ** 0.5
            for d, s in enumerate(shape)]
    return st, init


def rmse(st, fs):
    model = multilinear_values(st, fs)
    d = (st.values - model) * st.mask
    return float(jnp.sqrt(jnp.sum(d ** 2) / jnp.sum(st.mask)))


def test_als_cg_converges_and_matches_explicit():
    st, fs = make_problem(jax.random.PRNGKey(0))
    omega = st.with_values(jnp.ones_like(st.values))
    e0 = rmse(st, fs)
    sweep = jax.jit(lambda s, o, a, b, c: als_sweep(s, o, [a, b, c], 1e-6,
                                                    cg_iters=16))
    f_cg = list(fs)
    for _ in range(25):
        f_cg = sweep(st, omega, *f_cg)
    assert rmse(st, f_cg) < 0.1 * e0
    # one sweep from same init agrees with the explicit (Cholesky) baseline
    f1 = sweep(st, omega, *fs)
    f2 = jax.jit(lambda s, a, b, c: als_sweep_explicit(s, [a, b, c], 1e-6))(
        st, *fs)
    for a, b in zip(f1, f2):
        np.testing.assert_allclose(a, b, rtol=3e-2, atol=3e-2)


def test_ccd_variants_identical_and_converge():
    st, fs = make_problem(jax.random.PRNGKey(1))
    rho = residual_values(st, fs)
    e0 = rmse(st, fs)
    s1 = jax.jit(lambda s, f, r: ccd_sweep(s, f, r, 1e-6))
    s2 = jax.jit(lambda s, f, r: ccd_sweep_tttp(s, f, r, 1e-6))
    fa, ra = list(fs), rho
    fb, rb = list(fs), rho
    for _ in range(8):
        fa, ra = s1(st, fa, ra)
        fb, rb = s2(st, fb, rb)
    for a, b in zip(fa, fb):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)
    assert rmse(st, fa) < 0.5 * e0
    # maintained residual stays consistent with direct recomputation
    np.testing.assert_allclose(ra, residual_values(st, fa),
                               rtol=1e-3, atol=1e-3)


def test_sgd_descends():
    st, fs = make_problem(jax.random.PRNGKey(2))
    e0 = rmse(st, fs)
    step = jax.jit(lambda k, s, f: sgd_sweep(k, s, f, 1e-6, lr=4e-3,
                                             sample_size=2048))
    key = jax.random.PRNGKey(3)
    for i in range(100):
        fs = step(jax.random.fold_in(key, i), st, fs)
    assert rmse(st, fs) < 0.75 * e0


@pytest.mark.parametrize("loss_name", ["quadratic", "poisson", "poisson_log",
                                       "huber", "logistic"])
def test_gcp_generalized_losses_descend(loss_name):
    st, fs = make_problem(jax.random.PRNGKey(4))
    loss = L.LOSSES[loss_name]
    if loss_name.startswith("poisson"):
        st = st.with_values(jnp.round(jnp.abs(st.values) * 4))
        fs = [jnp.abs(f) + 0.05 for f in fs]
    if loss_name == "logistic":
        st = st.with_values((st.values > 0).astype(jnp.float32))
    ad = gcp_adam_init(fs)
    step = jax.jit(lambda s, f, a: gcp_step(s, f, loss, 1e-7, 5e-3, a))
    l0 = float(gcp_loss(st, fs, loss, 1e-7))
    for _ in range(60):
        fs, ad = step(st, fs, ad)
    l1 = float(gcp_loss(st, fs, loss, 1e-7))
    assert l1 < l0, (loss_name, l0, l1)


def test_ccd_tttp_variant_uses_two_tttp_calls_per_column(monkeypatch):
    """Perf regression guard: the TTTP-routed column update reuses
    vw = TTTP(Ω, fac) for both the numerator and the residual update —
    two TTTP kernel calls per column update, not three — and stays
    numerically identical to the einsum variant."""
    import repro.planner as planner_mod
    from repro.core.completion.ccd import (_ccd_column_update_einsum,
                                           _ccd_column_update_tttp,
                                           residual_values)
    from repro.core.distributed import LOCAL
    st, fs = make_problem(jax.random.PRNGKey(7), nnz=600)
    rho = residual_values(st, fs)
    cols = [f[:, 0] for f in fs]
    calls = []
    orig = planner_mod.planned_tttp

    def counting(*a, **kw):
        calls.append(1)
        return orig(*a, **kw)

    monkeypatch.setattr(planner_mod, "planned_tttp", counting)
    col_t, rho_t = _ccd_column_update_tttp(rho, st, cols, 0, 1e-6, LOCAL)
    assert len(calls) == 2, f"expected 2 TTTP calls, got {len(calls)}"
    col_e, rho_e = _ccd_column_update_einsum(rho, st, cols, 0, 1e-6, LOCAL)
    np.testing.assert_allclose(col_t, col_e, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(rho_t, rho_e, rtol=1e-5, atol=1e-5)


def test_sgd_sample_entries_empty_shard():
    """Regression: a shard with zero valid entries must not feed an all-zero
    probability vector to jax.random.choice (garbage indices / NaNs under
    sharded SGD). The fallback samples uniformly, marks the sample invalid,
    and the sweep stays finite."""
    from repro.core.completion.sgd import sample_entries
    shape = (10, 8, 6)
    cap = 32
    empty = SparseTensor(jnp.zeros((cap, 3), jnp.int32), jnp.zeros((cap,)),
                         jnp.zeros((cap,), bool), shape)
    s = sample_entries(jax.random.PRNGKey(0), empty, 16)
    idx = np.asarray(s.indices)
    assert np.all(np.isfinite(idx))
    assert np.all(idx >= 0) and all(
        np.all(idx[:, d] < shape[d]) for d in range(3))
    assert not bool(jnp.any(s.valid))
    # a full sgd sweep on the empty shard: finite, regularization-only drift
    fs = [jax.random.normal(jax.random.PRNGKey(d), (n, 4))
          for d, n in enumerate(shape)]
    out = sgd_sweep(jax.random.PRNGKey(1), empty, list(fs), lam=1e-3,
                    lr=1e-2, sample_size=16)
    for f in out:
        assert bool(jnp.all(jnp.isfinite(f)))
    # under jit as well (the sharded code path always traces)
    out_j = jax.jit(lambda k, s_, f: sgd_sweep(k, s_, list(f), 1e-3, 1e-2,
                                               16))(jax.random.PRNGKey(1),
                                                    empty, tuple(fs))
    for f in out_j:
        assert bool(jnp.all(jnp.isfinite(f)))


def test_gcp_quadratic_grad_matches_autodiff():
    """MTTKRP-based GCP gradient == jax.grad of the objective."""
    from repro.core.completion.gcp import gcp_gradients
    st, fs = make_problem(jax.random.PRNGKey(5), nnz=500)
    lam = 1e-3

    def objective(factors):
        model = multilinear_values(st, factors)
        data = jnp.sum(jnp.where(st.mask,
                                 L.quadratic.value(st.values, model), 0.0))
        return data + lam * sum(jnp.sum(jnp.square(f)) for f in factors)

    got = gcp_gradients(st, fs, L.quadratic, lam)
    want = jax.grad(objective)(fs)
    for g, w in zip(got, want):
        np.testing.assert_allclose(g, w, rtol=1e-4, atol=1e-4)
