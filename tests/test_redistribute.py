"""Tests for ``repro.sparse.redistribute`` (paper Fig. 4): distributed
transpose with shard-boundary rebalancing, order-preserving reshape, and the
butterfly sparse all-reduce on ≥4 forced host devices (subprocess, per the
single-device harness contract)."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.sparse_tensor import SparseTensor
from repro.sparse import redistribute


def _random_st(key, shape=(12, 10, 8), nnz=200, cap=256):
    return SparseTensor.random(key, shape, nnz, cap=cap)


def test_transpose_distributed_matches_dense():
    st = _random_st(jax.random.PRNGKey(0))
    perm = (2, 0, 1)
    out = redistribute.transpose_distributed(st, perm)
    np.testing.assert_allclose(np.asarray(out.todense()),
                               np.asarray(jnp.transpose(st.todense(), perm)),
                               rtol=1e-6, atol=1e-6)


def test_transpose_distributed_resorts_by_new_leading_mode():
    """The global re-sort is the redistribution step: after transposition
    entries are sorted by the NEW mode 0 (shard-boundary rebalancing), with
    padding pushed to the end."""
    st = _random_st(jax.random.PRNGKey(1))
    out = redistribute.transpose_distributed(st, (1, 2, 0))
    assert out.sorted_mode == 0
    rows = np.asarray(out.indices[:, 0])
    valid = np.asarray(out.valid)
    nnz = int(valid.sum())
    # all valid entries first (padding rebalanced to the tail) ...
    assert valid[:nnz].all() and not valid[nnz:].any()
    # ... and sorted by the new leading mode
    assert (np.diff(rows[:nnz]) >= 0).all()


def test_transpose_distributed_no_resort_keeps_order():
    st = _random_st(jax.random.PRNGKey(2))
    out = redistribute.transpose_distributed(st, (1, 0, 2), resort=False)
    assert out.sorted_mode is None
    np.testing.assert_array_equal(np.asarray(out.indices[:, 0]),
                                  np.asarray(st.indices[:, 1]))


def test_reshape_distributed_preserves_global_order():
    from repro.core.utils import lex_sort_perm
    st = _random_st(jax.random.PRNGKey(3))
    p = lex_sort_perm(st.indices, st.valid, range(st.ndim))
    st = SparseTensor(st.indices[p], st.values[p], st.valid[p], st.shape,
                      st.nnz, sorted_mode=0)
    out = redistribute.reshape_distributed(st, (12 * 10, 8))
    assert out.sorted_mode == 0
    rows = np.asarray(out.indices[:, 0])[np.asarray(out.valid)]
    assert (np.diff(rows) >= 0).all()   # row-major order really is preserved
    np.testing.assert_allclose(
        np.asarray(out.todense()),
        np.asarray(st.todense().reshape(12 * 10, 8)), rtol=1e-6, atol=1e-6)


_DIST_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import sys
    sys.path.insert(0, "src")
    import jax, jax.numpy as jnp
    import numpy as np
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.core.sparse_tensor import SparseTensor
    from repro.core.distributed import sparse_allreduce_butterfly
    from repro.sparse import redistribute
    from repro.data.synthetic import shuffle_and_pad

    mesh = jax.make_mesh((4,), ("data",))
    key = jax.random.PRNGKey(0)

    # 1) sharded transpose_distributed == local dense transpose (the global
    #    sort is XLA's distributed sort over the sharded arrays)
    st = shuffle_and_pad(SparseTensor.random(key, (16, 12, 8), 500, cap=512),
                         key, 4)
    st = redistribute.shard_nonzeros(st, mesh, "data")
    out = jax.jit(lambda s: redistribute.transpose_distributed(s, (2, 1, 0)))(st)
    np.testing.assert_allclose(
        np.asarray(out.todense()),
        np.asarray(jnp.transpose(st.todense(), (2, 1, 0))),
        rtol=1e-5, atol=1e-5)
    rows = np.asarray(out.indices[:, 0]); valid = np.asarray(out.valid)
    nnz = int(valid.sum())
    assert valid[:nnz].all() and (np.diff(rows[:nnz]) >= 0).all()
    print("transpose-dist-ok")

    # 2) butterfly sparse all-reduce over 4 devices (power-of-two ranks,
    #    device-dependent patterns)
    blocks = [SparseTensor.random(jax.random.fold_in(key, i), (16, 8), 30,
                                  cap=32) for i in range(4)]
    idx = jnp.stack([b.indices for b in blocks])
    vals = jnp.stack([b.values for b in blocks])
    valid = jnp.stack([b.valid for b in blocks])

    def d_butterfly(idx, vals, valid):
        local = SparseTensor(idx[0], vals[0], valid[0], (16, 8), None)
        return sparse_allreduce_butterfly(local, "data").todense()
    got = jax.jit(shard_map(d_butterfly, mesh=mesh,
                            in_specs=(P("data"), P("data"), P("data")),
                            out_specs=P("data"), check_rep=False))(
        idx, vals, valid)
    want = np.asarray(sum(b.todense() for b in blocks))
    got = np.asarray(got).reshape(4, 16, 8)
    for d in range(4):
        np.testing.assert_allclose(got[d], want, rtol=1e-5, atol=1e-5)
    print("butterfly4-ok")
    print("REDIST-DIST-OK")
""")


@pytest.mark.slow
def test_redistribute_distributed_subprocess(tmp_path):
    """Sharded transpose + 4-device butterfly all-reduce (forced host
    devices; see test_distributed.py for the subprocess rationale)."""
    script = tmp_path / "redist_check.py"
    script.write_text(_DIST_SCRIPT)
    env = dict(os.environ)
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    out = subprocess.run([sys.executable, str(script)], env=env,
                         capture_output=True, text=True, timeout=900,
                         cwd=os.path.dirname(os.path.dirname(__file__)))
    assert "REDIST-DIST-OK" in out.stdout, out.stdout + "\n---\n" + out.stderr
