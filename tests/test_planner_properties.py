"""Randomized planner-path equivalence: every candidate path of a random
order-3/4 contraction IR must match the dense einsum reference in VALUES and
GRADIENTS to 1e-4.

This module is hypothesis-free (a fixed deterministic seed grid) so the
sweep always runs in tier-1; ``tests/test_properties.py`` wraps the same
helpers under hypothesis for fuzzing in CI, where the package is installed.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core.api as ctf
from repro.core.sparse_tensor import SparseTensor

KINDS = ("mttkrp", "partial_mttkrp", "tttp", "ttm", "reduce", "cg_matvec")
_LETTERS = "ijklmn"


def random_ir_case(kind: str, order: int, seed: int, r: int = 4):
    """Build (expr, operands) for a random IR of the given family/order."""
    rng = np.random.default_rng(seed)
    shape = tuple(int(rng.integers(4, 10)) for _ in range(order))
    nnz = int(rng.integers(10, 50))
    key = jax.random.PRNGKey(seed)
    # unique coordinates: duplicate entries are linear-equivalent but make
    # the squared-output gradient functional ambiguous between the
    # per-entry kernels and the densified reference
    cells = int(np.prod(shape))
    lin = rng.choice(cells, size=min(nnz, cells), replace=False)
    idx = np.zeros((lin.shape[0], order), np.int64)
    rem = lin
    for d in range(order - 1, -1, -1):
        idx[:, d] = rem % shape[d]
        rem = rem // shape[d]
    vals = jax.random.uniform(key, (lin.shape[0],), minval=-1.0, maxval=1.0)
    st = SparseTensor.from_coo(idx, vals, shape,
                               cap=lin.shape[0] + int(rng.integers(0, 8)))
    s_term = _LETTERS[:order]

    def factor(d, rank, salt):
        return jax.random.normal(jax.random.fold_in(key, 100 + salt),
                                 (shape[d], rank))

    if kind == "mttkrp":
        mode = int(rng.integers(0, order))
        others = [d for d in range(order) if d != mode]
        out = s_term[mode] + "z"
        if rng.integers(0, 2):                      # permuted output
            out = out[::-1]
        terms = [s_term] + [s_term[d] + "z" for d in others]
        ops = (st, *[factor(d, r, d) for d in others])
    elif kind == "partial_mttkrp":
        contracted = sorted(rng.choice(order, size=max(order - 2, 1),
                                       replace=False).tolist())
        kept = [d for d in range(order) if d not in contracted]
        kept_perm = list(rng.permutation(kept))
        out = "".join(s_term[d] for d in kept_perm) + "z"
        terms = [s_term] + [s_term[d] + "z" for d in contracted]
        ops = (st, *[factor(d, r, d) for d in contracted])
    elif kind == "tttp":
        covered = sorted(rng.choice(order, size=int(rng.integers(1, order + 1)),
                                    replace=False).tolist())
        out = s_term
        terms = [s_term] + [s_term[d] + "z" for d in covered]
        ops = (st, *[factor(d, r, d) for d in covered])
    elif kind == "ttm":
        mode = int(rng.integers(0, order))
        kept = [d for d in range(order) if d != mode]
        kept_perm = list(rng.permutation(kept))
        out = "".join(s_term[d] for d in kept_perm) + "z"
        terms = [s_term, s_term[mode] + "z"]
        ops = (st, factor(mode, r, mode))
    elif kind == "reduce":
        k = int(rng.integers(0, order))
        kept = list(rng.permutation(rng.choice(order, size=k, replace=False)))
        out = "".join(s_term[d] for d in kept)
        terms = [s_term]
        ops = (st,)
    elif kind == "cg_matvec":
        mode = int(rng.integers(0, order))
        others = [d for d in range(order) if d != mode]
        terms = ([s_term]
                 + [s_term[d] + "z" for d in others]
                 + [s_term[mode] + "y"]
                 + [s_term[d] + "y" for d in others])
        out = s_term[mode] + "z"
        fs = {d: factor(d, r, d) for d in others}
        x = factor(mode, r, 50 + mode)
        ops = (st, *[fs[d] for d in others], x, *[fs[d] for d in others])
    else:
        raise ValueError(kind)
    return ",".join(terms) + "->" + out, ops


def _as_dense_args(expr, ops):
    return [op.todense() if isinstance(op, SparseTensor) else op
            for op in ops]


def check_all_paths_match_dense(expr, ops, rtol=1e-4, atol=1e-4):
    """Values: every candidate path == jnp.einsum on the densified operands."""
    want = jnp.einsum(expr, *_as_dense_args(expr, ops))
    plan = ctf.plan(expr, *ops)
    assert plan.candidates, expr
    for path in plan.candidates:
        got = ctf.einsum(expr, *ops, path=path)
        if isinstance(got, SparseTensor):
            got = got.todense()
        np.testing.assert_allclose(got, want, rtol=rtol, atol=atol,
                                   err_msg=f"{expr} via {path}")


def check_all_paths_grads_match_dense(expr, ops, rtol=1e-4, atol=1e-4):
    """Gradients w.r.t. every dense operand (and the sparse values) match
    the dense reference for every candidate path."""
    st = next(op for op in ops if isinstance(op, SparseTensor))
    dense_ops = tuple(op for op in ops if not isinstance(op, SparseTensor))
    lhs, out_term = expr.replace(" ", "").split("->")
    sparse_out = out_term == lhs.split(",")[0]      # TTTP: output == Ω

    def _rebuild(cur, dense):
        rebuilt, di = [], 0
        for op in ops:
            if isinstance(op, SparseTensor):
                rebuilt.append(cur)
            else:
                rebuilt.append(dense[di])
                di += 1
        return rebuilt

    def run(path):
        def f(vals, dense):
            cur = st.with_values(vals)
            out = ctf.einsum(expr, *_rebuild(cur, dense), path=path)
            if isinstance(out, SparseTensor):
                return jnp.sum(out.masked_values() ** 2)
            return jnp.sum(out ** 2)
        return jax.grad(f, argnums=(0, 1))(st.values, dense_ops)

    def run_dense():
        def f(vals, dense):
            cur = st.with_values(vals)
            rebuilt = [op.todense() if isinstance(op, SparseTensor) else op
                       for op in _rebuild(cur, dense)]
            out = jnp.einsum(expr, *rebuilt)
            if sparse_out:                          # TTTP family: re-sample
                out = out[tuple(st.indices[:, d] for d in range(st.ndim))]
                out = jnp.where(st.mask, out, 0.0)
            return jnp.sum(out ** 2)
        return jax.grad(f, argnums=(0, 1))(st.values, dense_ops)

    want_v, want_f = run_dense()
    plan = ctf.plan(expr, *ops)
    for path in plan.candidates:
        got_v, got_f = run(path)
        for g, w, label in [(got_v, want_v, "values"),
                            *[(g, w, f"dense[{i}]") for i, (g, w)
                              in enumerate(zip(got_f, want_f))]]:
            np.testing.assert_allclose(
                g, w, rtol=rtol, atol=atol,
                err_msg=f"grad({label}) {expr} via {path}")


SEEDS = (11, 29, 47)
CASES = [(k, o, s) for k in KINDS for o in (3, 4) for s in SEEDS]


@pytest.mark.parametrize("kind,order,seed", CASES,
                         ids=[f"{k}-o{o}-s{s}" for k, o, s in CASES])
def test_random_ir_every_path_matches_dense(kind, order, seed):
    expr, ops = random_ir_case(kind, order, seed)
    check_all_paths_match_dense(expr, ops)


@pytest.mark.parametrize("kind,order,seed",
                         [(k, o, s) for k, o, s in CASES if s == SEEDS[0]],
                         ids=[f"{k}-o{o}-s{s}" for k, o, s in CASES
                              if s == SEEDS[0]])
def test_random_ir_every_path_grads_match_dense(kind, order, seed):
    expr, ops = random_ir_case(kind, order, seed)
    check_all_paths_grads_match_dense(expr, ops)
