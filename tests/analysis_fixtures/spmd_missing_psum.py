# Seeded-bug fixture for the sharding-propagation pass (exactly ONE planted
# defect): a data-sharded segment-sum with NO psum — each device returns
# only its local rows' contribution, a partial-sum escape. The analyzer
# must report SP001 and nothing else.
import jax
import jax.numpy as jnp

AXIS_ENV = (("data", 2),)
ARGS = (
    jax.ShapeDtypeStruct((16,), jnp.float32),     # nonzero values (sharded)
    jax.ShapeDtypeStruct((16,), jnp.int32),       # mode-0 rows (sharded)
    jax.ShapeDtypeStruct((8, 4), jnp.float32),    # factor (replicated)
)
IN_STATES = (
    {"data": ("shard", 0)},
    {"data": ("shard", 0)},
    {"data": ("rep",)},
)
EXPECTED = {"data": "rep"}   # an MTTKRP row block must be fully reduced


def run(values, rows, factor):
    contrib = values[:, None] * factor[rows]
    out = jax.ops.segment_sum(contrib, rows, num_segments=8)
    return out   # BUG: missing jax.lax.psum(out, "data")
