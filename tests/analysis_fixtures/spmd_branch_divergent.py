# Seeded-bug fixture for the collective-matching pass (exactly ONE planted
# defect): a psum executed only on the branch of a device-varying Python
# `if` — devices whose shard fails the test skip the rendezvous and the
# psum deadlocks across processes. The analyzer must report SP101 and
# nothing else (the axis name is threaded, so no SP103; no lax.cond, so no
# SP102).
import jax
import jax.numpy as jnp


def exchange(x, axis):
    if jnp.any(x > 0):              # device-varying: each shard differs
        x = jax.lax.psum(x, axis)   # BUG: only some devices rendezvous
    return x
