"""Deliberately corrupted pytree fixtures for ``repro-lint --pytrees
--pytree-module bad_pytree`` (run with this directory on PYTHONPATH).

Each exemplar violates one aux-hygiene contract; the pytree pass must turn
every one of them into a finding (the ISSUE acceptance tripwire).
"""
import jax
import jax.numpy as jnp
import numpy as np


@jax.tree_util.register_pytree_node_class
class UnhashableAux:
    """Aux data is a list — hashing the treedef raises at the first jit."""

    def __init__(self, values, meta):
        self.values = values
        self.meta = meta

    def tree_flatten(self):
        return (self.values,), [self.meta]          # list aux: unhashable

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], aux[0])


@jax.tree_util.register_pytree_node_class
class ArrayAux:
    """Aux data smuggles an array — retraces on every value change."""

    def __init__(self, values, lookup):
        self.values = values
        self.lookup = lookup

    def tree_flatten(self):
        return (self.values,), (self.lookup,)       # array in aux

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], aux[0])


@jax.tree_util.register_pytree_node_class
class UnstableAux:
    """Aux equality is identity-based — every reconstruction looks new, so
    the jit cache misses on each rebuild."""

    class _Token:
        pass  # default object eq/hash: identity

    def __init__(self, values, token=None):
        self.values = values
        self.token = token if token is not None else self._Token()

    def tree_flatten(self):
        return (self.values,), (self.token,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], aux[0])


PYTREE_EXEMPLARS = [
    lambda: UnhashableAux(jnp.zeros(3), {"shape": 3}),
    lambda: ArrayAux(jnp.zeros(3), np.arange(3)),
    lambda: UnstableAux(jnp.zeros(3)),
]
