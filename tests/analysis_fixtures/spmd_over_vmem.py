# Seeded-bug fixture for the VMEM certification pass (exactly ONE planted
# defect): a cg_matvec tile whose resident factors (netflix-full mode-0
# extent at rank 64) cannot fit a 16 MiB core. The analyzer must report
# SP201 and nothing else.
FAMILY = "cg_matvec"
TILE = {"block_m": 1024, "block_r": 128}
GEOMETRY = {
    "nd": 3,
    "rank": 64,
    "factor_rows": (17_770, 2_182),   # resident non-target factors
    "capacity": 4096,
    "x_rows": 480_189,                # the CG direction spans mode 0
}
BUDGET_MB = 16
