# Suppression-syntax fixture: mixes valid, reasonless, and unknown-rule
# suppressions (tests/test_analysis.py). Never imported.
import time


def reasonless(f, x):
    t0 = time.perf_counter()  # repro-lint: disable=JS003
    f(x)
    return time.perf_counter() - t0  # repro-lint: disable=JS003 -- fixture: reasonless above stays blocking


def unknown_rule(f, x):
    t0 = time.perf_counter()  # repro-lint: disable=JS999 -- no such rule
    f(x)
    t1 = time.perf_counter()  # repro-lint: disable=JS003 -- fixture: valid suppression
    return t1 - t0


def comment_line_covers_next(f, x):
    # repro-lint: disable=JS003 -- fixture: comment-only line covers next line
    t0 = time.perf_counter()
    f(x)
    return t0
