# Known-GOOD twin of bad_lint.py: the same intents expressed with jit-safe /
# fenced / seeded idioms. The linter must emit ZERO findings on this file
# even under the strict jit-reachable rule set. Never imported.
import time

import jax
import numpy as np
import jax.numpy as jnp


def good_branch(x):
    return jnp.where(jnp.sum(x) > 0, x, -x)          # jnp.where, not `if`


def good_loop(x):
    return jax.lax.while_loop(lambda s: s[1] > 1e-3,
                              lambda s: (s[0] * 0.5, s[1] * 0.5),
                              (x, 1.0))[0]


def good_host_branch(n: int, x):
    if n > 3:                # branching on a static Python value is fine
        return x
    return -x


def good_fetch(x):
    return jax.device_get(jnp.sum(x))     # explicit eager-boundary fetch


def good_timing(f, x):
    out = f(x)
    jax.block_until_ready(out)            # fenced before reading the clock
    t0 = time.perf_counter()
    jax.block_until_ready(f(x))
    return time.perf_counter() - t0


def good_timing_closure(f, x):
    def run():
        return jax.block_until_ready(f(x))
    run()
    t0 = time.perf_counter()              # fence lives in the closure above
    run()
    return time.perf_counter() - t0


def good_print(xs):
    total = sum(xs)
    print("done:", total)                 # print outside any loop is fine


def good_rng():
    rng = np.random.default_rng(1234)     # seeded generator
    return rng.standard_normal(3)
