# Known-BAD fixture for the jit-safety linter (tests/test_analysis.py).
# Every block below must be flagged by exactly the rule named in its comment
# when linted with the jit-reachable rule set. This file is never imported.
import logging
import random
import time

import jax
import numpy as np
import jax.numpy as jnp

log = logging.getLogger(__name__)


def js001_if(x):
    if jnp.sum(x) > 0:            # JS001: Python `if` on a traced value
        return x
    return -x


def js001_while(x):
    while jnp.linalg.norm(x) > 1e-3:   # JS001: `while` on a traced value
        x = x * 0.5
    return x


def js001_ternary(x):
    return x if jnp.any(x) else -x     # JS001: ternary on a traced value


def js001_assert(x):
    assert jnp.all(x > 0)              # JS001: assert on a traced value
    return x


def js002_item(x):
    return jnp.sum(x).item()           # JS002: .item() host sync


def js002_float(x):
    return float(jnp.sum(x))           # JS002: float() of traced expr


def js002_asarray(x):
    return np.asarray(jnp.exp(x))      # JS002: np.asarray of traced expr


def js003_unfenced(f, x):
    t0 = time.perf_counter()           # JS003: no fence in this function
    f(x)
    return time.perf_counter() - t0    # JS003


def js004_print_loop(xs):
    for x in xs:
        print("step", x)               # JS004: print inside loop body


def js004_log_loop(xs):
    for x in xs:
        log.info("step %s", x)         # JS004: logging inside loop body


def js005_stdlib():
    return random.random()             # JS005: stdlib global RNG


def js005_np_legacy():
    return np.random.rand(3)           # JS005: legacy global np RNG


def js005_seedless():
    return np.random.default_rng()     # JS005: entropy-seeded generator
