"""Checkpoint correctness sweep: async-failure propagation, gc boundary
semantics, and restore-time leaf validation (the serving layer's trust
boundary — see DESIGN.md §14)."""
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import checkpointer as ckpt
from repro.checkpoint.checkpointer import (Checkpointer, latest_step,
                                           read_manifest, restore, save)


# ---- async save failures must not be swallowed -----------------------------

def test_async_failure_reraises_at_wait(tmp_path, monkeypatch):
    def boom(*a, **k):
        raise OSError("disk full")
    monkeypatch.setattr(ckpt, "save", boom)
    ck = Checkpointer(str(tmp_path))
    ck.save_async(5, {"w": jnp.ones(3)})
    with pytest.raises(RuntimeError, match="step 5") as ei:
        ck.wait()
    assert isinstance(ei.value.__cause__, OSError)
    ck.wait()                       # error state cleared by the raise


def test_async_failure_reraises_at_next_save_async(tmp_path, monkeypatch):
    real_save = ckpt.save
    def boom(*a, **k):
        raise OSError("disk full")
    monkeypatch.setattr(ckpt, "save", boom)
    ck = Checkpointer(str(tmp_path))
    ck.save_async(1, {"w": jnp.ones(3)})
    monkeypatch.setattr(ckpt, "save", real_save)
    with pytest.raises(RuntimeError, match="step 1"):
        ck.save_async(2, {"w": jnp.ones(3)})
    # the failure is not sticky: a later save succeeds and commits
    ck.save_async(3, {"w": jnp.ones(3)})
    ck.wait()
    assert ck.latest() == 3


def test_async_organic_failure(tmp_path):
    """No monkeypatching: an uncreatable directory (parent is a file)."""
    blocker = tmp_path / "blocker"
    blocker.write_text("not a directory")
    ck = Checkpointer(str(blocker / "sub"))
    ck.save_async(0, {"w": jnp.ones(2)})
    with pytest.raises(RuntimeError, match="step 0"):
        ck.wait()


# ---- gc boundary: keep_last in {0, 1} --------------------------------------

def _steps_on_disk(path):
    return sorted(int(d.split("_")[1]) for d in os.listdir(path)
                  if d.startswith("step_") and not d.endswith(".tmp"))


def test_gc_keep_last_zero_keeps_nothing(tmp_path):
    # regression: steps[:-0] is the empty slice, so keep_last=0 used to
    # delete nothing at all (the opposite of "keep nothing")
    for s in range(3):
        save(str(tmp_path), s, {"x": jnp.ones(2)}, keep_last=0)
    assert _steps_on_disk(tmp_path) == []
    assert latest_step(str(tmp_path)) is None


def test_gc_keep_last_one(tmp_path):
    for s in range(4):
        save(str(tmp_path), s, {"x": jnp.ones(2)}, keep_last=1)
    assert _steps_on_disk(tmp_path) == [3]


# ---- restore-time validation against manifest AND `like` -------------------

def test_restore_rejects_shape_drift(tmp_path):
    save(str(tmp_path), 1, {"factor_0": jnp.ones((6, 4))})
    with pytest.raises(ValueError, match=r"factor_0.*\(6, 3\)"):
        restore(str(tmp_path), 1, {"factor_0": jnp.zeros((6, 3))})


def test_restore_rejects_dtype_drift(tmp_path):
    save(str(tmp_path), 1, {"w": jnp.ones((3,), jnp.float32)})
    with pytest.raises(ValueError, match="dtype"):
        restore(str(tmp_path), 1, {"w": jnp.zeros((3,), jnp.int32)})


def test_restore_rejects_corrupted_leaf(tmp_path):
    save(str(tmp_path), 2, {"w": jnp.ones((3, 3))})
    # truncate the array on disk behind the manifest's back
    step_dir = os.path.join(tmp_path, "step_000000002")
    [npy] = [f for f in os.listdir(step_dir) if f.endswith(".npy")]
    np.save(os.path.join(step_dir, npy), np.ones((2, 3), np.float32))
    with pytest.raises(ValueError, match="corrupted"):
        restore(str(tmp_path), 2, {"w": jnp.zeros((3, 3))})


def test_restore_rejects_missing_leaf(tmp_path):
    save(str(tmp_path), 1, {"a": jnp.ones(2)})
    with pytest.raises(ValueError, match="__b__"):
        restore(str(tmp_path), 1, {"a": jnp.zeros(2), "b": jnp.zeros(2)})


def test_restore_valid_roundtrip_and_manifest(tmp_path):
    state = {"factor_0": jnp.arange(8.0).reshape(4, 2),
             "factor_1": jnp.arange(6.0).reshape(3, 2)}
    save(str(tmp_path), 9, state, metadata={"rank": 2})
    man = read_manifest(str(tmp_path), 9)
    assert man["metadata"]["rank"] == 2
    # dict keys are path-sanitized (e.g. __factor_0__); the serving layer
    # recovers the mode with re.search, so match the same way here
    [k0] = [k for k in man["leaves"] if "factor_0" in k]
    assert man["leaves"][k0]["shape"] == [4, 2]
    got, _ = restore(str(tmp_path), 9,
                     {k: jnp.zeros_like(v) for k, v in state.items()})
    for k in state:
        np.testing.assert_allclose(got[k], state[k])
