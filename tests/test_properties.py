"""Hypothesis property tests on the system's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st_

from repro.core import losses as L
from repro.core.sparse_tensor import SparseTensor
from repro.core.completion.als import batched_cg
from repro.core.tttp import multilinear_values, tttp
from repro.sparse import ops as sops

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


dims = st_.tuples(st_.integers(3, 20), st_.integers(3, 15),
                  st_.integers(3, 10))


@given(dims, st_.integers(5, 60), st_.integers(1, 12), st_.integers(0, 2 ** 31))
def test_tttp_linearity_in_values(shape, nnz, r, seed):
    """TTTP(αS, A) == α·TTTP(S, A) and TTTP(S+S', A) == TTTP(S)+TTTP(S')."""
    key = jax.random.PRNGKey(seed % (2 ** 31))
    s = SparseTensor.random(key, shape, nnz, cap=nnz + 5)
    ks = jax.random.split(key, 3)
    factors = [jax.random.normal(k, (d, r)) for k, d in zip(ks, shape)]
    a = tttp(s.scale(2.5), factors).values
    b = 2.5 * tttp(s, factors).values
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)
    s2 = s.with_values(jax.random.normal(ks[0], (s.cap,)))
    lhs = tttp(s.add(s2), factors).values
    rhs = tttp(s, factors).values + tttp(s2, factors).values
    np.testing.assert_allclose(lhs, rhs, rtol=1e-4, atol=1e-4)


@given(dims, st_.integers(5, 60), st_.integers(1, 8), st_.integers(0, 2 ** 31))
def test_tttp_rank_additivity(shape, nnz, r, seed):
    """TTTP is linear in the rank dimension: concatenating factor columns
    sums the outputs (the H-slicing identity the parallel algorithm uses)."""
    key = jax.random.PRNGKey(seed % (2 ** 31))
    s = SparseTensor.random(key, shape, nnz)
    ks = jax.random.split(key, 6)
    f1 = [jax.random.normal(k, (d, r)) for k, d in zip(ks[:3], shape)]
    f2 = [jax.random.normal(k, (d, r)) for k, d in zip(ks[3:], shape)]
    cat = [jnp.concatenate([a, b], 1) for a, b in zip(f1, f2)]
    lhs = tttp(s, cat).values
    rhs = tttp(s, f1).values + tttp(s, f2).values
    np.testing.assert_allclose(lhs, rhs, rtol=1e-4, atol=1e-4)


@given(dims, st_.integers(5, 50), st_.integers(1, 8), st_.integers(0, 2 ** 31))
def test_mttkrp_matches_dense_einsum(shape, nnz, r, seed):
    key = jax.random.PRNGKey(seed % (2 ** 31))
    s = SparseTensor.random(key, shape, nnz)
    ks = jax.random.split(key, 3)
    factors = [jax.random.normal(k, (d, r)) for k, d in zip(ks, shape)]
    got = sops.mttkrp(s, [None, factors[1], factors[2]], 0)
    want = jnp.einsum("ijk,jr,kr->ir", s.todense(), factors[1], factors[2])
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@given(dims, st_.integers(5, 50), st_.integers(0, 2 ** 31))
def test_transpose_roundtrip(shape, nnz, seed):
    key = jax.random.PRNGKey(seed % (2 ** 31))
    s = SparseTensor.random(key, shape, nnz)
    perm = (2, 0, 1)
    inv = (1, 2, 0)
    back = s.transpose(perm).transpose(inv)
    np.testing.assert_allclose(back.todense(), s.todense())


@given(dims, st_.integers(5, 50), st_.integers(0, 2 ** 31))
def test_reshape_preserves_values(shape, nnz, seed):
    key = jax.random.PRNGKey(seed % (2 ** 31))
    s = SparseTensor.random(key, shape, nnz)
    flat = s.reshape((int(np.prod(shape)),))
    np.testing.assert_allclose(jnp.sort(flat.masked_values()),
                               jnp.sort(s.masked_values()))


@given(st_.integers(2, 30), st_.integers(1, 10), st_.integers(0, 2 ** 31))
def test_batched_cg_solves_spd(n, r, seed):
    """CG solves random SPD systems to tolerance within r iterations."""
    key = jax.random.PRNGKey(seed % (2 ** 31))
    a = jax.random.normal(key, (n, r, r))
    spd = jnp.einsum("nij,nkj->nik", a, a) + \
        3e-1 * jnp.eye(r)[None]
    b = jax.random.normal(jax.random.fold_in(key, 1), (n, r))
    mv = lambda x: jnp.einsum("nij,nj->ni", spd, x)
    x, iters = batched_cg(mv, b, jnp.zeros_like(b), tol=1e-6,
                          max_iters=4 * r + 10)
    np.testing.assert_allclose(mv(x), b, rtol=2e-3, atol=2e-3)


# clamp-region sampling shared with the hypothesis-free suite
from test_losses import _sample as _loss_sample_points


@given(st_.sampled_from(list(L.LOSSES)), st_.integers(0, 2 ** 31))
def test_loss_grads_match_autodiff(name, seed):
    """Hand-written loss gradients == jax.grad, clamp regions included."""
    loss = L.LOSSES[name]
    t, m = _loss_sample_points(name, seed % (2 ** 31))
    got = loss.grad(t, m)
    want = jax.vmap(jax.grad(lambda mm, tt: loss.value(tt, mm)))(m, t)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@given(st_.sampled_from(list(L.LOSSES)), st_.integers(0, 2 ** 31))
def test_loss_hess_match_autodiff(name, seed):
    """Hand-written loss curvatures == jax.grad of Loss.grad (the GGN
    weights), clamp regions included — poisson curvature vanishes below the
    floor, huber outside delta."""
    loss = L.LOSSES[name]
    t, m = _loss_sample_points(name, seed % (2 ** 31))
    got = loss.hess(t, m)
    want = jax.vmap(jax.grad(lambda mm, tt: loss.grad(tt, mm)))(m, t)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_poisson_grad_is_one_below_floor():
    """Regression: the clamped poisson grad is exactly 1 where m ≤ ε (the
    log(max(m, ε)) term is constant there), not 1 − t/ε."""
    t = jnp.array([3.0, 1.0, 7.0])
    m = jnp.array([-1.0, 0.0, L._EPS * 0.25])
    np.testing.assert_allclose(L.poisson.grad(t, m), jnp.ones(3))
    np.testing.assert_allclose(L.poisson.hess(t, m), jnp.zeros(3))


@given(dims, st_.integers(5, 40), st_.integers(5, 40), st_.integers(0, 2 ** 31))
def test_union_add_commutes(shape, n1, n2, seed):
    key = jax.random.PRNGKey(seed % (2 ** 31))
    a = SparseTensor.random(key, shape, n1)
    b = SparseTensor.random(jax.random.fold_in(key, 1), shape, n2)
    ab = sops.sparse_add_union(a, b).todense()
    ba = sops.sparse_add_union(b, a).todense()
    np.testing.assert_allclose(ab, ba, rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# planner candidate-path equivalence on random order-3/4 IRs (values AND
# gradients vs the dense reference) — the deterministic seed-grid variant
# always runs in tests/test_planner_properties.py; under hypothesis the
# same helpers fuzz over the whole (family, order, seed) space.
# ---------------------------------------------------------------------------
from test_planner_properties import (KINDS, check_all_paths_grads_match_dense,
                                     check_all_paths_match_dense,
                                     random_ir_case)


@settings(max_examples=40, deadline=None)
@given(st_.sampled_from(KINDS), st_.sampled_from((3, 4)),
       st_.integers(0, 2 ** 31))
def test_random_ir_paths_match_dense_fuzzed(kind, order, seed):
    expr, ops = random_ir_case(kind, order, seed % (2 ** 31))
    check_all_paths_match_dense(expr, ops)


@settings(max_examples=10, deadline=None)
@given(st_.sampled_from(KINDS), st_.sampled_from((3, 4)),
       st_.integers(0, 2 ** 31))
def test_random_ir_path_grads_match_dense_fuzzed(kind, order, seed):
    expr, ops = random_ir_case(kind, order, seed % (2 ** 31))
    check_all_paths_grads_match_dense(expr, ops)


@given(dims, st_.integers(10, 60), st_.integers(1, 6), st_.integers(2, 4),
       st_.integers(0, 2 ** 31))
def test_h_sliced_tttp_invariant(shape, nnz, r_per, h, seed):
    """Paper's H-slicing: slicing R into H column groups is exact."""
    from repro.core.tttp import tttp_sliced
    key = jax.random.PRNGKey(seed % (2 ** 31))
    r = r_per * h
    s = SparseTensor.random(key, shape, nnz)
    ks = jax.random.split(key, 3)
    factors = [jax.random.normal(k, (d, r)) for k, d in zip(ks, shape)]
    np.testing.assert_allclose(tttp_sliced(s, factors, h).values,
                               tttp(s, factors).values,
                               rtol=1e-4, atol=1e-4)
