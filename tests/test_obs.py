"""Telemetry subsystem tests (DESIGN.md §11): span nesting + aggregation,
JSONL round-trip, jit-safety of the disabled path, planner plan records,
ingest gauges, and the measured-overhead bound on a real ALS run."""
import json
import os
import time

import jax
import jax.numpy as jnp
import pytest

from repro import obs
from repro.obs.metrics import MetricsRegistry, Timing, _jsonable


@pytest.fixture(autouse=True)
def _clean_obs():
    """Every test starts and ends with tracing off and a fresh registry."""
    obs.disable()
    obs.get_registry().reset()
    yield
    obs.disable()
    obs.get_registry().reset()


# ---------------------------------------------------------------------------
# registry primitives
# ---------------------------------------------------------------------------

def test_timing_summary_quantiles():
    t = Timing()
    for v in [0.001 * i for i in range(1, 101)]:
        t.observe(v)
    s = t.summary()
    assert s["count"] == 100
    assert s["min_s"] == pytest.approx(0.001)
    assert s["max_s"] == pytest.approx(0.100)
    assert s["mean_s"] == pytest.approx(0.0505)
    assert 0.045 <= s["p50_s"] <= 0.055
    assert 0.090 <= s["p95_s"] <= 0.100


def test_timing_reservoir_bounded():
    t = Timing()
    for i in range(5000):
        t.observe(float(i))
    assert len(t.samples) <= 512
    assert t.count == 5000       # exact stats unaffected by the reservoir
    assert t.max == 4999.0


def test_registry_counters_gauges():
    r = MetricsRegistry()
    r.counter_add("c")
    r.counter_add("c", 2.0)
    r.gauge_set("g", 7.5)
    s = r.summary()
    assert s["counters"]["c"] == 3.0
    assert s["gauges"]["g"] == 7.5
    r.reset()
    assert r.summary() == {"counters": {}, "gauges": {}, "timings": {},
                           "plans": {}}


def test_plan_record_freezes_prediction_and_accumulates():
    r = MetricsRegistry()
    r.record_plan("k", "mttkrp", "kr_first", "ijk,jr,kr->ir",
                  {"flops": 10.0, "seconds": 2.0}, 1.0)
    r.record_plan("k", "mttkrp", "kr_first", "ijk,jr,kr->ir",
                  {"flops": 99.0, "seconds": 99.0}, 3.0)   # ignored: frozen
    p = r.summary()["plans"]["k"]
    assert p["predicted"]["seconds"] == 2.0
    assert p["measured"]["count"] == 2
    assert p["measured_over_predicted"] == pytest.approx(1.0)  # mean 2.0 / 2.0


def test_jsonable_coerces_array_scalars():
    assert _jsonable(jnp.float32(1.5)) == 1.5
    assert _jsonable({"a": (jnp.int32(2), None)}) == {"a": [2, None]}


# ---------------------------------------------------------------------------
# spans
# ---------------------------------------------------------------------------

def test_span_disabled_is_noop():
    with obs.span("x") as sp:
        assert sp.record is None
        assert sp.fence(42) == 42          # fence passes through, no jax call
    assert obs.get_registry().summary()["timings"] == {}


def test_span_nesting_and_aggregation():
    obs.enable()
    with obs.span("outer", tag="t") as outer:
        with obs.span("inner") as inner:
            time.sleep(0.001)
        assert inner.record["path"] == "outer/inner"
    rec = outer.record
    assert rec["name"] == "outer" and rec["path"] == "outer"
    assert rec["attrs"] == {"tag": "t"}
    assert [c["path"] for c in rec["children"]] == ["outer/inner"]
    assert rec["dur_s"] >= rec["children"][0]["dur_s"] >= 0.001
    assert obs.last_root() is rec
    timings = obs.get_registry().summary()["timings"]
    assert timings["outer"]["count"] == 1
    assert timings["outer/inner"]["count"] == 1


def test_span_exception_still_closes():
    obs.enable()
    with pytest.raises(ValueError):
        with obs.span("boom"):
            raise ValueError("x")
    assert obs.get_registry().summary()["timings"]["boom"]["count"] == 1


def test_jsonl_round_trip(tmp_path):
    path = os.path.join(tmp_path, "t.jsonl")
    obs.enable(jsonl=path)
    with obs.span("a", k=1):
        with obs.span("b"):
            pass
    obs.emit_event({"kind": "custom", "v": jnp.float32(2.0)})
    obs.disable()
    events = obs.read_jsonl(path)
    kinds = [e["kind"] for e in events]
    assert kinds == ["span", "span", "custom"]     # children close first
    by_path = {e.get("path"): e for e in events if e["kind"] == "span"}
    assert by_path["a"]["attrs"] == {"k": 1}
    assert by_path["a/b"]["depth"] == 2
    assert "children" not in by_path["a"]          # sink stream stays flat
    assert events[2]["v"] == 2.0
    for e in events:
        json.dumps(e)                              # every event JSON-clean


# ---------------------------------------------------------------------------
# jit-safety: the enabled path must be a no-op inside traced code
# ---------------------------------------------------------------------------

def test_span_inside_jit_no_tracer_leak():
    obs.enable()

    def f(x):
        with obs.span("traced", n=3) as sp:
            return sp.fence(x * 2.0)

    eager = f(jnp.arange(4.0))
    jitted = jax.jit(f)(jnp.arange(4.0))
    assert jnp.allclose(eager, jitted)
    timings = obs.get_registry().summary()["timings"]
    # the eager call recorded; the traced call must NOT have
    assert timings["traced"]["count"] == 1


def test_disabled_span_compiles_identically():
    def f(x):
        with obs.span("s") as sp:
            return sp.fence(jnp.sum(x * x))

    x = jnp.arange(8.0)
    assert jax.jit(f)(x) == f(x)


# ---------------------------------------------------------------------------
# integration: planner plan table, kernel spans, ingest gauges
# ---------------------------------------------------------------------------

def test_planner_records_predicted_vs_measured():
    from repro import planner
    from repro.core.sparse_tensor import SparseTensor

    st = SparseTensor.random(jax.random.PRNGKey(0), (30, 20, 10), 300)
    fs = [jax.random.normal(jax.random.PRNGKey(i), (d, 4))
          for i, d in enumerate(st.shape)]
    obs.enable()
    out = planner.planned_mttkrp(st, [None, fs[1], fs[2]], mode=0)
    out2 = planner.planned_mttkrp(st, [None, fs[1], fs[2]], mode=0)
    assert jnp.allclose(out, out2)
    plans = obs.get_registry().summary()["plans"]
    assert len(plans) == 1
    (key, p), = plans.items()
    assert "m300" in key and p["kind"] == "mttkrp"
    assert p["measured"]["count"] == 2
    assert p["predicted"]["seconds"] > 0
    assert set(p["predicted"]) >= {"flops", "mem", "comm", "seconds"}
    # the dispatch span landed in the timing histogram under planner/<kind>
    timings = obs.get_registry().summary()["timings"]
    assert any(k.startswith("planner/mttkrp/") for k in timings), \
        timings.keys()


def test_kernel_wrapper_spans():
    from repro.core.sparse_tensor import SparseTensor
    from repro.kernels import ops as kops

    st = SparseTensor.random(jax.random.PRNGKey(2), (20, 15, 10), 150)
    fs = [jax.random.normal(jax.random.PRNGKey(30 + i), (d, 4))
          for i, d in enumerate(st.shape)]
    obs.enable()
    kops.tttp_values(st, fs, use_pallas=False)
    out = kops.mttkrp_bucketed(st.row_buckets(0, 8), [None, fs[1], fs[2]],
                               num_rows=20, use_pallas=False)
    assert out.shape == (20, 4)
    timings = obs.get_registry().summary()["timings"]
    assert "kernel/tttp" in timings
    assert "kernel/mttkrp_bucketed" in timings


def test_planner_result_unchanged_by_tracing():
    from repro import planner
    from repro.core.sparse_tensor import SparseTensor

    st = SparseTensor.random(jax.random.PRNGKey(1), (25, 15, 10), 200)
    fs = [jax.random.normal(jax.random.PRNGKey(10 + i), (d, 3))
          for i, d in enumerate(st.shape)]
    off = planner.planned_mttkrp(st, [None, fs[1], fs[2]], mode=0)
    obs.enable()
    on = planner.planned_mttkrp(st, [None, fs[1], fs[2]], mode=0)
    assert jnp.allclose(off, on)


def test_ingest_telemetry(tmp_path):
    from repro.data import streaming

    obs.enable()
    chunks = streaming.make_stream("function", 0, (40, 30, 20), 2000, 512)
    ing = streaming.StreamingIngest((40, 30, 20), num_shards=2)
    for c in chunks:
        ing.add(c)
    ing.finalize()
    stats = ing.stats
    assert stats.ingest_seconds > 0
    assert stats.mnnz_per_s > 0
    assert stats.peak_rss_mb > 0
    s = obs.get_registry().summary()
    assert s["gauges"]["ingest/mnnz_per_s"] == pytest.approx(
        stats.mnnz_per_s)
    assert s["counters"]["ingest/entries_read"] >= 2000


# ---------------------------------------------------------------------------
# overhead bound: tracing a real 10-sweep ALS run costs <2%
# ---------------------------------------------------------------------------

def test_tracing_overhead_under_two_percent():
    from repro.core.completion import als_sweep
    from repro.core.sparse_tensor import SparseTensor

    st = SparseTensor.random(jax.random.PRNGKey(3), (60, 50, 40), 4000)
    omega = st.with_values(jnp.ones_like(st.values))
    fs0 = [jax.random.normal(jax.random.PRNGKey(20 + i), (d, 6)) / 6 ** 0.5
           for i, d in enumerate(st.shape)]
    step = jax.jit(lambda fs: tuple(als_sweep(st, omega, list(fs), 1e-3,
                                              cg_iters=4)))

    def run_sweeps():
        fs = tuple(fs0)
        for i in range(10):
            with obs.span("sweep", i=i) as sp:
                fs = step(fs)
                sp.fence(fs)
        jax.block_until_ready(fs)
        return fs

    run_sweeps()                                   # compile once
    def best_of(n):
        best = float("inf")
        for _ in range(n):
            t0 = time.perf_counter()
            run_sweeps()
            best = min(best, time.perf_counter() - t0)
        return best

    obs.disable()
    base = best_of(5)
    obs.enable()
    traced = best_of(5)
    obs.disable()
    # 2% of a ~100ms 10-sweep run is ~2ms of timer noise territory on a
    # shared container — allow a small absolute epsilon alongside the bound
    if traced > base * 1.02 + 2e-3:
        # noise is one-sided (other tenants only slow you down): re-measure
        # both arms once before declaring a real tracing regression
        base = min(base, best_of(5))
        obs.enable()
        traced = min(traced, best_of(5))
        obs.disable()
    assert traced <= base * 1.02 + 2e-3, (traced, base)
    reg = obs.get_registry().summary()
    assert reg["timings"]["sweep"]["count"] in (50, 100)  # 10 sweeps x reps
