"""Experiment harness (launch/experiment.py): JSON metrics structure,
held-out RMSE improvement, and metric-history resume through the
RestartableLoop checkpoint manifest."""
import dataclasses
import json
import os

import pytest

from repro.launch.experiment import SPECS, ExperimentSpec, run_experiment

TINY = ExperimentSpec(
    "tiny-test", "netflix", (40, 30, 10), nnz=5_000, chunk_size=1_500,
    rank=4, sweeps=5, test_fraction=0.15, lam=1e-4, seed=0)


def test_known_specs_cover_paper_scales():
    assert {"netflix-ci", "netflix-small", "function-small",
            "paper-netflix", "paper-function"} <= set(SPECS)
    assert SPECS["paper-function"].nnz == 10_000_000_000
    assert SPECS["paper-netflix"].nnz == 100_477_727
    for s in SPECS.values():
        assert set(s.algorithms) <= {"als", "ccd", "sgd", "ggn", "gcp"}


def test_run_experiment_json_and_heldout_rmse_improves(tmp_path):
    report = run_experiment(
        TINY, out_dir=str(tmp_path),
        algorithms=("als", "ggn"), losses=("quadratic",))
    out = tmp_path / "experiment_tiny-test.json"
    assert out.exists()
    on_disk = json.loads(out.read_text())
    assert on_disk["ingest"]["nnz"] == report["ingest"]["nnz"] > 0
    assert on_disk["ingest"]["nnz_rows"] == [40, 30, 10]
    assert len(on_disk["runs"]) == 2
    for run in on_disk["runs"]:
        sweeps = run["sweeps"]
        assert len(sweeps) == TINY.sweeps
        for e in sweeps:
            assert {"sweep", "seconds", "objective", "rmse_train",
                    "rmse_test", "poisson_deviance_test"} <= set(e)
        rmses = [e["rmse_test"] for e in sweeps]
        # held-out RMSE improves monotonically (small tolerance for the
        # final-sweep overfitting wiggle) and substantially overall
        for a, b in zip(rmses, rmses[1:]):
            assert b <= a * 1.05 + 1e-6, (run["algorithm"], rmses)
        assert rmses[-1] < 0.8 * rmses[0], (run["algorithm"], rmses)
        assert run["final"] == sweeps[-1]
        assert run["update_loss"] == "quadratic"


def test_quadratic_solvers_report_surrogate_under_poisson(tmp_path):
    report = run_experiment(
        dataclasses.replace(TINY, sweeps=2), out_dir=str(tmp_path),
        algorithms=("ccd",), losses=("poisson_log",))
    (run,) = report["runs"]
    assert run["loss"] == "poisson_log"
    assert run["update_loss"] == "quadratic"   # Fig.-8 comparison semantics
    assert run["link"] == "identity"
    assert run["sweeps"][-1]["rmse_test"] < run["sweeps"][0]["rmse_test"]


def test_experiment_resumes_metrics_from_manifest(tmp_path):
    """Kill the loop mid-run; the rerun resumes from the checkpoint AND
    rebuilds the earlier sweeps' metrics from the manifest metadata."""
    spec = dataclasses.replace(TINY, sweeps=7)
    ckpt_root = str(tmp_path / "ckpt")
    import repro.runtime.fault_tolerance as ft
    orig_run = ft.RestartableLoop.run

    def failing_run(self, init_state, num_steps, fail_at=None):
        return orig_run(self, init_state, num_steps, fail_at=4)

    ft.RestartableLoop.run = failing_run
    try:
        with pytest.raises(RuntimeError, match="injected failure"):
            run_experiment(spec, out_dir=str(tmp_path), ckpt_root=ckpt_root,
                           algorithms=("als",), losses=("quadratic",))
    finally:
        ft.RestartableLoop.run = orig_run
    report = run_experiment(spec, out_dir=str(tmp_path), ckpt_root=ckpt_root,
                            algorithms=("als",), losses=("quadratic",))
    (run,) = report["runs"]
    # sweeps 0..4 ran pre-failure (checkpointed at 4), 5..6 post-resume;
    # the manifest metadata restored the full per-sweep history
    assert [e["sweep"] for e in run["sweeps"]] == list(range(7))
    # re-running the COMPLETED experiment runs zero sweeps but must not
    # clobber the checkpointed history — the report rebuilds from the
    # manifest (regression: the final re-save used to wipe it)
    report2 = run_experiment(spec, out_dir=str(tmp_path),
                             ckpt_root=ckpt_root, algorithms=("als",),
                             losses=("quadratic",))
    (run2,) = report2["runs"]
    assert [e["sweep"] for e in run2["sweeps"]] == list(range(7))
    assert run2["sweeps"][:5] == run["sweeps"][:5]
