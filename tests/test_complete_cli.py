"""End-to-end LOCAL-vs-mesh equivalence through the real CLI: every
algorithm launched via ``repro.launch.complete --mesh`` on 8 forced host
devices must produce factors matching the LOCAL run to 1e-4, with the
contractions dispatched through ``planner.execute`` (ISSUE 3 acceptance).

Subprocesses (one jax init each) because the forced-device XLA flag must be
set before jax initializes, and the main test process keeps the
single-device view per the harness contract."""
import os
import subprocess
import sys

import numpy as np
import pytest

_DIMS = "24,20,16"
_NNZ = "4000"          # divisible by every data-shard count used below, so
                       # the ingest shuffle (keyed on padded cap) is identical
_COMMON = ["--dataset", "function", "--dims", _DIMS, "--nnz", _NNZ,
           "--sweeps", "2", "--cg-iters", "30", "--cg-tol", "1e-7"]

# (algorithm, mesh, rank): sgd keeps the data axis at size 1 — per-shard
# sampling decorrelates the RNG on >1 data shards by design, so its
# distributed run exercises the model (column-sharded) axis instead; the
# rank must divide the model axis.
CASES = [
    ("als", "4,2", "4"),
    ("ccd", "4,2", "4"),
    ("ccd_tttp", "4,2", "4"),
    ("sgd", "1,8", "8"),
    ("gcp", "4,2", "4"),
    ("ggn", "4,2", "4"),
]


def _run(tmp_path, tag, extra):
    env = dict(os.environ)
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    dump = tmp_path / f"{tag}.npz"
    cmd = [sys.executable, "-m", "repro.launch.complete", *_COMMON, *extra,
           "--ckpt-dir", str(tmp_path / f"ckpt_{tag}"),
           "--dump-factors", str(dump)]
    out = subprocess.run(cmd, env=env, capture_output=True, text=True,
                         timeout=900,
                         cwd=os.path.dirname(os.path.dirname(__file__)))
    assert out.returncode == 0, out.stdout + "\n---\n" + out.stderr
    return np.load(dump)


@pytest.mark.slow
@pytest.mark.parametrize("algo,mesh,rank", CASES,
                         ids=[c[0] for c in CASES])
def test_mesh_run_matches_local(tmp_path, algo, mesh, rank):
    base = ["--algorithm", algo, "--rank", rank]
    local = _run(tmp_path, f"{algo}_local", base)
    dist = _run(tmp_path, f"{algo}_mesh",
                base + ["--mesh", mesh, "--force-host-devices", "8"])
    for k in local.files:
        np.testing.assert_allclose(dist[k], local[k], rtol=1e-4, atol=1e-4,
                                    err_msg=f"{algo} factor {k}")
