"""Planner subsystem: IR classification, path equivalence, plan caching,
and the paper-§5.3 cost-model ranking (DESIGN.md §5)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core.api as ctf
from repro import planner
from repro.core.sparse_tensor import SparseTensor
from repro.planner import ir as pir


def _sparse(shape, nnz, seed=0, cap=None):
    return SparseTensor.random(jax.random.PRNGKey(seed), shape, nnz, cap=cap)


def _factor(dim, r, seed):
    return jax.random.normal(jax.random.PRNGKey(100 + seed), (dim, r))


def _operands(expr, shape, nnz, r, seed=0):
    """Build (sparse, dense factors...) operands for a one-sparse expr."""
    lhs, _ = expr.replace(" ", "").split("->")
    terms = lhs.split(",")
    st = _sparse(shape, nnz, seed)
    sizes = dict(zip(terms[0], shape))
    dense = [_factor(sizes[t[0]], r, i) for i, t in enumerate(terms[1:])]
    return (st, *dense)


# every supported pattern family, order 3 through 5
PATTERNS = [
    ("ijk,jr,kr->ir", (13, 11, 7)),          # classic MTTKRP, order 3
    ("ijk,jr,kr->ri", (13, 11, 7)),          # ... rank-first output
    ("ijk,ir,kr->jr", (13, 11, 7)),          # ... middle mode
    ("ijkl,jr,kr,lr->ir", (9, 8, 7, 6)),     # classic MTTKRP, order 4
    ("abcde,br,cr,dr,er->ar", (7, 6, 5, 4, 3)),  # classic MTTKRP, order 5
    ("ijkl,kr,lr->ijr", (9, 8, 7, 6)),       # partial MTTKRP, multi-out
    ("ijkl,kr,lr->jir", (9, 8, 7, 6)),       # ... permuted output
    ("ijk,ir,jr,kr->r", (13, 11, 7)),        # full contraction onto rank
    ("ijk,kr->ijr", (13, 11, 7)),            # TTM
    ("ijkl,jr->ilkr", (9, 8, 7, 6)),         # TTM, middle mode + permuted out
    ("ijk,ir,jr,kr->ijk", (13, 11, 7)),      # TTTP
    ("ij,ir,jr->ij", (20, 15)),              # SDDMM (order-2 TTTP)
    ("ijk,ir,kr->ijk", (13, 11, 7)),         # partial TTTP
    ("ijk,jr,kr,iy,jy,ky->ir", (13, 11, 7)),  # weighted Gram matvec (eq. 3)
    ("ijk,jr,kr,iy,jy,ky->ri", (13, 11, 7)),  # ... rank-first output
    ("ijkl,jr,kr,lr,iy,jy,ky,ly->ir", (9, 8, 7, 6)),  # ... order 4
    ("ijk->i", (13, 11, 7)),                 # single-mode reduction
    ("ijkl->il", (9, 8, 7, 6)),              # multi-mode subset reduction
    ("ijkl->li", (9, 8, 7, 6)),              # ... permuted output
    ("ijk->", (13, 11, 7)),                  # full reduction
]


@pytest.mark.parametrize("expr,shape", PATTERNS, ids=[p[0] for p in PATTERNS])
def test_every_path_matches_dense_einsum(expr, shape):
    """Forcing each candidate path produces the dense-reference result."""
    ops = _operands(expr, shape, nnz=60, r=4)
    st = ops[0]
    dense_ops = (st.todense(), *ops[1:])
    want = jnp.einsum(expr, *dense_ops)
    plan = ctf.plan(expr, *ops)
    assert len(plan.candidates) >= 1
    for path in plan.candidates:
        got = ctf.einsum(expr, *ops, path=path)
        if isinstance(got, SparseTensor):
            got = got.todense()
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5,
                                   err_msg=f"{expr} via {path}")


def test_reduce_with_trailing_dense_axis():
    """reduce_mode semantics survive the planner: a (cap, R)-valued
    SparseTensor reduces with the trailing axis riding along."""
    st = _sparse((13, 11, 7), 50)
    stR = st.with_values(jnp.broadcast_to(st.values[:, None],
                                          (st.cap, 4)) * jnp.arange(1.0, 5.0))
    got = ctf.einsum("ijk->i", stR)
    np.testing.assert_allclose(got, stR.reduce_mode(0), rtol=1e-6, atol=1e-6)
    assert got.shape == (13, 4)
    # and factor-contracting kinds reject it with a clear error
    with pytest.raises(NotImplementedError):
        ctf.einsum("ijk,jr,kr->ir", stR, _factor(11, 4, 0), _factor(7, 4, 1))


def test_tttp_default_path_is_all_at_once():
    """Paper Fig. 6: the fused all-at-once kernel is the default TTTP route."""
    ops = _operands("ijk,ir,jr,kr->ijk", (13, 11, 7), nnz=60, r=4)
    assert ctf.plan("ijk,ir,jr,kr->ijk", *ops).path == "all_at_once"


def test_pure_dense_delegates():
    a = jax.random.normal(jax.random.PRNGKey(0), (5, 6))
    b = jax.random.normal(jax.random.PRNGKey(1), (6, 7))
    np.testing.assert_allclose(ctf.einsum("ij,jk->ik", a, b), a @ b,
                               rtol=1e-5, atol=1e-5)
    # jnp.einsum accepts lists/scalars; the planner shim must too
    np.testing.assert_allclose(ctf.einsum("i,i->", [1.0, 2.0], [3.0, 4.0]),
                               11.0, rtol=1e-6)


def test_sparse_operand_not_first():
    """The sparse operand may sit anywhere in the operand list."""
    st = _sparse((13, 11, 7), 50)
    v, w = _factor(11, 4, 0), _factor(7, 4, 1)
    want = jnp.einsum("jr,ijk,kr->ir", v, st.todense(), w)
    plan = ctf.plan("jr,ijk,kr->ir", v, st, w)
    for path in plan.candidates:
        got = ctf.einsum("jr,ijk,kr->ir", v, st, w, path=path)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5,
                                   err_msg=f"sparse-mid via {path}")


def test_ir_classification():
    st = _sparse((13, 11, 7), 50)
    v, w = _factor(11, 4, 0), _factor(7, 4, 1)
    assert planner.build_ir("ijk,jr,kr->ir", (st, v, w)).kind == pir.MTTKRP
    assert planner.build_ir("ijk,kr->ijr", (st, w)).kind == pir.TTM
    assert planner.build_ir("ijk->ik", (st,)).kind == pir.REDUCE
    u = _factor(13, 4, 2)
    assert planner.build_ir("ijk,ir,jr,kr->ijk", (st, u, v, w)).kind == pir.TTTP
    assert planner.build_ir("ij,jk->ik", (v, w.T)).kind == pir.DENSE


def test_ir_rejects_unsupported():
    st = _sparse((13, 11, 7), 50)
    st2 = _sparse((13, 11, 7), 50, seed=1)
    v = _factor(11, 4, 0)
    with pytest.raises(NotImplementedError):
        planner.build_ir("ijk,ijk->ijk", (st, st2))  # two sparse operands
    with pytest.raises(ValueError):
        planner.build_ir("ijk,jr,kr->ir", (st, v))   # operand count mismatch
    with pytest.raises(ValueError):
        ctf.einsum("ijk,jr,kr->ir", st, v, _factor(7, 4, 1),
                   path="not_a_path")


def test_plan_cache_identity():
    """Identical static signatures return the *identical* plan object."""
    planner.clear_plan_cache()
    st = _sparse((13, 11, 7), 50)
    v, w = _factor(11, 4, 0), _factor(7, 4, 1)
    p1 = ctf.plan("ijk,jr,kr->ir", st, v, w)
    p2 = ctf.plan("ijk, jr, kr -> ir", st, v, w)     # whitespace-insensitive
    assert p1 is p2
    assert planner.plan_cache_size() == 1
    # same shapes but different values: still the same static signature
    st_b = _sparse((13, 11, 7), 50, seed=9)
    assert ctf.plan("ijk,jr,kr->ir", st_b, v, w) is p1
    # different nnz hint ⇒ different signature ⇒ fresh plan
    st_c = _sparse((13, 11, 7), 40, cap=50)
    assert ctf.plan("ijk,jr,kr->ir", st_c, v, w) is not p1


def test_cost_ranking_hypersparse_prefers_all_at_once():
    """Paper §5.3: at density ≤ 1e-6 the dense-KR intermediate explodes, so
    all-at-once must beat pairwise KR-first (and be the chosen path)."""
    dim, nnz = 4700, 100_000
    st = _sparse((dim, dim, dim), nnz)
    density = nnz / dim ** 3
    assert density <= 1e-6
    f = jnp.zeros((dim, 32))
    plan = ctf.plan("ijk,jr,kr->ir", st, f, f)
    assert plan.cost("all_at_once").seconds < plan.cost("kr_first").seconds
    assert plan.cost("all_at_once").seconds < plan.cost("t_first").seconds
    assert plan.path == "all_at_once"


def test_cost_model_covers_every_candidate():
    for expr, shape in PATTERNS:
        ops = _operands(expr, shape, nnz=30, r=4)
        ir = planner.build_ir(expr, ops)
        for path in planner.candidate_paths(ir):
            c = planner.estimate(ir, path)
            assert c.flops >= 0 and c.mem > 0 and c.seconds > 0


def test_autotune_pins_a_measured_winner():
    planner.clear_plan_cache()
    ops = _operands("ijk,jr,kr->ir", (13, 11, 7), nnz=60, r=4)
    plan = planner.plan_contraction("ijk,jr,kr->ir", ops, autotune=True)
    assert plan.autotuned and plan.timings
    assert plan.path in plan.candidates
    assert {p for p, _ in plan.timings} == set(plan.candidates)
    # the autotuned plan is cached and reused
    assert planner.plan_contraction("ijk,jr,kr->ir", ops, autotune=True) is plan
    # a forced path makes autotune moot and still caches (identity holds)
    forced = planner.plan_contraction("ijk,jr,kr->ir", ops, path="t_first",
                                      autotune=True)
    assert forced.path == "t_first" and not forced.autotuned
    assert planner.plan_contraction("ijk,jr,kr->ir", ops, path="t_first",
                                    autotune=True) is forced


def test_planned_dispatch_under_jit():
    """Planning at trace time: static signature only, bucketed falls back."""
    st = _sparse((13, 11, 7), 60)
    v, w = _factor(11, 4, 0), _factor(7, 4, 1)
    want = jnp.einsum("ijk,jr,kr->ir", st.todense(), v, w)
    for path in (None, "all_at_once", "t_first", "kr_first", "bucketed"):
        f = jax.jit(lambda t, a, b: ctf.einsum("ijk,jr,kr->ir", t, a, b,
                                               path=path))
        np.testing.assert_allclose(f(st, v, w), want, rtol=1e-5, atol=1e-5)


def test_solver_path_overrides_match_default():
    """ALS / CCD / GCP produce identical results with planner dispatch."""
    from repro.core.completion import als, ccd, gcp
    from repro.core.losses import LOSSES
    key = jax.random.PRNGKey(0)
    st = _sparse((12, 10, 8), 120)
    omega = st.with_values(jnp.ones_like(st.values) * st.mask)
    r = 4
    fs = [jax.random.normal(jax.random.fold_in(key, d), (s, r)) * 0.3
          for d, s in enumerate(st.shape)]

    base = als.als_sweep(st, omega, fs, lam=0.1)
    planned = als.als_sweep(st, omega, fs, lam=0.1, mttkrp_path="t_first")
    for a, b in zip(base, planned):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)
    # the override must also reach the H-sliced matvec schedule
    sliced = als.als_sweep(st, omega, fs, lam=0.1, h_slices=2,
                           mttkrp_path="t_first")
    for a, b in zip(base, sliced):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)

    rho = ccd.residual_values(st, fs)
    f1, rho1 = ccd.ccd_sweep_tttp(st, fs, rho, lam=0.1)
    f2, rho2 = ccd.ccd_sweep_tttp(st, fs, rho, lam=0.1, tttp_path="pairwise")
    for a, b in zip(f1, f2):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(rho1, rho2, rtol=1e-4, atol=1e-4)

    loss = LOSSES["quadratic"]
    g1 = gcp.gcp_gradients(st, fs, loss, lam=0.1)
    g2 = gcp.gcp_gradients(st, fs, loss, lam=0.1, mttkrp_path="all_at_once")
    g3 = gcp.gcp_gradients(st, fs, loss, lam=0.1, mttkrp_path="kr_first")
    for a, b, c in zip(g1, g2, g3):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(a, c, rtol=1e-4, atol=1e-4)


def test_tttp_shim_none_and_vector_factors():
    """api.TTTP keeps the paper's Listing-3 surface through the planner."""
    T = _sparse((12, 10, 8), 100)
    U, V, W = (jnp.ones((12, 4)), jnp.ones((10, 4)), jnp.ones((8, 4)))
    S = ctf.TTTP(T, [U, V, W])
    np.testing.assert_allclose(S.masked_values(), 4.0 * T.masked_values(),
                               rtol=1e-6)
    S2 = ctf.TTTP(T, [U, None, W])
    np.testing.assert_allclose(S2.masked_values(), 4.0 * T.masked_values(),
                               rtol=1e-6)
    S3 = ctf.TTTP(T, [jnp.ones(12), jnp.ones(10), jnp.ones(8)])
    np.testing.assert_allclose(S3.masked_values(), T.masked_values(),
                               rtol=1e-6)
    with pytest.raises(ValueError):
        ctf.TTTP(T, [None, None, None])
