"""SparseTensor container + hypersparse kernel behaviour (paper §3.1)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.sparse_tensor import SparseTensor
from repro.core import tttp as core_tttp
from repro.sparse import ops as sops
from repro.sparse.ccsr import build_ccsr


@pytest.fixture
def st():
    return SparseTensor.random(jax.random.PRNGKey(0), (37, 23, 11), 300,
                               cap=384)


def test_todense_roundtrip(st):
    dense = st.todense()
    assert dense.shape == (37, 23, 11)
    # values at stored coordinates present
    assert float(jnp.sum(jnp.abs(dense))) > 0


def test_transpose_matches_dense(st):
    for perm in [(2, 0, 1), (1, 0, 2), (2, 1, 0)]:
        got = st.transpose(perm).todense()
        want = jnp.transpose(st.todense(), perm)
        np.testing.assert_allclose(got, want)


def test_reshape_matches_dense(st):
    got = st.reshape((37 * 23, 11)).todense()
    np.testing.assert_allclose(got, st.todense().reshape(37 * 23, 11))


def test_sort_and_ccsr_invariants(st):
    sts = st.sort_by_mode(0)
    rows = np.asarray(sts.indices[:, 0])[np.asarray(sts.valid)]
    assert (np.diff(rows) >= 0).all()
    cc = build_ccsr(sts, 0)
    nr = int(cc.nnz_rows)
    rid = np.asarray(cc.row_ids)
    rptr = np.asarray(cc.row_ptr)
    uniq, counts = np.unique(rows, return_counts=True)
    assert nr == len(uniq)
    np.testing.assert_array_equal(rid[:nr], uniq)
    np.testing.assert_array_equal(np.diff(rptr[:nr + 1]), counts)
    # Θ(m) storage: capacity never scales with the number of rows
    assert cc.rows_cap <= sts.cap


def test_ttm_variants_agree(st):
    w = jax.random.normal(jax.random.PRNGKey(1), (11, 16))
    dense = sops.ttm_fully_dense(st.todense(), w, 2)
    sparse_dense_out = sops.ttm_dense_output(st, w, 2)
    hyper = sops.ttm_hypersparse(st, w, 2).todense()
    np.testing.assert_allclose(sparse_dense_out, dense, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(hyper, dense, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("mode", [0, 1, 2])
def test_mttkrp_all_paths_agree(st, mode):
    r = 12
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    factors = [jax.random.normal(k, (d, r)) for k, d in zip(ks, st.shape)]
    fac = list(factors)
    fac[mode] = None
    a = sops.mttkrp(st, fac, mode)
    b = sops.mttkrp_pairwise_t_first(st, fac, mode)
    c = sops.mttkrp_pairwise_kr_first(st, fac, mode)
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(a, c, rtol=1e-4, atol=1e-4)


def test_sparse_add_union_patterns():
    a = SparseTensor.random(jax.random.PRNGKey(3), (20, 10, 5), 80)
    b = SparseTensor.random(jax.random.PRNGKey(4), (20, 10, 5), 60)
    got = sops.sparse_add_union(a, b).todense()
    np.testing.assert_allclose(got, a.todense() + b.todense(),
                               rtol=1e-6, atol=1e-6)


def test_sparse_add_union_duplicate_merge():
    idx = jnp.array([[1, 2, 3], [1, 2, 3], [4, 5, 0]], jnp.int32)
    a = SparseTensor.from_coo(idx, jnp.array([1.0, 2.0, 3.0]), (8, 8, 8))
    out = sops.sparse_add_union(a, a)
    dense = out.todense()
    assert float(dense[1, 2, 3]) == 6.0
    assert float(dense[4, 5, 0]) == 6.0


def test_sddmm_matches_dense():
    s = SparseTensor.random(jax.random.PRNGKey(5), (30, 20), 100)
    u = jax.random.normal(jax.random.PRNGKey(6), (30, 8))
    v = jax.random.normal(jax.random.PRNGKey(7), (20, 8))
    got = sops.sddmm(s, u, v).todense()
    want = s.todense() * (u @ v.T)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_tttp_pairwise_and_sliced_equal_allatonce(st):
    r = 16
    ks = jax.random.split(jax.random.PRNGKey(8), 3)
    factors = [jax.random.normal(k, (d, r)) for k, d in zip(ks, st.shape)]
    a = core_tttp.tttp(st, factors).values
    b = core_tttp.tttp_pairwise(st, factors).values
    c = core_tttp.tttp_sliced(st, factors, 4).values
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(a, c, rtol=1e-5, atol=1e-5)
