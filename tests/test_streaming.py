"""Streaming out-of-core ingest (repro.data.streaming, DESIGN.md §10):
stream-vs-memory bit-identity across shard counts, dedup semantics, the
triplet-file reader, incremental bucket patterns, and the netflix_like
duplicate-inflation regression."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import streaming, synthetic
from repro.data.pipeline import CompletionDataset
from repro.sparse.ccsr import IncrementalBucketBuilder, bucket_pattern

SHAPE = (40, 30, 12)


def _chunks(seed=7, nnz=5000, chunk=1200, kind="function", shape=SHAPE):
    gen = (streaming.function_stream if kind == "function"
           else streaming.netflix_stream)
    return list(gen(seed, shape, nnz, chunk))


# ---------------------------------------------------------------------------
# bit-identity: streamed chunks == in-memory, across 1/2/4 shards
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", ["function", "netflix"])
def test_streamed_ingest_bit_identical_across_shards(kind):
    """CompletionDataset built from streamed chunks is bit-identical to the
    in-memory path (all chunks materialized as ONE slab) on the same seed,
    for 1/2/4 shards — global gather comparison, exact equality."""
    chunks = _chunks(kind=kind)
    big = streaming.Chunk(np.concatenate([c.indices for c in chunks]),
                          np.concatenate([c.values for c in chunks]))
    ds_mem = CompletionDataset.from_stream(iter([big]), SHAPE, num_shards=1)
    want_idx, want_vals = ds_mem.gather_global()
    assert want_idx.shape[0] == ds_mem.tensor.nnz > 0
    for shards in (1, 2, 4):
        ds = CompletionDataset.from_stream(iter(chunks), SHAPE,
                                           num_shards=shards)
        gi, gv = ds.gather_global()
        assert np.array_equal(gi, want_idx), f"{shards} shards: indices"
        assert np.array_equal(gv, want_vals), f"{shards} shards: values"
        assert ds.tensor.nnz == want_idx.shape[0]
        # streamed metadata becomes the planner's hints
        assert ds.tensor.nnz_rows == ds.stats.nnz_rows
        assert ds.stats.shard_nnz and sum(ds.stats.shard_nnz) == ds.tensor.nnz


def test_streamed_matches_shuffled_inmemory_entry_set():
    """The streamed path holds the same entry SET as the classic
    shuffle-and-pad ingest of the deduped tensor (layouts differ)."""
    chunks = _chunks()
    ds = CompletionDataset.from_stream(iter(chunks), SHAPE, num_shards=2)
    gi, gv = ds.gather_global()
    # classic path over the same (deduped) entries
    st = streaming.pack_shards(
        [streaming.StreamingIngest(SHAPE, 1).consume(chunks).finalize_shard(0)],
        SHAPE)
    ds2 = CompletionDataset(st, jax.random.PRNGKey(0))
    gi2, gv2 = ds2.gather_global()
    assert np.array_equal(gi, gi2) and np.array_equal(gv, gv2)


def test_first_occurrence_wins_across_chunks():
    """Cross-chunk duplicate coordinates keep the FIRST stream value."""
    idx = np.array([[1, 2, 3], [4, 5, 6]], np.int32)
    c1 = streaming.Chunk(idx, np.array([10.0, 20.0], np.float32))
    c2 = streaming.Chunk(idx[:1], np.array([99.0], np.float32))
    ing = streaming.StreamingIngest(SHAPE, 1)
    ing.add(c1)
    ing.add(c2)
    shards, stats = ing.finalize()
    assert stats.nnz == 2 and stats.duplicates_dropped == 1
    (si, sv) = shards[0]
    row = np.nonzero((si == idx[0]).all(axis=1))[0]
    assert sv[row] == 10.0


def test_spool_dir_out_of_core_equivalent(tmp_path):
    """Spilled (out-of-core) ingest produces the identical dataset."""
    chunks = _chunks()
    ds_mem = CompletionDataset.from_stream(iter(chunks), SHAPE, num_shards=4)
    ds_ooc = CompletionDataset.from_stream(iter(chunks), SHAPE, num_shards=4,
                                           spool_dir=str(tmp_path))
    for a, b in zip(ds_mem.gather_global(), ds_ooc.gather_global()):
        assert np.array_equal(a, b)
    assert any(f.endswith(".npz") for f in os.listdir(tmp_path))


# ---------------------------------------------------------------------------
# split + evaluation
# ---------------------------------------------------------------------------

def test_split_is_deterministic_and_disjoint():
    chunks = _chunks(kind="netflix")
    train, test, stats = streaming.ingest(iter(chunks), SHAPE, num_shards=2,
                                          test_fraction=0.2)
    def lin_set(st):
        idx = np.asarray(st.indices)[np.asarray(st.valid)]
        return set(streaming._linearize64(idx, SHAPE).tolist())
    tr, te = lin_set(train), lin_set(test)
    assert tr and te and not (tr & te)
    frac = len(te) / (len(te) + len(tr))
    assert 0.1 < frac < 0.3
    # same split on re-ingest
    _, test2, _ = streaming.ingest(iter(chunks), SHAPE, num_shards=1,
                                   test_fraction=0.2)
    assert lin_set(test2) == te


def test_heldout_metrics_perfect_model():
    """A rank-1 factorization of its own TTTP has ~zero held-out error."""
    key = jax.random.PRNGKey(0)
    fs = [jnp.abs(jax.random.normal(k, (d, 1))) + 0.5
          for k, d in zip(jax.random.split(key, 3), SHAPE)]
    idx = np.stack(np.unravel_index(np.arange(0, 600, 7),
                                    SHAPE), 1).astype(np.int32)
    from repro.core.sparse_tensor import SparseTensor
    from repro.core.tttp import multilinear_values
    st = SparseTensor.from_coo(idx, np.ones(idx.shape[0], np.float32), SHAPE)
    st = st.with_values(multilinear_values(st, fs))
    m = streaming.heldout_metrics(st, fs)
    assert m["rmse"] < 1e-5
    assert m["count"] == idx.shape[0]
    # log link evaluates exp(model)
    fs_log = [jnp.zeros((d, 1)) for d in SHAPE]
    st1 = st.with_values(jnp.ones_like(st.values))
    m_log = streaming.heldout_metrics(st1, fs_log, link="log")
    assert m_log["rmse"] < 1e-5


def test_heldout_metrics_log_clamp_region():
    """Model values beyond ±30 are clamped BEFORE exp: a huge positive
    log-rate yields exp(30), not inf, and huge negatives stay finite."""
    from repro.core.sparse_tensor import SparseTensor
    rng = np.random.default_rng(3)
    idx = np.stack([rng.integers(0, s, size=32) for s in SHAPE],
                   axis=1).astype(np.int32)
    st = SparseTensor.from_coo(idx, np.ones(32, np.float32), SHAPE)
    for sign in (+1.0, -1.0):
        # rank-1 all-constant factors: model value = sign * 100 everywhere
        fs = [jnp.full((d, 1), c) for d, c in
              zip(SHAPE, (sign * 100.0, 1.0, 1.0))]
        m = streaming.heldout_metrics(st, fs, link="log")
        assert np.isfinite(m["rmse"]) and np.isfinite(m["poisson_deviance"])
        pred = np.exp(sign * 30.0)       # the clamp boundary value
        np.testing.assert_allclose(m["rmse"], abs(pred - 1.0), rtol=1e-4)
    # inside the clamp region the link is exactly exp(model)
    fs = [jnp.full((d, 1), c) for d, c in zip(SHAPE, (2.0, 1.0, 1.0))]
    m = streaming.heldout_metrics(st, fs, link="log")
    np.testing.assert_allclose(m["rmse"], np.exp(2.0) - 1.0, rtol=1e-4)


def test_heldout_metrics_all_masked():
    """A fully-padded (zero valid entries) tensor must not divide by zero
    or poison the metrics with padding rows."""
    from repro.core.sparse_tensor import SparseTensor
    st = SparseTensor.from_coo(np.zeros((0, 3), np.int32),
                               np.zeros((0,), np.float32), SHAPE, cap=16)
    assert int(np.sum(np.asarray(st.mask))) == 0
    fs = [jnp.ones((d, 2)) for d in SHAPE]
    m = streaming.heldout_metrics(st, fs)
    assert m["count"] == 0 or m["count"] == 1   # n clamped to >= 1
    assert m["rmse"] == 0.0
    assert m["poisson_deviance"] == 0.0
    assert np.isfinite(m["rmse"])


# ---------------------------------------------------------------------------
# triplet file reader
# ---------------------------------------------------------------------------

def test_triplet_file_stream_roundtrip(tmp_path):
    chunks = _chunks(nnz=800, chunk=300)
    path = tmp_path / "triplets.txt"
    with open(path, "w") as f:
        f.write("# i j k value\n")
        for c in chunks:
            for (i, j, k), v in zip(c.indices, c.values):
                f.write(f"{i} {j} {k} {v}\n")
    read = list(streaming.triplet_file_stream(str(path), ndim=3,
                                              chunk_size=256))
    assert sum(len(c) for c in read) == sum(len(c) for c in chunks)
    got_idx = np.concatenate([c.indices for c in read])
    want_idx = np.concatenate([c.indices for c in chunks])
    assert np.array_equal(got_idx, want_idx)
    ds_file = CompletionDataset.from_stream(iter(read), SHAPE, num_shards=2)
    ds_mem = CompletionDataset.from_stream(iter(chunks), SHAPE, num_shards=2)
    gi, gv = ds_file.gather_global()
    mi, mv = ds_mem.gather_global()
    assert np.array_equal(gi, mi)
    np.testing.assert_allclose(gv, mv, rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# incremental bucket patterns
# ---------------------------------------------------------------------------

def test_incremental_bucket_pattern_matches_direct():
    """Streamed occupancy counts give the same bucket view as the direct
    host-side build (capacity may be padded up, pattern content equal)."""
    chunks = _chunks(nnz=2000, chunk=500)
    ds = CompletionDataset.from_stream(iter(chunks), SHAPE, num_shards=1,
                                       block_rows=8)
    st = ds.tensor
    for mode in range(st.ndim):
        got = st.row_buckets(mode, 8)          # served from the ingest cache
        direct = bucket_pattern(
            SparseTensor_copy(st), mode, 8).gather(st)
        assert got.values.shape[1] >= direct.values.shape[1]
        cap = direct.values.shape[1]
        np.testing.assert_allclose(np.asarray(got.values)[:, :cap],
                                   np.asarray(direct.values))
        assert not np.asarray(got.valid)[:, cap:].any()


def SparseTensor_copy(st):
    """Pattern-cache-free copy (forces a direct rebuild)."""
    from repro.core.sparse_tensor import SparseTensor
    return SparseTensor(st.indices, st.values, st.valid, st.shape, st.nnz,
                        st.sorted_mode)


def test_incremental_builder_counts_are_upper_bounds():
    chunks = _chunks(nnz=3000, chunk=700)
    ing = streaming.StreamingIngest(SHAPE, 2, block_rows=8)
    for c in chunks:
        ing.add(c)
    shards, stats = ing.finalize()
    st = streaming.pack_shards(shards, SHAPE, stats)
    assert stats.bucket_block_rows == 8
    for mode in range(3):
        actual = np.bincount(
            np.asarray(st.indices)[np.asarray(st.valid)][:, mode] // 8,
            minlength=stats.bucket_counts[mode].shape[0])
        assert (stats.bucket_counts[mode] >= actual).all()


def test_incremental_builder_build_matches_direct():
    """builder.build (streamed-capacity pattern) gathers the same buckets
    as a direct build, padded up to the streamed capacity."""
    chunks = _chunks(nnz=1200, chunk=300)
    b = IncrementalBucketBuilder(SHAPE, 8)
    for c in chunks:
        b.observe(c.indices)
    sh = streaming.StreamingIngest(SHAPE, 1).consume(chunks).finalize_shard(0)
    st = streaming.pack_shards([sh], SHAPE)
    for mode in range(3):
        got = b.build(st, mode).gather(st)
        direct = bucket_pattern(SparseTensor_copy(st), mode, 8).gather(st)
        cap = direct.values.shape[1]
        assert got.values.shape[1] >= cap
        np.testing.assert_allclose(np.asarray(got.values)[:, :cap],
                                   np.asarray(direct.values))
        assert not np.asarray(got.valid)[:, cap:].any()


def test_sorted_mode_fast_path_matches_unsorted():
    """bucket_pattern's argsort-skip for sorted tensors is bit-equivalent."""
    chunks = _chunks(nnz=1500, chunk=400)
    sh = streaming.StreamingIngest(SHAPE, 1).consume(chunks).finalize_shard(0)
    st_sorted = streaming.pack_shards([sh], SHAPE)        # sorted_mode=0
    assert st_sorted.sorted_mode == 0
    st_plain = SparseTensor_copy(st_sorted)
    object.__setattr__(st_plain, "sorted_mode", None)
    a = bucket_pattern(st_sorted, 0, 8)
    b = bucket_pattern(st_plain, 0, 8)
    for f in ("sel", "indices", "local_row", "valid"):
        assert np.array_equal(np.asarray(getattr(a, f)),
                              np.asarray(getattr(b, f))), f


_MESH_SCRIPT = r"""
import jax, numpy as np
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map
from repro.data import streaming
from repro.data.pipeline import CompletionDataset
from repro.core.completion import als_sweep
from repro.core.distributed import DistLayout

mesh = jax.make_mesh((4,), ("data",))
shape = (40, 32, 12)
chunks = list(streaming.function_stream(5, shape, 8000, 2000))
ds = CompletionDataset.from_stream(iter(chunks), shape, mesh=mesh,
                                   bucket_modes=())
layout = DistLayout(mesh, ("data",), None)
st_spec = layout.sparse_specs(ds.tensor)
fs = [jax.random.normal(k, (d, 4))
      for k, d in zip(jax.random.split(jax.random.PRNGKey(0), 3), shape)]
fn = jax.jit(shard_map(
    lambda s, o, f: tuple(als_sweep(s, o, list(f), 1e-4, ctx=layout.ctx)),
    mesh=mesh, in_specs=(st_spec, st_spec, (P(None, None),) * 3),
    out_specs=(P(None, None),) * 3, check_rep=False))
out = fn(ds.tensor, ds.omega, tuple(fs))
ds_l = CompletionDataset.from_stream(iter(chunks), shape, num_shards=1,
                                     bucket_modes=())
out_l = jax.jit(lambda s, o, f: tuple(als_sweep(s, o, list(f), 1e-4)))(
    ds_l.tensor, ds_l.omega, tuple(fs))
for a, b in zip(out, out_l):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=2e-4, atol=2e-4)
print("MESH_STREAM_OK")
"""


@pytest.mark.slow
def test_streamed_dataset_under_mesh_matches_local():
    """from_stream(mesh=...) feeds shard_map ALS with results matching the
    single-shard LOCAL ingest (subprocess: needs 4 forced host devices)."""
    import subprocess
    import sys
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=4",
               JAX_PLATFORMS="cpu",
               PYTHONPATH=os.pathsep.join(
                   [os.path.join(os.path.dirname(__file__), "..", "src")]
                   + os.environ.get("PYTHONPATH", "").split(os.pathsep)))
    res = subprocess.run([sys.executable, "-c", _MESH_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert res.returncode == 0, res.stderr[-2000:]
    assert "MESH_STREAM_OK" in res.stdout


# ---------------------------------------------------------------------------
# netflix_like duplicate-inflation regression (in-memory generator)
# ---------------------------------------------------------------------------

def test_netflix_like_exact_nnz_no_duplicates():
    """Zipf sampling repeats coordinates; the fixed generator dedups and
    returns EXACTLY the requested nnz unique entries (regression pin)."""
    st = synthetic.netflix_like(jax.random.PRNGKey(0), (50, 40, 10), nnz=2000)
    assert st.nnz == 2000
    assert int(np.asarray(st.valid).sum()) == 2000
    idx = np.asarray(st.indices)[np.asarray(st.valid)]
    lin = streaming._linearize64(idx, (50, 40, 10))
    assert np.unique(lin).size == 2000              # Ω is a set
    vals = np.asarray(st.values)[np.asarray(st.valid)]
    assert vals.min() >= 1.0 and vals.max() <= 5.0


def test_netflix_like_rejects_impossible_density():
    with pytest.raises(ValueError):
        synthetic.netflix_like(jax.random.PRNGKey(0), (4, 4, 4), nnz=100)


# ---------------------------------------------------------------------------
# memory boundedness (scaled-down smoke of the 50M benchmark claim)
# ---------------------------------------------------------------------------

def test_metadata_only_ingest_is_chunk_bounded():
    """keep_entries=False drops each chunk after metadata extraction —
    nothing accumulates, so a stream much larger than any chunk completes
    with peak host memory strictly O(chunk) (the 50M-nnz benchmark claim,
    measured for real in benchmarks/bench_ingest.py)."""
    shape = (5000, 4000, 300)
    ing = streaming.StreamingIngest(shape, 8, block_rows=64,
                                    keep_entries=False)
    ing._runs = None                 # hard proof: storing a run would crash
    for c in streaming.function_stream(3, shape, 200_000, 50_000):
        ing.add(c)
    stats = ing.finalize_stats()
    assert stats.nnz == stats.entries_kept > 190_000
    assert all(r > 0 for r in stats.nnz_rows)
    assert stats.bucket_counts is not None
