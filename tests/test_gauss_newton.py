"""Generalized Gauss-Newton solver + planner cg_matvec family tests:
the weighted eq.-3 Gram matvec agrees with the dense reference on EVERY
planner path, the fused kernel is reachable from dispatch, PCG solves SPD
systems, and GGN converges (quadratic: beats the ALS 10-sweep RMSE in ≤ 5
iterations on the synthetic function tensor; generalized losses descend)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import planner
from repro.core import losses as L
from repro.core.completion import als_sweep, batched_pcg, ggn_init, ggn_sweep
from repro.core.completion.als import gram_matvec
from repro.core.completion.gauss_newton import (curvature_tensor,
                                                ggn_update_mode,
                                                joint_ggn_matvec)
from repro.core.completion.gcp import gcp_loss
from repro.core.sparse_tensor import SparseTensor
from repro.core.tttp import multilinear_values


def _problem(key, shape=(13, 11, 7), nnz=60, r=4):
    st = SparseTensor.random(key, shape, nnz, cap=nnz + 6)
    ks = jax.random.split(key, len(shape) + 1)
    fs = [jax.random.normal(k, (d, r)) for k, d in zip(ks, shape)]
    return st, fs


def _dense_gram_matvec(w, fs, mode, x):
    """Dense reference: y[i,r] = Σ_n ω_n kr_{n,r} Σ_s kr_{n,s} x[i_n,s]."""
    nd = w.ndim
    letters = "ijk"
    others = [d for d in range(nd) if d != mode]
    s_terms = [letters[d] + "s" for d in others] + [letters[mode] + "s"]
    r_terms = [letters[d] + "r" for d in others]
    expr = ("ijk," + ",".join(s_terms + r_terms) + "->" + letters[mode] + "r")
    ops = [w] + [fs[d] for d in others] + [x] + [fs[d] for d in others]
    return jnp.einsum(expr, *ops)


@pytest.mark.parametrize("mode", [0, 1, 2])
def test_weighted_gram_matvec_every_path_matches_dense(mode):
    """Acceptance: every planner path of the weighted Gram matvec (fused
    cg_matvec_bucketed, TTTP+MTTKRP, H-sliced, dense) agrees with the dense
    reference to 1e-4 — with NON-uniform curvature weights."""
    key = jax.random.PRNGKey(0)
    st, fs = _problem(key)
    w_st = st.with_values(jnp.abs(st.values) + 0.3)   # ω > 0, non-uniform
    x = jax.random.normal(jax.random.fold_in(key, 5), fs[mode].shape)
    want = _dense_gram_matvec(w_st.todense(), fs, mode, x)
    plan = planner.plan_contraction(
        "abc,bz,cz,ay,by,cy->az" if mode == 0 else
        ("abc,az,cz,by,ay,cy->bz" if mode == 1 else "abc,az,bz,cy,ay,by->cz"),
        tuple([w_st] + [fs[d] for d in range(3) if d != mode] + [x] +
              [fs[d] for d in range(3) if d != mode]))
    assert plan.ir.kind == "cg_matvec"
    assert set(plan.candidates) == {"fused", "tttp_mttkrp", "sliced", "dense"}
    for path in plan.candidates:
        got = planner.planned_cg_matvec(w_st, fs, mode, x, path=path)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4,
                                   err_msg=f"mode {mode} via {path}")
    # cost-model default agrees too
    got = planner.planned_cg_matvec(w_st, fs, mode, x)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_gram_matvec_matvec_path_routes_and_agrees():
    """als.gram_matvec(matvec_path=...) == the direct composition (+λx),
    for every path and under jit (where fused falls back safely)."""
    key = jax.random.PRNGKey(1)
    st, fs = _problem(key)
    w_st = st.with_values(jnp.abs(st.values) + 0.1)
    x = jax.random.normal(jax.random.fold_in(key, 2), fs[0].shape)
    lam = 0.37
    want = gram_matvec(w_st, fs, 0, x, lam=lam)
    for path in ("fused", "tttp_mttkrp", "sliced", "dense", "auto"):
        got = gram_matvec(w_st, fs, 0, x, lam=lam, matvec_path=path)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4,
                                   err_msg=path)
        jitted = jax.jit(lambda w, a, b, c, xx: gram_matvec(
            w, [a, b, c], 0, xx, lam=lam, matvec_path=path))
        np.testing.assert_allclose(jitted(w_st, *fs, x), want,
                                   rtol=1e-4, atol=1e-4, err_msg=path)


def test_fused_path_reaches_cg_matvec_bucketed(monkeypatch):
    """The fused planner path actually lowers onto the previously-unreachable
    kernels.ops.cg_matvec_bucketed (eager dispatch only)."""
    from repro.kernels import ops as kops
    calls = []
    orig = kops.cg_matvec_bucketed
    monkeypatch.setattr(kops, "cg_matvec_bucketed",
                        lambda *a, **k: calls.append(1) or orig(*a, **k))
    key = jax.random.PRNGKey(2)
    st, fs = _problem(key)
    w_st = st.with_values(jnp.ones_like(st.values))
    x = jax.random.normal(key, fs[1].shape)
    planner.planned_cg_matvec(w_st, fs, 1, x, path="fused")
    assert calls, "fused path did not dispatch to cg_matvec_bucketed"


def test_joint_ggn_matvec_matches_dense():
    """The joint GGN matvec covers all N² Jacobian blocks: compare against
    an explicitly assembled dense H = JᵀWJ + shift·I."""
    key = jax.random.PRNGKey(3)
    shape, r = (7, 6, 5), 3
    st, fs = _problem(key, shape=shape, nnz=40, r=r)
    loss = L.quadratic
    w_st, _ = curvature_tensor(st, fs, loss)
    xs = [jax.random.normal(jax.random.fold_in(key, d), f.shape)
          for d, f in enumerate(fs)]
    shift = 0.21
    got = joint_ggn_matvec(st, w_st, fs, xs, shift)
    # dense reference: J columns indexed by (mode, row, r)
    mask = np.asarray(st.mask)
    idx = np.asarray(st.indices)[mask]
    w = np.asarray(w_st.values)[np.asarray(st.mask)]
    f_np = [np.asarray(f) for f in fs]
    m = idx.shape[0]
    cols = []
    for d in range(3):
        jd = np.zeros((m, shape[d], r))
        kr = np.ones((m, r))
        for e in range(3):
            if e != d:
                kr = kr * f_np[e][idx[:, e]]
        for n in range(m):
            jd[n, idx[n, d], :] = kr[n]
        cols.append(jd.reshape(m, -1))
    J = np.concatenate(cols, axis=1)
    H = J.T @ (w[:, None] * J) + shift * np.eye(J.shape[1])
    xflat = np.concatenate([np.asarray(x).ravel() for x in xs])
    want = H @ xflat
    got_flat = np.concatenate([np.asarray(g).ravel() for g in got])
    np.testing.assert_allclose(got_flat, want, rtol=1e-4, atol=1e-4)


def test_batched_pcg_solves_spd_with_preconditioner():
    key = jax.random.PRNGKey(4)
    n, r = 20, 6
    a = jax.random.normal(key, (n, r, r))
    spd = jnp.einsum("nij,nkj->nik", a, a) + 0.3 * jnp.eye(r)[None]
    b = jax.random.normal(jax.random.fold_in(key, 1), (n, r))
    mv = lambda x: jnp.einsum("nij,nj->ni", spd, x)
    diag = jnp.stack([jnp.diag(spd[i]) for i in range(n)])
    x, iters = batched_pcg(mv, b, jnp.zeros_like(b),
                           precond=lambda v: v / diag,
                           tol=1e-6, max_iters=4 * r + 10)
    np.testing.assert_allclose(mv(x), b, rtol=2e-3, atol=2e-3)
    # no preconditioner reduces to plain CG
    x2, _ = batched_pcg(mv, b, jnp.zeros_like(b), tol=1e-6,
                        max_iters=4 * r + 10)
    np.testing.assert_allclose(mv(x2), b, rtol=2e-3, atol=2e-3)


def test_ggn_update_mode_matches_als_for_quadratic():
    """For quadratic loss and μ→0, one per-mode GGN update equals the ALS
    implicit-CG update (same normal equations)."""
    from repro.core.completion.als import als_update_mode
    key = jax.random.PRNGKey(5)
    shape = (15, 12, 10)
    st, fs = _problem(key, shape=shape, nnz=300, r=4)
    omega = st.with_values(jnp.ones_like(st.values))
    lam = 1e-4
    want = als_update_mode(st, omega, list(fs), 0, lam, cg_tol=1e-8,
                           cg_iters=60)
    got = ggn_update_mode(st, list(fs), 0, L.quadratic, lam, damping=0.0,
                          cg_tol=1e-8, cg_iters=60)
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


def _function_problem(seed=0, shape=(80, 70, 60), nnz=40_000, r=8):
    from repro.data import synthetic
    key = jax.random.PRNGKey(seed)
    st = synthetic.function_tensor(key, shape, nnz)
    ks = jax.random.split(key, len(shape))
    fs = [jax.random.normal(k, (d, r)) / r ** 0.5
          for k, d in zip(ks, shape)]
    return st, fs


def _rmse(st, fs):
    model = multilinear_values(st, fs)
    d = (st.values - model) * st.mask
    return float(jnp.sqrt(jnp.sum(d ** 2) / jnp.sum(st.mask)))


def test_ggn_quadratic_reaches_als_10sweep_rmse_in_5_iters():
    """Acceptance: on the synthetic function tensor, GGN with quadratic
    loss reaches the RMSE of 10 ALS sweeps in ≤ 5 GGN iterations (the
    joint LM step + per-mode pass captures cross-mode curvature that
    block-coordinate ALS cannot)."""
    st, fs = _function_problem()
    lam = 1e-5
    omega = st.with_values(jnp.ones_like(st.values))
    als = jax.jit(lambda s, o, f: tuple(als_sweep(s, o, list(f), lam,
                                                  cg_iters=20)))
    f_als = tuple(fs)
    for _ in range(10):
        f_als = als(st, omega, f_als)
    als10 = _rmse(st, list(f_als))

    ggn = jax.jit(lambda s, stt: ggn_sweep(s, stt, L.quadratic, lam,
                                           cg_iters=20))
    state = ggn_init(fs)
    best = np.inf
    for _ in range(5):
        state = ggn(st, state)
        best = min(best, _rmse(st, list(state.factors)))
    assert best <= als10, (best, als10)


@pytest.mark.parametrize("loss_name", ["poisson_log", "logistic", "huber"])
def test_ggn_descends_generalized_losses(loss_name):
    """GGN decreases the generalized objective (second-order counterpart of
    the first-order GCP path) and never increases it (LM acceptance)."""
    st, fs = _problem(jax.random.PRNGKey(6), shape=(25, 20, 15), nnz=900,
                      r=4)
    loss = L.LOSSES[loss_name]
    if loss_name.startswith("poisson"):
        st = st.with_values(jnp.round(jnp.abs(st.values) * 4))
    if loss_name == "logistic":
        st = st.with_values((st.values > 0).astype(jnp.float32))
    fs = [0.3 * f for f in fs]
    lam = 1e-6
    step = jax.jit(lambda s, stt: ggn_sweep(s, stt, loss, lam, cg_iters=12,
                                            joint_iters=8, precond_iters=4))
    state = ggn_init(fs, damping=1e-3)
    hist = [float(gcp_loss(st, list(state.factors), loss, lam))]
    for _ in range(4):
        state = step(st, state)
        hist.append(float(gcp_loss(st, list(state.factors), loss, lam)))
    assert hist[-1] < hist[0], hist
    assert all(b <= a + 1e-5 for a, b in zip(hist, hist[1:])), hist


def test_ggn_poisson_curvature_weights_clamp():
    """Below the poisson floor the curvature weight is exactly 0 (the
    clamped hess), keeping the GGN system PSD."""
    st, fs = _problem(jax.random.PRNGKey(7))
    st = st.with_values(jnp.round(jnp.abs(st.values) * 3))
    fs = [-jnp.abs(f) for f in fs]      # drive the model negative
    w_st, model = curvature_tensor(st, fs, L.poisson)
    assert bool(jnp.all(w_st.values[model < L._EPS * 0.99] == 0.0))
    assert bool(jnp.all(w_st.values >= 0.0))
